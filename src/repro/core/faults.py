"""Deterministic fault-injection plane (the robustness backbone).

Production failure modes — a non-PSD Hessian at layer 40, a NaN logit in
one decode lane, a Mosaic lowering failure, an engine tick dying under a
live queue — are rare, hardware-flavored and unreproducible in CI.  This
module makes every one of them a *named site* (``FAULT_SITES``) that tests
and launchers arm with a *seeded trigger schedule*, so each failure path
executes deterministically.  The full site table, the
``site@trigger[:mode]`` arming grammar, worked examples, and the
supervisor/watchdog knobs that consume the ``serve.*`` sites live in
docs/FAULTS.md.

Hot code calls :func:`fire` (raises :class:`FaultError` when the schedule
triggers) or :func:`poll` (returns the :class:`FaultSpec` for sites whose
fault is a corruption rather than an exception).  Tests use the
:func:`inject` context manager; launchers call
:func:`install_from_config` (``faults.arm=...`` overrides).
"""
from __future__ import annotations

import contextlib
import dataclasses
import zlib
from typing import Dict, Iterator, Optional

import numpy as np

FAULT_SITES = (
    "hessian.cholesky",
    "plan.stage1_executor",
    "plan.stage2_executor",
    "stream.capture_forward",
    "serve.decode_step",
    "serve.prefill_chunk",
    "serve.engine_step",
    "kernels.pallas_dispatch",
    "checkpoint.load",
)


class FaultError(RuntimeError):
    """An injected kill-type fault (carries the site for dispatchers that
    must tell an injected kernel fault from an injected request fault)."""

    def __init__(self, site: str, mode: str, hit: int):
        super().__init__(f"injected fault at {site!r} "
                         f"(mode={mode}, hit {hit})")
        self.site = site
        self.mode = mode
        self.hit = hit


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One armed site: a trigger window + per-hit probability + mode."""
    site: str
    mode: str = "kill"
    first: int = 1          # 1-based first hit the fault may fire at
    last: int = 1           # last hit (inclusive); -1 = no upper bound
    prob: float = 1.0       # per-hit fire probability inside the window


def parse_spec(text: str) -> FaultSpec:
    """Parse one ``site@trigger[:mode]`` spec (grammar in the module doc)."""
    text = text.strip()
    if "@" not in text:
        raise ValueError(f"fault spec needs site@trigger, got {text!r}")
    site, rest = text.split("@", 1)
    site = site.strip()
    if site not in FAULT_SITES:
        raise ValueError(f"unknown fault site {site!r}; "
                         f"known: {', '.join(FAULT_SITES)}")
    mode = "kill"
    if ":" in rest:
        rest, mode = rest.split(":", 1)
    rest = rest.strip()
    if rest.startswith("p"):
        return FaultSpec(site, mode, first=1, last=-1, prob=float(rest[1:]))
    if rest.endswith("+"):
        n = int(rest[:-1])
        return FaultSpec(site, mode, first=n, last=-1)
    if ".." in rest:
        a, b = rest.split("..", 1)
        return FaultSpec(site, mode, first=int(a), last=int(b))
    n = int(rest)
    return FaultSpec(site, mode, first=n, last=n)


class FaultPlane:
    """Armed specs + per-site hit counters + seeded probability streams."""

    def __init__(self):
        self._specs: Dict[str, FaultSpec] = {}
        self._seed = 0
        self._rng: Dict[str, np.random.Generator] = {}
        self.hits: Dict[str, int] = {}
        self.fired: Dict[str, int] = {}

    # -- arming ------------------------------------------------------------

    def arm(self, spec, seed: int = 0) -> FaultSpec:
        if isinstance(spec, str):
            spec = parse_spec(spec)
        if spec.site not in FAULT_SITES:
            raise ValueError(f"unknown fault site {spec.site!r}")
        self._specs[spec.site] = spec
        self._seed = seed
        # schedule is a pure function of (seed, site): replays are identical
        self._rng[spec.site] = np.random.default_rng(
            (seed & 0xFFFFFFFF) ^ zlib.crc32(spec.site.encode()))
        self.hits[spec.site] = 0
        self.fired[spec.site] = 0
        return spec

    def arm_string(self, text: str, seed: int = 0) -> None:
        """Arm a comma-separated spec list (the ``faults.arm`` config knob)."""
        for part in text.split(","):
            if part.strip():
                self.arm(part, seed=seed)

    def disarm(self, site: Optional[str] = None) -> None:
        if site is None:
            self._specs.clear()
            self._rng.clear()
        else:
            self._specs.pop(site, None)
            self._rng.pop(site, None)

    def armed(self, site: str) -> bool:
        return site in self._specs

    # -- hot-path queries --------------------------------------------------

    def poll(self, site: str) -> Optional[FaultSpec]:
        """Count a hit; return the spec iff the schedule fires this hit."""
        spec = self._specs.get(site)
        if spec is None:
            return None
        self.hits[site] = h = self.hits.get(site, 0) + 1
        if h < spec.first or (spec.last >= 0 and h > spec.last):
            return None
        if spec.prob < 1.0 and self._rng[site].random() >= spec.prob:
            return None
        self.fired[site] = self.fired.get(site, 0) + 1
        return spec

    def fire(self, site: str) -> None:
        """Kill-type site: raise :class:`FaultError` when the schedule fires."""
        spec = self.poll(site)
        if spec is not None:
            raise FaultError(site, spec.mode, self.hits[site])

    def stats(self) -> Dict[str, Dict[str, int]]:
        return {"hits": dict(self.hits), "fired": dict(self.fired)}


#: the process-wide plane all sites consult
PLANE = FaultPlane()


def fire(site: str) -> None:
    PLANE.fire(site)


def poll(site: str) -> Optional[FaultSpec]:
    return PLANE.poll(site)


def armed(site: str) -> bool:
    return PLANE.armed(site)


@contextlib.contextmanager
def inject(*specs: str, seed: int = 0) -> Iterator[FaultPlane]:
    """Arm specs for a ``with`` block; previous arming is restored on exit
    (including when the injected fault itself propagates out)."""
    parsed = [parse_spec(s) if isinstance(s, str) else s for s in specs]
    saved = {p.site: PLANE._specs.get(p.site) for p in parsed}
    try:
        for p in parsed:
            PLANE.arm(p, seed=seed)
        yield PLANE
    finally:
        for site, prev in saved.items():
            if prev is None:
                PLANE.disarm(site)
            else:
                PLANE.arm(prev, seed=seed)


def install_from_config(cfg) -> None:
    """Arm the plane from ``cfg.faults`` (launch entry points call this)."""
    fc = getattr(cfg, "faults", None)
    if fc is not None and fc.arm:
        PLANE.arm_string(fc.arm, seed=fc.seed)
