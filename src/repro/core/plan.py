"""Declarative quantization plan: group same-shape linears, execute batched.

The pipeline's capture pass produces one :class:`PlanMember` per linear
(dense taps and stacked MoE expert slices alike). :func:`build_plan` groups
members by ``(out, in, n_last, group_size, blocksize, bits, symmetric)`` —
everything that determines a jit cache entry — and :func:`execute_plan`
hands each group to the **batched executors**
(:func:`repro.core.gptq.gptq_quantize_batched`,
:func:`repro.core.rpiq.rpiq_refine_batched`): the group's weights,
Hessians, grids and last-instance activations are stacked on a leading
axis and quantized in ONE dispatch per stage instead of one per linear.

Why this matters: the paper's headline claim is quantization *throughput*
(single-instance calibration exists to make 4-bit compression cheap on
assistive devices). A transformer layer typically holds ≥4 identically
shaped linears (q/k/v/o) and an MoE layer holds E× identically shaped
expert slices; per-linear dispatch pays trace/dispatch overhead B times
and leaves the accelerator underfilled at small widths. Grouping makes the
cost one compile + one dispatch per *shape class*, with every inner op B×
wider.

MoE starved experts (fewer routed tokens than one quant group) stay inside
their group as a **mask**: the batched RTN fallback is computed for the
whole stack (row-wise, nearly free) and selected per member with
``jnp.where`` — no per-expert Python loop. Members whose input dim doesn't
align to the grid are carried on a per-member fallback list (skip, or
full-row RTN for starved experts), exactly the legacy semantics.

``execute_plan(..., batched=False)`` runs the same plan through the
singleton executors (one dispatch per linear) — the pre-plan reference
path kept for parity tests and the table4 per-linear-vs-batched benchmark.

**Sharded group execution** (``execute_plan(..., mesh=...)``, DESIGN.md
§2.6): each group's stacked slab is embarrassingly parallel over lanes AND
over Cout, so with a ``(data, model)`` mesh the executor lays the slab out
lane-axis over ``data`` and row tiles over ``model``
(:func:`repro.distributed.sharding.quant_group_sharding`), places the
stacked Hessian state lane-local (damp + Cholesky run where their rows
run), and sweeps via ``kernels.ops.gptq_block_sharded`` — one device-local
(member, Cout-tile) kernel per shard, zero sweep collectives.  Groups that
fail the divisibility guards keep the single-device batched path, so every
config stays lowerable; executor cache entries are additionally keyed by
mesh + resolved sharding.  ``quant.mesh`` plumbs this from configs
(launch/mesh.py; docs/QUANTIZATION.md walks the knobs).
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import QuantConfig
from repro.core import faults
from repro.core import hessian as hess
from repro.core.gptq import (GPTQResult, gptq_quantize,
                             gptq_quantize_batched, rtn_quantize,
                             rtn_quantize_batched)
from repro.core.rpiq import RPIQResult, rpiq_refine, rpiq_refine_batched
from repro.distributed.sharding import (QuantGroupSharding,
                                        quant_group_sharding)
from repro.kernels import ops as kops


# ---------------------------------------------------------------------------
# Report records (schema consumed by benchmarks/tables — do not change)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class LinearRecord:
    name: str
    shape: Tuple[int, int]           # (out, in)
    gptq_err: float
    gamma: List[float]               # Γ trajectory (Γ[0] = post-stage-1)
    gamma_final: float
    iters: int
    mode: str                        # "rpiq" | "gptq" | "rtn-fallback" |
    #                                  "rtn-guardrail" | "skipped"
    seconds: float


@dataclasses.dataclass
class QuantReport:
    linears: List[LinearRecord] = dataclasses.field(default_factory=list)
    seconds_total: float = 0.0
    seconds_stage1: float = 0.0
    seconds_stage2: float = 0.0
    peak_resident_bytes: int = 0     # analytic single-instance residency
    # stream-scheduler telemetry (core/stream.py): wall seconds per
    # layer-step (the overlap schedule's only sync point is the step's
    # report boundary, so this is its per-layer measurement; under serial
    # seconds_stage1/2 stay the synchronized per-stage split) and the
    # {mode, steps, spec_captures, repairs, serial_fallbacks} counters.
    layer_step_seconds: List[float] = dataclasses.field(default_factory=list)
    pipeline_stats: Dict[str, Any] = dataclasses.field(default_factory=dict)
    # robustness telemetry (additive; empty = nothing triggered): guardrail
    # ladder outcomes per run ({damp_retries, lanes_flagged,
    # lanes_damp_recovered, lanes_rtn_forced}) and the kernels/ops
    # auto→xla fallback counters observed during the run
    guardrail_stats: Dict[str, int] = dataclasses.field(default_factory=dict)
    kernel_fallbacks: Dict[str, int] = dataclasses.field(default_factory=dict)
    # calibration-coverage honesty: per-MoE-layer count of (token, k)
    # assignments dropped by expert capacity during Hessian capture —
    # these tokens never reach any per-expert Hessian (models/moe.py
    # ``_capacity``), so a nonzero entry means that layer's calibration
    # saw fewer instances than the batch implies
    moe_capacity_dropped: Dict[str, int] = dataclasses.field(
        default_factory=dict)

    def summary(self) -> str:
        n = len(self.linears)
        improved = sum(1 for l in self.linears
                       if l.gamma and l.gamma_final < l.gamma[0] * 0.999)
        return (f"{n} linears quantized; stage2 improved {improved}; "
                f"t={self.seconds_total:.1f}s "
                f"(s1={self.seconds_stage1:.1f} s2={self.seconds_stage2:.1f})")


# ---------------------------------------------------------------------------
# Plan structure
# ---------------------------------------------------------------------------

GroupKey = Tuple[int, int, int, int, int, int, bool]
# (out, in, n_last, group_size, blocksize, bits, symmetric)


@dataclasses.dataclass
class PlanMember:
    """One linear — or a pre-stacked slab of S same-shape linears.

    Singleton (``names is None``): w_oi (out, in), hessian (in, in),
    x_last (n, in), x_count scalar, starved bool.

    Stacked (``names`` lists the S per-slice report names, e.g. one per
    MoE expert): w_oi (S, out, in), hessian (S, in, in)/(S,), x_last
    (S, n, in), x_count (S,), starved bool or (S,) mask. Stacked members
    flow capture → plan → executor → scatter as whole arrays — no
    per-expert device slicing anywhere on the batched path.
    """
    name: str
    w_oi: jax.Array                  # (out, in) | (S, out, in) float32
    hessian: hess.HessianState       # (in, in) | stacked (S, in, in)
    x_last: jax.Array                # (n, in) | (S, n, in) inputs
    x_count: Optional[jax.Array]     # () | (S,) int32 real rows in x_last
    #                                  (None ⇒ all n rows are real)
    starved: Any = False             # bool | (S,) mask: below one quant
    #                                  group of tokens → RTN fallback
    names: Optional[List[str]] = None  # per-slice names when stacked

    @property
    def stacked(self) -> bool:
        return self.names is not None

    @property
    def lanes(self) -> int:
        return len(self.names) if self.stacked else 1

    @property
    def lane_names(self) -> List[str]:
        return self.names if self.stacked else [self.name]

    @property
    def wshape(self) -> Tuple[int, int]:
        return tuple(self.w_oi.shape[-2:])

    def starved_mask(self) -> np.ndarray:
        s = np.asarray(self.starved, bool).reshape(-1)
        return np.full(self.lanes, bool(s[0])) if s.size == 1 else s


@dataclasses.dataclass
class QuantGroup:
    key: GroupKey
    members: List[PlanMember]


@dataclasses.dataclass
class QuantPlan:
    groups: List[QuantGroup]         # batched-executable, grid-aligned
    fallbacks: List[PlanMember]      # in % group/blocksize ≠ 0: skip or
    #                                  full-row RTN (starved)

    @property
    def n_members(self) -> int:
        return sum(len(g.members) for g in self.groups) + len(self.fallbacks)


@dataclasses.dataclass
class MemberResult:
    """Per-member outcome, keyed back to the param tree by ``name``.

    Stacked members return stacked arrays: w_q (S, out, in) and grid
    (S, out, groups) — the scatter assigns them wholesale.
    """
    name: str
    w_q: Optional[jax.Array]         # (out, in)|(S, out, in); None = skipped
    grid: Optional[Tuple[jax.Array, jax.Array]]   # stage-1 (scales, zeros)


def build_plan(qc: QuantConfig, members: List[PlanMember]) -> QuantPlan:
    """Group members by jit-cache identity; order inside a group is the
    member submission order (stable), so scatter-back is positional."""
    groups: Dict[GroupKey, List[PlanMember]] = {}
    fallbacks: List[PlanMember] = []
    for m in members:
        out_dim, in_dim = m.wshape
        if in_dim % qc.blocksize != 0 or in_dim % qc.group_size != 0:
            fallbacks.append(m)
            continue
        key: GroupKey = (out_dim, in_dim, int(m.x_last.shape[-2]),
                         qc.group_size, qc.blocksize, qc.bits, qc.symmetric)
        groups.setdefault(key, []).append(m)
    return QuantPlan([QuantGroup(k, v) for k, v in groups.items()],
                     fallbacks)


# ---------------------------------------------------------------------------
# Execution
# ---------------------------------------------------------------------------

def _gamma_list(hist_row: np.ndarray) -> List[float]:
    return [float(g) for g in hist_row if np.isfinite(g)]


def _as3d(a: jax.Array) -> jax.Array:
    return a if a.ndim == 3 else a[None]


def _lane_x_counts(m: PlanMember) -> jax.Array:
    """(S,) int32 real-row counts; starved lanes report n (see below)."""
    n = m.x_last.shape[-2]
    if m.x_count is None:
        xc = jnp.full((m.lanes,), n, jnp.int32)
    else:
        xc = jnp.asarray(m.x_count, jnp.int32).reshape(-1)
        if xc.shape[0] != m.lanes:
            xc = jnp.broadcast_to(xc, (m.lanes,))
    # starved lanes pair with the identity curvature below: x_count = n
    # keeps the eq.-13 rescale at 1 instead of zeroing it
    return jnp.where(jnp.asarray(m.starved_mask()), n, xc)


def _lane_hessians(m: PlanMember) -> hess.HessianState:
    """(S, in, in) curvature block fed to the batched lanes.

    Starved lanes are masked to RTN afterwards, but they still *execute*
    GPTQ/RPIQ under vmap; a zero-token expert has H = 0 and x_count = 0,
    whose Cholesky is NaN — and a NaN Γ never satisfies the early-stop
    predicate, pinning the whole group's while_loop at t_max. Feed those
    lanes an identity Hessian (count = n) so they converge immediately;
    the mask discards their output either way.
    """
    H = _as3d(m.hessian.H)
    count = jnp.asarray(m.hessian.count, jnp.int32).reshape(-1)
    sv = m.starved_mask()
    if sv.any():
        svj = jnp.asarray(sv)
        n = m.x_last.shape[-2]
        eye = jnp.eye(H.shape[-1], dtype=jnp.float32)
        H = jnp.where(svj[:, None, None], eye, H)
        count = jnp.where(svj, n, count)
    return hess.HessianState(H, count)


# ---------------------------------------------------------------------------
# Cross-layer executor jit cache
#
# Sequential calibration walks the stack layer by layer, but the executor
# entry a group needs is fully determined by its signature — GroupKey plus
# the stage statics, the sweep backend, and (when sharded) the mesh + the
# resolved group sharding.  Keying the jitted stage closures in a
# module-level cache means the q/k/v/o group of layer 7 reuses the entry
# layer 0 compiled (first half of the ROADMAP "cross-layer plan batching"
# item; the pipelined-capture half remains open).  Each cached entry
# additionally FUSES its stage into one dispatch: stage 1 runs damp +
# Cholesky + GPTQ sweep (+ the RTN fallback lane when the group has
# starved members) inside a single jit, stage 2 wraps the RPIQ refinement
# with its statics bound.  Sharded stage-1 entries close over the mesh
# (the sweep goes through gptq_block_sharded's shard_map), so the mesh
# component of the key is what keeps single-device and sharded entries —
# or two different meshes — from aliasing.
# ---------------------------------------------------------------------------

_EXEC_CACHE: Dict[Tuple, Callable] = {}
_EXEC_CACHE_STATS = {"hits": 0, "misses": 0}
_EXEC_CACHE_MAX = 64     # FIFO-evict beyond this: entries hold compiled
#                          executables, and jax.clear_caches() doesn't see
#                          them — a long-lived process sweeping shapes/
#                          configs must not accumulate programs unboundedly


def executor_cache_stats() -> Dict[str, int]:
    """Copy of {hits, misses} for the cross-layer executor cache."""
    return dict(_EXEC_CACHE_STATS)


def clear_executor_cache() -> None:
    _EXEC_CACHE.clear()
    _EXEC_CACHE_STATS["hits"] = 0
    _EXEC_CACHE_STATS["misses"] = 0


def _cached_executor(key: Tuple, make: Callable[[], Callable]) -> Callable:
    fn = _EXEC_CACHE.get(key)
    if fn is None:
        _EXEC_CACHE_STATS["misses"] += 1
        while len(_EXEC_CACHE) >= _EXEC_CACHE_MAX:
            _EXEC_CACHE.pop(next(iter(_EXEC_CACHE)))
        fn = make()
        _EXEC_CACHE[key] = fn
    else:
        _EXEC_CACHE_STATS["hits"] += 1
    return fn


_GUARDRAIL_KEYS = ("damp_retries", "lanes_flagged", "lanes_damp_recovered",
                   "lanes_rtn_forced")


def _guardrail_stats(report: QuantReport) -> Dict[str, int]:
    for k in _GUARDRAIL_KEYS:
        report.guardrail_stats.setdefault(k, 0)
    return report.guardrail_stats


def _finite_lanes(res1: GPTQResult) -> np.ndarray:
    """(B,) host mask: lane produced fully finite stage-1 outputs.

    One fused reduction per array — any NaN/Inf (a failed Cholesky turns
    the whole lane NaN) poisons the lane's sum. This is the guardrail
    ladder's detector, so it synchronizes on stage 1; the transfer is B
    floats.
    """
    tot = (jnp.sum(res1.w_q, axis=(-2, -1)) +
           jnp.sum(res1.scales, axis=(-2, -1)) +
           jnp.sum(res1.zeros, axis=(-2, -1)))
    return np.asarray(jnp.isfinite(tot + res1.err))


def _make_stage1(qc: QuantConfig, impl: str, with_rtn: bool,
                 gshard: Optional[QuantGroupSharding] = None) -> Callable:
    bits, group_size = qc.bits, qc.group_size
    blocksize, symmetric = qc.blocksize, qc.symmetric

    def fn(w, H, percdamp):
        # inputs arrive committed to the group sharding (lane-local H,
        # (lane, row)-tiled w); damp + Cholesky partition along with them,
        # so each lane factors where its rows live.
        hd = hess.damped(hess.HessianState(H, None), percdamp)
        u = hess.cholesky_inverse_upper(hd)
        if gshard is None:
            res1 = gptq_quantize_batched(w, u, bits=bits,
                                         group_size=group_size,
                                         blocksize=blocksize,
                                         symmetric=symmetric, impl=impl)
        else:
            res1 = GPTQResult(*kops.gptq_block_sharded(
                w, u, mesh=gshard.mesh, lane_axis=gshard.lane_axis,
                row_axis=gshard.row_axis, bits=bits, group_size=group_size,
                blocksize=blocksize, symmetric=symmetric, impl=impl))
        rtn = rtn_quantize_batched(w, bits=bits, group_size=group_size,
                                   symmetric=symmetric) if with_rtn else None
        return hd, res1, rtn

    return jax.jit(fn)


def _make_stage2(qc: QuantConfig, impl: str,
                 gshard: Optional[QuantGroupSharding] = None) -> Callable:
    kw = dict(bits=qc.bits, group_size=qc.group_size,
              block_size=qc.blocksize, alpha=qc.rpiq_alpha,
              t_max=qc.rpiq_iters, early_stop=qc.rpiq_early_stop,
              symmetric=qc.symmetric,
              exact_gram=not qc.rpiq_use_global_hessian)
    if gshard is None:
        return jax.jit(functools.partial(rpiq_refine_batched, impl=impl,
                                         **kw))

    def fn(w_init, w_fp, x, hd, scales, zeros, h_count=None, x_count=None):
        # the stage-2 shard_map twin: lanes shard like stage 1; rows shard
        # only when the per-shard dispatch resolves to the fused kernel
        # (the closed-loop bookkeeping is global over rows — see
        # kernels/ops.rpiq_block_sharded)
        return RPIQResult(*kops.rpiq_block_sharded(
            w_init, w_fp, x, hd, scales, zeros, h_count=h_count,
            x_count=x_count, mesh=gshard.mesh, lane_axis=gshard.lane_axis,
            row_axis=gshard.row_axis, impl=impl, **kw))

    return jax.jit(fn)


def _execute_group_batched(qc: QuantConfig, group: QuantGroup,
                           report: QuantReport, rpiq_enabled: bool,
                           gshard: Optional[QuantGroupSharding] = None,
                           sync: bool = True,
                           deferred: Optional[List[Callable[[], None]]]
                           = None) -> List[MemberResult]:
    """One stacked dispatch per stage for the whole group.

    Members concatenate on the lane axis — a stacked member (e.g. E MoE
    experts) contributes its slab wholesale, so lane count is
    Σ member.lanes while the host-side work stays O(#members).  Stage
    entries come from the cross-layer cache above, so identically shaped
    groups anywhere in the stack share one compiled executor.

    With ``gshard`` the stacked inputs are committed to the group's mesh
    placement first (weights (lane, row)-tiled, Hessian state and
    instances lane-local) and the stage entries are the mesh-keyed sharded
    variants; the outputs come back sharded and are gathered to the
    default device before scatter (see the comment below — the propagate
    forward must stay single-device).

    ``sync=False`` (the overlap schedule) skips the per-stage
    ``block_until_ready`` so stage dispatches stay async — the stage
    seconds then measure dispatch, and the scheduler takes wall-clock per
    layer-step at its report boundary instead. With ``deferred`` the
    per-linear report records (whose ``np.asarray`` calls would
    synchronize on the executor outputs) are packaged as a closure
    appended to the list, to be materialized at that same boundary —
    record ORDER is preserved, so reports match the serial schedule
    exactly.
    """
    ms = group.members
    t0 = time.perf_counter()
    w = jnp.concatenate([_as3d(jnp.asarray(m.w_oi, jnp.float32))
                         for m in ms])
    hs_lanes = [_lane_hessians(m) for m in ms]
    st = hess.HessianState(jnp.concatenate([h.H for h in hs_lanes]),
                           jnp.concatenate([h.count for h in hs_lanes]))
    starved = np.concatenate([m.starved_mask() for m in ms])
    with_rtn = bool(starved.any())
    fspec = faults.poll("hessian.cholesky")
    if fspec is not None:
        st = hess.HessianState(
            hess.corrupt_stacked(st.H, fspec.mode, qc.percdamp), st.count)
    shard_key = None if gshard is None else gshard.cache_key()
    if gshard is not None:
        w = jax.device_put(w, gshard.sharding("w"))
        st = hess.shard_stacked(st, gshard)
    stage1 = _cached_executor(
        ("stage1", group.key, qc.gptq_impl, with_rtn, shard_key),
        lambda: _make_stage1(qc, qc.gptq_impl, with_rtn, gshard))
    faults.fire("plan.stage1_executor")
    lanes_total = int(w.shape[0])
    damp = jnp.full((lanes_total,), qc.percdamp, jnp.float32)
    hd, res1, rtn = stage1(w, st.H, damp)
    guarded = np.zeros(lanes_total, bool)
    if qc.guardrail:
        bad0 = bad = ~_finite_lanes(res1)
        rung = 0
        while bad.any() and rung < qc.guardrail_retries:
            # guardrail ladder rung: escalate damping only on lanes whose
            # stage-1 output went non-finite (non-PSD / NaN Hessian).
            # Every stage-1 op is lane-independent, so untouched lanes
            # reproduce bitwise and the retry reuses the cached executor.
            rung += 1
            _guardrail_stats(report)["damp_retries"] += 1
            damp = jnp.where(jnp.asarray(bad),
                             damp * jnp.float32(qc.guardrail_damp_factor),
                             damp)
            hd, res1, rtn = stage1(w, st.H, damp)
            bad = ~_finite_lanes(res1)
        if bad0.any():
            gs = _guardrail_stats(report)
            gs["lanes_flagged"] += int(bad0.sum())
            gs["lanes_damp_recovered"] += int((bad0 & ~bad).sum())
            gs["lanes_rtn_forced"] += int(bad.sum())
        if bad.any():
            # ladder exhausted → per-group RTN rung. Stage 2 still runs
            # these lanes under vmap, so feed it sanitized inputs (RTN
            # weights on the RTN grid, identity curvature): a NaN Γ never
            # satisfies the early-stop predicate and would pin the whole
            # group's while_loop at t_max. The mask below discards their
            # stage-2 output anyway.
            guarded = np.asarray(bad)
            if rtn is None:
                rtn = rtn_quantize_batched(w, bits=qc.bits,
                                           group_size=qc.group_size,
                                           symmetric=qc.symmetric)
            gj = jnp.asarray(guarded)
            sel3 = gj[:, None, None]
            hd = jnp.where(sel3, jnp.eye(hd.shape[-1], dtype=hd.dtype), hd)
            res1 = GPTQResult(jnp.where(sel3, rtn.w_q, res1.w_q),
                              jnp.where(sel3, rtn.scales, res1.scales),
                              jnp.where(sel3, rtn.zeros, res1.zeros),
                              jnp.where(gj, 0.0, res1.err))
    if sync:
        jax.block_until_ready(res1.w_q)
    t1 = time.perf_counter()
    report.seconds_stage1 += t1 - t0

    do_rpiq = rpiq_enabled and qc.rpiq_iters > 0
    res2 = None
    if do_rpiq:
        x = jnp.concatenate([_as3d(jnp.asarray(m.x_last, jnp.float32))
                             for m in ms])
        xc = jnp.concatenate([_lane_x_counts(m) for m in ms])
        if gshard is not None:
            # commit the instance batch lane-local so the stage-2 shard_map
            # twin (rpiq_block_sharded) keeps each lane's refinement where
            # its rows run without a gather at dispatch
            x = jax.device_put(x, gshard.sharding("x"))
            xc = jax.device_put(xc, gshard.sharding("lane"))
        stage2 = _cached_executor(
            ("stage2", group.key, qc.rpiq_alpha, qc.rpiq_iters,
             qc.rpiq_early_stop, qc.rpiq_use_global_hessian, qc.rpiq_impl,
             shard_key),
            lambda: _make_stage2(qc, qc.rpiq_impl, gshard))
        faults.fire("plan.stage2_executor")
        res2 = stage2(res1.w_q, w, x, hd, res1.scales, res1.zeros,
                      h_count=st.count, x_count=xc)
        if sync:
            jax.block_until_ready(res2.w_q)
        t2 = time.perf_counter()
        report.seconds_stage2 += t2 - t1

    # starved-expert + guardrail-forced mask: select the RTN lane
    # (weights AND grid)
    w_final = res2.w_q if do_rpiq else res1.w_q
    scales, zeros = res1.scales, res1.zeros
    if rtn is not None:
        sel = jnp.asarray(starved | guarded)[:, None, None]
        w_final = jnp.where(sel, rtn.w_q, w_final)
        scales = jnp.where(sel, rtn.scales, scales)
        zeros = jnp.where(sel, rtn.zeros, zeros)

    if gshard is not None:
        # gather the group's artifacts off the mesh: the scatter feeds the
        # (single-device) propagate forward, and leaving mesh-committed
        # leaves in the param tree would silently partition that forward —
        # perturbing downstream Hessians and breaking parity with the
        # single-device path. The mesh is an executor-internal resource.
        # device_put to one device reshards on-fabric (no host round-trip).
        dev0 = jax.local_devices()[0]
        w_final, scales, zeros = (jax.device_put(a, dev0)
                                  for a in (w_final, scales, zeros))

    seconds = (time.perf_counter() - t0) / max(1, int((~starved).sum()))

    def _record():
        # np.asarray synchronizes on the executor outputs — under the
        # overlap schedule this runs deferred, at the step's report
        # boundary, so the dispatch queue has already been refilled.
        err1 = np.asarray(res1.err)
        hist = np.asarray(res2.loss_history) if res2 is not None else None
        ploss = np.asarray(res2.proj_loss) if res2 is not None else None
        iters = np.asarray(res2.iters_run) if res2 is not None else None
        off = 0
        for m in ms:
            shape = m.wshape
            for li, lname in enumerate(m.lane_names):
                i = off + li
                if starved[i]:
                    report.linears.append(LinearRecord(
                        lname, shape, 0.0, [], 0.0, 0, "rtn-fallback", 0.0))
                elif guarded[i]:
                    report.linears.append(LinearRecord(
                        lname, shape, 0.0, [], 0.0, 0, "rtn-guardrail", 0.0))
                elif do_rpiq:
                    report.linears.append(LinearRecord(
                        lname, shape, float(err1[i]), _gamma_list(hist[i]),
                        float(ploss[i]), int(iters[i]), "rpiq", seconds))
                else:
                    report.linears.append(LinearRecord(
                        lname, shape, float(err1[i]), [], 0.0, 0, "gptq",
                        seconds))
            off += m.lanes

    if deferred is None:
        _record()
    else:
        deferred.append(_record)

    results = []
    off = 0
    for m in ms:
        sl = slice(off, off + m.lanes)
        if m.stacked:
            results.append(MemberResult(m.name, w_final[sl],
                                        (scales[sl], zeros[sl])))
        else:
            results.append(MemberResult(m.name, w_final[off],
                                        (scales[off], zeros[off])))
        off += m.lanes
    return results


def _lane_view(m: PlanMember, li: int) -> "PlanMember":
    """Singleton view of one lane of a stacked member (legacy path only)."""
    if not m.stacked:
        return m
    xc = None if m.x_count is None else \
        jnp.asarray(m.x_count, jnp.int32).reshape(-1)[li]
    return PlanMember(m.lane_names[li], m.w_oi[li],
                      hess.HessianState(m.hessian.H[li],
                                        jnp.asarray(m.hessian.count,
                                                    jnp.int32
                                                    ).reshape(-1)[li]),
                      m.x_last[li], x_count=xc,
                      starved=bool(m.starved_mask()[li]))


def _execute_member_singleton(qc: QuantConfig, m: PlanMember,
                              report: QuantReport, rpiq_enabled: bool
                              ) -> MemberResult:
    """Legacy per-linear path: one dispatch per lane, per stage."""
    if m.stacked:
        parts = [_execute_member_singleton(qc, _lane_view(m, li), report,
                                           rpiq_enabled)
                 for li in range(m.lanes)]
        return MemberResult(m.name,
                            jnp.stack([p.w_q for p in parts]),
                            (jnp.stack([p.grid[0] for p in parts]),
                             jnp.stack([p.grid[1] for p in parts])))
    shape = m.wshape
    if m.starved:
        res = rtn_quantize(jnp.asarray(m.w_oi, jnp.float32), bits=qc.bits,
                           group_size=qc.group_size, symmetric=qc.symmetric)
        report.linears.append(LinearRecord(
            m.name, shape, 0.0, [], 0.0, 0, "rtn-fallback", 0.0))
        return MemberResult(m.name, res.w_q, (res.scales, res.zeros))
    t0 = time.perf_counter()
    w_oi = jnp.asarray(m.w_oi, jnp.float32)
    hd = hess.damped(m.hessian, qc.percdamp)
    u = hess.cholesky_inverse_upper(hd)
    res1 = gptq_quantize(w_oi, u, bits=qc.bits, group_size=qc.group_size,
                         blocksize=qc.blocksize, symmetric=qc.symmetric,
                         impl=qc.gptq_impl)
    jax.block_until_ready(res1.w_q)
    t1 = time.perf_counter()
    report.seconds_stage1 += t1 - t0
    grid = (res1.scales, res1.zeros)
    if not rpiq_enabled or qc.rpiq_iters <= 0:
        report.linears.append(LinearRecord(
            m.name, shape, float(res1.err), [], 0.0, 0, "gptq", t1 - t0))
        return MemberResult(m.name, res1.w_q, grid)
    res2 = rpiq_refine(res1.w_q, w_oi, jnp.asarray(m.x_last, jnp.float32),
                       hd, res1.scales, res1.zeros,
                       h_count=m.hessian.count, x_count=m.x_count,
                       bits=qc.bits, group_size=qc.group_size,
                       block_size=qc.blocksize, alpha=qc.rpiq_alpha,
                       t_max=qc.rpiq_iters, early_stop=qc.rpiq_early_stop,
                       exact_gram=not qc.rpiq_use_global_hessian,
                       symmetric=qc.symmetric, impl=qc.rpiq_impl)
    jax.block_until_ready(res2.w_q)
    t2 = time.perf_counter()
    report.seconds_stage2 += t2 - t1
    report.linears.append(LinearRecord(
        m.name, shape, float(res1.err), _gamma_list(np.asarray(
            res2.loss_history)), float(res2.proj_loss),
        int(res2.iters_run), "rpiq", t2 - t0))
    return MemberResult(m.name, res2.w_q, grid)


def _execute_fallback(qc: QuantConfig, m: PlanMember, report: QuantReport,
                      deferred: Optional[List[Callable[[], None]]] = None
                      ) -> MemberResult:
    """Blocksize/grid-unaligned member: RTN for starved lanes, else skip.

    A starved expert still gets the per-group grid when its input dim
    aligns to ``group_size`` (only GPTQ/RPIQ need ``blocksize``
    alignment); otherwise one full-row group, no stored grid. A stacked
    member mixes per-lane outcomes via the mask; its grid is stored only
    when every lane produced one (all-starved + aligned). Fallback
    records carry no device values, but with ``deferred`` they still
    queue behind the group closures so report ORDER matches serial.
    """
    recs: List[LinearRecord] = []

    def _emit():
        if deferred is None:
            report.linears.extend(recs)
        else:
            deferred.append(lambda: report.linears.extend(recs))

    shape = m.wshape
    aligned = shape[1] % qc.group_size == 0
    gsz = qc.group_size if aligned else shape[1]
    sv = m.starved_mask()
    if not m.stacked:
        if m.starved:
            res = rtn_quantize(jnp.asarray(m.w_oi, jnp.float32),
                               bits=qc.bits, group_size=gsz,
                               symmetric=qc.symmetric)
            recs.append(LinearRecord(
                m.name, shape, 0.0, [], 0.0, 0, "rtn-fallback", 0.0))
            _emit()
            return MemberResult(m.name, res.w_q,
                                (res.scales, res.zeros) if aligned else None)
        recs.append(LinearRecord(
            m.name, shape, 0.0, [], 0.0, 0, "skipped", 0.0))
        _emit()
        return MemberResult(m.name, None, None)
    for li, lname in enumerate(m.lane_names):
        recs.append(LinearRecord(
            lname, shape, 0.0, [], 0.0, 0,
            "rtn-fallback" if sv[li] else "skipped", 0.0))
    _emit()
    if not sv.any():
        return MemberResult(m.name, None, None)
    w = jnp.asarray(m.w_oi, jnp.float32)
    res = rtn_quantize_batched(w, bits=qc.bits, group_size=gsz,
                               symmetric=qc.symmetric)
    svj = jnp.asarray(sv)[:, None, None]
    w_q = jnp.where(svj, res.w_q, w)              # skipped lanes keep fp
    grid = ((res.scales, res.zeros)
            if aligned and bool(sv.all()) else None)
    return MemberResult(m.name, w_q, grid)


def execute_plan(qc: QuantConfig, plan: QuantPlan, report: QuantReport,
                 rpiq_enabled: bool = True,
                 batched: Optional[bool] = None,
                 mesh=None, sync: bool = True,
                 deferred: Optional[List[Callable[[], None]]] = None
                 ) -> Dict[str, MemberResult]:
    """Run every group + fallback; returns {member name → MemberResult}.

    ``batched=None`` reads ``qc.batched_executor``; ``False`` forces the
    legacy per-linear dispatch (parity tests, table4 baseline).

    ``mesh`` (a ``(data, model)`` or ``(data, model, expert)``
    :class:`jax.sharding.Mesh`) turns on sharded group execution: every
    batched group whose lane count / Cout pass the divisibility guards
    runs mesh-wide (DESIGN.md §2.6); groups made entirely of stacked
    expert slabs additionally offer their lane axis to the ``expert``
    mesh axis (expert parallelism — per-expert Hessians already live
    with their expert, so the placement adds no collectives). The rest —
    and the whole plan when ``mesh`` is None or ``batched`` is False —
    keep the single-device paths.

    ``sync=False`` + ``deferred`` is the overlap schedule's contract
    (core/stream.py): batched stage dispatches stay async and the
    report-record closures (which synchronize via ``np.asarray``) queue
    into ``deferred`` for the caller's report boundary. The legacy
    per-linear path stays per-stage synchronized regardless — it exists
    as the timing baseline.
    """
    if batched is None:
        batched = qc.batched_executor
    out: Dict[str, MemberResult] = {}
    for group in plan.groups:
        if batched:
            gshard = quant_group_sharding(
                mesh, sum(m.lanes for m in group.members), group.key[0],
                expert_stacked=all(m.stacked for m in group.members))
            results = _execute_group_batched(qc, group, report, rpiq_enabled,
                                             gshard, sync=sync,
                                             deferred=deferred)
        else:
            results = [_execute_member_singleton(qc, m, report, rpiq_enabled)
                       for m in group.members]
        for r in results:
            out[r.name] = r
    for m in plan.fallbacks:
        r = _execute_fallback(qc, m, report, deferred=deferred)
        out[r.name] = r
    return out
