"""Calibration Hessian machinery (paper §3.2, eq. 9–14).

The layer Hessian is the Gram matrix of the layer inputs accumulated over
all calibration batches, ``H ≈ Σ_b X_b^T X_b`` (eq. 9), damped by
``λ = percdamp · mean(diag H)`` (eq. 10).

`HessianState` supports streaming accumulation (one batch at a time — the
single-instance paradigm keeps only the *last* batch's activations, the
Hessian itself is a fixed (Cin, Cin) buffer) and cross-data-shard reduction
(psum) for distributed calibration.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops as kops


class HessianState(NamedTuple):
    """Gram accumulator for one linear — or a *stack* of same-shape linears.

    Singleton: H (in, in), count scalar. Stacked (the quant-plan batched
    executors, MoE expert stacks): H (B, in, in), count (B,) — every op
    below accepts both layouts.
    """
    H: jax.Array          # (in, in) | (B, in, in) float32 Gram accumulator
    count: jax.Array      # () | (B,) int32: total rows (tokens) accumulated


def init_hessian(in_dim: int, batch: Optional[int] = None) -> HessianState:
    if batch is None:
        return HessianState(jnp.zeros((in_dim, in_dim), jnp.float32),
                            jnp.zeros((), jnp.int32))
    return HessianState(jnp.zeros((batch, in_dim, in_dim), jnp.float32),
                        jnp.zeros((batch,), jnp.int32))


@jax.jit
def accumulate(state: HessianState, x: jax.Array) -> HessianState:
    """Add one calibration batch.

    Singleton state: x (..., in) — leading dims flattened. Stacked state:
    x (B, ..., in) — per-member Gram updates in one batched contraction
    (each member sees its own rows; no cross-member mixing).
    """
    if state.H.ndim == 2:
        x2 = x.reshape(-1, x.shape[-1]).astype(jnp.float32)
        H = state.H + kops.hessian_accum(x2)
        return HessianState(H, state.count + x2.shape[0])
    b = state.H.shape[0]
    x3 = x.reshape(b, -1, x.shape[-1]).astype(jnp.float32)
    # HIGHEST: match the singleton kernel's full-fp32 accumulation contract
    # on TPU (default MXU precision would silently break batched==legacy
    # Hessian parity there)
    H = state.H + jnp.einsum("bni,bnj->bij", x3, x3,
                             precision=jax.lax.Precision.HIGHEST)
    return HessianState(H, state.count + x3.shape[1])


def stack_states(states) -> HessianState:
    """Stack singleton HessianStates into one (B, in, in) stacked state."""
    return HessianState(
        jnp.stack([s.H for s in states]),
        jnp.stack([jnp.asarray(s.count, jnp.int32).reshape(()) for s in
                   states]))


def damped(state: HessianState, percdamp) -> jax.Array:
    """eq. 10: H̃ = H + percdamp·mean(diag H)·I  (also rescues dead columns).

    Works on singleton (in, in) and stacked (B, in, in) states alike.
    ``percdamp`` may be a scalar or, for a stacked state, a per-lane (B,)
    array — the guardrail ladder (core/plan.py) escalates damping only on
    lanes whose Cholesky went non-finite, and every per-lane op here is
    lane-independent, so untouched lanes stay bitwise-identical.
    """
    H = state.H
    diag = jnp.diagonal(H, axis1=-2, axis2=-1)           # (..., in)
    lam = jnp.mean(diag, axis=-1) * percdamp             # (...,)
    # GPTQ convention: columns with zero activation get diag forced to 1 so
    # the Cholesky stays well-posed; the corresponding weights quantize RTN.
    dead = diag <= 0.0
    eye = jnp.eye(H.shape[-1], dtype=H.dtype)
    H = H + jnp.where(dead, 1.0, 0.0)[..., None, :] * eye
    return H + lam[..., None, None] * eye


def corrupt_stacked(H: jax.Array, mode: str, percdamp: float,
                    lane: int = 0) -> jax.Array:
    """``hessian.cholesky`` fault-site payload: break one lane of a stacked
    (B, in, in) Gram matrix so the guardrail ladder's rungs execute
    deterministically (tests/test_faults.py).

    - ``"nonpsd"``: shift the lane's spectrum by ``-(λmin + 2·lam)·I`` so
      the base-damped matrix still has a negative eigenvalue (Cholesky →
      NaN) while one damp-factor escalation turns it positive — exercises
      the retry rung without reaching RTN.
    - ``"nan"``: poison the lane outright — no damping rescues it, forcing
      the per-group RTN rung.

    Only ``lane`` is touched; all other lanes are bitwise-unchanged.
    """
    if H.ndim == 2:
        H = H[None]
    if mode == "nan":
        return H.at[lane].set(jnp.nan)
    Hl = np.asarray(jax.device_get(H[lane]), np.float64)
    lam = float(np.mean(np.diag(Hl))) * percdamp
    ev_min = float(np.linalg.eigvalsh(Hl)[0])
    eye = jnp.eye(H.shape[-1], dtype=H.dtype)
    return H.at[lane].add(-(ev_min + 2.0 * lam) * eye)


def _cholesky_inverse_upper_2d(Hd: jax.Array) -> jax.Array:
    n = Hd.shape[0]
    L = jnp.linalg.cholesky(Hd)
    Hinv = jax.scipy.linalg.cho_solve((L, True), jnp.eye(n, dtype=Hd.dtype))
    # upper factor: cholesky returns lower L' with Hinv = L'L'^T; we need
    # U with Hinv = U^T U?  torch's upper=True returns U s.t. Hinv = U^T U
    # ... actually torch.cholesky(A, upper=True) returns U with A = U^T U.
    Lu = jnp.linalg.cholesky(Hinv)          # Hinv = Lu Lu^T
    return Lu.T                             # U = Lu^T  => Hinv = U^T U


@jax.jit
def cholesky_inverse_upper(Hd: jax.Array) -> jax.Array:
    """GPTQ's ``Hinv``: upper Cholesky factor of H̃^{-1}.

    torch reference::
        Hinv = cholesky(cholesky_inverse(cholesky(H)), upper=True)

    We compute H^{-1} via a Cholesky solve then factor it. fp64 would be
    nicer but TPUs are fp32; percdamp keeps this stable in practice.
    Accepts a stacked (B, in, in) ``Hd`` (vmapped per member).
    """
    if Hd.ndim == 3:
        return jax.vmap(_cholesky_inverse_upper_2d)(Hd)
    return _cholesky_inverse_upper_2d(Hd)


def block_solver(Hd: jax.Array, c1: int, c2: int):
    """Return a solve(rhs) for the damped Hessian block H̃[c1:c2, c1:c2].

    eq. 12–14: stage 2 uses the *global* Hessian's block diagonal as the
    instantaneous curvature, pre-factored once per block.
    """
    Hb = Hd[c1:c2, c1:c2]
    L = jnp.linalg.cholesky(Hb)

    def solve(rhs: jax.Array) -> jax.Array:
        return jax.scipy.linalg.cho_solve((L, True), rhs)

    return solve


def gram_solver(Xb: jax.Array, damp_rel: float = 1e-6):
    """Solve with (X_i^T X_i + εI) — the paper's eq. 6 literal variant.

    Used when ``rpiq_use_global_hessian=False``; with a single calibration
    batch the Gram matrix can be singular, so a small relative damping is
    always applied.
    """
    G = Xb.T @ Xb
    lam = damp_rel * jnp.mean(jnp.diag(G)) + 1e-12
    L = jnp.linalg.cholesky(G + lam * jnp.eye(G.shape[0], dtype=G.dtype))

    def solve(rhs: jax.Array) -> jax.Array:
        return jax.scipy.linalg.cho_solve((L, True), rhs)

    return solve


# -- distributed reduction / placement ---------------------------------------

def psum_hessian(state: HessianState, axis_name: str) -> HessianState:
    """Reduce partial Hessians across a mesh axis (inside shard_map)."""
    return HessianState(jax.lax.psum(state.H, axis_name),
                        jax.lax.psum(state.count, axis_name))


def shard_stacked(state: HessianState, gshard) -> HessianState:
    """Place a stacked (B, in, in) state on the quant-group mesh.

    ``gshard``: a :class:`repro.distributed.sharding.QuantGroupSharding`
    (duck-typed — only ``sharding(kind)`` is used, so this module needs
    no distributed import). Sharded over the lane (member) axis only —
    each lane's (in, in) block is one damp + Cholesky problem, so it
    lives wholesale on the devices that execute that lane's rows and
    stays replicated across the ``model`` axis the row tiles use
    (DESIGN.md §2.6); a rows-only group replicates the state across the
    whole mesh. Placement is unconditional for a sharded group: every
    stage input must be committed to the SAME mesh, or a caller-committed
    Hessian (e.g. scattered output of a previous sharded layer) would
    clash with the mesh-committed weights at dispatch. No-op only when
    the group is unsharded (``gshard`` None).
    """
    if gshard is None:
        return state
    return HessianState(
        jax.device_put(state.H, gshard.sharding("hessian")),
        jax.device_put(state.count, gshard.sharding("lane")))
