"""RPIQ stage-2: residual-projected multi-collaborative closed-loop refinement.

Paper §3.1–3.3 (eq. 2–8, 12–14, 19–23), per linear layer ``Y = X W^T``:

  - global output residual ``D = Y_orig − Y_q`` (eq. 2) kept explicit;
  - per column-block ``i``: *directed* residual
    ``D_i = Y_orig − (Y_q − Y_{q,i})`` (eq. 4/20) — the global residual with
    the current block's stale contribution removed;
  - local least squares ``min ‖D_i − X_i B_i^T‖²`` solved with the *global*
    damped Hessian's block diagonal as instantaneous curvature,
    ``B_i* = H_i^{-1} X_i^T D_i`` (eq. 6/13/14) — single-instance paradigm:
    the only data this stage touches is the last calibration batch
    ``(X_last, Y_orig)`` (eq. 11) plus the stage-1 Hessian;
  - projection onto the stage-1 quantization grid ``B̃_i = Q(B_i*)`` (eq. 7);
  - damped update ``B_i ← B_i + α (B̃_i − B_i)`` (eq. 8);
  - **Gauss–Seidel**: the running output ``Y_q`` is updated immediately after
    each block (eq. 21–22), so block ``i+1`` sees blocks ``1..i`` of the
    *current* round (eq. 19 mixed state);
  - loss ``Γ^(t) = ‖Y_orig − Y_q^(t)‖²`` (eq. 23), early stop when it stops
    decreasing or ``T_max`` reached; best projected weights retained.

Notes recorded for EXPERIMENTS.md:
  * eq. 8 keeps a **continuous** iterate (a convex combination of grid points
    is generally off-grid). The deployable artifact must live on the int4
    grid, so we track ``Q(B^{(t)})`` alongside and keep the best projected
    candidate by projected loss (``keep_best_projection``). With the paper's
    α = 0.01 the projection usually stays at the stage-1 solution for the
    first iterations; larger α (≤1) trades stability for faster residual
    decay — swept in benchmarks/table5_convergence.py.
  * everything is row-parallel over ``Cout`` (see gptq.py) and jit-safe.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.quant import QuantParams


class RPIQResult(NamedTuple):
    w_q: jax.Array          # (out, in) best *projected* weights (on-grid)
    w_cont: jax.Array       # (out, in) final continuous iterate (eq. 8)
    loss_history: jax.Array  # (T_max+1,) Γ per round; Γ[0] = stage-1 loss;
    #                          padded with +inf after early stop
    proj_loss: jax.Array    # scalar: Γ of the returned projected weights
    iters_run: jax.Array    # scalar int32: rounds actually executed


def _project_block(b: jax.Array, scales: jax.Array, zeros: jax.Array,
                   bits: int, group_size: int) -> jax.Array:
    """Q(·): project a (out, bs) block onto the fixed stage-1 grid.

    scales/zeros: (out, bs // group_size) for this block's groups.
    """
    out_dim, bs = b.shape
    qmax = 2.0 ** bits - 1.0
    s = jnp.repeat(scales, group_size, axis=1)
    z = jnp.repeat(zeros, group_size, axis=1)
    q = jnp.clip(jnp.round(b / s) + z, 0.0, qmax)
    return (q - z) * s


def _rpiq_core(w_init: jax.Array, w_fp: jax.Array, x_last: jax.Array,
               h_damped: jax.Array, scales: jax.Array, zeros: jax.Array,
               h_count: jax.Array | None, x_count: jax.Array | None, *,
               bits: int, group_size: int, block_size: int, alpha: float,
               t_max: int, early_stop: bool,
               exact_gram: bool) -> RPIQResult:
    """Single-linear RPIQ body — traceable, vmappable (see batched entry).

    w_init:   (out, in) stage-1 dequantized weights (on-grid)
    w_fp:     (out, in) full-precision weights (defines Y_orig)
    x_last:   (n, in)   last calibration batch inputs (single instance)
    h_damped: (in, in)  stage-1 damped global Hessian H̃
    scales/zeros: (out, in//group_size) stage-1 grid
    h_count:  total samples accumulated into H̃. The paper's eq. 13
        (``H_i^{-1} ≈ (X_i^T X_i)^{-1}``) holds only under consistent
        per-sample normalization; H̃ sums over *all* calibration batches
        while ``X_i^T D_i`` is single-instance, so we rescale
        ``H_i ← H_i · n_last / h_count`` to make the least-squares solve
        correctly scaled. Without this the LS step shrinks blocks by
        ``n_last/n_total`` and Γ diverges for α near 1 (measured — see
        EXPERIMENTS.md). ``None`` ⇒ H̃ is already single-instance scaled.
    exact_gram: eq. 6 vs eq. 13–14. ``False`` (paper's single-instance
        Hessian-curvature-reconstruction) uses the global H̃ block diagonals
        as curvature — O(1) extra memory but an *approximation* of the
        instance Gram whose eigenvalue error grows as ``sqrt(bs/n_last)``;
        for α near 1 the Gauss–Seidel iteration matrix can then exceed unit
        spectral radius and Γ diverges (measured). ``True`` implements eq. 6
        literally: per-block Gram ``X_i^T X_i`` of the instance (lightly
        damped), which makes each pre-projection update a true least-squares
        descent step — stable at α = 1. Both modes keep the best projected
        candidate, so the returned weights never regress either way.

    ``block_size % group_size == 0`` required (grid aligned to blocks).
    """
    out_dim, in_dim = w_init.shape
    assert in_dim % block_size == 0
    assert block_size % group_size == 0
    n_blocks = in_dim // block_size
    gpb = block_size // group_size

    x = x_last.astype(jnp.float32)              # (n, in)
    w0 = w_init.astype(jnp.float32)
    y_orig = x @ w_fp.astype(jnp.float32).T     # (n, out)

    # per-block column slabs of X: (M, n, bs)
    x_blocks = x.reshape(x.shape[0], n_blocks, block_size).transpose(1, 0, 2)

    # --- pre-factor the blockwise curvature -------------------------------
    if exact_gram:
        # eq. 6 literal: G_i = X_i^T X_i (+ relative damping for rank safety)
        grams = jnp.einsum("mnb,mnc->mbc", x_blocks, x_blocks)
        diag_mean = jnp.mean(jnp.diagonal(grams, axis1=1, axis2=2),
                             axis=1)             # (M,)
        eye = jnp.eye(block_size, dtype=jnp.float32)
        grams = grams + (1e-4 * diag_mean + 1e-8)[:, None, None] * eye
        chol = jax.vmap(jnp.linalg.cholesky)(grams)
    else:
        # eq. 12–14: block diagonals of the (rescaled) global damped Hessian
        if h_count is None:
            h_scale = jnp.float32(1.0)
        else:
            n_x = (jnp.asarray(x.shape[0], jnp.float32) if x_count is None
                   else x_count.astype(jnp.float32))
            h_scale = n_x / jnp.maximum(h_count.astype(jnp.float32), 1.0)
        idx = jnp.arange(n_blocks)
        h4 = (h_damped * h_scale).reshape(n_blocks, block_size,
                                          n_blocks, block_size)
        h_blocks = h4[idx, :, idx, :]           # (M, bs, bs) block diagonals
        chol = jax.vmap(jnp.linalg.cholesky)(h_blocks)
    # per-block grid: (M, out, gpb)
    s_blocks = scales.reshape(out_dim, n_blocks, gpb).transpose(1, 0, 2)
    z_blocks = zeros.reshape(out_dim, n_blocks, gpb).transpose(1, 0, 2)

    def block_outputs(w):
        """Y_{q,i} = X_i B_i^T for all blocks: (M, n, out)."""
        wb = w.reshape(out_dim, n_blocks, block_size).transpose(1, 0, 2)
        return jnp.einsum("mnb,mob->mno", x_blocks, wb)

    def loss_of(w):
        y = x @ w.T
        return jnp.sum((y_orig - y) ** 2)

    gamma0 = loss_of(w0)

    def _project_full(w):
        s = jnp.repeat(scales, group_size, axis=1)
        z = jnp.repeat(zeros, group_size, axis=1)
        qmax = 2.0 ** bits - 1.0
        q = jnp.clip(jnp.round(w / s) + z, 0.0, qmax)
        return (q - z) * s

    def sweep_block(i, bc):
        w, y_q = bc
        c1 = i * block_size
        b_old = jax.lax.dynamic_slice(w, (0, c1), (out_dim, block_size))
        x_i = x_blocks[i]                               # (n, bs)
        y_qi = x_i @ b_old.T                            # (n, out)
        d_i = y_orig - (y_q - y_qi)                     # eq. 4/20
        rhs = x_i.T @ d_i                               # (bs, out)
        b_star = jax.scipy.linalg.cho_solve(
            (chol[i], True), rhs).T                     # (out, bs) eq. 14
        b_proj = _project_block(b_star, s_blocks[i], z_blocks[i],
                                bits, group_size)       # eq. 7
        b_new = b_old + alpha * (b_proj - b_old)        # eq. 8
        y_q = y_q - y_qi + x_i @ b_new.T                # eq. 21–22
        w = jax.lax.dynamic_update_slice(w, b_new, (0, c1))
        return w, y_q

    # while (not fori+cond-skip): post-early-stop rounds were carry-
    # preserving no-ops, and under vmap a lax.cond lowers to select — both
    # branches execute — so the batched executor would otherwise burn all
    # t_max Gauss–Seidel rounds on every lane; the loop instead terminates
    # as soon as every lane has stopped.
    def gs_cond(carry):
        _, _, _, _, _, done, t = carry
        return jnp.logical_and(t < t_max, jnp.logical_not(done))

    def gs_round(carry):
        """One Gauss–Seidel sweep over all blocks (eq. 19–22)."""
        w, y_q, best_w, best_loss, hist, done, t = carry
        w, y_q = jax.lax.fori_loop(0, n_blocks, sweep_block, (w, y_q))
        gamma = jnp.sum((y_orig - y_q) ** 2)            # eq. 23
        hist = hist.at[t + 1].set(gamma)
        # candidate: full projection of the continuous iterate
        w_proj = _project_full(w)
        ploss = loss_of(w_proj)
        improve = ploss < best_loss
        best_w = jnp.where(improve, w_proj, best_w)
        best_loss = jnp.where(improve, ploss, best_loss)
        # early stop: Γ stopped decreasing vs the previous round
        stop = jnp.logical_and(
            jnp.asarray(early_stop), gamma >= hist[t] * (1.0 - 1e-6))
        return w, y_q, best_w, best_loss, hist, stop, t + 1

    hist0 = jnp.full((t_max + 1,), jnp.inf, jnp.float32).at[0].set(gamma0)
    y_q0 = x @ w0.T
    carry = (w0, y_q0, w0, gamma0, hist0, jnp.asarray(False),
             jnp.zeros((), jnp.int32))
    w, y_q, best_w, best_loss, hist, done, iters = jax.lax.while_loop(
        gs_cond, gs_round, carry)
    return RPIQResult(best_w, w, hist, best_loss, iters)


@functools.partial(jax.jit, static_argnames=(
    "bits", "group_size", "block_size", "t_max", "early_stop", "exact_gram"))
def rpiq_refine(w_init: jax.Array, w_fp: jax.Array, x_last: jax.Array,
                h_damped: jax.Array, scales: jax.Array, zeros: jax.Array, *,
                h_count: jax.Array | None = None,
                x_count: jax.Array | None = None,
                bits: int = 4, group_size: int = 128, block_size: int = 128,
                alpha: float = 0.01, t_max: int = 5,
                early_stop: bool = True,
                exact_gram: bool = False) -> RPIQResult:
    """Stage-2 refinement for one linear layer (see :func:`_rpiq_core`)."""
    return _rpiq_core(w_init, w_fp, x_last, h_damped, scales, zeros,
                      h_count, x_count, bits=bits, group_size=group_size,
                      block_size=block_size, alpha=alpha, t_max=t_max,
                      early_stop=early_stop, exact_gram=exact_gram)


@functools.partial(jax.jit, static_argnames=(
    "bits", "group_size", "block_size", "t_max", "early_stop", "exact_gram"))
def rpiq_refine_batched(w_init: jax.Array, w_fp: jax.Array,
                        x_last: jax.Array, h_damped: jax.Array,
                        scales: jax.Array, zeros: jax.Array, *,
                        h_count: jax.Array | None = None,
                        x_count: jax.Array | None = None,
                        bits: int = 4, group_size: int = 128,
                        block_size: int = 128, alpha: float = 0.01,
                        t_max: int = 5, early_stop: bool = True,
                        exact_gram: bool = False) -> RPIQResult:
    """vmapped stage-2 over a stacked leading axis (one group dispatch).

    Array args gain a leading (B,) axis: w_init/w_fp (B, out, in), x_last
    (B, n, in), h_damped (B, in, in), scales/zeros (B, out, groups);
    h_count/x_count are (B,) or None. Every member runs its own early-stop
    lane (``iters_run`` stays per-member); the RPIQResult fields carry the
    stacked axis. One jit cache entry per group instead of per linear.
    """
    assert w_init.ndim == 3, w_init.shape
    fn = functools.partial(_rpiq_core, bits=bits, group_size=group_size,
                           block_size=block_size, alpha=alpha, t_max=t_max,
                           early_stop=early_stop, exact_gram=exact_gram)
    in_axes = (0, 0, 0, 0, 0, 0,
               None if h_count is None else 0,
               None if x_count is None else 0)
    return jax.vmap(fn, in_axes=in_axes)(w_init, w_fp, x_last, h_damped,
                                         scales, zeros, h_count, x_count)
