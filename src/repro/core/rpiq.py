"""RPIQ stage-2: residual-projected multi-collaborative closed-loop refinement.

Paper §3.1–3.3 (eq. 2–8, 12–14, 19–23), per linear layer ``Y = X W^T``:

  - global output residual ``D = Y_orig − Y_q`` (eq. 2) kept explicit;
  - per column-block ``i``: *directed* residual
    ``D_i = Y_orig − (Y_q − Y_{q,i})`` (eq. 4/20) — the global residual with
    the current block's stale contribution removed;
  - local least squares ``min ‖D_i − X_i B_i^T‖²`` solved with the *global*
    damped Hessian's block diagonal as instantaneous curvature,
    ``B_i* = H_i^{-1} X_i^T D_i`` (eq. 6/13/14) — single-instance paradigm:
    the only data this stage touches is the last calibration batch
    ``(X_last, Y_orig)`` (eq. 11) plus the stage-1 Hessian;
  - projection onto the stage-1 quantization grid ``B̃_i = Q(B_i*)`` (eq. 7);
  - damped update ``B_i ← B_i + α (B̃_i − B_i)`` (eq. 8);
  - **Gauss–Seidel**: the running output ``Y_q`` is updated immediately after
    each block (eq. 21–22), so block ``i+1`` sees blocks ``1..i`` of the
    *current* round (eq. 19 mixed state);
  - loss ``Γ^(t) = ‖Y_orig − Y_q^(t)‖²`` (eq. 23), early stop when it stops
    decreasing or ``T_max`` reached; best projected weights retained.

The public entries (:func:`rpiq_refine`, :func:`rpiq_refine_batched`) route
through :func:`repro.kernels.ops.rpiq_block`, which dispatches the closed
loop either to the fused Pallas kernel (kernels/rpiq_block.py — ALL
Gauss–Seidel rounds in one ``pallas_call``) or to the vmapped
:func:`_rpiq_core` XLA body kept here as the reference/fallback path
(``quant.rpiq_impl`` config knob).  Both backends consume the SAME
pre-factored blockwise curvature: :func:`_block_curvature_inv` turns either
curvature mode into an explicit ``(M, bs, bs)`` stack of ``H_i^{-1}`` via
the existing Cholesky, so the inner loop is pure matmuls everywhere — no
triangular solve inside the sweep (and none in Mosaic).

Notes recorded for EXPERIMENTS.md:
  * eq. 8 keeps a **continuous** iterate (a convex combination of grid points
    is generally off-grid). The deployable artifact must live on the int4
    grid, so we track ``Q(B^{(t)})`` alongside and keep the best projected
    candidate by projected loss (``keep_best_projection``). With the paper's
    α = 0.01 the projection usually stays at the stage-1 solution for the
    first iterations; larger α (≤1) trades stability for faster residual
    decay — swept in benchmarks/table5_convergence.py.
  * everything is row-parallel over ``Cout`` (see gptq.py) EXCEPT the
    closed-loop bookkeeping: Γ, the early stop and the best-projection
    choice are sums/decisions over ALL rows, which is why the row-sharded
    execution path folds per-shard loss partials before deciding
    (kernels/ops.rpiq_block_sharded, DESIGN.md §2.6).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops
# eq. 7 grid projection — ONE definition shared by the XLA body and the
# fused kernel (rpiq_block.py is a cycle-free leaf; a drifted copy would
# silently break backend parity).  kernels/ref.py keeps an independent
# NumPy variant on purpose: the oracle must not share code with the
# implementations it checks.
from repro.kernels.rpiq_block import _project


class RPIQResult(NamedTuple):
    w_q: jax.Array          # (out, in) best *projected* weights (on-grid)
    w_cont: jax.Array       # (out, in) final continuous iterate (eq. 8)
    loss_history: jax.Array  # (T_max+1,) Γ per round; Γ[0] = stage-1 loss;
    #                          padded with +inf after early stop
    proj_loss: jax.Array    # scalar: Γ of the returned projected weights
    iters_run: jax.Array    # scalar int32: rounds actually executed


def _block_curvature_inv(x_last: jax.Array, h_damped: jax.Array,
                         h_count: jax.Array | None,
                         x_count: jax.Array | None, *,
                         block_size: int, exact_gram: bool) -> jax.Array:
    """Pre-factor the blockwise curvature: explicit ``(M, bs, bs)`` inverses.

    h_count: total samples accumulated into H̃. The paper's eq. 13
        (``H_i^{-1} ≈ (X_i^T X_i)^{-1}``) holds only under consistent
        per-sample normalization; H̃ sums over *all* calibration batches
        while ``X_i^T D_i`` is single-instance, so we rescale
        ``H_i ← H_i · n_last / h_count`` to make the least-squares solve
        correctly scaled. Without this the LS step shrinks blocks by
        ``n_last/n_total`` and Γ diverges for α near 1 (measured — see
        EXPERIMENTS.md). ``None`` ⇒ H̃ is already single-instance scaled.
    exact_gram: eq. 6 vs eq. 13–14. ``False`` (paper's single-instance
        Hessian-curvature-reconstruction) uses the global H̃ block diagonals
        as curvature — O(1) extra memory but an *approximation* of the
        instance Gram whose eigenvalue error grows as ``sqrt(bs/n_last)``;
        for α near 1 the Gauss–Seidel iteration matrix can then exceed unit
        spectral radius and Γ diverges (measured). ``True`` implements eq. 6
        literally: per-block Gram ``X_i^T X_i`` of the instance (lightly
        damped), which makes each pre-projection update a true least-squares
        descent step — stable at α = 1.

    Both modes Cholesky-factor OUTSIDE the refinement loop and return the
    explicit inverse (``cho_solve`` against I), so the loop body — XLA and
    Pallas alike — solves eq. 13–14 as one matmul per block.
    """
    x = x_last.astype(jnp.float32)
    in_dim = x.shape[-1]
    assert in_dim % block_size == 0, (x.shape, block_size)
    n_blocks = in_dim // block_size
    if exact_gram:
        # eq. 6 literal: G_i = X_i^T X_i (+ relative damping for rank safety)
        x_blocks = x.reshape(x.shape[0], n_blocks,
                             block_size).transpose(1, 0, 2)
        blocks = jnp.einsum("mnb,mnc->mbc", x_blocks, x_blocks)
        diag_mean = jnp.mean(jnp.diagonal(blocks, axis1=1, axis2=2),
                             axis=1)             # (M,)
        eye = jnp.eye(block_size, dtype=jnp.float32)
        blocks = blocks + (1e-4 * diag_mean + 1e-8)[:, None, None] * eye
    else:
        # eq. 12–14: block diagonals of the (rescaled) global damped Hessian
        if h_count is None:
            h_scale = jnp.float32(1.0)
        else:
            n_x = (jnp.asarray(x.shape[0], jnp.float32) if x_count is None
                   else x_count.astype(jnp.float32))
            h_scale = n_x / jnp.maximum(h_count.astype(jnp.float32), 1.0)
        idx = jnp.arange(n_blocks)
        h4 = (h_damped * h_scale).reshape(n_blocks, block_size,
                                          n_blocks, block_size)
        blocks = h4[idx, :, idx, :]             # (M, bs, bs) block diagonals
    chol = jax.vmap(jnp.linalg.cholesky)(blocks)
    eye = jnp.eye(block_size, dtype=jnp.float32)
    return jax.vmap(lambda L: jax.scipy.linalg.cho_solve((L, True), eye))(
        chol)


def _rpiq_core(w_init: jax.Array, w_fp: jax.Array, x_last: jax.Array,
               hinv_blocks: jax.Array, scales: jax.Array, zeros: jax.Array,
               *, bits: int, group_size: int, block_size: int, alpha: float,
               t_max: int, early_stop: bool, symmetric: bool) -> RPIQResult:
    """Single-linear RPIQ body — traceable, vmappable (the XLA backend).

    w_init:      (out, in) stage-1 dequantized weights (on-grid)
    w_fp:        (out, in) full-precision weights (defines Y_orig)
    x_last:      (n, in)   last calibration batch inputs (single instance)
    hinv_blocks: (M, bs, bs) pre-factored blockwise curvature inverses
                 (:func:`_block_curvature_inv`)
    scales/zeros: (out, in//group_size) stage-1 grid

    ``block_size % group_size == 0`` required (grid aligned to blocks).
    """
    out_dim, in_dim = w_init.shape
    assert in_dim % block_size == 0
    assert block_size % group_size == 0
    n_blocks = in_dim // block_size

    x = x_last.astype(jnp.float32)              # (n, in)
    w0 = w_init.astype(jnp.float32)
    y_orig = x @ w_fp.astype(jnp.float32).T     # (n, out)
    hinv = hinv_blocks.astype(jnp.float32)

    # per-block column slabs of X: (M, n, bs)
    x_blocks = x.reshape(x.shape[0], n_blocks, block_size).transpose(1, 0, 2)

    # grid expanded to column resolution ONCE (hoisted out of the sweep)
    s_rep = jnp.repeat(scales.astype(jnp.float32), group_size, axis=1)
    z_rep = jnp.repeat(zeros.astype(jnp.float32), group_size, axis=1)
    s_blocks = s_rep.reshape(out_dim, n_blocks,
                             block_size).transpose(1, 0, 2)
    z_blocks = z_rep.reshape(out_dim, n_blocks,
                             block_size).transpose(1, 0, 2)

    def loss_of(w):
        y = x @ w.T
        return jnp.sum((y_orig - y) ** 2)

    gamma0 = loss_of(w0)

    def sweep_block(i, bc):
        w, y_q = bc
        c1 = i * block_size
        b_old = jax.lax.dynamic_slice(w, (0, c1), (out_dim, block_size))
        x_i = x_blocks[i]                               # (n, bs)
        y_qi = x_i @ b_old.T                            # (n, out)
        d_i = y_orig - (y_q - y_qi)                     # eq. 4/20
        rhs = x_i.T @ d_i                               # (bs, out)
        # eq. 13–14 with the pre-factored explicit inverse:
        # B* = (H_i^{-1} rhs)^T as one contraction, (out, bs)
        b_star = jax.lax.dot_general(rhs, hinv[i],
                                     (((0,), (1,)), ((), ())),
                                     preferred_element_type=jnp.float32)
        b_proj = _project(b_star, s_blocks[i], z_blocks[i],
                          bits=bits, symmetric=symmetric)   # eq. 7
        b_new = b_old + alpha * (b_proj - b_old)        # eq. 8
        y_q = y_q - y_qi + x_i @ b_new.T                # eq. 21–22
        w = jax.lax.dynamic_update_slice(w, b_new, (0, c1))
        return w, y_q

    # while (not fori+cond-skip): post-early-stop rounds were carry-
    # preserving no-ops, and under vmap a lax.cond lowers to select — both
    # branches execute — so the batched executor would otherwise burn all
    # t_max Gauss–Seidel rounds on every lane; the loop instead terminates
    # as soon as every lane has stopped.
    def gs_cond(carry):
        _, _, _, _, _, done, t = carry
        return jnp.logical_and(t < t_max, jnp.logical_not(done))

    def gs_round(carry):
        """One Gauss–Seidel sweep over all blocks (eq. 19–22)."""
        w, y_q, best_w, best_loss, hist, done, t = carry
        w, y_q = jax.lax.fori_loop(0, n_blocks, sweep_block, (w, y_q))
        gamma = jnp.sum((y_orig - y_q) ** 2)            # eq. 23
        hist = hist.at[t + 1].set(gamma)
        # candidate: full projection of the continuous iterate
        w_proj = _project(w, s_rep, z_rep, bits=bits, symmetric=symmetric)
        ploss = loss_of(w_proj)
        improve = ploss < best_loss
        best_w = jnp.where(improve, w_proj, best_w)
        best_loss = jnp.where(improve, ploss, best_loss)
        # early stop: Γ stopped decreasing vs the previous round
        stop = jnp.logical_and(
            jnp.asarray(early_stop), gamma >= hist[t] * (1.0 - 1e-6))
        return w, y_q, best_w, best_loss, hist, stop, t + 1

    hist0 = jnp.full((t_max + 1,), jnp.inf, jnp.float32).at[0].set(gamma0)
    y_q0 = x @ w0.T
    carry = (w0, y_q0, w0, gamma0, hist0, jnp.asarray(False),
             jnp.zeros((), jnp.int32))
    w, y_q, best_w, best_loss, hist, done, iters = jax.lax.while_loop(
        gs_cond, gs_round, carry)
    return RPIQResult(best_w, w, hist, best_loss, iters)


@functools.partial(jax.jit, static_argnames=(
    "bits", "group_size", "block_size", "alpha", "t_max", "early_stop",
    "symmetric"))
def _rpiq_xla_batched(w_init: jax.Array, w_fp: jax.Array, x_last: jax.Array,
                      hinv_blocks: jax.Array, scales: jax.Array,
                      zeros: jax.Array, *, bits: int, group_size: int,
                      block_size: int, alpha: float, t_max: int,
                      early_stop: bool, symmetric: bool) -> RPIQResult:
    """The XLA fallback behind :func:`repro.kernels.ops.rpiq_block`:
    vmapped :func:`_rpiq_core` over the stacked member axis — the
    ``while_loop``-of-``fori_loop`` body whose O(t·M) dispatched ops per
    group the fused kernel removes.  Every member runs its own early-stop
    lane (``iters_run`` stays per-member)."""
    assert w_init.ndim == 3, w_init.shape
    fn = functools.partial(_rpiq_core, bits=bits, group_size=group_size,
                           block_size=block_size, alpha=alpha, t_max=t_max,
                           early_stop=early_stop, symmetric=symmetric)
    return jax.vmap(fn)(w_init, w_fp, x_last, hinv_blocks, scales, zeros)


def rpiq_refine(w_init: jax.Array, w_fp: jax.Array, x_last: jax.Array,
                h_damped: jax.Array, scales: jax.Array, zeros: jax.Array, *,
                h_count: jax.Array | None = None,
                x_count: jax.Array | None = None,
                bits: int = 4, group_size: int = 128, block_size: int = 128,
                alpha: float = 0.01, t_max: int = 5,
                early_stop: bool = True, exact_gram: bool = False,
                symmetric: bool = False, impl: str = "auto") -> RPIQResult:
    """Stage-2 refinement for one linear layer (see :func:`_rpiq_core`).

    ``impl`` selects the closed-loop backend through the kernel dispatcher
    (:func:`repro.kernels.ops.rpiq_block`): the fused Pallas kernel
    ("pallas"), the vmapped XLA body ("xla"), or backend-based "auto".
    """
    hinv = _block_curvature_inv(x_last, h_damped, h_count, x_count,
                                block_size=block_size,
                                exact_gram=exact_gram)
    out = kops.rpiq_block(w_init, w_fp, x_last, hinv, scales, zeros,
                          bits=bits, group_size=group_size,
                          block_size=block_size, alpha=alpha, t_max=t_max,
                          early_stop=early_stop, symmetric=symmetric,
                          impl=impl)
    return RPIQResult(*out)


def rpiq_refine_batched(w_init: jax.Array, w_fp: jax.Array,
                        x_last: jax.Array, h_damped: jax.Array,
                        scales: jax.Array, zeros: jax.Array, *,
                        h_count: jax.Array | None = None,
                        x_count: jax.Array | None = None,
                        bits: int = 4, group_size: int = 128,
                        block_size: int = 128, alpha: float = 0.01,
                        t_max: int = 5, early_stop: bool = True,
                        exact_gram: bool = False, symmetric: bool = False,
                        impl: str = "auto", local: bool = False,
                        interpret: bool | None = None,
                        loss_psum_axis: str | None = None) -> RPIQResult:
    """vmapped stage-2 over a stacked leading axis (one group dispatch).

    Array args gain a leading (B,) axis: w_init/w_fp (B, out, in), x_last
    (B, n, in), h_damped (B, in, in), scales/zeros (B, out, groups);
    h_count/x_count are (B,) or None. Every member runs its own early-stop
    lane (``iters_run`` stays per-member); the RPIQResult fields carry the
    stacked axis. One jit cache entry per group instead of per linear.

    ``local``/``interpret``/``loss_psum_axis`` plumb through to
    :func:`repro.kernels.ops.rpiq_block` for the sharded twin — see
    :func:`repro.kernels.ops.rpiq_block_sharded`.
    """
    assert w_init.ndim == 3, w_init.shape
    prep = functools.partial(_block_curvature_inv, block_size=block_size,
                             exact_gram=exact_gram)
    in_axes = (0, 0, None if h_count is None else 0,
               None if x_count is None else 0)
    hinv = jax.vmap(prep, in_axes=in_axes)(x_last, h_damped, h_count,
                                           x_count)
    out = kops.rpiq_block(w_init, w_fp, x_last, hinv, scales, zeros,
                          bits=bits, group_size=group_size,
                          block_size=block_size, alpha=alpha, t_max=t_max,
                          early_stop=early_stop, symmetric=symmetric,
                          impl=impl, local=local, interpret=interpret,
                          loss_psum_axis=loss_psum_axis)
    return RPIQResult(*out)
