"""The RPIQ model-quantization pipeline (the paper's end-to-end procedure).

Sequential layer-wise calibration, exactly as GPTQ/AutoGPTQ practice it and
the paper assumes:

  1. embed every calibration batch → residual streams ``hs``;
  2. for each transformer layer (segment-element by element):
     a. **capture** — run the layer over all batches with a :class:`Tap`
        that streams each named linear's inputs into its Hessian
        (eq. 9, ``H += X_bᵀX_b``) and keeps only the **last** batch's
        inputs resident (single-instance paradigm, eq. 11). With
        ``quant.jit_capture`` (default) the forward is COMPILED — the tap
        collects tracers inside the jit and the inputs come back as
        outputs — and cached per layer signature, so repeated layers
        reuse the compiled forward (``False`` = legacy eager capture);
     b. **plan** — :func:`repro.core.plan.build_plan` turns the captured
        linears (dense taps AND stacked MoE expert slices) into a
        :class:`~repro.core.plan.QuantPlan`: members grouped by
        ``(shape, n_last, group_size, blocksize, bits, symmetric)``;
     c. **execute** — each group runs through the *batched* executors
        (``gptq_quantize_batched`` stage 1, eq. 10; ``rpiq_refine_batched``
        stage 2, eq. 4–8, 12–14, 19–23): weights/Hessians/instances are
        stacked on a leading axis and quantized in ONE dispatch per stage
        per group instead of one per linear (``quant.batched_executor=False``
        restores per-linear dispatch — same plan, singleton executors);
     d. **scatter** the on-grid results back into the param tree and re-run
        the layer to **propagate quantized activations** to the next layer
        (so later Hessians see the quantized network — GPTQ semantics);
  3. MoE layers: the router/shared-expert linears tap normally; routed
     expert FFNs get **per-expert Hessians from their routed tokens** via
     ``moe.dispatch``, accumulated as ONE stacked (E, d, d) HessianState
     (capacity-padded zero rows contribute nothing to ``XᵀX``). All E
     experts of a weight join the plan as one group of E stacked members —
     w_gate and w_up even share a 2E-member group — and experts that saw
     fewer than one group of tokens become an RTN fallback *mask inside
     the group* (recorded in the report as before).

Returns float params whose quantized linears hold *on-grid* values plus a
``QuantReport`` (per-linear Γ histories = paper Table 5 / Fig. 5) and a
packer to int4 serving artifacts (QuantizedTensor leaves). Stage timings
are synchronized (``jax.block_until_ready``) so the report measures
compute, not async dispatch.
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import Config
from repro.core import hessian as hess
from repro.core import plan as qplan
from repro.core.plan import (LinearRecord, MemberResult,  # noqa: F401
                             PlanMember, QuantReport)
from repro.core.quant import QuantizedTensor, pack_int4
from repro.models import transformer as T
from repro.models import moe as moe_mod
from repro.models.linear import Tap
from repro.models.layers import embed, norm, sinusoidal_positions


# ---------------------------------------------------------------------------
# Jitted calibration forward (capture + propagate)
#
# The capture/propagate forwards used to run eagerly, op by op — the
# second wall-clock dominator after the executors (benchmarks/
# table4_time.py).  ``_layer_forward_jit`` compiles them instead: the Tap
# opens INSIDE the traced function in collect-tracers mode, so the tapped
# layer inputs come back as ordinary jit outputs.  Entries are cached per
# (fwd_key, batch index, layer-signature) for ONE ``quantize_model`` run
# — repeated layers (same spec + shapes) reuse the compiled forward, and
# scoping the cache to the run keeps closure constants (positions,
# encoder outputs) from leaking across models.  Batch-independent layers
# collapse the batch index to 0; the encoder-decoder decoder bakes
# ``enc_out[bi]`` into the trace, so it keys per batch.
# ---------------------------------------------------------------------------

def _tree_signature(tree) -> Tuple:
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return (str(treedef),
            tuple((tuple(l.shape), str(l.dtype)) for l in leaves))


def _layer_forward_jit(fwd_cache: Dict, fwd_key: Tuple, apply_fn,
                       params: Dict, h: jax.Array, bi: int,
                       batch_dependent: bool, collect: bool = True):
    """Run one layer forward compiled; returns (h_out, {name: [inputs]}).

    ``collect=False`` (the propagate pass) compiles a tap-less forward —
    returning the tapped inputs as jit outputs would force XLA to
    materialize every linear's input buffer the caller then discards.
    """
    key_bi = bi if batch_dependent else 0
    key = (fwd_key, key_bi, collect, _tree_signature(params), h.shape,
           str(h.dtype))
    fn = fwd_cache.get(key)
    if fn is None:
        def fwd(p, hh, _bi=bi):
            if not collect:
                return apply_fn(p, hh, _bi), {}
            tap = Tap(collect_tracers=True)
            with tap:
                out = apply_fn(p, hh, _bi)
            return out, {k: list(v) for k, v in tap.records.items()}
        fn = jax.jit(fwd)
        fwd_cache[key] = fn
    return fn(params, h)


def _resolve(tree: Dict, dotted: str):
    node = tree
    for part in dotted.split("."):
        node = node[part]
    return node


def _linear_names_in(tree: Dict, prefix: str = "") -> List[str]:
    """Dotted paths of {w:...} dense params inside a layer subtree."""
    out = []
    for k, v in tree.items():
        path = f"{prefix}.{k}" if prefix else k
        if isinstance(v, dict):
            if "w" in v and not isinstance(v["w"], dict) \
                    and getattr(v["w"], "ndim", 0) == 2:
                out.append(path)
            else:
                out.extend(_linear_names_in(v, path))
    return out


_QUANT_SUBTREES = ("mixer", "mlp", "xattn")   # norms/embeds stay fp
_MOE_WNAMES = ("w_gate", "w_up", "w_down")


def _moe_members(cfg: Config, p_moe: Dict, xs: List[jax.Array],
                 name: str) -> List[PlanMember]:
    """Plan members for the routed experts (paper's method per expert).

    ``xs``: per-calibration-batch flat MoE block inputs (T, d), collected
    from the router tap. Per-expert Hessians accumulate as one stacked
    (E, ·, ·) state per input kind — no per-expert Python loop; the
    starved-expert check becomes a flag the executor applies as a mask.
    """
    qc = cfg.quant
    mc = cfg.model
    e = mc.moe.num_experts
    d, f = p_moe["w_gate"].shape[1:]
    # stream dispatch over batches: stacked per-expert Hessians for gate/up
    # (input d) and for down (input f, needs the expert mid activations).
    H_in = hess.init_hessian(d, batch=e)
    H_mid = hess.init_hessian(f, batch=e)
    real_counts = np.zeros(e, np.int64)
    x_last_in: Optional[jax.Array] = None
    x_last_mid: Optional[jax.Array] = None
    last_counts: Optional[jax.Array] = None
    for bi, xt in enumerate(xs):
        dsp = moe_mod.dispatch(mc, p_moe, xt.astype(jnp.dtype(mc.dtype)))
        buf = dsp.buf                                   # (E, C, d)
        g = jnp.einsum("ecd,edf->ecf", buf.astype(jnp.float32),
                       p_moe["w_gate"].astype(jnp.float32))
        u = jnp.einsum("ecd,edf->ecf", buf.astype(jnp.float32),
                       p_moe["w_up"].astype(jnp.float32))
        from repro.models.layers import _act
        mid = _act(mc.act, g) * u                       # (E, C, f)
        real_counts += np.asarray(dsp.counts, np.int64)
        H_in = hess.accumulate(H_in, buf)
        H_mid = hess.accumulate(H_mid, mid)
        if bi == len(xs) - 1:
            x_last_in, x_last_mid = buf, mid
            last_counts = dsp.counts

    members: List[PlanMember] = []
    for wname, Hst, xl in (("w_gate", H_in, x_last_in),
                           ("w_up", H_in, x_last_in),
                           ("w_down", H_mid, x_last_mid)):
        # zero-padded capacity rows contribute nothing to XᵀX; real routed
        # token counts drive both the starvation check and the eq.-13
        # rescale. One stacked member per weight: the expert axis stays a
        # whole (E, ·, ·) slab from capture through scatter.
        members.append(PlanMember(
            f"{name}.{wname}",
            jnp.swapaxes(jnp.asarray(p_moe[wname], jnp.float32), -1, -2),
            hess.HessianState(Hst.H,
                              jnp.asarray(real_counts, jnp.int32)),
            xl, x_count=last_counts.astype(jnp.int32),
            starved=real_counts < qc.group_size,
            names=[f"{name}.{wname}[{ei}]" for ei in range(e)]))
    return members


def _scatter_moe(p_moe: Dict, results: Dict[str, MemberResult],
                 name: str) -> Dict:
    """Reassemble stacked expert weights (+grids) from member results."""
    new = dict(p_moe)
    for wname in _MOE_WNAMES:
        res = results[f"{name}.{wname}"]
        if res.w_q is None:                             # skipped (unaligned)
            continue
        new[wname] = jnp.swapaxes(res.w_q, -1, -2).astype(
            p_moe[wname].dtype)
        if res.grid is not None:
            new[f"{wname}_qscales"] = res.grid[0]
            new[f"{wname}_qzeros"] = res.grid[1]
    return new


def quantize_layer(cfg: Config, layer_params: Dict, hs: List[jax.Array],
                   apply_fn, report: QuantReport,
                   fwd_cache: Optional[Dict] = None,
                   fwd_key: Tuple = ("layer",),
                   batch_dependent: bool = False,
                   mesh=None) -> Tuple[Dict, List]:
    """Quantize one layer's linears via the plan, then propagate.

    ``apply_fn(params, h, batch_index) -> h_out`` runs the layer.  With
    ``quant.jit_capture`` (default) and a ``fwd_cache`` dict, the capture
    and propagate forwards run through :func:`_layer_forward_jit` —
    compiled once per (fwd_key, layer signature) and reused by every
    identically shaped layer in the stack; otherwise they run eagerly
    (legacy path).  ``mesh`` forwards to
    :func:`repro.core.plan.execute_plan` for sharded group execution
    (capture itself stays single-device — only executor work scales with
    the mesh).  Returns (new_layer_params, new_hs).
    """
    qc = cfg.quant
    use_jit = qc.jit_capture and fwd_cache is not None
    is_moe = "mlp" in layer_params and "w_gate" in layer_params.get("mlp", {})
    # 1. capture: stream Hessians, keep last batch inputs
    hessians: Dict[str, hess.HessianState] = {}
    last_x: Dict[str, jax.Array] = {}
    moe_xs: List[jax.Array] = []     # per-batch MoE block inputs (router tap)

    targets = set()
    for sub in _QUANT_SUBTREES:
        if sub in layer_params:
            targets.update(f"{sub}.{n}" if n else sub
                           for n in _linear_names_in(layer_params[sub]))
    # the router stays full-precision (standard MoE-PTQ practice; its tap is
    # only used to collect the block inputs for the per-expert Hessians)
    targets.discard("mlp.router")

    def on_record(name: str, x: jax.Array):
        if name == "mlp.router":
            moe_xs.append(x.reshape(-1, x.shape[-1]))
            return
        if name not in targets:
            return
        x2 = x.reshape(-1, x.shape[-1])
        if name not in hessians:
            hessians[name] = hess.init_hessian(x2.shape[1])
        hessians[name] = hess.accumulate(hessians[name], x2)
        last_x[name] = x2        # overwritten per batch → last batch stays

    for bi, h in enumerate(hs):
        if use_jit:
            _, recs = _layer_forward_jit(fwd_cache, fwd_key, apply_fn,
                                         layer_params, h, bi,
                                         batch_dependent)
            for name, xs in recs.items():
                for x in xs:
                    on_record(name, x)
        else:
            with Tap(on_record=on_record):
                apply_fn(layer_params, h, bi)

    # 2. plan: dense taps + stacked MoE expert slices as uniform members
    new_params = jax.tree_util.tree_map(lambda x: x, layer_params)
    members: List[PlanMember] = []
    dense_names = sorted(hessians.keys())
    for name in dense_names:
        node = _resolve(new_params, name)
        members.append(PlanMember(
            name, jnp.asarray(node["w"], jnp.float32).T, hessians[name],
            last_x[name], x_count=None))
    if is_moe:
        assert len(moe_xs) == len(hs), "router tap missed batches"
        members.extend(_moe_members(cfg, new_params["mlp"], moe_xs, "mlp"))
    plan = qplan.build_plan(qc, members)

    # 3. execute groups (batched GPTQ + RPIQ) and scatter back
    results = qplan.execute_plan(qc, plan, report, mesh=mesh)
    for name in dense_names:
        res = results[name]
        if res.w_q is None:
            continue                                    # skipped: keep fp
        node = _resolve(new_params, name)
        node["w"] = res.w_q.T.astype(node["w"].dtype)
        if res.grid is not None:
            # stage-1 grid travels with the weight → exact int4 packing
            node["qscales"], node["qzeros"] = res.grid
    if is_moe:
        new_params["mlp"] = _scatter_moe(new_params["mlp"], results, "mlp")

    # 4. propagate quantized activations (same compiled forward; the
    # quantized params carry extra grid leaves, so they key their own
    # cross-layer cache entry)
    if use_jit:
        new_hs = [_layer_forward_jit(fwd_cache, fwd_key, apply_fn,
                                     new_params, h, bi, batch_dependent,
                                     collect=False)[0]
                  for bi, h in enumerate(hs)]
    else:
        new_hs = [apply_fn(new_params, h, bi) for bi, h in enumerate(hs)]
    return new_params, new_hs


_MESH_FROM_CONFIG = object()     # sentinel: resolve the quant.mesh knob


def quantize_model(cfg: Config, params: Dict,
                   calib: List[Dict[str, jax.Array]],
                   verbose: bool = False,
                   mesh=_MESH_FROM_CONFIG) -> Tuple[Dict, QuantReport]:
    """Quantize every transformer layer of a decoder-only or enc-dec model.

    ``calib``: list of batch dicts ({tokens, embeds?/frames?}); the last one
    is the single instance for stage 2.

    ``mesh``: a ``(data, model)`` Mesh for sharded group execution
    (DESIGN.md §2.6), or None to force single-device execution; left
    unset, the ``quant.mesh`` knob is resolved through
    :func:`repro.launch.mesh.make_quant_mesh` (default "off" = single
    device).
    """
    t_start = time.perf_counter()
    report = QuantReport()
    if mesh is _MESH_FROM_CONFIG:
        from repro.launch.mesh import make_quant_mesh
        mesh = make_quant_mesh(cfg.quant.mesh)

    fwd_cache: Dict = {}     # per-run compiled-forward cache (jit_capture)
    if cfg.model.is_encoder_decoder:
        out = _quantize_encdec(cfg, params, calib, report, verbose,
                               fwd_cache, mesh)
    else:
        out = _quantize_decoder_only(cfg, params, calib, report, verbose,
                                     fwd_cache, mesh)
    report.seconds_total = time.perf_counter() - t_start
    return out, report


def _quantize_decoder_only(cfg: Config, params: Dict, calib, report,
                           verbose: bool, fwd_cache: Dict,
                           mesh=None) -> Dict:
    mc = cfg.model
    dtype = jnp.dtype(mc.dtype)
    hs = []
    for b in calib:
        h = embed(params["embed"], b["tokens"], dtype)
        if b.get("embeds") is not None:
            h = jnp.concatenate([b["embeds"].astype(dtype), h], axis=1)
        hs.append(h)
    seqs = [h.shape[1] for h in hs]
    assert len(set(seqs)) == 1, "calibration batches must share seq_len"
    b0, s0, _ = hs[0].shape
    positions = jnp.arange(s0, dtype=jnp.int32)[None, :].repeat(b0, 0)

    new_blocks = []
    specs_per_seg = T.segments(mc)
    li = 0
    for seg, seg_params in zip(specs_per_seg, params["blocks"]):
        elems = []
        for c in range(seg.count):
            elem = T._seg_take(seg_params, c)
            new_elem = {}
            for s_i, spec in enumerate(seg.specs):
                lp = elem[f"sub{s_i}"]

                def apply_fn(p, h, bi, _spec=spec):
                    out, _ = T.layer_forward(mc, _spec, p, h, positions)
                    return out

                lp_new, hs = quantize_layer(cfg, lp, hs, apply_fn, report,
                                            fwd_cache=fwd_cache,
                                            fwd_key=("dec", str(spec)),
                                            mesh=mesh)
                new_elem[f"sub{s_i}"] = lp_new
                li += 1
                if verbose:
                    print(f"  layer {li}: {report.summary()}")
            elems.append(new_elem)
        new_blocks.append(T._stack_trees(elems))
    out = dict(params)
    out["blocks"] = new_blocks
    return out


def _quantize_encdec(cfg: Config, params: Dict, calib, report,
                     verbose: bool, fwd_cache: Dict, mesh=None) -> Dict:
    mc = cfg.model
    dtype = jnp.dtype(mc.dtype)
    # ----- encoder -----
    hs = []
    for b in calib:
        fr = b["frames"].astype(dtype)
        hs.append(fr + sinusoidal_positions(fr.shape[1], mc.d_model
                                            )[None].astype(dtype))
    se = hs[0].shape[1]
    b0 = hs[0].shape[0]
    enc_pos = jnp.arange(se, dtype=jnp.int32)[None, :].repeat(b0, 0)

    n_enc = jax.tree_util.tree_leaves(
        params["encoder"]["layers"])[0].shape[0]
    enc_elems = []
    for i in range(n_enc):
        lp = T._seg_take(params["encoder"]["layers"], i)

        def enc_apply(p, h, bi):
            hn = norm(mc, p["norm1"], h)
            from repro.models import attention as attn
            y = attn.attention_forward(mc, p["mixer"], hn, enc_pos,
                                       causal=False, use_rope=False,
                                       name="mixer")
            h = h + y
            hn = norm(mc, p["norm2"], h)
            from repro.models.layers import mlp as mlp_fn
            return h + mlp_fn(mc, p["mlp"], hn, name="mlp")

        lp_new, hs = quantize_layer(cfg, lp, hs, enc_apply, report,
                                    fwd_cache=fwd_cache, fwd_key=("enc",),
                                    mesh=mesh)
        enc_elems.append(lp_new)
    enc_out = [norm(mc, params["encoder"]["final_norm"], h) for h in hs]

    # ----- decoder -----
    dhs = []
    for b in calib:
        tk = b["tokens"]
        h = embed(params["embed"], tk, dtype)
        dhs.append(h + sinusoidal_positions(tk.shape[1], mc.d_model
                                            )[None].astype(dtype))
    sd = dhs[0].shape[1]
    dec_pos = jnp.arange(sd, dtype=jnp.int32)[None, :].repeat(b0, 0)

    n_dec = jax.tree_util.tree_leaves(
        params["decoder"]["layers"])[0].shape[0]
    dec_elems = []
    for i in range(n_dec):
        lp = T._seg_take(params["decoder"]["layers"], i)

        def dec_apply(p, h, bi):
            from repro.models import attention as attn
            from repro.models.layers import mlp as mlp_fn
            llp = p["layer"]
            hn = norm(mc, llp["norm1"], h)
            y = attn.attention_forward(mc, llp["mixer"], hn, dec_pos,
                                       causal=True, use_rope=False,
                                       name="layer.mixer")
            h = h + y
            hn = norm(mc, p["xnorm"], h)
            kv = attn.cross_attention_kv(mc, p["xattn"], enc_out[bi],
                                         "xattn")
            h = h + attn.cross_attention(mc, p["xattn"], hn, kv, "xattn")
            hn = norm(mc, llp["norm2"], h)
            return h + mlp_fn(mc, llp["mlp"], hn, name="layer.mlp")

        # enc_out[bi] is baked into the trace → key per batch index
        lp_new, dhs = quantize_layer(cfg, lp, dhs, dec_apply, report,
                                     fwd_cache=fwd_cache, fwd_key=("xdec",),
                                     batch_dependent=True, mesh=mesh)
        dec_elems.append(lp_new)

    out = dict(params)
    out["encoder"] = {"layers": T._stack_trees(enc_elems),
                      "final_norm": params["encoder"]["final_norm"]}
    out["decoder"] = {"layers": T._stack_trees(dec_elems),
                      "final_norm": params["decoder"]["final_norm"]}
    return out


# ---------------------------------------------------------------------------
# Packing to serving artifacts
# ---------------------------------------------------------------------------

def pack_for_serving(cfg: Config, params_q: Dict) -> Dict:
    """Replace quantized-linear float weights with int4 QuantizedTensor.

    Weights are re-gridded with fresh (scale, zero) per group — the values
    are already on a 4-bit grid from the pipeline, so this round-trips
    exactly (asserted in tests). Norms/embeddings stay fp.
    """
    qc = cfg.quant

    from repro.core.quant import QuantParams, compute_qparams, quantize_codes

    def pack_generic(w: jax.Array, scales=None,
                     zeros=None) -> QuantizedTensor:
        """(..., in, out) float → (..., out, in//2)-packed QuantizedTensor.

        Leading dims cover scan-stacked layers and/or the expert axis; the
        math is fully vectorized (no per-expert Python loops — deepseek has
        58×256 expert matrices). When the pipeline carried the stage-1 grid
        (qscales/qzeros), packing on it round-trips the refined weights
        EXACTLY; otherwise the grid is recomputed (lossy only for weights
        not already on a grid, e.g. fp checkpoints packed directly).
        """
        w_oi = jnp.swapaxes(jnp.asarray(w, jnp.float32), -1, -2)
        lead = w_oi.shape[:-2]
        o, i = w_oi.shape[-2:]
        g = i // qc.group_size
        w2 = w_oi.reshape(-1, i)
        if scales is not None:
            qp = QuantParams(jnp.asarray(scales, jnp.float32)
                             .reshape(-1, g),
                             jnp.asarray(zeros, jnp.float32).reshape(-1, g))
        else:
            qp = compute_qparams(w2, qc.bits, qc.group_size)
        codes = quantize_codes(w2, qp, qc.bits, qc.group_size)
        packed = pack_int4(codes).reshape(*lead, o, i // 2)
        return QuantizedTensor(packed,
                               qp.scales.reshape(*lead, o, g),
                               qp.zeros.reshape(*lead, o, g),
                               (*lead, o, i), qc.bits, qc.group_size)

    def walk(tree, path=""):
        if isinstance(tree, dict):
            out = {}
            for k, v in tree.items():
                sub = f"{path}.{k}"
                if k in ("qscales", "qzeros") or k.endswith("_qscales") \
                        or k.endswith("_qzeros"):
                    continue                      # consumed by the packer
                if (k == "w" and getattr(v, "ndim", 0) >= 2
                        and any(s in path for s in _QUANT_SUBTREES)
                        and v.shape[-2] % qc.group_size == 0
                        and "router" not in path):
                    out[k] = pack_generic(v, tree.get("qscales"),
                                          tree.get("qzeros"))
                elif (k in ("w_gate", "w_up", "w_down")
                      and getattr(v, "ndim", 0) >= 3
                      and v.shape[-2] % qc.group_size == 0):
                    out[k] = pack_generic(v, tree.get(f"{k}_qscales"),
                                          tree.get(f"{k}_qzeros"))
                else:
                    out[k] = walk(v, sub)
            return out
        if isinstance(tree, list):
            return [walk(v, path) for v in tree]
        return tree

    return walk(params_q)
