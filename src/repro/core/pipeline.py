"""The RPIQ model-quantization pipeline (the paper's end-to-end procedure).

Sequential layer-wise calibration, exactly as GPTQ/AutoGPTQ practice it and
the paper assumes:

  1. embed every calibration batch → residual streams ``hs``;
  2. for each transformer layer (eagerly, segment-element by element):
     a. **capture** — run the layer over all batches with a :class:`Tap`
        that streams each named linear's inputs into its Hessian
        (eq. 9, ``H += X_bᵀX_b``) and keeps only the **last** batch's
        inputs resident (single-instance paradigm, eq. 11);
     b. **stage 1** — GPTQ per linear from the damped Hessian (eq. 10);
     c. **stage 2** — RPIQ refinement per linear from
        ``(X_last, W_fp, H̃)`` (eq. 4–8, 12–14, 19–23);
     d. **replace** the layer's weights with the refined on-grid values and
        re-run the layer to **propagate quantized activations** to the next
        layer (so later Hessians see the quantized network — GPTQ
        semantics);
  3. MoE layers: the router/shared-expert linears tap normally; routed
     expert FFNs get **per-expert Hessians from their routed tokens** via
     ``moe.dispatch`` (capacity-padded zero rows contribute nothing to
     ``XᵀX``); experts that saw fewer than one group of tokens fall back
     to RTN on their own grid (recorded in the report).

Returns float params whose quantized linears hold *on-grid* values plus a
``QuantReport`` (per-linear Γ histories = paper Table 5 / Fig. 5) and a
packer to int4 serving artifacts (QuantizedTensor leaves).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import Config, QuantConfig
from repro.core import hessian as hess
from repro.core.gptq import gptq_quantize, rtn_quantize
from repro.core.quant import QuantizedTensor, pack_int4
from repro.core.rpiq import rpiq_refine
from repro.kernels import ops as kops
from repro.models import transformer as T
from repro.models import moe as moe_mod
from repro.models.linear import Tap
from repro.models.layers import embed, norm, sinusoidal_positions


@dataclasses.dataclass
class LinearRecord:
    name: str
    shape: Tuple[int, int]           # (out, in)
    gptq_err: float
    gamma: List[float]               # Γ trajectory (Γ[0] = post-stage-1)
    gamma_final: float
    iters: int
    mode: str                        # "rpiq" | "rtn-fallback" | "skipped"
    seconds: float


@dataclasses.dataclass
class QuantReport:
    linears: List[LinearRecord] = dataclasses.field(default_factory=list)
    seconds_total: float = 0.0
    seconds_stage1: float = 0.0
    seconds_stage2: float = 0.0
    peak_resident_bytes: int = 0     # analytic single-instance residency

    def summary(self) -> str:
        n = len(self.linears)
        improved = sum(1 for l in self.linears
                       if l.gamma and l.gamma_final < l.gamma[0] * 0.999)
        return (f"{n} linears quantized; stage2 improved {improved}; "
                f"t={self.seconds_total:.1f}s "
                f"(s1={self.seconds_stage1:.1f} s2={self.seconds_stage2:.1f})")


def _resolve(tree: Dict, dotted: str):
    node = tree
    for part in dotted.split("."):
        node = node[part]
    return node


def _quantize_linear(qc: QuantConfig, w_io: jax.Array,
                     hstate: hess.HessianState, x_last: jax.Array,
                     report: QuantReport, name: str,
                     rpiq_enabled: bool = True,
                     x_count: Optional[jax.Array] = None):
    """Quantize one linear. w_io: (in, out) model weight.

    Returns (w_io_quantized, (scales, zeros) | None) — the grid is carried
    in the param tree so packing round-trips exactly.
    """
    t0 = time.perf_counter()
    w_oi = jnp.asarray(w_io, jnp.float32).T
    in_dim = w_oi.shape[1]
    if in_dim % qc.blocksize != 0 or in_dim % qc.group_size != 0:
        report.linears.append(LinearRecord(
            name, tuple(w_oi.shape), 0.0, [], 0.0, 0, "skipped",
            time.perf_counter() - t0))
        return w_io, None
    Hd = hess.damped(hstate, qc.percdamp)
    u = hess.cholesky_inverse_upper(Hd)
    res1 = gptq_quantize(w_oi, u, bits=qc.bits, group_size=qc.group_size,
                         blocksize=qc.blocksize, symmetric=qc.symmetric)
    t1 = time.perf_counter()
    report.seconds_stage1 += t1 - t0
    grid = (res1.scales, res1.zeros)
    if not rpiq_enabled or qc.rpiq_iters <= 0:
        report.linears.append(LinearRecord(
            name, tuple(w_oi.shape), float(res1.err), [], 0.0, 0, "gptq",
            t1 - t0))
        return res1.w_q.T.astype(w_io.dtype), grid
    x2 = x_last.reshape(-1, in_dim)
    res2 = rpiq_refine(res1.w_q, w_oi, x2, Hd, res1.scales, res1.zeros,
                       h_count=hstate.count, x_count=x_count, bits=qc.bits,
                       group_size=qc.group_size, block_size=qc.blocksize,
                       alpha=qc.rpiq_alpha, t_max=qc.rpiq_iters,
                       early_stop=qc.rpiq_early_stop,
                       exact_gram=not qc.rpiq_use_global_hessian)
    t2 = time.perf_counter()
    report.seconds_stage2 += t2 - t1
    gam = [float(g) for g in np.asarray(res2.loss_history)
           if np.isfinite(g)]
    report.linears.append(LinearRecord(
        name, tuple(w_oi.shape), float(res1.err), gam,
        float(res2.proj_loss), int(res2.iters_run), "rpiq", t2 - t0))
    return res2.w_q.T.astype(w_io.dtype), grid


def _quantize_moe_experts(cfg: Config, p_moe: Dict, xs: List[jax.Array],
                          mc, report: QuantReport, name: str) -> Dict:
    """Per-expert Hessians from routed tokens (paper's method per expert).

    ``xs``: per-calibration-batch flat MoE block inputs (T, d), collected
    from the router tap.
    """
    qc = cfg.quant
    m = mc.moe
    e = m.num_experts
    d, f = p_moe["w_gate"].shape[1:]
    # stream dispatch over batches: per-expert Hessians for gate/up (input d)
    # and for down (input f, needs the expert mid activations).
    H_in = [hess.init_hessian(d) for _ in range(e)]
    H_mid = [hess.init_hessian(f) for _ in range(e)]
    real_counts = np.zeros(e, np.int64)
    x_last_in: Optional[jax.Array] = None
    x_last_mid: Optional[jax.Array] = None
    for bi, xt in enumerate(xs):
        dsp = moe_mod.dispatch(mc, p_moe, xt.astype(jnp.dtype(mc.dtype)))
        buf = dsp.buf                                   # (E, C, d)
        g = jnp.einsum("ecd,edf->ecf", buf.astype(jnp.float32),
                       p_moe["w_gate"].astype(jnp.float32))
        u = jnp.einsum("ecd,edf->ecf", buf.astype(jnp.float32),
                       p_moe["w_up"].astype(jnp.float32))
        from repro.models.layers import _act
        mid = _act(mc.act, g) * u                       # (E, C, f)
        real_counts += np.asarray(dsp.counts, np.int64)
        for ei in range(e):
            H_in[ei] = hess.accumulate(H_in[ei], buf[ei])
            H_mid[ei] = hess.accumulate(H_mid[ei], mid[ei])
        if bi == len(xs) - 1:
            x_last_in, x_last_mid = buf, mid

    # zero-padded capacity rows contribute nothing to XᵀX; use real routed
    # token counts for both the starvation check and the eq.-13 rescale.
    H_in = [hess.HessianState(h.H, jnp.asarray(int(c), jnp.int32))
            for h, c in zip(H_in, real_counts)]
    H_mid = [hess.HessianState(h.H, jnp.asarray(int(c), jnp.int32))
             for h, c in zip(H_mid, real_counts)]

    new = dict(p_moe)
    for wname, Hs, xl in (
            ("w_gate", H_in, x_last_in),
            ("w_up", H_in, x_last_in),
            ("w_down", H_mid, x_last_mid)):
        stacked, grids = [], []
        for ei in range(e):
            w_e = p_moe[wname][ei]                      # (in, out)
            n_tok = int(Hs[ei].count)
            if n_tok < qc.group_size:
                # starved expert: RTN fallback on its own grid
                gsz = (qc.group_size
                       if w_e.shape[0] % qc.group_size == 0
                       else w_e.shape[0])
                res = rtn_quantize(jnp.asarray(w_e, jnp.float32).T,
                                   bits=qc.bits, group_size=gsz)
                stacked.append(res.w_q.T.astype(p_moe[wname].dtype))
                grids.append((res.scales, res.zeros) if gsz ==
                             qc.group_size else None)
                report.linears.append(LinearRecord(
                    f"{name}.{wname}[{ei}]", tuple(w_e.shape[::-1]),
                    0.0, [], 0.0, 0, "rtn-fallback", 0.0))
            else:
                w_q, grid = _quantize_linear(
                    qc, w_e, Hs[ei], xl[ei], report,
                    f"{name}.{wname}[{ei}]",
                    x_count=dsp.counts[ei].astype(jnp.int32))
                stacked.append(w_q)
                grids.append(grid)
        new[wname] = jnp.stack(stacked)
        if all(g is not None for g in grids):
            new[f"{wname}_qscales"] = jnp.stack([g[0] for g in grids])
            new[f"{wname}_qzeros"] = jnp.stack([g[1] for g in grids])
    return new


def _linear_names_in(tree: Dict, prefix: str = "") -> List[str]:
    """Dotted paths of {w:...} dense params inside a layer subtree."""
    out = []
    for k, v in tree.items():
        path = f"{prefix}.{k}" if prefix else k
        if isinstance(v, dict):
            if "w" in v and not isinstance(v["w"], dict) \
                    and getattr(v["w"], "ndim", 0) == 2:
                out.append(path)
            else:
                out.extend(_linear_names_in(v, path))
    return out


_QUANT_SUBTREES = ("mixer", "mlp", "xattn")   # norms/embeds stay fp


def quantize_layer(cfg: Config, layer_params: Dict, hs: List[jax.Array],
                   apply_fn, report: QuantReport) -> Tuple[Dict, List]:
    """Quantize one layer's linears, then propagate quantized outputs.

    ``apply_fn(params, h, batch_index) -> h_out`` runs the layer eagerly.
    Returns (new_layer_params, new_hs).
    """
    qc = cfg.quant
    mc = cfg.model
    is_moe = "mlp" in layer_params and "w_gate" in layer_params.get("mlp", {})
    # 1. capture: stream Hessians, keep last batch inputs
    hessians: Dict[str, hess.HessianState] = {}
    last_x: Dict[str, jax.Array] = {}
    moe_xs: List[jax.Array] = []     # per-batch MoE block inputs (router tap)

    targets = set()
    for sub in _QUANT_SUBTREES:
        if sub in layer_params:
            targets.update(f"{sub}.{n}" if n else sub
                           for n in _linear_names_in(layer_params[sub]))
    # the router stays full-precision (standard MoE-PTQ practice; its tap is
    # only used to collect the block inputs for the per-expert Hessians)
    targets.discard("mlp.router")

    def on_record(name: str, x: jax.Array):
        if name == "mlp.router":
            moe_xs.append(x.reshape(-1, x.shape[-1]))
            return
        if name not in targets:
            return
        x2 = x.reshape(-1, x.shape[-1])
        if name not in hessians:
            hessians[name] = hess.init_hessian(x2.shape[1])
        hessians[name] = hess.accumulate(hessians[name], x2)
        last_x[name] = x2        # overwritten per batch → last batch stays

    for bi, h in enumerate(hs):
        with Tap(on_record=on_record):
            apply_fn(layer_params, h, bi)

    # 2/3. quantize each captured linear (stage 1 + stage 2)
    new_params = jax.tree_util.tree_map(lambda x: x, layer_params)
    for name in sorted(hessians.keys()):
        node = _resolve(new_params, name)
        node["w"], grid = _quantize_linear(qc, node["w"], hessians[name],
                                           last_x[name], report, name)
        if grid is not None:
            # stage-1 grid travels with the weight → exact int4 packing
            node["qscales"], node["qzeros"] = grid

    # MoE routed experts (stacked einsums, not dense() taps)
    if is_moe:
        assert len(moe_xs) == len(hs), "router tap missed batches"
        new_params["mlp"] = _quantize_moe_experts(
            cfg, new_params["mlp"], moe_xs, mc, report, "mlp")

    # 4. propagate quantized activations
    new_hs = [apply_fn(new_params, h, bi) for bi, h in enumerate(hs)]
    return new_params, new_hs


def quantize_model(cfg: Config, params: Dict,
                   calib: List[Dict[str, jax.Array]],
                   verbose: bool = False) -> Tuple[Dict, QuantReport]:
    """Quantize every transformer layer of a decoder-only or enc-dec model.

    ``calib``: list of batch dicts ({tokens, embeds?/frames?}); the last one
    is the single instance for stage 2.
    """
    t_start = time.perf_counter()
    mc = cfg.model
    report = QuantReport()
    dtype = jnp.dtype(mc.dtype)

    if mc.is_encoder_decoder:
        out = _quantize_encdec(cfg, params, calib, report, verbose)
    else:
        out = _quantize_decoder_only(cfg, params, calib, report, verbose)
    report.seconds_total = time.perf_counter() - t_start
    return out, report


def _quantize_decoder_only(cfg: Config, params: Dict, calib, report,
                           verbose: bool) -> Dict:
    mc = cfg.model
    dtype = jnp.dtype(mc.dtype)
    hs = []
    for b in calib:
        h = embed(params["embed"], b["tokens"], dtype)
        if b.get("embeds") is not None:
            h = jnp.concatenate([b["embeds"].astype(dtype), h], axis=1)
        hs.append(h)
    seqs = [h.shape[1] for h in hs]
    assert len(set(seqs)) == 1, "calibration batches must share seq_len"
    b0, s0, _ = hs[0].shape
    positions = jnp.arange(s0, dtype=jnp.int32)[None, :].repeat(b0, 0)

    new_blocks = []
    specs_per_seg = T.segments(mc)
    li = 0
    for seg, seg_params in zip(specs_per_seg, params["blocks"]):
        elems = []
        for c in range(seg.count):
            elem = T._seg_take(seg_params, c)
            new_elem = {}
            for s_i, spec in enumerate(seg.specs):
                lp = elem[f"sub{s_i}"]

                def apply_fn(p, h, bi, _spec=spec):
                    out, _ = T.layer_forward(mc, _spec, p, h, positions)
                    return out

                lp_new, hs = quantize_layer(cfg, lp, hs, apply_fn, report)
                new_elem[f"sub{s_i}"] = lp_new
                li += 1
                if verbose:
                    last = report.linears[-1] if report.linears else None
                    print(f"  layer {li}: {report.summary()}")
            elems.append(new_elem)
        new_blocks.append(T._stack_trees(elems))
    out = dict(params)
    out["blocks"] = new_blocks
    return out


def _quantize_encdec(cfg: Config, params: Dict, calib, report,
                     verbose: bool) -> Dict:
    mc = cfg.model
    dtype = jnp.dtype(mc.dtype)
    # ----- encoder -----
    hs = []
    for b in calib:
        fr = b["frames"].astype(dtype)
        hs.append(fr + sinusoidal_positions(fr.shape[1], mc.d_model
                                            )[None].astype(dtype))
    se = hs[0].shape[1]
    b0 = hs[0].shape[0]
    enc_pos = jnp.arange(se, dtype=jnp.int32)[None, :].repeat(b0, 0)

    n_enc = jax.tree_util.tree_leaves(
        params["encoder"]["layers"])[0].shape[0]
    enc_elems = []
    for i in range(n_enc):
        lp = T._seg_take(params["encoder"]["layers"], i)

        def enc_apply(p, h, bi):
            hn = norm(mc, p["norm1"], h)
            from repro.models import attention as attn
            y = attn.attention_forward(mc, p["mixer"], hn, enc_pos,
                                       causal=False, use_rope=False,
                                       name="mixer")
            h = h + y
            hn = norm(mc, p["norm2"], h)
            from repro.models.layers import mlp as mlp_fn
            return h + mlp_fn(mc, p["mlp"], hn, name="mlp")

        lp_new, hs = quantize_layer(cfg, lp, hs, enc_apply, report)
        enc_elems.append(lp_new)
    enc_out = [norm(mc, params["encoder"]["final_norm"], h) for h in hs]

    # ----- decoder -----
    dhs = []
    for b in calib:
        tk = b["tokens"]
        h = embed(params["embed"], tk, dtype)
        dhs.append(h + sinusoidal_positions(tk.shape[1], mc.d_model
                                            )[None].astype(dtype))
    sd = dhs[0].shape[1]
    dec_pos = jnp.arange(sd, dtype=jnp.int32)[None, :].repeat(b0, 0)

    n_dec = jax.tree_util.tree_leaves(
        params["decoder"]["layers"])[0].shape[0]
    dec_elems = []
    for i in range(n_dec):
        lp = T._seg_take(params["decoder"]["layers"], i)

        def dec_apply(p, h, bi):
            from repro.models import attention as attn
            from repro.models.layers import mlp as mlp_fn
            llp = p["layer"]
            hn = norm(mc, llp["norm1"], h)
            y = attn.attention_forward(mc, llp["mixer"], hn, dec_pos,
                                       causal=True, use_rope=False,
                                       name="layer.mixer")
            h = h + y
            hn = norm(mc, p["xnorm"], h)
            kv = attn.cross_attention_kv(mc, p["xattn"], enc_out[bi],
                                         "xattn")
            h = h + attn.cross_attention(mc, p["xattn"], hn, kv, "xattn")
            hn = norm(mc, llp["norm2"], h)
            return h + mlp_fn(mc, llp["mlp"], hn, name="layer.mlp")

        lp_new, dhs = quantize_layer(cfg, lp, dhs, dec_apply, report)
        dec_elems.append(lp_new)

    out = dict(params)
    out["encoder"] = {"layers": T._stack_trees(enc_elems),
                      "final_norm": params["encoder"]["final_norm"]}
    out["decoder"] = {"layers": T._stack_trees(dec_elems),
                      "final_norm": params["decoder"]["final_norm"]}
    return out


# ---------------------------------------------------------------------------
# Packing to serving artifacts
# ---------------------------------------------------------------------------

def pack_for_serving(cfg: Config, params_q: Dict) -> Dict:
    """Replace quantized-linear float weights with int4 QuantizedTensor.

    Weights are re-gridded with fresh (scale, zero) per group — the values
    are already on a 4-bit grid from the pipeline, so this round-trips
    exactly (asserted in tests). Norms/embeddings stay fp.
    """
    qc = cfg.quant

    from repro.core.quant import QuantParams, compute_qparams, quantize_codes

    def pack_generic(w: jax.Array, scales=None,
                     zeros=None) -> QuantizedTensor:
        """(..., in, out) float → (..., out, in//2)-packed QuantizedTensor.

        Leading dims cover scan-stacked layers and/or the expert axis; the
        math is fully vectorized (no per-expert Python loops — deepseek has
        58×256 expert matrices). When the pipeline carried the stage-1 grid
        (qscales/qzeros), packing on it round-trips the refined weights
        EXACTLY; otherwise the grid is recomputed (lossy only for weights
        not already on a grid, e.g. fp checkpoints packed directly).
        """
        w_oi = jnp.swapaxes(jnp.asarray(w, jnp.float32), -1, -2)
        lead = w_oi.shape[:-2]
        o, i = w_oi.shape[-2:]
        g = i // qc.group_size
        w2 = w_oi.reshape(-1, i)
        if scales is not None:
            qp = QuantParams(jnp.asarray(scales, jnp.float32)
                             .reshape(-1, g),
                             jnp.asarray(zeros, jnp.float32).reshape(-1, g))
        else:
            qp = compute_qparams(w2, qc.bits, qc.group_size)
        codes = quantize_codes(w2, qp, qc.bits, qc.group_size)
        packed = pack_int4(codes).reshape(*lead, o, i // 2)
        return QuantizedTensor(packed,
                               qp.scales.reshape(*lead, o, g),
                               qp.zeros.reshape(*lead, o, g),
                               (*lead, o, i), qc.bits, qc.group_size)

    def walk(tree, path=""):
        if isinstance(tree, dict):
            out = {}
            for k, v in tree.items():
                sub = f"{path}.{k}"
                if k in ("qscales", "qzeros") or k.endswith("_qscales") \
                        or k.endswith("_qzeros"):
                    continue                      # consumed by the packer
                if (k == "w" and getattr(v, "ndim", 0) >= 2
                        and any(s in path for s in _QUANT_SUBTREES)
                        and v.shape[-2] % qc.group_size == 0
                        and "router" not in path):
                    out[k] = pack_generic(v, tree.get("qscales"),
                                          tree.get("qzeros"))
                elif (k in ("w_gate", "w_up", "w_down")
                      and getattr(v, "ndim", 0) >= 3
                      and v.shape[-2] % qc.group_size == 0):
                    out[k] = pack_generic(v, tree.get(f"{k}_qscales"),
                                          tree.get(f"{k}_qzeros"))
                else:
                    out[k] = walk(v, sub)
            return out
        if isinstance(tree, list):
            return [walk(v, path) for v in tree]
        return tree

    return walk(params_q)
