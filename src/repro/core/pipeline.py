"""The RPIQ model-quantization pipeline (the paper's end-to-end procedure).

Sequential layer-wise calibration, exactly as GPTQ/AutoGPTQ practice it and
the paper assumes:

  1. embed every calibration batch → residual streams ``hs``;
  2. for each transformer layer (segment-element by element):
     a. **capture** — run the layer over all batches with a :class:`Tap`
        that streams each named linear's inputs into its Hessian
        (eq. 9, ``H += X_bᵀX_b``) and keeps only the **last** batch's
        inputs resident (single-instance paradigm, eq. 11). With
        ``quant.jit_capture`` (default) the forward is COMPILED — the tap
        collects tracers inside the jit and the inputs come back as
        outputs — and cached per layer signature, so repeated layers
        reuse the compiled forward (``False`` = legacy eager capture);
     b. **plan** — :func:`repro.core.plan.build_plan` turns the captured
        linears (dense taps AND stacked MoE expert slices) into a
        :class:`~repro.core.plan.QuantPlan`: members grouped by
        ``(shape, n_last, group_size, blocksize, bits, symmetric)``;
     c. **execute** — each group runs through the *batched* executors
        (``gptq_quantize_batched`` stage 1, eq. 10; ``rpiq_refine_batched``
        stage 2, eq. 4–8, 12–14, 19–23): weights/Hessians/instances are
        stacked on a leading axis and quantized in ONE dispatch per stage
        per group instead of one per linear (``quant.batched_executor=False``
        restores per-linear dispatch — same plan, singleton executors);
     d. **scatter** the on-grid results back into the param tree and re-run
        the layer to **propagate quantized activations** to the next layer
        (so later Hessians see the quantized network — GPTQ semantics);
  3. MoE layers: the router/shared-expert linears tap normally; routed
     expert FFNs get **per-expert Hessians from their routed tokens** via
     ``moe.dispatch``, accumulated as ONE stacked (E, d, d) HessianState
     (capacity-padded zero rows contribute nothing to ``XᵀX``). All E
     experts of a weight join the plan as one group of E stacked members —
     w_gate and w_up even share a 2E-member group — and experts that saw
     fewer than one group of tokens become an RTN fallback *mask inside
     the group* (recorded in the report as before).

The walk itself is architecture-agnostic: both decoder-only and enc-dec
models (MoE layers included) describe themselves as ONE
:class:`~repro.core.stream.LayerWalker` — a flat list of
``LayerStep{apply_fn, param_subtree, hs_slot, signature}`` items built by
:func:`_walker_decoder_only` / :func:`_walker_encdec` — and the scheduler
in :mod:`repro.core.stream` drains it. ``quant.pipeline`` selects the
schedule: ``serial`` alternates capture/execute/propagate per layer with
per-stage synchronized timings; ``overlap`` keeps executor dispatches
async and speculatively runs the next layer's capture forward on the
pre-quantization stream, repairing it exactly after the scatter lands
(DESIGN.md §2.7). Both schedules produce bitwise-identical artifacts.

Returns float params whose quantized linears hold *on-grid* values plus a
``QuantReport`` (per-linear Γ histories = paper Table 5 / Fig. 5) and a
packer to int4 serving artifacts (QuantizedTensor leaves).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import Config
from repro.core import faults
from repro.core import hessian as hess
from repro.core import plan as qplan
from repro.core import stream as qstream
from repro.core.plan import (LinearRecord, MemberResult,  # noqa: F401
                             PlanMember, QuantReport)
from repro.core.quant import QuantizedTensor, pack_int4
from repro.kernels import ops as kops
from repro.core.stream import LayerStep, LayerWalker, StreamSwitch
from repro.models import transformer as T
from repro.models import moe as moe_mod
from repro.models.linear import Tap
from repro.models.layers import embed, norm, sinusoidal_positions


# ---------------------------------------------------------------------------
# Jitted calibration forward (capture + propagate)
#
# The capture/propagate forwards used to run eagerly, op by op — the
# second wall-clock dominator after the executors (benchmarks/
# table4_time.py).  ``_layer_forward_jit`` compiles them instead: the Tap
# opens INSIDE the traced function in collect-tracers mode, so the tapped
# layer inputs come back as ordinary jit outputs.  Entries are cached per
# (fwd_key, batch index, layer-signature) for ONE ``quantize_model`` run
# — repeated layers (same spec + shapes) reuse the compiled forward, and
# scoping the cache to the run keeps closure constants (positions,
# encoder outputs) from leaking across models.  Batch-independent layers
# collapse the batch index to 0; the encoder-decoder decoder bakes
# ``enc_out[bi]`` into the trace, so it keys per batch.
# ---------------------------------------------------------------------------

class ForwardCache(dict):
    """Per-run compiled-forward cache with hit/miss counters.

    A plain dict keyed by (fwd_key, batch-index, collect, layer
    signature); the counters make capture-forward reuse observable next
    to :func:`repro.core.plan.executor_cache_stats` (the overlap
    scheduler's speculative captures share entries with their exact
    repairs, so speculation never doubles compiles).
    """

    def __init__(self):
        super().__init__()
        self.hits = 0
        self.misses = 0

    def get(self, key, default=None):
        fn = super().get(key, default)
        if fn is None:
            self.misses += 1
        else:
            self.hits += 1
        return fn

    def stats(self) -> Dict[str, int]:
        return {"hits": self.hits, "misses": self.misses}


_LAST_FWD_STATS: Dict[str, int] = {"hits": 0, "misses": 0}


def capture_cache_stats() -> Dict[str, int]:
    """{hits, misses} of the capture/propagate forward cache of the most
    recent :func:`quantize_model` run (API symmetry with
    ``plan.executor_cache_stats()``). Only the counters outlive the run —
    the cache itself (compiled forwards + their baked closure constants)
    stays run-scoped and is dropped with it."""
    return dict(_LAST_FWD_STATS)


def _tree_signature(tree) -> Tuple:
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return (str(treedef),
            tuple((tuple(l.shape), str(l.dtype)) for l in leaves))


def _layer_forward_jit(fwd_cache: Dict, fwd_key: Tuple, apply_fn,
                       params: Dict, h: jax.Array, bi: int,
                       batch_dependent: bool, collect: bool = True):
    """Run one layer forward compiled; returns (h_out, {name: [inputs]}).

    ``collect=False`` (the propagate pass) compiles a tap-less forward —
    returning the tapped inputs as jit outputs would force XLA to
    materialize every linear's input buffer the caller then discards.
    """
    key_bi = bi if batch_dependent else 0
    key = (fwd_key, key_bi, collect, _tree_signature(params), h.shape,
           str(h.dtype))
    fn = fwd_cache.get(key)
    if fn is None:
        def fwd(p, hh, _bi=bi):
            if not collect:
                return apply_fn(p, hh, _bi), {}
            tap = Tap(collect_tracers=True)
            with tap:
                out = apply_fn(p, hh, _bi)
            return out, {k: list(v) for k, v in tap.records.items()}
        fn = jax.jit(fwd)
        fwd_cache[key] = fn
    return fn(params, h)


def _resolve(tree: Dict, dotted: str):
    node = tree
    for part in dotted.split("."):
        node = node[part]
    return node


def _linear_names_in(tree: Dict, prefix: str = "") -> List[str]:
    """Dotted paths of {w:...} dense params inside a layer subtree."""
    out = []
    for k, v in tree.items():
        path = f"{prefix}.{k}" if prefix else k
        if isinstance(v, dict):
            if "w" in v and not isinstance(v["w"], dict) \
                    and getattr(v["w"], "ndim", 0) == 2:
                out.append(path)
            else:
                out.extend(_linear_names_in(v, path))
    return out


_QUANT_SUBTREES = ("mixer", "mlp", "xattn")   # norms/embeds stay fp
_MOE_WNAMES = ("w_gate", "w_up", "w_down")


def _is_moe_layer(layer_params: Dict) -> bool:
    mlp = layer_params.get("mlp")
    return isinstance(mlp, dict) and "w_gate" in mlp


def _layer_repair_sound(layer_params: Dict) -> bool:
    """Is the capture-ahead Hessian repair sound for this layer signature?

    Every current signature is. Dense layers re-propagate their taps on
    the post-scatter stream through the same compiled entries (the exact
    repair). Routed-MoE layers — formerly the exception — now repair at
    the *plan* level: the speculative pass precomputes each batch's
    dispatch plan, and ``_moe_members`` re-runs only the routing head on
    the true stream, reusing the sort/capacity structure wholesale when
    no assignment flipped and re-sorting flipped batches (bounded by
    ``quant.moe_flip_budget``). Kept as a predicate so tests can
    monkeypatch a forced-unsound lane (tests/test_pipeline_stream.py).
    """
    del layer_params
    return True


def _moe_members(cfg: Config, p_moe: Dict, xs: List[jax.Array],
                 name: str, report: Optional[QuantReport] = None,
                 stats: Optional[Dict] = None,
                 spec_routes: Optional[List] = None,
                 layer_name: str = "layer") -> List[PlanMember]:
    """Plan members for the routed experts (paper's method per expert).

    ``xs``: per-calibration-batch flat MoE block inputs (T, d), collected
    from the router tap. Per-expert Hessians accumulate as one stacked
    (E, ·, ·) state per input kind — no per-expert Python loop; the
    starved-expert check becomes a flag the executor applies as a mask.

    ``spec_routes`` (overlap scheduler): dispatch plans the speculative
    capture computed on the PRE-quantization stream. Routing is always
    recomputed here on the true stream — only the routing head (router
    matmul + top-k); the sort/capacity *structure* is a pure function of
    the expert ids (models/moe.py), so batches whose assignments did not
    flip reuse the speculative structure bitwise and only flipped
    batches re-sort. Every Hessian accumulates true-stream values
    through the same ops as serial either way, which is what keeps
    overlap bitwise-equal to serial on routed MoE.
    """
    qc = cfg.quant
    mc = cfg.model
    e = mc.moe.num_experts
    d, f = p_moe["w_gate"].shape[1:]
    xs_c = [xt.astype(jnp.dtype(mc.dtype)) for xt in xs]

    def bump(key: str, n: int = 1) -> None:
        if stats is not None and isinstance(stats.get(key), int):
            stats[key] += int(n)

    plans: Optional[List[moe_mod.RoutePlan]] = None
    if spec_routes is not None and len(spec_routes) == len(xs_c):
        heads = [moe_mod.route_head(mc, p_moe, xt) for xt in xs_c]
        flips = np.asarray(jnp.stack(
            [jnp.sum(h.experts != sp.experts)
             for h, sp in zip(heads, spec_routes)]))    # one host sync
        n_assign = sum(h.experts.size for h in heads)
        n_flips = int(flips.sum())
        bump("moe_spec_layers")
        bump("moe_flipped_assignments", n_flips)
        bump("moe_assignments", n_assign)
        if n_assign and n_flips / n_assign > qc.moe_flip_budget:
            # too much of the routing moved — the speculative plans buy
            # nothing; discard them wholesale and re-plan serially
            bump("fallback_flip_budget")
            bump("serial_fallbacks")
        else:
            plans = []
            for h, sp, nf in zip(heads, spec_routes, flips):
                if nf == 0:
                    plans.append(moe_mod.reuse_plan(sp, h))
                    bump("moe_plan_reuses")
                else:
                    plans.append(moe_mod.plan_from_head(mc, h))
                    bump("moe_flip_repairs")
    if plans is None:
        plans = [moe_mod.route(mc, p_moe, xt) for xt in xs_c]

    # stream dispatch over batches: stacked per-expert Hessians for gate/up
    # (input d) and for down (input f, needs the expert mid activations).
    from repro.models.layers import _act
    H_in = hess.init_hessian(d, batch=e)
    H_mid = hess.init_hessian(f, batch=e)
    x_last_in: Optional[jax.Array] = None
    x_last_mid: Optional[jax.Array] = None
    for plan, xt in zip(plans, xs_c):
        buf = moe_mod.apply_route(plan, xt)             # (E, C, d)
        g = jnp.einsum("ecd,edf->ecf", buf.astype(jnp.float32),
                       p_moe["w_gate"].astype(jnp.float32))
        u = jnp.einsum("ecd,edf->ecf", buf.astype(jnp.float32),
                       p_moe["w_up"].astype(jnp.float32))
        mid = _act(mc.act, g) * u                       # (E, C, f)
        H_in = hess.accumulate(H_in, buf)
        H_mid = hess.accumulate(H_mid, mid)
        x_last_in, x_last_mid = buf, mid
    last_counts = plans[-1].counts

    # routed-count + capacity-drop tallies in ONE host sync (the per-batch
    # np.asarray round-trip this loop used to make stalled the async
    # queue every batch — the stall the overlap schedule exists to avoid)
    count_stack = jnp.stack([p.counts for p in plans])  # (B, E)
    dropped = jnp.stack([jnp.sum(~p.keep) for p in plans])
    tallies = np.asarray(jnp.concatenate(
        [jnp.sum(count_stack, axis=0),
         jnp.sum(dropped)[None].astype(jnp.int32)]), np.int64)
    real_counts, n_dropped = tallies[:e], int(tallies[e])
    bump("moe_dropped_tokens", n_dropped)
    if report is not None:
        # capacity-dropped tokens vanish from the per-expert Hessians by
        # construction — record them so calibration coverage is honest
        report.moe_capacity_dropped[layer_name] = \
            report.moe_capacity_dropped.get(layer_name, 0) + n_dropped

    members: List[PlanMember] = []
    for wname, Hst, xl in (("w_gate", H_in, x_last_in),
                           ("w_up", H_in, x_last_in),
                           ("w_down", H_mid, x_last_mid)):
        # zero-padded capacity rows contribute nothing to XᵀX; real routed
        # token counts drive both the starvation check and the eq.-13
        # rescale. One stacked member per weight: the expert axis stays a
        # whole (E, ·, ·) slab from capture through scatter.
        members.append(PlanMember(
            f"{name}.{wname}",
            jnp.swapaxes(jnp.asarray(p_moe[wname], jnp.float32), -1, -2),
            hess.HessianState(Hst.H,
                              jnp.asarray(real_counts, jnp.int32)),
            xl, x_count=last_counts.astype(jnp.int32),
            starved=real_counts < qc.group_size,
            names=[f"{name}.{wname}[{ei}]" for ei in range(e)]))
    return members


def _scatter_moe(p_moe: Dict, results: Dict[str, MemberResult],
                 name: str) -> Dict:
    """Reassemble stacked expert weights (+grids) from member results."""
    new = dict(p_moe)
    for wname in _MOE_WNAMES:
        res = results[f"{name}.{wname}"]
        if res.w_q is None:                             # skipped (unaligned)
            continue
        new[wname] = jnp.swapaxes(res.w_q, -1, -2).astype(
            p_moe[wname].dtype)
        if res.grid is not None:
            new[f"{wname}_qscales"] = res.grid[0]
            new[f"{wname}_qzeros"] = res.grid[1]
    return new


# ---------------------------------------------------------------------------
# Per-step primitives (capture / plan / scatter / propagate)
#
# These are the stage bodies the stream scheduler composes — the serial
# schedule chains them per layer, the overlap schedule interleaves them
# across adjacent layers (core/stream.py).
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class CaptureResult:
    """One layer's tapped calibration state.

    ``h_out`` holds the capture forward's per-batch outputs — the layer's
    PRE-quantization residual stream, which the overlap scheduler feeds
    to the next step's speculative capture (it exists before the
    executor finishes). Collected only on request: the serial schedule —
    and the speculative pass itself — would otherwise pin n_batches
    activation arrays per step for nothing.

    ``spec_routes`` is set only by a *speculative* capture of a routed-MoE
    layer: the per-batch dispatch plans computed on the pre-quantization
    stream, which ``_moe_members`` verifies against recomputed routing on
    the true stream and reuses where no assignment flipped.
    """
    hessians: Dict[str, hess.HessianState]
    last_x: Dict[str, jax.Array]
    moe_xs: List[jax.Array]
    h_out: Optional[List[jax.Array]]
    is_moe: bool
    spec_routes: Optional[List] = None


def capture_layer(cfg: Config, step: LayerStep, hs: List[jax.Array],
                  fwd_cache: Optional[Dict] = None,
                  speculative: bool = False,
                  collect_h_out: bool = False) -> CaptureResult:
    """Stage (a): stream Hessians over all batches, keep last inputs.

    ``speculative`` marks a capture-ahead pass (overlap scheduler): same
    dispatches on a different stream, dense results discarded by the
    exact repair. For a routed-MoE layer the speculative pass
    additionally dispatches the per-batch routing plans on its stream
    (``CaptureResult.spec_routes``) — the structure the plan-level
    flip-repair reuses when the post-scatter routing agrees.
    ``collect_h_out`` retains the per-batch forward outputs (the
    pre-quantization stream the scheduler speculates on).
    """
    faults.fire("stream.capture_forward")
    qc = cfg.quant
    layer_params = step.resolve_params()
    use_jit = qc.jit_capture and fwd_cache is not None
    is_moe = _is_moe_layer(layer_params)
    hessians: Dict[str, hess.HessianState] = {}
    last_x: Dict[str, jax.Array] = {}
    moe_xs: List[jax.Array] = []     # per-batch MoE block inputs (router tap)

    targets = set()
    for sub in _QUANT_SUBTREES:
        if sub in layer_params:
            targets.update(f"{sub}.{n}" if n else sub
                           for n in _linear_names_in(layer_params[sub]))
    # the router stays full-precision (standard MoE-PTQ practice; its tap is
    # only used to collect the block inputs for the per-expert Hessians)
    targets.discard("mlp.router")

    def on_record(name: str, x: jax.Array):
        if name == "mlp.router":
            moe_xs.append(x.reshape(-1, x.shape[-1]))
            return
        if name not in targets:
            return
        x2 = x.reshape(-1, x.shape[-1])
        if name not in hessians:
            hessians[name] = hess.init_hessian(x2.shape[1])
        hessians[name] = hess.accumulate(hessians[name], x2)
        last_x[name] = x2        # overwritten per batch → last batch stays

    h_out: Optional[List[jax.Array]] = [] if collect_h_out else None
    for bi, h in enumerate(hs):
        if use_jit:
            out, recs = _layer_forward_jit(fwd_cache, step.fwd_key,
                                           step.apply_fn, layer_params, h,
                                           bi, step.batch_dependent)
            for name, xs in recs.items():
                for x in xs:
                    on_record(name, x)
        else:
            with Tap(on_record=on_record):
                out = step.apply_fn(layer_params, h, bi)
        if collect_h_out:
            h_out.append(out)
    spec_routes: Optional[List] = None
    if speculative and is_moe and moe_xs:
        # dispatch the routing plans on the speculative stream while the
        # previous step's executor is in flight — async device work; the
        # repair verifies them against the true stream at plan time
        dtype = jnp.dtype(cfg.model.dtype)
        spec_routes = [moe_mod.route(cfg.model, layer_params["mlp"],
                                     xt.astype(dtype)) for xt in moe_xs]
    return CaptureResult(hessians, last_x, moe_xs, h_out, is_moe,
                         spec_routes)


def plan_layer(cfg: Config, step: LayerStep, cap: CaptureResult,
               hs: List[jax.Array], report: Optional[QuantReport] = None,
               stats: Optional[Dict] = None,
               spec_routes: Optional[List] = None
               ) -> Tuple[Dict, List[str], "qplan.QuantPlan"]:
    """Stage (b): dense taps + stacked MoE expert slices → QuantPlan.

    ``spec_routes`` threads the speculative dispatch plans from the
    overlap scheduler's capture-ahead to the MoE flip-repair; ``report``/
    ``stats`` receive capacity-drop and repair counters when given.
    Returns (fresh param-subtree copy, sorted dense names, plan).
    """
    qc = cfg.quant
    new_params = jax.tree_util.tree_map(lambda x: x, step.resolve_params())
    members: List[PlanMember] = []
    dense_names = sorted(cap.hessians.keys())
    for name in dense_names:
        node = _resolve(new_params, name)
        members.append(PlanMember(
            name, jnp.asarray(node["w"], jnp.float32).T, cap.hessians[name],
            cap.last_x[name], x_count=None))
    if cap.is_moe:
        assert len(cap.moe_xs) == len(hs), "router tap missed batches"
        members.extend(_moe_members(cfg, new_params["mlp"], cap.moe_xs,
                                    "mlp", report=report, stats=stats,
                                    spec_routes=spec_routes,
                                    layer_name=step.name))
    return new_params, dense_names, qplan.build_plan(qc, members)


def scatter_layer(new_params: Dict, dense_names: List[str],
                  cap: CaptureResult,
                  results: Dict[str, MemberResult]) -> Dict:
    """Stage (d, first half): write on-grid results back into the subtree."""
    for name in dense_names:
        res = results[name]
        if res.w_q is None:
            continue                                    # skipped: keep fp
        node = _resolve(new_params, name)
        node["w"] = res.w_q.T.astype(node["w"].dtype)
        if res.grid is not None:
            # stage-1 grid travels with the weight → exact int4 packing
            node["qscales"], node["qzeros"] = res.grid
    if cap.is_moe:
        new_params["mlp"] = _scatter_moe(new_params["mlp"], results, "mlp")
    return new_params


def propagate_layer(cfg: Config, step: LayerStep, new_params: Dict,
                    hs: List[jax.Array],
                    fwd_cache: Optional[Dict] = None) -> List[jax.Array]:
    """Stage (d, second half): re-run the layer with quantized params so
    the next layer's Hessians see the quantized network (same compiled
    forward family; the quantized params carry extra grid leaves, so they
    key their own cross-layer cache entry)."""
    use_jit = cfg.quant.jit_capture and fwd_cache is not None
    if use_jit:
        return [_layer_forward_jit(fwd_cache, step.fwd_key, step.apply_fn,
                                   new_params, h, bi, step.batch_dependent,
                                   collect=False)[0]
                for bi, h in enumerate(hs)]
    return [step.apply_fn(new_params, h, bi) for bi, h in enumerate(hs)]


def quantize_layer(cfg: Config, layer_params: Dict, hs: List[jax.Array],
                   apply_fn, report: QuantReport,
                   fwd_cache: Optional[Dict] = None,
                   fwd_key: Tuple = ("layer",),
                   batch_dependent: bool = False,
                   mesh=None) -> Tuple[Dict, List]:
    """Quantize one layer's linears via the plan, then propagate (serial).

    The single-layer convenience wrapper over the per-step primitives
    above — what the serial schedule does per step. ``apply_fn(params, h,
    batch_index) -> h_out`` runs the layer; ``mesh`` forwards to
    :func:`repro.core.plan.execute_plan` for sharded group execution
    (capture itself stays single-device — only executor work scales with
    the mesh). Returns (new_layer_params, new_hs).
    """
    step = LayerStep(name="layer", params=layer_params, apply_fn=apply_fn,
                     hs_slot="h", fwd_key=fwd_key, store=lambda p: None,
                     batch_dependent=batch_dependent)
    cap = capture_layer(cfg, step, hs, fwd_cache)
    new_params, dense_names, plan = plan_layer(cfg, step, cap, hs,
                                               report=report)
    results = qplan.execute_plan(cfg.quant, plan, report, mesh=mesh)
    scatter_layer(new_params, dense_names, cap, results)
    return new_params, propagate_layer(cfg, step, new_params, hs, fwd_cache)


# ---------------------------------------------------------------------------
# LayerWalkers: each architecture described once, as data
#
# A walker builder turns (cfg, params, calib) into streams + a flat list
# of LayerSteps (+ StreamSwitch fences) + a finalizer. Builders must not
# read stream VALUES while building (closures only bake static context:
# specs, positions, the params they quantize) — stream-dependent work
# (e.g. the encoder final norm feeding cross-attention) happens inside a
# StreamSwitch at its place in the walk, which is what lets the overlap
# scheduler look one step ahead safely.
# ---------------------------------------------------------------------------

def _walker_decoder_only(cfg: Config, params: Dict, calib) -> LayerWalker:
    mc = cfg.model
    dtype = jnp.dtype(mc.dtype)
    hs = []
    for b in calib:
        h = embed(params["embed"], b["tokens"], dtype)
        if b.get("embeds") is not None:
            h = jnp.concatenate([b["embeds"].astype(dtype), h], axis=1)
        hs.append(h)
    seqs = [h.shape[1] for h in hs]
    assert len(set(seqs)) == 1, "calibration batches must share seq_len"
    b0, s0, _ = hs[0].shape
    positions = jnp.arange(s0, dtype=jnp.int32)[None, :].repeat(b0, 0)

    items: List[qstream.WalkItem] = []
    collected: List[List[Dict]] = []    # per segment: per-element subtrees
    li = 0
    for seg, seg_params in zip(T.segments(mc), params["blocks"]):
        elems: List[Dict] = [dict() for _ in range(seg.count)]
        collected.append(elems)
        for c in range(seg.count):
            for s_i, spec in enumerate(seg.specs):

                def apply_fn(p, h, bi, _spec=spec):
                    out, _ = T.layer_forward(mc, _spec, p, h, positions)
                    return out

                li += 1
                items.append(LayerStep(
                    name=f"layer {li}",
                    # lazy slice: materialized at the step's turn, released
                    # after it — the walk never pins all pre-quant slices
                    params=(lambda _sp=seg_params, _c=c, _k=f"sub{s_i}":
                            T._seg_take(_sp, _c)[_k]),
                    apply_fn=apply_fn,
                    hs_slot="h", fwd_key=("dec", str(spec)),
                    store=(lambda p, _e=elems[c], _k=f"sub{s_i}":
                           _e.__setitem__(_k, p))))

    def finalize() -> Dict:
        out = dict(params)
        out["blocks"] = [T._stack_trees(elems) for elems in collected]
        return out

    return LayerWalker(streams={"h": hs}, items=items, finalize=finalize)


def _walker_encdec(cfg: Config, params: Dict, calib) -> LayerWalker:
    mc = cfg.model
    dtype = jnp.dtype(mc.dtype)
    # ----- encoder stream -----
    hs = []
    for b in calib:
        fr = b["frames"].astype(dtype)
        hs.append(fr + sinusoidal_positions(fr.shape[1], mc.d_model
                                            )[None].astype(dtype))
    se = hs[0].shape[1]
    b0 = hs[0].shape[0]
    enc_pos = jnp.arange(se, dtype=jnp.int32)[None, :].repeat(b0, 0)

    items: List[qstream.WalkItem] = []
    n_enc = jax.tree_util.tree_leaves(
        params["encoder"]["layers"])[0].shape[0]
    enc_elems: List[Optional[Dict]] = [None] * n_enc
    for i in range(n_enc):

        def enc_apply(p, h, bi):
            hn = norm(mc, p["norm1"], h)
            from repro.models import attention as attn
            y = attn.attention_forward(mc, p["mixer"], hn, enc_pos,
                                       causal=False, use_rope=False,
                                       name="mixer")
            h = h + y
            hn = norm(mc, p["norm2"], h)
            from repro.models.layers import mlp as mlp_fn
            return h + mlp_fn(mc, p["mlp"], hn, name="mlp")

        items.append(LayerStep(
            name=f"enc {i + 1}",
            params=(lambda _i=i: T._seg_take(params["encoder"]["layers"],
                                             _i)),
            apply_fn=enc_apply, hs_slot="enc", fwd_key=("enc",),
            store=(lambda p, _i=i: enc_elems.__setitem__(_i, p))))

    # ----- enc → dec fence: finalize the (quantized) encoder stream into
    # the cross-attention memory, open the decoder stream -----
    dhs = []
    for b in calib:
        tk = b["tokens"]
        h = embed(params["embed"], tk, dtype)
        dhs.append(h + sinusoidal_positions(tk.shape[1], mc.d_model
                                            )[None].astype(dtype))
    sd = dhs[0].shape[1]
    dec_pos = jnp.arange(sd, dtype=jnp.int32)[None, :].repeat(b0, 0)
    ctx: Dict[str, List[jax.Array]] = {}

    def switch(streams: Dict[str, List[jax.Array]]) -> None:
        ctx["enc_out"] = [norm(mc, params["encoder"]["final_norm"], h)
                          for h in streams["enc"]]
        streams["dec"] = dhs

    items.append(StreamSwitch(name="enc→dec", run=switch))

    n_dec = jax.tree_util.tree_leaves(
        params["decoder"]["layers"])[0].shape[0]
    dec_elems: List[Optional[Dict]] = [None] * n_dec
    for i in range(n_dec):

        def dec_apply(p, h, bi):
            from repro.models import attention as attn
            from repro.models.layers import mlp as mlp_fn
            llp = p["layer"]
            hn = norm(mc, llp["norm1"], h)
            y = attn.attention_forward(mc, llp["mixer"], hn, dec_pos,
                                       causal=True, use_rope=False,
                                       name="layer.mixer")
            h = h + y
            hn = norm(mc, p["xnorm"], h)
            kv = attn.cross_attention_kv(mc, p["xattn"], ctx["enc_out"][bi],
                                         "xattn")
            h = h + attn.cross_attention(mc, p["xattn"], hn, kv, "xattn")
            hn = norm(mc, llp["norm2"], h)
            return h + mlp_fn(mc, llp["mlp"], hn, name="layer.mlp")

        # enc_out[bi] is baked into the trace → key per batch index
        items.append(LayerStep(
            name=f"dec {i + 1}",
            params=(lambda _i=i: T._seg_take(params["decoder"]["layers"],
                                             _i)),
            apply_fn=dec_apply, hs_slot="dec", fwd_key=("xdec",),
            batch_dependent=True,
            store=(lambda p, _i=i: dec_elems.__setitem__(_i, p))))

    def finalize() -> Dict:
        out = dict(params)
        out["encoder"] = {"layers": T._stack_trees(enc_elems),
                          "final_norm": params["encoder"]["final_norm"]}
        out["decoder"] = {"layers": T._stack_trees(dec_elems),
                          "final_norm": params["decoder"]["final_norm"]}
        return out

    return LayerWalker(streams={"enc": hs}, items=items, finalize=finalize)


_MESH_FROM_CONFIG = object()     # sentinel: resolve the quant.mesh knob


def quantize_model(cfg: Config, params: Dict,
                   calib: List[Dict[str, jax.Array]],
                   verbose: bool = False,
                   mesh=_MESH_FROM_CONFIG) -> Tuple[Dict, QuantReport]:
    """Quantize every transformer layer of a decoder-only or enc-dec model.

    ``calib``: list of batch dicts ({tokens, embeds?/frames?}); the last one
    is the single instance for stage 2.

    ``mesh``: a ``(data, model)`` Mesh for sharded group execution
    (DESIGN.md §2.6), or None to force single-device execution; left
    unset, the ``quant.mesh`` knob is resolved through
    :func:`repro.launch.mesh.make_quant_mesh` (default "off" = single
    device).

    The walk runs under ``quant.pipeline`` (serial | overlap — see
    :mod:`repro.core.stream`); artifacts are schedule-independent.
    """
    global _LAST_FWD_STATS
    t_start = time.perf_counter()
    report = QuantReport()
    if mesh is _MESH_FROM_CONFIG:
        from repro.launch.mesh import make_quant_mesh
        mesh = make_quant_mesh(cfg.quant.mesh)

    fwd_cache = ForwardCache()   # per-run compiled-forward cache (jit_capture)
    build = (_walker_encdec if cfg.model.is_encoder_decoder
             else _walker_decoder_only)
    walker = build(cfg, params, calib)
    fb0 = kops.fallback_stats()
    try:
        out = qstream.run_walker(cfg, walker, report, fwd_cache=fwd_cache,
                                 mesh=mesh, verbose=verbose)
    finally:
        # only the counters outlive the run — keeping the cache itself
        # alive would pin every compiled forward and its baked closure
        # constants (positions, enc_out) past the model they belong to
        _LAST_FWD_STATS = fwd_cache.stats()
    # auto→xla kernel downgrades observed during THIS run (delta against
    # the process-wide counters): surfaced so a budget-driven fallback is
    # visible in the report instead of silently changing the backend
    report.kernel_fallbacks = {
        k: v - fb0.get(k, 0) for k, v in kops.fallback_stats().items()
        if v - fb0.get(k, 0)}
    report.seconds_total = time.perf_counter() - t_start
    return out, report


# ---------------------------------------------------------------------------
# Packing to serving artifacts
# ---------------------------------------------------------------------------

def pack_for_serving(cfg: Config, params_q: Dict) -> Dict:
    """Replace quantized-linear float weights with int4 QuantizedTensor.

    Weights are re-gridded with fresh (scale, zero) per group — the values
    are already on a 4-bit grid from the pipeline, so this round-trips
    exactly (asserted in tests). Norms/embeddings stay fp.
    """
    qc = cfg.quant

    from repro.core.quant import QuantParams, compute_qparams, quantize_codes

    def pack_generic(w: jax.Array, scales=None,
                     zeros=None) -> QuantizedTensor:
        """(..., in, out) float → (..., out, in//2)-packed QuantizedTensor.

        Leading dims cover scan-stacked layers and/or the expert axis; the
        math is fully vectorized (no per-expert Python loops — deepseek has
        58×256 expert matrices). When the pipeline carried the stage-1 grid
        (qscales/qzeros), packing on it round-trips the refined weights
        EXACTLY; otherwise the grid is recomputed (lossy only for weights
        not already on a grid, e.g. fp checkpoints packed directly).
        """
        w_oi = jnp.swapaxes(jnp.asarray(w, jnp.float32), -1, -2)
        lead = w_oi.shape[:-2]
        o, i = w_oi.shape[-2:]
        g = i // qc.group_size
        w2 = w_oi.reshape(-1, i)
        if scales is not None:
            qp = QuantParams(jnp.asarray(scales, jnp.float32)
                             .reshape(-1, g),
                             jnp.asarray(zeros, jnp.float32).reshape(-1, g))
        else:
            qp = compute_qparams(w2, qc.bits, qc.group_size)
        codes = quantize_codes(w2, qp, qc.bits, qc.group_size)
        packed = pack_int4(codes).reshape(*lead, o, i // 2)
        return QuantizedTensor(packed,
                               qp.scales.reshape(*lead, o, g),
                               qp.zeros.reshape(*lead, o, g),
                               (*lead, o, i), qc.bits, qc.group_size)

    def walk(tree, path=""):
        if isinstance(tree, dict):
            out = {}
            for k, v in tree.items():
                sub = f"{path}.{k}"
                if k in ("qscales", "qzeros") or k.endswith("_qscales") \
                        or k.endswith("_qzeros"):
                    continue                      # consumed by the packer
                if (k == "w" and getattr(v, "ndim", 0) >= 2
                        and any(s in path for s in _QUANT_SUBTREES)
                        and v.shape[-2] % qc.group_size == 0
                        and "router" not in path):
                    out[k] = pack_generic(v, tree.get("qscales"),
                                          tree.get("qzeros"))
                elif (k in ("w_gate", "w_up", "w_down")
                      and getattr(v, "ndim", 0) >= 3
                      and v.shape[-2] % qc.group_size == 0):
                    out[k] = pack_generic(v, tree.get(f"{k}_qscales"),
                                          tree.get(f"{k}_qzeros"))
                else:
                    out[k] = walk(v, sub)
            return out
        if isinstance(tree, list):
            return [walk(v, path) for v in tree]
        return tree

    return walk(params_q)
