"""Streaming layer-walk scheduler: one walker, two pipelines.

The quantization pipeline used to hold two near-duplicate serial walkers
(decoder-only and encoder-decoder) that each hand-rolled the same loop:
capture a layer's Hessians, execute its quant plan, scatter, propagate,
next layer. This module inverts that control flow. An architecture is
described once as a :class:`LayerWalker` — a flat list of
:class:`LayerStep` items (plus :class:`StreamSwitch` fences where the
residual stream changes, e.g. encoder → decoder) — and
:func:`run_walker` drains it under one of two schedules
(``quant.pipeline``):

``serial``
    The classic alternation, bit-for-bit the pre-walker behaviour:
    each step captures, executes (per-stage ``block_until_ready`` so the
    report's stage seconds measure compute), scatters, propagates.

``overlap``
    A two-deep stage queue built on JAX async dispatch. For step *i*:

    1. capture runs on the post-scatter stream of *i−1* (under overlap
       this is the **exact Hessian repair** of the speculative pass
       below — same compiled entries, same accumulation order, so the
       Hessian state is bitwise the serial one);
    2. the plan executes with **no per-stage sync** — stage dispatches
       are enqueued and timing lands at the step's report boundary;
    3. while the executor is in flight, step *i+1*'s jitted capture
       forward is dispatched **speculatively on the pre-quantization
       stream** (the capture-forward outputs of step *i*, which exist
       before the executor finishes). The speculative pass warms the
       capture jit entry and keeps the device queue full; its numeric
       results are discarded by the repair in (1), which is what keeps
       ``overlap`` bitwise-equal to ``serial``;
    4. scatter + propagate are enqueued, then the step's deferred
       executor records materialize and the per-step wall clock is
       taken (the only synchronization point in overlap mode).

    Speculation is skipped — the scheduler degrades to serial re-capture
    for that step — when the next step's signature marks the repair
    unsound (``LayerStep.repair_sound=False``: routed MoE, whose token
    routing can shift after the scatter and whose per-expert capture
    does host-side dispatch bookkeeping), when the next item is a
    :class:`StreamSwitch` fence, when the steps read different stream
    slots, or when capture runs eagerly (``quant.jit_capture=false``).

Per-run counters land in ``report.pipeline_stats`` and the per-step wall
clocks in ``report.layer_step_seconds``; parity between the two
schedules is pinned in ``tests/test_pipeline_stream.py``.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple, Union  # noqa: F401

import jax

from repro.config import Config
from repro.core import plan as qplan
from repro.core.plan import QuantReport

PIPELINE_MODES = ("serial", "overlap")


@dataclasses.dataclass
class LayerStep:
    """One quantizable layer of the walk.

    ``apply_fn(params, h, batch_index) -> h_out`` runs the layer;
    ``params`` is the layer's param subtree (pre-quantization) — either
    the dict itself or a zero-arg thunk producing it, so walkers over
    scan-stacked param trees slice each layer **lazily** at its turn
    instead of pinning every pre-quant slice for the whole walk (the
    scheduler also releases it once the step is stored). ``store`` puts
    the quantized subtree back into the caller's assembly. ``hs_slot``
    names the residual stream the step consumes and produces;
    ``fwd_key``/``batch_dependent`` key the jitted capture forward
    exactly as :func:`repro.core.pipeline._layer_forward_jit` expects.
    ``repair_sound=False`` marks the capture-ahead Hessian repair
    unsound for this step (routed MoE) — the overlap scheduler then
    degrades to serial re-capture for it; ``None`` (default) resolves
    lazily through ``pipeline._layer_repair_sound`` on the materialized
    params.
    """
    name: str
    params: Union[Dict, Callable[[], Dict]]
    apply_fn: Callable
    hs_slot: str
    fwd_key: Tuple
    store: Callable[[Dict], None]
    batch_dependent: bool = False
    repair_sound: Optional[bool] = None

    def resolve_params(self) -> Dict:
        if callable(self.params):
            self.params = self.params()
        return self.params

    def release_params(self) -> None:
        self.params = None


@dataclasses.dataclass
class StreamSwitch:
    """A fence between stream slots (e.g. encoder → decoder).

    ``run(streams)`` mutates the walker's stream dict — typically
    finalizing one slot (encoder final norm → cross-attention memory)
    and initializing the next. Speculation never crosses a switch, so
    the downstream slot always initializes from fully-propagated
    (post-quantization) upstream state, exactly as the serial walk does.
    """
    name: str
    run: Callable[[Dict[str, List[jax.Array]]], None]


WalkItem = Union[LayerStep, StreamSwitch]


def _repair_sound(qpipe, step: LayerStep) -> bool:
    """Resolve (and cache) a step's repair soundness — looked up through
    the pipeline module so tests can monkeypatch the predicate."""
    if step.repair_sound is None:
        step.repair_sound = qpipe._layer_repair_sound(step.resolve_params())
    return step.repair_sound


@dataclasses.dataclass
class LayerWalker:
    """An architecture's layer walk: streams + steps + reassembly.

    ``streams`` maps slot name → per-calibration-batch residual arrays
    (only the slots live at walk start; switches may add more).
    ``items`` must be constructible up front (builders bake closures,
    they do not read stream values — stream-dependent work belongs in a
    :class:`StreamSwitch`), which is what lets the scheduler look one
    step ahead. ``finalize()`` reassembles the quantized param tree from
    what the steps ``store``d.
    """
    streams: Dict[str, List[jax.Array]]
    items: Sequence[WalkItem]
    finalize: Callable[[], Dict]


def run_walker(cfg: Config, walker: LayerWalker, report: QuantReport,
               fwd_cache: Optional[Dict] = None, mesh=None,
               verbose: bool = False) -> Dict:
    """Drain the walker under ``cfg.quant.pipeline``; returns the
    finalized (quantized) param tree.

    Both schedules dispatch the same computations in the same order on
    the same inputs — ``overlap`` only moves synchronization points and
    adds discarded speculative work — so their artifacts (on-grid
    params, Γ histories, packed tensors) are bitwise-identical.
    """
    from repro.core import pipeline as qpipe   # circular-at-import only

    qc = cfg.quant
    mode = qc.pipeline
    if mode not in PIPELINE_MODES:
        raise ValueError(
            f"quant.pipeline must be one of {PIPELINE_MODES}, got {mode!r}")
    overlap = mode == "overlap"
    use_spec = overlap and qc.jit_capture and fwd_cache is not None
    stats = {"mode": mode, "steps": 0, "spec_captures": 0, "repairs": 0,
             "serial_fallbacks": 0}
    items: List[WalkItem] = list(walker.items)
    spec_for: Optional[LayerStep] = None   # step the in-flight speculative
    #                                        capture targeted
    for idx, item in enumerate(items):
        if isinstance(item, StreamSwitch):
            item.run(walker.streams)
            spec_for = None
            continue
        t_step = time.perf_counter()
        hs = walker.streams[item.hs_slot]
        # speculation eligibility is knowable up front (it only depends on
        # the NEXT item's signature/slot), so the pre-quant outputs are
        # retained exactly when the capture-ahead below will consume them.
        # The repair-soundness predicate resolves lazily and only under
        # overlap (short-circuit), materializing nxt's params at most one
        # step early — they are about to be needed anyway.
        nxt = items[idx + 1] if idx + 1 < len(items) else None
        can_spec = (use_spec and isinstance(nxt, LayerStep)
                    and nxt.hs_slot == item.hs_slot
                    and _repair_sound(qpipe, nxt))
        # 1. capture — under overlap this re-propagates the taps on the
        # repaired (post-scatter) stream: the exact Hessian repair of the
        # speculative pass, riding its compiled entries.
        cap = qpipe.capture_layer(cfg, item, hs, fwd_cache,
                                  collect_h_out=can_spec)
        if spec_for is item:
            stats["repairs"] += 1
        spec_for = None
        # 2. plan
        new_params, dense_names, plan = qpipe.plan_layer(cfg, item, cap, hs)
        # 3. execute — async under overlap: per-stage sync and record
        # materialization defer to this step's report boundary below.
        deferred: Optional[List[Callable[[], None]]] = \
            [] if overlap else None
        results = qplan.execute_plan(qc, plan, report, mesh=mesh,
                                     sync=not overlap, deferred=deferred)
        # 4. scatter on-grid weights (+ grids) back into the subtree
        qpipe.scatter_layer(new_params, dense_names, cap, results)
        # 5. capture-ahead: dispatch the NEXT step's capture forward on
        # THIS step's pre-quantization outputs while the executor is in
        # flight. Discarded at the repair in (1) — overlap stays exact.
        if use_spec and isinstance(nxt, LayerStep):
            if can_spec:
                qpipe.capture_layer(cfg, nxt, cap.h_out, fwd_cache,
                                    speculative=True)
                spec_for = nxt
                stats["spec_captures"] += 1
            else:
                stats["serial_fallbacks"] += 1
        # 6. propagate quantized activations
        walker.streams[item.hs_slot] = qpipe.propagate_layer(
            cfg, item, new_params, hs, fwd_cache)
        item.store(new_params)
        # 7. report boundary: materialize the deferred executor records
        # and take the per-layer-step wall clock — the only sync in
        # overlap mode (speculative work stays in flight across it).
        item.release_params()    # drop the pre-quant slice progressively
        if deferred:
            for fin in deferred:
                fin()
        if overlap:
            jax.block_until_ready(walker.streams[item.hs_slot][-1])
        report.layer_step_seconds.append(time.perf_counter() - t_step)
        stats["steps"] += 1
        if verbose:
            print(f"  {item.name}: {report.summary()}")
    report.pipeline_stats = dict(stats)
    return walker.finalize()
