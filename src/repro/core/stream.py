"""Streaming layer-walk scheduler: one walker, two pipelines.

The quantization pipeline used to hold two near-duplicate serial walkers
(decoder-only and encoder-decoder) that each hand-rolled the same loop:
capture a layer's Hessians, execute its quant plan, scatter, propagate,
next layer. This module inverts that control flow. An architecture is
described once as a :class:`LayerWalker` — a flat list of
:class:`LayerStep` items (plus :class:`StreamSwitch` fences where the
residual stream changes, e.g. encoder → decoder) — and
:func:`run_walker` drains it under one of two schedules
(``quant.pipeline``):

``serial``
    The classic alternation, bit-for-bit the pre-walker behaviour:
    each step captures, executes (per-stage ``block_until_ready`` so the
    report's stage seconds measure compute), scatters, propagates.

``overlap``
    A two-deep stage queue built on JAX async dispatch. For step *i*:

    1. capture runs on the post-scatter stream of *i−1* (under overlap
       this is the **exact Hessian repair** of the speculative pass
       below — same compiled entries, same accumulation order, so the
       Hessian state is bitwise the serial one);
    2. the plan executes with **no per-stage sync** — stage dispatches
       are enqueued and timing lands at the step's report boundary;
    3. while the executor is in flight, step *i+1*'s jitted capture
       forward is dispatched **speculatively on the pre-quantization
       stream** (the capture-forward outputs of step *i*, which exist
       before the executor finishes). The speculative pass warms the
       capture jit entry and keeps the device queue full; its numeric
       results are discarded by the repair in (1), which is what keeps
       ``overlap`` bitwise-equal to ``serial``;
    4. scatter + propagate are enqueued, then the step's deferred
       executor records materialize and the per-step wall clock is
       taken (the only synchronization point in overlap mode).

    For a routed-MoE next step the speculative pass additionally
    dispatches the per-batch routing plans on its stream; at the MoE
    step's own turn ``pipeline._moe_members`` recomputes only the
    routing *head* on the true stream, reuses the sort/capacity
    structure bitwise for batches whose expert assignments did not flip,
    re-sorts flipped batches (the plan-level **flip repair**), and
    discards the speculative plans wholesale when the flip fraction
    exceeds ``quant.moe_flip_budget``. Per-expert Hessians always
    accumulate true-stream values, so MoE overlap stays bitwise serial.

    Speculation is skipped — the scheduler degrades to serial re-capture
    for that step — when the next step's signature marks the repair
    unsound (``LayerStep.repair_sound=False``; a test seam now that MoE
    repairs at the plan level), when the next item is a
    :class:`StreamSwitch` fence, when the steps read different stream
    slots, or when capture runs eagerly (``quant.jit_capture=false``).

Per-run counters land in ``report.pipeline_stats`` — the
``serial_fallbacks`` total is split into per-reason counters
(``fallback_fence`` / ``fallback_cross_slot`` / ``fallback_eager_capture``
/ ``fallback_repair_unsound`` / ``fallback_flip_budget``) and the MoE
flip-repair keeps its own ledger (``moe_spec_layers``,
``moe_plan_reuses``, ``moe_flip_repairs``, ``moe_flipped_assignments`` /
``moe_assignments``, ``moe_dropped_tokens``) — and the per-step wall
clocks in ``report.layer_step_seconds``; parity between the two
schedules is pinned in ``tests/test_pipeline_stream.py`` and
``tests/test_moe_flip.py``.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import time
import warnings
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple, Union  # noqa: F401

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import Config, to_dict
from repro.core import plan as qplan
from repro.core.plan import LinearRecord, QuantReport

PIPELINE_MODES = ("serial", "overlap")


@dataclasses.dataclass
class LayerStep:
    """One quantizable layer of the walk.

    ``apply_fn(params, h, batch_index) -> h_out`` runs the layer;
    ``params`` is the layer's param subtree (pre-quantization) — either
    the dict itself or a zero-arg thunk producing it, so walkers over
    scan-stacked param trees slice each layer **lazily** at its turn
    instead of pinning every pre-quant slice for the whole walk (the
    scheduler also releases it once the step is stored). ``store`` puts
    the quantized subtree back into the caller's assembly. ``hs_slot``
    names the residual stream the step consumes and produces;
    ``fwd_key``/``batch_dependent`` key the jitted capture forward
    exactly as :func:`repro.core.pipeline._layer_forward_jit` expects.
    ``repair_sound=False`` marks the capture-ahead Hessian repair
    unsound for this step (routed MoE) — the overlap scheduler then
    degrades to serial re-capture for it; ``None`` (default) resolves
    lazily through ``pipeline._layer_repair_sound`` on the materialized
    params.
    """
    name: str
    params: Union[Dict, Callable[[], Dict]]
    apply_fn: Callable
    hs_slot: str
    fwd_key: Tuple
    store: Callable[[Dict], None]
    batch_dependent: bool = False
    repair_sound: Optional[bool] = None

    def resolve_params(self) -> Dict:
        if callable(self.params):
            self.params = self.params()
        return self.params

    def release_params(self) -> None:
        self.params = None


@dataclasses.dataclass
class StreamSwitch:
    """A fence between stream slots (e.g. encoder → decoder).

    ``run(streams)`` mutates the walker's stream dict — typically
    finalizing one slot (encoder final norm → cross-attention memory)
    and initializing the next. Speculation never crosses a switch, so
    the downstream slot always initializes from fully-propagated
    (post-quantization) upstream state, exactly as the serial walk does.
    """
    name: str
    run: Callable[[Dict[str, List[jax.Array]]], None]


WalkItem = Union[LayerStep, StreamSwitch]


def _repair_sound(qpipe, step: LayerStep) -> bool:
    """Resolve (and cache) a step's repair soundness — looked up through
    the pipeline module so tests can monkeypatch the predicate."""
    if step.repair_sound is None:
        step.repair_sound = qpipe._layer_repair_sound(step.resolve_params())
    return step.repair_sound


@dataclasses.dataclass
class LayerWalker:
    """An architecture's layer walk: streams + steps + reassembly.

    ``streams`` maps slot name → per-calibration-batch residual arrays
    (only the slots live at walk start; switches may add more).
    ``items`` must be constructible up front (builders bake closures,
    they do not read stream values — stream-dependent work belongs in a
    :class:`StreamSwitch`), which is what lets the scheduler look one
    step ahead. ``finalize()`` reassembles the quantized param tree from
    what the steps ``store``d.
    """
    streams: Dict[str, List[jax.Array]]
    items: Sequence[WalkItem]
    finalize: Callable[[], Dict]


# ---------------------------------------------------------------------------
# Layer-checkpointed resume (quant.ckpt_dir / quant.resume)
#
# At every step boundary the walker persists (a) the residual streams —
# the Hessian "slot state" every later capture derives from — and (b) the
# stored quantized subtrees of all completed steps, through
# distributed/checkpoint.py (atomic tmp+rename, async writer; fences
# flush synchronously). A killed run restarted with ``quant.resume=auto``
# replays only the StreamSwitch closures (host-side bookkeeping like the
# enc→dec memory publication), re-stores the checkpointed subtrees, and
# continues the walk from the first incomplete step. Because every step's
# inputs are exactly the checkpointed stream state the original run
# produced, the resumed walk's artifacts are bitwise-identical to an
# uninterrupted run (pinned in tests/test_faults.py, serial AND overlap).
#
# Cost note: each save snapshots the full stored-subtree dict to host, so
# checkpoint bandwidth grows with completed-walk size. That is the price
# of a self-contained latest-step checkpoint (retention gc keeps only
# ``quant.ckpt_keep``); smoke/tier-1 fixtures are tiny, and real runs
# amortize it against layer-quantization time.
# ---------------------------------------------------------------------------

def _resume_fingerprint(cfg: Config) -> str:
    """Config identity a checkpoint must match to be resumable: everything
    that shapes the walk EXCEPT the fault plane and the resume/ckpt knobs
    themselves (a resume run disarms faults and may relocate the dir)."""
    d = to_dict(cfg)
    d.pop("faults", None)
    for k in ("resume", "ckpt_dir", "ckpt_keep"):
        d.get("quant", {}).pop(k, None)
    return hashlib.sha256(json.dumps(d, sort_keys=True,
                                     default=str).encode()).hexdigest()[:16]


def _walk_ckpt_tree(streams: Dict[str, List[jax.Array]],
                    stored: Dict[str, Dict]) -> Dict:
    """Checkpoint payload: streams keyed slot/index + stored subtrees
    keyed by step name (both reconstructible blind via load_arrays)."""
    return {"streams": {slot: {f"{i:03d}": h for i, h in enumerate(hs)}
                        for slot, hs in streams.items()},
            "stored": stored}


def _restore_from_arrays(arrays: Dict[str, np.ndarray]
                         ) -> Tuple[Dict[str, List[jax.Array]],
                                    Dict[str, Dict]]:
    streams_ix: Dict[str, Dict[int, np.ndarray]] = {}
    stored: Dict[str, Any] = {}
    for path, arr in arrays.items():
        parts = path.split("/")
        if parts[0] == "streams":
            streams_ix.setdefault(parts[1], {})[int(parts[2])] = arr
        elif parts[0] == "stored":
            node = stored.setdefault(parts[1], {})
            for p in parts[2:-1]:
                node = node.setdefault(p, {})
            node[parts[-1]] = arr
    streams = {slot: [jnp.asarray(ix[i]) for i in range(len(ix))]
               for slot, ix in streams_ix.items()}
    stored = jax.tree_util.tree_map(jnp.asarray, stored)
    return streams, stored


def _report_state(report: QuantReport, stats: Dict[str, Any]) -> Dict:
    return {"linears": [dataclasses.asdict(l) for l in report.linears],
            "seconds_stage1": report.seconds_stage1,
            "seconds_stage2": report.seconds_stage2,
            "layer_step_seconds": list(report.layer_step_seconds),
            "guardrail_stats": dict(report.guardrail_stats),
            "moe_capacity_dropped": dict(report.moe_capacity_dropped),
            "pipeline_counters": {k: v for k, v in stats.items()
                                  if isinstance(v, int)}}


def _restore_report(report: QuantReport, state: Dict,
                    stats: Dict[str, Any]) -> None:
    report.linears[:] = [LinearRecord(**{**d, "shape": tuple(d["shape"])})
                         for d in state.get("linears", [])]
    report.seconds_stage1 = float(state.get("seconds_stage1", 0.0))
    report.seconds_stage2 = float(state.get("seconds_stage2", 0.0))
    report.layer_step_seconds[:] = state.get("layer_step_seconds", [])
    report.guardrail_stats.update(state.get("guardrail_stats", {}))
    for layer, n in state.get("moe_capacity_dropped", {}).items():
        report.moe_capacity_dropped[layer] = \
            report.moe_capacity_dropped.get(layer, 0) + int(n)
    for k, v in state.get("pipeline_counters", {}).items():
        if isinstance(stats.get(k), int):
            stats[k] += v


def run_walker(cfg: Config, walker: LayerWalker, report: QuantReport,
               fwd_cache: Optional[Dict] = None, mesh=None,
               verbose: bool = False) -> Dict:
    """Drain the walker under ``cfg.quant.pipeline``; returns the
    finalized (quantized) param tree.

    Both schedules dispatch the same computations in the same order on
    the same inputs — ``overlap`` only moves synchronization points and
    adds discarded speculative work — so their artifacts (on-grid
    params, Γ histories, packed tensors) are bitwise-identical.
    """
    qc = cfg.quant
    mode = qc.pipeline
    if mode not in PIPELINE_MODES:
        raise ValueError(
            f"quant.pipeline must be one of {PIPELINE_MODES}, got {mode!r}")
    overlap = mode == "overlap"
    use_spec = overlap and qc.jit_capture and fwd_cache is not None
    stats = {"mode": mode, "steps": 0, "spec_captures": 0, "repairs": 0,
             "serial_fallbacks": 0, "fallback_fence": 0,
             "fallback_cross_slot": 0, "fallback_eager_capture": 0,
             "fallback_repair_unsound": 0, "fallback_flip_budget": 0,
             "moe_spec_layers": 0, "moe_plan_reuses": 0,
             "moe_flip_repairs": 0, "moe_flipped_assignments": 0,
             "moe_assignments": 0, "moe_dropped_tokens": 0}
    items: List[WalkItem] = list(walker.items)

    ckpt = None
    fp = None
    start_idx = 0
    stored_snap: Dict[str, Dict] = {}   # completed-step subtrees (ckpt state)
    if qc.ckpt_dir:
        from repro.distributed.checkpoint import Checkpointer
        ckpt = Checkpointer(qc.ckpt_dir, keep=qc.ckpt_keep)
        fp = _resume_fingerprint(cfg)
        if qc.resume == "auto" and ckpt.latest_step() is not None:
            from repro.distributed.checkpoint import CheckpointIntegrityError
            try:
                arrays, extra = ckpt.load_arrays()
            except CheckpointIntegrityError as e:
                # damaged checkpoint (failed crc/manifest verification) is a
                # *different* condition from a config mismatch — warn with
                # the distinction and redo the walk from scratch; the next
                # step boundary overwrites the damaged state
                warnings.warn(
                    "quant.resume=auto: checkpoint in "
                    f"{qc.ckpt_dir!r} is corrupt ({e}) — starting fresh",
                    RuntimeWarning)
                arrays, extra = None, {}
            if arrays is None:
                pass
            elif extra.get("walk_fingerprint") != fp:
                warnings.warn(
                    "quant.resume=auto: checkpoint in "
                    f"{qc.ckpt_dir!r} was written by a different config "
                    "(fingerprint mismatch) — starting fresh", RuntimeWarning)
            else:
                start_idx = int(extra["item_idx"]) + 1
                streams_r, stored_snap = _restore_from_arrays(arrays)
                # Replay completed items host-side: switches rebuild their
                # closure side effects (e.g. the enc→dec fence publishing
                # the cross-attention memory), steps re-store their
                # checkpointed subtrees. Then overwrite the streams with
                # the checkpointed values — a replayed switch may reset
                # its output slot to walk-start state.
                walker.streams.clear()
                walker.streams.update({k: list(v)
                                       for k, v in streams_r.items()})
                for it in items[:start_idx]:
                    if isinstance(it, StreamSwitch):
                        it.run(walker.streams)
                    else:
                        it.store(stored_snap[it.name])
                        it.release_params()
                walker.streams.update({k: list(v)
                                       for k, v in streams_r.items()})
                _restore_report(report, extra.get("report", {}), stats)
                stats["resumed_at"] = start_idx
                if verbose:
                    print(f"  [resume] restarting at item "
                          f"{start_idx}/{len(items)}")

    def _save(idx: int) -> None:
        ckpt.save(idx, _walk_ckpt_tree(walker.streams, stored_snap),
                  extra={"item_idx": idx, "walk_fingerprint": fp,
                         "report": _report_state(report, stats)})

    try:
        _run_items(cfg, walker, report, fwd_cache, mesh, verbose, qc,
                   overlap, use_spec, stats, items, start_idx, ckpt, _save,
                   stored_snap)
    finally:
        # join any in-flight async write before propagating — an orphaned
        # writer racing a subsequent resume's own saves could publish a
        # stale LATEST pointer
        if ckpt is not None:
            ckpt.wait()
    report.pipeline_stats = dict(stats)
    return walker.finalize()


def _run_items(cfg, walker, report, fwd_cache, mesh, verbose, qc, overlap,
               use_spec, stats, items, start_idx, ckpt, save_fn,
               stored_snap):
    from repro.core import pipeline as qpipe   # circular-at-import only

    spec_for: Optional[LayerStep] = None
    spec_routes = None                # MoE routing plans from the spec pass
    for idx, item in enumerate(items):
        if idx < start_idx:
            continue                  # replayed from checkpoint above
        if isinstance(item, StreamSwitch):
            item.run(walker.streams)
            spec_for = None
            spec_routes = None
            if ckpt is not None:
                save_fn(idx)
                ckpt.wait()           # fences always flush
            continue
        t_step = time.perf_counter()
        hs = walker.streams[item.hs_slot]
        # speculation eligibility is knowable up front (it only depends on
        # the NEXT item's signature/slot), so the pre-quant outputs are
        # retained exactly when the capture-ahead below will consume them.
        # The repair-soundness predicate resolves lazily and only under
        # overlap (short-circuit), materializing nxt's params at most one
        # step early — they are about to be needed anyway.
        nxt = items[idx + 1] if idx + 1 < len(items) else None
        spec_block: Optional[str] = None
        if overlap and nxt is not None:
            if isinstance(nxt, StreamSwitch):
                spec_block = "fence"
            elif not use_spec:
                spec_block = "eager_capture"
            elif nxt.hs_slot != item.hs_slot:
                spec_block = "cross_slot"
            elif not _repair_sound(qpipe, nxt):
                spec_block = "repair_unsound"
        can_spec = overlap and nxt is not None and spec_block is None
        # 1. capture — under overlap this re-propagates the taps on the
        # repaired (post-scatter) stream: the exact Hessian repair of the
        # speculative pass, riding its compiled entries.
        cap = qpipe.capture_layer(cfg, item, hs, fwd_cache,
                                  collect_h_out=can_spec)
        routes = spec_routes if spec_for is item else None
        if spec_for is item:
            stats["repairs"] += 1
        spec_for = None
        spec_routes = None
        # 2. plan — spec routing plans (if any) feed the MoE flip repair
        new_params, dense_names, plan = qpipe.plan_layer(
            cfg, item, cap, hs, report=report, stats=stats,
            spec_routes=routes)
        # 3. execute — async under overlap: per-stage sync and record
        # materialization defer to this step's report boundary below.
        deferred: Optional[List[Callable[[], None]]] = \
            [] if overlap else None
        results = qplan.execute_plan(qc, plan, report, mesh=mesh,
                                     sync=not overlap, deferred=deferred)
        # 4. scatter on-grid weights (+ grids) back into the subtree
        qpipe.scatter_layer(new_params, dense_names, cap, results)
        # 5. capture-ahead: dispatch the NEXT step's capture forward on
        # THIS step's pre-quantization outputs while the executor is in
        # flight. Discarded at the repair in (1) — overlap stays exact.
        if can_spec:
            spec_cap = qpipe.capture_layer(cfg, nxt, cap.h_out, fwd_cache,
                                           speculative=True)
            spec_for = nxt
            spec_routes = spec_cap.spec_routes
            stats["spec_captures"] += 1
        elif spec_block is not None:
            stats["serial_fallbacks"] += 1
            stats["fallback_" + spec_block] += 1
        # 6. propagate quantized activations
        walker.streams[item.hs_slot] = qpipe.propagate_layer(
            cfg, item, new_params, hs, fwd_cache)
        item.store(new_params)
        # 7. report boundary: materialize the deferred executor records
        # and take the per-layer-step wall clock — the only sync in
        # overlap mode (speculative work stays in flight across it).
        item.release_params()    # drop the pre-quant slice progressively
        if deferred:
            for fin in deferred:
                fin()
        if overlap:
            jax.block_until_ready(walker.streams[item.hs_slot][-1])
        report.layer_step_seconds.append(time.perf_counter() - t_step)
        stats["steps"] += 1
        if ckpt is not None:
            # step boundary: the step's artifacts + post-propagate stream
            # state become durable (async; save() host-snapshots first,
            # so in-flight speculative work keeps the device busy)
            stored_snap[item.name] = new_params
            save_fn(idx)
        if verbose:
            print(f"  {item.name}: {report.summary()}")
