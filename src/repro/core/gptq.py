"""GPTQ stage-1: one-shot blockwise greedy quantization (paper §3.1 stage 1).

Faithful to Frantar et al. / AutoGPTQ:

  - damped Hessian ``H̃`` from the calibration Gram matrix (hessian.py),
  - ``Hinv = U`` upper Cholesky factor of ``H̃^{-1}``,
  - columns processed left→right in lazy blocks of ``blocksize``;
    within a block every column is quantized on its (row, group) grid and the
    rounding error is propagated to the *unquantized* columns of the block
    scaled by ``U[j, j+1:] / U[j, j]``; at block end the accumulated error is
    propagated to the remaining columns in one rank-``blocksize`` update;
  - group (scale, zero) are recomputed from the *error-compensated* weights
    when the column loop enters a new group (AutoGPTQ semantics).

TPU adaptation (DESIGN.md §2): the column loop is sequential in ``Cin`` but
embarrassingly parallel in ``Cout`` — every op below is vectorized over rows,
so sharding rows across the mesh parallelizes GPTQ exactly (no approximation:
rows are independent given ``U``). The whole function is jit-safe: fixed
shapes, ``fori_loop`` + ``dynamic_slice`` only.

The public entries (:func:`gptq_quantize`, :func:`gptq_quantize_batched`)
route through :func:`repro.kernels.ops.gptq_block`, which dispatches the
sweep either to the fused Pallas kernel (kernels/gptq_block.py — one
``pallas_call`` per group sweep) or to the vmapped ``_gptq_core`` XLA body
kept here as the reference/fallback path (``quant.gptq_impl`` config knob).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import hessian as hess
from repro.kernels import ops as kops


class GPTQResult(NamedTuple):
    w_q: jax.Array      # (out, in) dequantized quantized weights (f32)
    scales: jax.Array   # (out, in // group_size) f32
    zeros: jax.Array    # (out, in // group_size) f32 (integer-valued)
    err: jax.Array      # scalar Σ err²: greedy objective proxy (diagnostic)


def _group_qparams(wg: jax.Array, bits: int, symmetric: bool):
    """Per-row (scale, zero) for one group slab wg: (out, g)."""
    qmax = 2.0 ** bits - 1.0
    if symmetric:
        absmax = jnp.max(jnp.abs(wg), axis=1)
        scale = jnp.maximum(absmax / (2.0 ** (bits - 1) - 1), 1e-8)
        zero = jnp.zeros_like(scale)
    else:
        wmax = jnp.maximum(jnp.max(wg, axis=1), 0.0)
        wmin = jnp.minimum(jnp.min(wg, axis=1), 0.0)
        scale = jnp.maximum((wmax - wmin) / qmax, 1e-8)
        zero = jnp.clip(jnp.round(-wmin / scale), 0.0, qmax)
    return scale, zero


def _quant_col(w: jax.Array, scale: jax.Array, zero: jax.Array, bits: int,
               symmetric: bool) -> jax.Array:
    if symmetric:
        lo, hi = -(2.0 ** (bits - 1)), 2.0 ** (bits - 1) - 1
        return jnp.clip(jnp.round(w / scale), lo, hi) * scale
    qmax = 2.0 ** bits - 1.0
    q = jnp.clip(jnp.round(w / scale) + zero, 0.0, qmax)
    return (q - zero) * scale


def _gptq_core(w: jax.Array, hinv_u: jax.Array, *, bits: int,
               group_size: int, blocksize: int,
               symmetric: bool) -> GPTQResult:
    """Single-linear GPTQ body — traceable, vmappable (see batched entry)."""
    out_dim, in_dim = w.shape
    assert in_dim % blocksize == 0, (w.shape, blocksize)
    assert blocksize % group_size == 0, (blocksize, group_size)
    n_blocks = in_dim // blocksize
    n_groups = in_dim // group_size
    groups_per_block = blocksize // group_size

    w = w.astype(jnp.float32)
    u = hinv_u.astype(jnp.float32)

    def block_step(b, carry):
        w, scales, zeros, tot_err = carry
        c1 = b * blocksize
        wb = jax.lax.dynamic_slice(w, (0, c1), (out_dim, blocksize))
        ub = jax.lax.dynamic_slice(u, (c1, c1), (blocksize, blocksize))

        def col_step(j, cc):
            wb, errb, scale, zero, sb, zb = cc

            def refresh(args):
                wb_, sb_, zb_ = args
                g = j // group_size
                wg = jax.lax.dynamic_slice(wb_, (0, g * group_size),
                                           (out_dim, group_size))
                s, z = _group_qparams(wg, bits, symmetric)
                sb_ = jax.lax.dynamic_update_slice(sb_, s[:, None], (0, g))
                zb_ = jax.lax.dynamic_update_slice(zb_, z[:, None], (0, g))
                return s, z, sb_, zb_

            scale, zero, sb, zb = jax.lax.cond(
                j % group_size == 0, refresh,
                lambda args: (scale, zero, args[1], args[2]), (wb, sb, zb))

            wcol = jax.lax.dynamic_slice(wb, (0, j), (out_dim, 1))[:, 0]
            d = jax.lax.dynamic_slice(ub, (j, j), (1, 1))[0, 0]
            q = _quant_col(wcol, scale, zero, bits, symmetric)
            err = (wcol - q) / d
            # in-block propagation to columns > j
            urow = jax.lax.dynamic_slice(ub, (j, 0), (1, blocksize))[0]
            mask = (jnp.arange(blocksize) > j).astype(jnp.float32)
            wb = wb - err[:, None] * (urow * mask)[None, :]
            wb = jax.lax.dynamic_update_slice(wb, q[:, None], (0, j))
            errb = jax.lax.dynamic_update_slice(errb, err[:, None], (0, j))
            return wb, errb, scale, zero, sb, zb

        init = (wb, jnp.zeros_like(wb), jnp.zeros((out_dim,), jnp.float32),
                jnp.zeros((out_dim,), jnp.float32),
                jnp.zeros((out_dim, groups_per_block), jnp.float32),
                jnp.zeros((out_dim, groups_per_block), jnp.float32))
        wb, errb, _, _, sb, zb = jax.lax.fori_loop(0, blocksize, col_step,
                                                   init)

        # lazy batch update: W[:, c2:] -= Err @ U[c1:c2, c2:]
        u_rows = jax.lax.dynamic_slice(u, (c1, 0), (blocksize, in_dim))
        tail = (jnp.arange(in_dim) >= c1 + blocksize).astype(jnp.float32)
        w = w - errb @ (u_rows * tail[None, :])
        w = jax.lax.dynamic_update_slice(w, wb, (0, c1))
        scales = jax.lax.dynamic_update_slice(scales, sb,
                                              (0, b * groups_per_block))
        zeros = jax.lax.dynamic_update_slice(zeros, zb,
                                             (0, b * groups_per_block))
        return w, scales, zeros, tot_err + jnp.sum(errb * errb)

    init = (w, jnp.zeros((out_dim, n_groups), jnp.float32),
            jnp.zeros((out_dim, n_groups), jnp.float32),
            jnp.zeros((), jnp.float32))
    w_q, scales, zeros, tot_err = jax.lax.fori_loop(0, n_blocks, block_step,
                                                    init)
    return GPTQResult(w_q, scales, zeros, tot_err)


@functools.partial(jax.jit, static_argnames=("bits", "group_size",
                                             "blocksize", "symmetric"))
def _gptq_xla_batched(w: jax.Array, hinv_u: jax.Array, *, bits: int,
                      group_size: int, blocksize: int,
                      symmetric: bool) -> GPTQResult:
    """The XLA fallback behind :func:`repro.kernels.ops.gptq_block`:
    vmapped ``_gptq_core`` over the stacked member axis (the PR 1 batched
    executor body — O(Cin) dispatched ops per sweep)."""
    assert w.ndim == 3 and hinv_u.ndim == 3, (w.shape, hinv_u.shape)
    fn = functools.partial(_gptq_core, bits=bits, group_size=group_size,
                           blocksize=blocksize, symmetric=symmetric)
    return jax.vmap(fn)(w, hinv_u)


def gptq_quantize(w: jax.Array, hinv_u: jax.Array, *, bits: int = 4,
                  group_size: int = 128, blocksize: int = 128,
                  symmetric: bool = False, impl: str = "auto") -> GPTQResult:
    """Quantize ``w`` (out, in) given ``hinv_u``, upper Cholesky of H̃^{-1}.

    ``in % blocksize == 0`` and ``blocksize % group_size == 0`` (shipped
    configs use 128/128; tests exercise smaller aligned sizes).  ``impl``
    selects the sweep backend through the kernel dispatcher
    (:func:`repro.kernels.ops.gptq_block`): the fused Pallas kernel
    ("pallas"), the vmapped XLA body ("xla"), or backend-based "auto".
    """
    w_q, scales, zeros, err = kops.gptq_block(
        w, hinv_u, bits=bits, group_size=group_size, blocksize=blocksize,
        symmetric=symmetric, impl=impl)
    return GPTQResult(w_q, scales, zeros, err)


def gptq_quantize_batched(w: jax.Array, hinv_u: jax.Array, *, bits: int = 4,
                          group_size: int = 128, blocksize: int = 128,
                          symmetric: bool = False,
                          impl: str = "auto") -> GPTQResult:
    """Batched GPTQ over a stacked leading axis.

    w: (B, out, in); hinv_u: (B, in, in). One dispatch covers the whole
    group — B same-shape linears quantize together, which is the
    quant-plan executor's throughput win over per-linear dispatch.  On the
    "pallas" path the stack maps onto the kernel's member grid axis (one
    ``pallas_call`` for the whole sweep); on "xla" it vmaps the scalar
    body.  Fields of the returned GPTQResult carry the stacked axis.
    """
    assert w.ndim == 3 and hinv_u.ndim == 3, (w.shape, hinv_u.shape)
    w_q, scales, zeros, err = kops.gptq_block(
        w, hinv_u, bits=bits, group_size=group_size, blocksize=blocksize,
        symmetric=symmetric, impl=impl)
    return GPTQResult(w_q, scales, zeros, err)


def gptq_from_hessian(w: jax.Array, H: hess.HessianState, *, bits: int = 4,
                      group_size: int = 128, blocksize: int = 128,
                      percdamp: float = 0.01,
                      symmetric: bool = False) -> GPTQResult:
    """Convenience: damp H, factor, quantize. w: (out, in)."""
    Hd = hess.damped(H, percdamp)
    u = hess.cholesky_inverse_upper(Hd)
    return gptq_quantize(w, u, bits=bits, group_size=group_size,
                         blocksize=blocksize, symmetric=symmetric)


def rtn_quantize(w: jax.Array, *, bits: int = 4, group_size: int = 128,
                 symmetric: bool = False) -> GPTQResult:
    """Round-to-nearest baseline (no Hessian) in GPTQResult form."""
    from repro.core.quant import (compute_qparams, dequantize_codes,
                                  quantize_codes)
    qp = compute_qparams(w, bits, group_size, symmetric)
    q = quantize_codes(w, qp, bits, group_size, symmetric)
    dq = dequantize_codes(q, qp, group_size, symmetric)
    return GPTQResult(dq, qp.scales, qp.zeros, jnp.zeros((), jnp.float32))


def rtn_quantize_batched(w: jax.Array, *, bits: int = 4,
                         group_size: int = 128,
                         symmetric: bool = False) -> GPTQResult:
    """RTN over a stacked (B, out, in) weight block.

    RTN is purely row-wise, so the stack folds into the row axis — no vmap
    needed. Used for the MoE starved-expert fallback mask inside a batched
    group.
    """
    b, o, i = w.shape
    res = rtn_quantize(w.reshape(b * o, i), bits=bits, group_size=group_size,
                       symmetric=symmetric)
    return GPTQResult(res.w_q.reshape(b, o, i),
                      res.scales.reshape(b, o, -1),
                      res.zeros.reshape(b, o, -1),
                      jnp.zeros((b,), jnp.float32))
