"""Quantization grid primitives: group-wise asymmetric low-bit quantization.

Conventions (match GPTQ / AutoGPTQ):
  - weights quantized along the *input* dimension in groups of ``group_size``
  - asymmetric: q = clip(round(w/scale) + zero, 0, 2^bits-1)
                dq = scale * (q - zero)
  - symmetric:  q = clip(round(w/scale), -2^(b-1), 2^(b-1)-1), zero = 0
  - storage packs two 4-bit values per uint8 along the input dim.

All functions are pure jnp and jit-safe. Shapes:
  W           (out, in)
  scales      (out, n_groups)      n_groups = in // group_size
  zeros       (out, n_groups)      stored as float for exact dequant math
  qweight     (out, in)  int8      unpacked codes
  packed      (out, in // 2) uint8 two nibbles per byte (low nibble = even col)
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp


class QuantParams(NamedTuple):
    """Group quantization parameters for one weight matrix."""
    scales: jax.Array   # (out, n_groups) float32
    zeros: jax.Array    # (out, n_groups) float32 (integer-valued)


@jax.tree_util.register_pytree_node_class
class QuantizedTensor:
    """A packed quantized weight matrix (the serving artifact).

    Registered pytree with static metadata aux data, so jit / eval_shape /
    device_put treat (packed, scales, zeros) as array leaves while
    (shape, bits, group_size) stay Python ints — required for the jit'd
    quantized serve path and the dry-run's ShapeDtypeStruct lowering.
    """

    def __init__(self, packed, scales, zeros, shape: Tuple[int, int],
                 bits: int, group_size: int):
        self.packed = packed    # (out, in//2) uint8
        self.scales = scales    # (out, n_groups)
        self.zeros = zeros      # (out, n_groups)
        self.shape = tuple(shape)
        self.bits = int(bits)
        self.group_size = int(group_size)

    def tree_flatten(self):
        return ((self.packed, self.scales, self.zeros),
                (self.shape, self.bits, self.group_size))

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, *aux)

    def __repr__(self):
        return (f"QuantizedTensor(shape={self.shape}, bits={self.bits}, "
                f"group_size={self.group_size})")


def compute_qparams(w: jax.Array, bits: int, group_size: int,
                    symmetric: bool = False) -> QuantParams:
    """Compute per-(row, group) scale/zero from weight values.

    w: (out, in). Groups tile the input dim; ``in`` must be divisible by
    group_size (configs guarantee this; pad upstream otherwise).
    """
    out_dim, in_dim = w.shape
    assert in_dim % group_size == 0, (in_dim, group_size)
    g = w.reshape(out_dim, in_dim // group_size, group_size).astype(jnp.float32)
    qmax = 2.0 ** bits - 1.0
    if symmetric:
        absmax = jnp.max(jnp.abs(g), axis=-1)
        scale = jnp.maximum(absmax / (2.0 ** (bits - 1) - 1), 1e-8)
        zero = jnp.zeros_like(scale)
    else:
        wmax = jnp.maximum(jnp.max(g, axis=-1), 0.0)
        wmin = jnp.minimum(jnp.min(g, axis=-1), 0.0)
        scale = jnp.maximum((wmax - wmin) / qmax, 1e-8)
        zero = jnp.clip(jnp.round(-wmin / scale), 0.0, qmax)
    return QuantParams(scale, zero)


def quantize_codes(w: jax.Array, qp: QuantParams, bits: int,
                   group_size: int, symmetric: bool = False) -> jax.Array:
    """Map weights to integer codes (stored as int32 for safe arithmetic)."""
    out_dim, in_dim = w.shape
    n_groups = in_dim // group_size
    scale = jnp.repeat(qp.scales, group_size, axis=1)
    zero = jnp.repeat(qp.zeros, group_size, axis=1)
    if symmetric:
        lo, hi = -(2.0 ** (bits - 1)), 2.0 ** (bits - 1) - 1
        q = jnp.clip(jnp.round(w.astype(jnp.float32) / scale), lo, hi)
    else:
        q = jnp.clip(jnp.round(w.astype(jnp.float32) / scale) + zero,
                     0.0, 2.0 ** bits - 1.0)
    return q.astype(jnp.int32)


def dequantize_codes(q: jax.Array, qp: QuantParams, group_size: int,
                     symmetric: bool = False,
                     dtype=jnp.float32) -> jax.Array:
    scale = jnp.repeat(qp.scales, group_size, axis=1)
    if symmetric:
        return (q.astype(jnp.float32) * scale).astype(dtype)
    zero = jnp.repeat(qp.zeros, group_size, axis=1)
    return ((q.astype(jnp.float32) - zero) * scale).astype(dtype)


def fake_quantize(w: jax.Array, bits: int, group_size: int,
                  symmetric: bool = False,
                  qp: QuantParams | None = None) -> jax.Array:
    """Round-trip quantize→dequantize (the ``Q(.)`` of the paper, eq. 7).

    If ``qp`` is given, the grid is fixed (RPIQ stage-2 projections onto the
    stage-1 grid); otherwise scale/zero are recomputed from ``w``.
    """
    if qp is None:
        qp = compute_qparams(w, bits, group_size, symmetric)
    q = quantize_codes(w, qp, bits, group_size, symmetric)
    return dequantize_codes(q, qp, group_size, symmetric, dtype=w.dtype)


def quantize_column(w_col: jax.Array, scale: jax.Array, zero: jax.Array,
                    bits: int, symmetric: bool = False) -> jax.Array:
    """Quantize+dequantize a single column given per-row scale/zero.

    Used inside the GPTQ column loop. w_col/scale/zero: (out,).
    """
    if symmetric:
        lo, hi = -(2.0 ** (bits - 1)), 2.0 ** (bits - 1) - 1
        q = jnp.clip(jnp.round(w_col / scale), lo, hi)
        return q * scale
    qmax = 2.0 ** bits - 1.0
    q = jnp.clip(jnp.round(w_col / scale) + zero, 0.0, qmax)
    return (q - zero) * scale


# ---------------------------------------------------------------------------
# Nibble packing (4-bit storage)
# ---------------------------------------------------------------------------

def pack_int4(q: jax.Array) -> jax.Array:
    """Pack int codes in [0,15], shape (out, in), into (out, in//2) uint8.

    Low nibble holds the even column, high nibble the odd column.
    """
    out_dim, in_dim = q.shape
    assert in_dim % 2 == 0
    q = q.astype(jnp.uint8)
    lo = q[:, 0::2]
    hi = q[:, 1::2]
    return (lo | (hi << 4)).astype(jnp.uint8)


def unpack_int4(packed: jax.Array) -> jax.Array:
    """Inverse of :func:`pack_int4` → (out, in) int32 codes."""
    lo = (packed & jnp.uint8(0x0F)).astype(jnp.int32)
    hi = ((packed >> 4) & jnp.uint8(0x0F)).astype(jnp.int32)
    out = jnp.stack([lo, hi], axis=-1)  # (out, in//2, 2)
    return out.reshape(packed.shape[0], packed.shape[1] * 2)


def pack_quantized(w: jax.Array, bits: int, group_size: int,
                   symmetric: bool = False) -> QuantizedTensor:
    """Full quantize→pack path producing the serving artifact."""
    assert bits == 4, "packed storage currently supports 4-bit"
    qp = compute_qparams(w, bits, group_size, symmetric)
    q = quantize_codes(w, qp, bits, group_size, symmetric)
    if symmetric:  # shift to unsigned storage
        q = q + 8
        zeros = qp.zeros + 8.0
    else:
        zeros = qp.zeros
    return QuantizedTensor(pack_int4(q), qp.scales, zeros,
                           tuple(w.shape), bits, group_size)


def dequantize_packed(qt: QuantizedTensor, dtype=jnp.float32) -> jax.Array:
    q = unpack_int4(qt.packed)
    qp = QuantParams(qt.scales, qt.zeros)
    return dequantize_codes(q, qp, qt.group_size, symmetric=False, dtype=dtype)


def quant_error(w: jax.Array, bits: int, group_size: int,
                symmetric: bool = False) -> jax.Array:
    """Frobenius norm of the round-to-nearest quantization error (diagnostic)."""
    return jnp.linalg.norm(w - fake_quantize(w, bits, group_size, symmetric))
