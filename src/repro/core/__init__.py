"""RPIQ core: the paper's contribution as a composable JAX module."""
from repro.core.quant import (QuantParams, QuantizedTensor, compute_qparams,
                              fake_quantize, pack_quantized, dequantize_packed,
                              pack_int4, unpack_int4)  # noqa: F401
from repro.core.hessian import (HessianState, init_hessian, accumulate,
                                damped, stack_states)  # noqa: F401
from repro.core.plan import (PlanMember, QuantGroup, QuantPlan, QuantReport,
                             LinearRecord, build_plan, execute_plan)  # noqa: F401
from repro.core.stream import (LayerStep, LayerWalker, StreamSwitch,
                               run_walker)  # noqa: F401
