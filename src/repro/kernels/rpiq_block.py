"""Pallas TPU kernel: the FULL RPIQ stage-2 closed loop per grid cell chain.

RPIQ's headline contribution — the stage-2 multi-collaborative closed-loop
refinement (paper §3.1–3.3, eq. 4–8, 19–23) — lowers in XLA
(``core/rpiq._rpiq_core``) to a ``while_loop``-of-``fori_loop`` chain of
``dynamic_slice`` / small-matmul ops: O(t_max × n_blocks) dispatched ops
per member per refinement, the remaining XLA-op and wall-clock dominator of
every quantize run after the stage-1 sweep was fused (gptq_block.py).

This kernel runs EVERY Gauss–Seidel round inside one ``pallas_call``:

  - grid ``(B, Cout/block_out, t_max+1)`` — the stacked group-member axis ×
    row tiles (exactly :mod:`gptq_block`'s (member, Cout-tile) unit, which
    stays the per-shard unit of the mesh-sharded executor — DESIGN.md §2.6)
    × refinement rounds, rounds iterating innermost so the working tile of
    ``W`` and the running ``Y_q`` slab stay VMEM-resident across the whole
    closed loop of a tile (their block index ignores the round axis);
  - step 0 initializes the tile (``Y_q ← X W₀^T``, Γ₀ partial, candidate
    slot 0 = W₀); step t ≥ 1 runs one full Gauss–Seidel sweep over all
    column blocks: directed residual (eq. 4/20), least-squares solve
    (eq. 13–14) as ONE matmul against the pre-factored explicit block
    inverse ``H_i^{-1}`` (both ``exact_gram`` modes produce the same
    ``(M, bs, bs)`` stack via the existing Cholesky OUTSIDE the kernel —
    no triangular solve in Mosaic), grid projection (eq. 7), damped update
    (eq. 8) and the immediate ``Y_q`` update (eq. 21–22);
  - **deferred closed-loop bookkeeping**: the Gauss–Seidel trajectory is
    independent of the early-stop/best-projection logic (stopping only
    truncates it, the best choice only selects from it), but Γ (eq. 23),
    the stop predicate and the best-projection choice are sums/decisions
    over ALL rows — global across row tiles.  So each round emits its
    per-tile Γ/projected-loss partials into per-member accumulators (the
    accumulator block's index ignores both non-member grid axes, so it is
    VMEM-resident for the member's whole chain) and its projected candidate
    ``Q(W^{(t)})`` into a per-round slot; ``ops.rpiq_block`` reduces the
    partials and replays the exact while-loop semantics (stop threshold,
    strict-improvement best, per-lane ``iters_run``) as a handful of
    vectorized ops on (B, t_max+1) scalars.  Under the row-sharded twin the
    partials are psum-folded across shards first, which is what makes row
    sharding exact for stage 2 (rpiq.py docstring).

Consequences of running rounds unconditionally (documented trade):
  - lanes that early-stop still execute their remaining ≤ t_max−1 rounds
    (dead weight bounded by the small t_max, default 5; the dispatch-count
    win dominates — measured in benchmarks/table4_time.py);
  - the returned ``w_cont`` is the t_max-round iterate, not the stop-round
    iterate, whenever early stop fires before t_max.  ``w_q``,
    ``loss_history``, ``proj_loss`` and ``iters_run`` — everything the
    pipeline consumes — replay the XLA path exactly; the XLA body remains
    the reference for ``w_cont``.

VMEM contract: one cell holds five ``(block_out, in)`` tiles (input W₀,
working W, round candidate, expanded scales/zeros), the instance slab
``(n, in)``, two ``(n, block_out)`` output slabs and the ``(in, bs)``
inverse stack — ~``4·(5·block_out·in + n·in + 2·n·block_out + bs·in)``
bytes; ``ops.rpiq_block(impl="auto")`` falls back to the XLA path when that
exceeds the budget instead of failing in Mosaic.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_BLOCK_OUT = 128     # row tile (MXU/lane aligned)


def _iota1d(n: int) -> jax.Array:
    """1D int32 iota via 2D broadcasted_iota (TPU: 1D iota is invalid)."""
    return jax.lax.broadcasted_iota(jnp.int32, (1, n), 1)[0]


def _dot_t(a: jax.Array, b: jax.Array) -> jax.Array:
    """a @ b.T — (m, k) × (n, k) → (m, n), fp32 MXU accumulation."""
    return jax.lax.dot_general(a, b, (((1,), (1,)), ((), ())),
                               preferred_element_type=jnp.float32)


def _project(b: jax.Array, s: jax.Array, z: jax.Array, *, bits: int,
             symmetric: bool) -> jax.Array:
    """Q(·): project onto the fixed stage-1 grid (eq. 7).

    The ONE definition, shared with the XLA body (core/rpiq.py imports
    it; this module is a cycle-free leaf).  ``s``/``z`` are pre-expanded
    to column resolution (same shape as ``b``) — the ``jnp.repeat`` grid
    expansion is hoisted OUT of the per-round Gauss–Seidel sweep (it used
    to re-materialize the full grid every block, every round).  Mirrors
    ``gptq._quant_col`` per mode: symmetric grids carry zero ``z`` and
    quantize onto the signed code range.
    """
    if symmetric:
        lo, hi = -(2.0 ** (bits - 1)), 2.0 ** (bits - 1) - 1
        return jnp.clip(jnp.round(b / s), lo, hi) * s
    qmax = 2.0 ** bits - 1.0
    q = jnp.clip(jnp.round(b / s) + z, 0.0, qmax)
    return (q - z) * s


def _rpiq_block_kernel(w_ref, yo_ref, x_ref, hinv_ref, s_ref, z_ref,
                       wc_ref, wp_ref, yq_ref, hist_ref, pls_ref, *,
                       bits: int, block_size: int, n_blocks: int,
                       t_max: int, alpha: float, symmetric: bool):
    """One (member, row-tile, round) cell of the closed loop."""
    i = pl.program_id(1)
    t = pl.program_id(2)
    onehot_t = (_iota1d(t_max + 1) == t).astype(jnp.float32)

    @pl.when(t == 0)
    def _init():
        w0 = w_ref[0].astype(jnp.float32)
        wc_ref[0] = w0
        wp_ref[0, 0] = w0                       # candidate slot 0 = W₀
        y0 = _dot_t(x_ref[0], w0)               # Y_q ← X W₀^T
        yq_ref[0] = y0
        g0 = jnp.sum((yo_ref[0] - y0) ** 2)     # Γ₀ partial (this tile)

        @pl.when(i == 0)
        def _zero():
            hist_ref[0, 0] = jnp.zeros((t_max + 1,), jnp.float32)
            pls_ref[0, 0] = jnp.zeros((t_max + 1,), jnp.float32)

        hist_ref[0, 0] = hist_ref[0, 0] + g0 * onehot_t
        pls_ref[0, 0] = pls_ref[0, 0] + g0 * onehot_t

    @pl.when(t > 0)
    def _round():
        def block_step(b, carry):
            c1 = pl.multiple_of(b * block_size, block_size)
            b_old = wc_ref[0, :, pl.ds(c1, block_size)]       # (out_t, bs)
            x_i = x_ref[0, :, pl.ds(c1, block_size)]          # (n, bs)
            y_qi = _dot_t(x_i, b_old)                         # (n, out_t)
            d_i = yo_ref[0] - (yq_ref[0] - y_qi)              # eq. 4/20
            rhs = jax.lax.dot_general(                        # X_i^T D_i
                x_i, d_i, (((0,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)           # (bs, out_t)
            hinv_i = hinv_ref[0, pl.ds(c1, block_size), :]    # (bs, bs)
            # eq. 13–14 as one MXU dot against the explicit inverse —
            # same contraction as the XLA body, so rounding matches
            b_star = jax.lax.dot_general(
                rhs, hinv_i, (((0,), (1,)), ((), ())),
                preferred_element_type=jnp.float32)           # (out_t, bs)
            s_i = s_ref[0, :, pl.ds(c1, block_size)]
            z_i = z_ref[0, :, pl.ds(c1, block_size)]
            b_proj = _project(b_star, s_i, z_i, bits=bits,
                              symmetric=symmetric)            # eq. 7
            b_new = b_old + alpha * (b_proj - b_old)          # eq. 8
            yq_ref[0] = yq_ref[0] - y_qi + _dot_t(x_i, b_new)  # eq. 21–22
            wc_ref[0, :, pl.ds(c1, block_size)] = b_new
            return carry

        jax.lax.fori_loop(0, n_blocks, block_step, 0)
        gamma = jnp.sum((yo_ref[0] - yq_ref[0]) ** 2)         # eq. 23
        w_proj = _project(wc_ref[0], s_ref[0], z_ref[0], bits=bits,
                          symmetric=symmetric)
        wp_ref[0, 0] = w_proj                    # candidate slot t
        y_p = _dot_t(x_ref[0], w_proj)
        ploss = jnp.sum((yo_ref[0] - y_p) ** 2)
        hist_ref[0, 0] = hist_ref[0, 0] + gamma * onehot_t
        pls_ref[0, 0] = pls_ref[0, 0] + ploss * onehot_t


@functools.partial(jax.jit, static_argnames=(
    "bits", "group_size", "block_size", "alpha", "t_max", "symmetric",
    "block_out", "interpret"))
def rpiq_block_pallas(w_init: jax.Array, y_orig: jax.Array, x_last: jax.Array,
                      hinv_flat: jax.Array, s_full: jax.Array,
                      z_full: jax.Array, *, bits: int = 4,
                      group_size: int = 128, block_size: int = 128,
                      alpha: float = 0.01, t_max: int = 5,
                      symmetric: bool = False,
                      block_out: int = DEFAULT_BLOCK_OUT,
                      interpret: bool = True
                      ) -> Tuple[jax.Array, jax.Array, jax.Array,
                                 jax.Array, jax.Array]:
    """Full stage-2 closed loop for a stacked group. One ``pallas_call``.

    w_init: (B, out, in) f32 stage-1 weights; y_orig: (B, n, out) reference
    outputs ``X W_fp^T``; x_last: (B, n, in) instance; hinv_flat:
    (B, in, bs) — the (M, bs, bs) explicit block-curvature inverses
    flattened on the row axis; s_full/z_full: (B, out, in) stage-1 grid
    expanded to column resolution (the hoisted ``jnp.repeat``).

    Returns ``(w_cont, w_proj_all, y_q, hist_raw, ploss_raw)``:
    w_cont (B, out, in) t_max-round continuous iterate; w_proj_all
    (B, t_max+1, out, in) per-round projected candidates (slot 0 = W₀);
    y_q (B, n, out) final running outputs; hist_raw/ploss_raw
    (B, 1, t_max+1) raw per-round Γ / projected-loss sums (no early-stop
    masking — ``ops.rpiq_block`` applies the closed-loop bookkeeping).

    Divisibility is the caller's contract: ``in % block_size == 0``,
    ``block_size % group_size == 0``, ``out % block_out == 0``,
    ``t_max >= 1`` (ops.py pads rows / slices back and routes t_max == 0
    to the XLA body).
    """
    b, out_dim, in_dim = w_init.shape
    n = x_last.shape[1]
    assert in_dim % block_size == 0 and block_size % group_size == 0, \
        (w_init.shape, block_size, group_size)
    assert out_dim % block_out == 0, (w_init.shape, block_out)
    assert t_max >= 1, t_max
    n_blocks = in_dim // block_size
    t2 = t_max + 1
    grid = (b, out_dim // block_out, t2)
    kernel = functools.partial(_rpiq_block_kernel, bits=bits,
                               block_size=block_size, n_blocks=n_blocks,
                               t_max=t_max, alpha=alpha, symmetric=symmetric)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_out, in_dim), lambda m, i, t: (m, i, 0)),
            pl.BlockSpec((1, n, block_out), lambda m, i, t: (m, 0, i)),
            pl.BlockSpec((1, n, in_dim), lambda m, i, t: (m, 0, 0)),
            pl.BlockSpec((1, in_dim, block_size), lambda m, i, t: (m, 0, 0)),
            pl.BlockSpec((1, block_out, in_dim), lambda m, i, t: (m, i, 0)),
            pl.BlockSpec((1, block_out, in_dim), lambda m, i, t: (m, i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_out, in_dim), lambda m, i, t: (m, i, 0)),
            pl.BlockSpec((1, 1, block_out, in_dim),
                         lambda m, i, t: (m, t, i, 0)),
            pl.BlockSpec((1, n, block_out), lambda m, i, t: (m, 0, i)),
            pl.BlockSpec((1, 1, t2), lambda m, i, t: (m, 0, 0)),
            pl.BlockSpec((1, 1, t2), lambda m, i, t: (m, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, out_dim, in_dim), jnp.float32),
            jax.ShapeDtypeStruct((b, t2, out_dim, in_dim), jnp.float32),
            jax.ShapeDtypeStruct((b, n, out_dim), jnp.float32),
            jax.ShapeDtypeStruct((b, 1, t2), jnp.float32),
            jax.ShapeDtypeStruct((b, 1, t2), jnp.float32),
        ],
        interpret=interpret,
    )(w_init.astype(jnp.float32), y_orig.astype(jnp.float32),
      x_last.astype(jnp.float32), hinv_flat.astype(jnp.float32),
      s_full.astype(jnp.float32), z_full.astype(jnp.float32))
