"""Pallas TPU kernel: one full GPTQ lazy-block sweep per grid cell.

The quantization hot path (paper §3.1 stage 1 / Frantar et al.) is a
sequential sweep over ``Cin`` columns in lazy blocks of ``blocksize``.  The
XLA formulation (``core/gptq._gptq_core``) lowers that sweep to a
``fori_loop``-of-``dynamic_slice`` chain — O(Cin) small dispatched ops per
member per sweep, which bounds warm executor wall-clock once the plan
batching (core/plan.py) has removed the per-linear dispatch overhead.

This kernel runs the ENTIRE sweep inside one ``pallas_call``:

  - grid ``(B, Cout/block_out)`` — the stacked group-member axis times
    row tiles; rows are independent given ``U`` (see gptq.py), so the
    tiling is exact, not an approximation.  The same (member, Cout-tile)
    grid is the per-shard unit of the mesh-sharded executor: under
    ``ops.gptq_block_sharded``'s ``shard_map`` each device runs this
    kernel on its local ``(B/|data|, Cout/|model|, Cin)`` slab
    (DESIGN.md §2.6);
  - per cell the working ``(block_out, Cin)`` weight tile lives in the
    output ref (VMEM-resident for the whole sweep) and the member's
    ``(Cin, Cin)`` Cholesky factor ``U`` streams in once; the active
    ``(block_out, blocksize)`` weight block and ``(blocksize, blocksize)``
    diagonal ``U`` block are carried through an in-kernel ``fori_loop``;
  - per column: group (scale, zero) refresh via masked max/min (exact —
    the mask only excludes non-group columns from the reduction), column
    quantize on the (row, group) grid, and intra-block error propagation
    ``wb -= err · (U[j, j+1:] / U[j, j])`` — the same broadcasted
    expression as the XLA body, so interpret-mode output is bitwise-close;
  - per block: the rank-``blocksize`` tail update
    ``W[:, c2:] -= Err @ U[c1:c2, c2:]`` as one MXU dot with the same
    operand shapes as the XLA path.

VMEM contract: one cell holds ``U`` (Cin² f32) plus two (block_out, Cin)
tiles — ~``4·Cin·(Cin + 2·block_out)`` bytes.  At Cin = 1024/block_out =
128 that is ~5.2 MB; Cin ≳ 1.7k overflows a 16 MB VMEM budget, which is why
``ops.gptq_block(impl="auto")`` falls back to the XLA path for wide layers
instead of failing in Mosaic.

Scales/zeros accumulate in registers (``(block_out, n_groups)`` carries)
and are written once at sweep end; the per-row Σerr² diagnostic is summed
to the member scalar by the ops.py wrapper.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_BLOCK_OUT = 128     # row tile (MXU/lane aligned)


def _iota1d(n: int) -> jax.Array:
    """1D int32 iota via 2D broadcasted_iota (TPU: 1D iota is invalid)."""
    return jax.lax.broadcasted_iota(jnp.int32, (1, n), 1)[0]


def _gptq_block_kernel(w_ref, u_ref, wq_ref, s_ref, z_ref, err_ref, *,
                       bits: int, group_size: int, blocksize: int,
                       n_blocks: int, symmetric: bool):
    """One (member, row-tile) cell: the full sweep over all lazy blocks."""
    out_t, in_dim = wq_ref.shape[1], wq_ref.shape[2]
    gpb = blocksize // group_size
    n_groups = n_blocks * gpb
    qmax = 2.0 ** bits - 1.0

    cols_bs = _iota1d(blocksize)                  # (bs,) in-block column ids
    cols_in = _iota1d(in_dim)                     # (Cin,) absolute columns
    groups = _iota1d(n_groups)                    # (n_groups,)
    eye_bs = (jax.lax.broadcasted_iota(jnp.int32, (blocksize, blocksize), 0)
              == jax.lax.broadcasted_iota(jnp.int32,
                                          (blocksize, blocksize), 1))

    wq_ref[0] = w_ref[0].astype(jnp.float32)

    def block_step(b, carry):
        sfull, zfull, err_rows = carry
        c1 = pl.multiple_of(b * blocksize, blocksize)
        wb0 = wq_ref[0, :, pl.ds(c1, blocksize)]            # (out_t, bs)
        u_rows = u_ref[0, pl.ds(c1, blocksize), :]          # (bs, Cin)
        ub = u_ref[0, pl.ds(c1, blocksize), pl.ds(c1, blocksize)]
        diag = jnp.sum(jnp.where(eye_bs, ub, 0.0), axis=1)  # (bs,) exact

        def col_step(j, cc):
            wb, errb, scale, zero, sfull, zfull = cc
            onehot = cols_bs == j                            # (bs,)

            def refresh(args):
                wb, scale, zero, sfull, zfull = args
                # masked (scale, zero) — exact: the mask only drops
                # non-group columns from the max/min reductions (order-free)
                gmask = (cols_bs // group_size) == (j // group_size)
                if symmetric:
                    absmax = jnp.max(jnp.where(gmask[None, :], jnp.abs(wb),
                                               0.0), axis=1)
                    scale = jnp.maximum(absmax / (2.0 ** (bits - 1) - 1),
                                        1e-8)
                    zero = jnp.zeros_like(scale)
                else:
                    wmax = jnp.maximum(jnp.max(
                        jnp.where(gmask[None, :], wb, -jnp.inf), axis=1),
                        0.0)
                    wmin = jnp.minimum(jnp.min(
                        jnp.where(gmask[None, :], wb, jnp.inf), axis=1),
                        0.0)
                    scale = jnp.maximum((wmax - wmin) / qmax, 1e-8)
                    zero = jnp.clip(jnp.round(-wmin / scale), 0.0, qmax)
                gsel = (groups == ((c1 + j) // group_size))[None, :]
                sfull = jnp.where(gsel, scale[:, None], sfull)
                zfull = jnp.where(gsel, zero[:, None], zfull)
                return scale, zero, sfull, zfull

            # group-entry refresh only (the cond skips the reductions on
            # the other group_size-1 columns, like the XLA body)
            scale, zero, sfull, zfull = jax.lax.cond(
                j % group_size == 0, refresh,
                lambda args: (args[1], args[2], args[3], args[4]),
                (wb, scale, zero, sfull, zfull))

            # one-hot extraction is exact: a single nonzero per reduction
            wcol = jnp.sum(jnp.where(onehot[None, :], wb, 0.0), axis=1)
            d = jnp.sum(jnp.where(onehot, diag, 0.0))
            if symmetric:
                lo, hi = -(2.0 ** (bits - 1)), 2.0 ** (bits - 1) - 1
                q = jnp.clip(jnp.round(wcol / scale), lo, hi) * scale
            else:
                q = (jnp.clip(jnp.round(wcol / scale) + zero, 0.0, qmax)
                     - zero) * scale
            err = (wcol - q) / d
            urow = jnp.sum(jnp.where(onehot[:, None], ub, 0.0), axis=0)
            mask = (cols_bs > j).astype(jnp.float32)
            wb = wb - err[:, None] * (urow * mask)[None, :]
            wb = jnp.where(onehot[None, :], q[:, None], wb)
            errb = jnp.where(onehot[None, :], err[:, None], errb)
            return wb, errb, scale, zero, sfull, zfull

        init = (wb0, jnp.zeros_like(wb0),
                jnp.zeros((out_t,), jnp.float32),
                jnp.zeros((out_t,), jnp.float32), sfull, zfull)
        wb, errb, _, _, sfull, zfull = jax.lax.fori_loop(
            0, blocksize, col_step, init)

        # lazy batch update: W[:, c2:] -= Err @ U[c1:c2, c2:] — same operand
        # shapes as the XLA path so the contraction rounds identically
        tail = (cols_in >= c1 + blocksize).astype(jnp.float32)
        w_full = wq_ref[0]
        w_full = w_full - jnp.dot(errb, u_rows * tail[None, :],
                                  preferred_element_type=jnp.float32)
        wq_ref[0] = w_full
        wq_ref[0, :, pl.ds(c1, blocksize)] = wb
        return sfull, zfull, err_rows + jnp.sum(errb * errb, axis=1)

    init = (jnp.zeros((out_t, n_groups), jnp.float32),
            jnp.zeros((out_t, n_groups), jnp.float32),
            jnp.zeros((out_t,), jnp.float32))
    sfull, zfull, err_rows = jax.lax.fori_loop(0, n_blocks, block_step, init)
    s_ref[0] = sfull
    z_ref[0] = zfull
    err_ref[0] = err_rows[:, None]


@functools.partial(jax.jit, static_argnames=("bits", "group_size",
                                             "blocksize", "block_out",
                                             "symmetric", "interpret"))
def gptq_block_pallas(w: jax.Array, hinv_u: jax.Array, *, bits: int = 4,
                      group_size: int = 128, blocksize: int = 128,
                      block_out: int = DEFAULT_BLOCK_OUT,
                      symmetric: bool = False, interpret: bool = True
                      ) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Full GPTQ sweep for a stacked group. One ``pallas_call``.

    w: (B, out, in) f32; hinv_u: (B, in, in) upper Cholesky of H̃^{-1}.
    Returns (w_q (B, out, in), scales (B, out, in//group_size), zeros
    (same), err_rows (B, out, 1) per-row Σerr² — trailing singleton keeps
    the output block TPU-tileable).  Divisibility is the caller's
    contract: ``in % blocksize == 0``, ``blocksize % group_size == 0``,
    ``out % block_out == 0`` (ops.py pads rows and slices back).
    """
    b, out_dim, in_dim = w.shape
    assert in_dim % blocksize == 0 and blocksize % group_size == 0, \
        (w.shape, blocksize, group_size)
    assert out_dim % block_out == 0, (w.shape, block_out)
    n_blocks = in_dim // blocksize
    n_groups = in_dim // group_size
    grid = (b, out_dim // block_out)
    kernel = functools.partial(_gptq_block_kernel, bits=bits,
                               group_size=group_size, blocksize=blocksize,
                               n_blocks=n_blocks, symmetric=symmetric)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_out, in_dim), lambda m, i: (m, i, 0)),
            pl.BlockSpec((1, in_dim, in_dim), lambda m, i: (m, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_out, in_dim), lambda m, i: (m, i, 0)),
            pl.BlockSpec((1, block_out, n_groups), lambda m, i: (m, i, 0)),
            pl.BlockSpec((1, block_out, n_groups), lambda m, i: (m, i, 0)),
            pl.BlockSpec((1, block_out, 1), lambda m, i: (m, i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, out_dim, in_dim), jnp.float32),
            jax.ShapeDtypeStruct((b, out_dim, n_groups), jnp.float32),
            jax.ShapeDtypeStruct((b, out_dim, n_groups), jnp.float32),
            jax.ShapeDtypeStruct((b, out_dim, 1), jnp.float32),
        ],
        interpret=interpret,
    )(w.astype(jnp.float32), hinv_u.astype(jnp.float32))
