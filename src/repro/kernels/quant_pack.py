"""Pallas TPU kernel: fused quantize-to-grid + nibble pack.

The RPIQ stage-2 inner loop projects a continuous least-squares solution
onto the 4-bit grid every (block, iteration); at deployment the final
weights are packed 2 nibbles/byte. Fusing round/clip/pack keeps the
float weights' HBM traffic to a single read and writes 0.5 byte/weight,
instead of materializing an intermediate int32 code tensor.

Tiling: rows × column-pairs. The K tile is a multiple of the quant group
so a (scale, zero) column never straddles tiles; scales stay VMEM-resident
per tile. The pack itself is a vector shift+or on the even/odd deinterleave.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_BLOCK_N = 256   # rows per tile
DEFAULT_BLOCK_K = 512   # weight columns per tile (multiple of group_size)


def _quant_pack_kernel(w_ref, scales_ref, zeros_ref, out_ref, *,
                       group_size: int):
    w = w_ref[...].astype(jnp.float32)                     # (bn, bk)
    s = jnp.repeat(scales_ref[...].astype(jnp.float32), group_size, axis=1)
    z = jnp.repeat(zeros_ref[...].astype(jnp.float32), group_size, axis=1)
    q = jnp.clip(jnp.round(w / s) + z, 0.0, 15.0).astype(jnp.uint8)
    bn, bk = q.shape
    lo = q.reshape(bn, bk // 2, 2)[:, :, 0]
    hi = q.reshape(bn, bk // 2, 2)[:, :, 1]
    out_ref[...] = (lo | (hi << 4)).astype(jnp.uint8)


@functools.partial(jax.jit, static_argnames=("group_size", "block_n",
                                             "block_k", "interpret"))
def quant_pack_pallas(w: jax.Array, scales: jax.Array, zeros: jax.Array, *,
                      group_size: int = 128,
                      block_n: int = DEFAULT_BLOCK_N,
                      block_k: int = DEFAULT_BLOCK_K,
                      interpret: bool = True) -> jax.Array:
    """w: (n, k) float; scales/zeros: (n, k//group_size) → (n, k//2) uint8.

    Divisibility is the caller's contract (ops.py pads).
    """
    n, k = w.shape
    block_n = min(block_n, n)
    block_k = min(block_k, k)
    assert block_k % group_size == 0 and block_k % 2 == 0
    assert n % block_n == 0 and k % block_k == 0, (w.shape, block_n, block_k)
    grid = (n // block_n, k // block_k)
    return pl.pallas_call(
        functools.partial(_quant_pack_kernel, group_size=group_size),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, block_k), lambda i, j: (i, j)),
            pl.BlockSpec((block_n, block_k // group_size), lambda i, j: (i, j)),
            pl.BlockSpec((block_n, block_k // group_size), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((block_n, block_k // 2), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, k // 2), jnp.uint8),
        interpret=interpret,
    )(w, scales, zeros)
