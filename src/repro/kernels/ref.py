"""Pure-jnp oracles for every Pallas kernel in this package.

These are the ground truth for the per-kernel allclose tests and the XLA
fallback implementations used on non-TPU backends (e.g. the CPU dry-run).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def hessian_accum_ref(x: jax.Array) -> jax.Array:
    """H = X^T X with fp32 accumulation. x: (n, d) any float dtype."""
    xf = x.astype(jnp.float32)
    return xf.T @ xf


def w4a16_matmul_ref(x: jax.Array, packed: jax.Array, scales: jax.Array,
                     zeros: jax.Array, group_size: int) -> jax.Array:
    """y = x @ dequant(W)^T.

    x:      (m, k) float (bf16/f32)
    packed: (n, k//2) uint8 — two 4-bit codes per byte, low nibble = even col
    scales: (n, k//group_size) f32
    zeros:  (n, k//group_size) f32 (integer-valued)
    returns (m, n) in x.dtype, fp32 accumulation.
    """
    n, kh = packed.shape
    k = kh * 2
    lo = (packed & jnp.uint8(0x0F)).astype(jnp.float32)
    hi = ((packed >> 4) & jnp.uint8(0x0F)).astype(jnp.float32)
    codes = jnp.stack([lo, hi], axis=-1).reshape(n, k)
    s = jnp.repeat(scales.astype(jnp.float32), group_size, axis=1)
    z = jnp.repeat(zeros.astype(jnp.float32), group_size, axis=1)
    w = (codes - z) * s                                   # (n, k) f32
    y = jnp.dot(x.astype(jnp.float32), w.T,
                preferred_element_type=jnp.float32)
    return y.astype(x.dtype)


def selective_scan_ref(u: jax.Array, dt: jax.Array, bm: jax.Array,
                       cm: jax.Array, a_log: jax.Array, d_skip: jax.Array,
                       h0: jax.Array):
    """Mamba-1 diagonal SSM, sequential scan oracle.

    u/dt: (B, S, d); bm/cm: (B, S, n); a_log: (d, n) (A = -exp(a_log));
    d_skip: (d,); h0: (B, d, n). Returns (y (B,S,d) in u.dtype, h_last).
    """
    A = -jnp.exp(a_log.astype(jnp.float32))

    def step(h, xs):
        u_t, dt_t, b_t, c_t = xs                    # (B,d),(B,d),(B,n),(B,n)
        a_t = jnp.exp(dt_t[..., None] * A[None])    # (B, d, n)
        h = a_t * h + (dt_t * u_t)[..., None] * b_t[:, None, :]
        y_t = jnp.einsum("bdn,bn->bd", h, c_t) \
            + u_t * d_skip.astype(jnp.float32)[None]
        return h, y_t

    xs = (u.astype(jnp.float32).transpose(1, 0, 2),
          dt.astype(jnp.float32).transpose(1, 0, 2),
          bm.astype(jnp.float32).transpose(1, 0, 2),
          cm.astype(jnp.float32).transpose(1, 0, 2))
    h_last, ys = jax.lax.scan(step, h0.astype(jnp.float32), xs)
    return ys.transpose(1, 0, 2).astype(u.dtype), h_last.astype(h0.dtype)


def quant_pack_ref(w: jax.Array, scales: jax.Array, zeros: jax.Array,
                   group_size: int) -> jax.Array:
    """Quantize to 4-bit codes on a fixed grid and pack 2 codes/byte.

    w: (n, k); scales/zeros: (n, k//group_size). Returns (n, k//2) uint8.
    """
    n, k = w.shape
    s = jnp.repeat(scales.astype(jnp.float32), group_size, axis=1)
    z = jnp.repeat(zeros.astype(jnp.float32), group_size, axis=1)
    q = jnp.clip(jnp.round(w.astype(jnp.float32) / s) + z, 0.0, 15.0)
    q = q.astype(jnp.uint8)
    lo = q[:, 0::2]
    hi = q[:, 1::2]
    return (lo | (hi << 4)).astype(jnp.uint8)
