"""Pure-jnp oracles for every Pallas kernel in this package.

These are the ground truth for the per-kernel allclose tests and the XLA
fallback implementations used on non-TPU backends (e.g. the CPU dry-run).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def hessian_accum_ref(x: jax.Array) -> jax.Array:
    """H = X^T X with fp32 accumulation. x: (n, d) any float dtype."""
    xf = x.astype(jnp.float32)
    return xf.T @ xf


def w4a16_matmul_ref(x: jax.Array, packed: jax.Array, scales: jax.Array,
                     zeros: jax.Array, group_size: int) -> jax.Array:
    """y = x @ dequant(W)^T.

    x:      (m, k) float (bf16/f32)
    packed: (n, k//2) uint8 — two 4-bit codes per byte, low nibble = even col
    scales: (n, k//group_size) f32
    zeros:  (n, k//group_size) f32 (integer-valued)
    returns (m, n) in x.dtype, fp32 accumulation.
    """
    n, kh = packed.shape
    k = kh * 2
    lo = (packed & jnp.uint8(0x0F)).astype(jnp.float32)
    hi = ((packed >> 4) & jnp.uint8(0x0F)).astype(jnp.float32)
    codes = jnp.stack([lo, hi], axis=-1).reshape(n, k)
    s = jnp.repeat(scales.astype(jnp.float32), group_size, axis=1)
    z = jnp.repeat(zeros.astype(jnp.float32), group_size, axis=1)
    w = (codes - z) * s                                   # (n, k) f32
    y = jnp.dot(x.astype(jnp.float32), w.T,
                preferred_element_type=jnp.float32)
    return y.astype(x.dtype)


def int8_kv_attention_ref(q: jax.Array, k_codes: jax.Array,
                          k_scales: jax.Array, v_codes: jax.Array,
                          v_scales: jax.Array, kpos: jax.Array,
                          kv_block: int, softcap: float = 0.0) -> jax.Array:
    """Decode GQA attention against an int8 KV cache, full-dequant oracle.

    q: (B, KV, R, hd) pre-scaled (hd^-0.5 folded in by the caller);
    k/v codes: (B, S, KV, hd) int8; k/v scales: (B, S, KV, hd//kv_block)
    f32; kpos: (B, S) int32, -1 marks invalid slots (the caller encodes
    causal/window validity into kpos). Returns (B, KV, R, hd) in q.dtype
    with f32 score/value accumulation. Materializes the dequantized cache
    — the HBM cost the fused kernel avoids.
    """
    from repro.kernels import kv_codec
    k = kv_codec.dec_int8_blocks(k_codes, k_scales, kv_block)  # (B,S,KV,hd)
    v = kv_codec.dec_int8_blocks(v_codes, v_scales, kv_block)
    s = jnp.einsum("bgrd,bsgd->bgrs", q.astype(jnp.float32), k,
                   preferred_element_type=jnp.float32)
    if softcap > 0:
        s = jnp.tanh(s / softcap) * softcap
    s = jnp.where(kpos[:, None, None, :] >= 0, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bgrs,bsgd->bgrd", p, v,
                   preferred_element_type=jnp.float32)
    return o.astype(q.dtype)


def selective_scan_ref(u: jax.Array, dt: jax.Array, bm: jax.Array,
                       cm: jax.Array, a_log: jax.Array, d_skip: jax.Array,
                       h0: jax.Array):
    """Mamba-1 diagonal SSM, sequential scan oracle.

    u/dt: (B, S, d); bm/cm: (B, S, n); a_log: (d, n) (A = -exp(a_log));
    d_skip: (d,); h0: (B, d, n). Returns (y (B,S,d) in u.dtype, h_last).
    """
    A = -jnp.exp(a_log.astype(jnp.float32))

    def step(h, xs):
        u_t, dt_t, b_t, c_t = xs                    # (B,d),(B,d),(B,n),(B,n)
        a_t = jnp.exp(dt_t[..., None] * A[None])    # (B, d, n)
        h = a_t * h + (dt_t * u_t)[..., None] * b_t[:, None, :]
        y_t = jnp.einsum("bdn,bn->bd", h, c_t) \
            + u_t * d_skip.astype(jnp.float32)[None]
        return h, y_t

    xs = (u.astype(jnp.float32).transpose(1, 0, 2),
          dt.astype(jnp.float32).transpose(1, 0, 2),
          bm.astype(jnp.float32).transpose(1, 0, 2),
          cm.astype(jnp.float32).transpose(1, 0, 2))
    h_last, ys = jax.lax.scan(step, h0.astype(jnp.float32), xs)
    return ys.transpose(1, 0, 2).astype(u.dtype), h_last.astype(h0.dtype)


def gptq_block_ref(w, hinv_u, *, bits: int = 4, group_size: int = 128,
                   blocksize: int = 128, symmetric: bool = False):
    """Pure-NumPy GPTQ lazy-block sweep — the oracle for gptq_block.

    w: (out, in) or (B, out, in); hinv_u: matching (in, in) / (B, in, in)
    upper Cholesky of the damped inverse Hessian.  Returns (w_q, scales,
    zeros, err) with err the scalar Σerr² per member.  Mirrors
    ``core/gptq._gptq_core`` step for step (AutoGPTQ semantics: group
    qparams refresh from the error-compensated weights at group entry).
    """
    if np.ndim(w) == 3:
        outs = [gptq_block_ref(np.asarray(w)[i], np.asarray(hinv_u)[i],
                               bits=bits, group_size=group_size,
                               blocksize=blocksize, symmetric=symmetric)
                for i in range(np.shape(w)[0])]
        return tuple(np.stack([o[k] for o in outs]) for k in range(4))

    w = np.array(w, np.float32)
    u = np.array(hinv_u, np.float32)
    out_dim, in_dim = w.shape
    assert in_dim % blocksize == 0 and blocksize % group_size == 0
    qmax = 2.0 ** bits - 1.0
    n_groups = in_dim // group_size
    scales = np.zeros((out_dim, n_groups), np.float32)
    zeros = np.zeros((out_dim, n_groups), np.float32)
    tot_err = np.float32(0.0)

    for c1 in range(0, in_dim, blocksize):
        c2 = c1 + blocksize
        wb = w[:, c1:c2].copy()
        ub = u[c1:c2, c1:c2]
        errb = np.zeros_like(wb)
        scale = zero = None
        for j in range(blocksize):
            if j % group_size == 0:
                g = (c1 + j) // group_size
                wg = wb[:, (j // group_size) * group_size:
                        (j // group_size + 1) * group_size]
                if symmetric:
                    absmax = np.max(np.abs(wg), axis=1)
                    scale = np.maximum(
                        absmax / (2.0 ** (bits - 1) - 1), 1e-8)
                    zero = np.zeros_like(scale)
                else:
                    wmax = np.maximum(np.max(wg, axis=1), 0.0)
                    wmin = np.minimum(np.min(wg, axis=1), 0.0)
                    scale = np.maximum((wmax - wmin) / qmax, 1e-8)
                    zero = np.clip(np.round(-wmin / scale), 0.0, qmax)
                scales[:, g] = scale
                zeros[:, g] = zero
            wcol = wb[:, j]
            d = ub[j, j]
            if symmetric:
                lo, hi = -(2.0 ** (bits - 1)), 2.0 ** (bits - 1) - 1
                q = np.clip(np.round(wcol / scale), lo, hi) * scale
            else:
                q = (np.clip(np.round(wcol / scale) + zero, 0.0, qmax)
                     - zero) * scale
            err = (wcol - q) / d
            wb[:, j + 1:] -= err[:, None] * ub[j, j + 1:][None, :]
            wb[:, j] = q
            errb[:, j] = err
        w[:, c2:] -= errb @ u[c1:c2, c2:]
        w[:, c1:c2] = wb
        tot_err += np.sum(errb * errb)
    return w, scales, zeros, np.float32(tot_err)


def rpiq_block_ref(w_init, w_fp, x_last, hinv_blocks, scales, zeros, *,
                   bits: int = 4, group_size: int = 128,
                   block_size: int = 128, alpha: float = 0.01,
                   t_max: int = 5, early_stop: bool = True,
                   symmetric: bool = False):
    """Pure-NumPy RPIQ stage-2 closed loop — the oracle for rpiq_block.

    w_init/w_fp: (out, in) or (B, out, in); x_last matches with (n, in)
    trailing dims; hinv_blocks: (M, bs, bs) / (B, M, bs, bs) explicit
    blockwise curvature inverses (``core/rpiq._block_curvature_inv``).
    Returns the RPIQResult tuple ``(w_q, w_cont, loss_history, proj_loss,
    iters_run)``.  Mirrors ``core/rpiq._rpiq_core`` step for step:
    directed residual, one-matmul LS solve against the pre-factored
    inverse, grid projection, damped update, immediate Y_q update, Γ
    early stop and strict-improvement best-projection tracking.
    """
    if np.ndim(w_init) == 3:
        outs = [rpiq_block_ref(np.asarray(w_init)[i], np.asarray(w_fp)[i],
                               np.asarray(x_last)[i],
                               np.asarray(hinv_blocks)[i],
                               np.asarray(scales)[i], np.asarray(zeros)[i],
                               bits=bits, group_size=group_size,
                               block_size=block_size, alpha=alpha,
                               t_max=t_max, early_stop=early_stop,
                               symmetric=symmetric)
                for i in range(np.shape(w_init)[0])]
        return tuple(np.stack([o[k] for o in outs]) for k in range(5))

    w0 = np.array(w_init, np.float32)
    x = np.array(x_last, np.float32)
    hinv = np.array(hinv_blocks, np.float32)
    out_dim, in_dim = w0.shape
    assert in_dim % block_size == 0 and block_size % group_size == 0
    n_blocks = in_dim // block_size
    y_orig = x @ np.array(w_fp, np.float32).T
    s = np.repeat(np.array(scales, np.float32), group_size, axis=1)
    z = np.repeat(np.array(zeros, np.float32), group_size, axis=1)

    def project(b, sl, zl):
        if symmetric:
            lo, hi = -(2.0 ** (bits - 1)), 2.0 ** (bits - 1) - 1
            return np.clip(np.round(b / sl), lo, hi) * sl
        qmax = 2.0 ** bits - 1.0
        q = np.clip(np.round(b / sl) + zl, 0.0, qmax)
        return (q - zl) * sl

    w = w0.copy()
    y_q = x @ w.T
    hist = np.full(t_max + 1, np.inf, np.float32)
    hist[0] = np.float32(np.sum((y_orig - y_q) ** 2))
    best_w, best_loss = w0.copy(), hist[0]
    iters = 0
    for t in range(t_max):
        for i in range(n_blocks):
            c1, c2 = i * block_size, (i + 1) * block_size
            b_old = w[:, c1:c2]
            x_i = x[:, c1:c2]
            y_qi = x_i @ b_old.T
            d_i = y_orig - (y_q - y_qi)
            rhs = x_i.T @ d_i
            b_star = (hinv[i] @ rhs).T
            b_proj = project(b_star, s[:, c1:c2], z[:, c1:c2])
            b_new = b_old + np.float32(alpha) * (b_proj - b_old)
            y_q = y_q - y_qi + x_i @ b_new.T
            w = w.copy()
            w[:, c1:c2] = b_new
        gamma = np.float32(np.sum((y_orig - y_q) ** 2))
        hist[t + 1] = gamma
        w_proj = project(w, s, z)
        ploss = np.float32(np.sum((y_orig - x @ w_proj.T) ** 2))
        iters = t + 1
        if ploss < best_loss:
            best_w, best_loss = w_proj, ploss
        if early_stop and gamma >= hist[t] * (1.0 - 1e-6):
            break
    return (best_w, w, hist, np.float32(best_loss), np.int32(iters))


def quant_pack_ref(w: jax.Array, scales: jax.Array, zeros: jax.Array,
                   group_size: int) -> jax.Array:
    """Quantize to 4-bit codes on a fixed grid and pack 2 codes/byte.

    w: (n, k); scales/zeros: (n, k//group_size). Returns (n, k//2) uint8.
    """
    n, k = w.shape
    s = jnp.repeat(scales.astype(jnp.float32), group_size, axis=1)
    z = jnp.repeat(zeros.astype(jnp.float32), group_size, axis=1)
    q = jnp.clip(jnp.round(w.astype(jnp.float32) / s) + z, 0.0, 15.0)
    q = q.astype(jnp.uint8)
    lo = q[:, 0::2]
    hi = q[:, 1::2]
    return (lo | (hi << 4)).astype(jnp.uint8)
