"""Pallas TPU kernels for the RPIQ compute hot-spots.

  - hessian_accum  — H = X^T X calibration Gram accumulation (paper eq. 9)
  - w4a16_matmul   — int4-grouped dequant matmul (quantized serving path)
  - quant_pack     — fused quantize-to-grid + nibble pack (stage-2 projection
                     and deployment packing)
  - gptq_block     — the stage-1 GPTQ lazy-block sweep fused into ONE
                     ``pallas_call``: grid (members, Cout tiles), the
                     working row tile + the member's Cholesky factor stay
                     VMEM-resident for the whole sweep, replacing the
                     O(Cin) ``fori_loop``-of-``dynamic_slice`` XLA ops per
                     sweep with a single kernel dispatch.  Dispatch
                     contract (``ops.gptq_block``): ``impl="pallas"|"xla"``
                     force a backend; ``"auto"`` uses pallas on TPU only
                     when the per-cell VMEM residency
                     ``4·Cin·(Cin + 2·block_out + blocksize)`` bytes fits
                     the budget (Cin ≳ 1.7k f32 falls back to XLA); rows
                     are padded to the ``block_out`` tile and sliced back.

``ops`` is the dispatch layer (pallas on TPU / interpret-validated on CPU /
XLA fallback); ``ref`` holds the pure-jnp/NumPy oracles used by the
allclose tests.
"""
from repro.kernels import ops, ref  # noqa: F401
