"""Pallas TPU kernels for the RPIQ compute hot-spots.

  - hessian_accum  — H = X^T X calibration Gram accumulation (paper eq. 9)
  - w4a16_matmul   — int4-grouped dequant matmul (quantized serving path)
  - quant_pack     — fused quantize-to-grid + nibble pack (stage-2 projection
                     and deployment packing)

``ops`` is the dispatch layer (pallas on TPU / interpret-validated on CPU /
XLA fallback); ``ref`` holds the pure-jnp oracles used by the allclose tests.
"""
from repro.kernels import ops, ref  # noqa: F401
