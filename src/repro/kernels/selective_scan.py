"""Pallas TPU kernel: Mamba-1 selective scan with VMEM-resident state.

The §Perf cell-C hot spot: the pure-JAX associative scan materializes the
(B, S, d_inner, n) state-expansion tensors a = exp(Δ⊙A) and b = Δ⊙B⊙u in
HBM (~25× the residual-stream bytes for falcon-mamba prefill_32k —
measured). The CUDA reference (selective_scan_cuda) keeps h in shared
memory; the TPU-native formulation here:

  - grid (B, d/bd, S/ts): batch × d-tiles parallel, the TIME axis is the
    innermost (sequential) grid dim, so the (bd, n) state scratch persists
    in VMEM across time tiles — the recurrence never touches HBM;
  - per time tile, (ts, bd) slabs of u/Δ and (ts, n) slabs of B/C stream
    through VMEM; a_t = exp(Δ_t ⊙ A) is computed in-register (A is a
    VMEM-resident (bd, n) constant per tile);
  - the time loop inside the tile is a ``fori_loop`` over ts steps of rank-1
    state updates h ← a_t ⊙ h + (Δ_t u_t)·B_t and y_t = h·C_t + D⊙u_t —
    vector ops on (bd, n), MXU-free by design (the op is bandwidth-bound;
    the win is HBM traffic, not flops).

HBM traffic: reads u, Δ (B,S,bd-tiled), B, C (B,S,n), writes y (B,S,d) —
O(B·S·d) instead of O(B·S·d·n). Validated in interpret mode against
``ref.selective_scan_ref`` over shape/dtype sweeps (tests/test_kernels.py).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


DEFAULT_BLOCK_D = 256    # d_inner tile (lane-aligned)
DEFAULT_BLOCK_T = 128    # time steps per VMEM slab


def _ssm_kernel(u_ref, dt_ref, b_ref, c_ref, a_ref, dskip_ref, h0_ref,
                y_ref, hout_ref, h_scratch, *, n_t_tiles: int, ts: int):
    t_idx = pl.program_id(2)

    @pl.when(t_idx == 0)
    def _init():
        h_scratch[...] = h0_ref[0].astype(jnp.float32)

    a_neg = -jnp.exp(a_ref[...].astype(jnp.float32))  # A = -exp(a_log)
    dskip = dskip_ref[...].astype(jnp.float32)        # (1, bd)

    def step(i, h):
        dt_i = dt_ref[0, i, :].astype(jnp.float32)          # (bd,)
        u_i = u_ref[0, i, :].astype(jnp.float32)            # (bd,)
        b_i = b_ref[0, i, :].astype(jnp.float32)            # (n,)
        c_i = c_ref[0, i, :].astype(jnp.float32)            # (n,)
        a_i = jnp.exp(dt_i[:, None] * a_neg)                # (bd, n)
        h = a_i * h + (dt_i * u_i)[:, None] * b_i[None, :]  # (bd, n)
        y_i = jnp.sum(h * c_i[None, :], axis=1) + dskip[0] * u_i
        y_ref[0, i, :] = y_i.astype(y_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, ts, step, h_scratch[...])
    h_scratch[...] = h

    @pl.when(t_idx == n_t_tiles - 1)
    def _out():
        hout_ref[0] = h.astype(hout_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_d", "block_t",
                                             "interpret"))
def selective_scan_pallas(u: jax.Array, dt: jax.Array, bm: jax.Array,
                          cm: jax.Array, a_log: jax.Array,
                          d_skip: jax.Array, h0: jax.Array, *,
                          block_d: int = DEFAULT_BLOCK_D,
                          block_t: int = DEFAULT_BLOCK_T,
                          interpret: bool = True
                          ) -> Tuple[jax.Array, jax.Array]:
    """u/dt: (B, S, d); bm/cm: (B, S, n); a_log: (d, n) with A = -exp(a_log);
    d_skip: (d,); h0: (B, d, n). Returns (y (B,S,d), h_last (B,d,n)).

    Divisibility: S % block_t == 0, d % block_d == 0 (ops.py pads).
    """
    B, S, d = u.shape
    n = bm.shape[-1]
    block_d = min(block_d, d)
    block_t = min(block_t, S)
    assert S % block_t == 0 and d % block_d == 0, (u.shape, block_t, block_d)
    grid = (B, d // block_d, S // block_t)
    kernel = functools.partial(_ssm_kernel, n_t_tiles=grid[2], ts=block_t)
    y, h_last = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_t, block_d), lambda b, i, t: (b, t, i)),
            pl.BlockSpec((1, block_t, block_d), lambda b, i, t: (b, t, i)),
            pl.BlockSpec((1, block_t, n), lambda b, i, t: (b, t, 0)),
            pl.BlockSpec((1, block_t, n), lambda b, i, t: (b, t, 0)),
            pl.BlockSpec((block_d, n), lambda b, i, t: (i, 0)),
            pl.BlockSpec((1, block_d), lambda b, i, t: (0, i)),
            pl.BlockSpec((1, block_d, n), lambda b, i, t: (b, i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_t, block_d), lambda b, i, t: (b, t, i)),
            pl.BlockSpec((1, block_d, n), lambda b, i, t: (b, i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, d), u.dtype),
            jax.ShapeDtypeStruct((B, d, n), h0.dtype),
        ],
        scratch_shapes=[pltpu.VMEM((block_d, n), jnp.float32)],
        interpret=interpret,
    )(u, dt, bm, cm, a_log, d_skip.reshape(1, d), h0)
    return y, h_last
