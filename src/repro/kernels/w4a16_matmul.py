"""Pallas TPU kernel: grouped int4-dequant matmul  y = x @ dequant(W)^T.

The deployment hot spot for RPIQ-quantized models: decode-time GEMV/GEMM
against 4-bit packed weights. GPU implementations unpack int4 in CUDA cores;
the TPU-native formulation here:

  - weight nibbles live packed in HBM as (n, k/2) uint8 and are unpacked
    with vector bit-ops in VREGs *after* the (bn, bk/2) tile is in VMEM —
    HBM traffic stays at 0.5 byte/weight + scales, which is what makes
    memory-bound decode ~3.8x faster than bf16 weights;
  - per-(row, group) scale/zero tiles are tiny and stay VMEM-resident;
  - K tiles are multiples of the quant group (128) so a group never
    straddles tiles and dequant is a broadcasted multiply;
  - dequantized bf16/f32 tiles feed the MXU via dot_general with fp32
    accumulation; M/N tiles are multiples of (8, 128) lane geometry.

Grid: (m/bm, n/bn, k/bk), K innermost (sequential accumulation).
Validated in interpret mode on CPU; on real TPU the same kernel lowers via
Mosaic (the nibble unpack is a shift+mask+interleave, which Mosaic lowers to
vector shuffles; native jnp.int4 loads would be the next step).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


DEFAULT_BLOCK_M = 128
DEFAULT_BLOCK_N = 128
DEFAULT_BLOCK_K = 512


def _w4a16_kernel(x_ref, packed_ref, scales_ref, zeros_ref, y_ref, acc_ref, *,
                  group_size: int, n_k_steps: int, out_dtype):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    packed = packed_ref[...]                                # (bn, bk//2) u8
    lo = (packed & jnp.uint8(0x0F)).astype(jnp.float32)
    hi = ((packed >> 4) & jnp.uint8(0x0F)).astype(jnp.float32)
    bn, bkh = packed.shape
    codes = jnp.stack([lo, hi], axis=-1).reshape(bn, bkh * 2)

    s = scales_ref[...].astype(jnp.float32)                 # (bn, bk//g)
    z = zeros_ref[...].astype(jnp.float32)
    s = jnp.repeat(s, group_size, axis=1)
    z = jnp.repeat(z, group_size, axis=1)
    w = (codes - z) * s                                     # (bn, bk) f32

    x = x_ref[...].astype(jnp.float32)                      # (bm, bk)
    acc_ref[...] += jax.lax.dot_general(
        x, w, (((1,), (1,)), ((), ())),                     # x @ w.T
        preferred_element_type=jnp.float32)

    @pl.when(k == n_k_steps - 1)
    def _store():
        y_ref[...] = acc_ref[...].astype(out_dtype)


@functools.partial(jax.jit, static_argnames=(
    "group_size", "block_m", "block_n", "block_k", "interpret"))
def w4a16_matmul_pallas(x: jax.Array, packed: jax.Array, scales: jax.Array,
                        zeros: jax.Array, *, group_size: int = 128,
                        block_m: int = DEFAULT_BLOCK_M,
                        block_n: int = DEFAULT_BLOCK_N,
                        block_k: int = DEFAULT_BLOCK_K,
                        interpret: bool = True) -> jax.Array:
    """x: (m, k); packed: (n, k//2) uint8; scales/zeros: (n, k//group_size).

    Returns (m, n) in x.dtype. Shape divisibility is the caller's contract
    (ops.py pads); block_k must be a multiple of group_size.
    """
    m, kdim = x.shape
    n = packed.shape[0]
    block_m = min(block_m, m)
    block_k = min(block_k, kdim)
    assert block_k % group_size == 0, (block_k, group_size)
    assert m % block_m == 0 and n % block_n == 0 and kdim % block_k == 0, (
        x.shape, packed.shape, (block_m, block_n, block_k))
    grid = (m // block_m, n // block_n, kdim // block_k)
    kernel = functools.partial(_w4a16_kernel, group_size=group_size,
                               n_k_steps=grid[2], out_dtype=x.dtype)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, k: (i, k)),
            pl.BlockSpec((block_n, block_k // 2), lambda i, j, k: (j, k)),
            pl.BlockSpec((block_n, block_k // group_size),
                         lambda i, j, k: (j, k)),
            pl.BlockSpec((block_n, block_k // group_size),
                         lambda i, j, k: (j, k)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        interpret=interpret,
    )(x, packed, scales, zeros)
