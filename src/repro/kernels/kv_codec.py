"""Shared per-block absmax int8 codec (wire compression + KV cache).

One quantization scheme, two consumers:

  - the gradient all-reduce wire format (``distributed/compression.py``,
    flat blocks of :data:`WIRE_BLOCK` elements over the raveled tensor), and
  - the quantized decode KV cache (``models/attention.py``, blocks along
    the trailing head dim so each cached (position, kv-head) row carries
    its own scales and can be dequantized per attention tile).

Both entry points share the same per-block math — ``scale = absmax/127 +
1e-12``, symmetric round-to-nearest clipped to [-127, 127] — so the codec
property suite (``tests/test_kv_codec.py``) pins one semantics for both
paths and the wire format stays bitwise-identical to the pre-extraction
``compression._enc_int8``/``_dec_int8`` at the default block size.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

# Default block for the flat/wire entry points — the historical
# compression.py constant. The KV path picks its block per head dim
# (:func:`default_kv_block`) instead.
WIRE_BLOCK = 256


def enc_int8(g: jax.Array, block: int = WIRE_BLOCK
             ) -> Tuple[jax.Array, jax.Array]:
    """Flat encode: ravel, pad to a block multiple, quantize per block.

    Returns ``(codes int8 (nb, block), scales f32 (nb,))``.
    """
    flat = g.reshape(-1)
    n = flat.shape[0]
    nb = -(-n // block)
    flat = jnp.pad(flat, (0, nb * block - n)).reshape(nb, block)
    scale = jnp.max(jnp.abs(flat), axis=1) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(flat / scale[:, None]), -127, 127)
    return q.astype(jnp.int8), scale.astype(jnp.float32)


def dec_int8(q: jax.Array, scale: jax.Array, shape,
             block: int = WIRE_BLOCK) -> jax.Array:
    """Flat decode: dequantize, drop the padding tail, restore ``shape``."""
    del block  # the codes carry the block as their trailing dim
    flat = (q.astype(jnp.float32) * scale[:, None]).reshape(-1)
    return flat[:math.prod(shape)].reshape(shape)


def default_kv_block(head_dim: int) -> int:
    """KV-cache block size for a given head dim: the largest of (128, 64,
    32) dividing it, else the head dim itself. A divisor keeps the scale
    leaf shape ``(..., head_dim // block)`` — no padding inside cache
    leaves, and the block is recoverable from the leaf shapes alone."""
    for b in (128, 64, 32):
        if head_dim % b == 0:
            return b
    return head_dim


def enc_int8_blocks(x: jax.Array, block: int
                    ) -> Tuple[jax.Array, jax.Array]:
    """Blocked encode along the trailing axis (the KV-cache layout).

    x: (..., d) with ``d % block == 0``. Returns ``(codes int8 (..., d),
    scales f32 (..., d // block))`` — codes keep x's shape, so cache
    update indexing is identical for the codes and the fp leaves.
    """
    d = x.shape[-1]
    assert d % block == 0, (x.shape, block)
    nb = d // block
    xb = x.astype(jnp.float32).reshape(x.shape[:-1] + (nb, block))
    scale = jnp.max(jnp.abs(xb), axis=-1) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(xb / scale[..., None]), -127, 127)
    return (q.astype(jnp.int8).reshape(x.shape),
            scale.astype(jnp.float32))


def dec_int8_blocks(codes: jax.Array, scales: jax.Array,
                    block: int) -> jax.Array:
    """Blocked decode: ``codes (..., d) int8, scales (..., d // block)`` →
    f32 (..., d)."""
    d = codes.shape[-1]
    nb = d // block
    cb = codes.astype(jnp.float32).reshape(codes.shape[:-1] + (nb, block))
    return (cb * scales.astype(jnp.float32)[..., None]).reshape(codes.shape)
