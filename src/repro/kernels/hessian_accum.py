"""Pallas TPU kernel: blocked Gram-matrix accumulation  H = X^T X.

Calibration hot spot of GPTQ/RPIQ stage 1 (paper eq. 9). The GPU reference
uses cuBLAS syrk on the full activation matrix; on TPU we tile the (d, d)
output into (128, 128) VMEM blocks and accumulate rank-``bn`` updates on the
MXU, streaming the token dimension through VMEM so arbitrarily long
calibration batches never materialize in VMEM at once.

Grid: (d/bi, d/bj, n/bn); the n-axis is the reduction (innermost, sequential
on TPU), so the output block stays resident in VMEM across the accumulation.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_BLOCK_D = 128   # output tile edge — MXU-aligned
DEFAULT_BLOCK_N = 512   # tokens per VMEM-resident slab


def _hessian_kernel(xi_ref, xj_ref, h_ref, *, n_steps: int):
    """One (bi, bj) output tile; accumulate over the token-grid axis."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    xi = xi_ref[...].astype(jnp.float32)        # (bn, bi)
    xj = xj_ref[...].astype(jnp.float32)        # (bn, bj)
    h_ref[...] += jax.lax.dot_general(
        xi, xj, (((0,), (0,)), ((), ())),       # contract token dim
        preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("block_d", "block_n", "interpret"))
def hessian_accum_pallas(x: jax.Array, *, block_d: int = DEFAULT_BLOCK_D,
                         block_n: int = DEFAULT_BLOCK_N,
                         interpret: bool = True) -> jax.Array:
    """H = X^T X. x: (n, d); n % block_n == 0 and d % block_d == 0
    (ops.py pads otherwise). Returns (d, d) float32."""
    n, d = x.shape
    assert n % block_n == 0 and d % block_d == 0, (x.shape, block_n, block_d)
    grid = (d // block_d, d // block_d, n // block_n)
    return pl.pallas_call(
        functools.partial(_hessian_kernel, n_steps=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, block_d), lambda i, j, k: (k, i)),
            pl.BlockSpec((block_n, block_d), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((block_d, block_d), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((d, d), jnp.float32),
        interpret=interpret,
    )(x, x)
