"""Pallas TPU kernel: fused int8-KV dequant + decode attention.

The sequel to ``w4a16_matmul`` on the serving hot path: one-token GQA
decode against an int8-quantized KV cache (``kernels/kv_codec.py`` blocked
layout). The XLA reference dequantizes the whole cache to f32 before the
score/value einsums — an HBM materialization of the full history per layer
per step. This kernel instead streams (bs, hd) int8 tiles of K/V history
into VMEM, dequantizes in VREGs (broadcasted per-block scale multiply, the
``w4a16`` move), and folds them into a flash-decode online softmax — so
int8 history never exists as a full fp16/f32 tensor in HBM:

  - grid (B, KV_heads, S/bs) with the history axis innermost (sequential
    accumulation per (batch, kv-head) cell);
  - running max ``m`` / denominator ``l`` / accumulator ``acc`` live in
    VMEM scratch across history tiles (m/l replicated over a 128-lane
    minor dim for TPU vector geometry);
  - invalid slots (kpos < 0: unwritten ring positions, padding) are masked
    to -1e30 *and* re-zeroed post-exp — a fully-masked tile otherwise
    contributes exp(-1e30 - (-1e30)) = 1 per slot;
  - queries arrive pre-scaled (hd^-0.5 folded in by the caller, matching
    ``attention_decode``'s fp16 path); softcap applies before masking.

Validated in interpret mode on CPU against ``ref.int8_kv_attention_ref``;
on TPU the same kernel lowers via Mosaic.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


DEFAULT_BLOCK_S = 128
_MIN_LANES = 128                      # f32 minor-dim tile for m/l scratch


def _kv_attn_kernel(q_ref, kc_ref, ks_ref, vc_ref, vs_ref, kpos_ref, o_ref,
                    acc_ref, m_ref, l_ref, *, kv_block: int, softcap: float,
                    n_s_steps: int, out_dtype):
    si = pl.program_id(2)

    @pl.when(si == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -1e30)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)                     # (R, hd)
    kc = kc_ref[0, :, 0, :].astype(jnp.float32)             # (bs, hd)
    ks = ks_ref[0, :, 0, :].astype(jnp.float32)             # (bs, nb)
    k = kc * jnp.repeat(ks, kv_block, axis=1)               # dequant in VREGs
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),  # q @ k.T
                            preferred_element_type=jnp.float32)  # (R, bs)
    if softcap > 0:
        s = jnp.tanh(s / softcap) * softcap
    valid = kpos_ref[0, :] >= 0                             # (bs,)
    s = jnp.where(valid[None, :], s, -1e30)

    m_prev = m_ref[...]                                     # (R, 128)
    m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    alpha = jnp.exp(m_prev - m_cur)                         # (R, 128)
    p = jnp.exp(s - m_cur[:, :1])                           # (R, bs)
    # fully-masked slots: exp(-1e30 - m) is 1 when m is still -1e30
    p = jnp.where(valid[None, :], p, 0.0)
    l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=1, keepdims=True)
    m_ref[...] = m_cur

    vc = vc_ref[0, :, 0, :].astype(jnp.float32)             # (bs, hd)
    vs = vs_ref[0, :, 0, :].astype(jnp.float32)             # (bs, nb)
    v = vc * jnp.repeat(vs, kv_block, axis=1)
    acc_ref[...] = acc_ref[...] * alpha[:, :1] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),                     # p @ v
        preferred_element_type=jnp.float32)

    @pl.when(si == n_s_steps - 1)
    def _store():
        l = jnp.maximum(l_ref[:, :1], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(out_dtype)


@functools.partial(jax.jit, static_argnames=(
    "kv_block", "softcap", "block_s", "interpret"))
def int8_kv_attention_pallas(q: jax.Array, k_codes: jax.Array,
                             k_scales: jax.Array, v_codes: jax.Array,
                             v_scales: jax.Array, kpos: jax.Array, *,
                             kv_block: int, softcap: float = 0.0,
                             block_s: int = DEFAULT_BLOCK_S,
                             interpret: bool = True) -> jax.Array:
    """q: (B, KV, R, hd) pre-scaled; k/v codes: (B, S, KV, hd) int8;
    k/v scales: (B, S, KV, hd//kv_block) f32; kpos: (B, S) int32 with -1
    marking invalid slots. Returns (B, KV, R, hd) in q.dtype.

    Shape divisibility (S % block_s == 0) is the caller's contract
    (ops.py pads with kpos=-1 sentinels).
    """
    b, kv, r, hd = q.shape
    s_len = k_codes.shape[1]
    nb = hd // kv_block
    assert k_scales.shape[-1] == nb, (k_scales.shape, kv_block)
    assert s_len % block_s == 0, (s_len, block_s)
    grid = (b, kv, s_len // block_s)
    kernel = functools.partial(_kv_attn_kernel, kv_block=kv_block,
                               softcap=softcap, n_s_steps=grid[2],
                               out_dtype=q.dtype)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, r, hd), lambda i, j, s: (i, j, 0, 0)),
            pl.BlockSpec((1, block_s, 1, hd), lambda i, j, s: (i, s, j, 0)),
            pl.BlockSpec((1, block_s, 1, nb), lambda i, j, s: (i, s, j, 0)),
            pl.BlockSpec((1, block_s, 1, hd), lambda i, j, s: (i, s, j, 0)),
            pl.BlockSpec((1, block_s, 1, nb), lambda i, j, s: (i, s, j, 0)),
            pl.BlockSpec((1, block_s), lambda i, j, s: (i, s)),
        ],
        out_specs=pl.BlockSpec((1, 1, r, hd), lambda i, j, s: (i, j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, kv, r, hd), q.dtype),
        scratch_shapes=[pltpu.VMEM((r, hd), jnp.float32),
                        pltpu.VMEM((r, _MIN_LANES), jnp.float32),
                        pltpu.VMEM((r, _MIN_LANES), jnp.float32)],
        interpret=interpret,
    )(q, k_codes, k_scales, v_codes, v_scales, kpos)
