"""Dispatch layer for the Pallas kernels.

Every op has three implementations:
  - ``*_pallas``  — the TPU kernel (interpret=True on CPU for validation),
  - ``*_ref``     — the pure-jnp oracle in :mod:`repro.kernels.ref`,
  - an XLA path (== ref) used for dry-run lowering and non-TPU backends.

``impl`` selects: "auto" (pallas-interpret only when explicitly requested on
CPU; real Mosaic lowering on TPU), "pallas", "xla". The CPU container always
*validates* the kernels in interpret mode via tests; production dispatch
defaults to XLA off-TPU so jit'd steps stay fast.

Padding contracts: callers may pass any shapes; wrappers pad to tile
multiples and slice back, so kernels keep hard divisibility asserts.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.gptq_block import gptq_block_pallas
from repro.kernels.hessian_accum import hessian_accum_pallas
from repro.kernels.quant_pack import quant_pack_pallas
from repro.kernels.selective_scan import selective_scan_pallas
from repro.kernels.w4a16_matmul import w4a16_matmul_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


# ---------------------------------------------------------------------------
# H += X^T X
# ---------------------------------------------------------------------------

def hessian_accum(x: jax.Array, *, impl: str = "auto") -> jax.Array:
    """Gram matrix X^T X with fp32 accumulation. x: (n, d)."""
    if impl == "xla" or (impl == "auto" and not _on_tpu()):
        return ref.hessian_accum_ref(x)
    n, d = x.shape
    block_n = 512 if n >= 512 else max(8, n)
    block_d = 128 if d >= 128 else d
    n_pad, d_pad = _round_up(n, block_n), _round_up(d, block_d)
    if (n_pad, d_pad) != (n, d):
        x = jnp.pad(x, ((0, n_pad - n), (0, d_pad - d)))
    H = hessian_accum_pallas(x, block_d=block_d, block_n=block_n,
                             interpret=not _on_tpu())
    return H[:d, :d]


# ---------------------------------------------------------------------------
# y = x @ dequant(W)^T      (W packed int4, grouped scales/zeros)
# ---------------------------------------------------------------------------

def w4a16_matmul(x: jax.Array, packed: jax.Array, scales: jax.Array,
                 zeros: jax.Array, *, group_size: int = 128,
                 impl: str = "auto") -> jax.Array:
    """x: (..., k); packed: (n, k//2) u8; scales/zeros: (n, k//group_size)."""
    if impl == "xla" or (impl == "auto" and not _on_tpu()):
        lead = x.shape[:-1]
        y = ref.w4a16_matmul_ref(x.reshape(-1, x.shape[-1]), packed,
                                 scales, zeros, group_size)
        return y.reshape(*lead, -1)
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    m, k = x2.shape
    n = packed.shape[0]
    block_m = 128 if m >= 128 else max(8, m)
    block_n, block_k = 128, min(512, k)
    m_pad, n_pad = _round_up(m, block_m), _round_up(n, block_n)
    if m_pad != m:
        x2 = jnp.pad(x2, ((0, m_pad - m), (0, 0)))
    if n_pad != n:
        packed = jnp.pad(packed, ((0, n_pad - n), (0, 0)))
        scales = jnp.pad(scales, ((0, n_pad - n), (0, 0)),
                         constant_values=1.0)
        zeros = jnp.pad(zeros, ((0, n_pad - n), (0, 0)))
    y = w4a16_matmul_pallas(x2, packed, scales, zeros, group_size=group_size,
                            block_m=block_m, block_n=block_n, block_k=block_k,
                            interpret=not _on_tpu())
    return y[:m, :n].reshape(*lead, n)


# ---------------------------------------------------------------------------
# quantize-to-grid + pack nibbles
# ---------------------------------------------------------------------------

def quant_pack(w: jax.Array, scales: jax.Array, zeros: jax.Array, *,
               group_size: int = 128, impl: str = "auto") -> jax.Array:
    """w: (n, k) float → (n, k//2) uint8 codes on the (scales, zeros) grid."""
    if impl == "xla" or (impl == "auto" and not _on_tpu()):
        return ref.quant_pack_ref(w, scales, zeros, group_size)
    n, k = w.shape
    block_n = 256 if n >= 256 else max(8, n)
    n_pad = _round_up(n, block_n)
    if n_pad != n:
        w = jnp.pad(w, ((0, n_pad - n), (0, 0)))
        scales = jnp.pad(scales, ((0, n_pad - n), (0, 0)), constant_values=1.0)
        zeros = jnp.pad(zeros, ((0, n_pad - n), (0, 0)))
    out = quant_pack_pallas(w, scales, zeros, group_size=group_size,
                            block_n=block_n, block_k=min(512, k),
                            interpret=not _on_tpu())
    return out[:n]


# ---------------------------------------------------------------------------
# GPTQ lazy-block sweep (stage-1 quantization hot path)
# ---------------------------------------------------------------------------

_VMEM_BUDGET_BYTES = 12 * 1024 * 1024     # conservative 16 MB minus headroom


def _gptq_vmem_bytes(block_out: int, in_dim: int, blocksize: int) -> int:
    """Per-cell residency: U (in²) + w-in/w-out tiles + the U row slab."""
    return 4 * (in_dim * in_dim + 2 * block_out * in_dim
                + blocksize * in_dim)


def gptq_block(w: jax.Array, hinv_u: jax.Array, *, bits: int = 4,
               group_size: int = 128, blocksize: int = 128,
               symmetric: bool = False, impl: str = "auto",
               block_out: int = 0, interpret: bool | None = None,
               local: bool = False):
    """One full GPTQ lazy-block sweep; the quantize-stage dispatcher.

    w: (out, in) or stacked (B, out, in); hinv_u matches with (in, in)
    trailing dims.  Returns ``(w_q, scales, zeros, err)`` shaped like the
    inputs (err: scalar per member).

    ``impl``: "pallas" forces the fused kernel (interpret-mode off-TPU),
    "xla" the ``fori_loop``-of-``dynamic_slice`` reference body in
    :mod:`repro.core.gptq`, and "auto" picks pallas on TPU only when the
    per-cell VMEM residency (U + two row tiles) fits the budget — wide
    layers (Cin ≳ 1.7k at f32) fall back to XLA instead of failing in
    Mosaic.  ``interpret`` overrides the off-TPU interpret default (the
    TPU-export path in benchmarks passes ``interpret=False`` to count the
    kernel as the single XLA op it is on hardware).

    ``local=True`` marks a per-shard call under :func:`gptq_block_sharded`'s
    ``shard_map``: the operands are device-local slabs, so "auto" skips the
    multi-device guard below and may lower the pallas kernel per shard.
    """
    squeeze = w.ndim == 2
    if squeeze:
        w, hinv_u = w[None], hinv_u[None]
    assert w.ndim == 3 and hinv_u.ndim == 3, (w.shape, hinv_u.shape)
    out_dim, in_dim = w.shape[-2:]
    assert in_dim % blocksize == 0 and blocksize % group_size == 0, \
        (w.shape, blocksize, group_size)
    bo = block_out or (128 if out_dim >= 128 else _round_up(out_dim, 8))
    # Outside shard_map, "auto" stays on XLA in multi-device processes: the
    # documented GSPMD row-sharded path (gptq.py docstring, examples/
    # distributed_quantize.py) relies on XLA partitioning the pure-XLA
    # sweep exactly, and a bare pallas_call carries no sharding rule.  The
    # sharded executor instead calls back in through gptq_block_sharded,
    # whose shard_map hands every device its own (member, Cout-tile) slab —
    # there ``local=True`` and "auto" may pick pallas per shard
    # (DESIGN.md §2.6).  Force impl="pallas" to override by hand.
    use_pallas = impl == "pallas" or (
        impl == "auto" and _on_tpu()
        and (local or jax.device_count() == 1)
        and _gptq_vmem_bytes(bo, in_dim, blocksize) <= _VMEM_BUDGET_BYTES)
    if not use_pallas:
        from repro.core.gptq import _gptq_xla_batched
        res = _gptq_xla_batched(w, hinv_u, bits=bits, group_size=group_size,
                                blocksize=blocksize, symmetric=symmetric)
        out = (res.w_q, res.scales, res.zeros, res.err)
    else:
        out_pad = _round_up(out_dim, bo)
        if out_pad != out_dim:
            w = jnp.pad(w, ((0, 0), (0, out_pad - out_dim), (0, 0)))
        w_q, scales, zeros, err_rows = gptq_block_pallas(
            w, hinv_u, bits=bits, group_size=group_size,
            blocksize=blocksize, block_out=bo, symmetric=symmetric,
            interpret=(not _on_tpu()) if interpret is None else interpret)
        out = (w_q[:, :out_dim], scales[:, :out_dim], zeros[:, :out_dim],
               jnp.sum(err_rows[:, :out_dim, 0], axis=-1))
    if squeeze:
        out = tuple(o[0] for o in out)
    return out


def gptq_block_sharded(w: jax.Array, hinv_u: jax.Array, *, mesh,
                       lane_axis: str | None, row_axis: str | None,
                       bits: int = 4, group_size: int = 128,
                       blocksize: int = 128, symmetric: bool = False,
                       impl: str = "auto", interpret: bool | None = None):
    """Mesh-sharded GPTQ sweep: one device-local :func:`gptq_block` per shard.

    w: (B, out, in) stacked group slab; hinv_u: (B, in, in).  The slab is
    laid out ``P(lane_axis, row_axis, None)`` with the Cholesky factors
    ``P(lane_axis, None, None)`` — the kernel's (member, Cout-tile) grid is
    exactly the per-shard unit, so each device sweeps its own
    ``(B/|lane|, out/|row|, in)`` slab with no communication; the only
    collective is one psum folding the per-shard Σerr² diagnostics over the
    row axis.  Exact, not approximate: lanes are independent linears and
    rows are independent given U (gptq.py).  Divisibility over the mesh
    axes is the caller's contract (``distributed.sharding.
    quant_group_sharding`` guards it); either axis may be None to shard
    one dim only.  Under ``local=True`` dispatch, "auto" may lower the
    fused pallas kernel per shard on TPU.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    if lane_axis is None and row_axis is None:
        return gptq_block(w, hinv_u, bits=bits, group_size=group_size,
                          blocksize=blocksize, symmetric=symmetric,
                          impl=impl, interpret=interpret)

    def local_sweep(wl, ul):
        w_q, scales, zeros, err = gptq_block(
            wl, ul, bits=bits, group_size=group_size, blocksize=blocksize,
            symmetric=symmetric, impl=impl, interpret=interpret, local=True)
        if row_axis is not None:
            err = jax.lax.psum(err, row_axis)
        return w_q, scales, zeros, err

    slab = P(lane_axis, row_axis, None)
    return shard_map(
        local_sweep, mesh=mesh,
        in_specs=(slab, P(lane_axis, None, None)),
        out_specs=(slab, slab, slab, P(lane_axis)),
        check_rep=False)(w, hinv_u)


# ---------------------------------------------------------------------------
# Mamba-1 selective scan
# ---------------------------------------------------------------------------

def selective_scan(u, dt, bm, cm, a_log, d_skip, h0, *, impl: str = "auto",
                   chunk: int = 256):
    """Diagonal SSM scan. See kernels/selective_scan.py for shapes.

    XLA fallback = chunked associative scan (materializes (B, chunk, d, n)
    per chunk — the §Perf cell-C baseline); pallas path keeps the state in
    VMEM (O(B·S·d) HBM traffic).
    """
    if impl == "pallas" or (impl == "auto" and _on_tpu()):
        B, S, d = u.shape
        bt = min(128, S)
        s_pad = _round_up(S, bt)
        if s_pad != S:
            padw = ((0, 0), (0, s_pad - S), (0, 0))
            u = jnp.pad(u, padw)
            dt = jnp.pad(dt, padw)
            bm = jnp.pad(bm, ((0, 0), (0, s_pad - S), (0, 0)))
            cm = jnp.pad(cm, ((0, 0), (0, s_pad - S), (0, 0)))
        y, h_last = selective_scan_pallas(u, dt, bm, cm, a_log, d_skip, h0,
                                          block_d=min(256, d), block_t=bt,
                                          interpret=not _on_tpu())
        # h_last after padded steps: padded dt=0 ⇒ a=1, b=0 ⇒ h unchanged
        return y[:, :S], h_last
    # XLA fallback: chunked diagonal recurrence (baseline memory behavior)
    from repro.models.recurrent import _chunked_recurrence
    A = -jnp.exp(a_log.astype(jnp.float32))
    a = jnp.exp(dt.astype(jnp.float32)[..., None] * A[None, None])
    b = (dt.astype(jnp.float32) * u.astype(jnp.float32))[..., None] \
        * bm.astype(jnp.float32)[:, :, None, :]
    h, h_last = _chunked_recurrence(a, b, h0.astype(jnp.float32), chunk)
    y = jnp.einsum("bsdn,bsn->bsd", h, cm.astype(jnp.float32))
    y = y + u.astype(jnp.float32) * d_skip.astype(jnp.float32)
    return y.astype(u.dtype), h_last.astype(h0.dtype)


__all__ = ["hessian_accum", "w4a16_matmul", "quant_pack", "gptq_block",
           "gptq_block_sharded", "selective_scan"]
