"""Dispatch layer for the Pallas kernels.

Every op has three implementations:
  - ``*_pallas``  — the TPU kernel (interpret=True on CPU for validation),
  - ``*_ref``     — the pure-jnp oracle in :mod:`repro.kernels.ref`,
  - an XLA path (== ref) used for dry-run lowering and non-TPU backends.

``impl`` selects: "auto" (pallas-interpret only when explicitly requested on
CPU; real Mosaic lowering on TPU), "pallas", "xla". The CPU container always
*validates* the kernels in interpret mode via tests; production dispatch
defaults to XLA off-TPU so jit'd steps stay fast.

Padding contracts: callers may pass any shapes; wrappers pad to tile
multiples and slice back, so kernels keep hard divisibility asserts.
"""
from __future__ import annotations

import contextlib
import functools
import warnings

import jax
import jax.numpy as jnp

from repro.core import faults
from repro.kernels import ref
from repro.kernels.gptq_block import gptq_block_pallas
from repro.kernels.rpiq_block import rpiq_block_pallas
from repro.kernels.hessian_accum import hessian_accum_pallas
from repro.kernels.quant_pack import quant_pack_pallas
from repro.kernels.selective_scan import selective_scan_pallas
from repro.kernels.w4a16_matmul import w4a16_matmul_pallas
from repro.kernels.kv_attention import int8_kv_attention_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


# ---------------------------------------------------------------------------
# Structured fallback accounting
#
# "auto" may resolve away from the pallas kernel because a budget guard
# (VMEM residency, HBM candidate-stack) failed. That downgrade used to be
# silent — a wide layer would quietly run the XLA body and the only
# symptom was a perf cliff. Every budget-driven downgrade now lands here:
# one warning per (op, reason) per process, plus counters that
# QuantReport.kernel_fallbacks and the serving engines' engine_stats()
# surface. Decisions happen at trace time, so a counter increments once
# per compiled entry, not once per call.
# ---------------------------------------------------------------------------

_FALLBACK_STATS: dict[str, int] = {}
_FALLBACK_WARNED: set[str] = set()
# per-caller scopes (innermost last): engine instances route their own
# fallback accounting here so two engines in one process never read each
# other's downgrades out of the module-global dict (engine_stats() would
# otherwise cross-contaminate — pinned in tests/test_supervisor.py)
_FALLBACK_SCOPES: list[dict[str, int]] = []


def fallback_stats() -> dict[str, int]:
    """Copy of the ``{"op:reason": count}`` auto→xla downgrade counters
    (process-global; per-engine views come from :func:`fallback_scope`)."""
    return dict(_FALLBACK_STATS)


def reset_fallback_stats() -> None:
    _FALLBACK_STATS.clear()
    _FALLBACK_WARNED.clear()


@contextlib.contextmanager
def fallback_scope(counters: dict[str, int]):
    """Additionally route downgrade counters into ``counters`` while the
    scope is active. Scopes nest; only the innermost receives the note —
    each engine wraps its own traces, so a downgrade is attributed to
    exactly the engine whose trace triggered it."""
    _FALLBACK_SCOPES.append(counters)
    try:
        yield counters
    finally:
        _FALLBACK_SCOPES.pop()


def _note_fallback(op: str, reason: str) -> None:
    key = f"{op}:{reason}"
    _FALLBACK_STATS[key] = _FALLBACK_STATS.get(key, 0) + 1
    if _FALLBACK_SCOPES:
        scope = _FALLBACK_SCOPES[-1]
        scope[key] = scope.get(key, 0) + 1
    if key not in _FALLBACK_WARNED:
        _FALLBACK_WARNED.add(key)
        warnings.warn(
            f"kernels.ops.{op}: impl='auto' fell back to the XLA path "
            f"({reason}); force impl='pallas' to override, or retile",
            RuntimeWarning, stacklevel=3)


# ---------------------------------------------------------------------------
# H += X^T X
# ---------------------------------------------------------------------------

def hessian_accum(x: jax.Array, *, impl: str = "auto") -> jax.Array:
    """Gram matrix X^T X with fp32 accumulation. x: (n, d)."""
    if impl == "xla" or (impl == "auto" and not _on_tpu()):
        return ref.hessian_accum_ref(x)
    n, d = x.shape
    block_n = 512 if n >= 512 else max(8, n)
    block_d = 128 if d >= 128 else d
    n_pad, d_pad = _round_up(n, block_n), _round_up(d, block_d)
    if (n_pad, d_pad) != (n, d):
        x = jnp.pad(x, ((0, n_pad - n), (0, d_pad - d)))
    H = hessian_accum_pallas(x, block_d=block_d, block_n=block_n,
                             interpret=not _on_tpu())
    return H[:d, :d]


# ---------------------------------------------------------------------------
# y = x @ dequant(W)^T      (W packed int4, grouped scales/zeros)
# ---------------------------------------------------------------------------

# The serving engines install cfg.serve.w4a16_impl here (a trace-time
# default, read when impl is not passed explicitly): every QuantizedTensor
# dense on the decode path flows through models/linear.dense, which cannot
# thread an impl argument without widening every model signature. Callers
# that jit must key their compiled entries on the impl they installed —
# serving/engine.py and serving/scheduler.py build their jitted steps per
# engine instance with the knob fixed at construction (docs/SERVING.md).
_W4A16_DEFAULT_IMPL = "auto"


@contextlib.contextmanager
def w4a16_default_impl(impl: str):
    """Scoped override of the w4a16_matmul default backend (trace-time)."""
    global _W4A16_DEFAULT_IMPL
    assert impl in ("auto", "pallas", "xla"), impl
    prev = _W4A16_DEFAULT_IMPL
    _W4A16_DEFAULT_IMPL = impl
    try:
        yield
    finally:
        _W4A16_DEFAULT_IMPL = prev


def _w4a16_vmem_bytes(block_m: int, block_n: int, block_k: int) -> int:
    """Per-cell residency upper bound: x + out tiles f32, packed u8 tile,
    and the dequantized weight tile (f32) the kernel materializes."""
    return (4 * (block_m * block_k + block_m * block_n
                 + 2 * block_n * block_k) + block_n * block_k // 2)


def w4a16_matmul(x: jax.Array, packed: jax.Array, scales: jax.Array,
                 zeros: jax.Array, *, group_size: int = 128,
                 impl: str | None = None) -> jax.Array:
    """x: (..., k); packed: (n, k//2) u8; scales/zeros: (n, k//group_size)."""
    if impl is None:
        impl = _W4A16_DEFAULT_IMPL
    if impl == "xla" or (impl == "auto" and not _on_tpu()):
        lead = x.shape[:-1]
        y = ref.w4a16_matmul_ref(x.reshape(-1, x.shape[-1]), packed,
                                 scales, zeros, group_size)
        return y.reshape(*lead, -1)
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    m, k = x2.shape
    n = packed.shape[0]
    block_m = 128 if m >= 128 else max(8, m)
    block_n, block_k = 128, min(512, k)
    if (impl == "auto" and _w4a16_vmem_bytes(block_m, block_n, block_k)
            > _VMEM_BUDGET_BYTES):
        _note_fallback("w4a16_matmul", "vmem-budget")
        y = ref.w4a16_matmul_ref(x2, packed, scales, zeros, group_size)
        return y.reshape(*lead, -1)
    # fault site: an injected Mosaic/lowering failure at the moment the
    # fused kernel would be traced — drives the serving engines' runtime
    # pallas→xla degradation path (docs/SERVING.md §Failure handling)
    faults.fire("kernels.pallas_dispatch")
    m_pad, n_pad = _round_up(m, block_m), _round_up(n, block_n)
    if m_pad != m:
        x2 = jnp.pad(x2, ((0, m_pad - m), (0, 0)))
    if n_pad != n:
        packed = jnp.pad(packed, ((0, n_pad - n), (0, 0)))
        scales = jnp.pad(scales, ((0, n_pad - n), (0, 0)),
                         constant_values=1.0)
        zeros = jnp.pad(zeros, ((0, n_pad - n), (0, 0)))
    y = w4a16_matmul_pallas(x2, packed, scales, zeros, group_size=group_size,
                            block_m=block_m, block_n=block_n, block_k=block_k,
                            interpret=not _on_tpu())
    return y[:m, :n].reshape(*lead, n)


# ---------------------------------------------------------------------------
# decode attention against an int8 KV cache (fused dequant)
# ---------------------------------------------------------------------------

# Same contract as _W4A16_DEFAULT_IMPL: the serving engines install
# cfg.serve.kv_impl here at trace time, because attention_decode sits under
# the jitted decode step and cannot thread an impl argument without
# widening every model signature. Engines key compiled entries on the
# installed impl (docs/SERVING.md).
_KV_ATTN_DEFAULT_IMPL = "auto"


@contextlib.contextmanager
def kv_attn_default_impl(impl: str):
    """Scoped override of the int8_kv_attention default backend."""
    global _KV_ATTN_DEFAULT_IMPL
    assert impl in ("auto", "pallas", "xla"), impl
    prev = _KV_ATTN_DEFAULT_IMPL
    _KV_ATTN_DEFAULT_IMPL = impl
    try:
        yield
    finally:
        _KV_ATTN_DEFAULT_IMPL = prev


def _kv_attn_vmem_bytes(block_s: int, r: int, hd: int, nb: int) -> int:
    """Per-cell residency: q/acc/out tiles + two dequantized (bs, hd) K/V
    tiles f32, the int8 code tiles, scale tiles, and the m/l scratch."""
    return (4 * (3 * r * hd + 2 * block_s * hd + 2 * block_s * nb
                 + 2 * r * 128 + r * block_s)
            + 2 * block_s * hd)


def int8_kv_attention(q: jax.Array, k_codes: jax.Array, k_scales: jax.Array,
                      v_codes: jax.Array, v_scales: jax.Array,
                      kpos: jax.Array, *, kv_block: int,
                      softcap: float = 0.0,
                      impl: str | None = None) -> jax.Array:
    """One-token GQA decode against an int8 KV cache (kernels/kv_codec.py).

    q: (B, KV, R, hd) pre-scaled queries; k/v codes: (B, S, KV, hd) int8;
    k/v scales: (B, S, KV, hd//kv_block) f32; kpos: (B, S) int32 slot
    positions, -1 = invalid (causal/window validity is encoded by the
    caller). Returns (B, KV, R, hd) in q.dtype.
    """
    if impl is None:
        impl = _KV_ATTN_DEFAULT_IMPL
    assert impl in ("auto", "pallas", "xla"), impl
    if impl == "xla" or (impl == "auto" and not _on_tpu()):
        return ref.int8_kv_attention_ref(q, k_codes, k_scales, v_codes,
                                         v_scales, kpos, kv_block, softcap)
    b, s, kv, hd = k_codes.shape
    r = q.shape[2]
    nb = hd // kv_block
    block_s = 128 if s >= 128 else _round_up(s, 8)
    if (impl == "auto" and _kv_attn_vmem_bytes(block_s, max(r, 8), hd, nb)
            > _VMEM_BUDGET_BYTES):
        _note_fallback("int8_kv_attention", "vmem-budget")
        return ref.int8_kv_attention_ref(q, k_codes, k_scales, v_codes,
                                         v_scales, kpos, kv_block, softcap)
    # fault site shared with w4a16_matmul: an injected lowering failure at
    # the moment the fused kernel would be traced drives the engines'
    # pallas→xla degradation path (docs/SERVING.md §Failure handling)
    faults.fire("kernels.pallas_dispatch")
    s_pad = _round_up(s, block_s)
    if s_pad != s:
        k_codes = jnp.pad(k_codes, ((0, 0), (0, s_pad - s), (0, 0), (0, 0)))
        v_codes = jnp.pad(v_codes, ((0, 0), (0, s_pad - s), (0, 0), (0, 0)))
        k_scales = jnp.pad(k_scales,
                           ((0, 0), (0, s_pad - s), (0, 0), (0, 0)))
        v_scales = jnp.pad(v_scales,
                           ((0, 0), (0, s_pad - s), (0, 0), (0, 0)))
        kpos = jnp.pad(kpos, ((0, 0), (0, s_pad - s)), constant_values=-1)
    r_pad = _round_up(r, 8)
    if r_pad != r:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, r_pad - r), (0, 0)))
    y = int8_kv_attention_pallas(q, k_codes, k_scales, v_codes, v_scales,
                                 kpos, kv_block=kv_block, softcap=softcap,
                                 block_s=block_s, interpret=not _on_tpu())
    return y[:, :, :r]


# ---------------------------------------------------------------------------
# quantize-to-grid + pack nibbles
# ---------------------------------------------------------------------------

def quant_pack(w: jax.Array, scales: jax.Array, zeros: jax.Array, *,
               group_size: int = 128, impl: str = "auto") -> jax.Array:
    """w: (n, k) float → (n, k//2) uint8 codes on the (scales, zeros) grid."""
    if impl == "xla" or (impl == "auto" and not _on_tpu()):
        return ref.quant_pack_ref(w, scales, zeros, group_size)
    n, k = w.shape
    block_n = 256 if n >= 256 else max(8, n)
    n_pad = _round_up(n, block_n)
    if n_pad != n:
        w = jnp.pad(w, ((0, n_pad - n), (0, 0)))
        scales = jnp.pad(scales, ((0, n_pad - n), (0, 0)), constant_values=1.0)
        zeros = jnp.pad(zeros, ((0, n_pad - n), (0, 0)))
    out = quant_pack_pallas(w, scales, zeros, group_size=group_size,
                            block_n=block_n, block_k=min(512, k),
                            interpret=not _on_tpu())
    return out[:n]


# ---------------------------------------------------------------------------
# GPTQ lazy-block sweep (stage-1 quantization hot path)
# ---------------------------------------------------------------------------

_VMEM_BUDGET_BYTES = 12 * 1024 * 1024     # conservative 16 MB minus headroom


def _gptq_vmem_bytes(block_out: int, in_dim: int, blocksize: int) -> int:
    """Per-cell residency: U (in²) + w-in/w-out tiles + the U row slab."""
    return 4 * (in_dim * in_dim + 2 * block_out * in_dim
                + blocksize * in_dim)


def gptq_block(w: jax.Array, hinv_u: jax.Array, *, bits: int = 4,
               group_size: int = 128, blocksize: int = 128,
               symmetric: bool = False, impl: str = "auto",
               block_out: int = 0, interpret: bool | None = None,
               local: bool = False):
    """One full GPTQ lazy-block sweep; the quantize-stage dispatcher.

    w: (out, in) or stacked (B, out, in); hinv_u matches with (in, in)
    trailing dims.  Returns ``(w_q, scales, zeros, err)`` shaped like the
    inputs (err: scalar per member).

    ``impl``: "pallas" forces the fused kernel (interpret-mode off-TPU),
    "xla" the ``fori_loop``-of-``dynamic_slice`` reference body in
    :mod:`repro.core.gptq`, and "auto" picks pallas on TPU only when the
    per-cell VMEM residency (U + two row tiles) fits the budget — wide
    layers (Cin ≳ 1.7k at f32) fall back to XLA instead of failing in
    Mosaic.  ``interpret`` overrides the off-TPU interpret default (the
    TPU-export path in benchmarks passes ``interpret=False`` to count the
    kernel as the single XLA op it is on hardware).

    ``local=True`` marks a per-shard call under :func:`gptq_block_sharded`'s
    ``shard_map``: the operands are device-local slabs, so "auto" skips the
    multi-device guard below and may lower the pallas kernel per shard.
    """
    squeeze = w.ndim == 2
    if squeeze:
        w, hinv_u = w[None], hinv_u[None]
    assert w.ndim == 3 and hinv_u.ndim == 3, (w.shape, hinv_u.shape)
    out_dim, in_dim = w.shape[-2:]
    assert in_dim % blocksize == 0 and blocksize % group_size == 0, \
        (w.shape, blocksize, group_size)
    bo = block_out or (128 if out_dim >= 128 else _round_up(out_dim, 8))
    # Outside shard_map, "auto" stays on XLA in multi-device processes: the
    # documented GSPMD row-sharded path (gptq.py docstring, examples/
    # distributed_quantize.py) relies on XLA partitioning the pure-XLA
    # sweep exactly, and a bare pallas_call carries no sharding rule.  The
    # sharded executor instead calls back in through gptq_block_sharded,
    # whose shard_map hands every device its own (member, Cout-tile) slab —
    # there ``local=True`` and "auto" may pick pallas per shard
    # (DESIGN.md §2.6).  Force impl="pallas" to override by hand.
    use_pallas = impl == "pallas"
    if (impl == "auto" and _on_tpu()
            and (local or jax.device_count() == 1)):
        if _gptq_vmem_bytes(bo, in_dim, blocksize) <= _VMEM_BUDGET_BYTES:
            use_pallas = True
        else:
            _note_fallback("gptq_block", "vmem-budget")
    if not use_pallas:
        from repro.core.gptq import _gptq_xla_batched
        res = _gptq_xla_batched(w, hinv_u, bits=bits, group_size=group_size,
                                blocksize=blocksize, symmetric=symmetric)
        out = (res.w_q, res.scales, res.zeros, res.err)
    else:
        out_pad = _round_up(out_dim, bo)
        if out_pad != out_dim:
            w = jnp.pad(w, ((0, 0), (0, out_pad - out_dim), (0, 0)))
        w_q, scales, zeros, err_rows = gptq_block_pallas(
            w, hinv_u, bits=bits, group_size=group_size,
            blocksize=blocksize, block_out=bo, symmetric=symmetric,
            interpret=(not _on_tpu()) if interpret is None else interpret)
        out = (w_q[:, :out_dim], scales[:, :out_dim], zeros[:, :out_dim],
               jnp.sum(err_rows[:, :out_dim, 0], axis=-1))
    if squeeze:
        out = tuple(o[0] for o in out)
    return out


def _axes_prod(mesh, axis) -> int:
    """Device count along a lane placement: str, tuple of axis names
    (expert-stacked groups shard lanes over e.g. ("expert", "data") —
    distributed/sharding.quant_group_sharding), or None → 1."""
    if axis is None:
        return 1
    axes = axis if isinstance(axis, tuple) else (axis,)
    out = 1
    for a in axes:
        out *= int(mesh.shape[a])
    return out


def gptq_block_sharded(w: jax.Array, hinv_u: jax.Array, *, mesh,
                       lane_axis=None, row_axis: str | None = None,
                       bits: int = 4, group_size: int = 128,
                       blocksize: int = 128, symmetric: bool = False,
                       impl: str = "auto", interpret: bool | None = None):
    """Mesh-sharded GPTQ sweep: one device-local :func:`gptq_block` per shard.

    w: (B, out, in) stacked group slab; hinv_u: (B, in, in).  The slab is
    laid out ``P(lane_axis, row_axis, None)`` with the Cholesky factors
    ``P(lane_axis, None, None)`` — ``lane_axis`` may be a tuple of mesh
    axes (expert-stacked groups shard lanes over the ``("expert",
    "data")`` product); the kernel's (member, Cout-tile) grid is
    exactly the per-shard unit, so each device sweeps its own
    ``(B/|lane|, out/|row|, in)`` slab with no communication; the only
    collective is one psum folding the per-shard Σerr² diagnostics over the
    row axis.  Exact, not approximate: lanes are independent linears and
    rows are independent given U (gptq.py).  Divisibility over the mesh
    axes is the caller's contract (``distributed.sharding.
    quant_group_sharding`` guards it); either axis may be None to shard
    one dim only.  Under ``local=True`` dispatch, "auto" may lower the
    fused pallas kernel per shard on TPU.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    if lane_axis is None and row_axis is None:
        return gptq_block(w, hinv_u, bits=bits, group_size=group_size,
                          blocksize=blocksize, symmetric=symmetric,
                          impl=impl, interpret=interpret)

    def local_sweep(wl, ul):
        w_q, scales, zeros, err = gptq_block(
            wl, ul, bits=bits, group_size=group_size, blocksize=blocksize,
            symmetric=symmetric, impl=impl, interpret=interpret, local=True)
        if row_axis is not None:
            err = jax.lax.psum(err, row_axis)
        return w_q, scales, zeros, err

    slab = P(lane_axis, row_axis, None)
    return shard_map(
        local_sweep, mesh=mesh,
        in_specs=(slab, P(lane_axis, None, None)),
        out_specs=(slab, slab, slab, P(lane_axis)),
        check_rep=False)(w, hinv_u)


# ---------------------------------------------------------------------------
# RPIQ closed-loop refinement (stage-2 hot path)
# ---------------------------------------------------------------------------


def _rpiq_vmem_bytes(block_out: int, in_dim: int, n: int,
                     block_size: int) -> int:
    """Per-cell residency: five (block_out, in) tiles (W₀, working W, round
    candidate, expanded scales/zeros) + the (n, in) instance slab + two
    (n, block_out) output slabs + the (in, bs) inverse stack."""
    return 4 * (5 * block_out * in_dim + n * in_dim
                + 2 * n * block_out + block_size * in_dim)


_RPIQ_HBM_BUDGET_BYTES = 2 * 1024 ** 3   # per-dispatch candidate-stack cap


def _rpiq_hbm_bytes(b: int, out_pad: int, in_dim: int, t_max: int) -> int:
    """HBM footprint of the deferred-bookkeeping candidate stack: the
    kernel materializes all t_max+1 per-round projections (B, t_max+1,
    out, in) — an O(t_max) inflation the XLA body does not have, so
    "auto" must budget it separately from VMEM."""
    return 4 * b * (t_max + 1) * out_pad * in_dim


def _rpiq_select(hist_raw: jax.Array, pls_raw: jax.Array,
                 wp_all: jax.Array, t_max: int, early_stop: bool):
    """Deferred closed-loop bookkeeping over the raw round trajectory.

    Replays :func:`repro.core.rpiq._rpiq_core`'s while-loop semantics from
    the (B, t_max+1) raw Γ / projected-loss sums: round 1 always runs,
    round r+1 runs iff round r did not trip the stop predicate
    ``Γ^(r) >= Γ^(r-1)·(1-1e-6)``; non-executed rounds mask to +inf in the
    history; the returned candidate is the FIRST executed round achieving
    the minimum projected loss (strict-improvement semantics — index 0 is
    the stage-1 solution itself, so "no round improved" selects it).
    """
    b = hist_raw.shape[0]
    if early_stop:
        stop = hist_raw[:, 1:] >= hist_raw[:, :-1] * (1.0 - 1e-6)  # (B, T)
    else:
        stop = jnp.zeros((b, t_max), bool)
    live = jnp.cumprod(jnp.logical_not(stop).astype(jnp.int32), axis=1)
    exec_mask = jnp.concatenate(
        [jnp.ones((b, 1), jnp.int32), live[:, :-1]], axis=1).astype(bool)
    iters = jnp.sum(exec_mask, axis=1).astype(jnp.int32)
    keep = jnp.concatenate([jnp.ones((b, 1), bool), exec_mask], axis=1)
    hist = jnp.where(keep, hist_raw, jnp.inf)
    cand = jnp.where(keep, pls_raw, jnp.inf)
    best = jnp.argmin(cand, axis=1)              # first occurrence of min
    proj_loss = jnp.take_along_axis(cand, best[:, None], axis=1)[:, 0]
    w_q = jnp.take_along_axis(wp_all, best[:, None, None, None],
                              axis=1)[:, 0]
    return w_q, hist, proj_loss, iters


def rpiq_block(w_init: jax.Array, w_fp: jax.Array, x_last: jax.Array,
               hinv_blocks: jax.Array, scales: jax.Array, zeros: jax.Array,
               *, bits: int = 4, group_size: int = 128,
               block_size: int = 128, alpha: float = 0.01, t_max: int = 5,
               early_stop: bool = True, symmetric: bool = False,
               impl: str = "auto", block_out: int = 0,
               interpret: bool | None = None, local: bool = False,
               loss_psum_axis: str | None = None):
    """The full stage-2 closed loop; the refinement-stage dispatcher.

    w_init/w_fp: (out, in) or stacked (B, out, in); x_last matches with
    (n, in) trailing dims, hinv_blocks with (M, bs, bs) — the explicit
    blockwise curvature inverses from
    :func:`repro.core.rpiq._block_curvature_inv` (shared by both
    backends, so eq. 13–14 rounds identically).  Returns the RPIQResult
    tuple ``(w_q, w_cont, loss_history, proj_loss, iters_run)`` shaped
    like the inputs.

    ``impl``: "pallas" forces the fused kernel (interpret-mode off-TPU),
    "xla" the ``while_loop``-of-``fori_loop`` reference body in
    :mod:`repro.core.rpiq`, and "auto" picks pallas on TPU only when the
    per-cell VMEM residency fits the budget — wide layers fall back to
    XLA instead of failing in Mosaic.  ``t_max == 0`` always takes the
    XLA body (the closed loop is empty; nothing to fuse).  ``interpret``
    overrides the off-TPU interpret default (the TPU-export path in
    benchmarks passes ``interpret=False``).

    ``local=True`` marks a per-shard call under
    :func:`rpiq_block_sharded`'s ``shard_map`` (same contract as
    ``gptq_block``); ``loss_psum_axis`` names the mesh axis to fold the
    per-shard Γ/projected-loss partials over BEFORE the deferred
    early-stop/best bookkeeping — the row-sharded twin's one collective.
    """
    squeeze = w_init.ndim == 2
    if squeeze:
        w_init, w_fp, x_last, hinv_blocks, scales, zeros = (
            a[None] for a in (w_init, w_fp, x_last, hinv_blocks, scales,
                              zeros))
    assert w_init.ndim == 3 and hinv_blocks.ndim == 4, \
        (w_init.shape, hinv_blocks.shape)
    b, out_dim, in_dim = w_init.shape
    n = x_last.shape[-2]
    assert in_dim % block_size == 0 and block_size % group_size == 0, \
        (w_init.shape, block_size, group_size)
    bo = block_out or (128 if out_dim >= 128 else _round_up(out_dim, 8))
    # Same multi-device guard as gptq_block: outside shard_map, "auto"
    # stays on XLA in multi-device processes (GSPMD partitions the pure-XLA
    # loop exactly; a bare pallas_call carries no sharding rule) — the
    # sharded executor calls back in through rpiq_block_sharded instead.
    use_pallas = t_max >= 1 and impl == "pallas"
    if (t_max >= 1 and impl == "auto" and _on_tpu()
            and (local or jax.device_count() == 1)):
        if _rpiq_vmem_bytes(bo, in_dim, n, block_size) > _VMEM_BUDGET_BYTES:
            _note_fallback("rpiq_block", "vmem-budget")
        elif (_rpiq_hbm_bytes(b, _round_up(out_dim, bo), in_dim, t_max)
              > _RPIQ_HBM_BUDGET_BYTES):
            _note_fallback("rpiq_block", "hbm-budget")
        else:
            use_pallas = True
    if not use_pallas:
        if loss_psum_axis is not None:
            # only reachable when a sharded caller forced impl="xla" with
            # rows still split — the twin prevents this (it gathers rows
            # for XLA-resolved backends), but keep the seam total
            raise ValueError("loss_psum_axis requires the pallas backend: "
                             "the XLA body early-stops on per-lane "
                             "data-dependent trip counts, which cannot "
                             "psum in lockstep across row shards")
        from repro.core.rpiq import _rpiq_xla_batched
        res = _rpiq_xla_batched(w_init, w_fp, x_last, hinv_blocks, scales,
                                zeros, bits=bits, group_size=group_size,
                                block_size=block_size, alpha=alpha,
                                t_max=t_max, early_stop=early_stop,
                                symmetric=symmetric)
        out = tuple(res)
    else:
        xf = x_last.astype(jnp.float32)
        # Y_orig = X W_fp^T once per member (the single-instance reference)
        y_orig = jnp.einsum("bni,boi->bno", xf, w_fp.astype(jnp.float32))
        # grid expanded to column resolution ONCE (hoisted jnp.repeat)
        s_full = jnp.repeat(scales.astype(jnp.float32), group_size, axis=-1)
        z_full = jnp.repeat(zeros.astype(jnp.float32), group_size, axis=-1)
        w0 = w_init.astype(jnp.float32)
        out_pad = _round_up(out_dim, bo)
        if out_pad != out_dim:
            # padded rows: w=0 on a (s=1, z=0) grid — projections and
            # residual contributions stay exactly 0, so real rows and the
            # Γ partial sums are unperturbed
            pad = ((0, 0), (0, out_pad - out_dim), (0, 0))
            w0 = jnp.pad(w0, pad)
            s_full = jnp.pad(s_full, pad, constant_values=1.0)
            z_full = jnp.pad(z_full, pad)
            y_orig = jnp.pad(y_orig, ((0, 0), (0, 0),
                                      (0, out_pad - out_dim)))
        hinv_flat = hinv_blocks.astype(jnp.float32).reshape(
            b, in_dim, block_size)
        w_cont, wp_all, _y_q, hist_raw, pls_raw = rpiq_block_pallas(
            w0, y_orig, xf, hinv_flat, s_full, z_full, bits=bits,
            group_size=group_size, block_size=block_size, alpha=alpha,
            t_max=t_max, symmetric=symmetric, block_out=bo,
            interpret=(not _on_tpu()) if interpret is None else interpret)
        hist_raw, pls_raw = hist_raw[:, 0], pls_raw[:, 0]
        if loss_psum_axis is not None:
            # fold row-shard partials into the global Γ trajectory — every
            # shard then replays identical bookkeeping for its rows
            hist_raw = jax.lax.psum(hist_raw, loss_psum_axis)
            pls_raw = jax.lax.psum(pls_raw, loss_psum_axis)
        w_q, hist, proj_loss, iters = _rpiq_select(hist_raw, pls_raw,
                                                   wp_all, t_max,
                                                   early_stop)
        out = (w_q[:, :out_dim], w_cont[:, :out_dim], hist, proj_loss,
               iters)
    if squeeze:
        out = tuple(o[0] for o in out)
    return out


def rpiq_block_sharded(w_init: jax.Array, w_fp: jax.Array,
                       x_last: jax.Array, h_damped: jax.Array,
                       scales: jax.Array, zeros: jax.Array, *,
                       h_count: jax.Array | None = None,
                       x_count: jax.Array | None = None, mesh=None,
                       lane_axis=None,
                       row_axis: str | None = None, bits: int = 4,
                       group_size: int = 128, block_size: int = 128,
                       alpha: float = 0.01, t_max: int = 5,
                       early_stop: bool = True, symmetric: bool = False,
                       exact_gram: bool = False, impl: str = "auto",
                       interpret: bool | None = None):
    """Mesh-sharded stage-2 refinement: the :func:`gptq_block_sharded` twin.

    w_init/w_fp: (B, out, in) stacked group slabs; h_damped: (B, in, in);
    scales/zeros: (B, out, groups).  Lanes lay out over ``lane_axis``
    exactly like stage 1 (members are independent linears, zero
    collectives).  Rows differ from the GPTQ sweep: the closed loop's Γ,
    early stop and best-projection choice are global over Cout, so a row
    shard is NOT an independent unit —

      - with the fused kernel the rounds run unconditionally and the
        bookkeeping is deferred (rpiq_block), so row sharding stays exact
        at the cost of ONE psum of the (B, t_max+1) loss partials per
        stage dispatch (``loss_psum_axis``);
      - the XLA body's while-loop trip count is data-dependent per lane —
        a mid-loop psum would have shards disagree on trip counts — so
        when the per-shard dispatch resolves to XLA the twin drops the
        row axis (the shard_map in_specs then gather rows) and shards
        lanes only.

    The blockwise curvature pre-factor runs lane-local inside the
    shard_map (each lane's Cholesky where its rows run, replicated over
    the row axis like the stage-1 factor — DESIGN.md §2.6).  Either axis
    may be None; both None degrades to the single-device dispatcher.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    from repro.core.rpiq import rpiq_refine_batched

    kw = dict(bits=bits, group_size=group_size, block_size=block_size,
              alpha=alpha, t_max=t_max, early_stop=early_stop,
              symmetric=symmetric, exact_gram=exact_gram)
    b, out_dim, in_dim = w_init.shape
    n = x_last.shape[-2]
    if row_axis is not None:
        rows_local = out_dim // int(mesh.shape[row_axis])
        lanes_local = b // _axes_prod(mesh, lane_axis)
        bo = 128 if rows_local >= 128 else _round_up(max(rows_local, 1), 8)
        pallas_local = t_max >= 1 and impl == "pallas"
        if t_max >= 1 and impl == "auto" and _on_tpu():
            if (_rpiq_vmem_bytes(bo, in_dim, n, block_size)
                    <= _VMEM_BUDGET_BYTES
                    and _rpiq_hbm_bytes(lanes_local,
                                        _round_up(rows_local, bo),
                                        in_dim, t_max)
                    <= _RPIQ_HBM_BUDGET_BYTES):
                pallas_local = True
            else:
                # budget-rejected per-shard kernel: the twin must also give
                # up ROW sharding (the XLA body cannot psum mid-loop), so
                # this downgrade costs layout, not just backend — record it
                _note_fallback("rpiq_block_sharded", "row-axis-dropped")
        if not pallas_local:
            row_axis = None
    if lane_axis is None and row_axis is None:
        return tuple(rpiq_refine_batched(
            w_init, w_fp, x_last, h_damped, scales, zeros, h_count=h_count,
            x_count=x_count, impl=impl, interpret=interpret, **kw))

    slab = P(lane_axis, row_axis, None)
    lane3 = P(lane_axis, None, None)
    in_specs = [slab, slab, lane3, lane3, slab, slab]
    args = [w_init, w_fp, x_last, h_damped, scales, zeros]
    if h_count is not None:
        in_specs.append(P(lane_axis))
        args.append(h_count)
    if x_count is not None:
        in_specs.append(P(lane_axis))
        args.append(x_count)

    def local_refine(*a):
        wl, wfl, xl, hdl, sl, zl = a[:6]
        rest = list(a[6:])
        hcl = rest.pop(0) if h_count is not None else None
        xcl = rest.pop(0) if x_count is not None else None
        return tuple(rpiq_refine_batched(
            wl, wfl, xl, hdl, sl, zl, h_count=hcl, x_count=xcl, impl=impl,
            interpret=interpret, local=True, loss_psum_axis=row_axis, **kw))

    # loss history / proj_loss / iters are identical across row shards
    # after the psum fold — lane-sharded only (check_rep off, as in the
    # stage-1 twin)
    out_specs = (slab, slab, P(lane_axis, None), P(lane_axis),
                 P(lane_axis))
    return shard_map(local_refine, mesh=mesh, in_specs=tuple(in_specs),
                     out_specs=out_specs, check_rep=False)(*args)


# ---------------------------------------------------------------------------
# Mamba-1 selective scan
# ---------------------------------------------------------------------------

def selective_scan(u, dt, bm, cm, a_log, d_skip, h0, *, impl: str = "auto",
                   chunk: int = 256):
    """Diagonal SSM scan. See kernels/selective_scan.py for shapes.

    XLA fallback = chunked associative scan (materializes (B, chunk, d, n)
    per chunk — the §Perf cell-C baseline); pallas path keeps the state in
    VMEM (O(B·S·d) HBM traffic).
    """
    if impl == "pallas" or (impl == "auto" and _on_tpu()):
        B, S, d = u.shape
        bt = min(128, S)
        s_pad = _round_up(S, bt)
        if s_pad != S:
            padw = ((0, 0), (0, s_pad - S), (0, 0))
            u = jnp.pad(u, padw)
            dt = jnp.pad(dt, padw)
            bm = jnp.pad(bm, ((0, 0), (0, s_pad - S), (0, 0)))
            cm = jnp.pad(cm, ((0, 0), (0, s_pad - S), (0, 0)))
        y, h_last = selective_scan_pallas(u, dt, bm, cm, a_log, d_skip, h0,
                                          block_d=min(256, d), block_t=bt,
                                          interpret=not _on_tpu())
        # h_last after padded steps: padded dt=0 ⇒ a=1, b=0 ⇒ h unchanged
        return y[:, :S], h_last
    # XLA fallback: chunked diagonal recurrence (baseline memory behavior)
    from repro.models.recurrent import _chunked_recurrence
    A = -jnp.exp(a_log.astype(jnp.float32))
    a = jnp.exp(dt.astype(jnp.float32)[..., None] * A[None, None])
    b = (dt.astype(jnp.float32) * u.astype(jnp.float32))[..., None] \
        * bm.astype(jnp.float32)[:, :, None, :]
    h, h_last = _chunked_recurrence(a, b, h0.astype(jnp.float32), chunk)
    y = jnp.einsum("bsdn,bsn->bsd", h, cm.astype(jnp.float32))
    y = y + u.astype(jnp.float32) * d_skip.astype(jnp.float32)
    return y.astype(u.dtype), h_last.astype(h0.dtype)


__all__ = ["hessian_accum", "w4a16_matmul", "w4a16_default_impl",
           "int8_kv_attention", "kv_attn_default_impl",
           "quant_pack", "gptq_block", "gptq_block_sharded", "rpiq_block",
           "rpiq_block_sharded", "selective_scan", "fallback_stats",
           "reset_fallback_stats"]
