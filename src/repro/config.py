"""Configuration system for the repro framework.

Plain dataclasses + dict overrides + a tiny CLI layer. No external deps.

Every launchable entry point takes ``--arch <id>`` (resolved through
``repro.configs.registry``) plus ``key=value`` dotted overrides, e.g.::

    python -m repro.launch.dryrun --arch minicpm-2b --shape train_4k \
        parallel.sp=true quant.bits=4
"""
from __future__ import annotations

import dataclasses
import json
from dataclasses import dataclass, field, fields, is_dataclass
from typing import Any, Dict, List, Optional, Tuple


# ---------------------------------------------------------------------------
# Model configuration
# ---------------------------------------------------------------------------

@dataclass
class MoEConfig:
    num_experts: int = 0            # 0 => dense
    top_k: int = 0
    d_ff_expert: int = 0            # per-expert hidden dim
    num_shared_experts: int = 0     # deepseek-style always-on experts
    first_dense_layers: int = 0     # leading layers that stay dense
    capacity_factor: float = 1.25
    aux_loss_weight: float = 0.001
    router_dtype: str = "float32"


@dataclass
class MLAConfig:
    """Multi-head latent attention (deepseek-v3)."""
    enabled: bool = False
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass
class SSMConfig:
    """Mamba-1 block configuration."""
    enabled: bool = False
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0                # 0 => ceil(d_model/16)


@dataclass
class RGLRUConfig:
    """RG-LRU recurrent block (recurrentgemma)."""
    enabled: bool = False
    lru_width: int = 0              # 0 => d_model
    conv1d_width: int = 4


@dataclass
class ModelConfig:
    name: str = "tiny"
    family: str = "dense"           # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int = 2
    d_model: int = 128
    num_heads: int = 4
    num_kv_heads: int = 4
    head_dim: int = 0               # 0 => d_model // num_heads
    d_ff: int = 512
    vocab_size: int = 512
    max_seq_len: int = 8192

    # block pattern: list of block kinds, cycled over the layer stack.
    # kinds: "attn", "swa", "local", "rglru", "mamba", ("mla" via mla.enabled)
    block_pattern: Tuple[str, ...] = ("attn",)
    window_size: int = 0            # sliding/local attention window (0 = full)

    norm: str = "rmsnorm"           # rmsnorm | layernorm
    act: str = "silu"               # silu (gated) | gelu (ungated)
    gated_mlp: bool = True
    rope_theta: float = 10000.0
    use_rope: bool = True
    tie_embeddings: bool = False
    logits_softcap: float = 0.0
    attn_logits_softcap: float = 0.0
    dtype: str = "bfloat16"         # compute dtype
    param_dtype: str = "float32"    # master param dtype (training)

    # architecture add-ons
    moe: MoEConfig = field(default_factory=MoEConfig)
    mla: MLAConfig = field(default_factory=MLAConfig)
    ssm: SSMConfig = field(default_factory=SSMConfig)
    rglru: RGLRUConfig = field(default_factory=RGLRUConfig)

    # encoder-decoder (whisper)
    is_encoder_decoder: bool = False
    encoder_layers: int = 0
    encoder_seq_len: int = 1500     # frames after the (stubbed) conv frontend

    # modality frontend stub: "none" | "audio" | "vision"
    frontend: str = "none"
    frontend_tokens: int = 0        # patch/frame tokens prepended at prefill

    # multi-token prediction (deepseek)
    mtp_depth: int = 0
    mtp_loss_weight: float = 0.3

    # beyond-paper perf toggles (§Perf hillclimb; False = naive baseline)
    opt_attention: bool = True      # bf16 cache/score einsums, no repeat_kv
    #                                 materialization (measured 2-2.5×
    #                                 decode/train memory-term win)

    def __post_init__(self):
        if self.head_dim == 0:
            self.head_dim = self.d_model // self.num_heads
        if self.ssm.enabled and self.ssm.dt_rank == 0:
            self.ssm.dt_rank = max(1, -(-self.d_model // 16))
        if self.rglru.enabled and self.rglru.lru_width == 0:
            self.rglru.lru_width = self.d_model

    @property
    def layer_kinds(self) -> Tuple[str, ...]:
        pat = self.block_pattern
        return tuple(pat[i % len(pat)] for i in range(self.num_layers))

    def is_subquadratic(self) -> bool:
        """True if the arch supports unbounded-context decode with bounded state."""
        kinds = set(self.layer_kinds)
        return kinds.issubset({"swa", "local", "rglru", "mamba"})


# ---------------------------------------------------------------------------
# Parallelism / runtime configuration
# ---------------------------------------------------------------------------

@dataclass
class ParallelConfig:
    data: int = 1
    model: int = 1
    pod: int = 1
    # strategy toggles
    fsdp: bool = True               # shard params/opt-state over data axis
    sp: bool = False                # Megatron-style sequence sharding over model
    ep: bool = True                 # expert parallel MoE over model axis
    pipeline_stages: int = 1        # >1 => GPipe over pod axis
    pp_microbatches: int = 8
    remat: str = "full"             # none | full | dots
    scan_layers: bool = True
    # distributed-optimization tricks
    grad_compression: str = "none"  # none | bf16 | int8 (explicit-DP mode)
    int8_optimizer_state: bool = False
    overlap_collectives: bool = True   # XLA latency hiding scheduler hints
    ep_local_dispatch: bool = True  # shard_map MoE routing per data shard
    #                                 (False = pure-GSPMD global dispatch —
    #                                 the §Perf cell-B baseline)


@dataclass
class QuantConfig:
    bits: int = 4
    group_size: int = 128
    symmetric: bool = False
    percdamp: float = 0.01
    blocksize: int = 128            # GPTQ lazy-update block
    # RPIQ stage 2
    rpiq_iters: int = 5
    rpiq_alpha: float = 0.01        # paper-faithful step size
    rpiq_early_stop: bool = True
    rpiq_use_global_hessian: bool = True   # eq. 12-14: block-diag of damped H
    keep_best_projection: bool = True
    calib_batches: int = 8
    calib_batch_size: int = 16
    calib_seq_len: int = 512
    act_order: bool = False
    gptq_impl: str = "auto"         # auto | pallas | xla: stage-1 sweep
    #                                 backend (kernels/ops.py gptq_block —
    #                                 fused Pallas lazy-block kernel vs the
    #                                 vmapped fori_loop XLA body; "auto" =
    #                                 pallas on TPU when the (U + row tile)
    #                                 VMEM residency fits, else xla)
    rpiq_impl: str = "auto"         # auto | pallas | xla: stage-2 closed-
    #                                 loop backend (kernels/ops.py
    #                                 rpiq_block — fused Gauss–Seidel
    #                                 Pallas kernel, all rounds in one
    #                                 pallas_call, vs the vmapped
    #                                 while_loop XLA body; "auto" = pallas
    #                                 on TPU when the row tile + instance
    #                                 slab + block inverses fit VMEM, else
    #                                 xla)
    jit_capture: bool = True        # jit the per-layer calibration forward
    #                                 (capture + propagate), cached per layer
    #                                 signature within one quantize_model
    #                                 run; False = legacy eager forwards
    batched_executor: bool = True   # group same-shape linears into vmapped
    #                                 GPTQ+RPIQ plan dispatches (core/plan.py);
    #                                 False = legacy per-linear dispatch
    #                                 (table4 baseline, parity tests)
    mesh: str = "off"               # sharded group execution (DESIGN.md
    #                                 §2.6): "off" = single device (default),
    #                                 "auto" = all devices on the data axis,
    #                                 "DxM" (e.g. "2x2") = explicit
    #                                 (data, model) mesh — group lanes shard
    #                                 over data, Cout row tiles over model;
    #                                 "DxMxE" (e.g. "1x1x8") adds an expert
    #                                 axis: groups made of stacked expert
    #                                 slabs shard lanes over expert (×data);
    #                                 non-divisible groups stay unsharded
    #                                 (launch/mesh.make_quant_mesh)
    resume: str = "off"             # off | auto: with "auto" and a ckpt_dir,
    #                                 quantize_model restarts a killed walk
    #                                 from the last completed LayerStep
    #                                 checkpoint — final artifacts are
    #                                 bitwise-identical to an uninterrupted
    #                                 run (tests/test_faults.py)
    ckpt_dir: str = ""              # "" disables layer checkpointing; set to
    #                                 a directory to persist completed
    #                                 LayerStep artifacts + stream state via
    #                                 distributed/checkpoint.py at every step
    #                                 boundary (fences always flush)
    ckpt_keep: int = 2              # retained step checkpoints in ckpt_dir
    guardrail: bool = True          # numerical guardrail ladder around the
    #                                 stage-1 Cholesky (core/plan.py): lanes
    #                                 with non-finite outputs (non-PSD /
    #                                 NaN Hessian) get escalating damping
    #                                 retries, then a per-group RTN fallback;
    #                                 outcomes counted in
    #                                 QuantReport.guardrail_stats
    guardrail_retries: int = 2      # damping-escalation rungs before RTN
    guardrail_damp_factor: float = 10.0  # percdamp multiplier per rung
    pipeline: str = "serial"        # layer-walk scheduling (core/stream.py,
    #                                 DESIGN.md §2.7): "serial" = capture →
    #                                 execute → propagate strictly alternate
    #                                 per layer (per-stage block_until_ready
    #                                 timing); "overlap" = streaming scheduler
    #                                 — executor dispatches stay async, the
    #                                 next layer's capture forward is
    #                                 dispatched speculatively on the
    #                                 pre-quantization residual stream while
    #                                 the executor is in flight, then repaired
    #                                 exactly after the scatter lands; routed
    #                                 MoE repairs at the plan level — only
    #                                 flipped routing assignments re-sort
    #                                 (core/pipeline._moe_members).
    #                                 Artifacts are bitwise-identical either
    #                                 way (tests/test_pipeline_stream.py,
    #                                 tests/test_moe_flip.py)
    moe_flip_budget: float = 0.5    # overlap + routed MoE: max fraction of
    #                                 (token, k) routing assignments allowed
    #                                 to flip between the speculative and
    #                                 post-quantization streams before the
    #                                 flip repair gives up on the speculative
    #                                 plans and re-plans the whole layer
    #                                 serially (counted as
    #                                 pipeline_stats["fallback_flip_budget"]);
    #                                 artifacts are bitwise-identical on
    #                                 either side of the budget


@dataclass
class TrainConfig:
    global_batch_size: int = 8
    seq_len: int = 128
    steps: int = 100
    lr: float = 3e-4
    warmup_steps: int = 10
    schedule: str = "cosine"        # cosine | wsd | constant
    wsd_stable_frac: float = 0.8
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    grad_accum: int = 1
    seed: int = 0
    log_every: int = 10
    ckpt_every: int = 200
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_keep: int = 3
    ckpt_async: bool = True


@dataclass
class ServeConfig:
    max_batch: int = 8              # decode lanes (continuous) / batch (static)
    max_new_tokens: int = 32
    temperature: float = 0.0        # 0 = greedy
    quantized: bool = True          # serve int4-packed weights
    prefill_chunk: int = 0          # 0 = single-shot prefill; >0 = prefill in
    #                                 chunks of this many positions (bounds
    #                                 per-tick prefill work so decode steps
    #                                 interleave — docs/SERVING.md). Chunked
    #                                 and single-shot prefill are
    #                                 logits/cache-equivalent (pinned in
    #                                 tests/test_serving.py)
    scheduler: str = "static"       # batching engine (docs/SERVING.md):
    #                                 "static" = engine.generate (whole batch
    #                                 padded to the slowest lane); "continuous"
    #                                 = serving/scheduler.ContinuousEngine —
    #                                 slot-based admit/evict mid-flight,
    #                                 chunked prefill interleaved with decode
    w4a16_impl: str = "auto"        # auto | pallas | xla: quantized-decode
    #                                 matmul backend for every QuantizedTensor
    #                                 dense on the serve path (kernels/ops.
    #                                 w4a16_matmul — same pattern and parity
    #                                 discipline as gptq_impl/rpiq_impl;
    #                                 "auto" = pallas on TPU, XLA ref
    #                                 elsewhere). Installed as the ops-level
    #                                 default around every engine trace; on a
    #                                 kernel fault the continuous engine
    #                                 degrades pallas→xla at runtime
    #                                 (docs/SERVING.md §Failure handling)
    kv_cache: str = "fp16"          # fp16 | int8: decode KV-cache precision.
    #                                 "int8" stores per-block absmax codes +
    #                                 f32 scales (kernels/kv_codec.py, the
    #                                 wire codec lifted into the cache) with
    #                                 per-lane error feedback on decode
    #                                 appends; MLA latents, recurrent states
    #                                 and enc-dec cross-KV stay bf16
    #                                 (docs/SERVING.md)
    kv_impl: str = "auto"           # auto | pallas | xla: int8-KV decode
    #                                 attention backend (kernels/ops.
    #                                 int8_kv_attention — fused dequant
    #                                 flash-decode kernel on TPU, XLA
    #                                 full-dequant oracle elsewhere; same
    #                                 dispatch/degradation discipline as
    #                                 w4a16_impl). No effect unless
    #                                 kv_cache="int8"
    request_timeout_s: float = 0.0  # per-request deadline (0 = none): a
    #                                 request past its deadline — queued,
    #                                 prefilling, parked, or decoding — is
    #                                 evicted with status "timeout" and its
    #                                 lane refilled the same tick
    max_queue: int = 0              # bounded admission queue (0 = unbounded):
    #                                 submits beyond this depth raise
    #                                 QueueFullError (counted backpressure
    #                                 instead of unbounded growth)
    decode_nan_guard: bool = True   # quarantine lanes whose decode logits go
    #                                 non-finite (evict only the poisoned
    #                                 lane, keep the batch decoding)
    supervise: bool = False         # wrap the continuous engine in
    #                                 serving/supervisor.SupervisedEngine:
    #                                 an engine crash (exception escaping
    #                                 step(), serve.engine_step fault, or a
    #                                 watchdog trip) rebuilds the engine and
    #                                 recovers in-flight requests by
    #                                 deterministic replay (docs/SERVING.md
    #                                 §Crash recovery)
    step_timeout_s: float = 0.0     # supervisor watchdog (0 = off): a tick
    #                                 whose clock() span exceeds this is
    #                                 treated as hung — the engine is
    #                                 rebuilt and its requests replayed
    #                                 (same injectable clock as deadlines)
    max_restarts: int = 3           # engine rebuilds the supervisor may
    #                                 perform before a crash loop surfaces
    #                                 as supervisor.EngineRestartExhausted
    #                                 (an explicit terminal error, never a
    #                                 silent retry forever)


@dataclass
class FaultsConfig:
    """Deterministic fault-injection plane (core/faults.py)."""
    arm: str = ""                   # comma-separated "site@trigger[:mode]"
    #                                 specs, e.g. "plan.stage1_executor@3" or
    #                                 "hessian.cholesky@1:nonpsd" — grammar
    #                                 and site table in core/faults.py
    seed: int = 0                   # seed for probabilistic (@pX) schedules


@dataclass
class Config:
    model: ModelConfig = field(default_factory=ModelConfig)
    parallel: ParallelConfig = field(default_factory=ParallelConfig)
    quant: QuantConfig = field(default_factory=QuantConfig)
    train: TrainConfig = field(default_factory=TrainConfig)
    serve: ServeConfig = field(default_factory=ServeConfig)
    faults: FaultsConfig = field(default_factory=FaultsConfig)


# ---------------------------------------------------------------------------
# Override machinery
# ---------------------------------------------------------------------------

def _coerce(value: str, current: Any) -> Any:
    if isinstance(current, bool):
        return value.lower() in ("1", "true", "yes", "on")
    if isinstance(current, int):
        return int(value)
    if isinstance(current, float):
        return float(value)
    if isinstance(current, tuple):
        parts = [p for p in value.split(",") if p]
        return tuple(parts)
    return value


def apply_overrides(cfg: Any, overrides: Dict[str, str]) -> Any:
    """Apply dotted-path string overrides to a (nested) dataclass, in place."""
    for key, value in overrides.items():
        parts = key.split(".")
        obj = cfg
        for p in parts[:-1]:
            obj = getattr(obj, p)
        leaf = parts[-1]
        if not hasattr(obj, leaf):
            raise KeyError(f"unknown config key: {key}")
        setattr(obj, leaf, _coerce(value, getattr(obj, leaf))
                if isinstance(value, str) else value)
    return cfg


def parse_overrides(argv: List[str]) -> Dict[str, str]:
    out: Dict[str, str] = {}
    for a in argv:
        if "=" not in a:
            raise ValueError(f"override must be key=value, got {a!r}")
        k, v = a.split("=", 1)
        out[k] = v
    return out


def to_dict(cfg: Any) -> Any:
    if is_dataclass(cfg):
        return {f.name: to_dict(getattr(cfg, f.name)) for f in fields(cfg)}
    if isinstance(cfg, (list, tuple)):
        return [to_dict(x) for x in cfg]
    return cfg


def config_fingerprint(cfg: Any) -> str:
    import hashlib
    return hashlib.sha256(json.dumps(to_dict(cfg), sort_keys=True,
                                     default=str).encode()).hexdigest()[:16]
