"""Synthetic, deterministic, checkpointable data pipelines.

``MarkovLM`` — a fixed random first-order Markov chain over the vocab with
temperature-controlled entropy. Sequences have real learnable structure, so
the opt-proxy model trained on it shows genuine PPL gaps between fp32, GPTQ
and RPIQ (benchmarks/table1). The transition structure is derived from the
seed only — two processes with the same seed see identical data.

``SentimentTask`` — the paper's downstream proxy: each sequence embeds
marker tokens of one of three "sentiment" classes plus noise; the final
position must be the class's answer token. Accuracy = argmax at the answer
slot, mirroring the paper's 3-way tweet classification.

Both iterators expose ``state()``/``restore()`` (just the step counter —
data is a pure function of (seed, step)), which the checkpoint manifest
stores so restarts resume the stream exactly.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class DataState:
    seed: int
    step: int


class MarkovLM:
    def __init__(self, vocab_size: int, seed: int = 0,
                 branching: int = 4, temperature: float = 1.0):
        self.vocab = vocab_size
        self.seed = seed
        self.step = 0
        rng = np.random.RandomState(seed)
        # sparse row-stochastic transition matrix: `branching` successors
        succ = rng.randint(0, vocab_size, size=(vocab_size, branching))
        logits = rng.randn(vocab_size, branching) / temperature
        probs = np.exp(logits)
        probs /= probs.sum(1, keepdims=True)
        self._succ = succ
        self._probs = probs

    def batch(self, batch_size: int, seq_len: int) -> Dict[str, jax.Array]:
        rng = np.random.RandomState((self.seed * 1_000_003 + self.step)
                                    % (2 ** 31))
        self.step += 1
        toks = np.empty((batch_size, seq_len), np.int32)
        cur = rng.randint(0, self.vocab, size=batch_size)
        toks[:, 0] = cur
        for t in range(1, seq_len):
            u = rng.rand(batch_size, 1)
            cdf = np.cumsum(self._probs[cur], axis=1)
            choice = (u > cdf).sum(1)
            cur = self._succ[cur, np.minimum(choice,
                                             self._succ.shape[1] - 1)]
            toks[:, t] = cur
        return {"tokens": jnp.asarray(toks)}

    def state(self) -> DataState:
        return DataState(self.seed, self.step)

    def restore(self, st: DataState) -> None:
        assert st.seed == self.seed, "data seed mismatch on restore"
        self.step = st.step


class SentimentTask:
    """3-class marker-counting task with an answer slot at the end."""

    def __init__(self, vocab_size: int, seed: int = 0):
        assert vocab_size >= 16
        self.vocab = vocab_size
        self.seed = seed
        self.step = 0
        # reserve: markers for class 0/1/2, answer tokens, a query token
        self.markers = (1, 2, 3)
        self.answers = (4, 5, 6)
        self.query = 7

    def batch(self, batch_size: int, seq_len: int
              ) -> Tuple[Dict[str, jax.Array], jax.Array]:
        rng = np.random.RandomState((self.seed * 9_999_991 + self.step)
                                    % (2 ** 31))
        self.step += 1
        toks = rng.randint(8, self.vocab, size=(batch_size, seq_len))
        labels = rng.randint(0, 3, size=batch_size)
        n_marks = max(2, seq_len // 6)
        for i in range(batch_size):
            pos = rng.choice(seq_len - 2, size=n_marks, replace=False)
            toks[i, pos] = self.markers[labels[i]]
            toks[i, -2] = self.query
            toks[i, -1] = self.answers[labels[i]]
        mask = np.zeros((batch_size, seq_len), np.float32)
        mask[:, -1] = 1.0           # loss/eval only on the answer slot
        return ({"tokens": jnp.asarray(toks),
                 "loss_mask": jnp.asarray(mask)},
                jnp.asarray(labels))

    def accuracy(self, logits_last: jax.Array, labels: jax.Array) -> float:
        """logits at the answer-predicting position, restricted to answers."""
        sub = logits_last[:, list(self.answers)]
        pred = jnp.argmax(sub, axis=-1)
        return float(jnp.mean((pred == labels).astype(jnp.float32)))

    def state(self) -> DataState:
        return DataState(self.seed, self.step)

    def restore(self, st: DataState) -> None:
        assert st.seed == self.seed
        self.step = st.step


def calibration_batches(source, n_batches: int, batch_size: int,
                        seq_len: int) -> List[Dict[str, jax.Array]]:
    """Materialize a fixed calibration set (the paper uses 128 sequences)."""
    return [source.batch(batch_size, seq_len) for _ in range(n_batches)]
