"""Deterministic synthetic data pipelines (training + calibration)."""
from repro.data.synthetic import (MarkovLM, SentimentTask, DataState,
                                  calibration_batches)  # noqa: F401
