"""HLO-text cost analyzer with while-loop trip-count scaling.

XLA's ``compiled.cost_analysis()`` counts a ``while`` body **once**, so a
scan-over-layers model under-reports FLOPs/bytes/collectives by the layer
count (measured: 4-step scan of a matmul reports 1 matmul). This analyzer
parses the optimized HLO text instead:

  - computations are parsed into symbol tables (every ``%name = type op``),
  - per-op costs:
      * ``dot``: 2 · prod(result dims) · prod(lhs contracting dims),
      * elementwise/compare/convert/...: 1 flop per result element,
      * bytes: operand sizes + result size for top-level ops; fusions are
        charged operands+result only (internals are register traffic),
      * collectives: per-chip ICI bytes with ring estimates
        (see hlo_analysis module docstring),
  - ``fusion``/``call``/``conditional`` add their called computation's
    *flops and collectives* (bytes of fusion internals are free),
  - ``while`` multiplies the body's full cost vector by
    ``backend_config.known_trip_count`` (1 when absent — conservative).

Costs are exact for the dot-dominated graphs we lower (elementwise flops
are an approximation, <2% of totals at these shapes).
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s2": 0.25, "u2": 0.25, "s4": 0.5, "u4": 0.5, "s8": 1,
    "u8": 1, "s16": 2, "u16": 2, "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "bf16": 2, "f16": 2,
    "f32": 4, "f64": 8, "c64": 8, "c128": 16, "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\](?:\{[^}]*\})?")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+?)\s+"
                     r"([\w\-]+)\(")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\((.*?)\)\s*->")
_TRIP_RE = re.compile(r'known_trip_count[":{ ]+n[": ]+"?(\d+)')
_CALLS_RE = re.compile(r"(?:calls|body|to_apply)=%?([\w.\-]+)")
_COND_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "power",
    "tanh", "exponential", "log", "negate", "abs", "rsqrt", "sqrt",
    "compare", "select", "and", "or", "xor", "not", "convert", "floor",
    "ceil", "round-nearest-afz", "round-nearest-even", "sign", "cosine",
    "sine", "logistic", "clamp", "remainder", "atan2", "erf", "exponential-minus-one",
    "log-plus-one", "shift-left", "shift-right-logical", "shift-right-arithmetic",
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    ici_bytes: float = 0.0
    ops: float = 0.0       # trip-count-aware executed-op count (free ops —
    #                        parameter/constant/tuple plumbing — excluded;
    #                        fusion internals included so the count is
    #                        backend-fusion-invariant)
    coll_counts: Dict[str, float] = dataclasses.field(default_factory=dict)
    coll_bytes: Dict[str, float] = dataclasses.field(default_factory=dict)

    def add(self, other: "Cost", scale: float = 1.0) -> None:
        self.flops += other.flops * scale
        self.bytes += other.bytes * scale
        self.ici_bytes += other.ici_bytes * scale
        self.ops += other.ops * scale
        for k, v in other.coll_counts.items():
            self.coll_counts[k] = self.coll_counts.get(k, 0.0) + v * scale
        for k, v in other.coll_bytes.items():
            self.coll_bytes[k] = self.coll_bytes.get(k, 0.0) + v * scale


def _shape_bytes_elems(type_str: str) -> Tuple[float, float]:
    """(bytes, elements) summed over all array shapes in a type string."""
    total_b = total_e = 0.0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1.0
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total_b += n * _DTYPE_BYTES[dt]
        total_e += n
    return total_b, total_e


class HloCostAnalyzer:
    def __init__(self, hlo_text: str, n_devices: int):
        self.n_devices = n_devices
        self.computations = self._split(hlo_text)
        self._memo: Dict[str, Cost] = {}
        self._reduce_memo: Dict[str, bool] = {}
        self._dus_memo: Dict[str, bool] = {}
        self.entry = self._find_entry(hlo_text)

    @staticmethod
    def _split(text: str) -> Dict[str, List[str]]:
        comps: Dict[str, List[str]] = {}
        cur: Optional[str] = None
        for line in text.splitlines():
            m = _COMP_HDR_RE.match(line)
            if m and "{" in line:
                cur = m.group(1)
                comps[cur] = []
            elif cur is not None:
                if line.strip().startswith("}"):
                    cur = None
                else:
                    comps[cur].append(line)
        return comps

    @staticmethod
    def _find_entry(text: str) -> Optional[str]:
        m = re.search(r"^ENTRY\s+%?([\w.\-]+)", text, re.M)
        return m.group(1) if m else None

    # -- per-op costing ------------------------------------------------------
    def _op_cost(self, line: str, symtab: Dict[str, str]) -> Cost:
        c = Cost()
        m = _DEF_RE.match(line)
        if not m:
            return c
        name, result_type, op = m.groups()
        symtab[name] = result_type
        rbytes, relems = _shape_bytes_elems(result_type)

        operands = re.findall(r"\(([^)]*)\)", line[:line.find(op) + 200])
        opnames = re.findall(r"%([\w.\-]+)", line.split(op + "(", 1)[-1]
                             .split(")", 1)[0]) if op + "(" in line else []

        def operand_bytes() -> float:
            tot = 0.0
            for o in opnames:
                t = symtab.get(o)
                if t:
                    tot += _shape_bytes_elems(t)[0]
            return tot

        if op == "dot":
            c.ops += 1
            mm = _CONTRACT_RE.search(line)
            contracted = 1.0
            if mm and opnames:
                lhs_t = symtab.get(opnames[0], "")
                sm = _SHAPE_RE.search(lhs_t)
                if sm and sm.group(2):
                    dims = [int(d) for d in sm.group(2).split(",")]
                    for ci in (mm.group(1).split(",") if mm.group(1)
                               else []):
                        if ci and int(ci) < len(dims):
                            contracted *= dims[int(ci)]
            c.flops += 2.0 * relems * contracted
            c.bytes += rbytes + operand_bytes()
        elif op == "fusion":
            # operand utilization: a kLoop fusion that slices a big operand
            # reads only the slice, so charge min(operand, result) per
            # operand — UNLESS the fused computation reduces (reads >>
            # writes), where operands are charged fully. (Charging operands
            # fully everywhere inflated scan-heavy models ~50×: the scan
            # body fusions take the whole stacked (n_chunks, ...) tensor as
            # operand and slice one chunk inside — measured on
            # falcon-mamba prefill.)
            mm = _CALLS_RE.search(line)
            reduces = False
            if mm:
                reduces = self._has_reduce(mm.group(1))
            if reduces:
                c.bytes += rbytes + operand_bytes()
            elif mm and (self._root_is_dus(mm.group(1))
                         or self._root_is_scatter(mm.group(1))):
                # in-place buffer update: traffic = read+write of the update
                # region (the smallest non-trivial operand), not the buffer
                cands = []
                for o in opnames:
                    t = symtab.get(o)
                    if t:
                        ob = _shape_bytes_elems(t)[0]
                        if ob >= 1024:
                            cands.append(ob)
                c.bytes += 2.0 * (min(cands) if cands else rbytes)
            else:
                tot = 0.0
                for o in opnames:
                    t = symtab.get(o)
                    if t:
                        tot += min(_shape_bytes_elems(t)[0], rbytes)
                c.bytes += rbytes + tot
            if mm:
                inner = self.cost_of(mm.group(1))
                c.flops += inner.flops          # fused dots/elementwise
                c.ops += inner.ops              # fusion-invariant op count
                c.ici_bytes += inner.ici_bytes
                for k, v in inner.coll_counts.items():
                    c.coll_counts[k] = c.coll_counts.get(k, 0) + v
        elif op == "while":
            trip = 1
            mm = _TRIP_RE.search(line)
            if mm:
                trip = int(mm.group(1))
            bm = _COND_BODY_RE.search(line)
            if bm:
                c.add(self.cost_of(bm.group(1)), scale=float(trip))
        elif op in ("call", "conditional", "custom-call", "map", "reduce",
                    "reduce-window", "sort", "scatter", "select-and-scatter"):
            c.bytes += rbytes + operand_bytes()
            c.flops += relems
            c.ops += 1
            mm = _CALLS_RE.search(line)
            if mm and mm.group(1) in self.computations:
                inner = self.cost_of(mm.group(1))
                c.flops += inner.flops
                c.ops += inner.ops
                c.ici_bytes += inner.ici_bytes
        elif any(op.startswith(k) for k in _COLLECTIVES):
            if op.endswith("-done"):
                return c
            kind = op.replace("-start", "")
            g = self._group_size(line)
            c.bytes += rbytes + operand_bytes()
            c.ops += 1
            if g > 1:
                c.coll_counts[kind] = c.coll_counts.get(kind, 0) + 1
                c.coll_bytes[kind] = c.coll_bytes.get(kind, 0) + rbytes
                if kind == "all-gather":
                    c.ici_bytes += rbytes * (g - 1) / g
                elif kind == "all-reduce":
                    c.ici_bytes += 2.0 * rbytes * (g - 1) / g
                elif kind == "reduce-scatter":
                    c.ici_bytes += rbytes * (g - 1)
                elif kind == "all-to-all":
                    c.ici_bytes += rbytes * (g - 1) / g
                elif kind == "collective-permute":
                    c.ici_bytes += rbytes
        elif op in ("parameter", "constant", "get-tuple-element", "tuple",
                    "bitcast", "after-all", "iota", "partition-id",
                    "replica-id", "reshape", "copy-start", "copy-done"):
            pass                                  # free / register-level
        elif op in ("slice", "dynamic-slice", "gather"):
            # reads only the sliced/gathered region, not the whole operand
            c.bytes += 2.0 * rbytes
            c.ops += 1
        elif op == "dynamic-update-slice":
            # in-place: read + write the *update* region only
            upd = symtab.get(opnames[1], "") if len(opnames) > 1 else ""
            ub = _shape_bytes_elems(upd)[0] if upd else rbytes
            c.bytes += 2.0 * min(ub, rbytes)
            c.ops += 1
        elif op == "scatter":
            upd = symtab.get(opnames[-1], "") if opnames else ""
            ub = _shape_bytes_elems(upd)[0] if upd else rbytes
            c.bytes += 3.0 * min(ub, rbytes)
            c.ops += 1
        elif op in ("copy", "transpose", "concatenate", "pad", "reverse"):
            c.bytes += rbytes + operand_bytes()
            c.ops += 1
        elif op in _ELEMENTWISE or op in ("broadcast", "convert"):
            # TPU memory model: standalone elementwise/convert/broadcast
            # fuse into their producer/consumer (the CPU backend leaves them
            # unfused in this HLO; charging operand+result here inflated the
            # memory term ~30× — measured). FLOPs still count.
            c.flops += relems
            c.ops += 1
        else:
            c.bytes += rbytes + operand_bytes()
            c.flops += relems
            c.ops += 1
        return c

    def _root_is_dus(self, comp: str) -> bool:
        if comp not in self._dus_memo:
            lines = self.computations.get(comp, ())
            root_dus = any("ROOT" in l and "dynamic-update-slice(" in l
                           for l in lines)
            # convert-of-DUS roots (bf16 cache updated from f32 values)
            # are still in-place buffer updates
            root_conv_dus = any(
                "ROOT" in l and "convert(" in l for l in lines) and any(
                "dynamic-update-slice(" in l for l in lines)
            self._dus_memo[comp] = root_dus or root_conv_dus
        return self._dus_memo[comp]

    def _root_is_scatter(self, comp: str) -> bool:
        """Scatter-rooted fusions update in place: traffic = update region
        (e.g. the one-token KV-cache write), not the whole buffer."""
        key = comp + "#sc"
        if key not in self._dus_memo:
            self._dus_memo[key] = any(
                "ROOT" in l and re.search(r"\bscatter(\.\d+)?\(", l)
                for l in self.computations.get(comp, ()))
        return self._dus_memo[key]

    def _has_reduce(self, comp: str) -> bool:
        if comp not in self._reduce_memo:
            self._reduce_memo[comp] = any(
                re.search(r"\breduce\(|\breduce-window\(", l)
                for l in self.computations.get(comp, ()))
        return self._reduce_memo[comp]

    def _group_size(self, line: str) -> int:
        m = _GROUPS_IOTA_RE.search(line)
        if m:
            return int(m.group(2))
        m = _GROUPS_RE.search(line)
        if m:
            return len(m.group(1).split(","))
        return self.n_devices

    def cost_of(self, comp: str) -> Cost:
        if comp in self._memo:
            return self._memo[comp]
        self._memo[comp] = Cost()           # cycle guard
        total = Cost()
        symtab: Dict[str, str] = {}
        for line in self.computations.get(comp, ()):  # defs in order
            total.add(self._op_cost(line, symtab))
        self._memo[comp] = total
        return total

    def entry_cost(self) -> Cost:
        if self.entry is None:
            # fall back: largest computation
            best = Cost()
            for name in self.computations:
                c = self.cost_of(name)
                if c.flops >= best.flops:
                    best = c
            return best
        return self.cost_of(self.entry)

    # -- attribution (perf-iteration tooling) --------------------------------
    def attribute(self, top: int = 20) -> List[Tuple[float, float, str]]:
        """(bytes, flops, 'comp::line') for the costliest individual ops,
        scaled by how often their computation executes (while trip counts).

        This is the §Perf profiling view: sort by bytes to find the memory
        hot spots in the per-device program.
        """
        reach: Dict[str, float] = {}

        def visit(comp: str, times: float):
            reach[comp] = reach.get(comp, 0.0) + times
            for line in self.computations.get(comp, ()):
                m = _DEF_RE.match(line)
                if not m:
                    continue
                op = m.group(3)
                if op == "while":
                    trip = 1
                    tm = _TRIP_RE.search(line)
                    if tm:
                        trip = int(tm.group(1))
                    bm = _COND_BODY_RE.search(line)
                    if bm:
                        visit(bm.group(1), times * trip)
                elif op in ("fusion", "call", "conditional"):
                    cm = _CALLS_RE.search(line)
                    if cm and cm.group(1) in self.computations:
                        visit(cm.group(1), times)

        if self.entry:
            visit(self.entry, 1.0)
        rows: List[Tuple[float, float, str]] = []
        for comp, times in reach.items():
            sym: Dict[str, str] = {}
            for line in self.computations.get(comp, ()):
                c = self._op_cost(line, sym)
                if c.bytes * times > 0 or c.flops * times > 0:
                    rows.append((c.bytes * times, c.flops * times,
                                 f"{comp}::{line.strip()[:140]}"))
        rows.sort(key=lambda r: -r[0])
        return rows[:top]
