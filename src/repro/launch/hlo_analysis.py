"""Roofline-term extraction from compiled dry-run artifacts.

Sources (§Roofline of EXPERIMENTS.md):
  - ``compiled.cost_analysis()`` → HLO FLOPs and bytes accessed;
  - the compiled HLO text → per-collective ICI bytes (not in cost_analysis):
    every all-gather / all-reduce / reduce-scatter / all-to-all /
    collective-permute is parsed for result size and replica-group size and
    converted to *per-chip ICI traffic* with ring-algorithm estimates:

        all-gather        R·(g−1)/g          (R = result bytes/chip)
        all-reduce        2·S·(g−1)/g        (S = operand bytes)
        reduce-scatter    R·(g−1)            (R = result bytes; op = R·g)
        all-to-all        S·(g−1)/g
        collective-permute S

  - hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM,
    ~50 GB/s/link ICI (3 links/chip; the collective term uses one link,
    i.e. the most conservative single-ring estimate).

Async pairs (``*-start``/``*-done``) are counted once at ``-start``.
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Any, Dict, List, Optional, Tuple

PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip (TPU v5e)
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link (one ring)

_DTYPE_BYTES = {
    "pred": 1, "s4": 0.5, "u4": 0.5, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s+(.*?)\s+(all-gather-start|all-gather|all-reduce-start|all-reduce|"
    r"reduce-scatter|all-to-all|collective-permute-start|collective-permute)"
    r"\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")


def _result_bytes(result_str: str) -> float:
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(result_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return default


@dataclasses.dataclass
class CollectiveStats:
    counts: Dict[str, int]
    result_bytes: Dict[str, float]       # summed result bytes per op kind
    ici_bytes_per_chip: float            # ring-estimate traffic, one chip

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


def parse_collectives(hlo_text: str, n_devices: int) -> CollectiveStats:
    counts: Dict[str, int] = {}
    rbytes: Dict[str, float] = {}
    ici = 0.0
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        result_str, op = m.group(1), m.group(2)
        kind = op.replace("-start", "")
        if op.endswith("-done"):
            continue
        r = _result_bytes(result_str)
        g = _group_size(line, n_devices)
        if g <= 1:
            continue
        counts[kind] = counts.get(kind, 0) + 1
        rbytes[kind] = rbytes.get(kind, 0.0) + r
        if kind == "all-gather":
            ici += r * (g - 1) / g
        elif kind == "all-reduce":
            ici += 2.0 * r * (g - 1) / g
        elif kind == "reduce-scatter":
            ici += r * (g - 1)
        elif kind == "all-to-all":
            ici += r * (g - 1) / g
        elif kind == "collective-permute":
            ici += r
    return CollectiveStats(counts, rbytes, ici)


def cost_terms(compiled, hlo_text: str, n_devices: int,
               model_flops: float = 0.0) -> Dict[str, Any]:
    """The three roofline terms (+ inputs) for one compiled executable.

    FLOPs/bytes/collectives come from the trip-count-aware HLO-text
    analyzer (``hlo_text.HloCostAnalyzer``) — XLA's ``cost_analysis()``
    counts ``while`` bodies once, under-reporting scanned-layer models by
    the layer count (measured; raw values kept under ``xla_cost_analysis``
    for comparison).
    """
    from repro.launch.hlo_text import HloCostAnalyzer
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    xla_flops = float(ca.get("flops", 0.0))
    xla_bytes = float(ca.get("bytes accessed", 0.0))

    an = HloCostAnalyzer(hlo_text, n_devices)
    cost = an.entry_cost()
    flops = cost.flops
    bytes_accessed = cost.bytes
    coll = CollectiveStats(
        {k: int(v) for k, v in cost.coll_counts.items()},
        dict(cost.coll_bytes), cost.ici_bytes)

    # the HLO is the per-device SPMD program: flops/bytes are per device.
    t_compute = flops / PEAK_FLOPS
    t_memory = bytes_accessed / HBM_BW
    t_collective = coll.ici_bytes_per_chip / ICI_BW
    dominant = max((("compute", t_compute), ("memory", t_memory),
                    ("collective", t_collective)), key=lambda kv: kv[1])[0]

    mem = {}
    try:
        ma = compiled.memory_analysis()
        for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                     "temp_size_in_bytes", "alias_size_in_bytes",
                     "generated_code_size_in_bytes",
                     "peak_memory_in_bytes"):
            if hasattr(ma, attr):
                mem[attr] = int(getattr(ma, attr))
    except Exception as e:            # backend-dependent; keep the dry-run up
        mem["error"] = str(e)

    out = {
        "per_device_flops": flops,
        "per_device_bytes": bytes_accessed,
        "xla_cost_analysis": {"flops": xla_flops,
                              "bytes_accessed": xla_bytes},
        "collectives": coll.to_dict(),
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_collective,
        "dominant": dominant,
        "memory_analysis": mem,
        "n_devices": n_devices,
    }
    if model_flops > 0:
        total_hlo = flops * n_devices
        out["model_flops"] = model_flops
        out["useful_fraction"] = model_flops / total_hlo if total_hlo else 0.0
        bound = max(t_compute, t_memory, t_collective)
        out["roofline_fraction"] = (
            (model_flops / n_devices / PEAK_FLOPS) / bound if bound else 0.0)
    return out


def executed_op_count(hlo_text: str, n_devices: int = 1) -> int:
    """Trip-count-aware executed-XLA-op count of an optimized HLO module.

    Counts every non-free instruction (fusion internals included, so the
    number is backend-fusion-invariant) and multiplies ``while`` bodies by
    their ``known_trip_count`` — i.e. "how many XLA ops run per launch",
    the dispatch-overhead metric behind the fused-kernel claim
    (benchmarks/table4_time.py): a ``fori_loop``-of-``dynamic_slice``
    sweep counts O(trip · body), a Pallas kernel counts as the single
    custom-call it is.
    """
    from repro.launch.hlo_text import HloCostAnalyzer
    return int(HloCostAnalyzer(hlo_text, n_devices).entry_cost().ops)


_STABLEHLO_FREE = ("stablehlo.constant", "stablehlo.return", "func.return",
                   "stablehlo.tuple", "stablehlo.get_tuple_element")


def stablehlo_op_count(mlir_text: str) -> int:
    """Static op count of an exported StableHLO module (no trip scaling —
    used for loop-free programs such as the Pallas quantize stage, where
    the whole sweep is one ``tpu_custom_call``)."""
    n = 0
    for mm in re.finditer(r"=\s+\"?((?:stablehlo|chlo|mhlo)\.[\w.]+)",
                          mlir_text):
        if mm.group(1) not in _STABLEHLO_FREE:
            n += 1
    return n


def tpu_exported_op_count(fn, *args) -> Optional[int]:
    """XLA-op count of ``fn`` lowered FOR TPU via cross-platform export.

    Works on any host (Mosaic kernel lowering needs no TPU runtime); this
    is how the CPU container measures what a Pallas path dispatches on
    hardware — compiling it locally would instead count the interpret-mode
    emulation loop.  Returns None when export is unavailable or fails
    (e.g. a kernel that cannot lower), so callers can degrade gracefully.
    """
    try:
        from jax import export as jax_export
        import jax
        abstract = [jax.ShapeDtypeStruct(a.shape, a.dtype) for a in args]
        exp = jax_export.export(jax.jit(fn), platforms=["tpu"])(*abstract)
        return stablehlo_op_count(exp.mlir_module())
    except Exception:
        return None


def model_flops_estimate(cfg, shape) -> float:
    """MODEL_FLOPS = 6·N·D (dense) / 6·N_active·D (MoE) for train;
    2·N(_active) per generated token for decode; 2·N·D for prefill."""
    mc = cfg.model
    n_active = active_param_count(mc)
    if shape.kind == "train":
        return 6.0 * n_active * shape.seq_len * shape.global_batch
    if shape.kind == "prefill":
        return 2.0 * n_active * shape.seq_len * shape.global_batch
    return 2.0 * n_active * shape.global_batch      # decode: 1 token/seq


def active_param_count(mc) -> float:
    """Parameters touched per token (MoE counts top-k + shared experts)."""
    d, l, v = mc.d_model, mc.num_layers, mc.vocab_size
    h, kv, hd = mc.num_heads, mc.num_kv_heads, mc.head_dim
    total = v * d * (1 if mc.tie_embeddings else 2)
    for mixer, mlp_kind in _specs(mc):
        if mixer == "mla":
            m = mc.mla
            qk = m.qk_nope_head_dim + m.qk_rope_head_dim
            total += (d * m.q_lora_rank + m.q_lora_rank * h * qk
                      + d * (m.kv_lora_rank + m.qk_rope_head_dim)
                      + m.kv_lora_rank * h * (m.qk_nope_head_dim
                                              + m.v_head_dim)
                      + h * m.v_head_dim * d)
        elif mixer in ("attn", "swa", "local"):
            total += d * (h + 2 * kv) * hd + h * hd * d
        elif mixer == "rglru":
            w = mc.rglru.lru_width
            total += d * w * 2 + w * w * 2 + w * d + w * mc.rglru.conv1d_width
        elif mixer == "mamba":
            di = mc.ssm.expand * d
            total += (d * 2 * di + di * mc.ssm.d_conv
                      + di * (mc.ssm.dt_rank + 2 * mc.ssm.d_state)
                      + mc.ssm.dt_rank * di + di * d)
        if mlp_kind == "dense":
            mult = 3 if mc.gated_mlp else 2
            total += mult * d * mc.d_ff
        elif mlp_kind == "moe":
            m = mc.moe
            mult = 3
            total += mult * d * m.d_ff_expert * (m.top_k
                                                 + m.num_shared_experts)
            total += d * m.num_experts          # router
    if mc.is_encoder_decoder:
        # encoder layers + decoder cross-attention
        total += mc.encoder_layers * (d * (h + 2 * kv) * hd + h * hd * d
                                      + 2 * d * mc.d_ff)
        total += mc.num_layers * (d * (h + 2 * kv) * hd + h * hd * d)
    return float(total)


def total_param_count(mc) -> float:
    """All parameters (MoE counts every expert)."""
    d = mc.d_model
    total = active_param_count(mc)
    for mixer, mlp_kind in _specs(mc):
        if mlp_kind == "moe":
            m = mc.moe
            total += 3 * d * m.d_ff_expert * (m.num_experts - m.top_k)
    return float(total)


def _specs(mc):
    from repro.models.transformer import layer_specs
    return layer_specs(mc)
