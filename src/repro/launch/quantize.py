"""Quantization launcher: calibrate → GPTQ → RPIQ → packed artifacts.

    PYTHONPATH=src python -m repro.launch.quantize --arch opt-proxy --smoke \
        quant.rpiq_iters=5 quant.rpiq_alpha=0.01

Loads a checkpoint when train.ckpt_dir has one (quantizing a *trained*
model); otherwise quantizes a fresh init (still exercises the full path).
Prints the per-layer Γ convergence summary (paper Table 5) and writes the
packed int4 params + report.

``quant.mesh`` (e.g. ``quant.mesh=auto``, ``quant.mesh=8x2``, or
``quant.mesh=2x1x4`` with an expert axis) turns on sharded group
execution: every quant-plan group that divides the mesh runs
lane-sharded over ``data`` and row-tiled over ``model``; stacked MoE
expert slabs shard lanes over ``expert`` when the third axis is given
(DESIGN.md §2.6, docs/QUANTIZATION.md). Default "off" = single device.

``quant.pipeline=overlap`` switches the layer walk to the streaming
scheduler (core/stream.py, DESIGN.md §2.7): executor dispatches stay
async and the next layer's capture forward runs speculatively on the
pre-quantization stream with exact Hessian repair after the scatter —
routed MoE included, via the plan-level flip repair gated by
``quant.moe_flip_budget``. Artifacts are bitwise-identical to the
default ``serial`` schedule.
"""
from __future__ import annotations

import argparse
import json
import os

import jax

from repro.config import apply_overrides, parse_overrides
from repro.configs.registry import get_config
from repro.core import faults
from repro.core.pipeline import pack_for_serving, quantize_model
from repro.data import MarkovLM, calibration_batches
from repro.distributed.checkpoint import Checkpointer, save_artifact
from repro.launch.mesh import make_quant_mesh
from repro.models import transformer as T


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--out", default="artifacts/quantized")
    ap.add_argument("overrides", nargs="*")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    apply_overrides(cfg, parse_overrides(args.overrides))
    mc, qc = cfg.model, cfg.quant
    faults.install_from_config(cfg)
    if cfg.faults.arm:
        print(f"[quantize] fault plane armed: {cfg.faults.arm}")
    if qc.ckpt_dir:
        print(f"[quantize] step checkpoints → {qc.ckpt_dir} "
              f"(quant.resume={qc.resume})")

    key = jax.random.PRNGKey(0)
    params = (T.init_encdec_params(mc, key) if mc.is_encoder_decoder
              else T.init_params(mc, key))
    ckpt = Checkpointer(cfg.train.ckpt_dir)
    if ckpt.latest_step() is not None:
        from repro.training.train_step import init_train_state
        state, _ = ckpt.restore(init_train_state(cfg, key))
        params = state.params
        print(f"[quantize] loaded checkpoint step {ckpt.latest_step()}")

    data = MarkovLM(mc.vocab_size, seed=7)
    calib = calibration_batches(data, qc.calib_batches, qc.calib_batch_size,
                                min(qc.calib_seq_len, mc.max_seq_len - 8))
    if mc.is_encoder_decoder:
        import jax.numpy as jnp
        for i, b in enumerate(calib):
            b["frames"] = jax.random.normal(
                jax.random.PRNGKey(i),
                (qc.calib_batch_size, mc.encoder_seq_len, mc.d_model),
                jnp.float32)

    mesh = make_quant_mesh(qc.mesh)
    if mesh is not None:
        print(f"[quantize] sharded group execution on mesh "
              f"{dict(zip(mesh.axis_names, mesh.devices.shape))}")
    if qc.pipeline != "serial":
        print(f"[quantize] streaming layer walk: quant.pipeline="
              f"{qc.pipeline}")
    params_q, report = quantize_model(cfg, params, calib, verbose=True,
                                      mesh=mesh)
    print(f"[quantize] {report.summary()}")
    if report.pipeline_stats.get("resumed_at") is not None:
        print(f"[quantize] resumed from checkpoint at walk item "
              f"{report.pipeline_stats['resumed_at']}")
    st = report.pipeline_stats
    if st.get("mode") == "overlap":
        print(f"[quantize] schedule: {st['steps']} steps, "
              f"{st.get('spec_captures', 0)} speculative captures, "
              f"{st.get('repairs', 0)} repairs, "
              f"{st.get('serial_fallbacks', 0)} serial fallbacks")
        reasons = {k[len("fallback_"):]: v for k, v in st.items()
                   if k.startswith("fallback_") and v}
        if reasons:
            print(f"[quantize] fallback reasons: {reasons}")
        if st.get("moe_spec_layers"):
            n_a = max(1, st.get("moe_assignments", 0))
            print(f"[quantize] moe flip repair: "
                  f"{st.get('moe_plan_reuses', 0)} plan reuses, "
                  f"{st.get('moe_flip_repairs', 0)} re-sorts, "
                  f"flip rate {st.get('moe_flipped_assignments', 0)}/{n_a}"
                  f" (budget {qc.moe_flip_budget})")
    if report.moe_capacity_dropped:
        print(f"[quantize] moe capacity-dropped assignments: "
              f"{report.moe_capacity_dropped}")
    if report.guardrail_stats:
        print(f"[quantize] guardrail: {report.guardrail_stats}")
    if report.kernel_fallbacks:
        print(f"[quantize] kernel fallbacks: {report.kernel_fallbacks}")
    packed = pack_for_serving(cfg, params_q)

    os.makedirs(args.out, exist_ok=True)
    tag = mc.name
    with open(os.path.join(args.out, f"{tag}.report.json"), "w") as f:
        json.dump([{**vars(r)} for r in report.linears], f, indent=1)
    # atomic write + sha256 sidecar manifest: launch.serve (and the
    # supervisor's params reload) verify the digest at load, so a flipped
    # byte in the artifact is a typed error, never a silent garbage load
    save_artifact(os.path.join(args.out, f"{tag}.params.pkl"),
                  jax.device_get(packed), extra={"arch": tag})
    print(f"[quantize] wrote {args.out}/{tag}.params.pkl (+ integrity "
          "manifest)")


if __name__ == "__main__":
    main()
