"""ShapeDtypeStruct input stand-ins for every (arch × shape) dry-run cell.

``input_specs(cfg, shape, rules)`` returns the exact pytree the
corresponding step function is lowered with — weak-type-correct, carrying
NamedShardings, no device allocation:

  train   → (TrainState, batch{tokens[, embeds|frames]})
  prefill → (params, batch)
  decode  → (params_int4_or_bf16, token (B,), pos (B,), caches)

Frontend stubs ([audio]/[vlm]): precomputed frame/patch embeddings of the
documented shapes appear as batch["frames"] / batch["embeds"].
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.config import Config
from repro.configs.registry import ShapeSpec
from repro.core.pipeline import pack_for_serving
from repro.distributed import sharding as shd
from repro.models import transformer as T
from repro.training.train_step import init_train_state


def batch_specs(cfg: Config, shape: ShapeSpec, rules: shd.Rules
                ) -> Dict[str, jax.ShapeDtypeStruct]:
    mc = cfg.model
    b = shape.global_batch
    out: Dict[str, Any] = {}
    if mc.is_encoder_decoder:
        out["frames"] = jax.ShapeDtypeStruct(
            (b, mc.encoder_seq_len, mc.d_model), jnp.bfloat16)
        out["tokens"] = jax.ShapeDtypeStruct((b, shape.seq_len), jnp.int32)
    elif mc.frontend in ("vision", "audio") and mc.frontend_tokens > 0:
        n_front = min(mc.frontend_tokens, shape.seq_len // 2)
        out["embeds"] = jax.ShapeDtypeStruct(
            (b, n_front, mc.d_model), jnp.bfloat16)
        out["tokens"] = jax.ShapeDtypeStruct(
            (b, shape.seq_len - n_front), jnp.int32)
    else:
        out["tokens"] = jax.ShapeDtypeStruct((b, shape.seq_len), jnp.int32)
    shardings = shd.batch_shardings(out, rules)
    return shd.sds_with_shardings(out, shardings)


def params_specs(cfg: Config, rules: shd.Rules, quantized: bool = False
                 ) -> Any:
    mc = cfg.model
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)

    def build(k):
        p = (T.init_encdec_params(mc, k) if mc.is_encoder_decoder
             else T.init_params(mc, k))
        p = jax.tree_util.tree_map(
            lambda a: a.astype(jnp.dtype(mc.dtype))
            if a.dtype == jnp.float32 and a.ndim >= 2 else a, p)
        if quantized:
            p = pack_for_serving(cfg, p)
        return p

    sds = jax.eval_shape(build, key)
    shardings = shd.param_shardings(sds, rules, fsdp=cfg.parallel.fsdp)
    return shd.sds_with_shardings(sds, shardings)


def state_specs(cfg: Config, rules: shd.Rules) -> Any:
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)
    sds = jax.eval_shape(functools.partial(init_train_state, cfg), key)
    shardings = shd.train_state_shardings(sds, rules,
                                          fsdp=cfg.parallel.fsdp)
    return shd.sds_with_shardings(sds, shardings)


def cache_specs(cfg: Config, shape: ShapeSpec, rules: shd.Rules) -> Any:
    mc = cfg.model
    b = shape.global_batch

    def build():
        if mc.is_encoder_decoder:
            # decoder self-cache + cross-cache, stacked over layers
            from repro.models import attention as attn
            self_c = attn.init_kv_cache(mc, b, shape.seq_len, jnp.bfloat16)
            cross_c = {"k": jnp.zeros((b, mc.encoder_seq_len,
                                       mc.num_kv_heads, mc.head_dim),
                                      jnp.bfloat16),
                       "v": jnp.zeros((b, mc.encoder_seq_len,
                                       mc.num_kv_heads, mc.head_dim),
                                      jnp.bfloat16)}
            one = {"self": self_c, "cross": cross_c}
            return jax.tree_util.tree_map(
                lambda a: jnp.broadcast_to(
                    a[None], (mc.num_layers,) + a.shape), one)
        return T.init_block_caches(mc, b, shape.seq_len, jnp.bfloat16)

    sds = jax.eval_shape(build)
    shardings = shd.cache_shardings(sds, rules)
    return shd.sds_with_shardings(sds, shardings)


def decode_token_specs(cfg: Config, shape: ShapeSpec, rules: shd.Rules
                       ) -> Tuple[Any, Any]:
    b = shape.global_batch
    tok = jax.ShapeDtypeStruct((b,), jnp.int32)
    pos = jax.ShapeDtypeStruct((b,), jnp.int32)
    sh = shd.batch_shardings({"t": tok, "p": pos}, rules)
    out = shd.sds_with_shardings({"t": tok, "p": pos}, sh)
    return out["t"], out["p"]


def input_specs(cfg: Config, shape: ShapeSpec, rules: shd.Rules, *,
                quantized_decode: bool = True) -> Dict[str, Any]:
    """Everything the dry-run lowers with, per shape kind."""
    if shape.kind == "train":
        return {"state": state_specs(cfg, rules),
                "batch": batch_specs(cfg, shape, rules)}
    if shape.kind == "prefill":
        return {"params": params_specs(cfg, rules, quantized=False),
                "batch": batch_specs(cfg, shape, rules)}
    if shape.kind == "decode":
        tok, pos = decode_token_specs(cfg, shape, rules)
        return {"params": params_specs(cfg, rules,
                                       quantized=quantized_decode),
                "token": tok, "pos": pos,
                "caches": cache_specs(cfg, shape, rules)}
    raise ValueError(shape.kind)
