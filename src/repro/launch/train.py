"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch opt-proxy \
        train.steps=200 train.global_batch_size=16 [--smoke] [--mesh d,m]

Uses the smoke (reduced) config by default on CPU; the full config with the
production mesh on real hardware. Checkpoints land in train.ckpt_dir and
restarts resume automatically (including the data-stream position).
"""
from __future__ import annotations

import argparse

import jax

from repro.config import apply_overrides, parse_overrides
from repro.configs.registry import get_config
from repro.data import MarkovLM
from repro.training.trainer import train


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-scale)")
    ap.add_argument("--mesh", default=None,
                    help="data,model (defaults to single device)")
    ap.add_argument("overrides", nargs="*")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    apply_overrides(cfg, parse_overrides(args.overrides))

    mesh = None
    if args.mesh:
        d, m = (int(x) for x in args.mesh.split(","))
        mesh = jax.make_mesh((d, m), ("data", "model"))

    data = MarkovLM(cfg.model.vocab_size, seed=cfg.train.seed)
    out = train(cfg, data, mesh=mesh)
    final = out["history"][-1] if out["history"] else {}
    print(f"done: step={final.get('step')} loss={final.get('loss'):.4f}")


if __name__ == "__main__":
    main()
