import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

The FIRST two lines above run before any other import (jax locks the device
count on first init). Usage::

    PYTHONPATH=src python -m repro.launch.dryrun --arch minicpm-2b \
        --shape train_4k --mesh pod
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both \
        --out artifacts/dryrun

Each cell writes ``<out>/<arch>__<shape>__<mesh>.json`` holding
``memory_analysis()`` (proves it fits), ``cost_analysis()`` FLOPs/bytes and
the parsed per-collective ICI bytes — the §Roofline inputs.
"""
import argparse
import functools
import json
import sys
import time
import traceback

import jax

from repro.config import apply_overrides, parse_overrides
from repro.configs.registry import (ARCH_IDS, SHAPES, get_config,
                                    shape_names_for)
from repro.distributed import sharding as shd
from repro.launch import hlo_analysis as hlo
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import input_specs
from repro.models import transformer as T
from repro.serving.engine import serve_step
from repro.training.train_step import make_train_step


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               overrides=None, quantized_decode: bool = True):
    """Lower + compile one cell; returns the artifact dict."""
    cfg = get_config(arch)
    if overrides:
        apply_overrides(cfg, overrides)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = shd.make_rules(mesh, cfg.parallel)
    n_dev = mesh.devices.size

    t0 = time.time()
    with mesh:
        specs = input_specs(cfg, shape, rules,
                            quantized_decode=quantized_decode)
        if shape.kind == "train":
            step = make_train_step(cfg)

            def fn(state, batch):
                with shd.use_rules(rules):
                    return step(state, batch)

            lowered = jax.jit(fn).lower(specs["state"], specs["batch"])
        elif shape.kind == "prefill":
            def fn(params, batch):
                with shd.use_rules(rules):
                    if cfg.model.is_encoder_decoder:
                        return T.encdec_prefill(
                            cfg.model, params, batch["frames"],
                            batch["tokens"], shape.seq_len)
                    return T.prefill(cfg.model, params, batch["tokens"],
                                     shape.seq_len,
                                     embeds=batch.get("embeds"))

            lowered = jax.jit(fn).lower(specs["params"], specs["batch"])
        else:  # decode
            def fn(params, token, pos, caches):
                with shd.use_rules(rules):
                    return serve_step(cfg, params, token, pos, caches)

            lowered = jax.jit(fn).lower(specs["params"], specs["token"],
                                        specs["pos"], specs["caches"])
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    hlo_text = compiled.as_text()
    terms = hlo.cost_terms(compiled, hlo_text, n_dev,
                           model_flops=hlo.model_flops_estimate(cfg, shape))
    terms.update({
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "seconds_lower": t_lower, "seconds_compile": t_compile,
        "quantized_decode": bool(shape.kind == "decode"
                                 and quantized_decode),
        "total_params": hlo.total_param_count(cfg.model),
        "active_params": hlo.active_param_count(cfg.model),
    })
    return terms


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["pod", "multipod", "both"],
                    default="pod")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--fp-decode", action="store_true",
                    help="decode cells with bf16 (not int4) weights")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("overrides", nargs="*")
    args = ap.parse_args(argv)
    overrides = parse_overrides(args.overrides)

    cells = []
    archs = [a for a in ARCH_IDS if a != "opt-proxy"] \
        if args.all or args.arch is None else [args.arch]
    for arch in archs:
        shapes = shape_names_for(arch) if args.shape is None \
            else [args.shape]
        for s in shapes:
            meshes = {"pod": [False], "multipod": [True],
                      "both": [False, True]}[args.mesh]
            for mp in meshes:
                cells.append((arch, s, mp))

    os.makedirs(args.out, exist_ok=True)
    failures = []
    for arch, s, mp in cells:
        tag = f"{arch}__{s}__{'2x16x16' if mp else '16x16'}"
        path = os.path.join(args.out, tag + ".json")
        if args.skip_existing and os.path.exists(path):
            print(f"[dryrun] {tag}: cached")
            continue
        print(f"[dryrun] {tag}: lowering...", flush=True)
        try:
            art = lower_cell(arch, s, mp, overrides,
                             quantized_decode=not args.fp_decode)
            with open(path, "w") as f:
                json.dump(art, f, indent=1)
            print(f"[dryrun] {tag}: OK  compute={art['t_compute_s']:.4f}s "
                  f"memory={art['t_memory_s']:.4f}s "
                  f"collective={art['t_collective_s']:.4f}s "
                  f"dominant={art['dominant']} "
                  f"(lower {art['seconds_lower']:.0f}s, "
                  f"compile {art['seconds_compile']:.0f}s)", flush=True)
        except Exception as e:
            failures.append((tag, repr(e)))
            print(f"[dryrun] {tag}: FAILED {e!r}", flush=True)
            traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for tag, err in failures:
            print(f"  {tag}: {err[:200]}")
        sys.exit(1)
    print(f"\nall {len(cells)} cells passed")


if __name__ == "__main__":
    main()
