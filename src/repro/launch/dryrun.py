import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

The FIRST two lines above run before any other import (jax locks the device
count on first init). Usage::

    PYTHONPATH=src python -m repro.launch.dryrun --arch minicpm-2b \
        --shape train_4k --mesh pod
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both \
        --out artifacts/dryrun

Each cell writes ``<out>/<arch>__<shape>__<mesh>.json`` holding
``memory_analysis()`` (proves it fits), ``cost_analysis()`` FLOPs/bytes and
the parsed per-collective ICI bytes — the §Roofline inputs.

``--quant-cell`` lowers the quantization path itself at production scale
instead of the train/prefill/decode forwards: the per-MoE-layer capture
forward (route + scatter + stacked per-expert Hessian accumulation), the
stage-1/stage-2 sharded group executors at the 671B expert-slab shapes on
an expert-parallel ``DxMxE`` quant mesh, and the quantized serve_step on
the 512-chip production mesh — the capture→quantize→serve chain
(EXPERIMENTS.md §Dry-run). Lowering-only by default (``--compile`` opts
in): the cell proves the programs *build* at shape, which is what the
check.sh smoke leg gates.
"""
import argparse
import functools
import json
import sys
import time
import traceback

import jax

from repro.config import apply_overrides, parse_overrides
from repro.configs.registry import (ARCH_IDS, SHAPES, get_config,
                                    shape_names_for)
from repro.distributed import sharding as shd
from repro.launch import hlo_analysis as hlo
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import input_specs
from repro.models import transformer as T
from repro.serving.engine import serve_step
from repro.training.train_step import make_train_step


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               overrides=None, quantized_decode: bool = True):
    """Lower + compile one cell; returns the artifact dict."""
    cfg = get_config(arch)
    if overrides:
        apply_overrides(cfg, overrides)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = shd.make_rules(mesh, cfg.parallel)
    n_dev = mesh.devices.size

    t0 = time.time()
    with mesh:
        specs = input_specs(cfg, shape, rules,
                            quantized_decode=quantized_decode)
        if shape.kind == "train":
            step = make_train_step(cfg)

            def fn(state, batch):
                with shd.use_rules(rules):
                    return step(state, batch)

            lowered = jax.jit(fn).lower(specs["state"], specs["batch"])
        elif shape.kind == "prefill":
            def fn(params, batch):
                with shd.use_rules(rules):
                    if cfg.model.is_encoder_decoder:
                        return T.encdec_prefill(
                            cfg.model, params, batch["frames"],
                            batch["tokens"], shape.seq_len)
                    return T.prefill(cfg.model, params, batch["tokens"],
                                     shape.seq_len,
                                     embeds=batch.get("embeds"))

            lowered = jax.jit(fn).lower(specs["params"], specs["batch"])
        else:  # decode
            def fn(params, token, pos, caches):
                with shd.use_rules(rules):
                    return serve_step(cfg, params, token, pos, caches)

            lowered = jax.jit(fn).lower(specs["params"], specs["token"],
                                        specs["pos"], specs["caches"])
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    hlo_text = compiled.as_text()
    terms = hlo.cost_terms(compiled, hlo_text, n_dev,
                           model_flops=hlo.model_flops_estimate(cfg, shape))
    terms.update({
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "seconds_lower": t_lower, "seconds_compile": t_compile,
        "quantized_decode": bool(shape.kind == "decode"
                                 and quantized_decode),
        "total_params": hlo.total_param_count(cfg.model),
        "active_params": hlo.active_param_count(cfg.model),
    })
    return terms


def lower_quant_cell(arch: str, quant_mesh: str = "1x2x256",
                     overrides=None, do_compile: bool = False):
    """Lower the capture→quantize→serve chain for a routed-MoE arch.

    Three legs, each timed separately in the artifact dict:

    - ``capture``: one MoE layer's calibration forward at full shape —
      routing (sort dispatch, capacity) + the (E, C, d) scatter + the
      stacked per-expert Hessian accumulation for gate/up and down
      (exactly core/pipeline._moe_members' math);
    - ``stage1`` / ``stage2``: the cached group executors for the
      (E, f, d) gate/up expert slab, built against the expert-parallel
      quant mesh (lanes over ``expert``×``data``, rows over ``model`` —
      distributed/sharding.quant_group_sharding);
    - ``serve``: the quantized decode serve_step on the 512-chip
      production mesh (same program the decode_32k cell compiles).
    """
    import jax.numpy as jnp

    from repro.core import hessian as hess
    from repro.core import plan as qplan
    from repro.distributed.sharding import quant_group_sharding
    from repro.launch.mesh import make_host_mesh
    from repro.models import moe as moe_mod
    from repro.models.layers import _act

    cfg = get_config(arch)
    if overrides:
        apply_overrides(cfg, overrides)
    mc, qc = cfg.model, cfg.quant
    m = mc.moe
    if m.num_experts <= 0:
        raise ValueError(f"{arch} has no routed experts")
    d_, m_, e_ = (int(p) for p in quant_mesh.lower().split("x"))
    qmesh = make_host_mesh(data=d_, model=m_, expert=e_)

    e, d, f = m.num_experts, mc.d_model, m.d_ff_expert
    t = qc.calib_batch_size * qc.calib_seq_len     # flat tokens per batch
    cap = moe_mod._capacity(mc, t)
    wdt = jnp.dtype(mc.dtype)
    sds = jax.ShapeDtypeStruct
    art = {"arch": arch, "quant_mesh": quant_mesh, "experts": e,
           "d_model": d, "d_ff_expert": f, "calib_tokens": t,
           "capacity": cap, "compiled": bool(do_compile)}

    def _leg(name, lowered_fn):
        t0 = time.time()
        lowered = lowered_fn()
        art[f"{name}_seconds_lower"] = time.time() - t0
        if do_compile:
            t0 = time.time()
            lowered.compile()
            art[f"{name}_seconds_compile"] = time.time() - t0
        print(f"[dryrun] quant-cell {arch} {name}: lowered in "
              f"{art[f'{name}_seconds_lower']:.1f}s", flush=True)

    # --- capture leg -------------------------------------------------------
    p_moe = {"router": {"w": sds((d, e), jnp.float32)},
             "w_gate": sds((e, d, f), wdt), "w_up": sds((e, d, f), wdt),
             "w_down": sds((e, f, d), wdt)}

    def capture(p, xt):
        plan = moe_mod.route(mc, p, xt)
        buf = moe_mod.apply_route(plan, xt)
        g = jnp.einsum("ecd,edf->ecf", buf.astype(jnp.float32),
                       p["w_gate"].astype(jnp.float32))
        u = jnp.einsum("ecd,edf->ecf", buf.astype(jnp.float32),
                       p["w_up"].astype(jnp.float32))
        mid = _act(mc.act, g) * u
        H_in = hess.accumulate(hess.init_hessian(d, batch=e), buf)
        H_mid = hess.accumulate(hess.init_hessian(f, batch=e), mid)
        return H_in, H_mid, plan.counts

    _leg("capture", lambda: jax.jit(capture).lower(
        p_moe, sds((t, d), wdt)))

    # --- stage executor legs on the expert-parallel quant mesh -------------
    gshard = quant_group_sharding(qmesh, lanes=e, out_dim=f,
                                  expert_stacked=True)
    if gshard is None:
        raise ValueError(f"quant mesh {quant_mesh} shards nothing for "
                         f"(E={e}, out={f})")
    art["lane_axis"] = str(gshard.lane_axis)
    art["row_axis"] = str(gshard.row_axis)
    groups = d // qc.group_size
    w_s = sds((e, f, d), jnp.float32, sharding=gshard.sharding("w"))
    H_s = sds((e, d, d), jnp.float32, sharding=gshard.sharding("hessian"))
    lane_s = sds((e,), jnp.float32, sharding=gshard.sharding("lane"))
    stage1 = qplan._make_stage1(qc, qc.gptq_impl, False, gshard)
    _leg("stage1", lambda: stage1.lower(w_s, H_s, lane_s))

    x_s = sds((e, cap, d), jnp.float32, sharding=gshard.sharding("x"))
    grid_s = sds((e, f, groups), jnp.float32, sharding=gshard.sharding("w"))
    cnt_s = sds((e,), jnp.int32, sharding=gshard.sharding("lane"))
    stage2 = qplan._make_stage2(qc, qc.rpiq_impl, gshard)
    _leg("stage2", lambda: stage2.lower(w_s, w_s, x_s, H_s, grid_s, grid_s,
                                        h_count=cnt_s, x_count=cnt_s))

    # --- serve leg on the 512-chip production mesh -------------------------
    pmesh = make_production_mesh(multi_pod=True)
    rules = shd.make_rules(pmesh, cfg.parallel)
    with pmesh:
        specs = input_specs(cfg, SHAPES["decode_32k"], rules,
                            quantized_decode=True)

        def serve_fn(params, token, pos, caches):
            with shd.use_rules(rules):
                return serve_step(cfg, params, token, pos, caches)

        _leg("serve", lambda: jax.jit(serve_fn).lower(
            specs["params"], specs["token"], specs["pos"],
            specs["caches"]))
    return art


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["pod", "multipod", "both"],
                    default="pod")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--fp-decode", action="store_true",
                    help="decode cells with bf16 (not int4) weights")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--quant-cell", action="store_true",
                    help="lower the capture→quantize→serve chain for the "
                         "given --arch instead of the forward cells")
    ap.add_argument("--quant-mesh", default="1x2x256",
                    help="DxMxE quant mesh for the --quant-cell stage "
                         "executors (expert-parallel lanes)")
    ap.add_argument("--compile", action="store_true",
                    help="with --quant-cell: compile each leg too "
                         "(lowering-only is the default smoke contract)")
    ap.add_argument("overrides", nargs="*")
    args = ap.parse_args(argv)
    overrides = parse_overrides(args.overrides)

    if args.quant_cell:
        if not args.arch:
            ap.error("--quant-cell requires --arch")
        os.makedirs(args.out, exist_ok=True)
        tag = f"{args.arch}__quant__{args.quant_mesh}"
        try:
            art = lower_quant_cell(args.arch, args.quant_mesh, overrides,
                                   do_compile=args.compile)
        except Exception as e:
            print(f"[dryrun] {tag}: FAILED {e!r}", flush=True)
            traceback.print_exc()
            sys.exit(1)
        path = os.path.join(args.out, tag + ".json")
        with open(path, "w") as fh:
            json.dump(art, fh, indent=1)
        legs = [k[:-len("_seconds_lower")] for k in art
                if k.endswith("_seconds_lower")]
        print(f"[dryrun] {tag}: OK ({', '.join(legs)}) → {path}")
        return

    cells = []
    archs = [a for a in ARCH_IDS if a != "opt-proxy"] \
        if args.all or args.arch is None else [args.arch]
    for arch in archs:
        shapes = shape_names_for(arch) if args.shape is None \
            else [args.shape]
        for s in shapes:
            meshes = {"pod": [False], "multipod": [True],
                      "both": [False, True]}[args.mesh]
            for mp in meshes:
                cells.append((arch, s, mp))

    os.makedirs(args.out, exist_ok=True)
    failures = []
    for arch, s, mp in cells:
        tag = f"{arch}__{s}__{'2x16x16' if mp else '16x16'}"
        path = os.path.join(args.out, tag + ".json")
        if args.skip_existing and os.path.exists(path):
            print(f"[dryrun] {tag}: cached")
            continue
        print(f"[dryrun] {tag}: lowering...", flush=True)
        try:
            art = lower_cell(arch, s, mp, overrides,
                             quantized_decode=not args.fp_decode)
            with open(path, "w") as f:
                json.dump(art, f, indent=1)
            print(f"[dryrun] {tag}: OK  compute={art['t_compute_s']:.4f}s "
                  f"memory={art['t_memory_s']:.4f}s "
                  f"collective={art['t_collective_s']:.4f}s "
                  f"dominant={art['dominant']} "
                  f"(lower {art['seconds_lower']:.0f}s, "
                  f"compile {art['seconds_compile']:.0f}s)", flush=True)
        except Exception as e:
            failures.append((tag, repr(e)))
            print(f"[dryrun] {tag}: FAILED {e!r}", flush=True)
            traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for tag, err in failures:
            print(f"  {tag}: {err[:200]}")
        sys.exit(1)
    print(f"\nall {len(cells)} cells passed")


if __name__ == "__main__":
    main()
