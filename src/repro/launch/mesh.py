"""Production mesh construction.

Functions (not module-level constants) so importing never touches jax
device state. Single pod: 16×16 = 256 chips (v5e pod), axes (data, model).
Multi-pod: 2×16×16 = 512 chips, axes (pod, data, model) — the ``pod`` axis
crosses DCN; sharding rules keep per-layer traffic off it (DP gradient
reduction and optional GPipe stages are the only pod-axis collectives).

``make_quant_mesh`` resolves the ``quant.mesh`` knob into the
``(data, model)`` — or, with an expert-parallel axis, ``(data, model,
expert)`` — mesh the sharded quantization executor runs on (DESIGN.md
§2.6, docs/QUANTIZATION.md); the default "off" keeps every config on the
single-device path.
"""
from __future__ import annotations

from typing import Optional

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1, pod: int = 1,
                   expert: int = 1):
    """Small CPU mesh for tests (requires forced host device count).

    ``expert > 1`` appends the expert-parallel axis (quantization-side
    only: stacked MoE slabs shard their lane axis over it — DESIGN.md
    §2.6); it composes with ``data``/``model`` but not ``pod``.
    """
    if expert > 1:
        if pod > 1:
            raise ValueError("expert axis does not compose with pod axis")
        return jax.make_mesh((data, model, expert),
                             ("data", "model", "expert"))
    if pod > 1:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))


def make_quant_mesh(spec: str = "off") -> Optional[Mesh]:
    """``quant.mesh`` knob → Mesh for sharded group execution.

    - "off" (default) / "" / "none" / "1x1" → None: single-device batched
      execution, exactly the pre-mesh behavior;
    - "auto" → all local devices on the ``data`` axis (lane parallelism
      needs no Cout divisibility, so it degrades most gracefully);
    - "DxM" (e.g. "2x2", "8x1") → explicit (data, model) axis sizes over
      the first D·M local devices;
    - "DxMxE" (e.g. "1x1x8", "2x1x4") → adds the ``expert`` axis:
      groups made entirely of stacked expert slabs shard lanes over
      expert (×data), everything else ignores the axis.

    Degrades to None (with a warning) when the spec is malformed or asks
    for more devices than the process has — a quantize config carrying a
    mesh knob stays runnable on a laptop, mirroring the per-group
    divisibility fallback.
    """
    def _fallback(why: str):
        print(f"[mesh] quant.mesh={spec!r} {why} — falling back to "
              f"single-device execution")
        return None

    if not spec or spec in ("off", "none", "1", "1x1", "1x1x1"):
        return None
    if spec == "auto":
        n = jax.device_count()
        if n <= 1:
            return None
        return make_host_mesh(data=n, model=1)
    parts = spec.lower().split("x")
    if len(parts) not in (2, 3):
        return _fallback("is not 'off', 'auto', 'DxM' or 'DxMxE'")
    try:
        sizes = [int(p) for p in parts]
    except ValueError:
        return _fallback("is not 'off', 'auto', 'DxM' or 'DxMxE'")
    if any(s < 1 for s in sizes):
        return _fallback("has non-positive axis sizes")
    d, m = sizes[0], sizes[1]
    e = sizes[2] if len(sizes) == 3 else 1
    total = d * m * e
    if total <= 1:
        return None
    if len(jax.devices()) < total:
        return _fallback(f"needs {total} devices, have "
                         f"{len(jax.devices())}")
    return make_host_mesh(data=d, model=m, expert=e)
