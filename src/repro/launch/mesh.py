"""Production mesh construction.

A function (not a module-level constant) so importing never touches jax
device state. Single pod: 16×16 = 256 chips (v5e pod), axes (data, model).
Multi-pod: 2×16×16 = 512 chips, axes (pod, data, model) — the ``pod`` axis
crosses DCN; sharding rules keep per-layer traffic off it (DP gradient
reduction and optional GPipe stages are the only pod-axis collectives).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1, pod: int = 1):
    """Small CPU mesh for tests (requires forced host device count)."""
    if pod > 1:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))
