"""Serving launcher: batched generation with (optionally int4) weights.

    PYTHONPATH=src python -m repro.launch.serve --arch opt-proxy --smoke \
        --prompt-len 32 --batch 4 serve.max_new_tokens=16
"""
from __future__ import annotations

import argparse
import pickle
import time

import jax
import jax.numpy as jnp

from repro.config import apply_overrides, parse_overrides
from repro.configs.registry import get_config
from repro.data import MarkovLM
from repro.models import transformer as T
from repro.serving.engine import generate


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--params", default=None,
                    help="pickled packed params from launch.quantize")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("overrides", nargs="*")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    apply_overrides(cfg, parse_overrides(args.overrides))
    mc = cfg.model

    key = jax.random.PRNGKey(0)
    if args.params:
        with open(args.params, "rb") as f:
            params = pickle.load(f)
        print(f"[serve] loaded int4 params from {args.params}")
    else:
        params = (T.init_encdec_params(mc, key) if mc.is_encoder_decoder
                  else T.init_params(mc, key))

    data = MarkovLM(mc.vocab_size, seed=3)
    batch = data.batch(args.batch, args.prompt_len)
    if mc.is_encoder_decoder:
        batch["frames"] = jax.random.normal(
            key, (args.batch, mc.encoder_seq_len, mc.d_model), jnp.float32)
    elif mc.frontend in ("vision", "audio") and mc.frontend_tokens:
        batch["embeds"] = jax.random.normal(
            key, (args.batch, min(mc.frontend_tokens, 8), mc.d_model),
            jnp.float32)

    t0 = time.perf_counter()
    res = generate(cfg, params, batch)
    dt = time.perf_counter() - t0
    toks = int(res.tokens.size)
    print(f"[serve] generated {toks} tokens in {dt:.2f}s "
          f"({toks/dt:.1f} tok/s incl. compile)")
    for i in range(min(args.batch, 4)):
        print(f"  seq{i}: {list(map(int, res.tokens[i]))}")


if __name__ == "__main__":
    main()
