"""Serving launcher: batched generation with (optionally int4) weights.

    PYTHONPATH=src python -m repro.launch.serve --arch opt-proxy --smoke \
        --prompt-len 32 --batch 4 serve.max_new_tokens=16

``serve.scheduler=continuous`` routes the same prompts through the
continuous-batching engine (serving/scheduler.py) instead of the static
batch; ``--pack-rtn`` RTN-packs the (init or loaded) weights to int4 so
the quantized decode hot path runs without a quantize-pipeline artifact.

``--params`` artifacts load through the integrity-checked
``distributed.checkpoint.load_artifact`` path (sha256 sidecar manifest
from ``launch.quantize``): a corrupt artifact is a typed
``ArtifactIntegrityError``, never a silent load.
``serve.supervise=true`` wraps the continuous engine in the crash-
recovering supervisor (serving/supervisor.py, docs/SERVING.md §Crash
recovery).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.config import apply_overrides, parse_overrides
from repro.configs.registry import get_config
from repro.core import faults
from repro.data import MarkovLM
from repro.distributed.checkpoint import load_artifact
from repro.models import transformer as T
from repro.serving.engine import generate
from repro.serving.scheduler import ContinuousEngine
from repro.serving.supervisor import SupervisedEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--params", default=None,
                    help="pickled packed params from launch.quantize")
    ap.add_argument("--pack-rtn", action="store_true",
                    help="RTN-pack weights to int4 QuantizedTensor before "
                         "serving (no quantize run needed)")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("overrides", nargs="*")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    apply_overrides(cfg, parse_overrides(args.overrides))
    mc = cfg.model
    faults.install_from_config(cfg)
    if cfg.faults.arm:
        print(f"[serve] fault plane armed: {cfg.faults.arm}")

    key = jax.random.PRNGKey(0)
    if args.params:
        params = load_artifact(args.params)
        print(f"[serve] loaded int4 params from {args.params} "
              "(integrity-checked)")
    else:
        params = (T.init_encdec_params(mc, key) if mc.is_encoder_decoder
                  else T.init_params(mc, key))
    if args.pack_rtn:
        from repro.core.pipeline import pack_for_serving
        params = pack_for_serving(cfg, params)
        print(f"[serve] RTN-packed weights to int4 "
              f"(w4a16_impl={cfg.serve.w4a16_impl})")

    data = MarkovLM(mc.vocab_size, seed=3)
    batch = data.batch(args.batch, args.prompt_len)
    if mc.is_encoder_decoder:
        batch["frames"] = jax.random.normal(
            key, (args.batch, mc.encoder_seq_len, mc.d_model), jnp.float32)
    elif mc.frontend in ("vision", "audio") and mc.frontend_tokens:
        batch["embeds"] = jax.random.normal(
            key, (args.batch, min(mc.frontend_tokens, 8), mc.d_model),
            jnp.float32)

    t0 = time.perf_counter()
    if cfg.serve.scheduler == "continuous":
        n_front = batch["embeds"].shape[1] if "embeds" in batch else 0
        cap = args.prompt_len + n_front + cfg.serve.max_new_tokens + 1
        if cfg.serve.supervise:
            # crash-recovering supervisor; a --params path is handed down
            # so a rebuild re-reads the artifact through the integrity
            # check instead of trusting a possibly-poisoned in-memory tree
            eng = SupervisedEngine(cfg, params, max_len=cap,
                                   params_path=args.params or None)
            print("[serve] supervised engine "
                  f"(max_restarts={cfg.serve.max_restarts}, "
                  f"step_timeout_s={cfg.serve.step_timeout_s})")
        else:
            eng = ContinuousEngine(cfg, params, max_len=cap)
        rids = []
        for i in range(args.batch):
            one = {k: v[i:i + 1] for k, v in batch.items()}
            rids.append(eng.submit(one))
        done = eng.run()
        seqs = [done[r].tokens for r in rids]
        toks = int(sum(len(s) for s in seqs))
        bad = {r: done[r].status for r in rids if done[r].status != "ok"}
        if bad:
            print(f"[serve] non-ok requests: {bad}")
        if any(done[r].status != "ok" for r in rids) or \
                any(v for v in eng.stats.values()):
            print(f"[serve] engine stats: {eng.engine_stats()}")
    else:
        res = generate(cfg, params, batch)
        seqs = [res.tokens[i] for i in range(args.batch)]
        toks = int(res.tokens.size)
    dt = time.perf_counter() - t0
    print(f"[serve] scheduler={cfg.serve.scheduler}: {toks} tokens "
          f"in {dt:.2f}s ({toks/dt:.1f} tok/s incl. compile)")
    for i in range(min(args.batch, 4)):
        print(f"  seq{i}: {list(map(int, seqs[i]))}")


if __name__ == "__main__":
    main()
