"""GSPMD sharding rules: params by path, activations by hint.

Mesh axes (launch/mesh.py): ``("data", "model")`` single-pod,
``("pod", "data", "model")`` multi-pod.

Strategy (DESIGN.md §2):
  - **DP** over ``pod`` × ``data`` — batch dims.
  - **TP** over ``model`` — Megatron col/row parallel linears, expert
    parallelism for stacked MoE weights, vocab-parallel embedding where the
    vocab divides.
  - **FSDP** over ``data`` — the non-TP weight dim (params gathered by XLA
    per layer; optimizer state stays sharded). Within-pod only: cross-pod
    param all-gathers would cross DCN every layer.
  - **SP** (optional) — sequence dim of the residual stream over ``model``.
  - **Quant-group sharding** (DESIGN.md §2.6) — the batched quantization
    executor's stacked ``(L, Cout, Cin)`` slabs: lane (member) axis over
    ``data``, Cout row tiles over ``model`` (rows are independent given the
    Cholesky factor — see gptq.py), Hessian state over the lane axis only.
    :func:`quant_group_sharding` below.

Every rule is guarded by divisibility: a dim that doesn't divide by the
mesh axis size stays unsharded (e.g. whisper's 51866 vocab, minicpm's 36
heads). This keeps every (arch × mesh) cell lowerable; the roofline then
shows what the fallback costs.

``shard_hint(x, kind)`` is a no-op unless a :class:`Rules` context is
active, so model code never depends on a mesh.
"""
from __future__ import annotations

import contextlib
import re
import threading
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.config import Config, ParallelConfig

_STATE = threading.local()


# ---------------------------------------------------------------------------
# Activation hints
# ---------------------------------------------------------------------------

@dataclass
class Rules:
    mesh: Mesh
    dp_axes: Tuple[str, ...]            # ("pod","data") or ("data",)
    tp_axis: Optional[str] = "model"
    sp: bool = False                    # shard seq dim of residual over TP
    ep_local_dispatch: bool = True      # shard_map MoE routing (§Perf B)

    def axis_size(self, name: Optional[str]) -> int:
        if name is None:
            return 1
        return int(self.mesh.shape[name])

    def dp_size(self) -> int:
        out = 1
        for a in self.dp_axes:
            out *= self.axis_size(a)
        return out


def current_rules() -> Optional[Rules]:
    return getattr(_STATE, "rules", None)


@contextlib.contextmanager
def use_rules(rules: Optional[Rules]):
    prev = getattr(_STATE, "rules", None)
    _STATE.rules = rules
    try:
        yield rules
    finally:
        _STATE.rules = prev


def _guard(spec_entry, dim: int, rules: Rules):
    """Drop a sharding axis when the dim doesn't divide it."""
    if spec_entry is None:
        return None
    axes = spec_entry if isinstance(spec_entry, tuple) else (spec_entry,)
    size = 1
    for a in axes:
        size *= rules.axis_size(a)
    if size <= 1 or dim % size != 0:
        return None
    return spec_entry


def shard_hint(x: jax.Array, kind: str) -> jax.Array:
    """Constrain activation sharding. kinds: act (B,S,D), logits (B,S,V),
    tokens (B,S), batch1 (B, ...)."""
    rules = current_rules()
    if rules is None:
        return x
    dp = tuple(rules.dp_axes) if rules.dp_axes else None
    tp = rules.tp_axis
    if kind == "act" and x.ndim == 3:
        seq = tp if rules.sp else None
        spec = P(_guard(dp, x.shape[0], rules),
                 _guard(seq, x.shape[1], rules), None)
    elif kind == "logits" and x.ndim >= 2:
        spec = P(_guard(dp, x.shape[0], rules),
                 *([None] * (x.ndim - 2)),
                 _guard(tp, x.shape[-1], rules))
    elif kind == "tokens":
        spec = P(_guard(dp, x.shape[0], rules), *([None] * (x.ndim - 1)))
    elif kind == "batch1":
        spec = P(_guard(dp, x.shape[0], rules), *([None] * (x.ndim - 1)))
    else:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(rules.mesh, spec))


# ---------------------------------------------------------------------------
# Parameter specs by path
# ---------------------------------------------------------------------------

# (regex on '/'-joined path, spec template for the *trailing* dims)
# templates use tokens: "tp" (model axis), "fsdp" (data axis), None.
_PARAM_RULES: List[Tuple[str, Tuple]] = [
    # embeddings / head
    (r"embed/embedding$",              ("tp", "fsdp")),
    (r"lm_head/w$",                    ("fsdp", "tp")),
    # attention projections (col-parallel q/k/v, row-parallel o)
    (r"(mixer|xattn)/(q|k|v)/w$",      ("fsdp", "tp")),
    (r"(mixer|xattn)/o/w$",            ("tp", "fsdp")),
    (r"(mixer|xattn)/(q|k|v)/b$",      ("tp",)),
    (r"(mixer|xattn)/o/b$",            (None,)),
    # MLA
    (r"mixer/(q_down|kv_down|k_rope)/w$", ("fsdp", None)),
    (r"mixer/(q_up|k_up|v_up)/w$",     ("fsdp", "tp")),
    # gated MLP
    (r"mlp/(gate|up)/w$",              ("fsdp", "tp")),
    (r"mlp/down/w$",                   ("tp", "fsdp")),
    (r"mlp/(gate|up)/b$",              ("tp",)),
    (r"mlp/down/b$",                   (None,)),
    # MoE (stacked experts, EP over model)
    (r"mlp/router/w$",                 (None, None)),
    (r"mlp/w_(gate|up)$",              ("tp", "fsdp", None)),
    (r"mlp/w_down$",                   ("tp", None, "fsdp")),
    (r"mlp/shared/(gate|up)/w$",       ("fsdp", "tp")),
    (r"mlp/shared/down/w$",            ("tp", "fsdp")),
    # mamba
    (r"mixer/in/w$",                   ("fsdp", "tp")),
    (r"mixer/x/w$",                    ("tp", None)),
    (r"mixer/dt/w$",                   (None, "tp")),
    (r"mixer/dt/b$",                   ("tp",)),
    (r"mixer/out/w$",                  ("tp", "fsdp")),
    (r"mixer/conv/w$",                 (None, "tp")),
    (r"mixer/conv/b$",                 ("tp",)),
    (r"mixer/a_log$",                  ("tp", None)),
    (r"mixer/d_skip$",                 ("tp",)),
    # rg-lru
    (r"mixer/(gate)/w$",               ("fsdp", "tp")),
    (r"mixer/(rg|ig)/w$",              (None, "tp")),
    (r"mixer/(rg|ig)/b$",              ("tp",)),
    (r"mixer/lambda$",                 ("tp",)),
    # mtp
    (r"mtp/proj/w$",                   ("fsdp", "tp")),
    # --- int4-packed serving leaves (QuantizedTensor children /0 /1 /2,
    # (out, in·)-major — col-parallel puts `out` on tp, row-parallel `in`) --
    (r"(mixer|xattn)/(q|k|v|q_up|k_up|v_up)/w/\d$", ("tp", "fsdp")),
    (r"(mixer|xattn)/o/w/\d$",         ("fsdp", "tp")),
    (r"mixer/(q_down|kv_down|k_rope)/w/\d$", (None, "fsdp")),
    (r"mlp/(gate|up)/w/\d$",           ("tp", "fsdp")),
    (r"mlp/down/w/\d$",                ("fsdp", "tp")),
    (r"mlp/shared/(gate|up)/w/\d$",    ("tp", "fsdp")),
    (r"mlp/shared/down/w/\d$",         ("fsdp", "tp")),
    (r"mlp/w_(gate|up|down)/\d$",      ("tp", None, "fsdp")),  # (E, out, in·)
    (r"mixer/(in|gate|rg|ig)/w/\d$",   ("tp", "fsdp")),
    (r"mixer/x/w/\d$",                 (None, "tp")),
    (r"mixer/dt/w/\d$",                ("tp", None)),
    (r"mixer/out/w/\d$",               ("fsdp", "tp")),
    (r"lm_head/w/\d$",                 ("tp", "fsdp")),
]


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def _resolve(template: Tuple, shape: Tuple[int, ...],
             rules: Rules) -> P:
    """Template applies to trailing dims; leading (stack) dims get None."""
    ndim = len(shape)
    t = template[-ndim:] if len(template) >= ndim else template
    lead = ndim - len(t)
    entries: List = [None] * lead
    for dim, tok in zip(shape[lead:], t):
        if tok == "tp":
            entries.append(_guard(rules.tp_axis, dim, rules))
        elif tok == "fsdp":
            entries.append(_guard("data", dim, rules)
                           if rules_has_fsdp(rules) else None)
        else:
            entries.append(None)
    return P(*entries)


def rules_has_fsdp(rules: Rules) -> bool:
    return getattr(rules, "fsdp", True) and "data" in rules.mesh.axis_names


def param_pspecs(params: Any, rules: Rules, fsdp: bool = True) -> Any:
    """PartitionSpec pytree matching ``params`` (arrays or SDS)."""
    rules.fsdp = fsdp  # type: ignore[attr-defined]

    def assign(path, leaf):
        shape = tuple(leaf.shape)
        if not shape:
            return P()
        s = _path_str(path)
        for pat, template in _PARAM_RULES:
            if re.search(pat, s):
                return _resolve(template, shape, rules)
        # default: replicate small leaves; fsdp-shard big 2D+ leaves
        if fsdp and len(shape) >= 2:
            ent = [None] * len(shape)
            for i in range(len(shape) - 1, -1, -1):
                if _guard("data", shape[i], rules) is not None:
                    ent[i] = "data"
                    break
            return P(*ent)
        return P(*([None] * len(shape)))

    return jax.tree_util.tree_map_with_path(assign, params)


def param_shardings(params: Any, rules: Rules, fsdp: bool = True) -> Any:
    specs = param_pspecs(params, rules, fsdp)
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(rules.mesh, s), specs,
        is_leaf=lambda x: isinstance(x, P))


def cache_pspecs(caches: Any, rules: Rules) -> Any:
    """KV/state caches: batch over DP, kv-heads over TP when divisible.

    Layouts (with leading segment-stack axes of ndim-4/5):
      k/v:   (..., B, S, KV, hd) → (None.., dp, None, tp, None)
      ckv:   (..., B, S, rank)   → (None.., dp, None, None)
      conv:  (..., B, K-1, C)    → (None.., dp, None, tp)
      h:     (..., B, W[, n])    → (None.., dp, tp[, None])
    """
    dp = tuple(rules.dp_axes) if rules.dp_axes else None

    def assign(path, leaf):
        s = _path_str(path)
        shape = tuple(leaf.shape)
        nd = len(shape)
        ent: List = [None] * nd
        if re.search(r"(^|/)(k|v)$", s) and nd >= 4:
            b, sq, kv, hd = shape[-4:]
            ent[-4] = _guard(dp, b, rules)
            ent[-2] = _guard(rules.tp_axis, kv, rules)
            if ent[-2] is None:
                # kv heads don't divide TP (minicpm 36, whisper 20, MQA 1):
                # shard the *sequence* dim instead — flash-decoding layout;
                # softmax over the sharded axis costs one small all-reduce
                # but cache reads drop 1/|tp| per chip (§Perf cell A it.2)
                ent[-3] = _guard(rules.tp_axis, sq, rules)
        elif re.search(r"(ckv|krope)$", s) and nd >= 3:
            ent[-3] = _guard(dp, shape[-3], rules)
        elif re.search(r"conv$", s) and nd >= 3:
            ent[-3] = _guard(dp, shape[-3], rules)
            ent[-1] = _guard(rules.tp_axis, shape[-1], rules)
        elif re.search(r"(^|/)h$", s) and nd >= 2:
            hdim = -2 if nd >= 3 and s.endswith("h") and shape[-1] <= 64 \
                else -1
            # mamba h: (B, d_inner, n); rglru h: (B, W)
            if nd >= 3:
                ent[-3] = _guard(dp, shape[-3], rules)
                ent[-2] = _guard(rules.tp_axis, shape[-2], rules)
            else:
                ent[-2] = _guard(dp, shape[-2], rules)
                ent[-1] = _guard(rules.tp_axis, shape[-1], rules)
        elif nd >= 1:
            ent[0] = None
        return P(*ent)

    return jax.tree_util.tree_map_with_path(assign, caches)


def cache_shardings(caches: Any, rules: Rules) -> Any:
    specs = cache_pspecs(caches, rules)
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(rules.mesh, s), specs,
        is_leaf=lambda x: isinstance(x, P))


def batch_shardings(batch: Any, rules: Rules) -> Any:
    dp = tuple(rules.dp_axes) if rules.dp_axes else None

    def assign(leaf):
        shape = tuple(leaf.shape)
        ent: List = [None] * len(shape)
        if shape:
            ent[0] = _guard(dp, shape[0], rules)
        return NamedSharding(rules.mesh, P(*ent))

    return jax.tree_util.tree_map(assign, batch)


def train_state_shardings(state: Any, rules: Rules,
                          fsdp: bool = True) -> Any:
    """NamedShardings for a TrainState (params + Adam moments + step).

    f32 moments mirror the param specs (same shapes). int8 moments
    (``Quantized8``: (n_blocks, 128) payload + (n_blocks,) scale) shard the
    block dim over data when it divides.
    """
    from repro.training.train_step import TrainState
    from repro.training.optimizer import AdamWState, Quantized8

    pspecs = param_pspecs(state.params, rules, fsdp)

    def moment_spec(path, leaf):
        shape = tuple(leaf.shape)
        s = _path_str(path)
        # Quantized8 children show up as trailing /q and /scale (NamedTuple)
        if s.endswith("/q") or s.endswith("/scale") or len(shape) <= 1:
            ent: List = [None] * len(shape)
            if shape and fsdp:
                ent[0] = _guard("data", shape[0], rules)
            return P(*ent)
        return None  # handled by mirroring below

    def mirror(ps, leaf):
        if isinstance(ps, P) and len(ps) == len(leaf.shape):
            return ps
        return P(*([None] * len(leaf.shape)))

    is_q8 = lambda x: isinstance(x, Quantized8)
    has_q8 = any(is_q8(l) for l in jax.tree_util.tree_leaves(
        state.opt.m, is_leaf=is_q8))

    if has_q8:
        def q8_specs(tree):
            return jax.tree_util.tree_map_with_path(
                lambda p, l: moment_spec(p, l) or P(
                    *([None] * len(l.shape))), tree)
        m_specs = q8_specs(state.opt.m)
        v_specs = q8_specs(state.opt.v)
    else:
        is_p = lambda x: isinstance(x, P)
        m_specs = jax.tree_util.tree_map(mirror, pspecs, state.opt.m,
                                         is_leaf=is_p)
        v_specs = jax.tree_util.tree_map(mirror, pspecs, state.opt.v,
                                         is_leaf=is_p)

    specs = TrainState(pspecs,
                       AdamWState(P(), m_specs, v_specs),
                       P())
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(rules.mesh, s), specs,
        is_leaf=lambda x: isinstance(x, P))


def sds_with_shardings(tree: Any, shardings: Any) -> Any:
    """ShapeDtypeStructs carrying NamedShardings (dry-run inputs)."""
    return jax.tree_util.tree_map(
        lambda l, s: jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=s),
        tree, shardings)


# ---------------------------------------------------------------------------
# Quantization-group sharding (DESIGN.md §2.6)
#
# The batched executor (core/plan.py) stacks a group's L same-shape linears
# into (L, Cout, Cin) slabs. Quantization is embarrassingly parallel over
# both leading axes — lanes are independent linears, and rows are
# independent given the per-lane Cholesky factor (gptq.py) — so the slab
# shards lane axis over ``data`` and Cout over ``model`` with zero
# collectives in the sweep (one lane-local psum for the Σerr² diagnostic).
# The Hessian state (L, Cin, Cin) shards over the lane axis only: each
# lane's damp + Cholesky runs on the devices that hold that lane's rows,
# and the factor is replicated across the ``model`` axis its row tiles use.
# With an ``expert`` mesh axis (launch/mesh.py "DxMxE"), groups made
# entirely of stacked expert slabs shard lanes over expert (×data) instead
# — expert parallelism for the quantization executors.
# ---------------------------------------------------------------------------

_QUANT_GROUP_SPECS = {
    # kind → spec template over (lane, row, ...) tokens. Grids (scales/
    # zeros) share the "w" layout but are only ever produced inside the
    # sweep's shard_map, never placed from the host.
    "w":       ("lane", "row", None),        # (L, Cout, Cin) weight slab
    "hessian": ("lane", None, None),         # (L, Cin, Cin) Gram/damped H
    "x":       ("lane", None, None),         # (L, n_last, Cin) instance
    "lane":    ("lane",),                    # (L,) counts / err / masks
}


@dataclass(frozen=True)
class QuantGroupSharding:
    """Resolved mesh placement for one quant group's stacked slabs.

    ``lane_axis``/``row_axis`` are mesh axis names or None when the
    corresponding dim failed its divisibility guard; at least one is set
    (``quant_group_sharding`` returns None otherwise, and the executor
    keeps the group single-device). ``lane_axis`` may also be a *tuple*
    of axis names (e.g. ``("expert", "data")``) when an expert-stacked
    group shards lanes over the expert × data product — PartitionSpec
    accepts the tuple entry directly and shard_map splits the dim over
    the axes' product.
    """
    mesh: Mesh
    lane_axis: Any                      # str | tuple[str, ...] | None:
    #                                     stacked member axis → "data",
    #                                     or ("expert", ...) for
    #                                     expert-stacked groups
    row_axis: Optional[str]             # Cout row tiles → "model"

    def spec(self, kind: str) -> P:
        tokens = _QUANT_GROUP_SPECS[kind]
        return P(*(self.lane_axis if t == "lane"
                   else self.row_axis if t == "row" else None
                   for t in tokens))

    def sharding(self, kind: str) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(kind))

    def cache_key(self) -> Tuple:
        """Stable executor-cache component: mesh identity + chosen axes."""
        return (self.lane_axis, self.row_axis, self.mesh.axis_names,
                tuple(self.mesh.devices.shape),
                tuple(d.id for d in self.mesh.devices.flat))


def quant_group_sharding(mesh: Optional[Mesh], lanes: int, out_dim: int,
                         expert_stacked: bool = False
                         ) -> Optional[QuantGroupSharding]:
    """Placement for a stacked (lanes, out_dim, ·) quant group, or None.

    Divisibility guards mirror the param rules above, per axis: a lane
    axis is used only when its size divides ``lanes`` evenly, the row
    axis only when ``model`` divides ``out_dim``. A group that fails
    both guards stays unsharded (None), so every config remains
    lowerable regardless of mesh shape.

    ``expert_stacked`` marks a group made entirely of stacked expert
    slabs: when the mesh carries an ``expert`` axis, such groups offer
    their lane axis to it — preferring the combined
    ``("expert", "data")`` product, then ``expert`` alone, then the
    plain ``data`` fallback. Per-expert Hessians travel with their lane,
    so expert-axis placement adds no collectives beyond what the data
    axis already pays. Non-expert groups ignore the expert axis
    entirely.
    """
    if mesh is None:
        return None
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    dp = sizes.get("data", 1)
    tp = sizes.get("model", 1)
    ep = sizes.get("expert", 1)

    def _axes_fit(axes: Tuple[str, ...]) -> bool:
        prod = 1
        for a in axes:
            prod *= sizes.get(a, 1)
        return prod > 1 and lanes % prod == 0

    lane_ax: Any = None
    candidates: List[Tuple[str, ...]] = []
    if expert_stacked and ep > 1:
        candidates += [("expert", "data"), ("expert",)]
    candidates.append(("data",))
    for cand in candidates:
        axes = tuple(a for a in cand if sizes.get(a, 1) > 1)
        if axes and _axes_fit(axes):
            lane_ax = axes[0] if len(axes) == 1 else axes
            break
    row_ax = "model" if tp > 1 and out_dim % tp == 0 else None
    if lane_ax is None and row_ax is None:
        return None
    return QuantGroupSharding(mesh, lane_ax, row_ax)


def make_rules(mesh: Mesh, parallel: Optional[ParallelConfig] = None
               ) -> Rules:
    names = mesh.axis_names
    dp = tuple(a for a in ("pod", "data") if a in names)
    tp = "model" if "model" in names else None
    sp = bool(parallel.sp) if parallel is not None else False
    epl = bool(parallel.ep_local_dispatch) if parallel is not None else True
    return Rules(mesh=mesh, dp_axes=dp, tp_axis=tp, sp=sp,
                 ep_local_dispatch=epl)
