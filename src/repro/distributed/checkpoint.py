"""Fault-tolerant checkpointing: atomic, async, manifest'd, elastic.

Layout (one directory per step)::

    <ckpt_dir>/step_000123/
        manifest.json      # schema version, step, tree structure, per-leaf
                           # dtype/shape/crc32, data state, wall time
        arrays.npz         # flattened leaves (gathered to host)
    <ckpt_dir>/LATEST      # atomic pointer (tmp + rename)

Properties required at scale and tested in tests/test_checkpoint.py:

  - **atomic**: writes land in ``step_*.tmp`` and are renamed only after
    fsync; a crash mid-write never corrupts LATEST.
  - **async**: ``save()`` snapshots leaves to host then hands the file I/O
    to a writer thread; training continues immediately. ``wait()`` joins.
  - **elastic restore**: arrays are saved fully gathered (host-global), so a
    checkpoint written on one mesh restores onto any other mesh/device
    count — ``restore(..., shardings=...)`` re-shards on load via
    ``jax.device_put``.
  - **integrity**: the manifest records a schema version and a per-leaf
    crc32 (over the npz-encoded bytes) at save; every load path verifies
    them and raises :class:`CheckpointIntegrityError` on any mismatch or
    unreadable file — a flipped byte is a typed error, never a silent
    load of garbage. The ``checkpoint.load`` fault site (core/faults.py)
    drives this path deterministically in tests and chaos soak.
  - **retention**: keep the newest ``keep`` checkpoints.
  - **data-iterator state** is stored in the manifest, so restart resumes
    the input stream exactly.
  - **preemption**: ``SignalCheckpointer`` flips a flag on SIGTERM; the
    trainer checks it at step boundaries and checkpoints before exit.

Packed serving artifacts (the ``launch.quantize`` → ``launch.serve``
hand-off) get the same guarantee through :func:`save_artifact` /
:func:`load_artifact`: an atomically-written pickle plus a
``<path>.manifest.json`` sidecar holding the payload sha256; corruption
raises :class:`ArtifactIntegrityError` at load.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import pickle
import shutil
import signal
import threading
import time
import warnings
import zlib
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import faults

#: manifest schema written by Checkpointer.save; v1 (pre-integrity) loads
#: are tolerated (no crc fields to verify), anything newer is refused
CHECKPOINT_SCHEMA = 2
#: sidecar schema written by save_artifact
ARTIFACT_SCHEMA = 1


class CheckpointIntegrityError(RuntimeError):
    """A checkpoint failed verification at load: unreadable npz/manifest,
    per-leaf crc32 mismatch, or a schema this code does not understand.
    Typed so callers (quant.resume=auto, the serving supervisor) can
    distinguish *corruption* from *absence* or *staleness*."""


class ArtifactIntegrityError(CheckpointIntegrityError):
    """A packed serving artifact failed its sha256 sidecar check."""


def _fire_load_fault(what: str) -> None:
    """``checkpoint.load`` site: mode ``corrupt`` surfaces as the typed
    integrity error (exactly what real bit-rot produces), any other mode
    is a kill (FaultError)."""
    spec = faults.poll("checkpoint.load")
    if spec is not None:
        if spec.mode == "corrupt":
            raise CheckpointIntegrityError(
                f"{what}: injected corruption (checkpoint.load:corrupt)")
        raise faults.FaultError("checkpoint.load", spec.mode,
                                faults.PLANE.hits["checkpoint.load"])


# np.savez silently stores ml_dtypes arrays (bfloat16, ...) as raw void
# records ("|V2"), which np.load cannot interpret. Encode such leaves as a
# same-width integer view and record the logical dtype in the manifest;
# decode restores the view. Bit-exact both ways.
_NPZ_VIEW_CODEC: Dict[str, str] = {"bfloat16": "uint16"}


def _npz_encode(a: np.ndarray) -> np.ndarray:
    view = _NPZ_VIEW_CODEC.get(str(a.dtype))
    return a.view(view) if view is not None else a


def _npz_decode(a: np.ndarray, dtype_str: str) -> np.ndarray:
    if dtype_str in _NPZ_VIEW_CODEC:
        import ml_dtypes  # noqa: F401  (registers the dtype name with numpy)
        return a.view(np.dtype(dtype_str))
    return a


def _tree_paths(tree: Any) -> List[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]

    def pstr(path):
        parts = []
        for p in path:
            if hasattr(p, "key"):
                parts.append(str(p.key))
            elif hasattr(p, "idx"):
                parts.append(str(p.idx))
            else:
                parts.append(str(p))
        return "/".join(parts)

    return [(pstr(path), leaf) for path, leaf in flat]


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3,
                 async_write: bool = True):
        self.dir = directory
        self.keep = keep
        self.async_write = async_write
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None
        os.makedirs(directory, exist_ok=True)

    # -- save ---------------------------------------------------------------
    def save(self, step: int, tree: Any,
             extra: Optional[Dict[str, Any]] = None) -> None:
        """Snapshot to host, then write (async by default)."""
        self.wait()
        named = _tree_paths(tree)
        # host snapshot NOW (so training can mutate device arrays after)
        arrays = {name: np.asarray(jax.device_get(leaf))
                  for name, leaf in named}
        treedef = jax.tree_util.tree_structure(tree)
        encoded = {n: _npz_encode(a) for n, a in arrays.items()}
        # crc32 over the *encoded* bytes — the representation that actually
        # lands on disk, so verification at load needs no decode first
        manifest = {
            "schema": CHECKPOINT_SCHEMA,
            "step": int(step),
            "time": time.time(),
            "treedef": str(treedef),
            "leaves": {n: {"shape": list(a.shape), "dtype": str(a.dtype),
                           "crc32": zlib.crc32(
                               np.ascontiguousarray(encoded[n]).tobytes())}
                       for n, a in arrays.items()},
            "extra": extra or {},
        }
        arrays = encoded

        def write():
            try:
                final = os.path.join(self.dir, f"step_{step:09d}")
                tmp = final + ".tmp"
                if os.path.exists(tmp):
                    shutil.rmtree(tmp)
                os.makedirs(tmp)
                np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
                with open(os.path.join(tmp, "manifest.json"), "w") as f:
                    json.dump(manifest, f, indent=1)
                    f.flush()
                    os.fsync(f.fileno())
                if os.path.exists(final):
                    shutil.rmtree(final)
                os.rename(tmp, final)
                # atomic LATEST pointer
                ltmp = os.path.join(self.dir, "LATEST.tmp")
                with open(ltmp, "w") as f:
                    f.write(os.path.basename(final))
                    f.flush()
                    os.fsync(f.fileno())
                os.replace(ltmp, os.path.join(self.dir, "LATEST"))
                self._gc()
            except BaseException as e:   # surfaced on next save/wait
                self._error = e

        if self.async_write:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()
        else:
            write()
            self._raise_if_failed()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        self._raise_if_failed()

    def _raise_if_failed(self):
        if self._error is not None:
            e, self._error = self._error, None
            raise RuntimeError(f"async checkpoint write failed: {e}") from e

    def _gc(self) -> None:
        steps = sorted(d for d in os.listdir(self.dir)
                       if d.startswith("step_") and not d.endswith(".tmp"))
        for d in steps[:-self.keep] if self.keep > 0 else []:
            shutil.rmtree(os.path.join(self.dir, d), ignore_errors=True)

    # -- restore ------------------------------------------------------------
    def latest_step(self) -> Optional[int]:
        p = os.path.join(self.dir, "LATEST")
        if not os.path.exists(p):
            return None
        with open(p) as f:
            name = f.read().strip()
        if not os.path.isdir(os.path.join(self.dir, name)):
            return None
        return int(name.split("_")[1])

    def _read_verified(self, step: int
                       ) -> Tuple[Dict[str, Any], Dict[str, np.ndarray]]:
        """Read + verify one step dir: manifest schema check, every leaf
        materialized, crc32 verified (schema >= 2). Any unreadable file,
        npz member, or checksum mismatch raises
        :class:`CheckpointIntegrityError` — the typed "this checkpoint is
        damaged" signal, distinct from FileNotFoundError (absence)."""
        d = os.path.join(self.dir, f"step_{step:09d}")
        _fire_load_fault(d)
        try:
            with open(os.path.join(d, "manifest.json")) as f:
                manifest = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            raise CheckpointIntegrityError(
                f"{d}: unreadable manifest ({e!r})") from e
        schema = manifest.get("schema", 1)
        if schema > CHECKPOINT_SCHEMA:
            raise CheckpointIntegrityError(
                f"{d}: manifest schema {schema} is newer than supported "
                f"{CHECKPOINT_SCHEMA}")
        encoded: Dict[str, np.ndarray] = {}
        try:
            # np.load on an npz is lazy; materializing each member runs the
            # zip CRC as a side effect, so truncation and byte flips in the
            # container surface here as BadZipFile/zlib errors
            data = np.load(os.path.join(d, "arrays.npz"))
            for name in manifest["leaves"]:
                encoded[name] = data[name]
        except CheckpointIntegrityError:
            raise
        except Exception as e:     # noqa: BLE001 — wrapped as typed error
            raise CheckpointIntegrityError(
                f"{d}: unreadable arrays.npz ({e!r})") from e
        for name, meta in manifest["leaves"].items():
            want = meta.get("crc32")
            if want is None:       # schema-1 checkpoint: nothing to verify
                continue
            got = zlib.crc32(np.ascontiguousarray(encoded[name]).tobytes())
            if got != want:
                raise CheckpointIntegrityError(
                    f"{d}: leaf {name!r} crc32 mismatch "
                    f"(stored {want}, recomputed {got})")
        return manifest, encoded

    def restore(self, tree_like: Any, step: Optional[int] = None,
                shardings: Any = None) -> Tuple[Any, Dict[str, Any]]:
        """Load into the structure of ``tree_like``; reshard if given.

        Elastic: the stored arrays are host-global; ``shardings`` may be for
        a different mesh than the one that saved.
        """
        self.wait()
        if step is None:
            step = self.latest_step()
            if step is None:
                raise FileNotFoundError(f"no checkpoint in {self.dir}")
        manifest, data = self._read_verified(step)
        named = _tree_paths(tree_like)
        leaves = []
        for name, like in named:
            if name not in data:
                raise KeyError(f"checkpoint missing leaf {name!r}")
            arr = _npz_decode(data[name], manifest["leaves"][name]["dtype"])
            if tuple(arr.shape) != tuple(like.shape):
                raise ValueError(f"{name}: shape {arr.shape} != "
                                 f"{like.shape} (elastic restore reshards "
                                 "devices, not parameter shapes)")
            leaves.append(jnp.asarray(arr, dtype=like.dtype))
        treedef = jax.tree_util.tree_structure(tree_like)
        tree = jax.tree_util.tree_unflatten(treedef, leaves)
        if shardings is not None:
            tree = jax.device_put(tree, shardings)
        return tree, manifest["extra"]

    def load_arrays(self, step: Optional[int] = None
                    ) -> Tuple[Dict[str, np.ndarray], Dict[str, Any]]:
        """Blind restore: flat ``{path: host array}`` + the extra dict.

        Unlike :meth:`restore` this needs no ``tree_like`` — callers that
        rebuild dynamic structures from the stored paths (the quantize
        resume path reconstructs stream/param trees the fresh process has
        not materialized yet) use this.
        """
        self.wait()
        if step is None:
            step = self.latest_step()
            if step is None:
                raise FileNotFoundError(f"no checkpoint in {self.dir}")
        manifest, data = self._read_verified(step)
        out = {name: _npz_decode(data[name], meta["dtype"])
               for name, meta in manifest["leaves"].items()}
        return out, manifest["extra"]


# ---------------------------------------------------------------------------
# Packed serving artifacts (pickle + sha256 sidecar)
# ---------------------------------------------------------------------------

def artifact_manifest_path(path: str) -> str:
    return path + ".manifest.json"


def save_artifact(path: str, tree: Any,
                  extra: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
    """Atomically write a pickled pytree + integrity sidecar.

    The sidecar (``<path>.manifest.json``) records the payload sha256 and
    a schema version; :func:`load_artifact` refuses a payload whose digest
    does not match — a flipped byte in a packed int4 artifact is a typed
    :class:`ArtifactIntegrityError`, never a silent load. Returns the
    manifest dict."""
    payload = pickle.dumps(jax.device_get(tree),
                           protocol=pickle.HIGHEST_PROTOCOL)
    manifest = {
        "schema": ARTIFACT_SCHEMA,
        "sha256": hashlib.sha256(payload).hexdigest(),
        "bytes": len(payload),
        "time": time.time(),
        "extra": extra or {},
    }
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(payload)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)
    mpath = artifact_manifest_path(path)
    mtmp = mpath + ".tmp"
    with open(mtmp, "w") as f:
        json.dump(manifest, f, indent=1)
        f.flush()
        os.fsync(f.fileno())
    os.replace(mtmp, mpath)
    return manifest


def load_artifact(path: str) -> Any:
    """Load a pickled artifact through its integrity sidecar.

    Verifies the sidecar sha256 before unpickling; raises
    :class:`ArtifactIntegrityError` on digest mismatch, unreadable
    sidecar, unsupported schema, or an unpicklable payload. A missing
    sidecar (pre-manifest artifact) loads with a warning — legacy files
    keep working, new writes are always covered."""
    _fire_load_fault(path)
    with open(path, "rb") as f:
        payload = f.read()
    mpath = artifact_manifest_path(path)
    if not os.path.exists(mpath):
        warnings.warn(
            f"{path}: no integrity manifest sidecar — loading unchecked "
            "(legacy artifact; re-save with save_artifact to cover it)",
            RuntimeWarning, stacklevel=2)
    else:
        try:
            with open(mpath) as f:
                manifest = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            raise ArtifactIntegrityError(
                f"{mpath}: unreadable manifest ({e!r})") from e
        schema = manifest.get("schema", 1)
        if schema > ARTIFACT_SCHEMA:
            raise ArtifactIntegrityError(
                f"{path}: artifact schema {schema} is newer than supported "
                f"{ARTIFACT_SCHEMA}")
        digest = hashlib.sha256(payload).hexdigest()
        if manifest.get("sha256") != digest:
            raise ArtifactIntegrityError(
                f"{path}: sha256 mismatch (manifest "
                f"{manifest.get('sha256')!r}, payload {digest!r}) — "
                "artifact is corrupt or was modified after save")
    try:
        return pickle.loads(payload)
    except Exception as e:         # noqa: BLE001 — wrapped as typed error
        raise ArtifactIntegrityError(
            f"{path}: unpicklable artifact ({e!r})") from e


class SignalCheckpointer:
    """SIGTERM/SIGINT → request checkpoint at the next step boundary."""

    def __init__(self):
        self.requested = False
        self._orig: Dict[int, Any] = {}

    def install(self) -> "SignalCheckpointer":
        for sig in (signal.SIGTERM,):
            self._orig[sig] = signal.signal(sig, self._handler)
        return self

    def _handler(self, signum, frame):
        self.requested = True

    def uninstall(self) -> None:
        for sig, h in self._orig.items():
            signal.signal(sig, h)
        self._orig.clear()
