"""Distribution substrate: sharding rules, checkpointing, pipeline
parallelism, gradient compression."""
