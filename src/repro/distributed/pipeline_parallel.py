"""GPipe pipeline parallelism over the ``pod`` axis (shard_map + ppermute).

At 2+ pods the ``pod`` axis crosses DCN; instead of FSDP/TP traffic per
layer, PP sends only microbatch boundary activations between pods — the
classic reason to pipeline across slow links. This module implements
schedule-level GPipe:

  - the layer stack is split into ``n_stages`` contiguous stages, one per
    pod-axis index; every device holds only its stage's parameters
    (stage-stacked leaves sharded on the leading stage dim);
  - a microbatch loop runs stages in lockstep: at tick ``t`` stage ``s``
    processes microbatch ``t − s`` (bubble fraction ``(S−1)/(T+S−1)``);
  - boundary activations move stage→stage+1 with ``lax.ppermute``.

The dry-run proves this lowers and partitions on the (pod, data, model)
mesh; tests/test_pipeline_parallel.py checks numeric equivalence of the
2-stage pipeline against the plain stacked forward on a CPU mesh.

This is the explicit-collective path; the default train config uses GSPMD
(DP×TP×FSDP) which XLA schedules with overlap. PP is the beyond-paper
option for DCN-limited multi-pod scaling (EXPERIMENTS.md §Perf discusses
when each wins).
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map


def gpipe_forward(mesh: Mesh, stage_fn: Callable[[Any, jax.Array], jax.Array],
                  stage_params: Any, x: jax.Array, *,
                  n_microbatches: int, axis: str = "pod") -> jax.Array:
    """Run ``stage_fn`` as a GPipe pipeline over ``axis``.

    stage_params: pytree with leading (n_stages,) dim on every leaf (sharded
    over ``axis``). x: (B, ...) global batch (sharded over ``axis`` is NOT
    required; microbatching happens on the leading dim).
    Returns stage_{S-1}(…stage_0(x)) for the full batch.
    """
    n_stages = mesh.shape[axis]
    assert x.shape[0] % n_microbatches == 0
    mb = x.shape[0] // n_microbatches

    def body(params_local, x_local):
        # params_local: this stage's params (leading dim 1) ; x_local: full x
        params_me = jax.tree_util.tree_map(lambda a: a[0], params_local)
        s = jax.lax.axis_index(axis)
        micro = x_local.reshape(n_microbatches, mb, *x_local.shape[1:])
        n_ticks = n_microbatches + n_stages - 1
        buf = jnp.zeros_like(micro[0])
        outs = jnp.zeros_like(micro)

        def tick(t, carry):
            buf, outs = carry
            # stage s works on microbatch t - s when 0 <= t-s < n_micro
            m_idx = t - s
            active = (m_idx >= 0) & (m_idx < n_microbatches)
            x_in = jnp.where(s == 0,
                             micro[jnp.clip(m_idx, 0, n_microbatches - 1)],
                             buf)
            y = stage_fn(params_me, x_in)
            y = jnp.where(active, y, jnp.zeros_like(y))
            # last stage records its finished microbatch
            outs = jax.lax.cond(
                active & (s == n_stages - 1),
                lambda o: o.at[jnp.clip(m_idx, 0, n_microbatches - 1)].set(y),
                lambda o: o, outs)
            # everyone passes forward (ring; the wrap-around is ignored)
            perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]
            buf = jax.lax.ppermute(y, axis, perm)
            return buf, outs

        _, outs = jax.lax.fori_loop(0, n_ticks, tick, (buf, outs))
        # only the last stage holds real outputs; broadcast via psum of the
        # masked buffer (other stages contribute zeros)
        outs = jnp.where(s == n_stages - 1, outs, jnp.zeros_like(outs))
        outs = jax.lax.psum(outs, axis)
        return outs.reshape(x_local.shape)

    other_axes = [a for a in mesh.axis_names if a != axis]
    pspec = P(axis)
    return shard_map(
        body, mesh=mesh,
        in_specs=(P(axis), P(*([None] * x.ndim))),
        out_specs=P(*([None] * x.ndim)),
        check_rep=False,
    )(stage_params, x)


def stack_stages(layer_params_list, n_stages: int):
    """Group per-layer params into ``n_stages`` stage-stacked pytrees.

    Layers must divide evenly; each stage applies its chunk sequentially.
    """
    n = len(layer_params_list)
    assert n % n_stages == 0, (n, n_stages)
    per = n // n_stages
    stages = []
    for s in range(n_stages):
        chunk = layer_params_list[s * per:(s + 1) * per]
        stages.append(jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs), *chunk))
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *stages), per


def make_stage_fn(layer_apply: Callable[[Any, jax.Array], jax.Array],
                  per_stage: int):
    """stage_fn scanning ``per_stage`` stacked layers."""

    def stage_fn(stage_params, x):
        def one(h, lp):
            return layer_apply(lp, h), None
        y, _ = jax.lax.scan(one, x, stage_params)
        return y

    return stage_fn
