"""Gradient compression for explicit-DP all-reduce, with error feedback.

Under plain GSPMD jit the gradient all-reduce is emitted by XLA and runs in
fp32/bf16; compression applies when data parallelism is *explicit*
(shard_map over the ``data`` axis — used by the PP driver and available as a
trainer mode). Two codecs:

  - ``bf16``: round gradients to bfloat16 before ``psum`` (2× bytes).
  - ``int8``: per-tensor-block absmax int8 (4× bytes) — the same block
    quantization the paper applies to weights, applied to the wire format.

Both keep an **error-feedback** accumulator: ``e ← g − dec(enc(g + e))``,
so the compression bias doesn't accumulate over steps (Karimireddy et al.);
without it int8 all-reduce visibly degrades convergence (tested).

The int8 block codec itself lives in :mod:`repro.kernels.kv_codec` — one
implementation shared with the quantized decode KV cache, with the block
size parameterized (the wire default stays :data:`kv_codec.WIRE_BLOCK` =
256, pinned bitwise-unchanged in ``tests/test_kv_codec.py``).
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import kv_codec

_BLOCK = kv_codec.WIRE_BLOCK


def _enc_int8(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    return kv_codec.enc_int8(g, block=_BLOCK)


def _dec_int8(q: jax.Array, scale: jax.Array, shape) -> jax.Array:
    return kv_codec.dec_int8(q, scale, shape, block=_BLOCK)


def compress_psum(grads: Any, axis_name: str, method: str = "none",
                  err: Optional[Any] = None) -> Tuple[Any, Any]:
    """All-reduce-mean ``grads`` over ``axis_name`` with optional
    compression + error feedback. Returns (mean_grads, new_err).

    Must be called inside ``shard_map`` with ``axis_name`` bound.
    """
    n = jax.lax.psum(1, axis_name)

    if method == "none":
        out = jax.tree_util.tree_map(
            lambda g: jax.lax.psum(g, axis_name) / n, grads)
        return out, err

    if err is None:
        err = jax.tree_util.tree_map(
            lambda g: jnp.zeros(g.shape, jnp.float32), grads)

    def one(g, e):
        gf = g.astype(jnp.float32) + e
        if method == "bf16":
            sent = gf.astype(jnp.bfloat16)
            recon = sent.astype(jnp.float32)
            new_e = gf - recon
            red = jax.lax.psum(sent.astype(jnp.float32), axis_name) / n
        elif method == "int8":
            q, s = _enc_int8(gf)
            recon = _dec_int8(q, s, gf.shape)
            new_e = gf - recon
            # wire format: int8 payload is what travels; psum models the
            # summed dequantized tensor (ring all-reduce sums payloads)
            red = jax.lax.psum(recon, axis_name) / n
        else:
            raise ValueError(method)
        return red, new_e

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_e = treedef.flatten_up_to(err)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (treedef.unflatten([o[0] for o in outs]),
            treedef.unflatten([o[1] for o in outs]))
