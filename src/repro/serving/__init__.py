"""Serving substrate: prefill/decode engine with quantized-weight path."""
