"""Supervised serving: engine crash recovery by deterministic replay.

``SupervisedEngine`` wraps :class:`~repro.serving.scheduler.ContinuousEngine`
with the one guarantee the engine itself cannot provide: surviving its own
death. The engine hardens *within* a tick (deadlines, NaN quarantine,
kernel degradation — docs/SERVING.md §Failure handling); the supervisor
hardens the tick loop itself:

- **crash detection** — any exception escaping ``step()`` (including the
  ``serve.engine_step`` kill-type fault site, which fires before any tick
  mutation), or a watchdog trip: a tick whose ``clock()`` span exceeds
  ``serve.step_timeout_s`` is treated as hung (same injectable clock the
  deadline machinery runs on, so tests and the bench drive it virtually).
- **recovery state** — host-side metadata only, maintained at tick
  boundaries from the engine's own ``StepReport``: the original prompt
  batch (host copies), per-request budget/eos/deadline, and every token
  emitted so far. No KV tensors are ever snapshotted — they are
  recomputable, which is the entire point.
- **deterministic replay** — greedy decode is deterministic and
  schedule-independent per sequence (pinned continuous == static in
  tests/test_serving.py), so resubmitting prompt-plus-emitted-prefix to a
  fresh engine produces token-identical continuations. The supervisor
  rebuilds the engine (fresh jits; params re-read through the
  integrity-checked ``distributed.checkpoint.load_artifact`` path when a
  ``params_path`` is given) and resubmits every in-flight request in its
  original submission order, with the remaining token budget and the
  remaining deadline. Pinned in tests/test_supervisor.py: a mid-trace
  ``serve.engine_step`` kill completes every non-expired request with
  outputs token-identical to the fault-free run.
- **bounded restarts** — ``serve.max_restarts`` rebuilds, then a crash
  loop surfaces as :class:`EngineRestartExhausted` (an explicit terminal
  error). Every recovery is counted in :meth:`engine_stats`
  (``restarts``, ``watchdog_trips``, ``replayed_requests``,
  ``recovered_completions``), never silent.

Temperature > 0 is *not* bit-matched across a restart: sampling draws
from a per-request key stream keyed by engine-local rids, which a fresh
engine restarts. Greedy (``serve.temperature=0``) is the deployment
configuration the replay guarantee covers.
"""
from __future__ import annotations

import time
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from repro.config import Config
from repro.distributed.checkpoint import load_artifact
from repro.serving.scheduler import ContinuousEngine, FinishedSeq, StepReport


class EngineRestartExhausted(RuntimeError):
    """The supervisor hit ``serve.max_restarts`` engine rebuilds — a crash
    loop is surfaced as a terminal error instead of an infinite retry."""


class _Tracked:
    """Host-side recoverable state for one in-flight request."""

    __slots__ = ("rid", "batch", "max_new", "eos_id", "deadline",
                 "prompt_len", "emitted", "replay_base", "replays")

    def __init__(self, rid: int, batch: Dict[str, np.ndarray], max_new: int,
                 eos_id: int, deadline: float, prompt_len: int):
        self.rid = rid
        self.batch = batch              # host copies of the submitted batch
        self.max_new = max_new
        self.eos_id = eos_id
        self.deadline = deadline        # absolute clock() time; inf = none
        self.prompt_len = prompt_len
        self.emitted: List[int] = []    # every usable token so far
        self.replay_base: List[int] = []   # emitted prefix at last replay
        self.replays = 0


class SupervisedEngine:
    """Crash-recovering wrapper around :class:`ContinuousEngine`.

    Drop-in for the engine's ``submit``/``cancel``/``step``/``run``/
    ``engine_stats`` surface, with supervisor-scope rids (stable across
    engine rebuilds)."""

    def __init__(self, cfg: Config, params: Any = None, *,
                 max_len: Optional[int] = None, seed: int = 0,
                 clock: Optional[Callable[[], float]] = None,
                 params_path: Optional[str] = None):
        if params is None:
            if params_path is None:
                raise ValueError("need params or params_path")
            params = load_artifact(params_path)
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self.seed = seed
        self.clock = clock or time.monotonic
        self.params_path = params_path
        self._tracked: Dict[int, _Tracked] = {}
        self._sup_of: Dict[int, int] = {}   # engine rid -> supervisor rid
        self._eng_of: Dict[int, int] = {}   # supervisor rid -> engine rid
        self._next_rid = 0
        self.stats: Dict[str, int] = {
            "restarts": 0, "watchdog_trips": 0, "replayed_requests": 0,
            "recovered_completions": 0, "params_reloads": 0,
        }
        # failure counters folded in from engines that died (the live
        # engine's stats are added on top in engine_stats())
        self._stats_acc: Dict[str, int] = {}
        self._fallbacks_acc: Dict[str, int] = {}
        self._eng = self._make_engine()

    def _make_engine(self) -> ContinuousEngine:
        return ContinuousEngine(self.cfg, self.params, max_len=self.max_len,
                                seed=self.seed, clock=self.clock)

    # -- observability -------------------------------------------------------

    @property
    def lanes(self) -> int:
        return self._eng.lanes

    @property
    def active(self) -> int:
        return self._eng.active

    @property
    def idle(self) -> bool:
        return self._eng.idle

    def engine_stats(self) -> Dict[str, Any]:
        """Live-engine counters + counters inherited from crashed engines +
        the supervisor's own recovery counters — nothing resets to zero
        just because the engine was rebuilt."""
        s: Dict[str, Any] = dict(self._stats_acc)
        for k, v in self._eng.stats.items():
            s[k] = s.get(k, 0) + v
        s["w4a16_impl"] = self._eng._impl
        s["kv_impl"] = self._eng._kv_impl
        fb = dict(self._fallbacks_acc)
        for k, v in self._eng._kernel_fallbacks.items():
            fb[k] = fb.get(k, 0) + v
        s["kernel_fallbacks"] = fb
        s.update(self.stats)
        return s

    # -- request surface -----------------------------------------------------

    @staticmethod
    def _prompt_positions(batch: Dict[str, Any]) -> int:
        """Decoder prompt positions incl. frontend embeds (matches the
        engine's ``h.shape[1]`` at admit; enc-dec frames live on the
        encoder side and add none)."""
        n = int(batch["tokens"].shape[1])
        if "frames" not in batch and batch.get("embeds") is not None:
            n += int(batch["embeds"].shape[1])
        return n

    def submit(self, batch: Dict[str, Any], *,
               max_new_tokens: Optional[int] = None,
               eos_id: int = -1,
               timeout_s: Optional[float] = None) -> int:
        """Same contract as ``ContinuousEngine.submit`` (QueueFullError on a
        full admission queue), returning a supervisor-scope rid that stays
        valid across engine rebuilds."""
        mnt = max_new_tokens or self.cfg.serve.max_new_tokens
        tmo = self.cfg.serve.request_timeout_s if timeout_s is None \
            else timeout_s
        # engine submit first: a rejected request is never tracked
        eng_rid = self._eng.submit(batch, max_new_tokens=mnt, eos_id=eos_id,
                                   timeout_s=tmo)
        deadline = self.clock() + tmo if tmo and tmo > 0 else float("inf")
        host = {k: (None if v is None else np.asarray(jax.device_get(v)))
                for k, v in batch.items()}
        rid = self._next_rid
        self._next_rid += 1
        self._tracked[rid] = _Tracked(rid, host, mnt, eos_id, deadline,
                                      self._prompt_positions(batch))
        self._sup_of[eng_rid] = rid
        self._eng_of[rid] = eng_rid
        return rid

    def cancel(self, rid: int) -> Optional[FinishedSeq]:
        t = self._tracked.get(rid)
        eng_rid = self._eng_of.get(rid)
        if t is None or eng_rid is None:
            return None
        f = self._eng.cancel(eng_rid)
        if f is None:                   # engine lost it; finish from tracking
            f = FinishedSeq(eng_rid, np.zeros((0,), np.int32), 0, 0,
                            "cancelled")
        return self._translate_finished(f)

    # -- the supervised tick -------------------------------------------------

    def step(self) -> StepReport:
        """One supervised tick: run the engine's tick; on an escaped
        exception or a watchdog trip, rebuild and replay. A watchdog trip
        absorbs the (completed, just slow) report *first* so its tokens are
        not replayed twice."""
        t0 = self.clock()
        try:
            rep = self._eng.step()
        except Exception as e:          # noqa: BLE001 — the contract is
            # "any exception escaping step()" = engine death
            return self._recover(e)
        rep = self._absorb(rep)
        wd = self.cfg.serve.step_timeout_s
        if wd and wd > 0 and (self.clock() - t0) > wd:
            self.stats["watchdog_trips"] += 1
            rec = self._recover(None)
            return rep._replace(finished=rep.finished + rec.finished,
                                active=rec.active)
        return rep

    def run(self) -> Dict[int, FinishedSeq]:
        """Drain: tick until every tracked request has finished."""
        done: Dict[int, FinishedSeq] = {}
        while not self.idle:
            for f in self.step().finished:
                done[f.rid] = f
        return done

    # -- internals -----------------------------------------------------------

    def _absorb(self, rep: StepReport) -> StepReport:
        """Record emitted tokens into the host-side tracking state and
        translate the report to supervisor rids."""
        sup = self._sup_of
        first_tokens: List[tuple] = []
        decoded: List[tuple] = []
        for erid, tok in rep.first_tokens:
            rid = sup.get(erid)
            if rid is None:
                continue
            t = self._tracked[rid]
            t.emitted.append(int(tok))
            # a replayed request's "first token" from the fresh engine is
            # really continuation token len(replay_base)+1 — report it as
            # decoded so TTFT consumers never see a second first-token
            if t.replay_base:
                decoded.append((rid, tok))
            else:
                first_tokens.append((rid, tok))
        for erid, tok in rep.decoded:
            rid = sup.get(erid)
            if rid is None:
                continue
            self._tracked[rid].emitted.append(int(tok))
            decoded.append((rid, tok))
        finished = [self._translate_finished(f) for f in rep.finished]
        finished = [f for f in finished if f is not None]
        admitted = [sup[e] for e in rep.admitted if e in sup]
        prefill_rid = sup.get(rep.prefill_rid) \
            if rep.prefill_rid is not None else None
        return StepReport(admitted, prefill_rid, first_tokens, decoded,
                          finished, rep.active, rep.lanes)

    def _translate_finished(self, f: FinishedSeq) -> Optional[FinishedSeq]:
        rid = self._sup_of.pop(f.rid, None)
        if rid is None:
            return None
        self._eng_of.pop(rid, None)
        t = self._tracked.pop(rid, None)
        if t is None:
            return None
        base = np.asarray(t.replay_base, np.int32)
        tokens = np.concatenate([base, np.asarray(f.tokens, np.int32)])
        if t.replays and f.status == "ok":
            self.stats["recovered_completions"] += 1
        return FinishedSeq(rid, tokens, int(tokens.shape[0]), t.prompt_len,
                           f.status)

    def _recover(self, cause: Optional[BaseException]) -> StepReport:
        """Rebuild the engine and replay every in-flight request.

        Does not run a tick itself — the caller's next ``step()`` resumes
        decoding, so ticks-to-recover stays measurable. Returns a report
        whose ``finished`` carries requests whose deadline expired while
        the engine was down (terminal status ``timeout``, counted)."""
        if self.stats["restarts"] >= self.cfg.serve.max_restarts:
            raise EngineRestartExhausted(
                f"engine crashed again after {self.stats['restarts']} "
                f"restarts (serve.max_restarts="
                f"{self.cfg.serve.max_restarts}); giving up with "
                f"{len(self._tracked)} requests in flight") from cause
        self.stats["restarts"] += 1
        # fold the dead engine's counters into the accumulator — restart
        # must never zero observability
        for k, v in self._eng.stats.items():
            self._stats_acc[k] = self._stats_acc.get(k, 0) + v
        for k, v in self._eng._kernel_fallbacks.items():
            self._fallbacks_acc[k] = self._fallbacks_acc.get(k, 0) + v
        if self.params_path is not None:
            # integrity-checked re-read: if the artifact rotted on disk,
            # recovery fails loudly (ArtifactIntegrityError) instead of
            # decoding garbage
            self.params = load_artifact(self.params_path)
            self.stats["params_reloads"] += 1
        self._sup_of.clear()
        self._eng_of.clear()
        self._eng = self._make_engine()
        now = self.clock()
        finished: List[FinishedSeq] = []
        for t in sorted(self._tracked.values(), key=lambda x: x.rid):
            if t.deadline <= now:
                # expired while the engine was down: the engine never sees
                # it again, so the supervisor issues the terminal status
                # (and keeps the timeout accounting consistent)
                self._stats_acc["timeout_evictions"] = \
                    self._stats_acc.get("timeout_evictions", 0) + 1
                self._tracked.pop(t.rid)
                base = np.asarray(t.emitted, np.int32)
                finished.append(FinishedSeq(t.rid, base, int(base.shape[0]),
                                            t.prompt_len, "timeout"))
                continue
            batch = {k: (None if v is None else jax.numpy.asarray(v))
                     for k, v in t.batch.items()}
            if t.emitted:
                # prompt + emitted prefix: greedy decode regenerates the
                # continuation token-identically (deterministic replay)
                prefix = np.asarray([t.emitted], np.int32)
                batch["tokens"] = jax.numpy.concatenate(
                    [batch["tokens"], jax.numpy.asarray(prefix)], axis=1)
            t.replay_base = list(t.emitted)
            t.replays += 1
            mnt = t.max_new - len(t.emitted)
            rem = t.deadline - now if np.isfinite(t.deadline) else 0.0
            if mnt <= 0:    # fully emitted but unreported-finished: done
                self._tracked.pop(t.rid)
                toks = np.asarray(t.emitted, np.int32)
                self.stats["recovered_completions"] += 1
                finished.append(FinishedSeq(t.rid, toks, int(toks.shape[0]),
                                            t.prompt_len, "ok"))
                continue
            eng_rid = self._eng.submit(batch, max_new_tokens=mnt,
                                       eos_id=t.eos_id, timeout_s=rem,
                                       force=True)
            self._sup_of[eng_rid] = t.rid
            self._eng_of[t.rid] = eng_rid
            self.stats["replayed_requests"] += 1
        return StepReport([], None, [], [], finished, self._eng.active,
                          self._eng.lanes)
