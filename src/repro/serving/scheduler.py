"""Continuous-batching scheduler (the serving hot path — docs/SERVING.md).

``ContinuousEngine`` keeps a fixed-lane decode batch backed by a slotted KV
cache (``models.transformer.cache_slots_like`` / ``cache_slot_insert`` /
``cache_slot_evict``). Sequences are admitted and evicted mid-flight:

- **submit** queues a request (batch-1 prompt + per-request max_new/eos).
- **step** is one scheduler tick: deficit-driven prefill (chunks of
  ``serve.prefill_chunk`` positions keep running while a decode lane would
  otherwise sit empty, one chunk per tick once supply covers the lanes)
  interleaved with one decode step over every occupied lane. Decode never
  waits for a whole prefill once lanes are fed, so time-to-first-token
  stays bounded under load; finished lanes are reused immediately instead
  of padding the batch to the slowest sequence (the static-batch failure
  mode).

Prefill runs at batch 1 through the incremental engine API
(``engine.prefill_begin/prefill_step/prefill_finish``); on completion the
first token is sampled from the prefill logits and the request's cache is
written into a free lane — the whole lane is overwritten, which is what
makes eviction reuse sound without any cache zeroing.

Greedy decoding is token-identical per sequence to the static
``engine.generate`` baseline (pinned in tests/test_serving.py): every
attention/cache op is row-wise in the batch axis, so lane composition and
per-lane positions don't change a sequence's numerics. Temperature > 0
draws from a per-request key stream (``fold_in(seed, rid)``) and is *not*
bit-matched to the static engine's shared key stream.

EOS convention matches ``engine.generate``: eos itself is never emitted;
``FinishedSeq.tokens`` holds exactly ``steps`` usable tokens.
"""
from __future__ import annotations

import functools
import time
import warnings
from collections import deque
from typing import Any, Callable, Dict, List, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import Config
from repro.core import faults
from repro.kernels import ops as kops
from repro.models import transformer as T
from repro.serving import engine as E


class QueueFullError(RuntimeError):
    """Raised by :meth:`ContinuousEngine.submit` when the admission queue is
    at ``serve.max_queue`` — explicit rejection beats unbounded memory."""


class FinishedSeq(NamedTuple):
    rid: int
    tokens: np.ndarray      # (steps,) generated ids, eos excluded
    steps: int              # == len(tokens)
    prompt_len: int         # decoder prompt positions (incl. frontend)
    status: str = "ok"      # ok | timeout | quarantined | cancelled | error


class _Pending(NamedTuple):
    rid: int
    batch: Dict[str, jax.Array]
    max_new: int
    eos_id: int
    deadline: float = float("inf")   # absolute clock() time, inf = no limit


def _poison_lane(caches: Any, lane: int) -> Any:
    """``serve.decode_step`` fault payload: NaN-fill one lane of the slotted
    KV cache (lane axis is axis 1 on every leaf — transformer.py). The next
    decode step's logits for that lane go non-finite, which is exactly what
    the quarantine guard detects; all other lanes are untouched."""
    def nanfill(a):
        if not jnp.issubdtype(a.dtype, jnp.floating):
            return a
        return a.at[:, lane].set(jnp.nan)
    return jax.tree_util.tree_map(nanfill, caches)


class _Prefill:
    """A request mid-prefill: embedded inputs + batch-1 caches + cursor."""

    def __init__(self, req: _Pending, h: jax.Array, caches: Any):
        self.req = req
        self.h = h
        self.caches = caches
        self.start = 0
        self.h_last = None
        self.first = None       # first sampled token, set at completion

    @property
    def done(self) -> bool:
        return self.start >= self.h.shape[1]


class StepReport(NamedTuple):
    admitted: List[int]         # rids that began prefill this tick
    prefill_rid: Optional[int]  # rid that ran a prefill chunk this tick
    first_tokens: List[tuple]   # (rid, token) sampled from prefill logits
    decoded: List[tuple]        # (rid, token) decode-step emissions
    finished: List[FinishedSeq]
    active: int                 # occupied decode lanes after this tick
    lanes: int


class ContinuousEngine:
    """Slot-based continuous batching over a fixed decode-lane batch."""

    def __init__(self, cfg: Config, params: Any, *,
                 max_len: Optional[int] = None, seed: int = 0,
                 clock: Optional[Callable[[], float]] = None):
        self.cfg = cfg
        self.params = params
        self.lanes = cfg.serve.max_batch
        self.cap = max_len or cfg.model.max_seq_len
        self.seed = seed
        # deadlines run off an injectable clock so the bench can drive
        # timeouts on its virtual-time axis (benchmarks/serving_bench.py)
        self.clock = clock or time.monotonic
        self._impl = cfg.serve.w4a16_impl
        self._kv_impl = cfg.serve.kv_impl
        self._next_rid = 0
        self._queue: deque = deque()
        self._prefill: Optional[_Prefill] = None
        self._ready: deque = deque()        # prefilled, waiting for a lane
        self._caches: Any = None            # slotted decode cache
        # host-side lane table
        self._lane_rid = np.full((self.lanes,), -1, np.int64)
        self._token = np.zeros((self.lanes,), np.int32)
        self._pos = np.zeros((self.lanes,), np.int32)
        self._remaining = np.zeros((self.lanes,), np.int32)
        self._eos = np.full((self.lanes,), -1, np.int32)
        self._deadline = np.full((self.lanes,), np.inf)
        self._out: Dict[int, List[int]] = {}
        self._prompt_len: Dict[int, int] = {}
        self._nstep: Dict[int, int] = {}
        # failure accounting — every eviction/rejection/degradation is
        # counted, never silent (docs/SERVING.md "Failure handling")
        self.stats: Dict[str, int] = {
            "timeout_evictions": 0, "rejections": 0, "cancelled": 0,
            "quarantined": 0, "kernel_degradations": 0,
            "prefill_failures": 0,
        }
        # per-instance trace-time fallback counters (kernels.ops routes
        # notes to the innermost active scope): two engines in one process
        # must not read each other's downgrades out of the module-global
        self._kernel_fallbacks: Dict[str, int] = {}
        self._build_jit()

    def _build_jit(self) -> None:
        """(Re)build the jitted step functions. Called once at init and
        again after a pallas→xla kernel degradation: the w4a16 backend is
        chosen at trace time, so surviving compiled entries must be dropped
        for the new default to take effect."""
        cfg = self.cfg
        self._jit_decode = jax.jit(functools.partial(E.serve_step, cfg))
        # greedy sampling + finite-logits flag fused into the jitted decode
        # step (one dispatch and two (lanes,) transfers per tick instead of
        # logits + host argmax)
        self._jit_decode_guarded = jax.jit(
            functools.partial(E.decode_step_guarded, cfg))
        self._jit_insert = jax.jit(T.cache_slot_insert)
        # prefill pieces are jitted per shape: begin keys on prompt length,
        # step on (chunk length, start) — a small set, since starts are
        # multiples of serve.prefill_chunk
        self._jit_pf_begin = jax.jit(functools.partial(E.prefill_begin, cfg),
                                     static_argnums=(2,))
        self._jit_pf_step = jax.jit(functools.partial(E.prefill_step, cfg),
                                    static_argnums=(2,))
        self._jit_pf_finish = jax.jit(functools.partial(E.prefill_finish,
                                                        cfg))

    def _guarded(self, name: str, *args):
        """Run one jitted piece under the current kernel backends (w4a16
        matmul + int8-KV attention); on a kernel fault, degrade pallas→xla
        (rebuild jits, count, warn) and retry the same call once.
        Already-xla faults and non-kernel faults propagate."""
        with kops.w4a16_default_impl(self._impl), \
                kops.kv_attn_default_impl(self._kv_impl), \
                kops.fallback_scope(self._kernel_fallbacks):
            try:
                return getattr(self, name)(*args)
            except Exception as e:          # noqa: BLE001 — classified below
                if (self._impl == "xla" and self._kv_impl == "xla") \
                        or not E._kernel_fault(e):
                    raise
                self.stats["kernel_degradations"] += 1
                warnings.warn(
                    f"kernel fault in {name} ({e!r}): degrading "
                    "engine to impl='xla'", RuntimeWarning, stacklevel=2)
        self._impl = "xla"
        self._kv_impl = "xla"
        self._build_jit()
        with kops.w4a16_default_impl("xla"), \
                kops.kv_attn_default_impl("xla"), \
                kops.fallback_scope(self._kernel_fallbacks):
            return getattr(self, name)(*args)

    def engine_stats(self) -> Dict[str, Any]:
        """Failure counters + current kernel backend + trace-time fallback
        counters — the observable surface the bench and tests assert on.
        ``kernel_fallbacks`` is *this instance's* scope (kernels.ops
        fallback_scope), not the process-global dict, so two engines in one
        process never report each other's downgrades."""
        s: Dict[str, Any] = dict(self.stats)
        s["w4a16_impl"] = self._impl
        s["kv_impl"] = self._kv_impl
        s["kernel_fallbacks"] = dict(self._kernel_fallbacks)
        return s

    # -- submission --------------------------------------------------------

    def submit(self, batch: Dict[str, jax.Array], *,
               max_new_tokens: Optional[int] = None,
               eos_id: int = -1,
               timeout_s: Optional[float] = None,
               force: bool = False) -> int:
        """Queue one request. ``batch`` is batch-1 ({tokens, embeds?/frames?}).

        Raises :class:`QueueFullError` (counted in ``stats["rejections"]``)
        when ``serve.max_queue > 0`` and that many requests are already
        waiting for admission. ``timeout_s`` (default
        ``serve.request_timeout_s``; 0 = no deadline) starts the request's
        wall-clock budget now — queue wait counts against it.

        ``force=True`` bypasses the queue bound: the supervisor's crash
        replay resubmits every in-flight request at once — requests that
        were already *admitted* (lanes, ready set, prefill) before the
        crash, so re-rejecting them at the admission bound would turn a
        recovery into silent request loss.
        """
        assert batch["tokens"].shape[0] == 1, "submit one sequence at a time"
        max_queue = self.cfg.serve.max_queue
        if not force and max_queue > 0 and len(self._queue) >= max_queue:
            self.stats["rejections"] += 1
            raise QueueFullError(
                f"admission queue full ({len(self._queue)} >= {max_queue})")
        mnt = max_new_tokens or self.cfg.serve.max_new_tokens
        s0 = batch["tokens"].shape[1]
        n_front = batch["embeds"].shape[1] if batch.get("embeds") is not None \
            else 0
        assert s0 + n_front + mnt + 1 <= self.cap, \
            f"request needs {s0 + n_front + mnt + 1} positions, cap={self.cap}"
        tmo = self.cfg.serve.request_timeout_s if timeout_s is None \
            else timeout_s
        deadline = self.clock() + tmo if tmo and tmo > 0 else float("inf")
        rid = self._next_rid
        self._next_rid += 1
        self._queue.append(_Pending(rid, batch, mnt, eos_id, deadline))
        return rid

    def cancel(self, rid: int) -> Optional[FinishedSeq]:
        """Cancel a request wherever it is (queued, mid-prefill, parked,
        decoding). Returns a partial :class:`FinishedSeq` with status
        ``"cancelled"`` (tokens produced so far), or None if ``rid`` is not
        in flight."""
        for q in (self._queue, self._ready):
            for item in list(q):
                item_rid = item.rid if isinstance(item, _Pending) \
                    else item.req.rid
                if item_rid == rid:
                    q.remove(item)
                    self.stats["cancelled"] += 1
                    return self._finish_rid(rid, "cancelled")
        if self._prefill is not None and self._prefill.req.rid == rid:
            self._prefill = None
            self.stats["cancelled"] += 1
            return self._finish_rid(rid, "cancelled")
        lanes = np.nonzero(self._lane_rid == rid)[0]
        if lanes.size:
            self._evict(int(lanes[0]))
            self.stats["cancelled"] += 1
            return self._finish_rid(rid, "cancelled")
        return None

    # -- scheduling --------------------------------------------------------

    @property
    def active(self) -> int:
        return int((self._lane_rid >= 0).sum())

    @property
    def idle(self) -> bool:
        return (not self._queue and self._prefill is None
                and not self._ready and self.active == 0)

    def step(self) -> StepReport:
        """One tick: ≤1 prefill chunk + one decode step over active lanes.

        The w4a16 backend context is installed per jitted call inside
        :meth:`_guarded` (not here) so a mid-tick pallas→xla degradation
        takes effect for the retry of the very call that faulted.

        The ``serve.engine_step`` kill site fires *before* any tick
        mutation, modeling the whole engine dying between ticks — the
        supervisor (serving/supervisor.py) catches the escaped exception,
        rebuilds the engine, and replays in-flight requests.
        """
        faults.fire("serve.engine_step")
        return self._step()

    def _sweep_deadlines(self, finished: List[FinishedSeq]) -> None:
        """Evict every request past its deadline — queued, mid-prefill,
        parked, or decoding. The freed lane is refilled by the normal
        admission path in the same tick."""
        now = self.clock()
        for req in [r for r in self._queue if r.deadline < now]:
            self._queue.remove(req)
            self.stats["timeout_evictions"] += 1
            finished.append(self._finish_rid(req.rid, "timeout"))
        if self._prefill is not None and \
                self._prefill.req.deadline < now:
            self.stats["timeout_evictions"] += 1
            finished.append(self._finish_rid(self._prefill.req.rid,
                                             "timeout"))
            self._prefill = None
        for pf in [p for p in self._ready if p.req.deadline < now]:
            self._ready.remove(pf)
            self.stats["timeout_evictions"] += 1
            finished.append(self._finish_rid(pf.req.rid, "timeout"))
        for i in np.nonzero((self._lane_rid >= 0)
                            & (self._deadline < now))[0]:
            rid = int(self._lane_rid[i])
            self._evict(int(i))
            self.stats["timeout_evictions"] += 1
            finished.append(self._finish_rid(rid, "timeout"))

    def _step(self) -> StepReport:
        admitted: List[int] = []
        first_tokens: List[tuple] = []
        finished: List[FinishedSeq] = []
        prefill_rid = None

        self._sweep_deadlines(finished)

        # refill freed lanes from already-prefilled parked requests
        while self._ready and self.active < self.lanes:
            self._insert(self._ready.popleft())

        # admit: prefill runs concurrently even with every lane busy — a
        # prefill completing with no free lane parks in _ready and is
        # inserted the moment an eviction frees one (no refill latency)
        if self._prefill is None and self._queue:
            admitted.append(self._admit())

        # prefill: deficit-driven. While the next decode tick would leave a
        # lane empty (active + parked supply < lanes), keep running chunks —
        # across request boundaries — so prefill throughput tracks lane
        # drain instead of capping at one chunk per tick (which starves
        # lanes under load). Once supply covers every lane, at most one
        # chunk per tick bounds the prefill latency each decode tick pays.
        # chunk 0 == whole prompt at once.
        ran_chunk = False
        while self._prefill is not None:
            pf = self._prefill
            starved = self.active + len(self._ready) < self.lanes
            if ran_chunk and not starved and self.active > 0:
                break
            chunk = self.cfg.serve.prefill_chunk or pf.h.shape[1]
            c0 = pf.start
            c1 = min(pf.h.shape[1], c0 + chunk)
            try:
                faults.fire("serve.prefill_chunk")
                pf.h_last, pf.caches = self._guarded(
                    "_jit_pf_step", self.params, pf.h[:, c0:c1], c0,
                    pf.caches)
            except faults.FaultError as e:
                if e.site != "serve.prefill_chunk":
                    raise
                # a failed prefill drops only its own request — lanes and
                # parked requests are untouched, the slot is re-admitted
                # from the queue immediately
                self.stats["prefill_failures"] += 1
                finished.append(self._finish_rid(pf.req.rid, "error"))
                self._prefill = None
                if self._queue:
                    admitted.append(self._admit())
                continue
            pf.start = c1
            ran_chunk = True
            prefill_rid = pf.req.rid
            if pf.done:
                first_tokens.extend(self._complete_prefill(pf, finished))
                self._prefill = None
                if self._queue:
                    admitted.append(self._admit())

        # one decode step over every occupied lane
        decoded = self._decode_tick(finished) if self.active else []

        return StepReport(admitted, prefill_rid, first_tokens, decoded,
                          finished, self.active, self.lanes)

    def run(self) -> Dict[int, FinishedSeq]:
        """Drain: tick until every submitted request has finished."""
        done: Dict[int, FinishedSeq] = {}
        while not self.idle:
            for f in self.step().finished:
                done[f.rid] = f
        return done

    # -- internals ---------------------------------------------------------

    def _admit(self) -> int:
        req = self._queue.popleft()
        h, caches = self._guarded("_jit_pf_begin", self.params, req.batch,
                                  self.cap)
        self._prefill = _Prefill(req, h, caches)
        self._prompt_len[req.rid] = h.shape[1]
        return req.rid

    def _key(self, rid: int, step: int) -> jax.Array:
        return jax.random.fold_in(
            jax.random.fold_in(jax.random.PRNGKey(self.seed), rid), step)

    def _complete_prefill(self, pf: _Prefill, finished: List[FinishedSeq]
                          ) -> List[tuple]:
        req = pf.req
        logits = self._guarded("_jit_pf_finish", self.params, pf.h_last)
        first = int(E._sample(self._key(req.rid, 0), logits,
                              self.cfg.serve.temperature)[0])
        if first == req.eos_id:        # eos on the very first sample
            finished.append(FinishedSeq(req.rid, np.zeros((0,), np.int32), 0,
                                        self._prompt_len.pop(req.rid, 0)))
            return []
        self._out[req.rid] = [first]
        self._nstep[req.rid] = 1
        if req.max_new <= 1:
            finished.append(self._finish_rid(req.rid))
            return [(req.rid, first)]
        pf.first = first
        if self.active < self.lanes:
            self._insert(pf)
        else:
            self._ready.append(pf)
        return [(req.rid, first)]

    def _insert(self, pf: _Prefill) -> None:
        req = pf.req
        lane = int(np.nonzero(self._lane_rid < 0)[0][0])
        if self._caches is None:
            self._caches = T.cache_slots_like(pf.caches, self.lanes)
        self._caches = self._guarded("_jit_insert", self._caches, pf.caches,
                                     jnp.int32(lane))
        self._lane_rid[lane] = req.rid
        self._token[lane] = pf.first
        self._pos[lane] = self._prompt_len[req.rid]
        self._remaining[lane] = req.max_new - 1
        self._eos[lane] = req.eos_id
        self._deadline[lane] = req.deadline

    def _finish_rid(self, rid: int, status: str = "ok") -> FinishedSeq:
        toks = np.asarray(self._out.pop(rid, []), np.int32)
        return FinishedSeq(rid, toks, self._nstep.pop(rid, 0),
                           self._prompt_len.pop(rid, 0), status)

    def _decode_tick(self, finished: List[FinishedSeq]) -> List[tuple]:
        temp = self.cfg.serve.temperature
        decoded: List[tuple] = []
        # serve.decode_step fault: poison the first occupied lane's KV cache
        # before the dispatch — the SAME fused step that decodes every lane
        # detects it via the finite-logits flags (no separate checking path
        # to keep honest)
        fspec = faults.poll("serve.decode_step")
        if fspec is not None:
            lane = int(np.nonzero(self._lane_rid >= 0)[0][0])
            self._caches = _poison_lane(self._caches, lane)
        if temp <= 0.0:
            raw_dev, ok_dev, self._caches = self._guarded(
                "_jit_decode_guarded", self.params, jnp.asarray(self._token),
                jnp.asarray(self._pos), self._caches)
            raw = np.asarray(raw_dev)
            ok = np.asarray(ok_dev)
        else:
            logits, self._caches = self._guarded(
                "_jit_decode", self.params, jnp.asarray(self._token),
                jnp.asarray(self._pos), self._caches)
            ok = np.asarray(jnp.all(jnp.isfinite(logits), axis=-1))
            raw = np.array([
                int(E._sample(self._key(int(self._lane_rid[i]),
                                        self._nstep.get(
                                            int(self._lane_rid[i]), 0)),
                              logits[i:i + 1], temp)[0])
                if self._lane_rid[i] >= 0 else 0
                for i in range(self.lanes)], np.int32)
        nan_guard = self.cfg.serve.decode_nan_guard
        for i in np.nonzero(self._lane_rid >= 0)[0]:
            rid = int(self._lane_rid[i])
            if nan_guard and not ok[i]:
                # quarantine: evict only the poisoned lane; its slot is
                # overwritten wholesale on the next admission, and every
                # other lane's numerics are row-wise independent of it
                self._evict(int(i))
                self.stats["quarantined"] += 1
                finished.append(self._finish_rid(rid, "quarantined"))
                continue
            tok = int(raw[i])
            if tok == self._eos[i]:
                self._evict(int(i))
                finished.append(self._finish_rid(rid))
                continue
            self._out[rid].append(tok)
            self._nstep[rid] += 1
            decoded.append((rid, tok))
            self._token[i] = tok
            self._pos[i] += 1
            self._remaining[i] -= 1
            if self._remaining[i] == 0:
                self._evict(int(i))
                finished.append(self._finish_rid(rid))
        return decoded

    def _evict(self, lane: int) -> None:
        # bookkeeping only: cache_slot_insert overwrites the whole lane on
        # the next admission, so zeroing the cache here (cache_slot_evict)
        # would be a pure extra dispatch on the hot path
        self._lane_rid[lane] = -1
        self._token[lane] = 0
        self._pos[lane] = 0
        self._remaining[lane] = 0
        self._eos[lane] = -1
        self._deadline[lane] = np.inf
