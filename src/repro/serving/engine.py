"""Batched serving engine.

``serve_step`` is the unit the dry-run lowers for decode shapes: one new
token for every sequence in the batch against a populated cache. The engine
wraps it in a greedy/temperature generation loop with a ragged-completion
mask (sequences finish independently; finished lanes keep decoding pad
tokens but their outputs are frozen — the standard static-shape batch
pattern).

Weights may be full precision or int4-packed (``QuantizedTensor`` leaves,
produced by core/pipeline.quantize_model) — ``models.linear.dense``
dispatches per leaf, so the same step function serves both and the dry-run
can lower the quantized decode path explicitly (the paper's deployment
claim: §Perf compares both).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import Config
from repro.models import transformer as T


class GenResult(NamedTuple):
    tokens: jax.Array       # (B, max_new) generated ids
    logprobs: jax.Array     # (B, max_new)
    steps: jax.Array        # (B,) tokens actually produced


def serve_step(cfg: Config, params: Any, token: jax.Array, pos: jax.Array,
               caches: Any) -> Tuple[jax.Array, Any]:
    """One decode step (the dry-run unit). token/pos: (B,)."""
    if cfg.model.is_encoder_decoder:
        return T.encdec_decode_step(cfg.model, params, token, pos, caches)
    return T.decode_step(cfg.model, params, token, pos, caches)


def prefill(cfg: Config, params: Any, batch: Dict[str, jax.Array],
            max_len: int) -> Tuple[jax.Array, Any]:
    """Prefill from a batch dict ({tokens, embeds?/frames?})."""
    if cfg.model.is_encoder_decoder:
        return T.encdec_prefill(cfg.model, params, batch["frames"],
                                batch["tokens"], max_len)
    return T.prefill(cfg.model, params, batch["tokens"], max_len,
                     embeds=batch.get("embeds"))


def _sample(key: jax.Array, logits: jax.Array, temperature: float
            ) -> jax.Array:
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(key, logits / temperature).astype(jnp.int32)


def generate(cfg: Config, params: Any, batch: Dict[str, jax.Array], *,
             max_new_tokens: Optional[int] = None, eos_id: int = -1,
             temperature: Optional[float] = None,
             seed: int = 0) -> GenResult:
    """Greedy/temperature generation. Static shapes; jit-compiled loop."""
    sc = cfg.serve
    mnt = max_new_tokens or sc.max_new_tokens
    temp = sc.temperature if temperature is None else temperature
    b, s0 = batch["tokens"].shape
    n_front = batch["embeds"].shape[1] if batch.get("embeds") is not None \
        else 0
    max_len = s0 + n_front + mnt + 1
    logits, caches = prefill(cfg, params, batch, max_len)

    def body(carry, i):
        token, pos, caches, done, key = carry
        key, sub = jax.random.split(key)
        lg, caches = serve_step(cfg, params, token, pos, caches)
        nxt = _sample(sub, lg, temp)
        lp = jax.nn.log_softmax(lg)[jnp.arange(b), nxt]
        nxt = jnp.where(done, 0, nxt)
        newly_done = done | (nxt == eos_id)
        out = (nxt, jnp.where(done, 0.0, lp))
        return (nxt, pos + 1, caches, newly_done, key), out

    first = _sample(jax.random.PRNGKey(seed), logits, temp)
    lp0 = jax.nn.log_softmax(logits)[jnp.arange(b), first]
    pos0 = jnp.full((b,), s0 + n_front, jnp.int32)
    done0 = first == eos_id
    carry = (first, pos0, caches, done0, jax.random.PRNGKey(seed + 1))
    if mnt > 1:
        carry, (toks, lps) = jax.lax.scan(body, carry,
                                          jnp.arange(mnt - 1))
        tokens = jnp.concatenate([first[:, None], toks.T], axis=1)
        logprobs = jnp.concatenate([lp0[:, None], lps.T], axis=1)
    else:
        tokens, logprobs = first[:, None], lp0[:, None]
    steps = jnp.sum((tokens != 0).astype(jnp.int32), axis=1)
    return GenResult(tokens, logprobs, steps)
