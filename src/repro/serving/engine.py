"""Batched serving engine.

``serve_step`` is the unit the dry-run lowers for decode shapes: one new
token for every sequence in the batch against a populated cache. The engine
wraps it in a greedy/temperature generation loop with a ragged-completion
mask (sequences finish independently; finished lanes keep decoding pad
tokens but their outputs are frozen — the standard static-shape batch
pattern).

EOS convention: the eos token itself is never emitted. The step that
samples eos writes token 0 / logprob 0.0 and marks the lane done, so
``tokens[b, :steps[b]]`` is exactly the usable output and a model that
legitimately generates token id 0 is not miscounted (``steps`` comes from
the done mask, not from ``tokens != 0``).

Weights may be full precision or int4-packed (``QuantizedTensor`` leaves,
produced by core/pipeline.quantize_model) — ``models.linear.dense``
dispatches per leaf, so the same step function serves both and the dry-run
can lower the quantized decode path explicitly (the paper's deployment
claim: §Perf compares both). The quantized matmul backend is selected by
``serve.w4a16_impl`` (kernels.ops.w4a16_default_impl trace-time context).

The static-batch loop here is the parity baseline; the continuous-batching
scheduler lives in serving/scheduler.py (docs/SERVING.md).
"""
from __future__ import annotations

import functools
import warnings
from typing import Any, Dict, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import Config
from repro.core import faults
from repro.kernels import ops as kops
from repro.models import transformer as T


class GenResult(NamedTuple):
    tokens: jax.Array       # (B, max_new) generated ids (0 on done lanes)
    logprobs: jax.Array     # (B, max_new)
    steps: jax.Array        # (B,) tokens actually produced (pre-eos)


# -- failure accounting -------------------------------------------------------
#
# Every runtime degradation is counted, never silent (docs/SERVING.md
# "Failure handling"). The static generate() loop below and the continuous
# scheduler both funnel pallas→xla downgrades through this counter.

_ENGINE_STATS: Dict[str, int] = {"kernel_degradations": 0}


def engine_stats() -> Dict[str, int]:
    """Snapshot of engine-level failure counters (see also
    ``kernels.ops.fallback_stats`` for trace-time budget fallbacks)."""
    return dict(_ENGINE_STATS)


def _kernel_fault(e: Exception) -> bool:
    """Is this exception a kernel-path failure worth degrading over?

    Injected faults carry a ``.site`` — only ``kernels.pallas_dispatch``
    counts (other sites must propagate to their own handlers). A real
    exception from inside a pallas dispatch has no site attribute and is
    treated as a kernel fault by the caller that just ran one.
    """
    site = getattr(e, "site", None)
    if site is not None:
        return site == "kernels.pallas_dispatch"
    return True


def decode_step_guarded(cfg: Config, params: Any, token: jax.Array,
                        pos: jax.Array, caches: Any
                        ) -> Tuple[jax.Array, jax.Array, Any]:
    """Greedy decode step with a fused finite-logits flag.

    Returns ``(next_token, ok, caches)`` where ``ok`` is a (B,) bool —
    False on any lane whose logits went non-finite (NaN/Inf poisoning, e.g.
    a corrupted KV lane). One dispatch, two (B,)-sized transfers: the
    quarantine check costs no extra logits round-trip.
    """
    lg, caches = serve_step(cfg, params, token, pos, caches)
    ok = jnp.all(jnp.isfinite(lg), axis=-1)
    return jnp.argmax(lg, axis=-1).astype(jnp.int32), ok, caches


def serve_step(cfg: Config, params: Any, token: jax.Array, pos: jax.Array,
               caches: Any) -> Tuple[jax.Array, Any]:
    """One decode step (the dry-run unit). token/pos: (B,)."""
    if cfg.model.is_encoder_decoder:
        return T.encdec_decode_step(cfg.model, params, token, pos, caches)
    return T.decode_step(cfg.model, params, token, pos, caches)


def cache_dtype(cfg: Config):
    """Decode-cache precision from ``serve.kv_cache``: the ``"int8"``
    string sentinel (quantized codes+scales leaves, models/attention.py)
    or bf16."""
    return "int8" if cfg.serve.kv_cache == "int8" else jnp.bfloat16


def prefill(cfg: Config, params: Any, batch: Dict[str, jax.Array],
            max_len: int) -> Tuple[jax.Array, Any]:
    """Prefill from a batch dict ({tokens, embeds?/frames?}).

    ``serve.prefill_chunk > 0`` runs the prompt through the blocks in
    chunks of that many positions (bounded per-step work for interleaving
    with decode); logits/caches match single-shot prefill.
    """
    chunk = cfg.serve.prefill_chunk
    cdt = cache_dtype(cfg)
    if cfg.model.is_encoder_decoder:
        if chunk > 0:
            return T.encdec_prefill_chunked(cfg.model, params,
                                            batch["frames"], batch["tokens"],
                                            max_len, chunk, cache_dtype=cdt)
        return T.encdec_prefill(cfg.model, params, batch["frames"],
                                batch["tokens"], max_len, cache_dtype=cdt)
    if chunk > 0:
        return T.prefill_chunked(cfg.model, params, batch["tokens"], max_len,
                                 chunk, embeds=batch.get("embeds"),
                                 cache_dtype=cdt)
    return T.prefill(cfg.model, params, batch["tokens"], max_len,
                     embeds=batch.get("embeds"), cache_dtype=cdt)


def prefill_begin(cfg: Config, params: Any, batch: Dict[str, jax.Array],
                  max_len: int) -> Tuple[jax.Array, Any]:
    """Incremental prefill setup (continuous batching): returns the full
    embedded input ``h`` and empty caches; feed ``h`` slices through
    :func:`prefill_step` one chunk at a time."""
    cdt = cache_dtype(cfg)
    if cfg.model.is_encoder_decoder:
        return T.encdec_prefill_begin(cfg.model, params, batch["frames"],
                                      batch["tokens"], max_len,
                                      cache_dtype=cdt)
    return T.prefill_begin(cfg.model, params, batch["tokens"], max_len,
                           embeds=batch.get("embeds"), cache_dtype=cdt)


def prefill_step(cfg: Config, params: Any, h_chunk: jax.Array, start: int,
                 caches: Any) -> Tuple[jax.Array, Any]:
    """One prefill chunk occupying positions [start, start + C)."""
    if cfg.model.is_encoder_decoder:
        return T.encdec_prefill_step(cfg.model, params, h_chunk, start,
                                     caches)
    return T.prefill_step(cfg.model, params, h_chunk, start, caches)


def prefill_finish(cfg: Config, params: Any, h_last: jax.Array) -> jax.Array:
    """Next-token logits from the final chunk's output."""
    if cfg.model.is_encoder_decoder:
        return T.encdec_prefill_finish(cfg.model, params, h_last)
    return T.prefill_finish(cfg.model, params, h_last)


def _sample(key: jax.Array, logits: jax.Array, temperature: float
            ) -> jax.Array:
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(key, logits / temperature).astype(jnp.int32)


def generate(cfg: Config, params: Any, batch: Dict[str, jax.Array], *,
             max_new_tokens: Optional[int] = None, eos_id: int = -1,
             temperature: Optional[float] = None,
             seed: int = 0) -> GenResult:
    """Greedy/temperature generation. Static shapes; jit-compiled loop.

    A kernel fault on a pallas path (w4a16 matmul or the fused int8-KV
    attention) degrades this call to the xla reference backends and retries
    once — counted in ``engine_stats()``, never silent.
    """
    impl = cfg.serve.w4a16_impl
    kv_impl = cfg.serve.kv_impl
    try:
        with kops.w4a16_default_impl(impl), \
                kops.kv_attn_default_impl(kv_impl):
            return _generate(cfg, params, batch,
                             max_new_tokens=max_new_tokens, eos_id=eos_id,
                             temperature=temperature, seed=seed)
    except Exception as e:                      # noqa: BLE001 — classified
        if (impl == "xla" and kv_impl == "xla") or not _kernel_fault(e):
            raise
        _ENGINE_STATS["kernel_degradations"] += 1
        warnings.warn(f"kernel fault ({e!r}): degrading generate() "
                      "to impl='xla'", RuntimeWarning, stacklevel=2)
        with kops.w4a16_default_impl("xla"), \
                kops.kv_attn_default_impl("xla"):
            return _generate(cfg, params, batch,
                             max_new_tokens=max_new_tokens, eos_id=eos_id,
                             temperature=temperature, seed=seed)


def _generate(cfg: Config, params: Any, batch: Dict[str, jax.Array], *,
              max_new_tokens: Optional[int], eos_id: int,
              temperature: Optional[float], seed: int) -> GenResult:
    sc = cfg.serve
    mnt = max_new_tokens or sc.max_new_tokens
    temp = sc.temperature if temperature is None else temperature
    b, s0 = batch["tokens"].shape
    n_front = batch["embeds"].shape[1] if batch.get("embeds") is not None \
        else 0
    max_len = s0 + n_front + mnt + 1
    logits, caches = prefill(cfg, params, batch, max_len)

    def body(carry, i):
        token, pos, caches, done, key = carry
        key, sub = jax.random.split(key)
        lg, caches = serve_step(cfg, params, token, pos, caches)
        raw = _sample(sub, lg, temp)
        lp = jax.nn.log_softmax(lg)[jnp.arange(b), raw]
        newly_done = done | (raw == eos_id)
        nxt = jnp.where(newly_done, 0, raw)
        out = (nxt, jnp.where(newly_done, 0.0, lp), ~newly_done)
        return (nxt, pos + 1, caches, newly_done, key), out

    first_raw = _sample(jax.random.PRNGKey(seed), logits, temp)
    done0 = first_raw == eos_id
    first = jnp.where(done0, 0, first_raw)
    lp0 = jnp.where(done0, 0.0,
                    jax.nn.log_softmax(logits)[jnp.arange(b), first_raw])
    pos0 = jnp.full((b,), s0 + n_front, jnp.int32)
    carry = (first, pos0, caches, done0, jax.random.PRNGKey(seed + 1))
    if mnt > 1:
        carry, (toks, lps, valid) = jax.lax.scan(body, carry,
                                                 jnp.arange(mnt - 1))
        tokens = jnp.concatenate([first[:, None], toks.T], axis=1)
        logprobs = jnp.concatenate([lp0[:, None], lps.T], axis=1)
        steps = (~done0).astype(jnp.int32) + \
            jnp.sum(valid.astype(jnp.int32), axis=0)
    else:
        tokens, logprobs = first[:, None], lp0[:, None]
        steps = (~done0).astype(jnp.int32)
    return GenResult(tokens, logprobs, steps)
