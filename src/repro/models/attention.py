"""Attention blocks: GQA (full / sliding-window / local), MLA, cross-attn.

Three entry points per variant:

  - ``*_forward``  — train/prefill over a full (B, S, D) sequence. Scores are
    never materialized at (S, S): queries are processed in chunks with an
    online-softmax accumulator (flash-attention recurrence in pure JAX via
    ``lax.scan``), keeping peak memory at (B, H, qc, S).
  - ``*_decode``   — one new token against a cache.
  - ``init_*`` / ``init_*_cache`` — params and cache constructors.

Cache layouts (per layer):
  GQA full:   {"k": (B, S_max, KV, hd), "v": ..., } position passed in.
  GQA window: ring buffer (B, W, KV, hd) indexed by pos % W.
  MLA:        {"ckv": (B, S_max, kv_lora_rank), "krope": (B, S_max, r_hd)}
              — the compressed latent is cached, not per-head K/V; this is
              MLA's decode-memory win and it is preserved here.

Quantized GQA caches (``dtype="int8"``, the ``serve.kv_cache=int8`` knob):
the ``"k"``/``"v"`` leaves hold int8 codes at the same shapes, paired with
per-(position, kv-head, block) f32 scale leaves ``"k_scale"``/``"v_scale"``
(block = ``kv_codec.default_kv_block(head_dim)``) and per-lane f32
error-feedback accumulators ``"k_err"``/``"v_err"`` (B, KV, hd) that decode
appends fold in (``e ← x − dec(enc(x + e))``) so quantization bias doesn't
compound over decode steps. Every leaf keeps batch at axis 1 after layer
stacking, so the slot API in models/transformer.py works unchanged —
``cache_slot_evict``'s lane zeroing resets the accumulator with the lane.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.kernels import kv_codec
from repro.kernels import ops as kops
from repro.models.linear import dense, init_dense
from repro.models.layers import apply_rope

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------

def _softcap(x: jax.Array, cap: float) -> jax.Array:
    return jnp.tanh(x / cap) * cap if cap > 0 else x


def repeat_kv(k: jax.Array, n_rep: int) -> jax.Array:
    """(B, S, KV, hd) -> (B, S, KV*n_rep, hd)."""
    if n_rep == 1:
        return k
    b, s, kv, hd = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :],
                            (b, s, kv, n_rep, hd)).reshape(b, s, kv * n_rep, hd)


def _attend_chunked(q: jax.Array, k: jax.Array, v: jax.Array,
                    q_positions: jax.Array, kv_positions: jax.Array,
                    *, causal: bool, window: int, softcap: float,
                    chunk: int = 512, opt: bool = True) -> jax.Array:
    """Online-softmax attention.

    q: (B, Sq, H, hd); k/v: (B, Sk, H, hd) (kv already head-repeated).
    positions: (B, Sq) / (B, Sk). Masks: causal (qpos >= kpos) and window
    (kpos > qpos - window) when window > 0. Returns (B, Sq, H, hd).
    """
    b, sq, h, hd = q.shape
    sk = k.shape[1]
    scale = hd ** -0.5
    chunk = min(chunk, sq)
    n_chunks = -(-sq // chunk)
    pad = n_chunks * chunk - sq
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        q_positions = jnp.pad(q_positions, ((0, 0), (0, pad)),
                              constant_values=-1)
    qc = q.reshape(b, n_chunks, chunk, h, hd).transpose(1, 0, 2, 3, 4)
    pc = q_positions.reshape(b, n_chunks, chunk).transpose(1, 0, 2)

    kT = k.transpose(0, 2, 3, 1)                     # (B, H, hd, Sk)
    vT = v.transpose(0, 2, 1, 3)                     # (B, H, Sk, hd)

    def one_chunk(carry, xs):
        qi, pi = xs                                  # (B, c, H, hd), (B, c)
        if opt:
            # matmuls stay in the compute dtype with f32 accumulation — an
            # .astype(f32) on kT/vT makes XLA hoist full-precision copies
            # of K/V out of the chunk loop (measured 2× attention bytes)
            s = jnp.einsum("bchd,bhdk->bhck",
                           (qi.astype(jnp.float32) * scale).astype(qi.dtype),
                           kT, preferred_element_type=jnp.float32)
        else:                       # naive baseline (§Perf before-state)
            s = jnp.einsum("bchd,bhdk->bhck",
                           qi.astype(jnp.float32) * scale,
                           kT.astype(jnp.float32))
        s = _softcap(s, softcap)
        mask = jnp.ones((b, 1, chunk, sk), bool)
        dq = pi[:, None, :, None]                    # (B,1,c,1)
        dk = kv_positions[:, None, None, :]          # (B,1,1,Sk)
        if causal:
            mask = mask & (dq >= dk)
        if window > 0:
            mask = mask & (dk > dq - window)
        mask = mask & (dq >= 0) & (dk >= 0)          # padding sentinels
        s = jnp.where(mask, s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        # fully-masked rows (padding) give uniform p; output is garbage but
        # sliced away below.
        if opt:
            o = jnp.einsum("bhck,bhkd->bchd", p.astype(vT.dtype), vT,
                           preferred_element_type=jnp.float32)
        else:
            o = jnp.einsum("bhck,bhkd->bchd", p, vT.astype(jnp.float32))
        return carry, o.astype(q.dtype)

    _, out = jax.lax.scan(one_chunk, (), (qc, pc))
    out = out.transpose(1, 0, 2, 3, 4).reshape(b, n_chunks * chunk, h, hd)
    return out[:, :sq]


# ---------------------------------------------------------------------------
# GQA attention
# ---------------------------------------------------------------------------

def init_attention(cfg: ModelConfig, key: jax.Array,
                   bias: bool = False) -> Dict:
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    return {"q": init_dense(ks[0], d, h * hd, bias=bias),
            "k": init_dense(ks[1], d, kv * hd, bias=bias),
            "v": init_dense(ks[2], d, kv * hd, bias=bias),
            "o": init_dense(ks[3], h * hd, d, bias=bias,
                            scale=(h * hd) ** -0.5)}


def _project_qkv(cfg: ModelConfig, p: Dict, x: jax.Array, name: str):
    b, s, _ = x.shape
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = dense(p["q"], x, f"{name}.q").reshape(b, s, h, hd)
    k = dense(p["k"], x, f"{name}.k").reshape(b, s, kv, hd)
    v = dense(p["v"], x, f"{name}.v").reshape(b, s, kv, hd)
    return q, k, v


def attention_forward(cfg: ModelConfig, p: Dict, x: jax.Array,
                      positions: jax.Array, *, causal: bool = True,
                      window: int = 0, name: str = "attn",
                      use_rope: Optional[bool] = None) -> jax.Array:
    """Train/prefill self-attention. x: (B, S, D); positions: (B, S)."""
    q, k, v = _project_qkv(cfg, p, x, name)
    if use_rope if use_rope is not None else cfg.use_rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    n_rep = cfg.num_heads // cfg.num_kv_heads
    o = _attend_chunked(q, repeat_kv(k, n_rep), repeat_kv(v, n_rep),
                        positions, positions, causal=causal, window=window,
                        softcap=cfg.attn_logits_softcap,
                        opt=cfg.opt_attention)
    b, s, _, _ = o.shape
    return dense(p["o"], o.reshape(b, s, -1), f"{name}.o")


def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int,
                  dtype=jnp.bfloat16) -> Dict:
    """``dtype`` is a jnp dtype, or the string sentinel ``"int8"`` for the
    quantized cache layout (codes + scales + error-feedback accumulators,
    module docstring)."""
    kv, hd = cfg.num_kv_heads, cfg.head_dim
    if isinstance(dtype, str) and dtype == "int8":
        nb = hd // kv_codec.default_kv_block(hd)
        return {"k": jnp.zeros((batch, max_len, kv, hd), jnp.int8),
                "k_scale": jnp.zeros((batch, max_len, kv, nb), jnp.float32),
                "k_err": jnp.zeros((batch, kv, hd), jnp.float32),
                "v": jnp.zeros((batch, max_len, kv, hd), jnp.int8),
                "v_scale": jnp.zeros((batch, max_len, kv, nb), jnp.float32),
                "v_err": jnp.zeros((batch, kv, hd), jnp.float32)}
    return {"k": jnp.zeros((batch, max_len, kv, hd), dtype),
            "v": jnp.zeros((batch, max_len, kv, hd), dtype)}


def kv_cache_quantized(cache: Dict) -> bool:
    """True for the int8 codes+scales cache layout."""
    return "k_scale" in cache


def kv_cache_block(cache: Dict) -> int:
    """Codec block size of a quantized cache, recovered from leaf shapes."""
    return cache["k"].shape[-1] // cache["k_scale"].shape[-1]


def attention_prefill(cfg: ModelConfig, p: Dict, x: jax.Array,
                      positions: jax.Array, cache: Dict, *,
                      window: int = 0, name: str = "attn",
                      start: Optional[int] = None
                      ) -> Tuple[jax.Array, Dict]:
    """Prefill: run causal attention AND populate the cache.

    Full-attn cache: written at [0:S]. Window cache (ring, size W): the last
    W tokens land at slot ``pos % W``.

    ``start`` switches to *continuation* mode (chunked prefill,
    docs/SERVING.md): ``x`` is the chunk of absolute positions
    ``[start, start+S)``, the cache already holds positions ``< start``, and
    queries attend to cached history + the chunk (read-before-write, so a
    ring cache still covers every in-chunk query's window). ``start=None``
    keeps the legacy whole-sequence path bit-for-bit untouched.
    """
    q, k, v = _project_qkv(cfg, p, x, name)
    if cfg.use_rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    n_rep = cfg.num_heads // cfg.num_kv_heads
    b, s = x.shape[:2]
    if start is None:
        o = _attend_chunked(q, repeat_kv(k, n_rep), repeat_kv(v, n_rep),
                            positions, positions, causal=True, window=window,
                            softcap=cfg.attn_logits_softcap,
                            opt=cfg.opt_attention)
    else:
        # continuation: history keys come from the cache as written by the
        # PREVIOUS chunks (read before this chunk's write — a ring cache
        # then still holds (start-1-W, start-1], which together with the
        # in-chunk keys covers every query's window)
        w_cache = cache["k"].shape[1]
        old_kpos = _cache_key_positions(start - 1, w_cache, window)
        old_kpos = jnp.broadcast_to(old_kpos[None], (b, w_cache))
        if kv_cache_quantized(cache):
            blk = kv_cache_block(cache)
            k_hist = kv_codec.dec_int8_blocks(
                cache["k"], cache["k_scale"], blk).astype(k.dtype)
            v_hist = kv_codec.dec_int8_blocks(
                cache["v"], cache["v_scale"], blk).astype(v.dtype)
        else:
            k_hist = cache["k"].astype(k.dtype)
            v_hist = cache["v"].astype(v.dtype)
        k_all = jnp.concatenate([repeat_kv(k_hist, n_rep),
                                 repeat_kv(k, n_rep)], axis=1)
        v_all = jnp.concatenate([repeat_kv(v_hist, n_rep),
                                 repeat_kv(v, n_rep)], axis=1)
        kv_pos = jnp.concatenate([old_kpos, positions], axis=1)
        o = _attend_chunked(q, k_all, v_all, positions, kv_pos, causal=True,
                            window=window, softcap=cfg.attn_logits_softcap,
                            opt=cfg.opt_attention)
    y = dense(p["o"], o.reshape(b, s, -1), f"{name}.o")

    w_cache = cache["k"].shape[1]
    if kv_cache_quantized(cache):
        # quantize then reuse the same three write-branch index ops for the
        # codes AND scales leaves (same shapes up to the trailing dim). The
        # error-feedback accumulators stay untouched at prefill — EF is a
        # decode-append recurrence; prefill writes are one-shot.
        blk = kv_cache_block(cache)
        if window > 0 and w_cache < s:
            ksel, vsel = k[:, -w_cache:], v[:, -w_cache:]
            idx = positions[:, -w_cache:] % w_cache              # (B, W)
        elif window > 0 and start is not None:
            ksel, vsel = k, v
            idx = positions % w_cache                            # (B, S)
        else:
            ksel, vsel, idx = k, v, None
        kq, ksc = kv_codec.enc_int8_blocks(ksel, blk)
        vq, vsc = kv_codec.enc_int8_blocks(vsel, blk)
        if idx is not None:
            bidx = jnp.arange(b)[:, None]
            cache = dict(cache,
                         k=cache["k"].at[bidx, idx].set(kq),
                         k_scale=cache["k_scale"].at[bidx, idx].set(ksc),
                         v=cache["v"].at[bidx, idx].set(vq),
                         v_scale=cache["v_scale"].at[bidx, idx].set(vsc))
        else:
            off = 0 if start is None else start
            upd = jax.lax.dynamic_update_slice
            cache = dict(cache,
                         k=upd(cache["k"], kq, (0, off, 0, 0)),
                         k_scale=upd(cache["k_scale"], ksc, (0, off, 0, 0)),
                         v=upd(cache["v"], vq, (0, off, 0, 0)),
                         v_scale=upd(cache["v_scale"], vsc, (0, off, 0, 0)))
    elif window > 0 and w_cache < s:
        # ring buffer: keep the last W entries, aligned to pos % W
        idx = positions[:, -w_cache:] % w_cache                  # (B, W)
        ksel = k[:, -w_cache:].astype(cache["k"].dtype)
        vsel = v[:, -w_cache:].astype(cache["v"].dtype)
        bidx = jnp.arange(b)[:, None]
        cache = {"k": cache["k"].at[bidx, idx].set(ksel),
                 "v": cache["v"].at[bidx, idx].set(vsel)}
    elif window > 0 and start is not None:
        # ring continuation: the chunk may straddle the wrap point, so the
        # slot-indexed scatter replaces the offset dynamic_update_slice
        idx = positions % w_cache                                # (B, S)
        bidx = jnp.arange(b)[:, None]
        cache = {"k": cache["k"].at[bidx, idx].set(
                     k.astype(cache["k"].dtype)),
                 "v": cache["v"].at[bidx, idx].set(
                     v.astype(cache["v"].dtype))}
    else:
        off = 0 if start is None else start
        cache = {"k": jax.lax.dynamic_update_slice(
                     cache["k"], k.astype(cache["k"].dtype), (0, off, 0, 0)),
                 "v": jax.lax.dynamic_update_slice(
                     cache["v"], v.astype(cache["v"].dtype), (0, off, 0, 0))}
    return y, cache


def _cache_key_positions(last: int, cache_len: int, window: int) -> jax.Array:
    """Absolute position held by each cache slot after ``last`` was written.

    Full cache (window=0): slot i holds position i, valid while i <= last.
    Ring cache: slot i holds the largest p <= last with p % W == i, valid
    only within the window (unwritten slots alias future positions and are
    masked exactly like the warm-up handling in :func:`attention_decode`).
    Returns (cache_len,) int32 with -1 marking invalid slots; ``last=-1``
    (empty cache) marks everything invalid.
    """
    if last < 0:
        return jnp.full((cache_len,), -1, jnp.int32)
    idx = jnp.arange(cache_len, dtype=jnp.int32)
    if window > 0:
        off = (last - idx) % cache_len
        kpos = last - off
        lo = last - min(window, cache_len)
        return jnp.where(kpos > lo, kpos, -1)
    return jnp.where(idx <= last, idx, -1)


def attention_decode(cfg: ModelConfig, p: Dict, x: jax.Array,
                     pos: jax.Array, cache: Dict, *, window: int = 0,
                     name: str = "attn") -> Tuple[jax.Array, Dict]:
    """One-token decode. x: (B, 1, D); pos: (B,) current position."""
    b = x.shape[0]
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q, k, v = _project_qkv(cfg, p, x, name)
    if cfg.use_rope:
        q = apply_rope(q, pos[:, None], cfg.rope_theta)
        k = apply_rope(k, pos[:, None], cfg.rope_theta)

    cache_len = cache["k"].shape[1]
    slot = (pos % cache_len) if window > 0 else pos
    bidx = jnp.arange(b)
    quantized = kv_cache_quantized(cache)
    if quantized:
        # error-bounded append: fold the lane's accumulated quantization
        # error into the new K/V row before encoding, then keep the fresh
        # residual — e ← x − dec(enc(x + e)) (Karimireddy et al., the wire
        # codec's recurrence applied per lane; cache eviction zeroes the
        # lane and the accumulator with it)
        blk = kv_cache_block(cache)
        kf = k[:, 0].astype(jnp.float32) + cache["k_err"]
        vf = v[:, 0].astype(jnp.float32) + cache["v_err"]
        kq, ksc = kv_codec.enc_int8_blocks(kf, blk)
        vq, vsc = kv_codec.enc_int8_blocks(vf, blk)
        ck = cache["k"].at[bidx, slot].set(kq)
        cks = cache["k_scale"].at[bidx, slot].set(ksc)
        cv = cache["v"].at[bidx, slot].set(vq)
        cvs = cache["v_scale"].at[bidx, slot].set(vsc)
        new_cache = {"k": ck, "k_scale": cks,
                     "k_err": kf - kv_codec.dec_int8_blocks(kq, ksc, blk),
                     "v": cv, "v_scale": cvs,
                     "v_err": vf - kv_codec.dec_int8_blocks(vq, vsc, blk)}
    else:
        ck = cache["k"].at[bidx, slot].set(k[:, 0].astype(cache["k"].dtype))
        cv = cache["v"].at[bidx, slot].set(v[:, 0].astype(cache["v"].dtype))
        new_cache = {"k": ck, "v": cv}

    # key positions for masking
    if window > 0:
        # ring slot i holds absolute position: the largest p <= pos with
        # p % W == i  (invalid until written; mask p > pos handles warmup
        # because unwritten slots alias future positions)
        off = (pos[:, None] - jnp.arange(cache_len)[None, :]) % cache_len
        kpos = pos[:, None] - off                                # (B, W)
        kpos = jnp.where(kpos > pos[:, None] - jnp.minimum(
            jnp.asarray(window), cache_len), kpos, -1)
    else:
        kpos = jnp.arange(cache_len)[None, :].repeat(b, 0)
        kpos = jnp.where(kpos <= pos[:, None], kpos, -1)

    if quantized:
        # fused dequant-attention: int8 history never materializes as a
        # full fp16/f32 tensor in HBM on the pallas path; the dispatcher
        # (impl = serve.kv_impl via ops.kv_attn_default_impl) falls back to
        # the full-dequant XLA oracle off-TPU / over VMEM budget.
        n_rep = h // kv
        qg = (q[:, 0] * hd ** -0.5).reshape(b, kv, n_rep, hd)
        o = kops.int8_kv_attention(qg, ck, cks, cv, cvs, kpos,
                                   kv_block=blk,
                                   softcap=cfg.attn_logits_softcap)
        o = o.astype(x.dtype)
    elif cfg.opt_attention:
        # grouped-query attention against the cache WITHOUT materializing an
        # f32 copy of the cache or the head-repeated expansion: the einsum
        # contracts bf16 cache entries directly with f32 accumulation. (The
        # naive repeat_kv(...).astype(f32) form makes XLA hoist a full f32
        # copy of the entire stacked cache out of the layer scan — ~2.5× the
        # whole decode memory term on minicpm; measured in §Perf.)
        n_rep = h // kv
        qg = (q[:, 0] * hd ** -0.5).reshape(b, kv, n_rep, hd)
        s = jnp.einsum("bgrd,bsgd->bgrs", qg.astype(ck.dtype), ck,
                       preferred_element_type=jnp.float32)
        s = _softcap(s, cfg.attn_logits_softcap)
        s = jnp.where(kpos[:, None, None, :] >= 0, s, NEG_INF)
        pw = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bgrs,bsgd->bgrd", pw.astype(cv.dtype), cv,
                       preferred_element_type=jnp.float32).astype(x.dtype)
    else:                               # naive baseline (§Perf before-state)
        n_rep = h // kv
        kk = repeat_kv(ck, n_rep).astype(jnp.float32)            # (B,S,H,hd)
        vv = repeat_kv(cv, n_rep).astype(jnp.float32)
        s = jnp.einsum("bhd,bshd->bhs",
                       q[:, 0].astype(jnp.float32) * hd ** -0.5, kk)
        s = _softcap(s, cfg.attn_logits_softcap)
        s = jnp.where(kpos[:, None, :] >= 0, s, NEG_INF)
        pw = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhs,bshd->bhd", pw, vv).astype(x.dtype)
    y = dense(p["o"], o.reshape(b, 1, h * hd), f"{name}.o")
    return y, new_cache


# ---------------------------------------------------------------------------
# Cross-attention (whisper decoder)
# ---------------------------------------------------------------------------

def init_cross_attention(cfg: ModelConfig, key: jax.Array,
                         bias: bool = True) -> Dict:
    return init_attention(cfg, key, bias=bias)


def cross_attention_kv(cfg: ModelConfig, p: Dict, enc: jax.Array,
                       name: str = "xattn") -> Dict:
    """Compute the encoder-side K/V once (prefill). enc: (B, Se, D)."""
    b, se, _ = enc.shape
    kv, hd = cfg.num_kv_heads, cfg.head_dim
    k = dense(p["k"], enc, f"{name}.k").reshape(b, se, kv, hd)
    v = dense(p["v"], enc, f"{name}.v").reshape(b, se, kv, hd)
    return {"k": k, "v": v}


def cross_attention(cfg: ModelConfig, p: Dict, x: jax.Array,
                    kv_cache: Dict, name: str = "xattn") -> jax.Array:
    """Decoder query against fixed encoder K/V. No positions, no mask."""
    b, s, _ = x.shape
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = dense(p["q"], x, f"{name}.q").reshape(b, s, h, hd)
    n_rep = h // kv
    k = repeat_kv(kv_cache["k"], n_rep)
    v = repeat_kv(kv_cache["v"], n_rep)
    se = k.shape[1]
    qpos = jnp.zeros((b, s), jnp.int32)
    kpos = jnp.zeros((b, se), jnp.int32)
    o = _attend_chunked(q, k, v, qpos, kpos, causal=False, window=0,
                        softcap=0.0, opt=cfg.opt_attention)
    return dense(p["o"], o.reshape(b, s, -1), f"{name}.o")


# ---------------------------------------------------------------------------
# MLA — multi-head latent attention (deepseek-v3)
# ---------------------------------------------------------------------------

def init_mla(cfg: ModelConfig, key: jax.Array) -> Dict:
    m = cfg.mla
    d, h = cfg.d_model, cfg.num_heads
    qk_hd = m.qk_nope_head_dim + m.qk_rope_head_dim
    ks = jax.random.split(key, 7)
    return {
        "q_down": init_dense(ks[0], d, m.q_lora_rank),
        "q_up": init_dense(ks[1], m.q_lora_rank, h * qk_hd),
        "kv_down": init_dense(ks[2], d, m.kv_lora_rank),
        "k_rope": init_dense(ks[3], d, m.qk_rope_head_dim),
        "k_up": init_dense(ks[4], m.kv_lora_rank, h * m.qk_nope_head_dim),
        "v_up": init_dense(ks[5], m.kv_lora_rank, h * m.v_head_dim),
        "o": init_dense(ks[6], h * m.v_head_dim, d,
                        scale=(h * m.v_head_dim) ** -0.5),
    }


def _mla_qkv(cfg: ModelConfig, p: Dict, x: jax.Array, positions: jax.Array,
             name: str):
    """Project to (q_nope, q_rope, ckv, k_rope). x: (B, S, D)."""
    m = cfg.mla
    b, s, _ = x.shape
    h = cfg.num_heads
    ql = dense(p["q_down"], x, f"{name}.q_down")
    q = dense(p["q_up"], ql, f"{name}.q_up").reshape(
        b, s, h, m.qk_nope_head_dim + m.qk_rope_head_dim)
    q_nope, q_rope = jnp.split(q, [m.qk_nope_head_dim], axis=-1)
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    ckv = dense(p["kv_down"], x, f"{name}.kv_down")           # (B,S,rank)
    k_rope = dense(p["k_rope"], x, f"{name}.k_rope")          # (B,S,r_hd)
    k_rope = apply_rope(k_rope[:, :, None, :], positions,
                        cfg.rope_theta)[:, :, 0, :]
    return q_nope, q_rope, ckv, k_rope


def _mla_attend(cfg: ModelConfig, p: Dict, q_nope, q_rope, ckv, k_rope,
                q_positions, kv_positions, name: str, causal: bool = True):
    """Expand latent → per-head K/V and run chunked attention."""
    m = cfg.mla
    b, sk = ckv.shape[:2]
    h = cfg.num_heads
    k_nope = dense(p["k_up"], ckv, f"{name}.k_up").reshape(
        b, sk, h, m.qk_nope_head_dim)
    v = dense(p["v_up"], ckv, f"{name}.v_up").reshape(b, sk, h, m.v_head_dim)
    # decoupled-rope score: concat nope+rope dims on both sides; k_rope is
    # shared across heads.
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    kr = jnp.broadcast_to(k_rope[:, :, None, :],
                          (b, sk, h, m.qk_rope_head_dim))
    k = jnp.concatenate([k_nope, kr], axis=-1)
    # pad v to qk head dim for the shared attend, slice after
    qk_hd = m.qk_nope_head_dim + m.qk_rope_head_dim
    if m.v_head_dim < qk_hd:
        v = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, qk_hd - m.v_head_dim)))
    o = _attend_chunked(q, k, v, q_positions, kv_positions, causal=causal,
                        window=0, softcap=cfg.attn_logits_softcap,
                        opt=cfg.opt_attention)
    o = o[..., :m.v_head_dim]
    sq = o.shape[1]
    return dense(p["o"], o.reshape(b, sq, -1), f"{name}.o")


def mla_forward(cfg: ModelConfig, p: Dict, x: jax.Array,
                positions: jax.Array, name: str = "attn") -> jax.Array:
    q_nope, q_rope, ckv, k_rope = _mla_qkv(cfg, p, x, positions, name)
    return _mla_attend(cfg, p, q_nope, q_rope, ckv, k_rope,
                       positions, positions, name)


def init_mla_cache(cfg: ModelConfig, batch: int, max_len: int,
                   dtype=jnp.bfloat16) -> Dict:
    m = cfg.mla
    return {"ckv": jnp.zeros((batch, max_len, m.kv_lora_rank), dtype),
            "krope": jnp.zeros((batch, max_len, m.qk_rope_head_dim), dtype)}


def mla_prefill(cfg: ModelConfig, p: Dict, x: jax.Array,
                positions: jax.Array, cache: Dict,
                name: str = "attn", start: Optional[int] = None
                ) -> Tuple[jax.Array, Dict]:
    """``start`` = chunked-prefill continuation, as in attention_prefill:
    queries attend cached latents (positions < start) + the chunk."""
    q_nope, q_rope, ckv, k_rope = _mla_qkv(cfg, p, x, positions, name)
    if start is None:
        y = _mla_attend(cfg, p, q_nope, q_rope, ckv, k_rope, positions,
                        positions, name)
    else:
        b = x.shape[0]
        s_max = cache["ckv"].shape[1]
        old_kpos = _cache_key_positions(start - 1, s_max, 0)
        old_kpos = jnp.broadcast_to(old_kpos[None], (b, s_max))
        ckv_all = jnp.concatenate([cache["ckv"].astype(x.dtype), ckv],
                                  axis=1)
        krope_all = jnp.concatenate([cache["krope"].astype(x.dtype), k_rope],
                                    axis=1)
        kv_pos = jnp.concatenate([old_kpos, positions], axis=1)
        y = _mla_attend(cfg, p, q_nope, q_rope, ckv_all, krope_all,
                        positions, kv_pos, name)
    off = 0 if start is None else start
    cache = {"ckv": jax.lax.dynamic_update_slice(
                 cache["ckv"], ckv.astype(cache["ckv"].dtype), (0, off, 0)),
             "krope": jax.lax.dynamic_update_slice(
                 cache["krope"], k_rope.astype(cache["krope"].dtype),
                 (0, off, 0))}
    return y, cache


def mla_decode(cfg: ModelConfig, p: Dict, x: jax.Array, pos: jax.Array,
               cache: Dict, name: str = "attn") -> Tuple[jax.Array, Dict]:
    """One-token MLA decode against the *latent* cache."""
    b = x.shape[0]
    q_nope, q_rope, ckv, k_rope = _mla_qkv(cfg, p, x, pos[:, None], name)
    bidx = jnp.arange(b)
    cache = {"ckv": cache["ckv"].at[bidx, pos].set(
                 ckv[:, 0].astype(cache["ckv"].dtype)),
             "krope": cache["krope"].at[bidx, pos].set(
                 k_rope[:, 0].astype(cache["krope"].dtype))}
    s_max = cache["ckv"].shape[1]
    kpos = jnp.arange(s_max)[None, :].repeat(b, 0)
    kpos = jnp.where(kpos <= pos[:, None], kpos, -1)
    qpos = pos[:, None]
    y = _mla_attend(cfg, p, q_nope, q_rope,
                    cache["ckv"].astype(x.dtype),
                    cache["krope"].astype(x.dtype),
                    qpos, kpos, name, causal=True)
    return y, cache
