"""Model zoo: pure-JAX functional models for all assigned architectures."""
from repro.models import transformer  # noqa: F401
from repro.models.linear import Tap, dense  # noqa: F401
