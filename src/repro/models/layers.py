"""Shared model layers: norms, MLPs, embeddings, rotary embeddings.

Pure functions over param dicts. Compute dtype follows the input; norms
and softmax statistics run in float32 for stability.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models.linear import dense, init_dense


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm(p: Dict, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + p["scale"].astype(jnp.float32))).astype(x.dtype)


def layernorm(p: Dict, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)
            + p["bias"].astype(jnp.float32)).astype(x.dtype)


def norm(cfg: ModelConfig, p: Dict, x: jax.Array) -> jax.Array:
    return rmsnorm(p, x) if cfg.norm == "rmsnorm" else layernorm(p, x)


def init_norm(cfg: ModelConfig, d: int) -> Dict:
    if cfg.norm == "rmsnorm":
        return {"scale": jnp.zeros((d,), jnp.float32)}
    return {"scale": jnp.ones((d,), jnp.float32),
            "bias": jnp.zeros((d,), jnp.float32)}


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def _act(name: str, x: jax.Array) -> jax.Array:
    if name == "silu":
        return jax.nn.silu(x)
    if name == "gelu":
        return jax.nn.gelu(x)
    raise ValueError(name)


def mlp(cfg: ModelConfig, p: Dict, x: jax.Array, name: str = "mlp") -> jax.Array:
    """Gated (llama-style) or plain two-layer MLP."""
    if cfg.gated_mlp:
        g = dense(p["gate"], x, f"{name}.gate")
        u = dense(p["up"], x, f"{name}.up")
        h = _act(cfg.act, g) * u
    else:
        h = _act(cfg.act, dense(p["up"], x, f"{name}.up"))
    return dense(p["down"], h, f"{name}.down")


def init_mlp(cfg: ModelConfig, key: jax.Array, d_model: int, d_ff: int,
             bias: bool = False) -> Dict:
    ks = jax.random.split(key, 3)
    p = {"up": init_dense(ks[0], d_model, d_ff, bias=bias),
         "down": init_dense(ks[1], d_ff, d_model, bias=bias,
                            scale=d_ff ** -0.5)}
    if cfg.gated_mlp:
        p["gate"] = init_dense(ks[2], d_model, d_ff, bias=bias)
    return p


# ---------------------------------------------------------------------------
# Embeddings / head
# ---------------------------------------------------------------------------

def embed(p: Dict, tokens: jax.Array, dtype=jnp.bfloat16) -> jax.Array:
    return p["embedding"].astype(dtype)[tokens]


def unembed(cfg: ModelConfig, params: Dict, h: jax.Array) -> jax.Array:
    """Final projection to vocab logits (tied or untied), fp32 logits."""
    if cfg.tie_embeddings:
        w = params["embed"]["embedding"]          # (V, D)
        logits = jnp.dot(h, w.T.astype(h.dtype),
                         preferred_element_type=jnp.float32)
    else:
        logits = dense(params["lm_head"], h, "lm_head").astype(jnp.float32)
    if cfg.logits_softcap > 0:
        c = cfg.logits_softcap
        logits = jnp.tanh(logits / c) * c
    return logits.astype(jnp.float32)


def init_embed(key: jax.Array, vocab: int, d: int) -> Dict:
    return {"embedding": jax.random.normal(key, (vocab, d)) * 0.02}


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    """Inverse frequencies for the even head dims: (head_dim//2,)."""
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2,
                                       dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array,
               theta: float = 10000.0) -> jax.Array:
    """x: (B, S, H, hd); positions: (B, S) or (S,) int32."""
    hd = x.shape[-1]
    inv = rope_freqs(hd, theta)                         # (hd/2,)
    pos = positions.astype(jnp.float32)
    ang = pos[..., None] * inv                          # (B, S, hd/2)
    cos = jnp.cos(ang)[..., None, :]                    # (B, S, 1, hd/2)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin,
                           x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(seq_len: int, d_model: int) -> jax.Array:
    """Whisper-style fixed sinusoidal table: (seq_len, d_model) f32."""
    pos = jnp.arange(seq_len, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d_model, 2, dtype=jnp.float32)[None, :]
    ang = pos / (10000.0 ** (dim / d_model))
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# 1D depthwise causal convolution (mamba / rglru / recurrentgemma blocks)
# ---------------------------------------------------------------------------

def causal_conv1d(p: Dict, x: jax.Array,
                  state: Optional[jax.Array] = None
                  ) -> Tuple[jax.Array, jax.Array]:
    """Depthwise causal conv over the sequence.

    x: (B, S, C); p["w"]: (K, C) depthwise taps; p["b"]: (C,).
    state: (B, K-1, C) trailing inputs from the previous chunk (decode) or
    None (zeros — training/prefill from scratch).
    Returns (y, new_state) with y: (B, S, C), new_state: (B, K-1, C).
    """
    w = p["w"].astype(x.dtype)                          # (K, C)
    k = w.shape[0]
    b, s, c = x.shape
    if state is None:
        state = jnp.zeros((b, k - 1, c), x.dtype)
    xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)  # (B, S+K-1, C)
    # y[t] = sum_j w[j] * xp[t + j]
    y = jnp.zeros((b, s, c), jnp.float32)
    for j in range(k):
        y = y + xp[:, j:j + s, :].astype(jnp.float32) * w[j].astype(jnp.float32)
    if "b" in p:
        y = y + p["b"].astype(jnp.float32)
    new_state = xp[:, s:, :] if k > 1 else state
    return y.astype(x.dtype), new_state


def init_conv1d(key: jax.Array, width: int, channels: int,
                bias: bool = True) -> Dict:
    p = {"w": jax.random.normal(key, (width, channels)) * (width ** -0.5)}
    if bias:
        p["b"] = jnp.zeros((channels,), jnp.float32)
    return p
