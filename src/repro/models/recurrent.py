"""Recurrent sequence mixers: RG-LRU (recurrentgemma) and Mamba-1 (SSM).

Both use a diagonal linear recurrence ``h_t = a_t ⊙ h_{t-1} + b_t`` computed
with ``jax.lax.associative_scan`` at train/prefill (log-depth on TPU) and a
single fused step at decode. Mamba's state is (d_inner, d_state) per token,
so the parallel scan is **chunked**: ``lax.scan`` over chunks of the
sequence carrying only the (B, d_inner, d_state) boundary state, associative
scan within a chunk — peak memory (B, chunk, d_inner, d_state) instead of
(B, S, d_inner, d_state). This is the TPU-native replacement for the CUDA
selective-scan kernel (DESIGN.md hardware-adaptation notes).

Quantizable linears (in/out/gate/x/dt projections) all route through
``dense`` and are therefore visible to the RPIQ pipeline.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models.linear import dense, init_dense
from repro.models.layers import causal_conv1d, init_conv1d


def _diag_recurrence(a: jax.Array, b: jax.Array,
                     h0: Optional[jax.Array]) -> jax.Array:
    """h_t = a_t ⊙ h_{t-1} + b_t along axis 1. a/b: (B, S, ...)."""
    if h0 is not None:
        # fold the boundary state into the first step
        b = b.at[:, 0].add(a[:, 0] * h0)
    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2
    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h


def _chunked_recurrence(a: jax.Array, b: jax.Array, h0: jax.Array,
                        chunk: int) -> Tuple[jax.Array, jax.Array]:
    """Chunked diagonal recurrence. a/b: (B, S, ...); h0: (B, ...).

    Returns (h: (B, S, ...), h_last: (B, ...)).
    """
    B, S = a.shape[:2]
    if S <= chunk:
        h = _diag_recurrence(a, b, h0)
        return h, h[:, -1]
    assert S % chunk == 0, (S, chunk)
    n = S // chunk
    a_c = a.reshape(B, n, chunk, *a.shape[2:]).transpose(
        1, 0, 2, *range(3, a.ndim + 1))
    b_c = b.reshape(B, n, chunk, *b.shape[2:]).transpose(
        1, 0, 2, *range(3, b.ndim + 1))

    def step(h, xs):
        ac, bc = xs
        hc = _diag_recurrence(ac, bc, h)
        return hc[:, -1], hc

    h_last, hs = jax.lax.scan(step, h0, (a_c, b_c))
    h = hs.transpose(1, 0, 2, *range(3, a.ndim + 1)).reshape(B, S,
                                                             *a.shape[2:])
    return h, h_last


# ---------------------------------------------------------------------------
# RG-LRU (recurrentgemma / Griffin)
# ---------------------------------------------------------------------------

_RGLRU_C = 8.0


def init_rglru_block(cfg: ModelConfig, key: jax.Array) -> Dict:
    d, w = cfg.d_model, cfg.rglru.lru_width
    ks = jax.random.split(key, 6)
    # Λ init so that a = sigmoid(Λ)^c lands in (0.9, 0.999)
    u = jax.random.uniform(ks[0], (w,), minval=0.9, maxval=0.999)
    lam = jnp.log(u ** (1.0 / _RGLRU_C) / (1 - u ** (1.0 / _RGLRU_C)))
    return {
        "in": init_dense(ks[1], d, w),
        "gate": init_dense(ks[2], d, w),
        "conv": init_conv1d(ks[3], cfg.rglru.conv1d_width, w),
        "rg": init_dense(ks[4], w, w, scale=w ** -0.5),   # recurrence gate
        "ig": init_dense(ks[5], w, w, scale=w ** -0.5),   # input gate
        "lambda": lam,
        "out": init_dense(jax.random.fold_in(key, 7), w, d,
                          scale=w ** -0.5),
    }


def _rglru_gates(p: Dict, x: jax.Array, name: str):
    """log_a: (B, S, W) in log space; gated input (B, S, W)."""
    r = jax.nn.sigmoid(dense(p["rg"], x, f"{name}.rg").astype(jnp.float32))
    i = jax.nn.sigmoid(dense(p["ig"], x, f"{name}.ig").astype(jnp.float32))
    log_a = -_RGLRU_C * r * jax.nn.softplus(-p["lambda"].astype(jnp.float32))
    a = jnp.exp(log_a)
    # normalized input: sqrt(1 - a^2) ⊙ (i ⊙ x)
    b = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (
        i * x.astype(jnp.float32))
    return a, b


def rglru_block(cfg: ModelConfig, p: Dict, x: jax.Array,
                state: Optional[Dict] = None, name: str = "rglru"
                ) -> Tuple[jax.Array, Dict]:
    """Full-sequence RG-LRU temporal-mix block. x: (B, S, D).

    state: {"conv": (B, K-1, W), "h": (B, W)} or None.
    Returns (y: (B, S, D), new_state).
    """
    gate = jax.nn.gelu(dense(p["gate"], x, f"{name}.gate"))
    u = dense(p["in"], x, f"{name}.in")
    conv_state = None if state is None else state["conv"]
    u, conv_state = causal_conv1d(p["conv"], u, conv_state)
    a, b = _rglru_gates(p, u, name)
    h0 = None if state is None else state["h"].astype(jnp.float32)
    h, h_last = _chunked_recurrence(a, b, jnp.zeros_like(a[:, 0])
                                    if h0 is None else h0, chunk=1024)
    y = dense(p["out"], (h.astype(x.dtype) * gate), f"{name}.out")
    return y, {"conv": conv_state, "h": h_last.astype(x.dtype)}


def rglru_decode(cfg: ModelConfig, p: Dict, x: jax.Array, state: Dict,
                 name: str = "rglru") -> Tuple[jax.Array, Dict]:
    """Single-token step. x: (B, 1, D)."""
    gate = jax.nn.gelu(dense(p["gate"], x, f"{name}.gate"))
    u = dense(p["in"], x, f"{name}.in")
    u, conv_state = causal_conv1d(p["conv"], u, state["conv"])
    a, b = _rglru_gates(p, u, name)                    # (B, 1, W)
    h = a[:, 0] * state["h"].astype(jnp.float32) + b[:, 0]
    y = dense(p["out"], (h[:, None, :].astype(x.dtype) * gate),
              f"{name}.out")
    return y, {"conv": conv_state, "h": h.astype(x.dtype)}


def init_rglru_state(cfg: ModelConfig, batch: int,
                     dtype=jnp.bfloat16) -> Dict:
    w = cfg.rglru.lru_width
    k = cfg.rglru.conv1d_width
    return {"conv": jnp.zeros((batch, k - 1, w), dtype),
            "h": jnp.zeros((batch, w), dtype)}


# ---------------------------------------------------------------------------
# Mamba-1 (falcon-mamba)
# ---------------------------------------------------------------------------

def init_mamba_block(cfg: ModelConfig, key: jax.Array) -> Dict:
    s = cfg.ssm
    d = cfg.d_model
    d_inner = s.expand * d
    ks = jax.random.split(key, 5)
    a = jnp.tile(jnp.arange(1, s.d_state + 1, dtype=jnp.float32)[None, :],
                 (d_inner, 1))
    return {
        "in": init_dense(ks[0], d, 2 * d_inner),
        "conv": init_conv1d(ks[1], s.d_conv, d_inner),
        "x": init_dense(ks[2], d_inner, s.dt_rank + 2 * s.d_state),
        "dt": init_dense(ks[3], s.dt_rank, d_inner, bias=True),
        "a_log": jnp.log(a),                       # (d_inner, d_state)
        "d_skip": jnp.ones((d_inner,), jnp.float32),
        "out": init_dense(ks[4], d_inner, d, scale=d_inner ** -0.5),
    }


def _mamba_ssm_inputs(cfg: ModelConfig, p: Dict, u: jax.Array, name: str):
    """u: (B, S, d_inner) post-conv. Returns (a, b, C) for the recurrence."""
    s = cfg.ssm
    proj = dense(p["x"], u, f"{name}.x").astype(jnp.float32)
    dt, Bm, Cm = jnp.split(proj, [s.dt_rank, s.dt_rank + s.d_state], axis=-1)
    dt = jax.nn.softplus(dense(p["dt"], dt.astype(u.dtype), f"{name}.dt")
                         .astype(jnp.float32))               # (B,S,d_inner)
    A = -jnp.exp(p["a_log"].astype(jnp.float32))             # (d_inner, n)
    a = jnp.exp(dt[..., None] * A[None, None])               # (B,S,d,n)
    b = (dt[..., None] * Bm[:, :, None, :]) * \
        u.astype(jnp.float32)[..., None]                     # (B,S,d,n)
    return a, b, Cm


def mamba_block(cfg: ModelConfig, p: Dict, x: jax.Array,
                state: Optional[Dict] = None, name: str = "mamba"
                ) -> Tuple[jax.Array, Dict]:
    """Full-sequence Mamba block. x: (B, S, D)."""
    from repro.kernels import ops as kops
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    xz = dense(p["in"], x, f"{name}.in")
    u, z = jnp.split(xz, [d_inner], axis=-1)
    conv_state = None if state is None else state["conv"]
    u, conv_state = causal_conv1d(p["conv"], u, conv_state)
    u = jax.nn.silu(u)
    proj = dense(p["x"], u, f"{name}.x").astype(jnp.float32)
    dt, Bm, Cm = jnp.split(proj, [s.dt_rank, s.dt_rank + s.d_state],
                           axis=-1)
    dt = jax.nn.softplus(dense(p["dt"], dt.astype(u.dtype), f"{name}.dt")
                         .astype(jnp.float32))
    h0 = (jnp.zeros((x.shape[0], d_inner, s.d_state), jnp.float32)
          if state is None else state["h"].astype(jnp.float32))
    # selective scan: Pallas kernel on TPU (state in VMEM, O(B·S·d) HBM
    # traffic); chunked associative scan on other backends
    y, h_last = kops.selective_scan(u, dt, Bm, Cm, p["a_log"], p["d_skip"],
                                    h0)
    y = (y.astype(jnp.float32)
         * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    out = dense(p["out"], y, f"{name}.out")
    return out, {"conv": conv_state, "h": h_last.astype(x.dtype)}


def mamba_decode(cfg: ModelConfig, p: Dict, x: jax.Array, state: Dict,
                 name: str = "mamba") -> Tuple[jax.Array, Dict]:
    """Single-token step. x: (B, 1, D)."""
    d_inner = cfg.ssm.expand * cfg.d_model
    xz = dense(p["in"], x, f"{name}.in")
    u, z = jnp.split(xz, [d_inner], axis=-1)
    u, conv_state = causal_conv1d(p["conv"], u, state["conv"])
    u = jax.nn.silu(u)
    a, b, Cm = _mamba_ssm_inputs(cfg, p, u, name)            # (B,1,d,n)
    h = a[:, 0] * state["h"].astype(jnp.float32) + b[:, 0]   # (B,d,n)
    y = jnp.einsum("bdn,bn->bd", h, Cm[:, 0])
    y = y + u[:, 0].astype(jnp.float32) * p["d_skip"].astype(jnp.float32)
    # round y to the compute dtype *before* the gate, matching the
    # full-sequence kernel's output rounding point exactly
    y = y.astype(x.dtype).astype(jnp.float32)
    y = (y * jax.nn.silu(z[:, 0].astype(jnp.float32))).astype(x.dtype)
    out = dense(p["out"], y[:, None, :], f"{name}.out")
    return out, {"conv": conv_state, "h": h.astype(x.dtype)}


def init_mamba_state(cfg: ModelConfig, batch: int,
                     dtype=jnp.bfloat16) -> Dict:
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    return {"conv": jnp.zeros((batch, s.d_conv - 1, d_inner), dtype),
            "h": jnp.zeros((batch, d_inner, s.d_state), dtype)}
