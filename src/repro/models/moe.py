"""Mixture-of-Experts FFN with capacity-based sort dispatch.

Design notes (DESIGN.md §2):

  - the classic Mesh-TF one-hot dispatch tensor is (tokens, E, C) — for
    deepseek-v3 train shapes that is ~1e13 elements, so we use the
    sort-based scatter instead: flatten (token, k) assignments, stable-sort
    by expert id, compute each entry's position inside its expert segment
    via ``searchsorted``, and scatter into a dense (E, C, d) buffer.
    Everything is jit-safe and O(T·K log T·K) with no (T, E) one-hots.
  - expert weights are stacked (E, ...) and sharded over the ``model`` mesh
    axis (EP); the buffer's expert axis is sharded likewise, so XLA lowers
    the scatter/gather into an all-to-all pair — the MoE collective the
    roofline tracks.
  - tokens over capacity are *dropped* (contribute nothing; the residual
    stream passes them through) — standard capacity-factor semantics.
  - router runs in float32; aux load-balance loss returned for training.
  - deepseek-style shared experts: always-on dense MLP(s) added to the
    routed output.

Per-expert FFN linears route through ``dense``-style matmuls on stacked
weights; for quantization the pipeline treats each expert's slices as
separate linears (per-expert Hessians from routed tokens — see
core/pipeline.py).
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models.linear import dense, init_dense
from repro.models.layers import _act, init_mlp, mlp


class MoEOutput(NamedTuple):
    y: jax.Array            # (B, S, D)
    aux_loss: jax.Array     # scalar load-balance loss
    expert_load: jax.Array  # (E,) fraction of routed tokens per expert


def init_moe(cfg: ModelConfig, key: jax.Array) -> Dict:
    m = cfg.moe
    d = cfg.d_model
    f = m.d_ff_expert
    ks = jax.random.split(key, 5)
    def stack(k, shape, scale):
        return jax.random.normal(k, shape) * scale
    p = {
        "router": init_dense(ks[0], d, m.num_experts, scale=0.02),
        # stacked expert weights: (E, in, out)
        "w_gate": stack(ks[1], (m.num_experts, d, f), d ** -0.5),
        "w_up": stack(ks[2], (m.num_experts, d, f), d ** -0.5),
        "w_down": stack(ks[3], (m.num_experts, f, d), f ** -0.5),
    }
    if m.num_shared_experts > 0:
        p["shared"] = init_mlp(cfg, ks[4], d, f * m.num_shared_experts)
    return p


def _expert_weights(w) -> jax.Array:
    """(E, in, out) bf16 view of stacked expert weights.

    Accepts a float array or a :class:`QuantizedTensor` holding (E, out,
    in//2)-packed int4 codes with (E, out, groups) scales/zeros.
    """
    from repro.core.quant import QuantizedTensor
    if isinstance(w, QuantizedTensor):
        packed = w.packed                          # (E, out, in//2)
        lo = (packed & jnp.uint8(0x0F)).astype(jnp.float32)
        hi = ((packed >> 4) & jnp.uint8(0x0F)).astype(jnp.float32)
        e, o, kh = packed.shape
        codes = jnp.stack([lo, hi], axis=-1).reshape(e, o, kh * 2)
        s = jnp.repeat(w.scales.astype(jnp.float32), w.group_size, axis=2)
        z = jnp.repeat(w.zeros.astype(jnp.float32), w.group_size, axis=2)
        return ((codes - z) * s).astype(jnp.bfloat16).transpose(0, 2, 1)
    return w.astype(jnp.bfloat16)


def _capacity(cfg: ModelConfig, n_tokens: int) -> int:
    m = cfg.moe
    c = int(m.capacity_factor * m.top_k * n_tokens / m.num_experts)
    return max(8, -(-c // 8) * 8)   # round up to 8 for TPU lane alignment


class Dispatch(NamedTuple):
    """Sort-based dispatch plan + the dense per-expert input buffer."""
    buf: jax.Array        # (E, C, d) expert inputs
    slot: jax.Array       # (T*K,) buffer row per sorted assignment
    st: jax.Array         # (T*K,) source token per sorted assignment
    sg: jax.Array         # (T*K,) gate per sorted assignment
    keep: jax.Array       # (T*K,) kept (under capacity)
    aux: jax.Array        # scalar load-balance loss
    counts: jax.Array     # (E,) routed tokens per expert (pre-capacity)


class RouteHead(NamedTuple):
    """The router's output alone: top-k assignments + renormalized gates.

    Everything *structural* about dispatch (sort order, segment
    positions, capacity keeps, buffer slots) is a pure function of
    ``experts`` — gate VALUES only weight the combine. That split is
    what makes the overlap scheduler's flip-repair sound: two streams
    whose ``experts`` agree elementwise share the entire plan bitwise.
    """
    experts: jax.Array    # (T, K) top-k expert ids
    gates: jax.Array      # (T, K) renormalized gates
    aux: jax.Array        # scalar load-balance loss


class RoutePlan(NamedTuple):
    """Full dispatch plan: head + the sort-based structural placement."""
    experts: jax.Array    # (T, K) top-k expert ids
    gates: jax.Array      # (T, K) renormalized gates
    aux: jax.Array        # scalar load-balance loss
    order: jax.Array      # (T*K,) stable argsort of the flat expert ids
    se: jax.Array         # (T*K,) sorted expert ids
    st: jax.Array         # (T*K,) source token per sorted assignment
    sg: jax.Array         # (T*K,) gate per sorted assignment
    keep: jax.Array       # (T*K,) kept (under capacity)
    slot: jax.Array       # (T*K,) buffer row (E*C = drop row)
    counts: jax.Array     # (E,) routed tokens per expert (pre-capacity)
    cap: int              # static per-expert capacity


def route_head(cfg: ModelConfig, p: Dict, xt: jax.Array,
               name: str = "moe") -> RouteHead:
    """Router forward + top-k on flat tokens xt: (T, d)."""
    m = cfg.moe
    e, k = m.num_experts, m.top_k
    # router in f32 (and tappable: the pipeline reads the MoE block inputs
    # from this tap; the router itself stays full-precision — see pipeline)
    logits = dense(p["router"], xt.astype(jnp.float32),
                   f"{name}.router")                            # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, experts = jax.lax.top_k(probs, k)                    # (T, K)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    # aux load-balance loss (Switch-style): E * Σ_e f_e · P_e
    me = jnp.mean(probs, axis=0)                                # (E,)
    one_hot_top1 = jax.nn.one_hot(experts[:, 0], e, dtype=jnp.float32)
    fe = jnp.mean(one_hot_top1, axis=0)
    aux = e * jnp.sum(fe * me) * m.aux_loss_weight
    return RouteHead(experts, gates, aux)


def plan_from_head(cfg: ModelConfig, head: RouteHead) -> RoutePlan:
    """Structural dispatch plan from the routing head (sort + capacity)."""
    m = cfg.moe
    t, k = head.experts.shape
    e = m.num_experts
    cap = _capacity(cfg, t)
    flat_e = head.experts.reshape(-1)                           # (T*K,)
    flat_t = jnp.repeat(jnp.arange(t), k)                       # (T*K,)
    flat_g = head.gates.reshape(-1)

    order = jnp.argsort(flat_e, stable=True)
    se = flat_e[order]
    st = flat_t[order]
    sg = flat_g[order]
    seg_start = jnp.searchsorted(se, jnp.arange(e), side="left")   # (E,)
    seg_end = jnp.searchsorted(se, jnp.arange(e), side="right")
    pos = jnp.arange(t * k) - seg_start[se]                     # pos in expert
    keep = pos < cap
    slot = jnp.where(keep, se * cap + pos, e * cap)             # drop row
    return RoutePlan(head.experts, head.gates, head.aux, order, se, st,
                     sg, keep, slot,
                     (seg_end - seg_start).astype(jnp.int32), cap)


def route(cfg: ModelConfig, p: Dict, xt: jax.Array,
          name: str = "moe") -> RoutePlan:
    """Full dispatch plan for flat tokens xt: (T, d)."""
    return plan_from_head(cfg, route_head(cfg, p, xt, name))


def reuse_plan(plan: RoutePlan, head: RouteHead) -> RoutePlan:
    """Rebind a structural plan to a fresh routing head.

    Only valid when ``head.experts`` equals ``plan.experts`` elementwise
    (the caller checks): the structure is a pure function of the expert
    ids, so the sort/positions/slots carry over bitwise while the gate
    values and aux loss come from the new head.
    """
    return plan._replace(experts=head.experts, gates=head.gates,
                         aux=head.aux,
                         sg=head.gates.reshape(-1)[plan.order])


def apply_route(plan: RoutePlan, xt: jax.Array) -> jax.Array:
    """Scatter flat tokens xt: (T, d) into the (E, C, d) expert buffer."""
    e = plan.counts.shape[0]
    cap = plan.cap
    d = xt.shape[-1]
    buf = jnp.zeros((e * cap + 1, d), xt.dtype)
    buf = buf.at[plan.slot].set(xt[plan.st].astype(xt.dtype))
    return buf[:-1].reshape(e, cap, d)


def flipped_assignments(spec: RoutePlan, true: RoutePlan) -> jax.Array:
    """(T*K,) bool mask, flat (token-major, k-minor) order: assignments
    whose dispatch *placement* differs between two plans.

    An assignment is flipped when its expert id changed OR its buffer
    slot moved — the latter catches the cascades a raw expert comparison
    misses: a flip elsewhere in a segment displaces every later position
    in it, and can push previously-kept assignments over capacity (their
    slot collapses to the drop row). Pinned against a brute-force
    placement oracle in tests/test_moe_flip.py.
    """
    def flat_slot(p: RoutePlan) -> jax.Array:
        # slot[i] belongs to sorted position i == flat index order[i]
        return jnp.zeros_like(p.slot).at[p.order].set(p.slot)

    return ((spec.experts.reshape(-1) != true.experts.reshape(-1))
            | (flat_slot(spec) != flat_slot(true)))


def dispatch(cfg: ModelConfig, p: Dict, xt: jax.Array,
             name: str = "moe") -> Dispatch:
    """Route flat tokens xt: (T, d) to the (E, C, d) expert buffer."""
    plan = route(cfg, p, xt, name)
    buf = apply_route(plan, xt)
    return Dispatch(buf, plan.slot, plan.st, plan.sg, plan.keep, plan.aux,
                    plan.counts)


def moe_ffn(cfg: ModelConfig, p: Dict, x: jax.Array,
            name: str = "moe") -> MoEOutput:
    """x: (B, S, D) -> routed expert mixture, same shape.

    When distributed rules are active, dispatch runs under a partial-manual
    ``shard_map`` (manual over the DP axes, GSPMD-auto over ``model``): the
    argsort/scatter routing then stays **local to each data shard** instead
    of forcing GSPMD to materialize the global (T·K, d) dispatch on every
    chip (measured 58 replicated full-size gathers/layer on deepseek-v3
    train_4k — §Perf cell B). Expert einsums still partition over ``model``
    (EP) inside the auto region.
    """
    from repro.distributed.sharding import current_rules
    rules = current_rules()
    if (rules is not None and rules.dp_axes
            and getattr(rules, "ep_local_dispatch", True)
            and x.shape[0] % rules.dp_size() == 0):
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        dp = tuple(rules.dp_axes)
        auto = frozenset(rules.mesh.axis_names) - frozenset(dp)

        def local(xl):
            out = _moe_ffn_body(cfg, p, xl, name)
            return (out.y, jax.lax.pmean(out.aux_loss, dp),
                    jax.lax.pmean(out.expert_load, dp))

        y, aux, load = shard_map(
            local, mesh=rules.mesh,
            in_specs=(P(dp),), out_specs=(P(dp), P(), P()),
            check_rep=False, auto=auto)(x)
        return MoEOutput(y, aux, load)
    return _moe_ffn_body(cfg, p, x, name)


def _moe_ffn_body(cfg: ModelConfig, p: Dict, x: jax.Array,
                  name: str = "moe") -> MoEOutput:
    m = cfg.moe
    b, s, d = x.shape
    t = b * s
    e, k = m.num_experts, m.top_k
    xt = x.reshape(t, d)
    cap = _capacity(cfg, t)

    dsp = dispatch(cfg, p, xt, name)
    buf, slot, st, sg, keep, aux = (dsp.buf, dsp.slot, dsp.st, dsp.sg,
                                    dsp.keep, dsp.aux)

    # --- expert FFN (stacked einsum; E sharded over model axis) ------------
    # experts may be int4-packed (quantized serving): dequantize on the fly —
    # HBM reads stay at 0.5 byte/weight, which is the memory-bound decode win
    g = jnp.einsum("ecd,edf->ecf", buf.astype(jnp.bfloat16),
                   _expert_weights(p["w_gate"]))
    u = jnp.einsum("ecd,edf->ecf", buf.astype(jnp.bfloat16),
                   _expert_weights(p["w_up"]))
    hmid = _act(cfg.act, g.astype(jnp.float32)).astype(jnp.bfloat16) * u
    yexp = jnp.einsum("ecf,efd->ecd", hmid,
                      _expert_weights(p["w_down"]))             # (E, C, d)

    # --- combine ------------------------------------------------------------
    yflat = yexp.reshape(e * cap, d)
    contrib = jnp.where(keep[:, None], yflat[jnp.clip(slot, 0, e * cap - 1)],
                        0.0).astype(jnp.float32) * sg[:, None]
    y = jnp.zeros((t, d), jnp.float32).at[st].add(contrib)

    if m.num_shared_experts > 0:
        y = y + mlp(cfg, p["shared"], xt[None], name=f"{name}.shared"
                    )[0].astype(jnp.float32)

    load = dsp.counts.astype(jnp.float32) * e / (t * k)  # 1.0 == balanced
    return MoEOutput(y.reshape(b, s, d).astype(x.dtype), aux, load)
