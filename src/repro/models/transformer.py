"""Model assembly: decoder-only LMs (all families) and enc-dec (whisper).

Layer stacking
--------------
``layer_specs(cfg)`` expands the config's block pattern into one
``(mixer, mlp)`` spec per layer; ``segment_specs`` groups the stack into
*segments* — either ``k`` repeats of a short periodic super-block (scanned
with ``lax.scan`` over stacked params → small HLO even for 61-layer models)
or a run of identical layers. recurrentgemma's (rglru, rglru, local)×12+2
and deepseek's 3-dense + 58-MoE both segment cleanly.

Param layout (canonical, used by training, serving, dry-run and the
quantization pipeline):

  params = {
    "embed": {...}, "final_norm": {...}, "lm_head"?: {...},
    "blocks": [seg0, seg1, ...]   # seg = {"sub0": {...}, "sub1": ...}
                                  # every leaf stacked with leading (count,)
    "mtp"?: {...}
  }

Eager per-layer access (calibration pipeline, CPU) uses
``tree_map(lambda a: a[i], seg)``.

Sharding hints: the residual stream gets `shard_hint(h, "dp", None/"sp",
None)` at segment boundaries; actual specs are injected by
``repro.distributed.sharding.use_rules`` — models stay mesh-agnostic.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, List, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models import attention as attn
from repro.models import recurrent as rec
from repro.models import moe as moe_mod
from repro.models.layers import (embed, init_embed, init_mlp, init_norm,
                                 mlp, norm, sinusoidal_positions, unembed)
from repro.models.linear import init_dense
from repro.distributed.sharding import shard_hint


LayerSpec = Tuple[str, str]     # (mixer, mlp) — static strings


class Segment(NamedTuple):
    specs: Tuple[LayerSpec, ...]   # super-block period
    count: int                     # repeats


# ---------------------------------------------------------------------------
# Spec expansion / segmentation
# ---------------------------------------------------------------------------

def layer_specs(cfg: ModelConfig) -> Tuple[LayerSpec, ...]:
    out: List[LayerSpec] = []
    for i, kind in enumerate(cfg.layer_kinds):
        if kind in ("mamba",):
            mixer, mlp_kind = "mamba", "none"
        elif kind in ("rglru",):
            mixer, mlp_kind = "rglru", "dense"
        else:                       # attn | swa | local
            mixer = "mla" if cfg.mla.enabled else kind
            mlp_kind = "dense"
        if cfg.moe.num_experts > 0 and mlp_kind == "dense":
            if i >= cfg.moe.first_dense_layers:
                mlp_kind = "moe"
        out.append((mixer, mlp_kind))
    return tuple(out)


def segment_specs(specs: Sequence[LayerSpec],
                  pattern_len: int) -> List[Segment]:
    """Greedy tiling: periodic super-blocks where they repeat, runs else."""
    segs: List[Segment] = []
    i, n = 0, len(specs)
    while i < n:
        j = i
        while j < n and specs[j] == specs[i]:
            j += 1
        run1 = j - i
        runq = 0
        q = pattern_len
        if q > 1 and i + q <= n:
            base = tuple(specs[i:i + q])
            while (i + (runq + 1) * q <= n
                   and tuple(specs[i + runq * q:i + (runq + 1) * q]) == base):
                runq += 1
        if q > 1 and runq * q > run1:
            segs.append(Segment(tuple(specs[i:i + q]), runq))
            i += runq * q
        else:
            segs.append(Segment((specs[i],), run1))
            i += run1
    return segs


def segments(cfg: ModelConfig) -> List[Segment]:
    return segment_specs(layer_specs(cfg), len(cfg.block_pattern))


# ---------------------------------------------------------------------------
# Single layer: init / forward / prefill / decode
# ---------------------------------------------------------------------------

def _window_of(cfg: ModelConfig, mixer: str) -> int:
    return cfg.window_size if mixer in ("swa", "local") else 0


def init_layer(cfg: ModelConfig, spec: LayerSpec, key: jax.Array) -> Dict:
    mixer, mlp_kind = spec
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p: Dict[str, Any] = {"norm1": init_norm(cfg, cfg.d_model)}
    if mixer == "mla":
        p["mixer"] = attn.init_mla(cfg, k1)
    elif mixer in ("attn", "swa", "local"):
        p["mixer"] = attn.init_attention(cfg, k1,
                                         bias=cfg.norm == "layernorm")
    elif mixer == "rglru":
        p["mixer"] = rec.init_rglru_block(cfg, k1)
    elif mixer == "mamba":
        p["mixer"] = rec.init_mamba_block(cfg, k1)
    else:
        raise ValueError(mixer)
    if mlp_kind != "none":
        p["norm2"] = init_norm(cfg, cfg.d_model)
        if mlp_kind == "moe":
            p["mlp"] = moe_mod.init_moe(cfg, k2)
        else:
            p["mlp"] = init_mlp(cfg, k2, cfg.d_model, cfg.d_ff,
                                bias=cfg.norm == "layernorm")
    return p


def layer_forward(cfg: ModelConfig, spec: LayerSpec, p: Dict, h: jax.Array,
                  positions: jax.Array, name: str = ""
                  ) -> Tuple[jax.Array, jax.Array]:
    """Train-mode (no cache). Returns (h, aux_loss)."""
    mixer, mlp_kind = spec
    aux = jnp.zeros((), jnp.float32)
    hn = norm(cfg, p["norm1"], h)
    if mixer == "mla":
        y = attn.mla_forward(cfg, p["mixer"], hn, positions,
                             name=f"{name}mixer")
    elif mixer in ("attn", "swa", "local"):
        y = attn.attention_forward(cfg, p["mixer"], hn, positions,
                                   window=_window_of(cfg, mixer),
                                   name=f"{name}mixer")
    elif mixer == "rglru":
        y, _ = rec.rglru_block(cfg, p["mixer"], hn, None,
                               name=f"{name}mixer")
    elif mixer == "mamba":
        y, _ = rec.mamba_block(cfg, p["mixer"], hn, None,
                               name=f"{name}mixer")
    h = h + y
    if mlp_kind != "none":
        hn = norm(cfg, p["norm2"], h)
        if mlp_kind == "moe":
            out = moe_mod.moe_ffn(cfg, p["mlp"], hn, name=f"{name}mlp")
            h = h + out.y
            aux = aux + out.aux_loss
        else:
            h = h + mlp(cfg, p["mlp"], hn, name=f"{name}mlp")
    return h, aux


def _float_cache_dtype(dtype):
    """Resolve the ``"int8"`` sentinel to bf16 for cache kinds that stay in
    float: MLA latents (already the compressed-memory form), recurrent
    states, and enc-dec cross-KV (computed once, not append-quantized)."""
    return jnp.bfloat16 if isinstance(dtype, str) and dtype == "int8" \
        else dtype


def init_layer_cache(cfg: ModelConfig, spec: LayerSpec, batch: int,
                     max_len: int, dtype=jnp.bfloat16) -> Any:
    """``dtype`` may be the string sentinel ``"int8"``: GQA full/ring
    self-attention caches then store int8 codes + f32 scales + error
    accumulators (attention.init_kv_cache); other cache kinds keep bf16."""
    mixer, _ = spec
    if mixer == "mla":
        return attn.init_mla_cache(cfg, batch, max_len,
                                   _float_cache_dtype(dtype))
    if mixer in ("attn",):
        return attn.init_kv_cache(cfg, batch, max_len, dtype)
    if mixer in ("swa", "local"):
        w = min(cfg.window_size, max_len)
        return attn.init_kv_cache(cfg, batch, w, dtype)
    if mixer == "rglru":
        return rec.init_rglru_state(cfg, batch, _float_cache_dtype(dtype))
    if mixer == "mamba":
        return rec.init_mamba_state(cfg, batch, _float_cache_dtype(dtype))
    raise ValueError(mixer)


def layer_prefill(cfg: ModelConfig, spec: LayerSpec, p: Dict, h: jax.Array,
                  positions: jax.Array, cache: Any, name: str = "",
                  start: Optional[int] = None) -> Tuple[jax.Array, Any]:
    """``start`` marks a chunked-prefill continuation (attention variants
    attend cache + chunk; recurrent states continue naturally)."""
    mixer, mlp_kind = spec
    hn = norm(cfg, p["norm1"], h)
    if mixer == "mla":
        y, cache = attn.mla_prefill(cfg, p["mixer"], hn, positions, cache,
                                    name=f"{name}mixer", start=start)
    elif mixer in ("attn", "swa", "local"):
        y, cache = attn.attention_prefill(cfg, p["mixer"], hn, positions,
                                          cache,
                                          window=_window_of(cfg, mixer),
                                          name=f"{name}mixer", start=start)
    elif mixer == "rglru":
        y, cache = rec.rglru_block(cfg, p["mixer"], hn, cache,
                                   name=f"{name}mixer")
    elif mixer == "mamba":
        y, cache = rec.mamba_block(cfg, p["mixer"], hn, cache,
                                   name=f"{name}mixer")
    h = h + y
    if mlp_kind != "none":
        hn = norm(cfg, p["norm2"], h)
        if mlp_kind == "moe":
            h = h + moe_mod.moe_ffn(cfg, p["mlp"], hn,
                                    name=f"{name}mlp").y
        else:
            h = h + mlp(cfg, p["mlp"], hn, name=f"{name}mlp")
    return h, cache


def layer_decode(cfg: ModelConfig, spec: LayerSpec, p: Dict, h: jax.Array,
                 pos: jax.Array, cache: Any, name: str = ""
                 ) -> Tuple[jax.Array, Any]:
    mixer, mlp_kind = spec
    hn = norm(cfg, p["norm1"], h)
    if mixer == "mla":
        y, cache = attn.mla_decode(cfg, p["mixer"], hn, pos, cache,
                                   name=f"{name}mixer")
    elif mixer in ("attn", "swa", "local"):
        y, cache = attn.attention_decode(cfg, p["mixer"], hn, pos, cache,
                                         window=_window_of(cfg, mixer),
                                         name=f"{name}mixer")
    elif mixer == "rglru":
        y, cache = rec.rglru_decode(cfg, p["mixer"], hn, cache,
                                    name=f"{name}mixer")
    elif mixer == "mamba":
        y, cache = rec.mamba_decode(cfg, p["mixer"], hn, cache,
                                    name=f"{name}mixer")
    h = h + y
    if mlp_kind != "none":
        hn = norm(cfg, p["norm2"], h)
        if mlp_kind == "moe":
            h = h + moe_mod.moe_ffn(cfg, p["mlp"], hn,
                                    name=f"{name}mlp").y
        else:
            h = h + mlp(cfg, p["mlp"], hn, name=f"{name}mlp")
    return h, cache


# ---------------------------------------------------------------------------
# Stacked segments
# ---------------------------------------------------------------------------

def _stack_trees(trees: List[Any]) -> Any:
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *trees)


def init_blocks(cfg: ModelConfig, key: jax.Array) -> List[Dict]:
    out = []
    li = 0
    for seg in segments(cfg):
        elems = []
        for c in range(seg.count):
            sub = {}
            for s_i, spec in enumerate(seg.specs):
                sub[f"sub{s_i}"] = init_layer(
                    cfg, spec, jax.random.fold_in(key, li))
                li += 1
            elems.append(sub)
        out.append(_stack_trees(elems))
    return out


def init_block_caches(cfg: ModelConfig, batch: int, max_len: int,
                      dtype=jnp.bfloat16) -> List[Any]:
    out = []
    for seg in segments(cfg):
        sub = {f"sub{i}": init_layer_cache(cfg, spec, batch, max_len, dtype)
               for i, spec in enumerate(seg.specs)}
        out.append(jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a[None], (seg.count,) + a.shape)
            .copy() if seg.count > 1 else a[None], sub))
    return out


def _seg_take(seg_params: Any, i) -> Any:
    return jax.tree_util.tree_map(lambda a: a[i], seg_params)


def blocks_forward(cfg: ModelConfig, blocks: List[Dict], h: jax.Array,
                   positions: jax.Array, *, remat: bool = False,
                   unroll_eager: bool = False
                   ) -> Tuple[jax.Array, jax.Array]:
    """Run all segments (train mode). Returns (h, aux_loss_sum)."""
    aux = jnp.zeros((), jnp.float32)
    segs = segments(cfg)
    for seg, seg_params in zip(segs, blocks):
        def superblock(carry, elem_params, _specs=seg.specs):
            h, aux = carry
            for s_i, spec in enumerate(_specs):
                h, a = layer_forward(cfg, spec, elem_params[f"sub{s_i}"], h,
                                     positions)
                aux = aux + a
            h = shard_hint(h, "act")
            return (h, aux), None

        if unroll_eager:
            for c in range(seg.count):
                (h, aux), _ = superblock((h, aux), _seg_take(seg_params, c))
        else:
            fn = superblock
            if remat:
                fn = jax.checkpoint(superblock,
                                    prevent_cse=False)
            (h, aux), _ = jax.lax.scan(fn, (h, aux), seg_params)
    return h, aux


def blocks_prefill(cfg: ModelConfig, blocks: List[Dict], h: jax.Array,
                   positions: jax.Array, caches: List[Any],
                   unroll_eager: bool = False,
                   start: Optional[int] = None
                   ) -> Tuple[jax.Array, List[Any]]:
    segs = segments(cfg)
    new_caches = []
    for seg, seg_params, seg_cache in zip(segs, blocks, caches):
        def superblock(h, xs, _specs=seg.specs):
            elem_params, elem_cache = xs
            out_cache = {}
            for s_i, spec in enumerate(_specs):
                h, c = layer_prefill(cfg, spec, elem_params[f"sub{s_i}"], h,
                                     positions, elem_cache[f"sub{s_i}"],
                                     start=start)
                out_cache[f"sub{s_i}"] = c
            h = shard_hint(h, "act")
            return h, out_cache

        if unroll_eager:
            ncs = []
            for c in range(seg.count):
                h, nc = superblock(h, (_seg_take(seg_params, c),
                                       _seg_take(seg_cache, c)))
                ncs.append(nc)
            new_caches.append(_stack_trees(ncs))
        else:
            h, nc = jax.lax.scan(superblock, h, (seg_params, seg_cache))
            new_caches.append(nc)
    return h, new_caches


def blocks_decode(cfg: ModelConfig, blocks: List[Dict], h: jax.Array,
                  pos: jax.Array, caches: List[Any],
                  unroll_eager: bool = False
                  ) -> Tuple[jax.Array, List[Any]]:
    segs = segments(cfg)
    new_caches = []
    for seg, seg_params, seg_cache in zip(segs, blocks, caches):
        def superblock(h, xs, _specs=seg.specs):
            elem_params, elem_cache = xs
            out_cache = {}
            for s_i, spec in enumerate(_specs):
                h, c = layer_decode(cfg, spec, elem_params[f"sub{s_i}"], h,
                                    pos, elem_cache[f"sub{s_i}"])
                out_cache[f"sub{s_i}"] = c
            return h, out_cache

        if unroll_eager:
            ncs = []
            for c in range(seg.count):
                h, nc = superblock(h, (_seg_take(seg_params, c),
                                       _seg_take(seg_cache, c)))
                ncs.append(nc)
            new_caches.append(_stack_trees(ncs))
        else:
            h, nc = jax.lax.scan(superblock, h, (seg_params, seg_cache))
            new_caches.append(nc)
    return h, new_caches


# ---------------------------------------------------------------------------
# Decoder-only LM
# ---------------------------------------------------------------------------

def init_params(cfg: ModelConfig, key: jax.Array) -> Dict:
    k_e, k_b, k_h, k_m = jax.random.split(key, 4)
    params: Dict[str, Any] = {
        "embed": init_embed(k_e, cfg.vocab_size, cfg.d_model),
        "blocks": init_blocks(cfg, k_b),
        "final_norm": init_norm(cfg, cfg.d_model),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = init_dense(k_h, cfg.d_model, cfg.vocab_size)
    if cfg.mtp_depth > 0:
        params["mtp"] = {
            "proj": init_dense(k_m, 2 * cfg.d_model, cfg.d_model),
            "norm": init_norm(cfg, cfg.d_model),
            "layer": init_layer(cfg, layer_specs(cfg)[-1],
                                jax.random.fold_in(k_m, 1)),
        }
    return params


def _embed_inputs(cfg: ModelConfig, params: Dict, tokens: jax.Array,
                  embeds: Optional[jax.Array]) -> jax.Array:
    """Token embeddings, with optional frontend embeds prepended."""
    dtype = jnp.dtype(cfg.dtype)
    h = embed(params["embed"], tokens, dtype)
    if embeds is not None:
        h = jnp.concatenate([embeds.astype(dtype), h], axis=1)
    return h


def forward(cfg: ModelConfig, params: Dict, tokens: jax.Array,
            embeds: Optional[jax.Array] = None, *, remat: bool = False,
            unroll_eager: bool = False, return_hidden: bool = False):
    """Full-sequence forward. Returns (logits (B,S,V) f32, aux_loss) or,
    with ``return_hidden``, (logits, aux_loss, h_normed) for MTP heads."""
    h = _embed_inputs(cfg, params, tokens, embeds)
    b, s, _ = h.shape
    h = shard_hint(h, "act")
    positions = jnp.arange(s, dtype=jnp.int32)[None, :].repeat(b, 0)
    h, aux = blocks_forward(cfg, params["blocks"], h, positions,
                            remat=remat, unroll_eager=unroll_eager)
    h = norm(cfg, params["final_norm"], h)
    logits = unembed(cfg, params, h)
    logits = shard_hint(logits, "logits")
    if return_hidden:
        return logits, aux, h
    return logits, aux


def mtp_logits(cfg: ModelConfig, params: Dict, h_final: jax.Array,
               tokens: jax.Array) -> jax.Array:
    """deepseek multi-token prediction head: predict t+2 from (h_t, e_{t+1}).

    h_final: (B, S, D) post-final-norm hidden; tokens: (B, S).
    Returns logits (B, S-1, V) for positions t -> token t+2.
    """
    p = params["mtp"]
    dtype = h_final.dtype
    e_next = embed(params["embed"], tokens[:, 1:], dtype)     # (B, S-1, D)
    h_in = jnp.concatenate([h_final[:, :-1], e_next], axis=-1)
    from repro.models.linear import dense
    h0 = dense(p["proj"], h_in, "mtp.proj")
    b, s, _ = h0.shape
    positions = jnp.arange(s, dtype=jnp.int32)[None, :].repeat(b, 0)
    h1, _ = layer_forward(cfg, layer_specs(cfg)[-1], p["layer"], h0,
                          positions, name="mtp.")
    h1 = norm(cfg, p["norm"], h1)
    return unembed(cfg, params, h1)


def prefill(cfg: ModelConfig, params: Dict, tokens: jax.Array,
            max_len: int, embeds: Optional[jax.Array] = None,
            cache_dtype=jnp.bfloat16, unroll_eager: bool = False
            ) -> Tuple[jax.Array, List[Any]]:
    """Prefill the cache; returns (last-position logits (B, V), caches)."""
    h = _embed_inputs(cfg, params, tokens, embeds)
    b, s, _ = h.shape
    positions = jnp.arange(s, dtype=jnp.int32)[None, :].repeat(b, 0)
    caches = init_block_caches(cfg, b, max_len, cache_dtype)
    h, caches = blocks_prefill(cfg, params["blocks"], h, positions, caches,
                               unroll_eager=unroll_eager)
    h = norm(cfg, params["final_norm"], h[:, -1:])
    logits = unembed(cfg, params, h)[:, 0]
    return logits, caches


def decode_step(cfg: ModelConfig, params: Dict, token: jax.Array,
                pos: jax.Array, caches: List[Any],
                unroll_eager: bool = False
                ) -> Tuple[jax.Array, List[Any]]:
    """One decode step. token: (B,) int32; pos: (B,) positions of `token`.

    Returns (logits (B, V) f32, new caches).
    """
    dtype = jnp.dtype(cfg.dtype)
    h = embed(params["embed"], token[:, None], dtype)         # (B, 1, D)
    h, caches = blocks_decode(cfg, params["blocks"], h, pos, caches,
                              unroll_eager=unroll_eager)
    h = norm(cfg, params["final_norm"], h)
    logits = unembed(cfg, params, h)[:, 0]
    return logits, caches


def prefill_begin(cfg: ModelConfig, params: Dict, tokens: jax.Array,
                  max_len: int, embeds: Optional[jax.Array] = None,
                  cache_dtype=jnp.bfloat16) -> Tuple[jax.Array, List[Any]]:
    """Incremental-prefill setup: embedded inputs + empty caches.

    The caller feeds slices of the returned ``h`` through
    :func:`prefill_step` one chunk at a time (the continuous-batching
    scheduler does this to interleave prefill with decode ticks)."""
    h = _embed_inputs(cfg, params, tokens, embeds)
    return h, init_block_caches(cfg, h.shape[0], max_len, cache_dtype)


def prefill_step(cfg: ModelConfig, params: Dict, h_chunk: jax.Array,
                 start: int, caches: List[Any],
                 unroll_eager: bool = False
                 ) -> Tuple[jax.Array, List[Any]]:
    """Run one prefill chunk occupying positions [start, start+C)."""
    b, c, _ = h_chunk.shape
    positions = start + jnp.arange(c, dtype=jnp.int32)[None, :].repeat(b, 0)
    return blocks_prefill(cfg, params["blocks"], h_chunk, positions, caches,
                          unroll_eager=unroll_eager, start=start)


def prefill_finish(cfg: ModelConfig, params: Dict, h_last: jax.Array
                   ) -> jax.Array:
    """Next-token logits (B, V) from the final chunk's block output."""
    h = norm(cfg, params["final_norm"], h_last[:, -1:])
    return unembed(cfg, params, h)[:, 0]


def prefill_chunked(cfg: ModelConfig, params: Dict, tokens: jax.Array,
                    max_len: int, chunk: int,
                    embeds: Optional[jax.Array] = None,
                    cache_dtype=jnp.bfloat16, unroll_eager: bool = False
                    ) -> Tuple[jax.Array, List[Any]]:
    """Chunked prefill: positions ``[c, c+chunk)`` at a time, each chunk
    attending cached history + itself (``blocks_prefill(start=...)``).
    Logits/caches are equivalent to single-shot :func:`prefill` (pinned in
    tests/test_serving.py); recurrent states thread through naturally.
    """
    assert chunk > 0
    h, caches = prefill_begin(cfg, params, tokens, max_len, embeds,
                              cache_dtype)
    s = h.shape[1]
    hc = h[:, :0]
    for c0 in range(0, s, chunk):
        hc, caches = prefill_step(cfg, params, h[:, c0:min(s, c0 + chunk)],
                                  c0, caches, unroll_eager=unroll_eager)
    return prefill_finish(cfg, params, hc), caches


# ---------------------------------------------------------------------------
# Slotted-cache API (continuous-batching serving — docs/SERVING.md)
# ---------------------------------------------------------------------------

def cache_slots_like(caches: Any, lanes: int) -> Any:
    """A zeroed slotted decode cache with ``lanes`` lanes, shaped like a
    (batch-1) prefill cache. Every cache leaf in this codebase is stacked
    ``(layers, batch, ...)``, so the lane axis is axis 1 uniformly (GQA /
    ring / MLA / recurrent / enc-dec self+cross)."""
    return jax.tree_util.tree_map(
        lambda a: jnp.zeros((a.shape[0], lanes) + a.shape[2:], a.dtype),
        caches)


def cache_slot_insert(caches: Any, src: Any, lane: jax.Array) -> Any:
    """Write a batch-1 cache ``src`` into lane ``lane`` of a slotted cache.

    ``lane`` may be traced (one compiled entry serves every lane). The whole
    lane is overwritten, which is what makes eviction reuse sound: any slots
    a previous occupant wrote are replaced by the new sequence's prefix (and
    positions beyond it are masked by the per-lane ``pos`` at decode time).
    """
    lane = jnp.asarray(lane, jnp.int32)
    return jax.tree_util.tree_map(
        lambda big, small: big.at[:, lane].set(
            small[:, 0].astype(big.dtype)), caches, src)


def cache_slot_evict(caches: Any, lane: jax.Array) -> Any:
    """Zero lane ``lane`` (hygiene only — admission overwrites the lane)."""
    lane = jnp.asarray(lane, jnp.int32)
    return jax.tree_util.tree_map(
        lambda a: a.at[:, lane].set(
            jnp.zeros(a.shape[:1] + a.shape[2:], a.dtype)), caches)


# ---------------------------------------------------------------------------
# Encoder-decoder (whisper)
# ---------------------------------------------------------------------------

def init_encdec_params(cfg: ModelConfig, key: jax.Array) -> Dict:
    """Whisper-style: encoder stack (bidirectional) + decoder with cross."""
    k_e, k_d, k_x, k_emb, k_h = jax.random.split(key, 5)
    enc_layers = []
    for i in range(cfg.encoder_layers):
        enc_layers.append(init_layer(cfg, ("attn", "dense"),
                                     jax.random.fold_in(k_e, i)))
    dec_layers = []
    for i in range(cfg.num_layers):
        li = {"layer": init_layer(cfg, ("attn", "dense"),
                                  jax.random.fold_in(k_d, i)),
              "xnorm": init_norm(cfg, cfg.d_model),
              "xattn": attn.init_cross_attention(
                  cfg, jax.random.fold_in(k_x, i))}
        dec_layers.append(li)
    return {
        "encoder": {"layers": _stack_trees(enc_layers),
                    "final_norm": init_norm(cfg, cfg.d_model)},
        "decoder": {"layers": _stack_trees(dec_layers),
                    "final_norm": init_norm(cfg, cfg.d_model)},
        "embed": init_embed(k_emb, cfg.vocab_size, cfg.d_model),
        "lm_head": init_dense(k_h, cfg.d_model, cfg.vocab_size),
    }


def encode(cfg: ModelConfig, params: Dict, frames: jax.Array,
           unroll_eager: bool = False) -> jax.Array:
    """frames: (B, Se, D) precomputed conv-frontend embeddings (stub)."""
    b, se, _ = frames.shape
    pos_table = sinusoidal_positions(se, cfg.d_model)
    h = frames.astype(jnp.dtype(cfg.dtype)) + pos_table[None].astype(
        jnp.dtype(cfg.dtype))
    positions = jnp.arange(se, dtype=jnp.int32)[None, :].repeat(b, 0)

    def one(h, p):
        hn = norm(cfg, p["norm1"], h)
        y = attn.attention_forward(cfg, p["mixer"], hn, positions,
                                   causal=False, use_rope=False,
                                   name="mixer")
        h = h + y
        hn = norm(cfg, p["norm2"], h)
        h = h + mlp(cfg, p["mlp"], hn, name="mlp")
        return shard_hint(h, "act"), None

    if unroll_eager:
        n = jax.tree_util.tree_leaves(params["encoder"]["layers"])[0].shape[0]
        for i in range(n):
            h, _ = one(h, _seg_take(params["encoder"]["layers"], i))
    else:
        h, _ = jax.lax.scan(one, h, params["encoder"]["layers"])
    return norm(cfg, params["encoder"]["final_norm"], h)


def encdec_forward(cfg: ModelConfig, params: Dict, frames: jax.Array,
                   tokens: jax.Array, unroll_eager: bool = False
                   ) -> Tuple[jax.Array, jax.Array]:
    """Teacher-forced training forward. Returns (logits, aux=0)."""
    enc = encode(cfg, params, frames, unroll_eager)
    dtype = jnp.dtype(cfg.dtype)
    b, s = tokens.shape
    h = embed(params["embed"], tokens, dtype)
    h = h + sinusoidal_positions(s, cfg.d_model)[None].astype(dtype)
    positions = jnp.arange(s, dtype=jnp.int32)[None, :].repeat(b, 0)

    def one(h, p):
        lp = p["layer"]
        hn = norm(cfg, lp["norm1"], h)
        y = attn.attention_forward(cfg, lp["mixer"], hn, positions,
                                   causal=True, use_rope=False,
                                   name="layer.mixer")
        h = h + y
        hn = norm(cfg, p["xnorm"], h)
        kv = attn.cross_attention_kv(cfg, p["xattn"], enc, "xattn")
        h = h + attn.cross_attention(cfg, p["xattn"], hn, kv, "xattn")
        hn = norm(cfg, lp["norm2"], h)
        h = h + mlp(cfg, lp["mlp"], hn, name="layer.mlp")
        return shard_hint(h, "act"), None

    if unroll_eager:
        n = jax.tree_util.tree_leaves(params["decoder"]["layers"])[0].shape[0]
        for i in range(n):
            h, _ = one(h, _seg_take(params["decoder"]["layers"], i))
    else:
        h, _ = jax.lax.scan(one, h, params["decoder"]["layers"])
    h = norm(cfg, params["decoder"]["final_norm"], h)
    logits = unembed(cfg, params, h)
    return logits, jnp.zeros((), jnp.float32)


def encdec_prefill(cfg: ModelConfig, params: Dict, frames: jax.Array,
                   tokens: jax.Array, max_len: int,
                   cache_dtype=jnp.bfloat16, unroll_eager: bool = False
                   ) -> Tuple[jax.Array, Dict]:
    """Encoder pass + decoder prefill. Cache holds self-KV and cross-KV."""
    enc = encode(cfg, params, frames, unroll_eager)
    dtype = jnp.dtype(cfg.dtype)
    b, s = tokens.shape
    h = embed(params["embed"], tokens, dtype)
    h = h + sinusoidal_positions(s, cfg.d_model)[None].astype(dtype)
    positions = jnp.arange(s, dtype=jnp.int32)[None, :].repeat(b, 0)

    cross_dtype = _float_cache_dtype(cache_dtype)

    def one(h, p):
        lp = p["layer"]
        self_cache = attn.init_kv_cache(cfg, b, max_len, cache_dtype)
        hn = norm(cfg, lp["norm1"], h)
        y, self_cache = attn.attention_prefill(
            cfg, lp["mixer"], hn, positions, self_cache, name="layer.mixer")
        h = h + y
        hn = norm(cfg, p["xnorm"], h)
        kv = attn.cross_attention_kv(cfg, p["xattn"], enc, "xattn")
        h = h + attn.cross_attention(cfg, p["xattn"], hn, kv, "xattn")
        hn = norm(cfg, lp["norm2"], h)
        h = h + mlp(cfg, lp["mlp"], hn, name="layer.mlp")
        return h, {"self": self_cache,
                   "cross": jax.tree_util.tree_map(
                       lambda a: a.astype(cross_dtype), kv)}

    caches = []
    if unroll_eager:
        n = jax.tree_util.tree_leaves(params["decoder"]["layers"])[0].shape[0]
        for i in range(n):
            h, c = one(h, _seg_take(params["decoder"]["layers"], i))
            caches.append(c)
        cache = _stack_trees(caches)
    else:
        h, cache = jax.lax.scan(one, h, params["decoder"]["layers"])
    h = norm(cfg, params["decoder"]["final_norm"], h[:, -1:])
    logits = unembed(cfg, params, h)[:, 0]
    return logits, cache


def encdec_prefill_begin(cfg: ModelConfig, params: Dict, frames: jax.Array,
                         tokens: jax.Array, max_len: int,
                         cache_dtype=jnp.bfloat16, unroll_eager: bool = False
                         ) -> Tuple[jax.Array, Dict]:
    """Incremental enc-dec prefill setup: one encoder pass, cross-KV cached
    per layer, empty self-KV caches, decoder inputs embedded + positional."""
    enc = encode(cfg, params, frames, unroll_eager)
    dtype = jnp.dtype(cfg.dtype)
    b, s = tokens.shape
    h = embed(params["embed"], tokens, dtype)
    h = h + sinusoidal_positions(s, cfg.d_model)[None].astype(dtype)

    cross_dtype = _float_cache_dtype(cache_dtype)

    def mk_cache(_, p):
        kv = attn.cross_attention_kv(cfg, p["xattn"], enc, "xattn")
        return 0, {"self": attn.init_kv_cache(cfg, b, max_len, cache_dtype),
                   "cross": jax.tree_util.tree_map(
                       lambda a: a.astype(cross_dtype), kv)}

    if unroll_eager:
        n = jax.tree_util.tree_leaves(params["decoder"]["layers"])[0].shape[0]
        cache = _stack_trees([mk_cache(0, _seg_take(
            params["decoder"]["layers"], i))[1] for i in range(n)])
    else:
        _, cache = jax.lax.scan(mk_cache, 0, params["decoder"]["layers"])
    return h, cache


def encdec_prefill_step(cfg: ModelConfig, params: Dict, h_chunk: jax.Array,
                        start: int, cache: Dict, unroll_eager: bool = False
                        ) -> Tuple[jax.Array, Dict]:
    """One decoder prefill chunk at positions [start, start+C)."""
    dtype = jnp.dtype(cfg.dtype)
    b, c, _ = h_chunk.shape
    positions = start + jnp.arange(c, dtype=jnp.int32)[None, :].repeat(b, 0)

    def one(h, xs):
        p, cc = xs
        lp = p["layer"]
        hn = norm(cfg, lp["norm1"], h)
        y, self_cache = attn.attention_prefill(
            cfg, lp["mixer"], hn, positions, cc["self"], name="layer.mixer",
            start=start)
        h = h + y
        hn = norm(cfg, p["xnorm"], h)
        h = h + attn.cross_attention(cfg, p["xattn"], hn,
                                     jax.tree_util.tree_map(
                                         lambda a: a.astype(dtype),
                                         cc["cross"]), "xattn")
        hn = norm(cfg, lp["norm2"], h)
        h = h + mlp(cfg, lp["mlp"], hn, name="layer.mlp")
        return h, {"self": self_cache, "cross": cc["cross"]}

    if unroll_eager:
        n = jax.tree_util.tree_leaves(params["decoder"]["layers"])[0].shape[0]
        ncs, h = [], h_chunk
        for i in range(n):
            h, nc = one(h, (_seg_take(params["decoder"]["layers"], i),
                            _seg_take(cache, i)))
            ncs.append(nc)
        return h, _stack_trees(ncs)
    return jax.lax.scan(one, h_chunk, (params["decoder"]["layers"], cache))


def encdec_prefill_finish(cfg: ModelConfig, params: Dict, h_last: jax.Array
                          ) -> jax.Array:
    h = norm(cfg, params["decoder"]["final_norm"], h_last[:, -1:])
    return unembed(cfg, params, h)[:, 0]


def encdec_prefill_chunked(cfg: ModelConfig, params: Dict, frames: jax.Array,
                           tokens: jax.Array, max_len: int, chunk: int,
                           cache_dtype=jnp.bfloat16,
                           unroll_eager: bool = False
                           ) -> Tuple[jax.Array, Dict]:
    """Chunked-decoder variant of :func:`encdec_prefill`: one encoder pass,
    cross-KV computed once, then the decoder prompt runs ``chunk`` positions
    at a time with self-attention continuing from cache (start offsets)."""
    assert chunk > 0
    h, cache = encdec_prefill_begin(cfg, params, frames, tokens, max_len,
                                    cache_dtype, unroll_eager)
    s = h.shape[1]
    hc = h[:, :0]
    for c0 in range(0, s, chunk):
        hc, cache = encdec_prefill_step(cfg, params,
                                        h[:, c0:min(s, c0 + chunk)], c0,
                                        cache, unroll_eager=unroll_eager)
    return encdec_prefill_finish(cfg, params, hc), cache


def encdec_decode_step(cfg: ModelConfig, params: Dict, token: jax.Array,
                       pos: jax.Array, cache: Dict,
                       unroll_eager: bool = False
                       ) -> Tuple[jax.Array, Dict]:
    dtype = jnp.dtype(cfg.dtype)
    b = token.shape[0]
    h = embed(params["embed"], token[:, None], dtype)
    # position embedding for the current slot (same table, gathered)
    tbl = sinusoidal_positions(cfg.max_seq_len, cfg.d_model).astype(dtype)
    h = h + tbl[pos][:, None, :]

    def one(h, xs):
        p, c = xs
        lp = p["layer"]
        hn = norm(cfg, lp["norm1"], h)
        y, self_cache = attn.attention_decode(cfg, lp["mixer"], hn, pos,
                                              c["self"], name="layer.mixer")
        h = h + y
        hn = norm(cfg, p["xnorm"], h)
        h = h + attn.cross_attention(cfg, p["xattn"], hn,
                                     jax.tree_util.tree_map(
                                         lambda a: a.astype(dtype),
                                         c["cross"]), "xattn")
        hn = norm(cfg, lp["norm2"], h)
        h = h + mlp(cfg, lp["mlp"], hn, name="layer.mlp")
        return h, {"self": self_cache, "cross": c["cross"]}

    if unroll_eager:
        n = jax.tree_util.tree_leaves(params["decoder"]["layers"])[0].shape[0]
        ncs = []
        for i in range(n):
            h, nc = one(h, (_seg_take(params["decoder"]["layers"], i),
                            _seg_take(cache, i)))
            ncs.append(nc)
        cache = _stack_trees(ncs)
    else:
        h, cache = jax.lax.scan(one, h, (params["decoder"]["layers"], cache))
    h = norm(cfg, params["decoder"]["final_norm"], h)
    logits = unembed(cfg, params, h)[:, 0]
    return logits, cache
