"""Tappable, quantization-aware dense layer.

Every matmul the quantizer can touch goes through :func:`dense`. Three
behaviours, decided by the *value* stored under ``"w"``:

  - plain ``jax.Array`` of shape (in, out): ordinary ``x @ w``;
  - :class:`~repro.core.quant.QuantizedTensor` (packed int4, stored
    (out, in)-major like GPTQ): the W4A16 path via ``repro.kernels.ops``;
  - during calibration a :class:`Tap` context records the layer inputs by
    name, which is how the quantization pipeline collects Hessians and the
    single-instance batch without any framework hooks.

Default taps only fire outside jit — inside jit the records would be
tracers, so ``Tap.record`` refuses them loudly.  The jitted calibration
forward (core/pipeline.py) instead opens a ``Tap(collect_tracers=True)``
*inside* the traced function: records are then collected as tracers and
returned as part of the jitted function's output, which is how capture
runs compiled without framework hooks.
"""
from __future__ import annotations

from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.core.quant import QuantizedTensor
from repro.kernels import ops as kops

_ACTIVE_TAPS: List["Tap"] = []


class Tap:
    """Context manager that observes inputs of named dense layers.

    ``on_record(name, x)`` is called with the *eager* input array each time
    a matching dense layer runs. Default behaviour appends to ``records``.
    """

    def __init__(self, on_record: Optional[Callable[[str, jax.Array], None]]
                 = None, prefix: str = "", collect_tracers: bool = False):
        self.prefix = prefix
        self.records: Dict[str, List[jax.Array]] = {}
        self._on_record = on_record
        self._collect_tracers = collect_tracers

    def __enter__(self) -> "Tap":
        _ACTIVE_TAPS.append(self)
        return self

    def __exit__(self, *exc) -> None:
        _ACTIVE_TAPS.remove(self)

    def record(self, name: str, x: jax.Array) -> None:
        if not name.startswith(self.prefix):
            return
        if self._collect_tracers:
            # jitted-capture mode: tracers are expected; the caller returns
            # self.records from the traced function (core/pipeline.py)
            self.records.setdefault(name, []).append(x)
            return
        if isinstance(x, jax.core.Tracer):
            raise RuntimeError(
                f"Tap saw a tracer for {name!r}: calibration forwards must "
                "run eagerly (outside jit)")
        if self._on_record is not None:
            self._on_record(name, x)
        else:
            self.records.setdefault(name, []).append(x)


def dense(p: Dict, x: jax.Array, name: str = "") -> jax.Array:
    """y = x @ w (+ b). p: {"w": (in, out) array | QuantizedTensor, "b"?}."""
    w = p["w"]
    if name and _ACTIVE_TAPS:
        for tap in _ACTIVE_TAPS:
            tap.record(name, x)
    if isinstance(w, QuantizedTensor):
        y = kops.w4a16_matmul(x, w.packed, w.scales, w.zeros,
                              group_size=w.group_size)
    else:
        y = jnp.dot(x, w.astype(x.dtype),
                    preferred_element_type=jnp.float32).astype(x.dtype)
    if "b" in p and p["b"] is not None:
        y = y + p["b"].astype(y.dtype)
    return y


def init_dense(key: jax.Array, d_in: int, d_out: int, *, bias: bool = False,
               dtype=jnp.float32, scale: float | None = None) -> Dict:
    if scale is None:
        scale = d_in ** -0.5
    p = {"w": (jax.random.normal(key, (d_in, d_out)) * scale).astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense_weight_oi(p: Dict) -> jax.Array:
    """The (out, in)-major float view the quantizer consumes."""
    w = p["w"]
    if isinstance(w, QuantizedTensor):
        from repro.core.quant import dequantize_packed
        return dequantize_packed(w)     # QuantizedTensor is (out, in)-major
    return jnp.asarray(w).T             # model storage is (in, out)


def set_dense_weight_oi(p: Dict, w_oi: jax.Array) -> Dict:
    """Replace the weight from an (out, in) float matrix, keeping dtype."""
    old = p["w"]
    dtype = old.dtype if isinstance(old, jax.Array) else jnp.float32
    out = dict(p)
    out["w"] = w_oi.T.astype(dtype)
    return out
