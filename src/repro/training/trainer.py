"""Trainer loop: jit'd sharded step + fault tolerance + straggler stats.

Wires together:
  - sharded ``train_step`` (params/opt-state sharded per distributed rules,
    batch sharded over DP),
  - checkpoint/restart (atomic + async + elastic; SIGTERM-safe),
  - straggler mitigation: per-step wall-time EMA with z-score outlier
    detection and bounded prefetch (the input thread stays ≤ ``prefetch``
    steps ahead so one slow host cannot run the pipeline dry elsewhere),
  - metric logging.
"""
from __future__ import annotations

import collections
import dataclasses
import math
import queue
import threading
import time
from typing import Any, Callable, Dict, Iterator, List, Optional

import jax
import jax.numpy as jnp

from repro.config import Config
from repro.distributed.checkpoint import Checkpointer, SignalCheckpointer
from repro.distributed import sharding as shd
from repro.training.train_step import (TrainState, init_train_state,
                                       make_train_step)


@dataclasses.dataclass
class StragglerStats:
    """Wall-time EMA + z-score outliers (the per-host signal a fleet
    scheduler consumes; on CPU CI it simply records step times)."""
    alpha: float = 0.1
    mean: float = 0.0
    var: float = 0.0
    n: int = 0
    outliers: List[int] = dataclasses.field(default_factory=list)

    def update(self, step: int, dt: float) -> Optional[float]:
        if self.n >= 5:
            sd = math.sqrt(max(self.var, 1e-12))
            z = (dt - self.mean) / sd if sd > 0 else 0.0
        else:
            z = 0.0
        d = dt - self.mean
        self.mean += self.alpha * d
        self.var = (1 - self.alpha) * (self.var + self.alpha * d * d)
        self.n += 1
        if z > 4.0:
            self.outliers.append(step)
            return z
        return None


class Prefetcher:
    """Bounded background prefetch of host batches."""

    def __init__(self, it: Iterator, depth: int = 2):
        self.q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._stop = False

        def run():
            for item in it:
                if self._stop:
                    return
                self.q.put(item)
            self.q.put(None)

        self.t = threading.Thread(target=run, daemon=True)
        self.t.start()

    def __iter__(self):
        return self

    def __next__(self):
        item = self.q.get()
        if item is None:
            raise StopIteration
        return item

    def stop(self):
        self._stop = True


def train(cfg: Config, data_source, *, mesh=None, verbose: bool = True,
          restore: bool = True) -> Dict[str, Any]:
    """Run ``cfg.train.steps`` steps; returns final state + history."""
    tc = cfg.train
    key = jax.random.PRNGKey(tc.seed)
    state = init_train_state(cfg, key)

    rules = None
    step_fn = make_train_step(cfg)
    if mesh is not None:
        rules = shd.make_rules(mesh, cfg.parallel)
        pshard = shd.param_shardings(state.params, rules,
                                     fsdp=cfg.parallel.fsdp)
        state = TrainState(
            jax.device_put(state.params, pshard),
            jax.tree_util.tree_map(lambda x: x, state.opt),
            state.step)
        def wrapped(state, batch):
            with shd.use_rules(rules):
                return step_fn(state, batch)
        step = jax.jit(wrapped, donate_argnums=(0,))
    else:
        step = jax.jit(step_fn, donate_argnums=(0,))

    ckpt = Checkpointer(tc.ckpt_dir, keep=tc.ckpt_keep,
                        async_write=tc.ckpt_async)
    start_step = 0
    if restore and ckpt.latest_step() is not None:
        state, extra = ckpt.restore(state)
        start_step = int(extra.get("step", 0))
        if hasattr(data_source, "restore") and "data" in extra:
            from repro.data.synthetic import DataState
            data_source.restore(DataState(**extra["data"]))
        if verbose:
            print(f"[trainer] restored step {start_step} from {tc.ckpt_dir}")

    sig = SignalCheckpointer().install()
    stats = StragglerStats()
    history: List[Dict[str, float]] = []

    def batches():
        while True:
            b = data_source.batch(tc.global_batch_size, tc.seq_len)
            b = b[0] if isinstance(b, tuple) else b
            # snapshot the stream position WITH the batch: the prefetcher
            # runs ahead of consumption, so checkpointing
            # ``data_source.state()`` directly would over-advance the
            # stream on restart (caught by test_restart_resumes_exactly)
            st = data_source.state() if hasattr(data_source, "state") \
                else None
            yield b, st

    prefetch = Prefetcher(batches(), depth=2)
    try:
        for i, (batch, dstate) in zip(range(start_step, tc.steps),
                                      prefetch):
            if mesh is not None:
                batch = jax.device_put(batch,
                                       shd.batch_shardings(batch, rules))
            t0 = time.perf_counter()
            state, metrics = step(state, batch)
            jax.block_until_ready(metrics["loss"])
            dt = time.perf_counter() - t0
            z = stats.update(i, dt)
            if z is not None and verbose:
                print(f"[trainer] straggler step {i}: {dt*1e3:.1f}ms "
                      f"(z={z:.1f})")
            row = {k: float(v) for k, v in metrics.items()}
            row["step"] = i
            row["dt"] = dt
            history.append(row)
            if verbose and (i % tc.log_every == 0 or i == tc.steps - 1):
                print(f"[trainer] step {i} loss={row['loss']:.4f} "
                      f"lr={row['lr']:.2e} {dt*1e3:.0f}ms")
            need_ckpt = ((i + 1) % tc.ckpt_every == 0 or sig.requested
                         or i == tc.steps - 1)
            if need_ckpt:
                extra = {"step": i + 1}
                if dstate is not None:
                    extra["data"] = {"seed": dstate.seed,
                                     "step": dstate.step}
                ckpt.save(i + 1, state, extra)
                if sig.requested:
                    if verbose:
                        print(f"[trainer] SIGTERM: checkpointed at {i+1}, "
                              "exiting")
                    break
        ckpt.wait()
    finally:
        prefetch.stop()
        sig.uninstall()
    return {"state": state, "history": history,
            "straggler_outliers": stats.outliers}
