"""Loss + train step for decoder-only and enc-dec models.

``make_train_step(cfg)`` builds a pure ``(state, batch) -> (state, metrics)``
suitable for ``jax.jit`` with sharded in/out. Features:

  - next-token CE with optional loss mask (frontend-token positions),
  - MoE aux load-balance loss, deepseek MTP auxiliary loss (weight 0.3),
  - grad accumulation via ``lax.scan`` over microbatches,
  - global-norm clipping, AdamW (optionally int8 moments),
  - cosine / WSD schedules,
  - activation remat policy from ``cfg.parallel.remat``,
  - optional explicit-DP gradient compression hook
    (``repro.distributed.compression``) — used when running shard_map-style
    explicit data parallelism; under plain GSPMD jit the all-reduce is
    emitted by XLA and compression is a no-op.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import Config
from repro.models import transformer as T
from repro.training import optimizer as opt
from repro.training.schedule import learning_rate
from repro.distributed.sharding import shard_hint


class TrainState(NamedTuple):
    params: Any
    opt: opt.AdamWState
    step: jax.Array


def init_train_state(cfg: Config, key: jax.Array) -> TrainState:
    if cfg.model.is_encoder_decoder:
        params = T.init_encdec_params(cfg.model, key)
    else:
        params = T.init_params(cfg.model, key)
    pdtype = jnp.dtype(cfg.model.param_dtype)
    params = jax.tree_util.tree_map(lambda a: a.astype(pdtype), params)
    return TrainState(params, opt.adamw_init(
        params, int8=cfg.parallel.int8_optimizer_state),
        jnp.zeros((), jnp.int32))


def cross_entropy(logits: jax.Array, targets: jax.Array,
                  mask: Optional[jax.Array]) -> jax.Array:
    """Mean CE over masked positions. logits (B,S,V) f32, targets (B,S)."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None],
                               axis=-1)[..., 0]
    ce = logz - gold
    if mask is None:
        return jnp.mean(ce)
    m = mask.astype(jnp.float32)
    return jnp.sum(ce * m) / jnp.maximum(jnp.sum(m), 1.0)


def loss_fn(cfg: Config, params: Any, batch: Dict[str, jax.Array]
            ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    mc = cfg.model
    remat = cfg.parallel.remat != "none"
    if mc.is_encoder_decoder:
        logits, aux = T.encdec_forward(mc, params, batch["frames"],
                                       batch["tokens"])
        tokens = batch["tokens"]
        mask = batch.get("loss_mask")
        h = None
    else:
        tokens = batch["tokens"]
        embeds = batch.get("embeds")
        logits, aux, h = T.forward(mc, params, tokens, embeds,
                                   remat=remat, return_hidden=True)
        mask = batch.get("loss_mask")
        if embeds is not None:
            # frontend positions prepended: logits cover [embeds; tokens] —
            # loss only over the token region.
            n_front = embeds.shape[1]
            logits = logits[:, n_front:]
    ce = cross_entropy(logits[:, :-1], tokens[:, 1:],
                       None if mask is None else mask[:, 1:])
    loss = ce + aux
    metrics = {"ce": ce, "aux": aux}
    if mc.mtp_depth > 0 and not mc.is_encoder_decoder \
            and batch.get("embeds") is None:
        mtp_lg = T.mtp_logits(mc, params, h, tokens)     # (B, S-1, V)
        mtp_ce = cross_entropy(mtp_lg[:, :-1], tokens[:, 2:], None)
        loss = loss + mc.mtp_loss_weight * mtp_ce
        metrics["mtp_ce"] = mtp_ce
    metrics["loss"] = loss
    return loss, metrics


def make_train_step(cfg: Config):
    tc = cfg.train
    accum = max(1, tc.grad_accum)
    int8 = cfg.parallel.int8_optimizer_state

    def micro_grads(params, batch):
        (loss, metrics), grads = jax.value_and_grad(
            functools.partial(loss_fn, cfg), has_aux=True)(params, batch)
        return grads, metrics

    def train_step(state: TrainState, batch: Dict[str, jax.Array]
                   ) -> Tuple[TrainState, Dict[str, jax.Array]]:
        if accum == 1:
            grads, metrics = micro_grads(state.params, batch)
        else:
            def split(x):
                return x.reshape(accum, x.shape[0] // accum, *x.shape[1:])
            micro = jax.tree_util.tree_map(split, batch)

            def body(acc, mb):
                g, m = micro_grads(state.params, mb)
                acc = jax.tree_util.tree_map(jnp.add, acc, g)
                return acc, m

            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params)
            grads, ms = jax.lax.scan(body, zeros, micro)
            grads = jax.tree_util.tree_map(lambda g: g / accum, grads)
            metrics = jax.tree_util.tree_map(lambda m: m[-1], ms)

        grads, gnorm = opt.clip_by_global_norm(grads, tc.grad_clip)
        lr = learning_rate(tc, state.step)
        params, ostate = opt.adamw_update(grads, state.opt, state.params,
                                          lr=lr, tc=tc, int8=int8)
        metrics = dict(metrics)
        metrics["grad_norm"] = gnorm
        metrics["lr"] = lr
        return TrainState(params, ostate, state.step + 1), metrics

    return train_step
