"""LR schedules: cosine, WSD (warmup-stable-decay, minicpm), constant."""
from __future__ import annotations

import jax.numpy as jnp

from repro.config import TrainConfig


def learning_rate(tc: TrainConfig, step: jnp.ndarray) -> jnp.ndarray:
    s = jnp.asarray(step, jnp.float32)
    warm = jnp.asarray(max(tc.warmup_steps, 1), jnp.float32)
    total = jnp.asarray(max(tc.steps, 1), jnp.float32)
    base = jnp.asarray(tc.lr, jnp.float32)
    warmup = base * jnp.minimum((s + 1.0) / warm, 1.0)   # lr > 0 at step 0
    if tc.schedule == "constant":
        return warmup
    if tc.schedule == "cosine":
        t = jnp.clip((s - warm) / jnp.maximum(total - warm, 1.0), 0.0, 1.0)
        return jnp.where(s < warm, warmup,
                         base * 0.5 * (1.0 + jnp.cos(jnp.pi * t)))
    if tc.schedule == "wsd":
        # warmup → stable plateau → exponential-ish linear decay tail
        stable_end = warm + (total - warm) * tc.wsd_stable_frac
        t = jnp.clip((s - stable_end) / jnp.maximum(total - stable_end, 1.0),
                     0.0, 1.0)
        decay = base * (1.0 - t * (1.0 - 0.1))       # decay to 10%
        return jnp.where(s < warm, warmup,
                         jnp.where(s < stable_end, base, decay))
    raise ValueError(tc.schedule)
