"""AdamW with optional int8 block-quantized moments.

The int8 moments are the Dettmers-style distributed-optimization trick the
paper's own method echoes (block-wise quantization): each moment tensor is
flattened into blocks of 128, stored int8 with a per-block f32 absmax
scale — 4× smaller optimizer state, which is what lets deepseek-v3 train
inside v5e HBM at 512 chips (EXPERIMENTS.md §Dry-run). Moments are
dequantized, updated in f32, and requantized every step; the quantization
noise on m/v is well inside Adam's own noise floor (tested against exact
AdamW in tests/test_training.py).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.config import TrainConfig

_BLOCK = 128


class Quantized8(NamedTuple):
    q: jax.Array        # int8 payload, padded flat (n_blocks, _BLOCK)
    scale: jax.Array    # (n_blocks,) f32 absmax / 127
    # static shape restored from the paired param


def _q8(x: jax.Array) -> Quantized8:
    flat = x.reshape(-1)
    n = flat.shape[0]
    nb = -(-n // _BLOCK)
    flat = jnp.pad(flat, (0, nb * _BLOCK - n)).reshape(nb, _BLOCK)
    scale = jnp.max(jnp.abs(flat), axis=1) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(flat / scale[:, None]), -127, 127).astype(jnp.int8)
    return Quantized8(q, scale.astype(jnp.float32))


def _dq8(z: Quantized8, shape: Tuple[int, ...]) -> jax.Array:
    flat = (z.q.astype(jnp.float32) * z.scale[:, None]).reshape(-1)
    n = 1
    for d in shape:
        n *= d
    return flat[:n].reshape(shape)


class AdamWState(NamedTuple):
    step: jax.Array
    m: Any              # pytree of f32 arrays or Quantized8
    v: Any


def adamw_init(params: Any, int8: bool = False) -> AdamWState:
    def zero(p):
        z = jnp.zeros(p.shape, jnp.float32)
        return _q8(z) if int8 else z
    # m and v must be distinct buffers (the train step donates its input
    # state; aliased leaves would be donated twice)
    return AdamWState(jnp.zeros((), jnp.int32),
                      jax.tree_util.tree_map(zero, params),
                      jax.tree_util.tree_map(zero, params))


def adamw_update(grads: Any, state: AdamWState, params: Any, *,
                 lr: jax.Array, tc: TrainConfig,
                 int8: bool = False) -> Tuple[Any, AdamWState]:
    step = state.step + 1
    b1, b2, eps, wd = tc.beta1, tc.beta2, tc.eps, tc.weight_decay
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        mf = _dq8(m, p.shape) if int8 else m
        vf = _dq8(v, p.shape) if int8 else v
        mf = b1 * mf + (1 - b1) * g
        vf = b2 * vf + (1 - b2) * g * g
        mh = mf / c1
        vh = vf / c2
        delta = mh / (jnp.sqrt(vh) + eps) + wd * p.astype(jnp.float32)
        newp = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return newp, (_q8(mf) if int8 else mf), (_q8(vf) if int8 else vf)

    is_q8 = lambda x: isinstance(x, Quantized8)
    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = jax.tree_util.tree_leaves(state.m, is_leaf=is_q8) \
        if int8 else treedef.flatten_up_to(state.m)
    flat_v = jax.tree_util.tree_leaves(state.v, is_leaf=is_q8) \
        if int8 else treedef.flatten_up_to(state.v)
    outs = [upd(p, g, m, v) for p, g, m, v in
            zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in outs])
    new_m = treedef.unflatten([o[1] for o in outs])
    new_v = treedef.unflatten([o[2] for o in outs])
    return new_p, AdamWState(step, new_m, new_v)


def global_norm(tree: Any) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(tree: Any, max_norm: float) -> Tuple[Any, jax.Array]:
    gn = global_norm(tree)
    factor = jnp.minimum(1.0, max_norm / (gn + 1e-9))
    return jax.tree_util.tree_map(
        lambda g: (g.astype(jnp.float32) * factor).astype(g.dtype),
        tree), gn
