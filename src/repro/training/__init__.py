"""Training substrate: optimizer, schedules, train step, trainer loop."""
