"""pixtral-12b [vlm] — mistral-nemo LM backbone, pixtral-ViT stubbed.

40L, d_model 5120, 32 heads (GQA kv=8, head_dim 128), d_ff 14336, vocab
131072. [hf:mistralai/Pixtral-12B-2409; unverified]. The vision frontend is
a stub: ``input_specs()`` supplies precomputed (B, 1024, 5120) patch
embeddings prepended to the text tokens.
"""
from repro.config import Config, ModelConfig


def full() -> Config:
    cfg = Config()
    cfg.model = ModelConfig(
        name="pixtral-12b", family="vlm",
        num_layers=40, d_model=5120, num_heads=32, num_kv_heads=8,
        head_dim=128, d_ff=14336, vocab_size=131072,
        norm="rmsnorm", act="silu", gated_mlp=True,
        frontend="vision", frontend_tokens=1024,
        max_seq_len=32768 + 8,
    )
    return cfg


def smoke() -> Config:
    cfg = Config()
    cfg.model = ModelConfig(
        name="pixtral-smoke", family="vlm",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        head_dim=16, d_ff=160, vocab_size=128,
        norm="rmsnorm", act="silu", gated_mlp=True,
        frontend="vision", frontend_tokens=8, max_seq_len=64,
    )
    cfg.quant.group_size = 8
    cfg.quant.blocksize = 8
    return cfg
