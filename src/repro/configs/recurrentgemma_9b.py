"""recurrentgemma-9b [hybrid] — RG-LRU + local attention, 2 recurrent : 1.

38L, d_model 4096, 16 heads (MQA kv=1), d_ff 12288, vocab 256000, local
window 2048, lru_width 4096. [arXiv:2402.19427; unverified]. Bounded decode
state (LRU h + 2048-token ring) ⇒ runs long_500k.
"""
from repro.config import Config, ModelConfig, RGLRUConfig


def full() -> Config:
    cfg = Config()
    cfg.model = ModelConfig(
        name="recurrentgemma-9b", family="hybrid",
        num_layers=38, d_model=4096, num_heads=16, num_kv_heads=1,
        d_ff=12288, vocab_size=256000,
        block_pattern=("rglru", "rglru", "local"), window_size=2048,
        norm="rmsnorm", act="gelu", gated_mlp=True,
        rglru=RGLRUConfig(enabled=True, lru_width=4096, conv1d_width=4),
        logits_softcap=30.0, tie_embeddings=True,
        max_seq_len=524288 + 8,
    )
    return cfg


def smoke() -> Config:
    cfg = Config()
    cfg.model = ModelConfig(
        name="recurrentgemma-smoke", family="hybrid",
        num_layers=5, d_model=64, num_heads=4, num_kv_heads=1,
        d_ff=160, vocab_size=128,
        block_pattern=("rglru", "rglru", "local"), window_size=8,
        norm="rmsnorm", act="gelu", gated_mlp=True,
        rglru=RGLRUConfig(enabled=True, lru_width=64, conv1d_width=4),
        logits_softcap=30.0, tie_embeddings=True, max_seq_len=64,
    )
    cfg.quant.group_size = 8
    cfg.quant.blocksize = 8
    return cfg
