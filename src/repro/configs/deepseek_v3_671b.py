"""deepseek-v3-671b [moe] — MLA + 1 shared + 256 routed experts top-8 + MTP.

61L, d_model 7168, 128 heads, per-expert d_ff 2048, vocab 129280, first 3
layers dense (d_ff 18432), MLA ranks (q 1536 / kv 512, nope 128 / rope 64 /
v 128), one MTP depth. [arXiv:2412.19437; hf].

bf16 master params + int8 Adam moments (parallel.int8_optimizer_state) keep
the train_4k cell inside v5e HBM at 512 chips — see EXPERIMENTS.md §Dry-run.
"""
from repro.config import Config, MLAConfig, ModelConfig, MoEConfig


def full() -> Config:
    cfg = Config()
    cfg.model = ModelConfig(
        name="deepseek-v3-671b", family="moe",
        num_layers=61, d_model=7168, num_heads=128, num_kv_heads=128,
        d_ff=18432, vocab_size=129280,
        norm="rmsnorm", act="silu", gated_mlp=True,
        mla=MLAConfig(enabled=True, q_lora_rank=1536, kv_lora_rank=512,
                      qk_nope_head_dim=128, qk_rope_head_dim=64,
                      v_head_dim=128),
        moe=MoEConfig(num_experts=256, top_k=8, d_ff_expert=2048,
                      num_shared_experts=1, first_dense_layers=3),
        mtp_depth=1,
        param_dtype="bfloat16",
        max_seq_len=32768 + 8,
    )
    cfg.parallel.int8_optimizer_state = True
    cfg.parallel.remat = "full"
    return cfg


def smoke() -> Config:
    cfg = Config()
    cfg.model = ModelConfig(
        name="deepseek-smoke", family="moe",
        num_layers=3, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=128, vocab_size=128,
        norm="rmsnorm", act="silu", gated_mlp=True,
        mla=MLAConfig(enabled=True, q_lora_rank=32, kv_lora_rank=16,
                      qk_nope_head_dim=16, qk_rope_head_dim=8,
                      v_head_dim=16),
        moe=MoEConfig(num_experts=8, top_k=2, d_ff_expert=32,
                      num_shared_experts=1, first_dense_layers=1),
        mtp_depth=1, max_seq_len=64,
    )
    cfg.quant.group_size = 8
    cfg.quant.blocksize = 8
    return cfg
