"""internlm2-1.8b [dense] — GQA 16 heads / 8 kv heads.

24L, d_model 2048, 16H (kv=8), d_ff 8192, vocab 92544.
[arXiv:2403.17297; hf].
"""
from repro.config import Config, ModelConfig


def full() -> Config:
    cfg = Config()
    cfg.model = ModelConfig(
        name="internlm2-1.8b", family="dense",
        num_layers=24, d_model=2048, num_heads=16, num_kv_heads=8,
        d_ff=8192, vocab_size=92544,
        norm="rmsnorm", act="silu", gated_mlp=True,
        max_seq_len=32768 + 8,
    )
    return cfg


def smoke() -> Config:
    cfg = Config()
    cfg.model = ModelConfig(
        name="internlm2-smoke", family="dense",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=192, vocab_size=128,
        norm="rmsnorm", act="silu", gated_mlp=True, max_seq_len=64,
    )
    cfg.quant.group_size = 8
    cfg.quant.blocksize = 8
    return cfg
