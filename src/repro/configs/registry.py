"""--arch resolution + the assigned input-shape grid.

Every architecture module exposes ``full()`` and ``smoke()`` returning a
:class:`repro.config.Config`. ``smoke`` is a reduced same-family config that
runs a forward/train step on CPU in seconds; ``full`` is the published
configuration, exercised only through the dry-run (ShapeDtypeStruct).

Shapes (assigned grid, LM family):
  train_4k     seq 4096  × global_batch 256   → train_step
  prefill_32k  seq 32768 × global_batch 32    → prefill forward
  decode_32k   cache 32768 × global_batch 128 → serve_step (1 new token)
  long_500k    cache 524288 × global_batch 1  → serve_step; sub-quadratic
               archs only (SWA / RG-LRU hybrid / SSM) — pure full-attention
               archs are recorded N/A-by-design (DESIGN.md §3).
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, List, Optional

from repro.config import Config


ARCH_IDS = [
    "whisper-large-v3",
    "minicpm-2b",
    "h2o-danube-1.8b",
    "stablelm-1.6b",
    "internlm2-1.8b",
    "recurrentgemma-9b",
    "olmoe-1b-7b",
    "deepseek-v3-671b",
    "pixtral-12b",
    "falcon-mamba-7b",
    # the paper's own model family (OPT-style proxy used by benchmarks)
    "opt-proxy",
]

_MODULES = {a: "repro.configs." + a.replace("-", "_").replace(".", "_")
            for a in ARCH_IDS}


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str          # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}


def get_config(arch: str, smoke: bool = False) -> Config:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(_MODULES[arch])
    cfg: Config = mod.smoke() if smoke else mod.full()
    cfg.model.__post_init__()
    return cfg


def shape_names_for(arch: str) -> List[str]:
    """The assigned shape cells for this arch (long_500k gated)."""
    cfg = get_config(arch)
    names = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.model.is_subquadratic():
        names.append("long_500k")
    return names


def input_shapes(arch: str, shape: str) -> ShapeSpec:
    return SHAPES[shape]
