"""stablelm-1.6b [dense] — LayerNorm + gated SiLU MLP.

24L, d_model 2048, 32 heads (kv=32), d_ff 5632, vocab 100352.
[hf:stabilityai/stablelm-2-1_6b; unverified].
"""
from repro.config import Config, ModelConfig


def full() -> Config:
    cfg = Config()
    cfg.model = ModelConfig(
        name="stablelm-1.6b", family="dense",
        num_layers=24, d_model=2048, num_heads=32, num_kv_heads=32,
        d_ff=5632, vocab_size=100352,
        norm="layernorm", act="silu", gated_mlp=True,
        max_seq_len=32768 + 8,
    )
    return cfg


def smoke() -> Config:
    cfg = Config()
    cfg.model = ModelConfig(
        name="stablelm-smoke", family="dense",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=160, vocab_size=128,
        norm="layernorm", act="silu", gated_mlp=True, max_seq_len=64,
    )
    cfg.quant.group_size = 8
    cfg.quant.blocksize = 8
    return cfg
