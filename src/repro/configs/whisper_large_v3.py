"""whisper-large-v3 [audio] — enc-dec, conv frontend stubbed.

32 encoder + 32 decoder layers, d_model 1280, 20 heads (kv=20), d_ff 5120,
vocab 51866. [arXiv:2212.04356; unverified]. The conv frontend is a stub:
``input_specs()`` supplies precomputed (B, 1500, 1280) frame embeddings.
LayerNorm + GELU (ungated), fixed sinusoidal positions (the published model
uses learned decoder positions; sinusoidal keeps the stub parameter-free —
recorded in DESIGN.md hardware/assumption notes).
"""
from repro.config import Config, ModelConfig


def full() -> Config:
    cfg = Config()
    cfg.model = ModelConfig(
        name="whisper-large-v3", family="audio",
        num_layers=32, d_model=1280, num_heads=20, num_kv_heads=20,
        d_ff=5120, vocab_size=51866,
        norm="layernorm", act="gelu", gated_mlp=False, use_rope=False,
        is_encoder_decoder=True, encoder_layers=32, encoder_seq_len=1500,
        frontend="audio", frontend_tokens=1500,
        max_seq_len=32768 + 8,
    )
    return cfg


def smoke() -> Config:
    cfg = Config()
    cfg.model = ModelConfig(
        name="whisper-smoke", family="audio",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=128, vocab_size=128,
        norm="layernorm", act="gelu", gated_mlp=False, use_rope=False,
        is_encoder_decoder=True, encoder_layers=2, encoder_seq_len=16,
        frontend="audio", frontend_tokens=16, max_seq_len=64,
    )
    cfg.quant.group_size = 16
    cfg.quant.blocksize = 16
    return cfg
