"""opt-proxy — the paper's own evaluation family (OPT-style decoder LM).

The paper quantizes OPT-6.7B/13B, Qwen3-8B, LLaMA-3.1-8B; this proxy keeps
the OPT block structure (LayerNorm, ungated GELU MLP, d_ff = 4·d_model,
biases) at a CPU-trainable scale so benchmarks/table1 can train → quantize →
evaluate the fp16 / GPTQ / RPIQ triple end-to-end. RoPE replaces OPT's
learned positions (positional scheme is orthogonal to the quantizer; noted
in DESIGN.md).
"""
from repro.config import Config, ModelConfig


def full() -> Config:
    cfg = Config()
    cfg.model = ModelConfig(
        name="opt-proxy", family="dense",
        num_layers=12, d_model=768, num_heads=12, num_kv_heads=12,
        d_ff=3072, vocab_size=50304,
        norm="layernorm", act="gelu", gated_mlp=False,
        max_seq_len=4096,
    )
    return cfg


def smoke() -> Config:
    cfg = Config()
    cfg.model = ModelConfig(
        name="opt-proxy-smoke", family="dense",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=256, vocab_size=256,
        norm="layernorm", act="gelu", gated_mlp=False, max_seq_len=64,
    )
    cfg.quant.group_size = 16
    cfg.quant.blocksize = 16
    return cfg
