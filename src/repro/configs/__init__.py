"""Architecture registry: one module per assigned architecture."""
from repro.configs.registry import (ARCH_IDS, get_config, input_shapes,
                                    shape_names_for)  # noqa: F401
