"""olmoe-1b-7b [moe] — 64 experts, top-8, 1B active / 7B total.

16L, d_model 2048, 16 heads (kv=16), per-expert d_ff 1024, vocab 50304.
[arXiv:2409.02060; hf].
"""
from repro.config import Config, ModelConfig, MoEConfig


def full() -> Config:
    cfg = Config()
    cfg.model = ModelConfig(
        name="olmoe-1b-7b", family="moe",
        num_layers=16, d_model=2048, num_heads=16, num_kv_heads=16,
        d_ff=1024, vocab_size=50304,
        norm="rmsnorm", act="silu", gated_mlp=True,
        moe=MoEConfig(num_experts=64, top_k=8, d_ff_expert=1024),
        max_seq_len=32768 + 8,
    )
    return cfg


def smoke() -> Config:
    cfg = Config()
    cfg.model = ModelConfig(
        name="olmoe-smoke", family="moe",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=64, vocab_size=128,
        norm="rmsnorm", act="silu", gated_mlp=True,
        moe=MoEConfig(num_experts=8, top_k=2, d_ff_expert=64),
        max_seq_len=64,
    )
    cfg.quant.group_size = 8
    cfg.quant.blocksize = 8
    return cfg
