"""h2o-danube-1.8b [dense] — llama+mistral mix with sliding-window attention.

24L, d_model 2560, 32 heads (GQA kv=8), d_ff 6912, vocab 32000, SWA window
4096. [arXiv:2401.16818; hf]. Sub-quadratic decode (ring-buffer cache) ⇒
runs the long_500k cell.
"""
from repro.config import Config, ModelConfig


def full() -> Config:
    cfg = Config()
    cfg.model = ModelConfig(
        name="h2o-danube-1.8b", family="dense",
        num_layers=24, d_model=2560, num_heads=32, num_kv_heads=8,
        d_ff=6912, vocab_size=32000,
        block_pattern=("swa",), window_size=4096,
        norm="rmsnorm", act="silu", gated_mlp=True,
        max_seq_len=524288 + 8,
    )
    return cfg


def smoke() -> Config:
    cfg = Config()
    cfg.model = ModelConfig(
        name="h2o-danube-smoke", family="dense",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=160, vocab_size=128,
        block_pattern=("swa",), window_size=8,
        norm="rmsnorm", act="silu", gated_mlp=True, max_seq_len=64,
    )
    cfg.quant.group_size = 8
    cfg.quant.blocksize = 8
    return cfg
