"""falcon-mamba-7b [ssm] — pure Mamba-1, attention-free.

64L, d_model 4096 (d_inner 8192), ssm_state 16, vocab 65024.
[arXiv:2410.05355; unverified]. O(1) decode state ⇒ runs long_500k.
"""
from repro.config import Config, ModelConfig, SSMConfig


def full() -> Config:
    cfg = Config()
    cfg.model = ModelConfig(
        name="falcon-mamba-7b", family="ssm",
        num_layers=64, d_model=4096, num_heads=1, num_kv_heads=1,
        d_ff=0, vocab_size=65024,
        block_pattern=("mamba",),
        norm="rmsnorm",
        ssm=SSMConfig(enabled=True, d_state=16, d_conv=4, expand=2),
        max_seq_len=524288 + 8,
    )
    return cfg


def smoke() -> Config:
    cfg = Config()
    cfg.model = ModelConfig(
        name="falcon-mamba-smoke", family="ssm",
        num_layers=3, d_model=64, num_heads=1, num_kv_heads=1,
        d_ff=0, vocab_size=128,
        block_pattern=("mamba",),
        norm="rmsnorm",
        ssm=SSMConfig(enabled=True, d_state=8, d_conv=4, expand=2),
        max_seq_len=64,
    )
    cfg.quant.group_size = 8
    cfg.quant.blocksize = 8
    return cfg
