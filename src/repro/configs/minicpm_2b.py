"""minicpm-2b [dense] — llama-like, WSD schedule, tied embeddings.

40L, d_model 2304, 36 heads (kv=36), d_ff 5760, vocab 122753.
[arXiv:2404.06395; hf]. Trains with the WSD schedule (train.schedule).
"""
from repro.config import Config, ModelConfig


def full() -> Config:
    cfg = Config()
    cfg.model = ModelConfig(
        name="minicpm-2b", family="dense",
        num_layers=40, d_model=2304, num_heads=36, num_kv_heads=36,
        d_ff=5760, vocab_size=122753,
        norm="rmsnorm", act="silu", gated_mlp=True,
        tie_embeddings=True, max_seq_len=32768 + 8,
    )
    cfg.train.schedule = "wsd"
    return cfg


def smoke() -> Config:
    cfg = Config()
    cfg.model = ModelConfig(
        name="minicpm-smoke", family="dense",
        num_layers=2, d_model=72, num_heads=6, num_kv_heads=6,
        d_ff=160, vocab_size=128,
        norm="rmsnorm", act="silu", gated_mlp=True,
        tie_embeddings=True, max_seq_len=64,
    )
    cfg.train.schedule = "wsd"
    cfg.quant.group_size = 8
    cfg.quant.blocksize = 8
    return cfg
