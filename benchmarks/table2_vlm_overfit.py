"""Paper Table 2: single-instance over-iteration overfits (5 vs 20 iters).

The paper's key ablation: RPIQ stage 2 with 5 iterations improves OCR-VQA,
but 20 iterations on the single calibration instance *degrades* it. We
reproduce the mechanism on the pixtral-style stub VLM: quantize with
t_max ∈ {0 (GPTQ), 5, 20} at a refinement strength where iterations matter
(exact-gram), and measure (a) the loss on the calibration instance and
(b) the loss on held-out batches. Overfitting = calibration loss keeps
falling while held-out loss rises.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import bench_config, make_calib, train_lm
from repro.core.pipeline import quantize_model
from repro.data import MarkovLM
from repro.models import transformer as T


def _ho_loss(cfg, params_fp, params_q, seed=123, n=4):
    """Held-out output-space error vs the fp model."""
    lm = MarkovLM(cfg.model.vocab_size, seed=seed)
    tot = 0.0
    for i in range(n):
        toks = lm.batch(4, 32)["tokens"]
        lg_fp, _ = T.forward(cfg.model, params_fp, toks)
        lg_q, _ = T.forward(cfg.model, params_q, toks)
        tot += float(jnp.linalg.norm(lg_fp - lg_q)
                     / jnp.linalg.norm(lg_fp))
    return tot / n


def run(steps: int = 60) -> list:
    cfg = bench_config("pixtral-12b")
    params, lm, _ = train_lm(cfg, steps=steps, mix_sentiment=False)
    calib = make_calib(cfg, lm, n_batches=2, batch=2, seq=24)

    rows = []
    for iters in (0, 5, 20):
        c = bench_config("pixtral-12b")
        c.quant.rpiq_iters = iters
        c.quant.rpiq_use_global_hessian = False
        c.quant.rpiq_alpha = 0.6
        c.quant.rpiq_early_stop = False
        c.quant.keep_best_projection = True
        pq, rep = quantize_model(c, params, calib)
        calib_gamma = sum(l.gamma_final for l in rep.linears
                          if l.mode == "rpiq")
        rows.append({
            "table": "table2", "iters": iters,
            "calib_gamma_sum": round(calib_gamma, 4),
            "heldout_rel_err": round(_ho_loss(cfg, params, pq), 5),
        })
    return rows
