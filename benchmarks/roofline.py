"""Roofline tables from the dry-run artifacts (§Roofline of EXPERIMENTS.md).

Reads artifacts/dryrun/*.json and renders, per (arch × shape × mesh):
compute/memory/collective terms, dominant bottleneck, MODEL_FLOPS/HLO_FLOPs
ratio and the roofline fraction. Run after ``repro.launch.dryrun --all``.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List


def load(art_dir: str = "artifacts/dryrun") -> List[Dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(art_dir, "*.json"))):
        with open(path) as f:
            rows.append(json.load(f))
    return rows


def table(rows: List[Dict], mesh: str = "16x16") -> str:
    out = ["| arch | shape | compute s | memory s | collective s | "
           "dominant | useful | roofline |",
           "|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r.get("mesh") != mesh:
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.4f} | "
            f"{r['t_memory_s']:.4f} | {r['t_collective_s']:.4f} | "
            f"{r['dominant']} | {r.get('useful_fraction', 0):.3f} | "
            f"{r.get('roofline_fraction', 0):.4f} |")
    return "\n".join(out)


def run() -> List[Dict]:
    rows = load()
    return [{
        "table": "roofline", "arch": r["arch"], "shape": r["shape"],
        "mesh": r["mesh"], "dominant": r["dominant"],
        "t_compute_s": round(r["t_compute_s"], 5),
        "t_memory_s": round(r["t_memory_s"], 5),
        "t_collective_s": round(r["t_collective_s"], 5),
        "roofline_fraction": round(r.get("roofline_fraction", 0.0), 5),
    } for r in rows]


if __name__ == "__main__":
    rows = load()
    print("## single pod (16x16)\n")
    print(table(rows, "16x16"))
    print("\n## multi-pod (2x16x16)\n")
    print(table(rows, "2x16x16"))
