"""Paper Table 4: quantization wall time — GPTQ vs RPIQ (ΔT), plus the
quant-plan executor comparison and the stage-1 sweep-backend comparison.

Across model widths; RPIQ's stage 2 adds a bounded, roughly width-
proportional overhead (paper: +12-18s on 7-13B GPUs; CPU-scale here).

The ``batched`` rows measure the QuantPlan batched executors
(core/plan.py: same-shape linears grouped into one vmapped GPTQ+RPIQ
dispatch) against the legacy per-linear dispatch on the SAME model/calib —
each opt-proxy layer holds 4 same-shape attention linears, and the MoE row
stacks 8 experts (gate/up share one 16-member group). Cold = first run
(includes compile); warm = second run (steady-state throughput, the
paper's deployment claim). Parity of the two paths is pinned bitwise-close
in tests/test_batched_parity.py.

The impl rows compare the per-stage backends behind ``kernels/ops`` on the
batched executor — stage 1 (``gptq_block``) AND stage 2 (``rpiq_block``)
set to the same backend per row — and MEASURE the dispatch-overhead claim
instead of asserting it: ``xla_ops`` / ``xla_ops_s2`` are the executed-
XLA-op counts of the stage-1 / stage-2 dispatches for the row's largest
group, and ``executor_s`` splits into ``stage1_s``/``stage2_s`` so the
closed-loop cost is visible on its own —

  - ``xla``: the vmapped loop bodies compiled locally, counted
    trip-count-aware (``launch/hlo_analysis.executed_op_count``) — O(Cin)
    ops per stage-1 sweep, O(t·n_blocks) per stage-2 refinement (the
    stage-2 ``while`` has no known trip count, so its body is counted
    once — a LOWER bound on the xla side, conservative for the claim);
  - ``pallas``: the fused kernels lowered FOR TPU via cross-platform
    export (``tpu_exported_op_count``) — each whole stage is one
    ``tpu_custom_call``, so the count is the handful of pad/reduce ops
    around it.  (Compiling the pallas path on CPU would count the
    interpret-mode emulation loop, which is an artifact of the CPU
    container, not the hardware dispatch story; for the same reason the
    interpret-mode ``pallas`` WALL times here do not represent TPU.)

The ``pipeline`` field records the layer-walk schedule behind every row
(core/stream.py): the impl rows run the default ``serial`` walk; each
config additionally gets one ``pipeline="overlap"`` row (impl ``xla``) —
the streaming scheduler A/B, compared against the matching serial row in
``overlap_delta_s``/``overlap_speedup``. On this CPU container the two
schedules share one synchronous device stream, so the overlap win is
bounded by host-side stall removal (deferred per-stage sync + record
materialization) and is largest where executor time dominates (the MoE
row); the speculative capture-ahead is extra stream work here, while on
TPU meshes it rides the executor gap (DESIGN.md §2.7 — same family of
caveat as the interpret-mode pallas wall times below).

Every ``pipeline="overlap"`` row also carries the scheduler's
``pipeline_stats`` counters (spec_captures / repairs / serial_fallbacks
plus the per-reason and MoE flip-repair tallies) so the bench artifact is
EVIDENCE that speculation actually engaged — scripts/check_bench.py gates
on it: a routed-MoE overlap row whose stats show serial re-capture instead
of flip repair fails CI. The MoE row additionally gets one
expert-sharded overlap cell (``quant_mesh="1x2x4"``): the same config
quantized with the expert mesh axis live, timed in a subprocess because
the expert axis needs a forced multi-device host platform
(``XLA_FLAGS=--xla_force_host_platform_device_count=8``) that must be set
before jax initializes. Parity of that path is pinned bitwise in
tests/test_distributed.py::test_moe_expert_sharded_matches_single.

Row schema and regeneration contract: docs/BENCHMARKS.md.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time

import jax
import jax.numpy as jnp

from benchmarks.common import bench_config
from repro.core import plan as qplan
from repro.core.pipeline import quantize_model
from repro.data import MarkovLM, calibration_batches
from repro.kernels import ops as kops
from repro.launch import hlo_analysis as ha
from repro.models import transformer as T


def _largest_group_shape(cfg) -> tuple:
    """(lanes, out, in) of the row's biggest quant group (MoE gate/up
    share a 2E-member group; dense layers group the 4 attention taps)."""
    mc = cfg.model
    if mc.moe.num_experts:
        return (2 * mc.moe.num_experts, mc.moe.d_ff_expert, mc.d_model)
    return (4, mc.d_model, mc.d_model)


def _quant_stage_op_counts(cfg, n_last: int = 128) -> dict:
    """Executed-XLA-op counts of the stage-1 AND stage-2 dispatches per
    impl, for the row's largest group: {impl: {"s1": ops, "s2": ops}}.

    ``n_last`` mirrors the calibration instance rows the timed runs below
    feed stage 2 (batch 4 × seq 32)."""
    qc = cfg.quant
    b, out_d, in_d = _largest_group_shape(cfg)
    bs = qc.blocksize
    w = jnp.zeros((b, out_d, in_d), jnp.float32)
    u = jnp.broadcast_to(jnp.eye(in_d, dtype=jnp.float32), (b, in_d, in_d))
    x = jnp.zeros((b, n_last, in_d), jnp.float32)
    s = jnp.ones((b, out_d, in_d // qc.group_size), jnp.float32)
    z = jnp.zeros_like(s)
    # (M, bs, bs) explicit block inverses: like the stage-1 count (which
    # takes the Cholesky factor U as an input), the curvature pre-factor is
    # excluded — it is the SAME code on both backends, so counting it would
    # only dilute the backend comparison the row exists to measure
    hinv = jnp.broadcast_to(jnp.eye(bs, dtype=jnp.float32),
                            (b, in_d // bs, bs, bs))
    kw1 = dict(bits=qc.bits, group_size=qc.group_size, blocksize=bs,
               symmetric=qc.symmetric)
    kw2 = dict(bits=qc.bits, group_size=qc.group_size, block_size=bs,
               alpha=qc.rpiq_alpha, t_max=qc.rpiq_iters,
               early_stop=qc.rpiq_early_stop, symmetric=qc.symmetric)

    def stage2(impl, **over):
        return lambda w, wf, x, hv, s, z: kops.rpiq_block(
            w, wf, x, hv, s, z, impl=impl, **kw2, **over)

    xla1 = jax.jit(
        lambda w, u: kops.gptq_block(w, u, impl="xla", **kw1)
    ).lower(w, u).compile().as_text()
    xla2 = jax.jit(stage2("xla")).lower(w, w, x, hinv, s,
                                        z).compile().as_text()
    return {
        "xla": {"s1": ha.executed_op_count(xla1),
                "s2": ha.executed_op_count(xla2)},
        "pallas": {
            "s1": ha.tpu_exported_op_count(
                lambda w, u: kops.gptq_block(w, u, impl="pallas",
                                             interpret=False, **kw1), w, u),
            "s2": ha.tpu_exported_op_count(
                stage2("pallas", interpret=False), w, w, x, hinv, s, z),
        },
    }


def _timed_repeats(cfg, params, calib, repeats: int):
    """Best-of-``repeats`` post-compile runs: (min wall seconds,
    (executor_s, stage1_s, stage2_s) of the best-executor run)."""
    walls, stats = [], []
    for _ in range(repeats):
        t0 = time.perf_counter()
        _, rep = quantize_model(cfg, params, calib)
        walls.append(time.perf_counter() - t0)
        stats.append((rep.seconds_stage1 + rep.seconds_stage2,
                      rep.seconds_stage1, rep.seconds_stage2))
    return min(walls), min(stats)


def _time_impls(cfg, params, calib, label: str, repeats: int = 3,
                op_counts: bool = True,
                impls: tuple = ("xla", "pallas"),
                pipeline: str = None) -> list:
    """Flat BENCH rows: batched executor with BOTH per-stage backends set
    to the row's impl (stage-1 gptq_block + stage-2 rpiq_block).
    ``pipeline`` overrides ``quant.pipeline`` for these rows — the
    serial-vs-overlap A/B reuses this exact scaffold (same cold/warm
    protocol, same row schema) via :func:`_time_overlap`."""
    ops_by_impl = _quant_stage_op_counts(cfg) if op_counts else {}
    rows = []
    cfg.quant.batched_executor = True
    prev_pipeline = cfg.quant.pipeline
    if pipeline is not None:
        cfg.quant.pipeline = pipeline
    for impl in impls:
        cfg.quant.gptq_impl = impl
        cfg.quant.rpiq_impl = impl
        jax.clear_caches()
        qplan.clear_executor_cache()
        t0 = time.perf_counter()
        _, rep = quantize_model(cfg, params, calib)
        cold = time.perf_counter() - t0
        wall, best = _timed_repeats(cfg, params, calib, repeats)
        ops = ops_by_impl.get(impl, {}) or {}
        row = {
            "config": label, "impl": impl,
            "pipeline": cfg.quant.pipeline,
            "cold_s": round(cold, 2), "warm_s": round(wall, 2),
            "executor_s": round(best[0], 3),
            "stage1_s": round(best[1], 3), "stage2_s": round(best[2], 3),
            "xla_ops": ops.get("s1"), "xla_ops_s2": ops.get("s2"),
        }
        if cfg.quant.pipeline == "overlap":
            # scheduler evidence: check_bench.py gates on these counters
            # (speculation engaged, MoE layers flip-repaired not re-planned)
            row["pipeline_stats"] = dict(rep.pipeline_stats)
        rows.append(row)
    cfg.quant.pipeline = prev_pipeline
    cfg.quant.gptq_impl = "auto"
    cfg.quant.rpiq_impl = "auto"
    return rows


def _time_overlap(cfg, params, calib, label: str, repeats: int = 3) -> list:
    """The streaming-scheduler A/B row: batched executor, xla backends,
    ``quant.pipeline=overlap`` (cold + best-of-``repeats`` warm).

    Skipped under the ``REPRO_BENCH_PIPELINE`` smoke override — it
    already forces every impl row onto one schedule, so this row would
    re-run an identical configuration with no serial row to compare to.
    """
    if os.environ.get("REPRO_BENCH_PIPELINE"):
        return []
    return _time_impls(cfg, params, calib, label, repeats=repeats,
                       op_counts=False, impls=("xla",), pipeline="overlap")


_EXPERT_MESH = "1x2x4"  # DxMxE: rows over model=2, expert lanes over E=4


def _expert_cell_main() -> None:
    """Subprocess entry for the expert-sharded MoE cell: quantize the MoE
    bench config with ``quant.mesh=_EXPERT_MESH`` under the overlap
    scheduler and print the bench row as JSON on the last stdout line.

    Runs out-of-process because the expert mesh axis needs a forced
    multi-device host platform, and ``XLA_FLAGS`` only takes effect
    before jax initializes (the parent keeps the single real device)."""
    cfg = bench_config("olmoe-1b-7b")
    cfg.quant.batched_executor = True
    cfg.quant.pipeline = "overlap"
    cfg.quant.mesh = _EXPERT_MESH
    params = T.init_params(cfg.model, jax.random.PRNGKey(0))
    calib = calibration_batches(
        MarkovLM(cfg.model.vocab_size, seed=0), 3, 4, 32)
    t0 = time.perf_counter()
    _, rep = quantize_model(cfg, params, calib)
    cold = time.perf_counter() - t0
    wall, best = _timed_repeats(cfg, params, calib, repeats=2)
    print(json.dumps({
        "config": f"moe-{cfg.model.name}", "impl": "xla",
        "pipeline": "overlap", "quant_mesh": _EXPERT_MESH,
        "cold_s": round(cold, 2), "warm_s": round(wall, 2),
        "executor_s": round(best[0], 3),
        "stage1_s": round(best[1], 3), "stage2_s": round(best[2], 3),
        "xla_ops": None, "xla_ops_s2": None,
        "pipeline_stats": dict(rep.pipeline_stats),
    }))


def _time_expert_sharded(label: str) -> list:
    """The expert-parallel A/B cell for the MoE row (see
    :func:`_expert_cell_main`). Skipped under ``REPRO_BENCH_PIPELINE``
    for the same reason as :func:`_time_overlap`."""
    if os.environ.get("REPRO_BENCH_PIPELINE"):
        return []
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    r = subprocess.run(
        [sys.executable, "-c",
         "from benchmarks.table4_time import _expert_cell_main; "
         "_expert_cell_main()"],
        capture_output=True, text=True, timeout=1800, env=env)
    if r.returncode != 0:
        raise RuntimeError(
            f"expert-sharded bench cell failed:\n{r.stderr[-3000:]}")
    cell = json.loads(r.stdout.strip().splitlines()[-1])
    assert cell["config"] == label, (cell["config"], label)
    return [cell]


def _overlap_summary(row: dict) -> None:
    """Fold the serial-vs-overlap warm delta into the table row (the
    matching serial reference is the impl="xla" row of the same config)."""
    serial = next((b for b in row["bench"] if b["impl"] == "xla"
                   and b.get("pipeline") != "overlap"), None)
    ov = next((b for b in row["bench"]
               if b.get("pipeline") == "overlap"), None)
    if serial is None or ov is None:
        return
    row["t_overlap_s"] = ov["warm_s"]
    row["overlap_delta_s"] = round(serial["warm_s"] - ov["warm_s"], 2)
    row["overlap_speedup"] = round(
        serial["warm_s"] / max(ov["warm_s"], 1e-9), 2)


def _time_exec_paths(cfg, params, calib, repeats: int = 5) -> dict:
    """Cold+warm wall-clock for per-linear vs batched plan execution.

    Warm = best of ``repeats`` post-compile runs (total wall-clock is
    dominated by the shared capture/propagate forwards, so single-shot
    timing is noisy); ``exec`` isolates the synchronized stage-1+stage-2
    executor seconds where the dispatch-count win lives.
    """
    out = {}
    for label, flag in (("perlinear", False), ("batched", True)):
        cfg.quant.batched_executor = flag
        # symmetric cold starts: earlier runs in this process may have
        # compiled one path's executors (e.g. the t_gptq/t_rpiq timings
        # run with the default batched executor)
        jax.clear_caches()
        qplan.clear_executor_cache()
        t0 = time.perf_counter()
        quantize_model(cfg, params, calib)
        out[f"t_{label}_cold_s"] = round(time.perf_counter() - t0, 2)
        wall, best = _timed_repeats(cfg, params, calib, repeats)
        out[f"t_{label}_s"] = round(wall, 2)
        out[f"t_{label}_exec_s"] = round(best[0], 3)
        out[f"t_{label}_s1_s"] = round(best[1], 3)
        out[f"t_{label}_s2_s"] = round(best[2], 3)
    out["speedup_warm"] = round(
        out["t_perlinear_s"] / max(out["t_batched_s"], 1e-9), 2)
    out["speedup_exec"] = round(
        out["t_perlinear_exec_s"] / max(out["t_batched_exec_s"], 1e-9), 2)
    return out


def run(tiny: bool = False) -> list:
    rows = []
    dense_grid = ((64, 256, 2),) if tiny else ((64, 256, 2), (128, 512, 2),
                                               (128, 512, 4))
    repeats = 2 if tiny else 5
    for d_model, d_ff, layers in dense_grid:
        cfg = bench_config("opt-proxy", d_model=d_model, d_ff=d_ff,
                           num_layers=layers,
                           num_heads=max(4, d_model // 16),
                           num_kv_heads=max(4, d_model // 16))
        cfg.model.head_dim = 0
        cfg.model.__post_init__()
        params = T.init_params(cfg.model, jax.random.PRNGKey(0))
        calib = calibration_batches(
            MarkovLM(cfg.model.vocab_size, seed=0), 3, 4, 32)

        cfg_g = bench_config("opt-proxy", d_model=d_model, d_ff=d_ff,
                             num_layers=layers,
                             num_heads=max(4, d_model // 16),
                             num_kv_heads=max(4, d_model // 16))
        cfg_g.model.head_dim = 0
        cfg_g.model.__post_init__()
        cfg_g.quant.rpiq_iters = 0
        t0 = time.perf_counter()
        quantize_model(cfg_g, params, calib)
        t_gptq = time.perf_counter() - t0

        t0 = time.perf_counter()
        _, rep = quantize_model(cfg, params, calib)
        t_rpiq = time.perf_counter() - t0
        label = f"d{d_model}-L{layers}"
        row = {
            "table": "table4", "d_model": d_model, "layers": layers,
            "t_gptq_s": round(t_gptq, 2), "t_rpiq_s": round(t_rpiq, 2),
            "delta_s": round(t_rpiq - t_gptq, 2),
            "stage2_s": round(rep.seconds_stage2, 2),
        }
        # plan-executor comparison: 4 same-shape q/k/v/o linears per layer
        row.update(_time_exec_paths(cfg, params, calib, repeats=repeats))
        row["bench"] = [
            {"config": label, "impl": "perlinear",
             "pipeline": cfg.quant.pipeline,
             "cold_s": row["t_perlinear_cold_s"],
             "warm_s": row["t_perlinear_s"],
             "executor_s": row["t_perlinear_exec_s"],
             "stage1_s": row["t_perlinear_s1_s"],
             "stage2_s": row["t_perlinear_s2_s"],
             "xla_ops": None, "xla_ops_s2": None},
        ] + _time_impls(cfg, params, calib, label, repeats=repeats) \
          + _time_overlap(cfg, params, calib, label, repeats=repeats)
        _overlap_summary(row)
        rows.append(row)

    if tiny:
        return rows

    # MoE: 8 experts/layer → gate/up stack into one 16-member group,
    # down into an 8-member group; per-linear pays 24 dispatch pairs/layer.
    cfg = bench_config("olmoe-1b-7b")
    params = T.init_params(cfg.model, jax.random.PRNGKey(0))
    calib = calibration_batches(
        MarkovLM(cfg.model.vocab_size, seed=0), 3, 4, 32)
    row = {"table": "table4", "d_model": cfg.model.d_model,
           "layers": cfg.model.num_layers,
           "moe_experts": cfg.model.moe.num_experts}
    row.update(_time_exec_paths(cfg, params, calib))
    label = f"moe-{cfg.model.name}"
    row["bench"] = [
        {"config": label, "impl": "perlinear",
         "pipeline": cfg.quant.pipeline,
         "cold_s": row["t_perlinear_cold_s"], "warm_s": row["t_perlinear_s"],
         "executor_s": row["t_perlinear_exec_s"],
         "stage1_s": row["t_perlinear_s1_s"],
         "stage2_s": row["t_perlinear_s2_s"],
         "xla_ops": None, "xla_ops_s2": None},
    ] + _time_impls(cfg, params, calib, label) \
      + _time_overlap(cfg, params, calib, label) \
      + _time_expert_sharded(label)
    _overlap_summary(row)
    # the headline fused-kernel claims, measured (≥10× required per stage):
    # (serial impl rows only — the overlap A/B row shares impl="xla" but
    # carries no op counts)
    impls = {b["impl"]: b for b in row["bench"]
             if b.get("pipeline") != "overlap"}
    if impls.get("pallas", {}).get("xla_ops"):
        row["op_reduction"] = round(
            impls["xla"]["xla_ops"] / impls["pallas"]["xla_ops"], 1)
    if impls.get("pallas", {}).get("xla_ops_s2"):
        row["op_reduction_s2"] = round(
            impls["xla"]["xla_ops_s2"] / impls["pallas"]["xla_ops_s2"], 1)
    rows.append(row)
    return rows
