"""Paper Table 4: quantization wall time — GPTQ vs RPIQ (ΔT), plus the
quant-plan executor comparison.

Across model widths; RPIQ's stage 2 adds a bounded, roughly width-
proportional overhead (paper: +12-18s on 7-13B GPUs; CPU-scale here).

The ``batched`` rows measure the QuantPlan batched executors
(core/plan.py: same-shape linears grouped into one vmapped GPTQ+RPIQ
dispatch) against the legacy per-linear dispatch on the SAME model/calib —
each opt-proxy layer holds 4 same-shape attention linears, and the MoE row
stacks 8 experts (gate/up share one 16-member group). Cold = first run
(includes compile); warm = second run (steady-state throughput, the
paper's deployment claim). Parity of the two paths is pinned bitwise-close
in tests/test_batched_parity.py.
"""
from __future__ import annotations

import time

import jax

from benchmarks.common import bench_config
from repro.core.pipeline import quantize_model
from repro.data import MarkovLM, calibration_batches
from repro.models import transformer as T


def _time_exec_paths(cfg, params, calib, repeats: int = 5) -> dict:
    """Cold+warm wall-clock for per-linear vs batched plan execution.

    Warm = best of ``repeats`` post-compile runs (total wall-clock is
    dominated by the shared capture/propagate forwards, so single-shot
    timing is noisy); ``exec`` isolates the synchronized stage-1+stage-2
    executor seconds where the dispatch-count win lives.
    """
    out = {}
    for label, flag in (("perlinear", False), ("batched", True)):
        cfg.quant.batched_executor = flag
        # symmetric cold starts: earlier runs in this process may have
        # compiled one path's executors (e.g. the t_gptq/t_rpiq timings
        # run with the default batched executor)
        jax.clear_caches()
        t0 = time.perf_counter()
        quantize_model(cfg, params, calib)
        out[f"t_{label}_cold_s"] = round(time.perf_counter() - t0, 2)
        walls, execs = [], []
        for _ in range(repeats):
            t0 = time.perf_counter()
            _, rep = quantize_model(cfg, params, calib)
            walls.append(time.perf_counter() - t0)
            execs.append(rep.seconds_stage1 + rep.seconds_stage2)
        out[f"t_{label}_s"] = round(min(walls), 2)
        out[f"t_{label}_exec_s"] = round(min(execs), 3)
    out["speedup_warm"] = round(
        out["t_perlinear_s"] / max(out["t_batched_s"], 1e-9), 2)
    out["speedup_exec"] = round(
        out["t_perlinear_exec_s"] / max(out["t_batched_exec_s"], 1e-9), 2)
    return out


def run() -> list:
    rows = []
    for d_model, d_ff, layers in ((64, 256, 2), (128, 512, 2),
                                  (128, 512, 4)):
        cfg = bench_config("opt-proxy", d_model=d_model, d_ff=d_ff,
                           num_layers=layers,
                           num_heads=max(4, d_model // 16),
                           num_kv_heads=max(4, d_model // 16))
        cfg.model.head_dim = 0
        cfg.model.__post_init__()
        params = T.init_params(cfg.model, jax.random.PRNGKey(0))
        calib = calibration_batches(
            MarkovLM(cfg.model.vocab_size, seed=0), 3, 4, 32)

        cfg_g = bench_config("opt-proxy", d_model=d_model, d_ff=d_ff,
                             num_layers=layers,
                             num_heads=max(4, d_model // 16),
                             num_kv_heads=max(4, d_model // 16))
        cfg_g.model.head_dim = 0
        cfg_g.model.__post_init__()
        cfg_g.quant.rpiq_iters = 0
        t0 = time.perf_counter()
        quantize_model(cfg_g, params, calib)
        t_gptq = time.perf_counter() - t0

        t0 = time.perf_counter()
        _, rep = quantize_model(cfg, params, calib)
        t_rpiq = time.perf_counter() - t0
        row = {
            "table": "table4", "d_model": d_model, "layers": layers,
            "t_gptq_s": round(t_gptq, 2), "t_rpiq_s": round(t_rpiq, 2),
            "delta_s": round(t_rpiq - t_gptq, 2),
            "stage2_s": round(rep.seconds_stage2, 2),
        }
        # plan-executor comparison: 4 same-shape q/k/v/o linears per layer
        row.update(_time_exec_paths(cfg, params, calib))
        rows.append(row)

    # MoE: 8 experts/layer → gate/up stack into one 16-member group,
    # down into an 8-member group; per-linear pays 24 dispatch pairs/layer.
    cfg = bench_config("olmoe-1b-7b")
    params = T.init_params(cfg.model, jax.random.PRNGKey(0))
    calib = calibration_batches(
        MarkovLM(cfg.model.vocab_size, seed=0), 3, 4, 32)
    row = {"table": "table4", "d_model": cfg.model.d_model,
           "layers": cfg.model.num_layers,
           "moe_experts": cfg.model.moe.num_experts}
    row.update(_time_exec_paths(cfg, params, calib))
    rows.append(row)
    return rows
