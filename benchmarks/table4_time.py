"""Paper Table 4: quantization wall time — GPTQ vs RPIQ (ΔT).

Across model widths; RPIQ's stage 2 adds a bounded, roughly width-
proportional overhead (paper: +12-18s on 7-13B GPUs; CPU-scale here)."""
from __future__ import annotations

import time

import jax

from benchmarks.common import bench_config, make_calib, train_lm
from repro.core.pipeline import quantize_model
from repro.data import MarkovLM, calibration_batches
from repro.models import transformer as T


def run() -> list:
    rows = []
    for d_model, d_ff, layers in ((64, 256, 2), (128, 512, 2),
                                  (128, 512, 4)):
        cfg = bench_config("opt-proxy", d_model=d_model, d_ff=d_ff,
                           num_layers=layers,
                           num_heads=max(4, d_model // 16),
                           num_kv_heads=max(4, d_model // 16))
        cfg.model.head_dim = 0
        cfg.model.__post_init__()
        params = T.init_params(cfg.model, jax.random.PRNGKey(0))
        calib = calibration_batches(
            MarkovLM(cfg.model.vocab_size, seed=0), 3, 4, 32)

        cfg_g = bench_config("opt-proxy", d_model=d_model, d_ff=d_ff,
                             num_layers=layers,
                             num_heads=max(4, d_model // 16),
                             num_kv_heads=max(4, d_model // 16))
        cfg_g.model.head_dim = 0
        cfg_g.model.__post_init__()
        cfg_g.quant.rpiq_iters = 0
        t0 = time.perf_counter()
        quantize_model(cfg_g, params, calib)
        t_gptq = time.perf_counter() - t0

        t0 = time.perf_counter()
        _, rep = quantize_model(cfg, params, calib)
        t_rpiq = time.perf_counter() - t0
        rows.append({
            "table": "table4", "d_model": d_model, "layers": layers,
            "t_gptq_s": round(t_gptq, 2), "t_rpiq_s": round(t_rpiq, 2),
            "delta_s": round(t_rpiq - t_gptq, 2),
            "stage2_s": round(rep.seconds_stage2, 2),
        })
    return rows
