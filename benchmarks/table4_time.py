"""Paper Table 4: quantization wall time — GPTQ vs RPIQ (ΔT), plus the
quant-plan executor comparison and the stage-1 sweep-backend comparison.

Across model widths; RPIQ's stage 2 adds a bounded, roughly width-
proportional overhead (paper: +12-18s on 7-13B GPUs; CPU-scale here).

The ``batched`` rows measure the QuantPlan batched executors
(core/plan.py: same-shape linears grouped into one vmapped GPTQ+RPIQ
dispatch) against the legacy per-linear dispatch on the SAME model/calib —
each opt-proxy layer holds 4 same-shape attention linears, and the MoE row
stacks 8 experts (gate/up share one 16-member group). Cold = first run
(includes compile); warm = second run (steady-state throughput, the
paper's deployment claim). Parity of the two paths is pinned bitwise-close
in tests/test_batched_parity.py.

The ``gptq_impl`` rows compare the stage-1 sweep backends behind
``kernels/ops.gptq_block`` on the batched executor, and MEASURE the
dispatch-overhead claim instead of asserting it: ``xla_ops`` is the
executed-XLA-op count of the quantize-stage dispatch for the row's largest
group —

  - ``xla``: the vmapped ``fori_loop`` body compiled locally, counted
    trip-count-aware (``launch/hlo_analysis.executed_op_count``) — O(Cin)
    ops per sweep;
  - ``pallas``: the fused kernel lowered FOR TPU via cross-platform export
    (``tpu_exported_op_count``) — the whole sweep is one
    ``tpu_custom_call``, so the count is the handful of pad/slice ops
    around it.  (Compiling the pallas path on CPU would count the
    interpret-mode emulation loop, which is an artifact of the CPU
    container, not the hardware dispatch story; for the same reason the
    interpret-mode ``pallas`` WALL times here do not represent TPU.)

Row schema and regeneration contract: docs/BENCHMARKS.md.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import bench_config
from repro.core import plan as qplan
from repro.core.pipeline import quantize_model
from repro.data import MarkovLM, calibration_batches
from repro.kernels import ops as kops
from repro.launch import hlo_analysis as ha
from repro.models import transformer as T


def _largest_group_shape(cfg) -> tuple:
    """(lanes, out, in) of the row's biggest quant group (MoE gate/up
    share a 2E-member group; dense layers group the 4 attention taps)."""
    mc = cfg.model
    if mc.moe.num_experts:
        return (2 * mc.moe.num_experts, mc.moe.d_ff_expert, mc.d_model)
    return (4, mc.d_model, mc.d_model)


def _quant_stage_op_counts(cfg) -> dict:
    """Executed-XLA-op count of the stage-1 sweep dispatch per impl."""
    qc = cfg.quant
    b, out_d, in_d = _largest_group_shape(cfg)
    w = jnp.zeros((b, out_d, in_d), jnp.float32)
    u = jnp.broadcast_to(jnp.eye(in_d, dtype=jnp.float32), (b, in_d, in_d))
    kw = dict(bits=qc.bits, group_size=qc.group_size,
              blocksize=qc.blocksize, symmetric=qc.symmetric)
    xla_txt = jax.jit(
        lambda w, u: kops.gptq_block(w, u, impl="xla", **kw)
    ).lower(w, u).compile().as_text()
    return {
        "xla": ha.executed_op_count(xla_txt),
        "pallas": ha.tpu_exported_op_count(
            lambda w, u: kops.gptq_block(w, u, impl="pallas",
                                         interpret=False, **kw), w, u),
    }


def _time_gptq_impls(cfg, params, calib, label: str, repeats: int = 3,
                     op_counts: bool = True) -> list:
    """Flat BENCH rows: batched executor with each stage-1 sweep backend."""
    ops_by_impl = _quant_stage_op_counts(cfg) if op_counts else {}
    rows = []
    cfg.quant.batched_executor = True
    for impl in ("xla", "pallas"):
        cfg.quant.gptq_impl = impl
        jax.clear_caches()
        qplan.clear_executor_cache()
        t0 = time.perf_counter()
        quantize_model(cfg, params, calib)
        cold = time.perf_counter() - t0
        walls, execs = [], []
        for _ in range(repeats):
            t0 = time.perf_counter()
            _, rep = quantize_model(cfg, params, calib)
            walls.append(time.perf_counter() - t0)
            execs.append(rep.seconds_stage1 + rep.seconds_stage2)
        rows.append({
            "config": label, "impl": impl,
            "cold_s": round(cold, 2), "warm_s": round(min(walls), 2),
            "executor_s": round(min(execs), 3),
            "xla_ops": ops_by_impl.get(impl),
        })
    cfg.quant.gptq_impl = "auto"
    return rows


def _time_exec_paths(cfg, params, calib, repeats: int = 5) -> dict:
    """Cold+warm wall-clock for per-linear vs batched plan execution.

    Warm = best of ``repeats`` post-compile runs (total wall-clock is
    dominated by the shared capture/propagate forwards, so single-shot
    timing is noisy); ``exec`` isolates the synchronized stage-1+stage-2
    executor seconds where the dispatch-count win lives.
    """
    out = {}
    for label, flag in (("perlinear", False), ("batched", True)):
        cfg.quant.batched_executor = flag
        # symmetric cold starts: earlier runs in this process may have
        # compiled one path's executors (e.g. the t_gptq/t_rpiq timings
        # run with the default batched executor)
        jax.clear_caches()
        qplan.clear_executor_cache()
        t0 = time.perf_counter()
        quantize_model(cfg, params, calib)
        out[f"t_{label}_cold_s"] = round(time.perf_counter() - t0, 2)
        walls, execs = [], []
        for _ in range(repeats):
            t0 = time.perf_counter()
            _, rep = quantize_model(cfg, params, calib)
            walls.append(time.perf_counter() - t0)
            execs.append(rep.seconds_stage1 + rep.seconds_stage2)
        out[f"t_{label}_s"] = round(min(walls), 2)
        out[f"t_{label}_exec_s"] = round(min(execs), 3)
    out["speedup_warm"] = round(
        out["t_perlinear_s"] / max(out["t_batched_s"], 1e-9), 2)
    out["speedup_exec"] = round(
        out["t_perlinear_exec_s"] / max(out["t_batched_exec_s"], 1e-9), 2)
    return out


def run(tiny: bool = False) -> list:
    rows = []
    dense_grid = ((64, 256, 2),) if tiny else ((64, 256, 2), (128, 512, 2),
                                               (128, 512, 4))
    repeats = 2 if tiny else 5
    for d_model, d_ff, layers in dense_grid:
        cfg = bench_config("opt-proxy", d_model=d_model, d_ff=d_ff,
                           num_layers=layers,
                           num_heads=max(4, d_model // 16),
                           num_kv_heads=max(4, d_model // 16))
        cfg.model.head_dim = 0
        cfg.model.__post_init__()
        params = T.init_params(cfg.model, jax.random.PRNGKey(0))
        calib = calibration_batches(
            MarkovLM(cfg.model.vocab_size, seed=0), 3, 4, 32)

        cfg_g = bench_config("opt-proxy", d_model=d_model, d_ff=d_ff,
                             num_layers=layers,
                             num_heads=max(4, d_model // 16),
                             num_kv_heads=max(4, d_model // 16))
        cfg_g.model.head_dim = 0
        cfg_g.model.__post_init__()
        cfg_g.quant.rpiq_iters = 0
        t0 = time.perf_counter()
        quantize_model(cfg_g, params, calib)
        t_gptq = time.perf_counter() - t0

        t0 = time.perf_counter()
        _, rep = quantize_model(cfg, params, calib)
        t_rpiq = time.perf_counter() - t0
        label = f"d{d_model}-L{layers}"
        row = {
            "table": "table4", "d_model": d_model, "layers": layers,
            "t_gptq_s": round(t_gptq, 2), "t_rpiq_s": round(t_rpiq, 2),
            "delta_s": round(t_rpiq - t_gptq, 2),
            "stage2_s": round(rep.seconds_stage2, 2),
        }
        # plan-executor comparison: 4 same-shape q/k/v/o linears per layer
        row.update(_time_exec_paths(cfg, params, calib, repeats=repeats))
        row["bench"] = [
            {"config": label, "impl": "perlinear",
             "cold_s": row["t_perlinear_cold_s"],
             "warm_s": row["t_perlinear_s"],
             "executor_s": row["t_perlinear_exec_s"], "xla_ops": None},
        ] + _time_gptq_impls(cfg, params, calib, label, repeats=repeats)
        rows.append(row)

    if tiny:
        return rows

    # MoE: 8 experts/layer → gate/up stack into one 16-member group,
    # down into an 8-member group; per-linear pays 24 dispatch pairs/layer.
    cfg = bench_config("olmoe-1b-7b")
    params = T.init_params(cfg.model, jax.random.PRNGKey(0))
    calib = calibration_batches(
        MarkovLM(cfg.model.vocab_size, seed=0), 3, 4, 32)
    row = {"table": "table4", "d_model": cfg.model.d_model,
           "layers": cfg.model.num_layers,
           "moe_experts": cfg.model.moe.num_experts}
    row.update(_time_exec_paths(cfg, params, calib))
    label = f"moe-{cfg.model.name}"
    row["bench"] = [
        {"config": label, "impl": "perlinear",
         "cold_s": row["t_perlinear_cold_s"], "warm_s": row["t_perlinear_s"],
         "executor_s": row["t_perlinear_exec_s"], "xla_ops": None},
    ] + _time_gptq_impls(cfg, params, calib, label)
    # the headline fused-kernel claim, measured (≥10× required):
    impls = {b["impl"]: b for b in row["bench"]}
    if impls.get("pallas", {}).get("xla_ops"):
        row["op_reduction"] = round(
            impls["xla"]["xla_ops"] / impls["pallas"]["xla_ops"], 1)
    rows.append(row)
    return rows
