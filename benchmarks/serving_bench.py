"""Serving benchmark: static vs continuous batching, fp16 vs int4 weights.

One synthetic Poisson trace per config (mixed prompt lengths, mixed
max_new), replayed against both engines:

- **static**: FCFS groups of ``serve.max_batch`` requests through
  ``engine.generate`` — prompts right-padded to the group max, every lane
  decodes to the group's slowest ``max_new``, results delivered at batch
  completion (that is the static engine's contract, and exactly the cost
  model continuous batching removes).
- **continuous**: the same trace through ``scheduler.ContinuousEngine``
  (chunked prefill interleaved with decode, lanes reused on finish).

The clock is virtual: ``t`` advances by the measured wall of each engine
call, and request ``i`` becomes visible once ``arrival_i <= t`` — so the
numbers are architecture-honest on any host without needing real threads.
Warmup calls (excluded from the clock) pre-compile every jitted shape.
The arrival rate is calibrated per (config, weights): the trace is first
replayed back-to-back through the continuous engine to measure its
saturated service time, and Poisson arrivals are then drawn at 1.3× that
service rate — sustained saturation, where lane occupancy and admission
latency under backlog are what distinguish the schedulers.

Metrics per row: tokens/s over engine-busy time, TTFT mean/p50/p95/p99
(arrival → first token available), TPOT p50/p95/p99 (per-token time after
the first; batch-amortized for static), and batch-occupancy (fraction of
decode-lane-steps doing useful work). Schema + regeneration contract:
docs/BENCHMARKS.md; full (non ``--tiny``) runs rewrite BENCH_serving.json
at the repo root.

The ``longctx`` trace compares decode-cache precisions at an **equal
memory budget**: the budget is what ``serve.max_batch`` fp16 lanes cost at
the trace's context cap (``common.cache_bytes_per_seq``), and each
``serve.kv_cache`` setting gets however many lanes fit in that budget —
int8's smaller per-sequence footprint buys it more concurrent lanes, which
is the deployment form of the memory claim (occupancy/TTFT at fixed HBM,
not bytes in the abstract).
"""
from __future__ import annotations

import contextlib
import dataclasses
import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import bench_config, cache_bytes_per_seq
from repro.core import faults
from repro.core.pipeline import pack_for_serving
from repro.models import transformer as T
from repro.serving.engine import generate
from repro.serving.scheduler import ContinuousEngine, QueueFullError
from repro.serving.supervisor import SupervisedEngine


def _make_requests(cfg, n: int, rng: np.random.Generator, tiny: bool):
    """Mixed-length prompts + mixed decode budgets (eos never fires, so
    lengths are exact and occupancy math is deterministic)."""
    mc = cfg.model
    plens = (4, 6) if tiny else (6, 10, 14, 18)
    # wide max_new spread: output length is the high-variance axis of real
    # traffic, and it is exactly what static batching pads away (every
    # lane decodes to the group max)
    mnews = (2, 4, 8) if tiny else (2, 4, 8, 16, 24)
    reqs = []
    for _ in range(n):
        s0 = int(rng.choice(plens))
        toks = rng.integers(1, mc.vocab_size, size=(1, s0)).astype(np.int32)
        b = {"tokens": jnp.asarray(toks)}
        if mc.is_encoder_decoder:
            b["frames"] = jnp.asarray(rng.standard_normal(
                (1, mc.encoder_seq_len, mc.d_model)).astype(np.float32))
        reqs.append({"batch": b, "max_new": int(rng.choice(mnews))})
    return reqs


def _arrivals(reqs, rate: float, rng: np.random.Generator) -> np.ndarray:
    gaps = rng.exponential(1.0 / rate, size=len(reqs))
    return np.cumsum(gaps) - gaps[0]          # first request arrives at t=0


def _pct(xs: List[float]) -> Dict[str, float]:
    a = np.asarray(xs, np.float64)
    return {"p50": float(np.percentile(a, 50)),
            "p95": float(np.percentile(a, 95)),
            "p99": float(np.percentile(a, 99))}


def _pad_group(reqs) -> Dict[str, jnp.ndarray]:
    """Right-pad prompts to the group max — the static-batch tax."""
    smax = max(r["batch"]["tokens"].shape[1] for r in reqs)
    toks = np.zeros((len(reqs), smax), np.int32)
    for i, r in enumerate(reqs):
        t = np.asarray(r["batch"]["tokens"][0])
        toks[i, :t.shape[0]] = t
    out = {"tokens": jnp.asarray(toks)}
    if "frames" in reqs[0]["batch"]:
        out["frames"] = jnp.concatenate([r["batch"]["frames"] for r in reqs])
    return out


def _jit_generate(cfg, mnt: int):
    """Fully-jitted static generate — the A/B isolates *scheduling*, so the
    static engine gets compiled execution too (its eager per-call tracing
    overhead is not what continuous batching fixes)."""
    def fn(params, batch):
        return generate(cfg, params, batch, max_new_tokens=mnt)
    return jax.jit(fn)


def _run_static(cfg, params, reqs, arrivals) -> Dict[str, float]:
    lanes = cfg.serve.max_batch
    groups = [list(range(i, min(i + lanes, len(reqs))))
              for i in range(0, len(reqs), lanes)]
    gen = {}
    for g in groups:      # warmup: compile each (B, S_max, mnt_max) shape
        batch = _pad_group([reqs[i] for i in g])
        mnt = max(reqs[i]["max_new"] for i in g)
        gen.setdefault(mnt, _jit_generate(cfg, mnt))
        jax.block_until_ready(gen[mnt](params, batch).tokens)
    t = 0.0
    busy = 0.0
    ttft, tpot = [], []
    tokens_total = 0
    lane_steps_useful = lane_steps_total = 0
    for g in groups:
        batch = _pad_group([reqs[i] for i in g])
        mnt = max(reqs[i]["max_new"] for i in g)
        t = max(t, float(arrivals[g[-1]]))      # batch forms on last arrival
        t0 = time.perf_counter()
        res = gen[mnt](params, batch)
        jax.block_until_ready(res.tokens)
        dt = time.perf_counter() - t0
        busy += dt
        t += dt
        for i in g:
            steps = reqs[i]["max_new"]
            tokens_total += steps
            ttft.append(t - float(arrivals[i]))   # delivered at completion
            tpot.append(dt / mnt)                 # batch-amortized
            lane_steps_useful += steps
        lane_steps_total += len(g) * mnt
    return {"tokens_total": tokens_total, "busy_s": busy,
            "ttft": ttft, "tpot": tpot,
            "occupancy": lane_steps_useful / lane_steps_total}


def _run_continuous(cfg, params, reqs, arrivals, max_len: int,
                    supervise: bool = False, arm: str | None = None
                    ) -> Dict[str, float]:
    # the engine's deadline machinery runs off the same virtual clock the
    # replay advances, so request_timeout_s measures virtual (trace) time —
    # the overload rows shed load exactly as a wall-clock deployment would.
    # supervise=True routes the trace through the crash-recovering
    # supervisor; `arm` (e.g. "serve.engine_step@K") injects faults over
    # the measured loop only (warmup ticks never consume schedule hits)
    clockbox = [0.0]
    mk = SupervisedEngine if supervise else ContinuousEngine
    eng = mk(cfg, params, max_len=max_len, clock=lambda: clockbox[0])
    # warmup: one request per distinct prompt length compiles every jitted
    # shape on the trace (prefill begin/step/finish, decode, insert, evict)
    seen = set()
    for r in reqs:
        s0 = r["batch"]["tokens"].shape[1]
        if s0 not in seen:
            seen.add(s0)
            eng.submit(r["batch"], max_new_tokens=2, timeout_s=0)
    eng.run()
    t = 0.0
    busy = 0.0
    next_req = 0
    first_t: Dict[int, float] = {}
    last_t: Dict[int, float] = {}
    rid_of: Dict[int, int] = {}
    steps_of: Dict[int, int] = {}
    status_of: Dict[int, str] = {}
    tokens_of: Dict[int, np.ndarray] = {}
    lane_steps = decode_ticks = ticks = 0
    n = len(reqs)
    finished = rejected = 0
    armed = faults.inject(arm) if arm else contextlib.nullcontext()
    with armed:
        while finished + rejected < n:
            while next_req < n and arrivals[next_req] <= t:
                try:
                    rid = eng.submit(
                        reqs[next_req]["batch"],
                        max_new_tokens=reqs[next_req]["max_new"])
                    rid_of[rid] = next_req
                except QueueFullError:
                    rejected += 1   # counted in eng.stats["rejections"] too
                next_req += 1
            if eng.idle and next_req < n:
                t = float(arrivals[next_req])   # idle: jump to next arrival
                clockbox[0] = t
                continue
            t0 = time.perf_counter()
            rep = eng.step()
            dt = time.perf_counter() - t0
            busy += dt
            t += dt
            clockbox[0] = t
            ticks += 1
            # decode participation this tick, from the report: every lane
            # active at the decode step emits exactly one token unless it
            # hit eos (eos never fires on bench traces) — pre-tick `active`
            # would undercount lanes the deficit-driven prefill inserted
            # mid-tick
            if rep.decoded:
                decode_ticks += 1
                lane_steps += len(rep.decoded)
            for rid, _ in rep.first_tokens:
                if rid in rid_of:
                    first_t[rid] = last_t[rid] = t
            for rid, _ in rep.decoded:
                if rid in rid_of:
                    last_t[rid] = t
            for f in rep.finished:
                if f.rid in rid_of:
                    steps_of[f.rid] = f.steps
                    status_of[f.rid] = f.status
                    tokens_of[rid_of[f.rid]] = np.asarray(f.tokens)
                    finished += 1
    ttft = [first_t[r] - float(arrivals[rid_of[r]]) for r in first_t]
    tpot = [(last_t[r] - first_t[r]) / (steps_of[r] - 1)
            for r in first_t if steps_of.get(r, 0) > 1]
    return {"tokens_total": int(sum(steps_of.values())), "busy_s": busy,
            "ttft": ttft, "tpot": tpot, "ticks": ticks,
            "tokens_of": tokens_of,
            "occupancy": lane_steps / max(1, decode_ticks * eng.lanes),
            "completed": sum(1 for s in status_of.values() if s == "ok"),
            "stats": dict(eng.stats),
            "engine_stats": eng.engine_stats()}


def run(tiny: bool = False) -> List[Dict]:
    # full runs scale the proxy models up (d256+) so decode-step compute
    # dominates per-tick host overhead and the A/B measures *scheduling*;
    # --tiny keeps the smoke dims — it checks the path runs, not perf
    sizes = {"opt-proxy": {} if tiny else dict(
                 num_layers=6, d_model=256, num_heads=8, num_kv_heads=8,
                 d_ff=1024),
             "whisper-large-v3": dict(
                 num_layers=4, d_model=256, num_heads=8, num_kv_heads=8,
                 d_ff=1024, encoder_layers=2)}
    archs = ["opt-proxy"] if tiny else ["opt-proxy", "whisper-large-v3"]
    n = 8 if tiny else 32
    load_factor = 1.3
    rows: List[Dict] = []
    for arch in archs:
        cfg = bench_config(arch, **sizes[arch])
        cfg.serve.max_batch = 2 if tiny else 4
        cfg.serve.prefill_chunk = 4 if tiny else 8
        rng = np.random.default_rng(0)
        reqs = _make_requests(cfg, n, rng, tiny)
        max_len = max(r["batch"]["tokens"].shape[1] + r["max_new"]
                      for r in reqs) + 2
        key = jax.random.PRNGKey(0)
        params = (T.init_encdec_params(cfg.model, key)
                  if cfg.model.is_encoder_decoder
                  else T.init_params(cfg.model, key))
        weight_sets = {"fp16": params,
                       "int4": pack_for_serving(cfg, params)}
        for wname, wparams in weight_sets.items():
            # calibrate per weight set: replay the trace back-to-back
            # (all arrivals at t=0) through the continuous engine to
            # measure its saturated service time, then draw Poisson
            # arrivals at `load_factor`× that service rate — sustained
            # saturation, the regime a loaded deployment runs in: lanes
            # stay contended, so occupancy measures how full each
            # scheduler keeps them and TTFT measures admission latency
            # under backlog. Both schedulers replay the *same* trace.
            ccfg = dataclasses.replace(cfg, serve=dataclasses.replace(
                cfg.serve, scheduler="continuous"))
            sat = _run_continuous(ccfg, wparams, reqs,
                                  np.zeros(n, np.float64), max_len)
            rate = n * load_factor / sat["busy_s"]
            arrivals = _arrivals(reqs, rate, np.random.default_rng(1))
            for sched in ("static", "continuous"):
                # each engine runs in its natural configuration: static
                # prefills single-shot, continuous prefills in chunks
                scfg = dataclasses.replace(cfg, serve=dataclasses.replace(
                    cfg.serve, scheduler=sched,
                    prefill_chunk=0 if sched == "static"
                    else cfg.serve.prefill_chunk))
                if sched == "static":
                    m = _run_static(scfg, wparams, reqs, arrivals)
                else:
                    m = _run_continuous(scfg, wparams, reqs, arrivals,
                                        max_len)
                rows.append(_row(arch, wname, sched, "poisson", n, cfg,
                                 scfg, m))
            # overload: arrivals at ~3× the saturated service rate against
            # a finite per-request deadline and a bounded admission queue —
            # this trace measures load *shedding* (timeout evictions at the
            # deadline, explicit rejections at the queue bound), not raw
            # latency: a hardened engine keeps completing work while the
            # counters account for every dropped request. Continuous engine
            # only — the static engine has no admission control to measure.
            per_req = sat["busy_s"] / n
            ocfg = dataclasses.replace(cfg, serve=dataclasses.replace(
                cfg.serve, scheduler="continuous",
                request_timeout_s=per_req * (3 if tiny else 10),
                max_queue=cfg.serve.max_batch * 2))
            orate = n * 3.0 / sat["busy_s"]
            oarr = _arrivals(reqs, orate, np.random.default_rng(2))
            m = _run_continuous(ocfg, wparams, reqs, oarr, max_len)
            rows.append(_row(arch, wname, "continuous", "overload", n, cfg,
                             ocfg, m))
            # crash: the same poisson trace through the supervised engine,
            # fault-free vs a mid-trace serve.engine_step kill. Measures
            # what recovery *costs* (extra ticks to replay the in-flight
            # prefix, goodput ratio vs fault-free) and pins that it loses
            # nothing (every request completes, token-identical outputs —
            # deterministic replay, docs/SERVING.md §Crash recovery)
            kcfg = dataclasses.replace(cfg, serve=dataclasses.replace(
                cfg.serve, scheduler="continuous",
                prefill_chunk=cfg.serve.prefill_chunk, supervise=True))
            clean = _run_continuous(kcfg, wparams, reqs, arrivals, max_len,
                                    supervise=True)
            kill_tick = max(2, clean["ticks"] // 2)
            crash = _run_continuous(kcfg, wparams, reqs, arrivals, max_len,
                                    supervise=True,
                                    arm=f"serve.engine_step@{kill_tick}")
            es = crash["engine_stats"]
            ident = all(
                np.array_equal(crash["tokens_of"][i], clean["tokens_of"][i])
                for i in clean["tokens_of"])
            rows.append(_row(
                arch, wname, "continuous", "crash", n, cfg, kcfg, crash,
                restarts=es.get("restarts", 0),
                replayed_requests=es.get("replayed_requests", 0),
                recovered_completions=es.get("recovered_completions", 0),
                kill_tick=kill_tick,
                ticks_fault_free=clean["ticks"],
                ticks_to_recover=crash["ticks"] - clean["ticks"],
                goodput_ratio=round(
                    (crash["tokens_total"] / crash["busy_s"])
                    / (clean["tokens_total"] / clean["busy_s"]), 4),
                token_identical=bool(ident)))
        rows.extend(_run_longctx(arch, cfg, params, tiny, load_factor))
    return rows


def _run_longctx(arch, cfg, params, tiny: bool, load_factor: float
                 ) -> List[Dict]:
    """Equal-memory-budget long-context trace: fp16 vs int8 decode cache,
    each with the lane count its per-sequence footprint affords (module
    docstring). fp16 weights on both sides so the A/B isolates the cache."""
    mc = cfg.model
    rng = np.random.default_rng(3)
    plens = (8, 12) if tiny else (24, 40, 56)
    mnews = (2, 4) if tiny else (4, 8, 12)
    n = 6 if tiny else 16
    reqs = []
    for _ in range(n):
        s0 = int(rng.choice(plens))
        toks = rng.integers(1, mc.vocab_size, size=(1, s0)).astype(np.int32)
        b = {"tokens": jnp.asarray(toks)}
        if mc.is_encoder_decoder:
            b["frames"] = jnp.asarray(rng.standard_normal(
                (1, mc.encoder_seq_len, mc.d_model)).astype(np.float32))
        reqs.append({"batch": b, "max_new": int(rng.choice(mnews))})
    max_len = max(r["batch"]["tokens"].shape[1] + r["max_new"]
                  for r in reqs) + 2
    bytes_fp16 = cache_bytes_per_seq(mc, max_len, jnp.bfloat16)
    bytes_int8 = cache_bytes_per_seq(mc, max_len, "int8")
    budget = cfg.serve.max_batch * bytes_fp16
    lanes_of = {"fp16": cfg.serve.max_batch,
                "int8": max(cfg.serve.max_batch, budget // bytes_int8)}
    bytes_of = {"fp16": bytes_fp16, "int8": bytes_int8}
    # one arrival process for both precisions, calibrated on the fp16 side
    fcfg = dataclasses.replace(cfg, serve=dataclasses.replace(
        cfg.serve, scheduler="continuous", kv_cache="fp16"))
    sat = _run_continuous(fcfg, params, reqs, np.zeros(n, np.float64),
                          max_len)
    arrivals = _arrivals(reqs, n * load_factor / sat["busy_s"],
                         np.random.default_rng(4))
    rows = []
    for kvc in ("fp16", "int8"):
        kcfg = dataclasses.replace(cfg, serve=dataclasses.replace(
            cfg.serve, scheduler="continuous", kv_cache=kvc,
            max_batch=int(lanes_of[kvc])))
        m = _run_continuous(kcfg, params, reqs, arrivals, max_len)
        rows.append(_row(arch, "fp16", "continuous", "longctx", n, kcfg,
                         kcfg, m,
                         cache_bytes_per_seq=int(bytes_of[kvc]),
                         cache_budget_bytes=int(budget)))
    return rows


def _row(arch, wname, sched, trace, n, cfg, scfg, m, **extra) -> Dict:
    tt, tp = _pct(m["ttft"]), _pct(m["tpot"])
    stats = m.get("stats", {})
    return {
        "config": arch, "weights": wname, "scheduler": sched,
        "trace": trace, "kv_cache": scfg.serve.kv_cache,
        "n_requests": n, "lanes": cfg.serve.max_batch,
        "prefill_chunk": scfg.serve.prefill_chunk,
        "tokens_total": m["tokens_total"],
        "tokens_per_s": round(m["tokens_total"] / m["busy_s"], 2),
        "ttft_mean_s": round(float(np.mean(m["ttft"])), 4),
        "ttft_p50_s": round(tt["p50"], 4),
        "ttft_p95_s": round(tt["p95"], 4),
        "ttft_p99_s": round(tt["p99"], 4),
        "tpot_p50_s": round(tp["p50"], 5),
        "tpot_p95_s": round(tp["p95"], 5),
        "tpot_p99_s": round(tp["p99"], 5),
        "occupancy": round(m["occupancy"], 4),
        "busy_s": round(m["busy_s"], 3),
        # shedding counters: 0 on poisson traces (deadline/queue unarmed);
        # the static engine has neither, so its row reports n completed
        "completed": m.get("completed", n),
        "timeout_evictions": stats.get("timeout_evictions", 0),
        "rejections": stats.get("rejections", 0),
        **extra,
    }
