"""Paper Table 5 / Fig. 5: Γ^(t) convergence trajectories + early stop.

Per-layer output-residual loss across RPIQ stage-2 iterations, for both
curvature modes and an α sweep — reproducing the paper's claims that (a)
most reduction lands in iterations 1-2, (b) early stopping fires before
T_max on some layers, and documenting the α/mode stability boundary the
paper leaves implicit (EXPERIMENTS.md discusses)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import bench_config, make_calib, train_lm
from repro.core.pipeline import quantize_model


def run(steps: int = 60, tiny: bool = False) -> list:
    """``tiny`` (scripts/check.sh smoke leg) shrinks to a barely trained
    model and one cell per curvature mode — it exercises the stage-2
    convergence path end to end, not the α sweep."""
    if tiny:
        steps = min(steps, 15)
    cfg0 = bench_config("opt-proxy")
    params, lm, _ = train_lm(cfg0, steps=steps, mix_sentiment=False)
    calib = make_calib(cfg0, lm)

    cells = ((("global-h", 0.01), ("exact-gram", 1.0)) if tiny else
             (("global-h", 0.01), ("global-h", 0.1),
              ("exact-gram", 0.25), ("exact-gram", 1.0)))
    rows = []
    for mode, alpha in cells:
        cfg = bench_config("opt-proxy")
        cfg.quant.rpiq_use_global_hessian = mode == "global-h"
        cfg.quant.rpiq_alpha = alpha
        cfg.quant.rpiq_iters = 5
        _, rep = quantize_model(cfg, params, calib)
        rpiq = [l for l in rep.linears if l.mode == "rpiq"]
        early = sum(1 for l in rpiq if l.iters < 5)
        red = [100 * (1 - l.gamma_final / l.gamma[0])
               for l in rpiq if l.gamma and l.gamma[0] > 0]
        # representative trajectory (first mlp.down-style layer)
        traj = next((l.gamma for l in rpiq if "down" in l.name), [])
        rows.append({
            "table": "table5", "mode": mode, "alpha": alpha,
            "layers": len(rpiq),
            "early_stopped": early,
            "proj_gamma_reduction_pct_mean": round(float(np.mean(red)), 2),
            "proj_gamma_reduction_pct_max": round(float(np.max(red)), 2),
            "example_gamma_traj": [round(g, 3) for g in traj[:6]],
        })
    return rows
