"""Benchmark driver: one module per paper table + the roofline summary.

    PYTHONPATH=src python -m benchmarks.run [table1 table2 ...] [--tiny]

Writes artifacts/bench/<table>.json and prints a flat CSV-ish summary.
``--tiny`` shrinks table4 to a CI smoke (single config, fewer repeats) and
table5 to one cell per curvature mode (the stage-2 convergence-path smoke)
— scripts/check.sh runs both. A FULL table4 run additionally rewrites the
stable machine-trackable ``BENCH_table4.json`` at the repo root — flat rows
of ``{config, impl, cold_s, warm_s, executor_s, stage1_s, stage2_s,
xla_ops, xla_ops_s2}`` so the perf trajectory (per-linear → batched-xla →
batched-pallas, per stage) is diffable across PRs; docs/BENCHMARKS.md
documents the schema, the regeneration contract, and why interpret-mode
pallas wall-times must not be read as perf. Set REPRO_BENCH_STEPS to raise
the training budget (default keeps the whole suite a few CPU-minutes)."""
from __future__ import annotations

import json
import os
import sys
import time


def main(argv=None) -> None:
    argv = list(sys.argv[1:] if argv is None else argv)
    tiny = "--tiny" in argv
    argv = [a for a in argv if a != "--tiny"]
    steps = int(os.environ.get("REPRO_BENCH_STEPS", "100"))

    from benchmarks import (table1_lm_quality, table2_vlm_overfit,
                            table3_memory, table4_time, table5_convergence,
                            roofline, serving_bench)
    suites = {
        "table1": lambda: table1_lm_quality.run(steps=steps),
        "table2": lambda: table2_vlm_overfit.run(steps=max(40, steps // 2)),
        "table3": table3_memory.run,
        "table4": lambda: table4_time.run(tiny=tiny),
        "table5": lambda: table5_convergence.run(steps=max(40, steps // 2),
                                                 tiny=tiny),
        "roofline": roofline.run,
        "serving": lambda: serving_bench.run(tiny=tiny),
    }
    wanted = argv or list(suites)
    os.makedirs("artifacts/bench", exist_ok=True)
    all_rows = []
    for name in wanted:
        t0 = time.perf_counter()
        print(f"== {name} ==", flush=True)
        rows = suites[name]()
        dt = time.perf_counter() - t0
        with open(f"artifacts/bench/{name}.json", "w") as f:
            json.dump(rows, f, indent=1)
        if name == "table4" and not tiny:
            # --tiny is a smoke run (single config, no MoE row) — don't let
            # it clobber the full cross-PR trajectory at the repo root
            flat = [b for r in rows for b in r.get("bench", [])]
            with open("BENCH_table4.json", "w") as f:
                json.dump(flat, f, indent=1)
            print(f"  wrote BENCH_table4.json ({len(flat)} impl rows)")
        if name == "serving" and not tiny:
            with open("BENCH_serving.json", "w") as f:
                json.dump(rows, f, indent=1)
            print(f"  wrote BENCH_serving.json ({len(rows)} rows)")
        for r in rows:
            print("  " + ",".join(f"{k}={v}" for k, v in r.items()
                                  if k != "bench"))
        print(f"  ({dt:.1f}s)")
        all_rows.extend(rows)
    print(f"\nwrote {len(all_rows)} rows to artifacts/bench/")


if __name__ == "__main__":
    main()
