"""Benchmark driver: one module per paper table + the roofline summary.

    PYTHONPATH=src python -m benchmarks.run [table1 table2 ...]

Writes artifacts/bench/<table>.json and prints a flat CSV-ish summary.
Set REPRO_BENCH_STEPS to raise the training budget (default keeps the whole
suite a few CPU-minutes)."""
from __future__ import annotations

import json
import os
import sys
import time


def main(argv=None) -> None:
    argv = list(sys.argv[1:] if argv is None else argv)
    steps = int(os.environ.get("REPRO_BENCH_STEPS", "100"))

    from benchmarks import (table1_lm_quality, table2_vlm_overfit,
                            table3_memory, table4_time, table5_convergence,
                            roofline)
    suites = {
        "table1": lambda: table1_lm_quality.run(steps=steps),
        "table2": lambda: table2_vlm_overfit.run(steps=max(40, steps // 2)),
        "table3": table3_memory.run,
        "table4": table4_time.run,
        "table5": lambda: table5_convergence.run(steps=max(40, steps // 2)),
        "roofline": roofline.run,
    }
    wanted = argv or list(suites)
    os.makedirs("artifacts/bench", exist_ok=True)
    all_rows = []
    for name in wanted:
        t0 = time.perf_counter()
        print(f"== {name} ==", flush=True)
        rows = suites[name]()
        dt = time.perf_counter() - t0
        with open(f"artifacts/bench/{name}.json", "w") as f:
            json.dump(rows, f, indent=1)
        for r in rows:
            print("  " + ",".join(f"{k}={v}" for k, v in r.items()))
        print(f"  ({dt:.1f}s)")
        all_rows.extend(rows)
    print(f"\nwrote {len(all_rows)} rows to artifacts/bench/")


if __name__ == "__main__":
    main()
