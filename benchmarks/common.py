"""Shared benchmark helpers: train a small LM, evaluate PPL/accuracy."""
from __future__ import annotations

import os
import time
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import Config
from repro.configs import get_config
from repro.data import MarkovLM, SentimentTask, calibration_batches
from repro.models import attention as A
from repro.models import transformer as T
from repro.training.train_step import init_train_state, make_train_step


def bench_config(arch: str = "opt-proxy", **model_over) -> Config:
    cfg = get_config(arch, smoke=True)
    for k, v in model_over.items():
        setattr(cfg.model, k, v)
    cfg.model.__post_init__()
    # CI smoke hook (scripts/check.sh): force the layer-walk schedule for
    # every benchmarked quantize_model run, e.g. REPRO_BENCH_PIPELINE=overlap
    pl = os.environ.get("REPRO_BENCH_PIPELINE")
    if pl:
        cfg.quant.pipeline = pl
    return cfg


def train_lm(cfg: Config, steps: int = 80, lr: float = 3e-3,
             batch: int = 8, seq: int = 32, seed: int = 0,
             mix_sentiment: bool = True):
    """Train on the Markov stream (+ sentiment batches so the downstream
    task is in-distribution, like the paper's instruction-tuned models)."""
    cfg.train.lr = lr
    cfg.train.warmup_steps = max(2, steps // 10)
    cfg.train.steps = steps
    st = init_train_state(cfg, jax.random.PRNGKey(seed))
    step = jax.jit(make_train_step(cfg))
    lm = MarkovLM(cfg.model.vocab_size, seed=seed, branching=3)
    sent = SentimentTask(cfg.model.vocab_size, seed=seed)
    for i in range(steps):
        if mix_sentiment and i % 3 == 2:
            b, _ = sent.batch(batch, seq)
        else:
            b = lm.batch(batch, seq)
        st, m = step(st, b)
    return st.params, lm, sent


def eval_ppl(cfg: Config, params, lm: MarkovLM, n: int = 4, batch: int = 8,
             seq: int = 32) -> float:
    lm_eval = MarkovLM(cfg.model.vocab_size, seed=lm.seed, branching=3)
    lm_eval.step = 50_000
    tot, cnt = 0.0, 0
    for _ in range(n):
        toks = lm_eval.batch(batch, seq)["tokens"]
        logits, _ = T.forward(cfg.model, params, toks)
        logz = jax.nn.logsumexp(logits[:, :-1], axis=-1)
        gold = jnp.take_along_axis(logits[:, :-1], toks[:, 1:, None],
                                   axis=-1)[..., 0]
        tot += float(jnp.sum(logz - gold))
        cnt += int(toks[:, 1:].size)
    return float(np.exp(tot / cnt))


def eval_sentiment(cfg: Config, params, sent: SentimentTask,
                   n: int = 128, seq: int = 24) -> float:
    ev = SentimentTask(cfg.model.vocab_size, seed=sent.seed)
    ev.step = 50_000
    batch, labels = ev.batch(n, seq)
    logits, _ = T.forward(cfg.model, params, batch["tokens"])
    return ev.accuracy(logits[:, -2], labels)


def make_calib(cfg: Config, lm: MarkovLM, n_batches: int = 4,
               batch: int = 8, seq: int = 32):
    src = MarkovLM(cfg.model.vocab_size, seed=lm.seed, branching=3)
    return calibration_batches(src, n_batches, batch, seq)


def param_bytes(params) -> int:
    return sum(l.size * l.dtype.itemsize
               for l in jax.tree_util.tree_leaves(params)
               if hasattr(l, "dtype"))


def _tree_bytes(tree) -> int:
    return sum(int(np.prod(l.shape)) * jnp.dtype(l.dtype).itemsize
               for l in jax.tree_util.tree_leaves(tree))


def cache_bytes_per_seq(mc, max_len: int, cache_dtype) -> int:
    """Decode-cache bytes for ONE sequence at capacity ``max_len``, measured
    via ``jax.eval_shape`` over the real cache constructors — the same
    layouts the engines allocate, nothing materialized. ``cache_dtype`` is a
    jnp dtype or the ``"int8"`` sentinel; for int8 the codes, per-block
    scales and error-feedback accumulators are all counted, and leaves the
    sentinel keeps in float (MLA latents, recurrent states, enc-dec
    cross-KV) are counted at their actual precision."""
    if mc.is_encoder_decoder:
        self_b = _tree_bytes(jax.eval_shape(
            lambda: A.init_kv_cache(mc, 1, max_len, cache_dtype)))
        cross_dtype = jnp.dtype(T._float_cache_dtype(cache_dtype))
        cross_b = 2 * mc.encoder_seq_len * mc.num_kv_heads * mc.head_dim \
            * cross_dtype.itemsize
        return mc.num_layers * (self_b + cross_b)
    return _tree_bytes(jax.eval_shape(
        lambda: T.init_block_caches(mc, 1, max_len, cache_dtype)))
