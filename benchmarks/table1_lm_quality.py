"""Paper Table 1: LM quality — fp32 vs GPTQ(4-bit) vs RPIQ(4-bit).

Trains the opt-proxy LM (the paper's OPT family at CPU scale) on the
synthetic corpus + sentiment task, quantizes with both methods, and reports
perplexity + 3-way classification accuracy + weight bytes, mirroring the
paper's Acc/PPL/Mem columns.
"""
from __future__ import annotations

import jax

from benchmarks.common import (bench_config, eval_ppl, eval_sentiment,
                               make_calib, param_bytes, train_lm)
from repro.core.pipeline import pack_for_serving, quantize_model


def run(steps: int = 120) -> list:
    cfg = bench_config("opt-proxy")
    params, lm, sent = train_lm(cfg, steps=steps)
    calib = make_calib(cfg, lm)

    rows = []

    def add(name, p, seconds=0.0):
        rows.append({
            "table": "table1", "method": name,
            "ppl": round(eval_ppl(cfg, p, lm), 4),
            "acc": round(eval_sentiment(cfg, p, sent), 4),
            "weight_bytes": param_bytes(pack_for_serving(cfg, p))
            if name != "fp32" else param_bytes(p),
            "quant_seconds": round(seconds, 2),
        })

    add("fp32", params)

    cfg_g = bench_config("opt-proxy")
    cfg_g.quant.rpiq_iters = 0
    pq_g, rep_g = quantize_model(cfg_g, params, calib)
    add("gptq-4bit", pq_g, rep_g.seconds_total)

    # paper-faithful RPIQ (global-H, alpha=0.01, 5 iters)
    cfg_r = bench_config("opt-proxy")
    pq_r, rep_r = quantize_model(cfg_r, params, calib)
    add("rpiq-4bit(paper)", pq_r, rep_r.seconds_total)

    # beyond-paper RPIQ (eq.6 exact-gram, alpha=0.3)
    cfg_b = bench_config("opt-proxy")
    cfg_b.quant.rpiq_use_global_hessian = False
    cfg_b.quant.rpiq_alpha = 0.3
    cfg_b.quant.rpiq_iters = 6
    pq_b, rep_b = quantize_model(cfg_b, params, calib)
    add("rpiq-4bit(exact-gram)", pq_b, rep_b.seconds_total)
    return rows
