"""Paper Table 3 + eq. 15-16: peak calibration residency.

Single-instance calibration keeps O(‖X_last‖ + ‖H‖) resident during stage 2
vs O(‖[X^(1..k)]‖) for all-batch schemes. Measured as actual resident array
bytes for the pipeline's stage-2 inputs across calibration-set sizes, plus
the deployment memory claim (paper abstract: 60-75% reduction): bf16 vs
int4-packed weight bytes per arch.

``table3-kv`` rows measure decode-cache bytes per sequence (eval_shape over
the real cache constructors, benchmarks/common.cache_bytes_per_seq): fp32 /
fp16 / int8 per arch per context length, with both reduction ratios. The
int8 layout pays per-block f32 scales + error-feedback accumulators on top
of the 1-byte codes, so the honest ceiling vs fp16 is < 2×; the ≥3.5×
reduction lands on the fp32 column. Architectures whose caches the sentinel
keeps in float (MLA latents, recurrent states) report ratios near 1 — that
is the measured truth, not a bug.
"""
from __future__ import annotations

import jax

from benchmarks.common import bench_config, cache_bytes_per_seq, param_bytes
from repro.configs import ARCH_IDS, get_config
from repro.launch.hlo_analysis import total_param_count


def run() -> list:
    rows = []
    cfg = bench_config("opt-proxy")
    mc = cfg.model
    d = mc.d_model
    seq, bs = 128, 8
    x_batch = bs * seq * d * 4                      # one batch of layer X
    h_bytes = d * d * 4
    for k in (4, 16, 64, 128):
        rows.append({
            "table": "table3", "calib_batches": k,
            "single_instance_bytes": x_batch + h_bytes,
            "all_batches_bytes": k * x_batch + h_bytes,
            "ratio": round((k * x_batch + h_bytes)
                           / (x_batch + h_bytes), 2),
        })

    # deployment memory: the paper's 60-75% claim, per assigned arch
    for arch in ARCH_IDS:
        mc = get_config(arch).model
        n = total_param_count(mc)
        bf16 = 2.0 * n
        # int4 + per-128-group f32 scale+zero on quantized linears (~97% of
        # params); embeddings/norms stay bf16 (~vocab*d)
        emb = mc.vocab_size * mc.d_model * (1 if mc.tie_embeddings else 2)
        lin = max(n - emb, 0)
        int4 = 0.5 * lin + (8.0 / 128.0) * lin + 2.0 * emb
        rows.append({
            "table": "table3-deploy", "arch": arch,
            "params_B": round(n / 1e9, 3),
            "bf16_GB": round(bf16 / 2**30, 2),
            "int4_GB": round(int4 / 2**30, 2),
            "reduction_pct": round(100 * (1 - int4 / bf16), 1),
        })

    # decode-cache residency: bytes per sequence at each context length,
    # per cache precision (serve.kv_cache knob; docs/SERVING.md)
    import jax.numpy as jnp
    for arch in ARCH_IDS:
        mc = get_config(arch).model
        for ctx in (512, 2048, 8192):
            if ctx > mc.max_seq_len:
                continue
            fp32 = cache_bytes_per_seq(mc, ctx, jnp.float32)
            fp16 = cache_bytes_per_seq(mc, ctx, jnp.float16)
            int8 = cache_bytes_per_seq(mc, ctx, "int8")
            rows.append({
                "table": "table3-kv", "arch": arch, "ctx": ctx,
                "fp32_bytes_per_seq": fp32,
                "fp16_bytes_per_seq": fp16,
                "int8_bytes_per_seq": int8,
                "int8_bytes_per_token": round(int8 / ctx, 1),
                "ratio_vs_fp32": round(fp32 / int8, 2),
                "ratio_vs_fp16": round(fp16 / int8, 2),
            })
    return rows
