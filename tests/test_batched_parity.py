"""Batched quant-plan executors vs singleton reference.

The whole point of the QuantPlan refactor is that grouping same-shape
linears into one vmapped dispatch changes NOTHING numerically: every test
here pins the batched entry points against mapping the single-linear
functions over the stack, including the MoE starved-expert RTN mask and
the full pipeline on an MoE model.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import hessian as hess
from repro.core import plan as qplan
from repro.core.gptq import (gptq_quantize, gptq_quantize_batched,
                             rtn_quantize, rtn_quantize_batched)
from repro.core.rpiq import rpiq_refine, rpiq_refine_batched


@pytest.fixture(scope="module")
def stack_problem():
    """B same-shape linears with correlated inputs + accumulated Hessians."""
    B, Cout, Cin, N = 4, 48, 128, 256
    ks = jax.random.split(jax.random.PRNGKey(7), 3 * B)
    Ws, Xs, sts = [], [], []
    for i in range(B):
        W = jax.random.normal(ks[i], (Cout, Cin)) * 0.1
        A = jax.random.normal(ks[B + i], (Cin, Cin)) * 0.2 + jnp.eye(Cin)
        X = jax.random.normal(ks[2 * B + i], (N, Cin)) @ A
        st = hess.init_hessian(Cin)
        for b in range(2):
            st = hess.accumulate(st, X[b * 128:(b + 1) * 128])
        Ws.append(W)
        Xs.append(X[-128:])
        sts.append(st)
    return dict(W=jnp.stack(Ws), X=jnp.stack(Xs), sts=sts,
                st=hess.stack_states(sts), B=B, N=128)


class TestStackedHessian:
    def test_stacked_accumulate_matches_singleton(self):
        e, n, d = 3, 64, 32
        x = jax.random.normal(jax.random.PRNGKey(0), (e, n, d))
        st = hess.accumulate(hess.init_hessian(d, batch=e), x)
        for i in range(e):
            ref = hess.accumulate(hess.init_hessian(d), x[i])
            np.testing.assert_allclose(np.asarray(st.H[i]),
                                       np.asarray(ref.H), rtol=1e-5,
                                       atol=1e-4)
        assert st.count.shape == (e,) and int(st.count[0]) == n

    def test_stacked_damped_and_cholesky(self, stack_problem):
        p = stack_problem
        Hd = hess.damped(p["st"], 0.01)
        U = hess.cholesky_inverse_upper(Hd)
        for i, st_i in enumerate(p["sts"]):
            Hd_i = hess.damped(st_i, 0.01)
            np.testing.assert_allclose(np.asarray(Hd[i]), np.asarray(Hd_i),
                                       rtol=1e-6, atol=1e-6)
            np.testing.assert_allclose(
                np.asarray(U[i]),
                np.asarray(hess.cholesky_inverse_upper(Hd_i)),
                rtol=1e-4, atol=1e-5)

    def test_stacked_damped_dead_column_rescue(self):
        e, d = 2, 16
        x = jnp.zeros((e, 32, d)).at[:, :, :8].set(
            jax.random.normal(jax.random.PRNGKey(1), (e, 32, 8)))
        st = hess.accumulate(hess.init_hessian(d, batch=e), x)
        Hd = hess.damped(st, 0.01)
        assert np.linalg.eigvalsh(np.asarray(Hd)).min() > 0


class TestBatchedGPTQ:
    def test_matches_singleton_stack(self, stack_problem):
        p = stack_problem
        Hd = hess.damped(p["st"], 0.01)
        U = hess.cholesky_inverse_upper(Hd)
        res_b = gptq_quantize_batched(p["W"], U, bits=4, group_size=32,
                                      blocksize=64)
        for i in range(p["B"]):
            Hd_i = hess.damped(p["sts"][i], 0.01)
            r = gptq_quantize(p["W"][i], hess.cholesky_inverse_upper(Hd_i),
                              bits=4, group_size=32, blocksize=64)
            np.testing.assert_allclose(np.asarray(res_b.w_q[i]),
                                       np.asarray(r.w_q), atol=1e-5)
            np.testing.assert_allclose(np.asarray(res_b.scales[i]),
                                       np.asarray(r.scales), atol=1e-6)
            np.testing.assert_allclose(np.asarray(res_b.zeros[i]),
                                       np.asarray(r.zeros), atol=1e-6)
            np.testing.assert_allclose(float(res_b.err[i]), float(r.err),
                                       rtol=1e-3, atol=1e-4)

    def test_rtn_batched_matches_singleton(self, stack_problem):
        p = stack_problem
        res_b = rtn_quantize_batched(p["W"], bits=4, group_size=32)
        for i in range(p["B"]):
            r = rtn_quantize(p["W"][i], bits=4, group_size=32)
            np.testing.assert_array_equal(np.asarray(res_b.w_q[i]),
                                          np.asarray(r.w_q))


class TestBatchedRPIQ:
    def _stage1(self, p):
        Hd = hess.damped(p["st"], 0.01)
        return Hd, gptq_quantize_batched(p["W"], hess.cholesky_inverse_upper(
            Hd), bits=4, group_size=32, blocksize=64)

    def test_matches_singleton_stack(self, stack_problem):
        p = stack_problem
        Hd, res1 = self._stage1(p)
        xc = jnp.full((p["B"],), p["N"], jnp.int32)
        res2 = rpiq_refine_batched(res1.w_q, p["W"], p["X"], Hd, res1.scales,
                                   res1.zeros, h_count=p["st"].count,
                                   x_count=xc, bits=4, group_size=32,
                                   block_size=64, alpha=0.25, t_max=4)
        for i in range(p["B"]):
            r = rpiq_refine(res1.w_q[i], p["W"][i], p["X"][i], Hd[i],
                            res1.scales[i], res1.zeros[i],
                            h_count=p["sts"][i].count,
                            x_count=jnp.asarray(p["N"], jnp.int32),
                            bits=4, group_size=32, block_size=64,
                            alpha=0.25, t_max=4)
            np.testing.assert_allclose(np.asarray(res2.w_q[i]),
                                       np.asarray(r.w_q), atol=1e-5)
            np.testing.assert_allclose(float(res2.proj_loss[i]),
                                       float(r.proj_loss), rtol=1e-3)
            assert int(res2.iters_run[i]) == int(r.iters_run)

    def test_no_count_rescale_path(self, stack_problem):
        """h_count=None / x_count=None lanes (in_axes=None broadcast)."""
        p = stack_problem
        Hd, res1 = self._stage1(p)
        res2 = rpiq_refine_batched(res1.w_q, p["W"], p["X"], Hd, res1.scales,
                                   res1.zeros, bits=4, group_size=32,
                                   block_size=64, alpha=0.01, t_max=2)
        r = rpiq_refine(res1.w_q[0], p["W"][0], p["X"][0], Hd[0],
                        res1.scales[0], res1.zeros[0], bits=4, group_size=32,
                        block_size=64, alpha=0.01, t_max=2)
        np.testing.assert_allclose(np.asarray(res2.w_q[0]),
                                   np.asarray(r.w_q), atol=1e-5)

    def test_exact_gram_mode(self, stack_problem):
        p = stack_problem
        Hd, res1 = self._stage1(p)
        res2 = rpiq_refine_batched(res1.w_q, p["W"], p["X"], Hd, res1.scales,
                                   res1.zeros, bits=4, group_size=32,
                                   block_size=64, alpha=1.0, t_max=3,
                                   exact_gram=True)
        assert bool(jnp.all(res2.proj_loss <= res2.loss_history[:, 0] + 1e-4))

    @pytest.mark.pallas
    @pytest.mark.parametrize("exact_gram,alpha", [(False, 0.1), (True, 1.0)])
    def test_exact_gram_iters_parity_across_impls(self, stack_problem,
                                                  exact_gram, alpha):
        """iters_run (early-stop round count) must agree lane for lane
        between the singleton path, the batched XLA body, and the fused
        kernel — in both curvature modes."""
        p = stack_problem
        Hd, res1 = self._stage1(p)
        xc = jnp.full((p["B"],), p["N"], jnp.int32)
        kw = dict(bits=4, group_size=32, block_size=64, alpha=alpha,
                  t_max=5, exact_gram=exact_gram)
        res_b = rpiq_refine_batched(res1.w_q, p["W"], p["X"], Hd,
                                    res1.scales, res1.zeros,
                                    h_count=p["st"].count, x_count=xc,
                                    impl="xla", **kw)
        res_k = rpiq_refine_batched(res1.w_q, p["W"], p["X"], Hd,
                                    res1.scales, res1.zeros,
                                    h_count=p["st"].count, x_count=xc,
                                    impl="pallas", **kw)
        np.testing.assert_array_equal(np.asarray(res_b.iters_run),
                                      np.asarray(res_k.iters_run))
        np.testing.assert_allclose(np.asarray(res_b.w_q),
                                   np.asarray(res_k.w_q), atol=1e-6)
        for i in range(p["B"]):
            r = rpiq_refine(res1.w_q[i], p["W"][i], p["X"][i], Hd[i],
                            res1.scales[i], res1.zeros[i],
                            h_count=p["st"].count[i], x_count=xc[i], **kw)
            assert int(r.iters_run) == int(res_b.iters_run[i])
            np.testing.assert_allclose(np.asarray(res_b.w_q[i]),
                                       np.asarray(r.w_q), atol=1e-5)


class TestPlanExecution:
    def _members(self, p, starve=()):
        ms = []
        for i in range(p["B"]):
            ms.append(qplan.PlanMember(
                f"lin{i}", p["W"][i], p["sts"][i], p["X"][i],
                x_count=None, starved=i in starve))
        return ms

    def _qc(self):
        from repro.config import QuantConfig
        return QuantConfig(group_size=32, blocksize=64, rpiq_iters=3,
                           rpiq_alpha=0.25)

    def test_grouping(self, stack_problem):
        p = stack_problem
        qc = self._qc()
        ms = self._members(p)
        # a second shape class → its own group; unaligned → fallback
        odd_w = jax.random.normal(jax.random.PRNGKey(9), (8, 64)) * 0.1
        odd_x = jax.random.normal(jax.random.PRNGKey(10), (32, 64))
        ms.append(qplan.PlanMember(
            "odd", odd_w, hess.accumulate(hess.init_hessian(64), odd_x),
            odd_x, x_count=None))
        bad_w = jax.random.normal(jax.random.PRNGKey(11), (8, 72)) * 0.1
        bad_x = jax.random.normal(jax.random.PRNGKey(12), (32, 72))
        ms.append(qplan.PlanMember(
            "unaligned", bad_w, hess.accumulate(hess.init_hessian(72),
                                                bad_x), bad_x, x_count=None))
        plan = qplan.build_plan(qc, ms)
        sizes = sorted(len(g.members) for g in plan.groups)
        assert sizes == [1, p["B"]]
        assert [m.name for m in plan.fallbacks] == ["unaligned"]
        assert plan.n_members == len(ms)

    def test_batched_matches_singleton_execution(self, stack_problem):
        """Same plan through both executors → same weights, grids, modes —
        including the starved-member RTN mask."""
        p = stack_problem
        qc = self._qc()
        rep_b, rep_s = qplan.QuantReport(), qplan.QuantReport()
        plan_b = qplan.build_plan(qc, self._members(p, starve=(2,)))
        plan_s = qplan.build_plan(qc, self._members(p, starve=(2,)))
        out_b = qplan.execute_plan(qc, plan_b, rep_b, batched=True)
        out_s = qplan.execute_plan(qc, plan_s, rep_s, batched=False)
        assert out_b.keys() == out_s.keys()
        for name in out_b:
            np.testing.assert_allclose(np.asarray(out_b[name].w_q),
                                       np.asarray(out_s[name].w_q),
                                       atol=2e-5)
            np.testing.assert_allclose(np.asarray(out_b[name].grid[0]),
                                       np.asarray(out_s[name].grid[0]),
                                       atol=1e-6)
        modes_b = {l.name: l.mode for l in rep_b.linears}
        modes_s = {l.name: l.mode for l in rep_s.linears}
        assert modes_b == modes_s
        assert modes_b["lin2"] == "rtn-fallback"
        assert rep_b.seconds_stage1 > 0 and rep_b.seconds_stage2 > 0

    def test_zero_token_starved_lane(self, stack_problem):
        """A starved member with ZERO routed tokens (H = 0, x_count = 0)
        must not poison the group: outputs stay finite, modes match the
        singleton path, and the lane's early stop fires instead of
        pinning the vmapped while_loop at t_max."""
        p = stack_problem
        qc = self._qc()
        in_dim = p["W"].shape[2]
        dead = qplan.PlanMember(
            "dead", jnp.zeros_like(p["W"][0]) + 0.05 * p["W"][0],
            hess.init_hessian(in_dim), jnp.zeros_like(p["X"][0]),
            x_count=jnp.zeros((), jnp.int32), starved=True)
        outs = {}
        for batched in (True, False):
            rep = qplan.QuantReport()
            plan = qplan.build_plan(qc, self._members(p) + [dead])
            outs[batched] = qplan.execute_plan(qc, plan, rep,
                                               batched=batched)
            assert {l.name: l.mode for l in rep.linears}["dead"] \
                == "rtn-fallback"
        for name in outs[True]:
            w_b, w_s = outs[True][name].w_q, outs[False][name].w_q
            assert not bool(jnp.any(jnp.isnan(w_b)))
            np.testing.assert_allclose(np.asarray(w_b), np.asarray(w_s),
                                       atol=2e-5)

    def test_fallback_starved_group_aligned_keeps_grid(self, stack_problem):
        """group_size-aligned but blocksize-unaligned starved expert still
        gets per-group RTN (legacy semantics), with a stored grid."""
        qc = self._qc()                  # group 32, blocksize 64
        w = jax.random.normal(jax.random.PRNGKey(3), (8, 96)) * 0.1
        x = jnp.zeros((16, 96))
        m = qplan.PlanMember("starved96", w, hess.init_hessian(96), x,
                             x_count=jnp.zeros((), jnp.int32), starved=True)
        plan = qplan.build_plan(qc, [m])
        assert plan.groups == [] and len(plan.fallbacks) == 1
        rep = qplan.QuantReport()
        out = qplan.execute_plan(qc, plan, rep)["starved96"]
        assert out.grid is not None and out.grid[0].shape == (8, 3)
        from repro.core.gptq import rtn_quantize
        ref = rtn_quantize(w, bits=qc.bits, group_size=32)
        np.testing.assert_array_equal(np.asarray(out.w_q),
                                      np.asarray(ref.w_q))

    def test_stacked_member_matches_singletons(self, stack_problem):
        """A pre-stacked member (the MoE expert slab) must produce the
        same lanes as submitting its slices as singleton members."""
        p = stack_problem
        qc = self._qc()
        S = p["B"]
        stacked = qplan.PlanMember(
            "slab", p["W"], p["st"], p["X"],
            x_count=jnp.full((S,), p["N"], jnp.int32),
            starved=np.array([False, False, True, False]),
            names=[f"slab[{i}]" for i in range(S)])
        singles = [qplan.PlanMember(
            f"slab[{i}]", p["W"][i], p["sts"][i], p["X"][i],
            x_count=jnp.asarray(p["N"], jnp.int32), starved=(i == 2))
            for i in range(S)]
        rep_a, rep_b = qplan.QuantReport(), qplan.QuantReport()
        out_a = qplan.execute_plan(qc, qplan.build_plan(qc, [stacked]),
                                   rep_a, batched=True)
        out_b = qplan.execute_plan(qc, qplan.build_plan(qc, singles),
                                   rep_b, batched=True)
        assert out_a["slab"].w_q.shape == (S, *p["W"].shape[1:])
        for i in range(S):
            np.testing.assert_allclose(
                np.asarray(out_a["slab"].w_q[i]),
                np.asarray(out_b[f"slab[{i}]"].w_q), atol=2e-5)
        assert {l.name: l.mode for l in rep_a.linears} \
            == {l.name: l.mode for l in rep_b.linears}
        # singleton executor over the stacked member agrees too
        rep_c = qplan.QuantReport()
        out_c = qplan.execute_plan(qc, qplan.build_plan(qc, [stacked]),
                                   rep_c, batched=False)
        np.testing.assert_allclose(np.asarray(out_a["slab"].w_q),
                                   np.asarray(out_c["slab"].w_q), atol=2e-5)

    def test_stacked_fallback_mixed_lanes(self):
        """Unaligned stacked member: starved lanes RTN, others keep fp."""
        qc = self._qc()                  # group 32, blocksize 64
        w = jax.random.normal(jax.random.PRNGKey(5), (2, 8, 96)) * 0.1
        x = jnp.zeros((2, 16, 96))
        m = qplan.PlanMember(
            "mix", w, hess.init_hessian(96, batch=2), x,
            x_count=jnp.zeros((2,), jnp.int32),
            starved=np.array([True, False]), names=["mix[0]", "mix[1]"])
        plan = qplan.build_plan(qc, [m])
        assert len(plan.fallbacks) == 1
        rep = qplan.QuantReport()
        out = qplan.execute_plan(qc, plan, rep)["mix"]
        modes = {l.name: l.mode for l in rep.linears}
        assert modes == {"mix[0]": "rtn-fallback", "mix[1]": "skipped"}
        from repro.core.gptq import rtn_quantize
        ref = rtn_quantize(w[0], bits=qc.bits, group_size=32)
        np.testing.assert_array_equal(np.asarray(out.w_q[0]),
                                      np.asarray(ref.w_q))
        np.testing.assert_array_equal(np.asarray(out.w_q[1]),
                                      np.asarray(w[1]))
        assert out.grid is None          # mixed lanes → no stored grid

    def test_gptq_only_mode(self, stack_problem):
        p = stack_problem
        qc = self._qc()
        qc.rpiq_iters = 0
        rep = qplan.QuantReport()
        plan = qplan.build_plan(qc, self._members(p))
        out = qplan.execute_plan(qc, plan, rep, batched=True)
        assert all(l.mode == "gptq" for l in rep.linears)
        assert len(out) == p["B"]


class TestExecutorCache:
    """Cross-layer jit-cache sharing: identically keyed groups anywhere in
    the stack must reuse ONE compiled executor entry per stage."""

    def test_identical_groups_share_entries(self, stack_problem):
        p = stack_problem
        from repro.config import QuantConfig
        qc = QuantConfig(group_size=32, blocksize=64, rpiq_iters=2,
                         rpiq_alpha=0.25)
        qplan.clear_executor_cache()

        def run_once(tag):
            ms = [qplan.PlanMember(f"{tag}{i}", p["W"][i], p["sts"][i],
                                   p["X"][i], x_count=None)
                  for i in range(p["B"])]
            plan = qplan.build_plan(qc, ms)
            qplan.execute_plan(qc, plan, qplan.QuantReport(), batched=True)

        run_once("layer0.")          # cold: one miss per stage
        s1 = qplan.executor_cache_stats()
        assert s1 == {"hits": 0, "misses": 2}
        run_once("layer1.")          # same group signature → pure hits
        s2 = qplan.executor_cache_stats()
        assert s2 == {"hits": 2, "misses": 2}

    def test_new_signature_is_a_miss(self, stack_problem):
        p = stack_problem
        from repro.config import QuantConfig
        qc = QuantConfig(group_size=32, blocksize=64, rpiq_iters=2,
                         rpiq_alpha=0.25)
        qplan.clear_executor_cache()
        m = qplan.PlanMember("a", p["W"][0], p["sts"][0], p["X"][0],
                             x_count=None)
        qplan.execute_plan(qc, qplan.build_plan(qc, [m]),
                           qplan.QuantReport(), batched=True)
        # different group_size → different signature → fresh entries
        qc2 = QuantConfig(group_size=64, blocksize=64, rpiq_iters=2,
                          rpiq_alpha=0.25)
        m2 = qplan.PlanMember("b", p["W"][0], p["sts"][0], p["X"][0],
                              x_count=None)
        qplan.execute_plan(qc2, qplan.build_plan(qc2, [m2]),
                           qplan.QuantReport(), batched=True)
        st = qplan.executor_cache_stats()
        assert st["misses"] == 4 and st["hits"] == 0


@pytest.mark.slow
class TestPipelineParity:
    def test_moe_pipeline_batched_matches_perlinear(self):
        """Quantized MoE params (8 experts) identical on a fixed seed
        whether groups run batched or per-linear."""
        from repro.core.pipeline import quantize_model
        from repro.data import MarkovLM, calibration_batches

        from repro.models import transformer as T

        outs, reports = [], []
        for batched in (False, True):
            cfg = get_config("olmoe-1b-7b", smoke=True)
            cfg.quant.batched_executor = batched
            mc = cfg.model
            params = T.init_params(mc, jax.random.PRNGKey(0))
            calib = calibration_batches(MarkovLM(mc.vocab_size, seed=1),
                                        3, 4, 24)
            pq, rep = quantize_model(cfg, params, calib)
            outs.append(pq)
            reports.append(rep)
        flat0 = jax.tree_util.tree_leaves(outs[0])
        flat1 = jax.tree_util.tree_leaves(outs[1])
        assert len(flat0) == len(flat1)
        for a, b in zip(flat0, flat1):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32),
                                       atol=2e-4)
        names0 = [(l.name, l.mode) for l in reports[0].linears]
        names1 = [(l.name, l.mode) for l in reports[1].linears]
        assert sorted(names0) == sorted(names1)
