"""Multi-device checks executed in a subprocess with forced host devices.

Invoked by test_distributed.py as::

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        python tests/_distributed_checks.py <check-name>

Keeping these out of the main pytest process means unit tests still see the
single real CPU device (required by the dry-run contract).
"""
import os
import sys

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax                                    # noqa: E402
import jax.numpy as jnp                       # noqa: E402
import numpy as np                            # noqa: E402


def check_sharded_train_matches_single():
    """Sharded (2 data × 2 model) train step == unsharded numerics."""
    from repro.configs import get_config
    from repro.data import MarkovLM
    from repro.distributed import sharding as shd
    from repro.training.train_step import init_train_state, make_train_step

    cfg = get_config("internlm2-1.8b", smoke=True)
    st = init_train_state(cfg, jax.random.PRNGKey(0))
    batch = MarkovLM(cfg.model.vocab_size, seed=1).batch(4, 16)
    step = make_train_step(cfg)
    st1, m1 = jax.jit(step)(st, batch)

    mesh = jax.make_mesh((2, 2), ("data", "model"))
    rules = shd.make_rules(mesh, cfg.parallel)
    pshard = shd.param_shardings(st.params, rules)
    st_sh = st._replace(params=jax.device_put(st.params, pshard))
    bsh = jax.device_put(batch, shd.batch_shardings(batch, rules))

    def fn(state, batch):
        with shd.use_rules(rules):
            return step(state, batch)

    with mesh:
        st2, m2 = jax.jit(fn)(st_sh, bsh)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-2, \
        (float(m1["loss"]), float(m2["loss"]))
    for a, b in zip(jax.tree_util.tree_leaves(st1.params),
                    jax.tree_util.tree_leaves(st2.params)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(jax.device_get(b), np.float32),
                                   rtol=3e-2, atol=3e-3)
    print("OK sharded==single")


def check_elastic_restore():
    """Checkpoint on a (4,) DP mesh, restore onto (2, 2) mesh."""
    from repro.configs import get_config
    from repro.distributed import sharding as shd
    from repro.distributed.checkpoint import Checkpointer
    from repro.training.train_step import init_train_state
    import tempfile

    cfg = get_config("opt-proxy", smoke=True)
    st = init_train_state(cfg, jax.random.PRNGKey(0))
    mesh1 = jax.make_mesh((4, 1), ("data", "model"))
    r1 = shd.make_rules(mesh1, cfg.parallel)
    st1 = st._replace(params=jax.device_put(
        st.params, shd.param_shardings(st.params, r1)))
    d = tempfile.mkdtemp()
    ck = Checkpointer(d, async_write=False)
    ck.save(1, st1, extra={"step": 1})

    mesh2 = jax.make_mesh((2, 2), ("data", "model"))
    r2 = shd.make_rules(mesh2, cfg.parallel)
    sh2 = shd.param_shardings(st.params, r2)
    restored, _ = ck.restore(st, shardings=None)
    params2 = jax.device_put(restored.params, sh2)
    for a, b in zip(jax.tree_util.tree_leaves(st.params),
                    jax.tree_util.tree_leaves(params2)):
        np.testing.assert_array_equal(np.asarray(a),
                                      np.asarray(jax.device_get(b)))
    print("OK elastic restore")


def check_grad_compression():
    """int8/bf16 compressed psum with error feedback ≈ exact mean over
    steps; single-step int8 error is bounded; error feedback shrinks bias."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    from repro.distributed.compression import compress_psum

    mesh = jax.make_mesh((8,), ("data",))
    g_global = jax.random.normal(jax.random.PRNGKey(0), (8, 64, 33))

    def run(method, steps=6):
        errs = []
        err = None
        acc_true = jnp.zeros((64, 33))
        acc_comp = jnp.zeros((64, 33))
        for s in range(steps):
            gs = g_global * (1.0 + 0.3 * s)

            def body(g, e):
                g = g[0]
                red, ne = compress_psum({"g": g}, "data", method,
                                        None if e is None else {"g": e[0]})
                ne_out = ne["g"] if ne is not None else jnp.zeros_like(g)
                return red["g"], ne_out[None] if ne_out.ndim == g.ndim \
                    else ne_out

            body_sm = shard_map(
                lambda g, e: body(g, e), mesh=mesh,
                in_specs=(P("data"), P("data")),
                out_specs=(P(), P("data")), check_rep=False)
            e_in = jnp.zeros((8, 64, 33)) if err is None else err
            red, err = body_sm(gs, e_in)
            true = jnp.mean(gs, axis=0)
            acc_true = acc_true + true
            acc_comp = acc_comp + red
            errs.append(float(jnp.linalg.norm(red - true)
                              / jnp.linalg.norm(true)))
        cum = float(jnp.linalg.norm(acc_comp - acc_true)
                    / jnp.linalg.norm(acc_true))
        return errs, cum

    errs8, cum8 = run("int8")
    assert errs8[0] < 0.05, errs8          # per-step int8 noise small
    assert cum8 < 0.02, cum8               # error feedback kills the bias
    errsb, cumb = run("bf16")
    assert cumb < 0.01, cumb
    print(f"OK compression int8 step={errs8[0]:.4f} cum={cum8:.4f} "
          f"bf16 cum={cumb:.4f}")


def check_gpipe_equivalence():
    """2-stage GPipe over 'pod' == plain stacked forward."""
    from repro.distributed.pipeline_parallel import (gpipe_forward,
                                                     make_stage_fn)
    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    n_layers, d = 4, 32
    ws = jax.random.normal(jax.random.PRNGKey(0),
                           (n_layers, d, d)) * (d ** -0.5)

    def layer_apply(w, x):
        return jnp.tanh(x @ w)

    x = jax.random.normal(jax.random.PRNGKey(1), (8, d))
    ref = x
    for i in range(n_layers):
        ref = layer_apply(ws[i], ref)

    stage_params = ws.reshape(2, 2, d, d)      # 2 stages × 2 layers
    stage_fn = make_stage_fn(layer_apply, per_stage=2)
    with mesh:
        out = gpipe_forward(mesh, stage_fn, stage_params, x,
                            n_microbatches=4, axis="pod")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-5)
    print("OK gpipe == stacked")


def check_quantize_rows_sharded():
    """Row-sharded GPTQ == single-device GPTQ (rows independent given U)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.core import hessian as hess
    from repro.core.gptq import gptq_quantize

    Cout, Cin = 64, 128
    W = jax.random.normal(jax.random.PRNGKey(0), (Cout, Cin)) * 0.1
    X = jax.random.normal(jax.random.PRNGKey(1), (256, Cin))
    st = hess.accumulate(hess.init_hessian(Cin), X)
    U = hess.cholesky_inverse_upper(hess.damped(st, 0.01))

    res_single = gptq_quantize(W, U, bits=4, group_size=32, blocksize=32)

    mesh = jax.make_mesh((8,), ("rows",))
    Wsh = jax.device_put(W, NamedSharding(mesh, P("rows", None)))
    Ur = jax.device_put(U, NamedSharding(mesh, P(None, None)))
    with mesh:
        res_sh = jax.jit(lambda w, u: gptq_quantize(
            w, u, bits=4, group_size=32, blocksize=32))(Wsh, Ur)
    np.testing.assert_allclose(np.asarray(res_single.w_q),
                               np.asarray(jax.device_get(res_sh.w_q)),
                               rtol=1e-5, atol=1e-6)
    print("OK row-sharded GPTQ exact")


def check_sharded_plan_parity():
    """Sharded group execution == single-device batched quantize_model.

    End-to-end over the knob route: ``quant.mesh="2x2"`` builds the
    (data, model) mesh through launch/mesh.make_quant_mesh and every
    divisible plan group runs lane-sharded over ``data`` with Cout row
    tiles over ``model`` (DESIGN.md §2.6); single-lane groups (e.g. the
    down-projection) exercise the per-axis divisibility fallback inside
    the same run. Group-level and non-divisible-group parity is pinned in
    tests/test_plan_sharded.py (the scripts/check.sh multi-device leg).
    """
    from repro.configs import get_config
    from repro.core.pipeline import quantize_model
    from repro.data import MarkovLM, calibration_batches
    from repro.models import transformer as T

    # make_quant_mesh degrades gracefully to single-device on too few
    # devices — which would make this parity check pass vacuously, so the
    # forced host device count is a hard precondition here
    assert jax.device_count() >= 4, \
        f"forced host devices missing (XLA_FLAGS?): {jax.device_count()}"
    cfg = get_config("opt-proxy", smoke=True)
    params = T.init_params(cfg.model, jax.random.PRNGKey(0))
    calib = calibration_batches(MarkovLM(cfg.model.vocab_size, seed=0),
                                2, 2, 32)
    pq1, rep1 = quantize_model(cfg, params, calib)
    cfg.quant.mesh = "2x2"
    pq2, rep2 = quantize_model(cfg, params, calib)

    mism, total, worst = 0, 0, 0.0
    for a, b in zip(jax.tree_util.tree_leaves(pq1),
                    jax.tree_util.tree_leaves(pq2)):
        a = np.asarray(a, np.float32)
        b = np.asarray(jax.device_get(b), np.float32)
        bad = ~np.isclose(a, b, rtol=1e-5, atol=1e-6)
        mism += int(bad.sum())
        total += a.size
        if bad.any():
            worst = max(worst, float(np.max(np.abs(a - b))))
    # functional equivalence: tiny fp divergence may flip the odd grid
    # cell; on the CPU host mesh the paths are in practice bitwise equal
    assert mism / total <= 1e-3, (mism, total, worst)
    for l1, l2 in zip(rep1.linears, rep2.linears):
        assert (l1.name, l1.mode) == (l2.name, l2.mode), (l1, l2)
    print(f"OK sharded plan == single-device batched "
          f"(mismatch {mism}/{total})")


def check_moe_expert_sharded():
    """Expert-parallel quantization == single-device, end-to-end + bitwise.

    The routed-MoE config quantizes once single-device and once on a
    ``quant.mesh="1x2x4"`` (data, model, expert) mesh: the stacked
    (E, ·, ·) expert groups shard lanes over the ``expert`` axis while
    dense groups keep the data/model rules — the ISSUE 10 scaled-down
    stand-in for the 671B shape. The olmoe smoke config has E=8 experts,
    so the expert axis (4) divides the slab. Runs under
    ``quant.pipeline=overlap`` so the flip repair and the expert-sharded
    executors compose in one run.
    """
    from repro.configs import get_config
    from repro.core.pipeline import quantize_model
    from repro.data import MarkovLM, calibration_batches
    from repro.models import transformer as T

    assert jax.device_count() >= 8, \
        f"forced host devices missing (XLA_FLAGS?): {jax.device_count()}"
    cfg = get_config("olmoe-1b-7b", smoke=True)
    cfg.quant.pipeline = "overlap"
    params = T.init_params(cfg.model, jax.random.PRNGKey(0))
    calib = calibration_batches(MarkovLM(cfg.model.vocab_size, seed=0),
                                2, 2, 32)
    pq1, rep1 = quantize_model(cfg, params, calib)
    cfg.quant.mesh = "1x2x4"
    pq2, rep2 = quantize_model(cfg, params, calib)
    assert rep2.pipeline_stats["moe_spec_layers"] > 0, \
        rep2.pipeline_stats

    mism, total, worst = 0, 0, 0.0
    for a, b in zip(jax.tree_util.tree_leaves(pq1),
                    jax.tree_util.tree_leaves(pq2)):
        a = np.asarray(a, np.float32)
        b = np.asarray(jax.device_get(b), np.float32)
        bad = ~np.isclose(a, b, rtol=1e-5, atol=1e-6)
        mism += int(bad.sum())
        total += a.size
        if bad.any():
            worst = max(worst, float(np.max(np.abs(a - b))))
    assert mism / total <= 1e-3, (mism, total, worst)
    for l1, l2 in zip(rep1.linears, rep2.linears):
        assert (l1.name, l1.mode) == (l2.name, l2.mode), (l1, l2)
    print(f"OK expert-sharded MoE == single-device "
          f"(mismatch {mism}/{total})")


CHECKS = {
    "sharded_train": check_sharded_train_matches_single,
    "elastic_restore": check_elastic_restore,
    "grad_compression": check_grad_compression,
    "gpipe": check_gpipe_equivalence,
    "gptq_rows": check_quantize_rows_sharded,
    "plan_sharded": check_sharded_plan_parity,
    "moe_expert_sharded": check_moe_expert_sharded,
}

if __name__ == "__main__":
    CHECKS[sys.argv[1]]()
