"""Quantization grid primitives: invariants + hypothesis property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_shim import given, settings, st

from repro.core.quant import (QuantParams, compute_qparams, dequantize_codes,
                              fake_quantize, pack_int4, pack_quantized,
                              quantize_codes, unpack_int4, dequantize_packed)


def _rand(shape, seed=0, scale=1.0):
    return jax.random.normal(jax.random.PRNGKey(seed), shape) * scale


class TestGrid:
    def test_codes_in_range(self):
        w = _rand((16, 64), 1)
        qp = compute_qparams(w, 4, 16)
        q = quantize_codes(w, qp, 4, 16)
        assert int(q.min()) >= 0 and int(q.max()) <= 15

    def test_fake_quant_idempotent(self):
        w = _rand((8, 32), 2)
        w1 = fake_quantize(w, 4, 16)
        qp = compute_qparams(w1, 4, 16)
        w2 = fake_quantize(w1, 4, 16, qp=qp)
        np.testing.assert_allclose(np.asarray(w1), np.asarray(w2), atol=2e-5)

    def test_zero_column_safe(self):
        w = jnp.zeros((4, 32))
        out = fake_quantize(w, 4, 16)
        assert not bool(jnp.any(jnp.isnan(out)))

    def test_symmetric_grid(self):
        w = _rand((8, 32), 3)
        out = fake_quantize(w, 4, 16, symmetric=True)
        err = float(jnp.max(jnp.abs(out - w)))
        qp = compute_qparams(w, 4, 16, symmetric=True)
        assert err <= float(jnp.max(qp.scales)) * 0.51 + 1e-6

    @given(bits=st.sampled_from([2, 3, 4, 8]),
           rows=st.integers(1, 8), groups=st.integers(1, 4))
    @settings(max_examples=20, deadline=None)
    def test_roundtrip_error_bound(self, bits, rows, groups):
        g = 16
        w = _rand((rows, groups * g), seed=bits * 100 + rows)
        qp = compute_qparams(w, bits, g)
        out = fake_quantize(w, bits, g, qp=qp)
        # |w - Q(w)| <= scale/2 elementwise (within-range values)
        s = jnp.repeat(qp.scales, g, axis=1)
        assert bool(jnp.all(jnp.abs(out - w) <= s * 0.5 + 1e-5))


class TestPacking:
    def test_pack_unpack_roundtrip(self):
        q = jax.random.randint(jax.random.PRNGKey(0), (8, 64), 0, 16)
        packed = pack_int4(q)
        assert packed.shape == (8, 32) and packed.dtype == jnp.uint8
        np.testing.assert_array_equal(np.asarray(unpack_int4(packed)),
                                      np.asarray(q))

    @given(rows=st.integers(1, 8), cols=st.integers(1, 16))
    @settings(max_examples=20, deadline=None)
    def test_pack_unpack_property(self, rows, cols):
        q = np.random.RandomState(rows * 17 + cols).randint(
            0, 16, (rows, cols * 2))
        packed = pack_int4(jnp.asarray(q))
        np.testing.assert_array_equal(np.asarray(unpack_int4(packed)), q)

    def test_pack_quantized_dequant(self):
        w = _rand((16, 128), 5)
        qt = pack_quantized(w, 4, 32)
        deq = dequantize_packed(qt)
        ref = fake_quantize(w, 4, 32)
        np.testing.assert_allclose(np.asarray(deq), np.asarray(ref),
                                   atol=1e-5)

    def test_quantized_tensor_is_pytree(self):
        w = _rand((8, 64), 6)
        qt = pack_quantized(w, 4, 32)
        leaves = jax.tree_util.tree_leaves(qt)
        assert len(leaves) == 3
        qt2 = jax.tree_util.tree_map(lambda x: x, qt)
        assert qt2.group_size == qt.group_size and qt2.shape == qt.shape

    def test_quantized_tensor_under_jit(self):
        from repro.models.linear import dense
        w = _rand((64, 32), 7)       # (in, out) model layout
        from repro.core.pipeline import pack_for_serving
        qt = pack_quantized(w.T, 4, 32)   # (out, in)-major
        x = _rand((4, 64), 8)
        y = jax.jit(lambda p, x: dense(p, x))({"w": qt}, x)
        y_ref = x @ dequantize_packed(qt).T.astype(x.dtype)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   rtol=2e-2, atol=2e-2)
