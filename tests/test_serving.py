"""Serving engine: generation, determinism, ragged completion, data."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data import DataState, MarkovLM, SentimentTask
from repro.models import transformer as T
from repro.serving.engine import generate


class TestGenerate:
    def _setup(self, arch="opt-proxy"):
        cfg = get_config(arch, smoke=True)
        params = T.init_params(cfg.model, jax.random.PRNGKey(0))
        batch = MarkovLM(cfg.model.vocab_size, seed=0).batch(3, 8)
        return cfg, params, batch

    def test_greedy_deterministic(self):
        cfg, params, batch = self._setup()
        r1 = generate(cfg, params, batch, max_new_tokens=6, temperature=0.0)
        r2 = generate(cfg, params, batch, max_new_tokens=6, temperature=0.0)
        np.testing.assert_array_equal(np.asarray(r1.tokens),
                                      np.asarray(r2.tokens))

    def test_greedy_matches_stepwise_forward(self):
        """generate() greedy == repeated argmax over full forwards."""
        cfg, params, batch = self._setup()
        mc = cfg.model
        res = generate(cfg, params, batch, max_new_tokens=4,
                       temperature=0.0)
        toks = batch["tokens"]
        for t in range(4):
            logits, _ = T.forward(mc, params, toks)
            nxt = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
            np.testing.assert_array_equal(np.asarray(res.tokens[:, t]),
                                          np.asarray(nxt))
            toks = jnp.concatenate([toks, nxt[:, None]], axis=1)

    def test_eos_freezes_lane(self):
        cfg, params, batch = self._setup()
        r = generate(cfg, params, batch, max_new_tokens=8,
                     temperature=0.0)
        eos = int(r.tokens[0, 2])
        r2 = generate(cfg, params, batch, max_new_tokens=8,
                      temperature=0.0, eos_id=eos)
        after = np.asarray(r2.tokens[0, 3:])
        assert (after == 0).all() or int(r2.tokens[0, 2]) != eos

    def test_recurrent_arch_generation(self):
        cfg, params, batch = self._setup("falcon-mamba-7b")
        r = generate(cfg, params, batch, max_new_tokens=5)
        assert r.tokens.shape == (3, 5)
        assert not np.any(np.isnan(np.asarray(r.logprobs)))

    def test_temperature_sampling_runs(self):
        cfg, params, batch = self._setup()
        r = generate(cfg, params, batch, max_new_tokens=4, temperature=0.8)
        assert r.tokens.shape == (3, 4)


class TestData:
    def test_markov_deterministic(self):
        a = MarkovLM(128, seed=3).batch(4, 16)["tokens"]
        b = MarkovLM(128, seed=3).batch(4, 16)["tokens"]
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_markov_state_restore(self):
        d1 = MarkovLM(128, seed=3)
        d1.batch(2, 8)
        st = d1.state()
        n1 = d1.batch(2, 8)["tokens"]
        d2 = MarkovLM(128, seed=3)
        d2.restore(st)
        n2 = d2.batch(2, 8)["tokens"]
        np.testing.assert_array_equal(np.asarray(n1), np.asarray(n2))

    def test_markov_learnable_structure(self):
        """Bigram statistics must be far from uniform."""
        toks = np.asarray(MarkovLM(64, seed=0).batch(32, 128)["tokens"])
        pairs = {}
        for row in toks:
            for a, b in zip(row[:-1], row[1:]):
                pairs.setdefault(int(a), set()).add(int(b))
        avg_successors = np.mean([len(v) for v in pairs.values()])
        assert avg_successors < 10       # branching=4 ≪ vocab=64

    def test_sentiment_batch_layout(self):
        task = SentimentTask(64, seed=0)
        batch, labels = task.batch(8, 24)
        toks = np.asarray(batch["tokens"])
        assert (toks[:, -2] == task.query).all()
        for i in range(8):
            assert toks[i, -1] == task.answers[int(labels[i])]
        assert np.asarray(batch["loss_mask"])[:, -1].all()
