"""Serving engine: generation, determinism, ragged completion, data,
chunked prefill, continuous batching, quantized decode path."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data import DataState, MarkovLM, SentimentTask
from repro.models import transformer as T
from repro.serving.engine import generate
from repro.serving.scheduler import ContinuousEngine


def _with_serve(cfg, **kw):
    return dataclasses.replace(cfg, serve=dataclasses.replace(cfg.serve,
                                                              **kw))


def _encdec_setup(b=3, s=6, seed=1):
    cfg = get_config("whisper-large-v3", smoke=True)
    params = T.init_encdec_params(cfg.model, jax.random.PRNGKey(seed))
    frames = jax.random.normal(
        jax.random.PRNGKey(seed + 1),
        (b, cfg.model.encoder_seq_len, cfg.model.d_model), jnp.float32)
    toks = jax.random.randint(jax.random.PRNGKey(seed + 2), (b, s), 0,
                              cfg.model.vocab_size)
    return cfg, params, {"frames": frames, "tokens": toks}


class TestGenerate:
    def _setup(self, arch="opt-proxy"):
        cfg = get_config(arch, smoke=True)
        params = T.init_params(cfg.model, jax.random.PRNGKey(0))
        batch = MarkovLM(cfg.model.vocab_size, seed=0).batch(3, 8)
        return cfg, params, batch

    def test_greedy_deterministic(self):
        cfg, params, batch = self._setup()
        r1 = generate(cfg, params, batch, max_new_tokens=6, temperature=0.0)
        r2 = generate(cfg, params, batch, max_new_tokens=6, temperature=0.0)
        np.testing.assert_array_equal(np.asarray(r1.tokens),
                                      np.asarray(r2.tokens))

    def test_greedy_matches_stepwise_forward(self):
        """generate() greedy == repeated argmax over full forwards."""
        cfg, params, batch = self._setup()
        mc = cfg.model
        res = generate(cfg, params, batch, max_new_tokens=4,
                       temperature=0.0)
        toks = batch["tokens"]
        for t in range(4):
            logits, _ = T.forward(mc, params, toks)
            nxt = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
            np.testing.assert_array_equal(np.asarray(res.tokens[:, t]),
                                          np.asarray(nxt))
            toks = jnp.concatenate([toks, nxt[:, None]], axis=1)

    def test_eos_freezes_lane(self):
        cfg, params, batch = self._setup()
        r = generate(cfg, params, batch, max_new_tokens=8,
                     temperature=0.0)
        eos = int(r.tokens[0, 2])
        r2 = generate(cfg, params, batch, max_new_tokens=8,
                      temperature=0.0, eos_id=eos)
        after = np.asarray(r2.tokens[0, 3:])
        assert (after == 0).all() or int(r2.tokens[0, 2]) != eos

    def test_recurrent_arch_generation(self):
        cfg, params, batch = self._setup("falcon-mamba-7b")
        r = generate(cfg, params, batch, max_new_tokens=5)
        assert r.tokens.shape == (3, 5)
        assert not np.any(np.isnan(np.asarray(r.logprobs)))

    def test_temperature_sampling_runs(self):
        cfg, params, batch = self._setup()
        r = generate(cfg, params, batch, max_new_tokens=4, temperature=0.8)
        assert r.tokens.shape == (3, 4)


class TestStepsSemantics:
    """GenResult.steps comes from the done mask, not from ``tokens != 0``:
    a model legitimately emitting token id 0 is counted, eos is never
    emitted, and an eos-first lane reports zero steps."""

    def _setup(self):
        cfg = get_config("opt-proxy", smoke=True)
        params = T.init_params(cfg.model, jax.random.PRNGKey(0))
        batch = MarkovLM(cfg.model.vocab_size, seed=0).batch(3, 8)
        return cfg, params, batch

    def test_steps_full_budget_without_eos(self):
        cfg, params, batch = self._setup()
        r = generate(cfg, params, batch, max_new_tokens=5, temperature=0.0)
        np.testing.assert_array_equal(np.asarray(r.steps), [5, 5, 5])

    def test_steps_stop_at_eos(self):
        cfg, params, batch = self._setup()
        ref = generate(cfg, params, batch, max_new_tokens=8, temperature=0.0)
        eos = int(ref.tokens[0, 3])
        r = generate(cfg, params, batch, max_new_tokens=8, temperature=0.0,
                     eos_id=eos)
        toks0 = np.asarray(ref.tokens[0])
        expect = int(np.argmax(toks0 == eos))   # tokens before first eos
        assert int(r.steps[0]) == expect
        assert (np.asarray(r.tokens[0, expect:]) == 0).all()
        assert (np.asarray(r.logprobs[0, expect:]) == 0.0).all()

    def test_eos_as_first_token_zeroed(self):
        cfg, params, batch = self._setup()
        ref = generate(cfg, params, batch, max_new_tokens=3, temperature=0.0)
        eos = int(ref.tokens[1, 0])
        r = generate(cfg, params, batch, max_new_tokens=3, temperature=0.0,
                     eos_id=eos)
        assert int(r.steps[1]) == 0
        assert (np.asarray(r.tokens[1]) == 0).all()
        assert (np.asarray(r.logprobs[1]) == 0.0).all()


class TestChunkedPrefill:
    """serve.prefill_chunk: chunked == single-shot logits and caches."""

    @pytest.mark.parametrize("chunk", [3, 4, 9])
    def test_dense_logits_and_caches(self, chunk):
        cfg = get_config("opt-proxy", smoke=True)
        params = T.init_params(cfg.model, jax.random.PRNGKey(0))
        toks = MarkovLM(cfg.model.vocab_size, seed=0).batch(3, 9)["tokens"]
        lg1, c1 = T.prefill(cfg.model, params, toks, 24)
        lg2, c2 = T.prefill_chunked(cfg.model, params, toks, 24, chunk)
        np.testing.assert_allclose(np.asarray(lg1), np.asarray(lg2),
                                   rtol=2e-5, atol=2e-5)
        for a, b in zip(jax.tree_util.tree_leaves(c1),
                        jax.tree_util.tree_leaves(c2)):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32), atol=2e-2)

    @pytest.mark.parametrize("chunk", [2, 3])
    def test_encdec_logits_and_caches(self, chunk):
        cfg, params, batch = _encdec_setup(b=2, s=7)
        lg1, c1 = T.encdec_prefill(cfg.model, params, batch["frames"],
                                   batch["tokens"], 20)
        lg2, c2 = T.encdec_prefill_chunked(cfg.model, params,
                                           batch["frames"], batch["tokens"],
                                           20, chunk)
        np.testing.assert_allclose(np.asarray(lg1), np.asarray(lg2),
                                   rtol=2e-5, atol=2e-5)
        for a, b in zip(jax.tree_util.tree_leaves(c1),
                        jax.tree_util.tree_leaves(c2)):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32), atol=2e-2)

    def test_generate_token_parity(self):
        """The serve.prefill_chunk knob doesn't change generated tokens."""
        cfg = get_config("opt-proxy", smoke=True)
        params = T.init_params(cfg.model, jax.random.PRNGKey(0))
        batch = MarkovLM(cfg.model.vocab_size, seed=0).batch(3, 8)
        ref = generate(cfg, params, batch, max_new_tokens=5, temperature=0.0)
        r = generate(_with_serve(cfg, prefill_chunk=3), params, batch,
                     max_new_tokens=5, temperature=0.0)
        np.testing.assert_array_equal(np.asarray(ref.tokens),
                                      np.asarray(r.tokens))


@pytest.mark.serving
class TestContinuousScheduler:
    """ContinuousEngine greedy == static generate per sequence."""

    def _setup(self):
        cfg = get_config("opt-proxy", smoke=True)
        params = T.init_params(cfg.model, jax.random.PRNGKey(0))
        return cfg, params

    def test_uniform_batch_parity(self):
        cfg, params = self._setup()
        batch = MarkovLM(cfg.model.vocab_size, seed=0).batch(3, 8)
        ref = generate(cfg, params, batch, max_new_tokens=6, temperature=0.0)
        eng = ContinuousEngine(cfg, params, max_len=32)
        rids = [eng.submit({"tokens": batch["tokens"][i:i + 1]},
                           max_new_tokens=6) for i in range(3)]
        done = eng.run()
        for i, rid in enumerate(rids):
            np.testing.assert_array_equal(done[rid].tokens,
                                          np.asarray(ref.tokens[i]))
            assert done[rid].steps == 6

    def test_mixed_lengths_chunked_parity(self):
        """Mixed prompt lengths + decode budgets, fewer lanes than
        requests, chunked prefill — still token-identical per sequence."""
        cfg, params = self._setup()
        eng = ContinuousEngine(_with_serve(cfg, prefill_chunk=3,
                                           max_batch=2), params, max_len=40)
        data = MarkovLM(cfg.model.vocab_size, seed=1)
        reqs = [(data.batch(1, L), M)
                for L, M in [(5, 4), (9, 7), (7, 2), (11, 5), (4, 1)]]
        rids = [eng.submit(b, max_new_tokens=m) for b, m in reqs]
        done = eng.run()
        for rid, (b, m) in zip(rids, reqs):
            ref = generate(cfg, params, b, max_new_tokens=m,
                           temperature=0.0)
            np.testing.assert_array_equal(done[rid].tokens,
                                          np.asarray(ref.tokens[0]))
            assert done[rid].steps == int(ref.steps[0])

    def test_eos_parity(self):
        cfg, params = self._setup()
        batch = MarkovLM(cfg.model.vocab_size, seed=0).batch(3, 8)
        probe = generate(cfg, params, batch, max_new_tokens=8,
                         temperature=0.0)
        eos = int(probe.tokens[0, 2])
        ref = generate(cfg, params, batch, max_new_tokens=8,
                       temperature=0.0, eos_id=eos)
        eng = ContinuousEngine(cfg, params, max_len=32)
        rids = [eng.submit({"tokens": batch["tokens"][i:i + 1]},
                           max_new_tokens=8, eos_id=eos) for i in range(3)]
        done = eng.run()
        for i, rid in enumerate(rids):
            s = int(ref.steps[i])
            assert done[rid].steps == s
            np.testing.assert_array_equal(done[rid].tokens,
                                          np.asarray(ref.tokens[i, :s]))

    def test_encdec_parity(self):
        cfg, params, batch = _encdec_setup(b=3, s=6)
        ref = generate(cfg, params, batch, max_new_tokens=5, temperature=0.0)
        eng = ContinuousEngine(_with_serve(cfg, prefill_chunk=2,
                                           max_batch=2), params, max_len=24)
        rids = [eng.submit({"frames": batch["frames"][i:i + 1],
                            "tokens": batch["tokens"][i:i + 1]},
                           max_new_tokens=5) for i in range(3)]
        done = eng.run()
        for i, rid in enumerate(rids):
            np.testing.assert_array_equal(done[rid].tokens,
                                          np.asarray(ref.tokens[i]))


class TestCancelRaces:
    """cancel(rid) racing the two transient scheduler stages: a prefilled
    request parked in the ready queue waiting for lane promotion, and a
    request mid-way through an active chunked prefill. Both must cancel
    cleanly (no resurrection, no leaked slot) and leave every other
    request token-identical to the fault-free static reference."""

    def _setup(self, **kw):
        cfg = get_config("opt-proxy", smoke=True)
        params = T.init_params(cfg.model, jax.random.PRNGKey(0))
        return _with_serve(cfg, **kw), params

    def test_cancel_parked_in_ready_queue(self):
        cfg, params = self._setup(max_batch=1)
        eng = ContinuousEngine(cfg, params, max_len=40)
        data = MarkovLM(cfg.model.vocab_size, seed=2)
        b0, b1 = data.batch(1, 8), data.batch(1, 8)
        r0 = eng.submit(b0, max_new_tokens=8)
        r1 = eng.submit(b1, max_new_tokens=8)
        # tick until r1 has prefilled but is parked: the single lane is
        # still held by r0, so r1 sits in _ready awaiting promotion
        for _ in range(20):
            eng.step()
            if any(p.req.rid == r1 for p in eng._ready):
                break
        else:
            pytest.fail("r1 never parked in the ready queue")
        c = eng.cancel(r1)
        assert c is not None and c.status == "cancelled"
        assert eng.stats["cancelled"] == 1
        assert not any(p.req.rid == r1 for p in eng._ready)
        done = eng.run()
        # the freed parking spot never resurrects r1...
        assert r1 not in done
        assert eng.idle and eng.active == 0
        # ...and r0's decode is untouched by the race
        ref = generate(cfg, params, b0, max_new_tokens=8, temperature=0.0)
        np.testing.assert_array_equal(done[r0].tokens,
                                      np.asarray(ref.tokens[0]))

    def test_cancel_mid_chunked_prefill(self):
        # an occupied lane pins prefill to one chunk per tick (the
        # deficit rule only multi-chunks while a lane would go empty), so
        # the mid-prefill window is observable across ticks
        cfg, params = self._setup(prefill_chunk=2, max_batch=1)
        eng = ContinuousEngine(cfg, params, max_len=40)
        data = MarkovLM(cfg.model.vocab_size, seed=3)
        b0, b1 = data.batch(1, 4), data.batch(1, 9)
        r0 = eng.submit(b0, max_new_tokens=8)
        eng.step()                      # r0 prefilled and decoding
        r1 = eng.submit(b1, max_new_tokens=6)
        eng.step()
        eng.step()
        # r1 is the active prefill with some chunks written, more to go —
        # the mid-prefill window the cancel must hit
        pf = eng._prefill
        assert pf is not None and pf.req.rid == r1
        assert 0 < pf.start < pf.h.shape[1]
        c = eng.cancel(r1)
        assert c is not None and c.status == "cancelled"
        assert c.steps == 0                     # no tokens emitted yet
        assert eng._prefill is None             # slot released immediately
        assert eng.stats["cancelled"] == 1
        done = eng.run()
        assert r1 not in done and eng.idle
        # the decoding lane never saw the race
        ref = generate(cfg, params, b0, max_new_tokens=8, temperature=0.0)
        np.testing.assert_array_equal(done[r0].tokens,
                                      np.asarray(ref.tokens[0]))


@pytest.mark.serving
class TestQuantizedDecodePath:
    """generate() with QuantizedTensor params routes every decode dense
    through ops.w4a16_matmul on decode shapes, deterministic across impls."""

    def _setup(self):
        from repro.core.pipeline import pack_for_serving
        cfg = get_config("opt-proxy", smoke=True)
        params = T.init_params(cfg.model, jax.random.PRNGKey(0))
        qparams = pack_for_serving(cfg, params)
        batch = MarkovLM(cfg.model.vocab_size, seed=0).batch(3, 8)
        return cfg, qparams, batch

    def test_decode_runs_w4a16_on_decode_shapes(self, monkeypatch):
        from repro.kernels import ops
        cfg, qparams, batch = self._setup()
        shapes = []
        orig = ops.w4a16_matmul

        def spy(x, *a, **kw):
            shapes.append(tuple(x.shape))
            return orig(x, *a, **kw)

        monkeypatch.setattr(ops, "w4a16_matmul", spy)
        from repro.models import linear
        monkeypatch.setattr(linear.kops, "w4a16_matmul", spy)
        generate(cfg, qparams, batch, max_new_tokens=3, temperature=0.0)
        # decode-shaped calls: (B, 1, d) with a leading batch dim
        assert any(len(s) == 3 and s[1] == 1 for s in shapes), shapes

    def test_impl_knob_deterministic(self):
        cfg, qparams, batch = self._setup()
        outs = {}
        for impl in ("auto", "xla", "pallas"):
            r = generate(_with_serve(cfg, w4a16_impl=impl), qparams, batch,
                         max_new_tokens=4, temperature=0.0)
            outs[impl] = np.asarray(r.tokens)
        np.testing.assert_array_equal(outs["auto"], outs["xla"])
        np.testing.assert_array_equal(outs["xla"], outs["pallas"])

    def test_continuous_quantized_parity(self):
        cfg, qparams, batch = self._setup()
        ref = generate(cfg, qparams, batch, max_new_tokens=4,
                       temperature=0.0)
        eng = ContinuousEngine(cfg, qparams, max_len=32)
        rids = [eng.submit({"tokens": batch["tokens"][i:i + 1]},
                           max_new_tokens=4) for i in range(3)]
        done = eng.run()
        for i, rid in enumerate(rids):
            np.testing.assert_array_equal(done[rid].tokens,
                                          np.asarray(ref.tokens[i]))


class TestData:
    def test_markov_deterministic(self):
        a = MarkovLM(128, seed=3).batch(4, 16)["tokens"]
        b = MarkovLM(128, seed=3).batch(4, 16)["tokens"]
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_markov_state_restore(self):
        d1 = MarkovLM(128, seed=3)
        d1.batch(2, 8)
        st = d1.state()
        n1 = d1.batch(2, 8)["tokens"]
        d2 = MarkovLM(128, seed=3)
        d2.restore(st)
        n2 = d2.batch(2, 8)["tokens"]
        np.testing.assert_array_equal(np.asarray(n1), np.asarray(n2))

    def test_markov_learnable_structure(self):
        """Bigram statistics must be far from uniform."""
        toks = np.asarray(MarkovLM(64, seed=0).batch(32, 128)["tokens"])
        pairs = {}
        for row in toks:
            for a, b in zip(row[:-1], row[1:]):
                pairs.setdefault(int(a), set()).add(int(b))
        avg_successors = np.mean([len(v) for v in pairs.values()])
        assert avg_successors < 10       # branching=4 ≪ vocab=64

    def test_sentiment_batch_layout(self):
        task = SentimentTask(64, seed=0)
        batch, labels = task.batch(8, 24)
        toks = np.asarray(batch["tokens"])
        assert (toks[:, -2] == task.query).all()
        for i in range(8):
            assert toks[i, -1] == task.answers[int(labels[i])]
        assert np.asarray(batch["loss_mask"])[:, -1].all()
