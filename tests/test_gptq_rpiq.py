"""GPTQ stage-1 + RPIQ stage-2 algorithm correctness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import hessian as hess
from repro.core.gptq import gptq_from_hessian, gptq_quantize, rtn_quantize
from repro.core.rpiq import rpiq_refine


@pytest.fixture(scope="module")
def layer_problem():
    """A correlated-input linear layer with its calibration Hessian."""
    Cout, Cin, N = 96, 256, 512
    W = jax.random.normal(jax.random.PRNGKey(1), (Cout, Cin)) * 0.1
    A = jax.random.normal(jax.random.PRNGKey(2), (Cin, Cin)) * 0.2 \
        + jnp.eye(Cin)
    X = jax.random.normal(jax.random.PRNGKey(3), (N, Cin)) @ A
    st = hess.init_hessian(Cin)
    for b in range(4):
        st = hess.accumulate(st, X[b * 128:(b + 1) * 128])
    Hd = hess.damped(st, 0.01)
    U = hess.cholesky_inverse_upper(Hd)
    return dict(W=W, X=X, st=st, Hd=Hd, U=U)


def _out_err(X, W, Wq):
    return float(jnp.linalg.norm(X @ (W - Wq).T))


class TestHessian:
    def test_accumulate_matches_gram(self, layer_problem):
        p = layer_problem
        np.testing.assert_allclose(np.asarray(p["st"].H),
                                   np.asarray(p["X"].T @ p["X"]),
                                   rtol=1e-4, atol=1e-2)
        assert int(p["st"].count) == 512

    def test_damping_spd(self, layer_problem):
        evs = np.linalg.eigvalsh(np.asarray(layer_problem["Hd"]))
        assert evs.min() > 0

    def test_dead_column_rescue(self):
        X = jnp.zeros((64, 32)).at[:, :16].set(
            jax.random.normal(jax.random.PRNGKey(0), (64, 16)))
        st = hess.accumulate(hess.init_hessian(32), X)
        Hd = hess.damped(st, 0.01)
        assert np.linalg.eigvalsh(np.asarray(Hd)).min() > 0
        U = hess.cholesky_inverse_upper(Hd)
        assert not bool(jnp.any(jnp.isnan(U)))

    def test_cholesky_inverse_identity(self, layer_problem):
        Hd = layer_problem["Hd"]
        U = hess.cholesky_inverse_upper(Hd)
        Hinv = U.T @ U
        np.testing.assert_allclose(np.asarray(Hinv @ Hd),
                                   np.eye(Hd.shape[0]), atol=5e-2)


class TestGPTQ:
    def test_beats_rtn_in_output_space(self, layer_problem):
        p = layer_problem
        rtn = rtn_quantize(p["W"], bits=4, group_size=64)
        res = gptq_quantize(p["W"], p["U"], bits=4, group_size=64,
                            blocksize=64)
        assert _out_err(p["X"], p["W"], res.w_q) \
            < _out_err(p["X"], p["W"], rtn.w_q)

    def test_output_on_grid(self, layer_problem):
        p = layer_problem
        res = gptq_quantize(p["W"], p["U"], bits=4, group_size=64,
                            blocksize=64)
        s = jnp.repeat(res.scales, 64, axis=1)
        z = jnp.repeat(res.zeros, 64, axis=1)
        codes = jnp.round(res.w_q / s) + z
        assert float(jnp.max(jnp.abs((codes - z) * s - res.w_q))) < 1e-4
        assert float(codes.min()) >= 0 and float(codes.max()) <= 15

    def test_group_smaller_than_block(self, layer_problem):
        p = layer_problem
        res = gptq_quantize(p["W"], p["U"], bits=4, group_size=32,
                            blocksize=64)
        assert res.scales.shape == (96, 256 // 32)
        assert _out_err(p["X"], p["W"], res.w_q) \
            < _out_err(p["X"], p["W"],
                       rtn_quantize(p["W"], bits=4, group_size=32).w_q)

    def test_identity_hessian_equals_rtn_error_scale(self, layer_problem):
        """With H = I the greedy update has nothing to exploit; error should
        be close to (slightly better/equal than) plain RTN."""
        p = layer_problem
        U = jnp.eye(256)
        res = gptq_quantize(p["W"], U, bits=4, group_size=64, blocksize=64)
        rtn = rtn_quantize(p["W"], bits=4, group_size=64)
        e_res = float(jnp.linalg.norm(p["W"] - res.w_q))
        e_rtn = float(jnp.linalg.norm(p["W"] - rtn.w_q))
        assert e_res <= e_rtn * 1.05

    def test_convenience_wrapper(self, layer_problem):
        p = layer_problem
        res = gptq_from_hessian(p["W"], p["st"], bits=4, group_size=64,
                                blocksize=64, percdamp=0.01)
        assert not bool(jnp.any(jnp.isnan(res.w_q)))


class TestRPIQ:
    def _run(self, p, **kw):
        res1 = gptq_quantize(p["W"], p["U"], bits=4, group_size=64,
                             blocksize=64)
        kw.setdefault("bits", 4)
        kw.setdefault("group_size", 64)
        kw.setdefault("block_size", 64)
        return res1, rpiq_refine(res1.w_q, p["W"], p["X"][-128:], p["Hd"],
                                 res1.scales, res1.zeros,
                                 h_count=p["st"].count, **kw)

    def test_never_regresses(self, layer_problem):
        for alpha in (0.01, 0.25, 1.0):
            res1, res2 = self._run(layer_problem, alpha=alpha, t_max=5)
            assert float(res2.proj_loss) <= float(res2.loss_history[0]) + 1e-5

    def test_projected_weights_on_grid(self, layer_problem):
        res1, res2 = self._run(layer_problem, alpha=0.25, t_max=5,
                               exact_gram=True)
        s = jnp.repeat(res1.scales, 64, axis=1)
        z = jnp.repeat(res1.zeros, 64, axis=1)
        codes = jnp.round(res2.w_q / s) + z
        assert float(jnp.max(jnp.abs((codes - z) * s - res2.w_q))) < 1e-4

    def test_exact_gram_improves_single_instance_loss(self, layer_problem):
        """eq. 6 literal mode at moderate α must genuinely reduce Γ."""
        res1, res2 = self._run(layer_problem, alpha=0.25, t_max=8,
                               exact_gram=True)
        assert float(res2.proj_loss) < float(res2.loss_history[0]) * 0.99

    def test_exact_gram_monotone_continuous(self, layer_problem):
        """Pre-projection GS descent: Γ must be non-increasing until the
        early stop fires (each block solve is a true least squares)."""
        _, res2 = self._run(layer_problem, alpha=1.0, t_max=6,
                            exact_gram=True, early_stop=True)
        hist = [h for h in np.asarray(res2.loss_history) if np.isfinite(h)]
        # all but the last recorded value must be non-increasing
        for a, b in zip(hist[:-2], hist[1:-1]):
            assert b <= a * 1.001

    def test_early_stop_fires(self, layer_problem):
        _, res2 = self._run(layer_problem, alpha=0.01, t_max=50)
        assert int(res2.iters_run) < 50

    def test_global_h_small_alpha_converges(self, layer_problem):
        """Paper-faithful mode (eq. 13-14): small α decreases continuous Γ."""
        _, res2 = self._run(layer_problem, alpha=0.01, t_max=5)
        hist = [h for h in np.asarray(res2.loss_history) if np.isfinite(h)]
        assert hist[1] < hist[0]

    def test_h_count_rescale_matters(self, layer_problem):
        """Without the n_last/n_total rescale the LS step is mis-scaled and
        the first GS round must be strictly worse (documented failure)."""
        p = layer_problem
        res1 = gptq_quantize(p["W"], p["U"], bits=4, group_size=64,
                             blocksize=64)
        good = rpiq_refine(res1.w_q, p["W"], p["X"][-128:], p["Hd"],
                           res1.scales, res1.zeros, h_count=p["st"].count,
                           alpha=1.0, t_max=1, bits=4, group_size=64,
                           block_size=64)
        bad = rpiq_refine(res1.w_q, p["W"], p["X"][-128:], p["Hd"],
                          res1.scales, res1.zeros, h_count=None,
                          alpha=1.0, t_max=1, bits=4, group_size=64,
                          block_size=64)
        assert float(good.loss_history[1]) < float(bad.loss_history[1])
