"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode.

Every kernel is executed with interpret=True (kernel body runs in Python on
CPU) and compared against ref.py. Block-shape edge cases (non-divisible
sizes exercised through the ops.py padding wrappers) are included.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_shim import given, settings, st

from repro.core.quant import pack_quantized
from repro.kernels import ref
from repro.kernels.hessian_accum import hessian_accum_pallas
from repro.kernels.quant_pack import quant_pack_pallas
from repro.kernels.selective_scan import selective_scan_pallas
from repro.kernels.w4a16_matmul import w4a16_matmul_pallas


def _rand(shape, seed=0, dtype=jnp.float32):
    return jax.random.normal(jax.random.PRNGKey(seed), shape).astype(dtype)


class TestHessianKernel:
    @pytest.mark.parametrize("n,d,bn,bd", [
        (128, 128, 64, 64), (256, 128, 128, 128), (512, 256, 256, 128),
        (64, 64, 32, 32),
    ])
    def test_shapes(self, n, d, bn, bd):
        x = _rand((n, d), n + d)
        out = hessian_accum_pallas(x, block_d=bd, block_n=bn, interpret=True)
        np.testing.assert_allclose(np.asarray(out),
                                   np.asarray(ref.hessian_accum_ref(x)),
                                   rtol=1e-4, atol=1e-3)

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_dtypes(self, dtype):
        x = _rand((128, 64), 3, dtype)
        out = hessian_accum_pallas(x, block_d=64, block_n=64, interpret=True)
        assert out.dtype == jnp.float32
        np.testing.assert_allclose(np.asarray(out),
                                   np.asarray(ref.hessian_accum_ref(x)),
                                   rtol=2e-2, atol=1e-1)

    def test_psd(self):
        x = _rand((256, 64), 9)
        H = hessian_accum_pallas(x, block_d=64, block_n=128, interpret=True)
        evs = np.linalg.eigvalsh(np.asarray(H))
        assert evs.min() > -1e-3


class TestW4A16Kernel:
    def _mk(self, m, n, k, g, seed=0):
        x = _rand((m, k), seed, jnp.float32)
        w = _rand((n, k), seed + 1) * 0.2
        qt = pack_quantized(w, 4, g)
        return x, qt

    @pytest.mark.parametrize("m,n,k,g,bm,bn,bk", [
        (8, 128, 256, 128, 8, 128, 128),
        (128, 128, 512, 128, 64, 128, 256),
        (16, 256, 256, 64, 16, 128, 128),
        (8, 128, 128, 128, 8, 128, 128),
    ])
    def test_shapes(self, m, n, k, g, bm, bn, bk):
        x, qt = self._mk(m, n, k, g, seed=m + n)
        y = w4a16_matmul_pallas(x, qt.packed, qt.scales, qt.zeros,
                                group_size=g, block_m=bm, block_n=bn,
                                block_k=bk, interpret=True)
        y_ref = ref.w4a16_matmul_ref(x, qt.packed, qt.scales, qt.zeros, g)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   rtol=1e-4, atol=1e-3)

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_dtypes(self, dtype):
        x = _rand((16, 256), 4, dtype)
        w = _rand((128, 256), 5) * 0.2
        qt = pack_quantized(w, 4, 128)
        y = w4a16_matmul_pallas(x, qt.packed, qt.scales, qt.zeros,
                                group_size=128, block_m=16, block_n=128,
                                block_k=256, interpret=True)
        assert y.dtype == dtype
        y_ref = ref.w4a16_matmul_ref(x, qt.packed, qt.scales, qt.zeros, 128)
        np.testing.assert_allclose(np.asarray(y, np.float32),
                                   np.asarray(y_ref, np.float32),
                                   rtol=2e-2, atol=2e-1)

    def test_ops_padding_path(self):
        """Non-divisible m/n through the ops wrapper (pads + slices)."""
        from repro.kernels import ops
        x = _rand((5, 256), 6)
        w = _rand((100, 256), 7) * 0.3
        qt = pack_quantized(w, 4, 128)
        y = ops.w4a16_matmul(x, qt.packed, qt.scales, qt.zeros,
                             group_size=128, impl="xla")
        y_ref = ref.w4a16_matmul_ref(x, qt.packed, qt.scales, qt.zeros, 128)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   rtol=1e-4, atol=1e-3)

    @pytest.mark.parametrize("lead", [(3, 1), (2, 5), (4,)])
    @pytest.mark.parametrize("impl", ["xla", "pallas"])
    def test_decode_shapes(self, lead, impl):
        """The serving decode path calls ops.w4a16_matmul with leading
        batch dims — (B, 1, k) single-token decode, (B, S, k) prefill.
        Every impl must match the 2-D ref on the flattened batch ≤1e-5."""
        from repro.kernels import ops
        k, n, g = 256, 128, 128
        x = _rand(lead + (k,), sum(lead), jnp.float32)
        w = _rand((n, k), 11) * 0.2
        qt = pack_quantized(w, 4, g)
        y = ops.w4a16_matmul(x, qt.packed, qt.scales, qt.zeros,
                             group_size=g, impl=impl)
        assert y.shape == lead + (n,)
        y_ref = ref.w4a16_matmul_ref(x.reshape(-1, k), qt.packed, qt.scales,
                                     qt.zeros, g).reshape(lead + (n,))
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   rtol=1e-5, atol=1e-5)

    def test_decode_shapes_impls_agree(self):
        """auto (CPU) == xla == pallas(interpret) bit-for-bit comparable on
        decode shapes, and the trace-time default-impl context routes the
        implicit (no-impl-arg) call sites used by models.linear.dense."""
        from repro.kernels import ops
        x = _rand((3, 1, 256), 21, jnp.float32)
        w = _rand((128, 256), 22) * 0.2
        qt = pack_quantized(w, 4, 128)
        ys = {}
        for impl in ("auto", "xla", "pallas"):
            with ops.w4a16_default_impl(impl):
                ys[impl] = np.asarray(ops.w4a16_matmul(
                    x, qt.packed, qt.scales, qt.zeros, group_size=128))
        np.testing.assert_allclose(ys["auto"], ys["xla"], rtol=0, atol=0)
        np.testing.assert_allclose(ys["xla"], ys["pallas"],
                                   rtol=1e-5, atol=1e-5)


class TestSelectiveScanKernel:
    def _mk(self, B, S, d, n, seed=0):
        ks = jax.random.split(jax.random.PRNGKey(seed), 6)
        u = jax.random.normal(ks[0], (B, S, d))
        dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, d)) - 1)
        bm = jax.random.normal(ks[2], (B, S, n))
        cm = jax.random.normal(ks[3], (B, S, n))
        a_log = jnp.log(jnp.tile(
            jnp.arange(1, n + 1, dtype=jnp.float32)[None], (d, 1)))
        d_skip = jax.random.normal(ks[4], (d,))
        h0 = jax.random.normal(ks[5], (B, d, n)) * 0.1
        return u, dt, bm, cm, a_log, d_skip, h0

    @pytest.mark.parametrize("B,S,d,n,bd,bt", [
        (2, 64, 32, 8, 16, 16), (1, 32, 16, 4, 16, 32),
        (3, 128, 64, 16, 32, 64), (2, 64, 32, 8, 32, 64),
    ])
    def test_shapes(self, B, S, d, n, bd, bt):
        args = self._mk(B, S, d, n, seed=B * 7 + S)
        y_ref, h_ref = ref.selective_scan_ref(*args)
        y, h = selective_scan_pallas(*args, block_d=bd, block_t=bt,
                                     interpret=True)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(h), np.asarray(h_ref),
                                   rtol=1e-4, atol=1e-4)

    def test_bf16_inputs(self):
        args = self._mk(2, 32, 16, 4, seed=9)
        args = tuple(a.astype(jnp.bfloat16) if a.ndim == 3 and i < 2
                     else a for i, a in enumerate(args))
        y_ref, _ = ref.selective_scan_ref(*args)
        y, _ = selective_scan_pallas(*args, block_d=16, block_t=16,
                                     interpret=True)
        np.testing.assert_allclose(np.asarray(y, np.float32),
                                   np.asarray(y_ref, np.float32),
                                   rtol=5e-2, atol=5e-2)

    def test_state_carry_across_time_tiles(self):
        """Two time tiles must chain h exactly (scratch persistence)."""
        args = self._mk(1, 64, 16, 4, seed=3)
        y1, h1 = selective_scan_pallas(*args, block_d=16, block_t=64,
                                       interpret=True)
        y2, h2 = selective_scan_pallas(*args, block_d=16, block_t=16,
                                       interpret=True)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(np.asarray(h1), np.asarray(h2),
                                   rtol=1e-5, atol=1e-5)

    def test_ops_dispatch_consistency(self):
        from repro.kernels import ops
        args = self._mk(2, 48, 32, 8, seed=11)
        y1, h1 = ops.selective_scan(*args, impl="pallas")
        y2, h2 = ops.selective_scan(*args, impl="xla")
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                                   rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(np.asarray(h1), np.asarray(h2),
                                   rtol=2e-4, atol=2e-4)


class TestQuantPackKernel:
    @pytest.mark.parametrize("n,k,g,bn,bk", [
        (64, 256, 128, 32, 256), (256, 512, 128, 256, 256),
        (32, 128, 64, 32, 128),
    ])
    def test_shapes(self, n, k, g, bn, bk):
        w = _rand((n, k), n + k) * 0.2
        from repro.core.quant import compute_qparams
        qp = compute_qparams(w, 4, g)
        out = quant_pack_pallas(w, qp.scales, qp.zeros, group_size=g,
                                block_n=bn, block_k=bk, interpret=True)
        ref_out = ref.quant_pack_ref(w, qp.scales, qp.zeros, g)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref_out))

    @given(seed=st.integers(0, 100))
    @settings(max_examples=10, deadline=None)
    def test_property_matches_ref(self, seed):
        w = _rand((32, 128), seed) * (0.1 + seed % 5)
        from repro.core.quant import compute_qparams
        qp = compute_qparams(w, 4, 64)
        out = quant_pack_pallas(w, qp.scales, qp.zeros, group_size=64,
                                block_n=32, block_k=128, interpret=True)
        ref_out = ref.quant_pack_ref(w, qp.scales, qp.zeros, 64)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref_out))
