"""Per-arch smoke tests (assigned-architecture deliverable) + model
behaviour: prefill/decode consistency against the full forward."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.data import MarkovLM
from repro.models import transformer as T
from repro.training.train_step import init_train_state, make_train_step


def _batch_for(cfg, b=2, s=16, seed=0):
    mc = cfg.model
    key = jax.random.PRNGKey(seed)
    batch = MarkovLM(mc.vocab_size, seed=seed).batch(b, s)
    if mc.is_encoder_decoder:
        batch["frames"] = jax.random.normal(
            key, (b, mc.encoder_seq_len, mc.d_model), jnp.float32)
    elif mc.frontend in ("vision", "audio") and mc.frontend_tokens:
        batch["embeds"] = jax.random.normal(
            key, (b, min(mc.frontend_tokens, 8), mc.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
class TestArchSmoke:
    def test_forward_shapes_no_nans(self, arch):
        cfg = get_config(arch, smoke=True)
        mc = cfg.model
        key = jax.random.PRNGKey(0)
        batch = _batch_for(cfg)
        if mc.is_encoder_decoder:
            params = T.init_encdec_params(mc, key)
            logits, aux = T.encdec_forward(mc, params, batch["frames"],
                                           batch["tokens"])
            exp_s = batch["tokens"].shape[1]
        else:
            params = T.init_params(mc, key)
            logits, aux = T.forward(mc, params, batch["tokens"],
                                    batch.get("embeds"))
            exp_s = batch["tokens"].shape[1] + (
                batch["embeds"].shape[1] if "embeds" in batch else 0)
        assert logits.shape == (2, exp_s, mc.vocab_size)
        assert logits.dtype == jnp.float32
        assert not bool(jnp.any(jnp.isnan(logits)))

    def test_train_step(self, arch):
        cfg = get_config(arch, smoke=True)
        st = init_train_state(cfg, jax.random.PRNGKey(0))
        step = jax.jit(make_train_step(cfg))
        st2, metrics = step(st, _batch_for(cfg))
        loss = float(metrics["loss"])
        assert np.isfinite(loss) and loss > 0
        # params actually moved
        d0 = jax.tree_util.tree_leaves(st.params)[1]
        d1 = jax.tree_util.tree_leaves(st2.params)[1]
        assert float(jnp.max(jnp.abs(d0.astype(jnp.float32)
                                     - d1.astype(jnp.float32)))) > 0


@pytest.mark.parametrize("arch", ["internlm2-1.8b", "h2o-danube-1.8b",
                                  "recurrentgemma-9b", "falcon-mamba-7b",
                                  "deepseek-v3-671b", "olmoe-1b-7b",
                                  "minicpm-2b"])
def test_prefill_decode_matches_forward(arch):
    """Greedy decode continuing a prefill must match slicing the full
    forward — validates every cache implementation."""
    cfg = get_config(arch, smoke=True)
    mc = cfg.model
    # capacity-based MoE drops depend on the token count; use a
    # non-saturating capacity so prefill(8 tok) == forward(12 tok) exactly
    if mc.moe.num_experts:
        mc.moe.capacity_factor = float(mc.moe.num_experts)
    key = jax.random.PRNGKey(0)
    params = T.init_params(mc, key)
    toks = MarkovLM(mc.vocab_size, seed=4).batch(2, 12)["tokens"]

    logits_all, _ = T.forward(mc, params, toks)
    lg_pref, caches = T.prefill(mc, params, toks[:, :8], max_len=16)
    np.testing.assert_allclose(np.asarray(lg_pref),
                               np.asarray(logits_all[:, 7]),
                               rtol=2e-2, atol=2e-2)
    pos = jnp.full((2,), 8, jnp.int32)
    for t in range(8, 11):
        lg_dec, caches = T.decode_step(mc, params, toks[:, t], pos, caches)
        np.testing.assert_allclose(np.asarray(lg_dec),
                                   np.asarray(logits_all[:, t]),
                                   rtol=3e-2, atol=3e-2)
        pos = pos + 1


def test_encdec_prefill_decode_matches_forward():
    cfg = get_config("whisper-large-v3", smoke=True)
    mc = cfg.model
    key = jax.random.PRNGKey(0)
    params = T.init_encdec_params(mc, key)
    frames = jax.random.normal(key, (2, mc.encoder_seq_len, mc.d_model))
    toks = MarkovLM(mc.vocab_size, seed=5).batch(2, 10)["tokens"]
    logits_all, _ = T.encdec_forward(mc, params, frames, toks)
    lg, cache = T.encdec_prefill(mc, params, frames, toks[:, :6],
                                 max_len=12)
    np.testing.assert_allclose(np.asarray(lg), np.asarray(logits_all[:, 5]),
                               rtol=2e-2, atol=2e-2)
    pos = jnp.full((2,), 6, jnp.int32)
    for t in range(6, 9):
        lg, cache = T.encdec_decode_step(mc, params, toks[:, t], pos, cache)
        np.testing.assert_allclose(np.asarray(lg),
                                   np.asarray(logits_all[:, t]),
                                   rtol=3e-2, atol=3e-2)
        pos = pos + 1


def test_sliding_window_ring_buffer_long_decode():
    """Decode past the window: ring cache must equal full forward."""
    cfg = get_config("h2o-danube-1.8b", smoke=True)
    mc = cfg.model          # window 8
    params = T.init_params(mc, jax.random.PRNGKey(1))
    toks = MarkovLM(mc.vocab_size, seed=6).batch(1, 20)["tokens"]
    logits_all, _ = T.forward(mc, params, toks)
    _, caches = T.prefill(mc, params, toks[:, :4], max_len=8)
    pos = jnp.full((1,), 4, jnp.int32)
    for t in range(4, 19):      # run well past window=8
        lg, caches = T.decode_step(mc, params, toks[:, t], pos, caches)
        np.testing.assert_allclose(np.asarray(lg),
                                   np.asarray(logits_all[:, t]),
                                   rtol=4e-2, atol=4e-2)
        pos = pos + 1


def test_segments_cover_all_layers():
    for arch in ARCH_IDS:
        cfg = get_config(arch, smoke=True)
        if cfg.model.is_encoder_decoder:
            continue
        segs = T.segments(cfg.model)
        n = sum(len(s.specs) * s.count for s in segs)
        assert n == cfg.model.num_layers, (arch, segs)


def test_full_configs_match_assignment():
    """Published numbers from the assignment table."""
    expect = {
        "whisper-large-v3": (32, 1280, 20, 20, 5120, 51866),
        "minicpm-2b": (40, 2304, 36, 36, 5760, 122753),
        "h2o-danube-1.8b": (24, 2560, 32, 8, 6912, 32000),
        "stablelm-1.6b": (24, 2048, 32, 32, 5632, 100352),
        "internlm2-1.8b": (24, 2048, 16, 8, 8192, 92544),
        "recurrentgemma-9b": (38, 4096, 16, 1, 12288, 256000),
        "pixtral-12b": (40, 5120, 32, 8, 14336, 131072),
        "falcon-mamba-7b": (64, 4096, 1, 1, 0, 65024),
    }
    for arch, (L, d, h, kv, ff, v) in expect.items():
        mc = get_config(arch).model
        assert (mc.num_layers, mc.d_model, mc.num_heads, mc.num_kv_heads,
                mc.d_ff, mc.vocab_size) == (L, d, h, kv, ff, v), arch
    ds = get_config("deepseek-v3-671b").model
    assert (ds.num_layers, ds.d_model, ds.num_heads) == (61, 7168, 128)
    assert (ds.moe.num_experts, ds.moe.top_k, ds.moe.d_ff_expert) \
        == (256, 8, 2048)
    ol = get_config("olmoe-1b-7b").model
    assert (ol.moe.num_experts, ol.moe.top_k) == (64, 8)
