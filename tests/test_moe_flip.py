"""MoE flip-repair soundness (DESIGN.md §2.7).

Two pins back the overlap scheduler's plan-level MoE repair:

1. ``models/moe.flipped_assignments`` — the detector deciding which
   speculative routing survives — against a brute-force numpy placement
   oracle, across random routing perturbations × capacity overflow ×
   starved experts. The detector must catch *placement* changes, not
   just expert-id changes: a flip elsewhere in a segment displaces every
   later position and can push previously-kept assignments over
   capacity.
2. Bitwise overlap == serial on the routed-MoE fixture where the
   post-quantization stream genuinely flips routing assignments (the
   counters prove the speculation engaged and repaired, not serialized).
"""
import types

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.config import MoEConfig
from repro.models import moe as moe_mod

from _hypothesis_shim import given, settings, st


# ---------------------------------------------------------------------------
# Brute-force placement oracle
# ---------------------------------------------------------------------------

def _mcfg(e: int, k: int, capacity_factor: float = 1.25):
    """Minimal stand-in carrying only what plan_from_head reads."""
    return types.SimpleNamespace(moe=MoEConfig(
        num_experts=e, top_k=k, capacity_factor=capacity_factor))


def _head(experts: np.ndarray, seed: int) -> moe_mod.RouteHead:
    gates = jax.random.uniform(jax.random.PRNGKey(seed), experts.shape)
    gates = gates / gates.sum(-1, keepdims=True)
    return moe_mod.RouteHead(jnp.asarray(experts, jnp.int32), gates,
                             jnp.float32(0.0))


def _oracle_slots(experts: np.ndarray, e: int, cap: int) -> np.ndarray:
    """(T*K,) flat-order buffer row per assignment, by direct simulation:
    walk the stable sort order, hand out intra-segment positions first
    come first served, overflow collapses to the E*cap drop row."""
    flat = experts.reshape(-1)
    slot = np.empty(flat.size, np.int64)
    handed = np.zeros(e, np.int64)
    for i in np.argsort(flat, kind="stable"):
        ex = int(flat[i])
        pos = handed[ex]
        handed[ex] += 1
        slot[i] = ex * cap + pos if pos < cap else e * cap
    return slot


def _scenario_experts(rng: np.random.Generator, scenario: str,
                      t: int, k: int, e: int) -> np.ndarray:
    if scenario == "overflow":
        # concentrate most assignments on two experts so segments blow
        # past capacity and the drop row engages
        pool = rng.choice([0, 1], size=(t, k)).astype(np.int64)
        mask = rng.random((t, k)) < 0.2
        return np.where(mask, rng.integers(0, e, (t, k)), pool)
    if scenario == "starved":
        # upper half of the expert range never routed
        return rng.integers(0, max(1, e // 2), (t, k))
    return rng.integers(0, e, (t, k))


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 10 ** 6),
       scenario=st.sampled_from(["sparse", "overflow", "starved"]))
def test_flipped_assignments_matches_oracle(seed, scenario):
    rng = np.random.default_rng(seed)
    t, k, e = 16, 2, 8
    cfg = _mcfg(e, k)
    cap = moe_mod._capacity(cfg, t)

    true_e = _scenario_experts(rng, scenario, t, k, e)
    # perturb a random subset of assignments to fresh experts — the
    # "speculative" routing the repair must vet against the true one
    spec_e = true_e.copy()
    n_flip = int(rng.integers(0, t * k // 2 + 1))
    idx = rng.choice(t * k, size=n_flip, replace=False)
    spec_e.reshape(-1)[idx] = rng.integers(0, e, n_flip)

    spec = moe_mod.plan_from_head(cfg, _head(spec_e, seed))
    true = moe_mod.plan_from_head(cfg, _head(true_e, seed + 1))
    got = np.asarray(moe_mod.flipped_assignments(spec, true))

    want = ((spec_e.reshape(-1) != true_e.reshape(-1))
            | (_oracle_slots(spec_e, e, cap) != _oracle_slots(true_e, e,
                                                              cap)))
    np.testing.assert_array_equal(got, want)
    # self-comparison never flips
    assert not np.asarray(moe_mod.flipped_assignments(true, true)).any()


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10 ** 6),
       scenario=st.sampled_from(["sparse", "overflow", "starved"]))
def test_reuse_plan_bitwise_when_no_flips(seed, scenario):
    """Zero flips ⇒ the speculative structure rebinds to the true head
    bitwise — the lemma the overlap flip-repair rests on."""
    rng = np.random.default_rng(seed)
    t, k, e = 16, 2, 8
    cfg = _mcfg(e, k)
    experts = _scenario_experts(rng, scenario, t, k, e)

    spec = moe_mod.plan_from_head(cfg, _head(experts, seed))
    head_true = _head(experts, seed + 1)       # same experts, fresh gates
    reused = moe_mod.reuse_plan(spec, head_true)
    direct = moe_mod.plan_from_head(cfg, head_true)

    assert reused.cap == direct.cap
    for f in ("experts", "gates", "aux", "order", "se", "st", "sg",
              "keep", "slot", "counts"):
        np.testing.assert_array_equal(
            np.asarray(getattr(reused, f)), np.asarray(getattr(direct, f)),
            err_msg=f)
    # and the scatter built from either plan is identical
    xt = jax.random.normal(jax.random.PRNGKey(seed + 2), (t, 4))
    np.testing.assert_array_equal(
        np.asarray(moe_mod.apply_route(reused, xt)),
        np.asarray(moe_mod.apply_route(direct, xt)))


# ---------------------------------------------------------------------------
# End-to-end: overlap == serial with genuine routing flips
# ---------------------------------------------------------------------------

def test_overlap_bitwise_serial_with_real_flips():
    """The routed-MoE fixture genuinely flips routing between the
    speculative (pre-quant) and true (post-quant) streams; the repair
    must keep packed artifacts bitwise serial while the counters prove
    speculation engaged."""
    from test_pipeline_stream import (_assert_reports_equal,
                                      _assert_trees_bitwise, _run)
    pq_s, rep_s, packed_s = _run("olmoe-1b-7b", "serial")
    pq_o, rep_o, packed_o = _run("olmoe-1b-7b", "overlap")
    st_o = rep_o.pipeline_stats
    # speculation engaged (flip repair, not serial re-capture) …
    assert st_o["spec_captures"] == st_o["steps"] - 1 > 0
    assert st_o["serial_fallbacks"] == 0
    # … on a fixture with nonzero genuine flips
    assert st_o["moe_flipped_assignments"] > 0
    assert st_o["moe_flip_repairs"] > 0
    assert 0 < st_o["moe_flipped_assignments"] <= st_o["moe_assignments"]
    # … and the artifacts are bitwise the serial walk's
    _assert_trees_bitwise(pq_s, pq_o, "moe-flip params")
    _assert_trees_bitwise(packed_s, packed_o, "moe-flip packed")
    _assert_reports_equal(rep_s, rep_o)


def test_capacity_dropped_tokens_reported():
    """Tokens dropped by expert capacity during capture are counted per
    layer — calibration-coverage honesty (ISSUE 10 satellite)."""
    from test_pipeline_stream import _run
    _, rep, _ = _run("olmoe-1b-7b", "serial")
    assert rep.moe_capacity_dropped, "fixture routes past capacity"
    assert all(isinstance(v, int) and v >= 0
               for v in rep.moe_capacity_dropped.values())
    # serial and overlap agree on the per-layer counts
    _, rep_o, _ = _run("olmoe-1b-7b", "overlap")
    assert rep_o.moe_capacity_dropped == rep.moe_capacity_dropped
