"""Property tests for the shared int8 block codec (kernels/kv_codec.py).

One suite pins the invariants BOTH consumers rely on — the gradient wire
format (distributed/compression.py) and the quantized decode KV cache
(models/attention.py):

- round-trip error per element is bounded by half its block's scale
- all-zero blocks reconstruct exactly
- the flat codec's zero-padding tail never leaks into real elements
- enc∘dec∘enc is code-bitwise idempotent (requantizing a reconstruction
  reproduces the codes) on non-degenerate inputs
- the compression-module wrappers are bitwise the codec at WIRE_BLOCK=256
  (the wire format predates the shared codec and must not move)

Runs under tests/_hypothesis_shim.py: real hypothesis when installed, a
deterministic bounds+midpoint grid otherwise.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from tests._hypothesis_shim import given, settings, st

from repro.distributed import compression as C
from repro.kernels import kv_codec


def _rand(shape, seed, scale=3.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(0.0, scale, shape).astype(np.float32))


class TestFlatCodec:
    """enc_int8/dec_int8 — the ravel-pad-block wire entry point."""

    @settings(max_examples=20, deadline=None)
    @given(n=st.integers(min_value=1, max_value=700),
           seed=st.integers(min_value=0, max_value=3))
    def test_roundtrip_error_bound(self, n, seed):
        x = _rand((n,), seed)
        q, s = kv_codec.enc_int8(x)
        y = kv_codec.dec_int8(q, s, x.shape)
        # element i lives in block i // 256; |x - dec(enc(x))| <= scale/2
        per_elem_scale = np.repeat(np.asarray(s), kv_codec.WIRE_BLOCK)[:n]
        err = np.abs(np.asarray(y) - np.asarray(x))
        assert np.all(err <= per_elem_scale / 2 + 1e-7)

    @settings(max_examples=10, deadline=None)
    @given(n=st.integers(min_value=1, max_value=600))
    def test_all_zero_exact(self, n):
        x = jnp.zeros((n,), jnp.float32)
        q, s = kv_codec.enc_int8(x)
        assert not np.any(np.asarray(q))
        np.testing.assert_array_equal(
            np.asarray(kv_codec.dec_int8(q, s, x.shape)), 0.0)

    @settings(max_examples=20, deadline=None)
    @given(n=st.integers(min_value=1, max_value=700),
           seed=st.integers(min_value=0, max_value=3))
    def test_padding_tail_invariance(self, n, seed):
        """Encoding a ragged tail == encoding the explicitly zero-padded
        tensor then truncating — the pad never changes real elements."""
        x = _rand((n,), seed)
        blk = kv_codec.WIRE_BLOCK
        pad = (-n) % blk
        xp = jnp.concatenate([x, jnp.zeros((pad,), jnp.float32)])
        q1, s1 = kv_codec.enc_int8(x)
        q2, s2 = kv_codec.enc_int8(xp)
        np.testing.assert_array_equal(np.asarray(q1), np.asarray(q2))
        np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))
        np.testing.assert_array_equal(
            np.asarray(kv_codec.dec_int8(q1, s1, x.shape)),
            np.asarray(kv_codec.dec_int8(q2, s2, xp.shape))[:n])

    @settings(max_examples=20, deadline=None)
    @given(n=st.integers(min_value=1, max_value=700),
           seed=st.integers(min_value=0, max_value=3))
    def test_enc_dec_enc_idempotent(self, n, seed):
        """Requantizing a reconstruction is a code-level fixed point.
        (Scales match to ~1 ulp, not bitwise; degenerate eps-dominated
        blocks are excluded by the non-tiny magnitudes of _rand.)"""
        x = _rand((n,), seed)
        q1, s1 = kv_codec.enc_int8(x)
        y = kv_codec.dec_int8(q1, s1, x.shape)
        q2, s2 = kv_codec.enc_int8(y)
        np.testing.assert_array_equal(np.asarray(q1), np.asarray(q2))
        np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                                   rtol=1e-6)


class TestBlockCodec:
    """enc_int8_blocks/dec_int8_blocks — the trailing-dim KV entry point."""

    @settings(max_examples=20, deadline=None)
    @given(block=st.sampled_from([32, 64, 128, 256]),
           nb=st.integers(min_value=1, max_value=3),
           seed=st.integers(min_value=0, max_value=2))
    def test_roundtrip_error_bound(self, block, nb, seed):
        x = _rand((2, 5, nb * block), seed)
        q, s = kv_codec.enc_int8_blocks(x, block)
        assert q.shape == x.shape and q.dtype == jnp.int8
        assert s.shape == x.shape[:-1] + (nb,)
        y = kv_codec.dec_int8_blocks(q, s, block)
        bound = np.repeat(np.asarray(s), block, axis=-1) / 2
        assert np.all(np.abs(np.asarray(y) - np.asarray(x))
                      <= bound + 1e-7)

    @settings(max_examples=10, deadline=None)
    @given(block=st.sampled_from([32, 64, 128, 256]))
    def test_all_zero_exact(self, block):
        x = jnp.zeros((3, 2, block), jnp.float32)
        q, s = kv_codec.enc_int8_blocks(x, block)
        np.testing.assert_array_equal(
            np.asarray(kv_codec.dec_int8_blocks(q, s, block)), 0.0)

    @settings(max_examples=20, deadline=None)
    @given(block=st.sampled_from([32, 64, 128, 256]),
           seed=st.integers(min_value=0, max_value=2))
    def test_enc_dec_enc_idempotent(self, block, seed):
        x = _rand((4, 2 * block), seed)
        q1, s1 = kv_codec.enc_int8_blocks(x, block)
        y = kv_codec.dec_int8_blocks(q1, s1, block)
        q2, s2 = kv_codec.enc_int8_blocks(y, block)
        np.testing.assert_array_equal(np.asarray(q1), np.asarray(q2))
        np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                                   rtol=1e-6)

    def test_rejects_ragged_trailing_dim(self):
        with pytest.raises(AssertionError):
            kv_codec.enc_int8_blocks(jnp.zeros((2, 65)), 64)


class TestDefaultBlock:
    def test_prefers_largest_divisor(self):
        assert kv_codec.default_kv_block(128) == 128
        assert kv_codec.default_kv_block(256) == 128
        assert kv_codec.default_kv_block(64) == 64
        assert kv_codec.default_kv_block(96) == 32
        assert kv_codec.default_kv_block(80) == 80   # no divisor -> whole dim


class TestWireFormatPinned:
    """The gradient wire format must be bitwise what it was before the
    codec was extracted: per-256-block absmax, eps 1e-12, round+clip."""

    def test_wrappers_are_the_codec_at_wire_block(self):
        g = _rand((3, 7, 19), 0)
        q1, s1 = C._enc_int8(g.astype(jnp.float32))
        q2, s2 = kv_codec.enc_int8(g.astype(jnp.float32), block=256)
        np.testing.assert_array_equal(np.asarray(q1), np.asarray(q2))
        np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))
        np.testing.assert_array_equal(
            np.asarray(C._dec_int8(q1, s1, g.shape)),
            np.asarray(kv_codec.dec_int8(q2, s2, g.shape, block=256)))

    def test_bitwise_vs_inline_reference(self):
        """Inline re-statement of the pre-extraction math."""
        g = _rand((1000,), 1)
        flat = np.asarray(g, np.float32)
        n = flat.size
        nb = -(-n // 256)
        padded = np.zeros((nb * 256,), np.float32)
        padded[:n] = flat
        blocks = padded.reshape(nb, 256)
        scale = np.max(np.abs(blocks), axis=1) / 127.0 + 1e-12
        ref_q = np.clip(np.round(blocks / scale[:, None]), -127, 127
                        ).astype(np.int8)
        q, s = C._enc_int8(g)
        np.testing.assert_array_equal(np.asarray(q), ref_q)
        np.testing.assert_allclose(np.asarray(s), scale.astype(np.float32),
                                   rtol=0, atol=0)

    def test_compress_psum_int8_unchanged(self):
        """End-to-end wire path still reconstructs within codec error."""
        grads = {"w": _rand((300,), 2)}

        def f(g):
            out, err = C.compress_psum(g, "data", method="int8")
            return out, err

        mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]), ("data",))
        out, err = jax.experimental.shard_map.shard_map(
            f, mesh=mesh,
            in_specs=(jax.sharding.PartitionSpec(),),
            out_specs=jax.sharding.PartitionSpec())(grads)
        q, s = C._enc_int8(grads["w"])
        per_elem = np.repeat(np.asarray(s), 256)[:300]
        assert np.all(np.abs(np.asarray(out["w"]) - np.asarray(grads["w"]))
                      <= per_elem / 2 + 1e-7)
        np.testing.assert_allclose(np.asarray(grads["w"]),
                                   np.asarray(out["w"]) + np.asarray(err["w"]),
                                   rtol=1e-5, atol=1e-6)
