"""Training substrate: optimizer, schedules, grad accum, int8 moments."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import Config, ModelConfig, TrainConfig
from repro.configs import get_config
from repro.data import MarkovLM, SentimentTask
from repro.training import optimizer as opt
from repro.training.schedule import learning_rate
from repro.training.train_step import (TrainState, init_train_state,
                                       make_train_step)


class TestSchedules:
    def test_warmup(self):
        tc = TrainConfig(lr=1e-3, warmup_steps=10, steps=100,
                         schedule="cosine")
        # step 0 takes a small but NONZERO lr ((s+1)/warm — a zero first
        # step makes one-step smoke tests vacuous)
        assert abs(float(learning_rate(tc, 0)) - 1e-4) < 1e-9
        assert abs(float(learning_rate(tc, 9)) - 1e-3) < 1e-9

    def test_cosine_decays_to_zero(self):
        tc = TrainConfig(lr=1e-3, warmup_steps=10, steps=100,
                         schedule="cosine")
        assert float(learning_rate(tc, 100)) < 1e-6

    def test_wsd_plateau_then_decay(self):
        tc = TrainConfig(lr=1e-3, warmup_steps=10, steps=100,
                         schedule="wsd", wsd_stable_frac=0.5)
        assert abs(float(learning_rate(tc, 30)) - 1e-3) < 1e-9
        assert abs(float(learning_rate(tc, 54)) - 1e-3) < 1e-9
        assert float(learning_rate(tc, 99)) < 4e-4


class TestOptimizer:
    def _setup(self):
        params = {"a": jnp.ones((64, 32)), "b": jnp.zeros((7,))}
        grads = {"a": jnp.full((64, 32), 0.1), "b": jnp.ones((7,))}
        return params, grads

    def test_adamw_moves_params(self):
        params, grads = self._setup()
        st = opt.adamw_init(params)
        tc = TrainConfig()
        new_p, st = opt.adamw_update(grads, st, params,
                                     lr=jnp.float32(1e-2), tc=tc)
        assert float(jnp.max(jnp.abs(new_p["a"] - params["a"]))) > 1e-4

    def test_int8_moments_close_to_exact(self):
        params, grads = self._setup()
        tc = TrainConfig(weight_decay=0.0)
        st_f = opt.adamw_init(params, int8=False)
        st_q = opt.adamw_init(params, int8=True)
        p_f, p_q = params, params
        for i in range(5):
            g = jax.tree_util.tree_map(
                lambda x: x * (1.0 + 0.1 * i), grads)
            p_f, st_f = opt.adamw_update(g, st_f, p_f,
                                         lr=jnp.float32(1e-2), tc=tc)
            p_q, st_q = opt.adamw_update(g, st_q, p_q,
                                         lr=jnp.float32(1e-2), tc=tc,
                                         int8=True)
        rel = float(jnp.linalg.norm(p_f["a"] - p_q["a"])
                    / jnp.linalg.norm(p_f["a"] - params["a"]))
        assert rel < 0.1, rel          # int8 noise ≪ actual update

    def test_int8_state_is_4x_smaller(self):
        params = {"a": jnp.ones((256, 256))}
        st_f = opt.adamw_init(params)
        st_q = opt.adamw_init(params, int8=True)
        bytes_f = sum(l.size * l.dtype.itemsize
                      for l in jax.tree_util.tree_leaves(st_f.m))
        bytes_q = sum(l.size * l.dtype.itemsize
                      for l in jax.tree_util.tree_leaves(st_q.m))
        assert bytes_q < bytes_f / 3.5

    def test_clip_by_global_norm(self):
        g = {"a": jnp.full((10,), 10.0)}
        clipped, gn = opt.clip_by_global_norm(g, 1.0)
        assert abs(float(opt.global_norm(clipped)) - 1.0) < 1e-4


class TestTrainStep:
    def test_loss_decreases_tiny_model(self):
        cfg = get_config("opt-proxy", smoke=True)
        cfg.train.lr = 3e-3
        cfg.train.warmup_steps = 2
        cfg.train.steps = 30
        st = init_train_state(cfg, jax.random.PRNGKey(0))
        step = jax.jit(make_train_step(cfg))
        data = MarkovLM(cfg.model.vocab_size, seed=0, branching=3)
        losses = []
        for i in range(30):
            batch = data.batch(8, 32)
            st, m = step(st, batch)
            losses.append(float(m["loss"]))
        assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.3, losses

    def test_grad_accum_equivalence(self):
        cfg = get_config("opt-proxy", smoke=True)
        st0 = init_train_state(cfg, jax.random.PRNGKey(0))
        batch = MarkovLM(cfg.model.vocab_size, seed=2).batch(8, 16)
        step1 = jax.jit(make_train_step(cfg))
        st1, m1 = step1(st0, batch)
        cfg.train.grad_accum = 4
        step4 = jax.jit(make_train_step(cfg))
        st4, m4 = step4(st0, batch)
        # Adam's first step is ±lr·sign(m/√v): where the true gradient is
        # ~0, accumulation-order noise flips the sign, so tolerance must
        # cover one warmup-lr step (3e-5); real accumulation bugs diverge
        # by the full update scale instead.
        for a, b in zip(jax.tree_util.tree_leaves(st1.params),
                        jax.tree_util.tree_leaves(st4.params)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=2e-4, atol=1e-4)

    def test_sentiment_task_learnable(self):
        """The paper's downstream-accuracy proxy is actually learnable."""
        cfg = get_config("opt-proxy", smoke=True)
        cfg.train.lr = 2e-3
        cfg.train.warmup_steps = 5
        cfg.train.steps = 60
        task = SentimentTask(cfg.model.vocab_size, seed=0)
        st = init_train_state(cfg, jax.random.PRNGKey(0))
        step = jax.jit(make_train_step(cfg))
        for i in range(60):
            batch, labels = task.batch(16, 24)
            st, m = step(st, batch)
        from repro.models import transformer as T
        batch, labels = task.batch(64, 24)
        logits, _ = T.forward(cfg.model, st.params, batch["tokens"])
        acc = task.accuracy(logits[:, -2], labels)
        assert acc > 0.55, acc          # 3 classes, chance = 0.33
