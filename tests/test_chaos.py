"""Chaos-soak invariants as a pytest surface (``-m chaos``).

The harness itself lives in scripts/chaos_soak.py (docs/FAULTS.md §Chaos
soak) and scripts/check.sh runs it over seeds 0,1,2; this suite drives
the same invariant checkers from pytest on *different* seeds, so marker
runs widen schedule coverage instead of re-verifying CI's fixed seeds.
"""
import os
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..",
                                "scripts"))
import chaos_soak  # noqa: E402

pytestmark = pytest.mark.chaos


class TestChaosSoak:
    def test_serving_invariants_fresh_seed(self):
        violations = chaos_soak.run_serving_soak(seed=7, smoke=True)
        assert violations == []

    def test_quantize_invariants_fresh_seed(self):
        violations = chaos_soak.run_quantize_soak(seed=7, smoke=True)
        assert violations == []

    def test_arm_string_is_seed_deterministic(self):
        import numpy as np
        a = chaos_soak._arm_string(chaos_soak._SERVE_SITES,
                                   np.random.default_rng(5))
        b = chaos_soak._arm_string(chaos_soak._SERVE_SITES,
                                   np.random.default_rng(5))
        assert a == b and a          # same rng → same schedule, non-empty
