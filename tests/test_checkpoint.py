"""Checkpoint/restart: atomicity, retention, async, elasticity, data state."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data import MarkovLM
from repro.distributed.checkpoint import Checkpointer, SignalCheckpointer
from repro.training.train_step import init_train_state
from repro.training.trainer import train


@pytest.fixture
def state():
    cfg = get_config("opt-proxy", smoke=True)
    return init_train_state(cfg, jax.random.PRNGKey(0))


class TestCheckpointer:
    def test_save_restore_roundtrip(self, state, tmp_path):
        ck = Checkpointer(str(tmp_path), async_write=False)
        ck.save(3, state, extra={"step": 3, "data": {"seed": 1, "step": 9}})
        restored, extra = ck.restore(state)
        assert extra["step"] == 3 and extra["data"]["step"] == 9
        for a, b in zip(jax.tree_util.tree_leaves(state),
                        jax.tree_util.tree_leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_async_save(self, state, tmp_path):
        ck = Checkpointer(str(tmp_path), async_write=True)
        ck.save(1, state)
        ck.wait()
        assert ck.latest_step() == 1

    def test_atomic_no_tmp_left(self, state, tmp_path):
        ck = Checkpointer(str(tmp_path), async_write=False)
        ck.save(1, state)
        assert not any(d.endswith(".tmp") for d in os.listdir(tmp_path))
        with open(tmp_path / "LATEST") as f:
            assert f.read().strip() == "step_000000001"

    def test_partial_write_not_latest(self, state, tmp_path):
        """A crashed write (tmp dir present, no rename) must be invisible."""
        ck = Checkpointer(str(tmp_path), async_write=False)
        ck.save(1, state)
        os.makedirs(tmp_path / "step_000000002.tmp")
        assert ck.latest_step() == 1
        restored, _ = ck.restore(state)   # still loads step 1

    def test_retention(self, state, tmp_path):
        ck = Checkpointer(str(tmp_path), keep=2, async_write=False)
        for s in (1, 2, 3, 4):
            ck.save(s, state)
        steps = sorted(d for d in os.listdir(tmp_path)
                       if d.startswith("step_"))
        assert len(steps) == 2 and steps[-1] == "step_000000004"

    def test_missing_leaf_raises(self, state, tmp_path):
        ck = Checkpointer(str(tmp_path), async_write=False)
        ck.save(1, state)
        bigger = {"params": state.params, "extra_leaf": jnp.zeros((3,))}
        with pytest.raises(KeyError):
            ck.restore(bigger)


class TestTrainerIntegration:
    def test_restart_resumes_exactly(self, tmp_path):
        """Train 6 steps; train 3 + restart + 3 must match bit-for-bit
        (including the data stream position)."""
        def mk():
            cfg = get_config("opt-proxy", smoke=True)
            cfg.train.steps = 6
            cfg.train.ckpt_every = 3
            cfg.train.ckpt_dir = str(tmp_path / "a")
            cfg.train.ckpt_async = False
            cfg.train.log_every = 100
            return cfg

        out1 = train(mk(), MarkovLM(256, seed=5), verbose=False,
                     restore=False)

        cfg = mk()
        cfg.train.steps = 3
        cfg.train.ckpt_dir = str(tmp_path / "b")
        train(cfg, MarkovLM(256, seed=5), verbose=False, restore=False)
        cfg2 = mk()
        cfg2.train.ckpt_dir = str(tmp_path / "b")
        out2 = train(cfg2, MarkovLM(256, seed=5), verbose=False,
                     restore=True)
        p1 = jax.tree_util.tree_leaves(out1["state"].params)
        p2 = jax.tree_util.tree_leaves(out2["state"].params)
        for a, b in zip(p1, p2):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=1e-6)

    def test_sigterm_requests_checkpoint(self, tmp_path):
        import signal
        sig = SignalCheckpointer().install()
        try:
            os.kill(os.getpid(), signal.SIGTERM)
            assert sig.requested
        finally:
            sig.uninstall()


class TestPackedServingArtifacts:
    """Round-tripping quantized serving artifacts (int4-packed trees with
    QuantizedTensor pytree leaves + scale/zero metadata) and the raw-array
    loader the quantize-resume path uses."""

    def _packed(self):
        from repro.core.pipeline import pack_for_serving
        from repro.models import transformer as T
        cfg = get_config("opt-proxy", smoke=True)
        params = T.init_params(cfg.model, jax.random.PRNGKey(0))
        return pack_for_serving(cfg, params)

    def test_packed_tree_roundtrip_bitwise(self, tmp_path):
        packed = self._packed()
        ck = Checkpointer(str(tmp_path), async_write=False)
        ck.save(1, packed, extra={"arch": "opt-proxy"})
        restored, extra = ck.restore(packed)
        assert extra["arch"] == "opt-proxy"
        ref = jax.tree_util.tree_leaves(packed)
        got = jax.tree_util.tree_leaves(restored)
        assert len(ref) == len(got)
        for a, b in zip(ref, got):
            a, b = np.asarray(jax.device_get(a)), np.asarray(jax.device_get(b))
            assert a.dtype == b.dtype           # uint8 codes stay uint8
            np.testing.assert_array_equal(a, b)

    def test_bfloat16_leaves_roundtrip_bitwise(self, tmp_path):
        """np.savez silently stores bf16 as raw void bytes; the codec must
        view-encode/decode so restore returns real bf16 values."""
        tree = {"h": (jnp.arange(16, dtype=jnp.bfloat16) / 3.0),
                "f": jnp.linspace(0, 1, 7, dtype=jnp.float32)}
        ck = Checkpointer(str(tmp_path), async_write=False)
        ck.save(2, tree)
        restored, _ = ck.restore(tree)
        assert restored["h"].dtype == jnp.bfloat16
        np.testing.assert_array_equal(
            np.asarray(tree["h"]).view(np.uint16),
            np.asarray(restored["h"]).view(np.uint16))
        np.testing.assert_array_equal(np.asarray(tree["f"]),
                                      np.asarray(restored["f"]))

    def test_load_arrays_without_template(self, tmp_path):
        """load_arrays returns the name→array dict + extra with no template
        tree — what quantize-resume uses before the walker exists."""
        tree = {"streams": {"resid": {"000": jnp.ones((2, 3), jnp.bfloat16)}},
                "stored": {"layer0": {"w": jnp.arange(4.0)}}}
        ck = Checkpointer(str(tmp_path), async_write=False)
        ck.save(5, tree, extra={"item_idx": 1})
        arrays, extra = ck.load_arrays()
        assert extra["item_idx"] == 1
        key = [k for k in arrays if "resid" in k][0]
        assert arrays[key].dtype == np.dtype("bfloat16")
        np.testing.assert_array_equal(
            arrays[key], np.ones((2, 3), np.dtype("bfloat16")))
        with pytest.raises(FileNotFoundError):
            Checkpointer(str(tmp_path / "empty")).load_arrays()


class TestIntegrityManifests:
    """Per-leaf crc32 manifests (schema 2) + artifact sha256 sidecars: a
    flipped byte anywhere in a saved artifact or step checkpoint is a
    typed error at load, never a silent garbage load."""

    def _quant_tree(self):
        # real packed-int4 QuantizedTensor leaves + a bf16 leaf, the two
        # encodings the npz view codec has to round-trip exactly
        from repro.core.pipeline import pack_for_serving
        from repro.models import transformer as T
        cfg = get_config("opt-proxy", smoke=True)
        params = T.init_params(cfg.model, jax.random.PRNGKey(1))
        packed = pack_for_serving(cfg, params)
        return {"packed": packed, "gamma": jnp.ones((7,), jnp.bfloat16)}

    def test_manifest_roundtrip_int4_and_bf16(self, tmp_path):
        from repro.distributed.checkpoint import CHECKPOINT_SCHEMA
        tree = self._quant_tree()
        ck = Checkpointer(str(tmp_path), async_write=False)
        ck.save(1, tree)
        with open(tmp_path / "step_000000001" / "manifest.json") as f:
            man = json.load(f)
        assert man["schema"] == CHECKPOINT_SCHEMA
        assert all("crc32" in v for v in man["leaves"].values())
        restored, _ = ck.restore(tree)
        for a, b in zip(jax.tree_util.tree_leaves(tree),
                        jax.tree_util.tree_leaves(restored)):
            a, b = np.asarray(a), np.asarray(b)
            assert a.dtype == b.dtype
            np.testing.assert_array_equal(a.view(np.uint8),
                                          b.view(np.uint8))

    def test_flipped_byte_detected_at_load(self, state, tmp_path):
        from repro.distributed.checkpoint import CheckpointIntegrityError
        ck = Checkpointer(str(tmp_path), async_write=False)
        ck.save(1, state)
        npz = tmp_path / "step_000000001" / "arrays.npz"
        raw = bytearray(npz.read_bytes())
        raw[len(raw) // 2] ^= 0xFF
        npz.write_bytes(bytes(raw))
        with pytest.raises(CheckpointIntegrityError):
            ck.restore(state)
        with pytest.raises(CheckpointIntegrityError):
            ck.load_arrays()

    def test_artifact_roundtrip_and_corruption(self, tmp_path):
        from repro.distributed.checkpoint import (ArtifactIntegrityError,
                                                  load_artifact,
                                                  save_artifact)
        tree = self._quant_tree()
        path = str(tmp_path / "m.params.pkl")
        save_artifact(path, jax.device_get(tree), extra={"arch": "t"})
        assert os.path.exists(path + ".manifest.json")
        back = load_artifact(path)
        for a, b in zip(jax.tree_util.tree_leaves(tree),
                        jax.tree_util.tree_leaves(back)):
            a, b = np.asarray(a), np.asarray(b)
            np.testing.assert_array_equal(a.view(np.uint8),
                                          b.view(np.uint8))
        raw = bytearray(open(path, "rb").read())
        raw[len(raw) // 2] ^= 0x01          # single flipped bit
        open(path, "wb").write(bytes(raw))
        with pytest.raises(ArtifactIntegrityError):
            load_artifact(path)

    def test_legacy_artifact_warns_not_fails(self, tmp_path):
        import pickle
        from repro.distributed.checkpoint import load_artifact
        path = str(tmp_path / "old.params.pkl")
        with open(path, "wb") as f:
            pickle.dump({"w": np.ones((2, 2), np.float32)}, f)
        with pytest.warns(RuntimeWarning, match="no integrity manifest"):
            back = load_artifact(path)
        np.testing.assert_array_equal(back["w"], np.ones((2, 2)))

    def test_load_fault_site_corrupt_mode(self, state, tmp_path):
        from repro.core import faults
        from repro.distributed.checkpoint import CheckpointIntegrityError
        ck = Checkpointer(str(tmp_path), async_write=False)
        ck.save(1, state)
        with faults.inject("checkpoint.load@1:corrupt"):
            with pytest.raises(CheckpointIntegrityError):
                ck.restore(state)
        with faults.inject("checkpoint.load@1"):
            with pytest.raises(faults.FaultError):
                ck.restore(state)
        restored, _ = ck.restore(state)      # disarmed: loads fine
