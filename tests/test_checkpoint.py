"""Checkpoint/restart: atomicity, retention, async, elasticity, data state."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data import MarkovLM
from repro.distributed.checkpoint import Checkpointer, SignalCheckpointer
from repro.training.train_step import init_train_state
from repro.training.trainer import train


@pytest.fixture
def state():
    cfg = get_config("opt-proxy", smoke=True)
    return init_train_state(cfg, jax.random.PRNGKey(0))


class TestCheckpointer:
    def test_save_restore_roundtrip(self, state, tmp_path):
        ck = Checkpointer(str(tmp_path), async_write=False)
        ck.save(3, state, extra={"step": 3, "data": {"seed": 1, "step": 9}})
        restored, extra = ck.restore(state)
        assert extra["step"] == 3 and extra["data"]["step"] == 9
        for a, b in zip(jax.tree_util.tree_leaves(state),
                        jax.tree_util.tree_leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_async_save(self, state, tmp_path):
        ck = Checkpointer(str(tmp_path), async_write=True)
        ck.save(1, state)
        ck.wait()
        assert ck.latest_step() == 1

    def test_atomic_no_tmp_left(self, state, tmp_path):
        ck = Checkpointer(str(tmp_path), async_write=False)
        ck.save(1, state)
        assert not any(d.endswith(".tmp") for d in os.listdir(tmp_path))
        with open(tmp_path / "LATEST") as f:
            assert f.read().strip() == "step_000000001"

    def test_partial_write_not_latest(self, state, tmp_path):
        """A crashed write (tmp dir present, no rename) must be invisible."""
        ck = Checkpointer(str(tmp_path), async_write=False)
        ck.save(1, state)
        os.makedirs(tmp_path / "step_000000002.tmp")
        assert ck.latest_step() == 1
        restored, _ = ck.restore(state)   # still loads step 1

    def test_retention(self, state, tmp_path):
        ck = Checkpointer(str(tmp_path), keep=2, async_write=False)
        for s in (1, 2, 3, 4):
            ck.save(s, state)
        steps = sorted(d for d in os.listdir(tmp_path)
                       if d.startswith("step_"))
        assert len(steps) == 2 and steps[-1] == "step_000000004"

    def test_missing_leaf_raises(self, state, tmp_path):
        ck = Checkpointer(str(tmp_path), async_write=False)
        ck.save(1, state)
        bigger = {"params": state.params, "extra_leaf": jnp.zeros((3,))}
        with pytest.raises(KeyError):
            ck.restore(bigger)


class TestTrainerIntegration:
    def test_restart_resumes_exactly(self, tmp_path):
        """Train 6 steps; train 3 + restart + 3 must match bit-for-bit
        (including the data stream position)."""
        def mk():
            cfg = get_config("opt-proxy", smoke=True)
            cfg.train.steps = 6
            cfg.train.ckpt_every = 3
            cfg.train.ckpt_dir = str(tmp_path / "a")
            cfg.train.ckpt_async = False
            cfg.train.log_every = 100
            return cfg

        out1 = train(mk(), MarkovLM(256, seed=5), verbose=False,
                     restore=False)

        cfg = mk()
        cfg.train.steps = 3
        cfg.train.ckpt_dir = str(tmp_path / "b")
        train(cfg, MarkovLM(256, seed=5), verbose=False, restore=False)
        cfg2 = mk()
        cfg2.train.ckpt_dir = str(tmp_path / "b")
        out2 = train(cfg2, MarkovLM(256, seed=5), verbose=False,
                     restore=True)
        p1 = jax.tree_util.tree_leaves(out1["state"].params)
        p2 = jax.tree_util.tree_leaves(out2["state"].params)
        for a, b in zip(p1, p2):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=1e-6)

    def test_sigterm_requests_checkpoint(self, tmp_path):
        import signal
        sig = SignalCheckpointer().install()
        try:
            os.kill(os.getpid(), signal.SIGTERM)
            assert sig.requested
        finally:
            sig.uninstall()
