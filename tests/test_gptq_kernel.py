"""Fused Pallas gptq_block kernel vs the XLA sweep and the NumPy oracle.

The kernel mirrors ``core/gptq._gptq_core`` op for op (masked one-hot
extractions are exact, the tail update uses identical dot shapes), so
interpret-mode output is pinned bitwise-close (≤1e-6) across symmetric/
asymmetric modes, group sizes, non-square shapes, a padded-Cout row tile,
and the stacked member axis the quant plan feeds it.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from test_batched_parity import stack_problem  # noqa: F401  (fixture reuse)

from repro.core import hessian as hess
from repro.core.gptq import _gptq_core, gptq_quantize_batched
from repro.kernels import ops as kops
from repro.kernels import ref

pytestmark = pytest.mark.pallas


def _problem(cout, cin, seed=0):
    kw, kx = jax.random.split(jax.random.PRNGKey(seed))
    w = jax.random.normal(kw, (cout, cin)) * 0.1
    x = jax.random.normal(kx, (2 * cin, cin))
    st = hess.accumulate(hess.init_hessian(cin), x)
    u = hess.cholesky_inverse_upper(hess.damped(st, 0.01))
    return w, u


class TestGPTQBlockKernel:
    @pytest.mark.parametrize("symmetric", [False, True])
    @pytest.mark.parametrize("group_size,blocksize", [(64, 64), (128, 128),
                                                      (64, 128)])
    def test_matches_core_and_ref(self, symmetric, group_size, blocksize):
        """Non-square (48, 256): pallas == _gptq_core == NumPy oracle."""
        w, u = _problem(48, 256, seed=group_size + blocksize + symmetric)
        kw = dict(bits=4, group_size=group_size, blocksize=blocksize,
                  symmetric=symmetric)
        w_q, s, z, err = kops.gptq_block(w, u, impl="pallas", **kw)
        core = _gptq_core(w, u, **kw)
        np.testing.assert_allclose(np.asarray(w_q), np.asarray(core.w_q),
                                   atol=1e-6)
        np.testing.assert_allclose(np.asarray(s), np.asarray(core.scales),
                                   atol=1e-6)
        np.testing.assert_allclose(np.asarray(z), np.asarray(core.zeros),
                                   atol=1e-6)
        np.testing.assert_allclose(float(err), float(core.err), rtol=1e-4)
        wq_r, s_r, z_r, err_r = ref.gptq_block_ref(
            np.asarray(w), np.asarray(u), **kw)
        np.testing.assert_allclose(np.asarray(w_q), wq_r, atol=1e-6)
        np.testing.assert_allclose(np.asarray(s), s_r, atol=1e-6)
        np.testing.assert_allclose(np.asarray(z), z_r, atol=1e-6)

    def test_padded_cout_tile(self):
        """Cout = 20 with an explicit block_out = 8 → zero-padded row tile
        (24 rows, 3 grid tiles); padded rows must not perturb real ones."""
        w, u = _problem(20, 128, seed=3)
        kw = dict(bits=4, group_size=64, blocksize=64)
        w_q, s, z, err = kops.gptq_block(w, u, impl="pallas", block_out=8,
                                         **kw)
        core = _gptq_core(w, u, symmetric=False, **kw)
        assert w_q.shape == (20, 128) and s.shape == (20, 2)
        np.testing.assert_allclose(np.asarray(w_q), np.asarray(core.w_q),
                                   atol=1e-6)
        np.testing.assert_allclose(np.asarray(s), np.asarray(core.scales),
                                   atol=1e-6)
        np.testing.assert_allclose(float(err), float(core.err), rtol=1e-4)

    def test_batched_member_axis(self, stack_problem):
        """The stacked group slab maps onto the kernel's member grid axis:
        every lane matches the XLA batched path and per-member core."""
        p = stack_problem
        Hd = hess.damped(p["st"], 0.01)
        U = hess.cholesky_inverse_upper(Hd)
        kw = dict(bits=4, group_size=32, blocksize=64)
        res_p = gptq_quantize_batched(p["W"], U, impl="pallas", **kw)
        res_x = gptq_quantize_batched(p["W"], U, impl="xla", **kw)
        np.testing.assert_allclose(np.asarray(res_p.w_q),
                                   np.asarray(res_x.w_q), atol=1e-6)
        np.testing.assert_allclose(np.asarray(res_p.scales),
                                   np.asarray(res_x.scales), atol=1e-6)
        for i in range(p["B"]):
            r = _gptq_core(p["W"][i], U[i], symmetric=False, **kw)
            np.testing.assert_allclose(np.asarray(res_p.w_q[i]),
                                       np.asarray(r.w_q), atol=1e-6)

    def test_auto_impl_off_tpu_is_xla(self, stack_problem):
        p = stack_problem
        U = hess.cholesky_inverse_upper(hess.damped(p["st"], 0.01))
        kw = dict(bits=4, group_size=32, blocksize=64)
        res_a = gptq_quantize_batched(p["W"], U, impl="auto", **kw)
        res_x = gptq_quantize_batched(p["W"], U, impl="xla", **kw)
        np.testing.assert_array_equal(np.asarray(res_a.w_q),
                                      np.asarray(res_x.w_q))


class TestServingArtifactParity:
    def test_packed_artifacts_match_across_impls(self):
        """End to end: quantize + pack a tiny model under each sweep
        backend — packed int4 codes and grids must agree ≤1e-6."""
        from repro.configs import get_config
        from repro.core.pipeline import pack_for_serving, quantize_model
        from repro.core.quant import QuantizedTensor
        from repro.data import MarkovLM, calibration_batches
        from repro.models import transformer as T

        packs = []
        for impl in ("xla", "pallas"):
            cfg = get_config("opt-proxy", smoke=True)
            cfg.model.num_layers = 2
            cfg.quant.gptq_impl = impl
            cfg.quant.rpiq_iters = 2
            params = T.init_params(cfg.model, jax.random.PRNGKey(0))
            calib = calibration_batches(MarkovLM(cfg.model.vocab_size,
                                                 seed=2), 2, 2, 16)
            pq, _ = quantize_model(cfg, params, calib)
            packs.append(pack_for_serving(cfg, pq))
        flat0 = jax.tree_util.tree_leaves(
            packs[0], is_leaf=lambda x: isinstance(x, QuantizedTensor))
        flat1 = jax.tree_util.tree_leaves(
            packs[1], is_leaf=lambda x: isinstance(x, QuantizedTensor))
        assert len(flat0) == len(flat1)
        n_packed = 0
        for a, b in zip(flat0, flat1):
            if isinstance(a, QuantizedTensor):
                n_packed += 1
                np.testing.assert_array_equal(np.asarray(a.packed),
                                              np.asarray(b.packed))
                np.testing.assert_allclose(np.asarray(a.scales),
                                           np.asarray(b.scales), atol=1e-6)
                np.testing.assert_allclose(np.asarray(a.zeros),
                                           np.asarray(b.zeros), atol=1e-6)
            else:
                np.testing.assert_allclose(np.asarray(a, np.float32),
                                           np.asarray(b, np.float32),
                                           atol=1e-6)
        assert n_packed > 0
