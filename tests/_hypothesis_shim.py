"""Property-test shim: real hypothesis when installed, deterministic
fallback otherwise.

``hypothesis`` is declared in requirements-dev.txt but isn't guaranteed in
every container; a hard import used to kill tier-1 *collection*. Importing
``given``/``settings``/``st`` from here keeps the property tests running
either way — the fallback expands each strategy to a small fixed sample
grid (bounds + midpoint) and runs the test over the cross product, so the
invariants are still exercised, just without randomized search/shrinking.
"""
try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:                                    # pragma: no cover
    import itertools

    HAVE_HYPOTHESIS = False

    class _Strategy:
        def __init__(self, values):
            self.values = list(values)

    class _St:
        @staticmethod
        def integers(min_value, max_value):
            mid = (min_value + max_value) // 2
            return _Strategy(sorted({min_value, mid, max_value}))

        @staticmethod
        def sampled_from(elements):
            return _Strategy(elements)

    st = _St()

    def settings(**_kw):
        def deco(fn):
            return fn
        return deco

    def given(**strategies):
        keys = list(strategies)
        combos = list(itertools.product(
            *(strategies[k].values for k in keys)))

        def deco(fn):
            # NOTE: no functools.wraps — pytest would introspect the wrapped
            # signature and treat the strategy params as missing fixtures.
            def wrapper(*args, **kwargs):
                for combo in combos:
                    fn(*args, **dict(zip(keys, combo)), **kwargs)
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            return wrapper
        return deco
