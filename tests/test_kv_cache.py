"""Int8 KV cache (serve.kv_cache knob): positions oracle, long-context
drift regression, bitwise slot/checkpoint round-trips, fused-kernel parity,
fault-plane degradation, continuous-engine parity.

The drift contract the serving path promises (docs/SERVING.md): greedy
decode under the quantized cache is token-identical to the bf16 cache over
a pinned horizon at smoke scale, and per-step logit drift stays bounded —
the error-feedback accumulator keeps it from growing with depth.
"""
import dataclasses
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from tests._hypothesis_shim import given, settings, st

from repro.configs import get_config
from repro.core import faults
from repro.data import MarkovLM
from repro.models import attention as attn
from repro.models import transformer as T
from repro.serving import engine as E
from repro.serving.engine import generate
from repro.serving.scheduler import ContinuousEngine


def _with_serve(cfg, **kw):
    return dataclasses.replace(cfg, serve=dataclasses.replace(cfg.serve,
                                                              **kw))


def _decoder_setup(arch="opt-proxy", b=3, s=8):
    cfg = get_config(arch, smoke=True)
    params = T.init_params(cfg.model, jax.random.PRNGKey(0))
    batch = MarkovLM(cfg.model.vocab_size, seed=0).batch(b, s)
    return cfg, params, batch


def _encdec_setup(b=2, s=6):
    cfg = get_config("whisper-large-v3", smoke=True)
    params = T.init_encdec_params(cfg.model, jax.random.PRNGKey(1))
    frames = jax.random.normal(
        jax.random.PRNGKey(2),
        (b, cfg.model.encoder_seq_len, cfg.model.d_model), jnp.float32)
    toks = jax.random.randint(jax.random.PRNGKey(3), (b, s), 0,
                              cfg.model.vocab_size)
    return cfg, params, {"frames": frames, "tokens": toks}


# ---------------------------------------------------------------------------
# _cache_key_positions vs an independent simulation oracle
# ---------------------------------------------------------------------------

def _positions_oracle(last, cache_len, window):
    """Independent restatement of the slot contract. Full cache: slot i
    holds position i (it never wraps — cache_len covers every position),
    valid while i <= last. Ring: replay the writes (position p lands in
    slot p % cache_len, later writes win), then invalidate slots outside
    the window."""
    if window == 0:
        return np.asarray([i if i <= last else -1
                           for i in range(cache_len)], np.int32)
    slots = [-1] * cache_len
    for p in range(0, last + 1):
        slots[p % cache_len] = p
    lo = last - min(window, cache_len)
    return np.asarray([p if p >= 0 and p > lo else -1 for p in slots],
                      np.int32)


class TestCacheKeyPositions:
    @settings(max_examples=60, deadline=None)
    @given(last=st.integers(min_value=-1, max_value=21),
           cache_len=st.integers(min_value=1, max_value=9),
           window=st.sampled_from([0, 2, 5, 16]))
    def test_matches_oracle(self, last, cache_len, window):
        got = np.asarray(attn._cache_key_positions(last, cache_len, window))
        np.testing.assert_array_equal(
            got, _positions_oracle(last, cache_len, window))

    def test_empty_cache_all_invalid(self):
        np.testing.assert_array_equal(
            np.asarray(attn._cache_key_positions(-1, 6, 4)), -1)
        np.testing.assert_array_equal(
            np.asarray(attn._cache_key_positions(-1, 6, 0)), -1)

    def test_ring_smaller_than_window(self):
        # w_cache < window: every written slot in the last cache_len
        # positions is valid (the ring can't hold more history than that)
        got = np.asarray(attn._cache_key_positions(10, 4, 16))
        np.testing.assert_array_equal(got, _positions_oracle(10, 4, 16))
        assert (got >= 0).all()


# ---------------------------------------------------------------------------
# drift regression: int8 vs bf16 cache, greedy decode
# ---------------------------------------------------------------------------

class TestDriftRegression:
    PIN_HORIZON = 8          # greedy tokens must match exactly this far
    LOGIT_DRIFT_BOUND = 0.25  # ~5x measured at smoke scale (~0.05)

    def test_decoder_token_identical_pinned_horizon(self):
        cfg, params, batch = _decoder_setup()
        r_fp = generate(cfg, params, batch,
                        max_new_tokens=self.PIN_HORIZON, temperature=0.0)
        r_q = generate(_with_serve(cfg, kv_cache="int8"), params, batch,
                       max_new_tokens=self.PIN_HORIZON, temperature=0.0)
        np.testing.assert_array_equal(np.asarray(r_fp.tokens),
                                      np.asarray(r_q.tokens))

    def test_encdec_token_identical_pinned_horizon(self):
        cfg, params, batch = _encdec_setup()
        r_fp = generate(cfg, params, batch,
                        max_new_tokens=self.PIN_HORIZON, temperature=0.0)
        r_q = generate(_with_serve(cfg, kv_cache="int8"), params, batch,
                       max_new_tokens=self.PIN_HORIZON, temperature=0.0)
        np.testing.assert_array_equal(np.asarray(r_fp.tokens),
                                      np.asarray(r_q.tokens))

    def test_logit_drift_bounded_beyond_horizon(self):
        """Feed both caches the SAME (bf16-chosen) token stream and bound
        the per-step logit gap — divergence, not agreement, is what the
        error-feedback accumulator is there to stop."""
        cfg, params, batch = _decoder_setup()
        mc = cfg.model
        toks = batch["tokens"]
        b, s0 = toks.shape
        max_len = s0 + 14
        lg_f, c_f = T.prefill(mc, params, toks, max_len)
        _, c_q = T.prefill(mc, params, toks, max_len, cache_dtype="int8")
        tok = jnp.argmax(lg_f, -1).astype(jnp.int32)
        pos = jnp.full((b,), s0, jnp.int32)
        deltas = []
        for _ in range(12):
            lf, c_f = T.decode_step(mc, params, tok, pos, c_f)
            lq, c_q = T.decode_step(mc, params, tok, pos, c_q)
            deltas.append(float(jnp.max(jnp.abs(lf - lq))))
            tok = jnp.argmax(lf, -1).astype(jnp.int32)
            pos = pos + 1
        assert max(deltas) <= self.LOGIT_DRIFT_BOUND, deltas
        # non-accumulation: the late-half drift is not ballooning past the
        # early half (generous 3x — this guards blowup, not noise)
        early = max(deltas[:6])
        late = max(deltas[6:])
        assert late <= 3 * early + 0.05, deltas

    def test_prefill_logits_unaffected(self):
        """Prefill attends to the fresh fp K/V, not the cache — the int8
        knob must not move prefill logits at all."""
        cfg, params, batch = _decoder_setup()
        mc = cfg.model
        lg_f, _ = T.prefill(mc, params, batch["tokens"], 24)
        lg_q, _ = T.prefill(mc, params, batch["tokens"], 24,
                            cache_dtype="int8")
        np.testing.assert_array_equal(np.asarray(lg_f), np.asarray(lg_q))

    def test_chunked_prefill_matches_single_shot(self):
        cfg, params, batch = _decoder_setup()
        base = _with_serve(cfg, kv_cache="int8")
        r1 = generate(base, params, batch, max_new_tokens=6,
                      temperature=0.0)
        r2 = generate(_with_serve(base, prefill_chunk=3), params, batch,
                      max_new_tokens=6, temperature=0.0)
        np.testing.assert_array_equal(np.asarray(r1.tokens),
                                      np.asarray(r2.tokens))


# ---------------------------------------------------------------------------
# bitwise slot + checkpoint round-trips of quantized leaves
# ---------------------------------------------------------------------------

def _leaf_pairs(a, b):
    la = jax.tree_util.tree_leaves(a)
    lb = jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    return zip(la, lb)


class TestQuantizedSlotRoundTrip:
    def _prefill_cache(self):
        cfg, params, batch = _decoder_setup(b=1)
        _, caches = T.prefill(cfg.model, params, batch["tokens"], 16,
                              cache_dtype="int8")
        return caches

    def test_cache_has_quantized_leaves(self):
        caches = self._prefill_cache()
        dtypes = {jnp.dtype(l.dtype)
                  for l in jax.tree_util.tree_leaves(caches)}
        assert jnp.dtype("int8") in dtypes

    def test_insert_is_bitwise(self):
        src = self._prefill_cache()
        slotted = T.cache_slots_like(src, 4)
        slotted = T.cache_slot_insert(slotted, src, jnp.int32(2))
        for big, small in _leaf_pairs(slotted, src):
            np.testing.assert_array_equal(np.asarray(big[:, 2]),
                                          np.asarray(small[:, 0]))
            # untouched lanes stay zero — incl. int8 codes and EF leaves
            assert not np.any(np.asarray(big[:, 0]))

    def test_evict_zeroes_lane_and_error_feedback(self):
        src = self._prefill_cache()
        slotted = T.cache_slots_like(src, 3)
        slotted = T.cache_slot_insert(slotted, src, jnp.int32(1))
        evicted = T.cache_slot_evict(slotted, jnp.int32(1))
        for leaf in jax.tree_util.tree_leaves(evicted):
            assert not np.any(np.asarray(leaf[:, 1]))

    def test_insert_evict_insert_roundtrip(self):
        src = self._prefill_cache()
        slotted = T.cache_slots_like(src, 2)
        slotted = T.cache_slot_insert(slotted, src, jnp.int32(0))
        slotted = T.cache_slot_evict(slotted, jnp.int32(0))
        slotted = T.cache_slot_insert(slotted, src, jnp.int32(0))
        for big, small in _leaf_pairs(slotted, src):
            np.testing.assert_array_equal(np.asarray(big[:, 0]),
                                          np.asarray(small[:, 0]))

    def test_checkpointer_roundtrip_bitwise(self, tmp_path):
        from repro.distributed.checkpoint import Checkpointer
        caches = self._prefill_cache()
        ck = Checkpointer(str(tmp_path), async_write=False)
        ck.save(0, caches)
        ck.wait()
        restored, _ = ck.restore(caches)
        for a, b in _leaf_pairs(caches, restored):
            assert a.dtype == b.dtype
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# fused kernel: pallas (interpret off-TPU) vs xla reference parity
# ---------------------------------------------------------------------------

class TestFusedKernelParity:
    @pytest.mark.parametrize("b,s,kv,r,hd,blk", [
        (2, 16, 2, 4, 64, 64),       # decode shape, small history
        (1, 130, 1, 8, 64, 32),      # history spans >1 s-tile after padding
        (2, 32, 4, 1, 128, 128),     # MQA-per-group (r=1)
        (1, 24, 2, 3, 64, 64),       # ragged r (padded to 8 inside)
    ])
    def test_pallas_matches_xla(self, b, s, kv, r, hd, blk):
        from repro.kernels import kv_codec, ops as kops
        rng = np.random.default_rng(b * 100 + s)
        k = rng.normal(size=(b, s, kv, hd)).astype(np.float32)
        v = rng.normal(size=(b, s, kv, hd)).astype(np.float32)
        kc, ks = kv_codec.enc_int8_blocks(jnp.asarray(k), blk)
        vc, vs = kv_codec.enc_int8_blocks(jnp.asarray(v), blk)
        q = jnp.asarray(rng.normal(size=(b, kv, r, hd)).astype(np.float32))
        kpos = jnp.broadcast_to(jnp.arange(s)[None], (b, s)).astype(jnp.int32)
        kpos = jnp.where(kpos < s - 3, kpos, -1)      # some invalid slots
        args = (q, kc, ks, vc, vs, kpos)
        o_x = kops.int8_kv_attention(*args, kv_block=blk, impl="xla")
        o_p = kops.int8_kv_attention(*args, kv_block=blk, impl="pallas")
        np.testing.assert_allclose(np.asarray(o_p), np.asarray(o_x),
                                   atol=1e-5, rtol=1e-5)

    def test_softcap_parity(self):
        from repro.kernels import kv_codec, ops as kops
        rng = np.random.default_rng(7)
        b, s, kv, r, hd, blk = 1, 16, 2, 4, 64, 64
        kc, ks = kv_codec.enc_int8_blocks(
            jnp.asarray(rng.normal(size=(b, s, kv, hd)).astype(np.float32)),
            blk)
        vc, vs = kv_codec.enc_int8_blocks(
            jnp.asarray(rng.normal(size=(b, s, kv, hd)).astype(np.float32)),
            blk)
        q = jnp.asarray(rng.normal(size=(b, kv, r, hd)).astype(np.float32))
        kpos = jnp.broadcast_to(jnp.arange(s)[None], (b, s)).astype(jnp.int32)
        o_x = kops.int8_kv_attention(q, kc, ks, vc, vs, kpos, kv_block=blk,
                                     softcap=8.0, impl="xla")
        o_p = kops.int8_kv_attention(q, kc, ks, vc, vs, kpos, kv_block=blk,
                                     softcap=8.0, impl="pallas")
        np.testing.assert_allclose(np.asarray(o_p), np.asarray(o_x),
                                   atol=1e-5, rtol=1e-5)

    def test_all_invalid_tile_is_safe(self):
        """A fully-masked s-tile must not poison the online softmax."""
        from repro.kernels import kv_codec, ops as kops
        rng = np.random.default_rng(11)
        b, s, kv, r, hd, blk = 1, 140, 1, 4, 64, 64
        kc, ks = kv_codec.enc_int8_blocks(
            jnp.asarray(rng.normal(size=(b, s, kv, hd)).astype(np.float32)),
            blk)
        vc, vs = kv_codec.enc_int8_blocks(
            jnp.asarray(rng.normal(size=(b, s, kv, hd)).astype(np.float32)),
            blk)
        q = jnp.asarray(rng.normal(size=(b, kv, r, hd)).astype(np.float32))
        kpos = jnp.where(jnp.arange(s)[None] < 5,
                         jnp.arange(s)[None], -1).astype(jnp.int32)
        kpos = jnp.broadcast_to(kpos, (b, s))
        o_x = kops.int8_kv_attention(q, kc, ks, vc, vs, kpos, kv_block=blk,
                                     impl="xla")
        o_p = kops.int8_kv_attention(q, kc, ks, vc, vs, kpos, kv_block=blk,
                                     impl="pallas")
        assert np.all(np.isfinite(np.asarray(o_p)))
        np.testing.assert_allclose(np.asarray(o_p), np.asarray(o_x),
                                   atol=1e-5, rtol=1e-5)


# ---------------------------------------------------------------------------
# serving integration: impl knob, fault degradation, continuous parity
# ---------------------------------------------------------------------------

@pytest.mark.serving
class TestInt8Serving:
    def test_kv_impl_knob_deterministic(self):
        cfg, params, batch = _decoder_setup()
        outs = {}
        for impl in ("auto", "xla", "pallas"):
            r = generate(_with_serve(cfg, kv_cache="int8", kv_impl=impl),
                         params, batch, max_new_tokens=4, temperature=0.0)
            outs[impl] = np.asarray(r.tokens)
        np.testing.assert_array_equal(outs["auto"], outs["xla"])
        np.testing.assert_array_equal(outs["xla"], outs["pallas"])

    def test_generate_degrades_on_kernel_fault(self):
        cfg, params, batch = _decoder_setup()
        cfg8 = _with_serve(cfg, kv_cache="int8", kv_impl="pallas")
        clean = generate(_with_serve(cfg, kv_cache="int8"), params, batch,
                         max_new_tokens=4, temperature=0.0)
        before = E.engine_stats()["kernel_degradations"]
        with faults.inject("kernels.pallas_dispatch@1"):
            with pytest.warns(RuntimeWarning, match="degrading"):
                r = generate(cfg8, params, batch, max_new_tokens=4,
                             temperature=0.0)
        assert E.engine_stats()["kernel_degradations"] == before + 1
        np.testing.assert_array_equal(np.asarray(r.tokens),
                                      np.asarray(clean.tokens))

    def test_continuous_engine_degrades_and_reports(self):
        cfg, params, batch = _decoder_setup()
        cfg8 = _with_serve(cfg, kv_cache="int8", kv_impl="pallas",
                           max_batch=2)
        eng = ContinuousEngine(cfg8, params, max_len=32)
        with faults.inject("kernels.pallas_dispatch@1"):
            with warnings.catch_warnings():
                warnings.simplefilter("ignore", RuntimeWarning)
                rids = [eng.submit({"tokens": batch["tokens"][i:i + 1]},
                                   max_new_tokens=4) for i in range(2)]
                done = eng.run()
        stats = eng.engine_stats()
        assert stats["kernel_degradations"] == 1
        assert stats["kv_impl"] == "xla"
        ref = generate(_with_serve(cfg, kv_cache="int8"), params, batch,
                       max_new_tokens=4, temperature=0.0)
        for i, rid in enumerate(rids):
            np.testing.assert_array_equal(done[rid].tokens,
                                          np.asarray(ref.tokens[i]))

    def test_continuous_matches_static_int8(self):
        cfg, params, batch = _decoder_setup()
        cfg8 = _with_serve(cfg, kv_cache="int8", max_batch=2,
                           prefill_chunk=4)
        ref = generate(_with_serve(cfg, kv_cache="int8"), params, batch,
                       max_new_tokens=6, temperature=0.0)
        eng = ContinuousEngine(cfg8, params, max_len=32)
        rids = [eng.submit({"tokens": batch["tokens"][i:i + 1]},
                           max_new_tokens=6) for i in range(3)]
        done = eng.run()
        for i, rid in enumerate(rids):
            np.testing.assert_array_equal(done[rid].tokens,
                                          np.asarray(ref.tokens[i]))

    def test_nan_quarantine_works_on_quantized_lanes(self):
        """Lane poisoning NaN-fills only float leaves (scales + error
        feedback — int8 codes can't hold NaN); dequant multiplies codes by
        scales, so the poisoned lane's logits still go non-finite and the
        quarantine guard catches it exactly as with the fp16 cache
        (docs/SERVING.md §Failure handling)."""
        cfg, params, batch = _decoder_setup(b=2)
        cfg8 = _with_serve(cfg, kv_cache="int8", max_batch=2)
        eng = ContinuousEngine(cfg8, params, max_len=32)
        rids = [eng.submit({"tokens": batch["tokens"][i:i + 1]},
                           max_new_tokens=6) for i in range(2)]
        with faults.inject("serve.decode_step@2"):
            done = eng.run()
        assert eng.stats["quarantined"] == 1
        assert "quarantined" in {done[r].status for r in rids}

    def test_stats_expose_kv_impl(self):
        cfg, params, _ = _decoder_setup()
        eng = ContinuousEngine(cfg, params, max_len=32)
        s = eng.engine_stats()
        assert "kv_impl" in s and "w4a16_impl" in s
