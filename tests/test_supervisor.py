"""Supervised-serving crash recovery (``-m faults``; robustness PR).

Pins the supervisor contract (serving/supervisor.py, docs/SERVING.md
§Crash recovery): a mid-trace engine death — the ``serve.engine_step``
kill site, or a watchdog trip on a hung tick — is recovered by engine
rebuild + deterministic replay, with outputs **token-identical** to the
fault-free run, every recovery counted, restarts budget-bounded, and
params re-read through the integrity-checked artifact path. Plus the
per-engine kernel-fallback scope regression (two engines in one process
must not cross-contaminate ``engine_stats()``).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import faults
from repro.core.pipeline import pack_for_serving
from repro.data import MarkovLM
from repro.distributed.checkpoint import (ArtifactIntegrityError,
                                          save_artifact)
from repro.kernels import ops as kops
from repro.models import transformer as T
from repro.serving.scheduler import ContinuousEngine
from repro.serving.supervisor import EngineRestartExhausted, SupervisedEngine

pytestmark = pytest.mark.faults


def _setup(**serve_kw):
    serve_kw.setdefault("scheduler", "continuous")
    serve_kw.setdefault("supervise", True)
    cfg = get_config("opt-proxy", smoke=True)
    cfg = dataclasses.replace(cfg, serve=dataclasses.replace(
        cfg.serve, **serve_kw))
    params = T.init_params(cfg.model, jax.random.PRNGKey(0))
    return cfg, params


def _submit_n(eng, n=4, mnt=6, plen=8, **kw):
    data = MarkovLM(eng.cfg.model.vocab_size, seed=0)
    return [eng.submit({"tokens": data.batch(1, plen)["tokens"]},
                       max_new_tokens=mnt, **kw) for _ in range(n)]


def _drain(eng):
    done = {}
    while not eng.idle:
        for f in eng.step().finished:
            done[f.rid] = f
    return done


class TestCrashRecovery:
    def test_kill_and_recover_token_identical(self):
        cfg, params = _setup()
        clean = SupervisedEngine(cfg, params, max_len=64)
        crids = _submit_n(clean)
        cdone = _drain(clean)
        assert clean.stats["restarts"] == 0

        eng = SupervisedEngine(cfg, params, max_len=64)
        rids = _submit_n(eng)
        with faults.inject("serve.engine_step@4"):
            done = _drain(eng)
        assert all(done[r].status == "ok" for r in rids)
        # deterministic replay: token-identical to the fault-free run,
        # and steps/prompt_len survive the rebuild
        for r0, r in zip(crids, rids):
            np.testing.assert_array_equal(cdone[r0].tokens, done[r].tokens)
            assert done[r].steps == cdone[r0].steps
            assert done[r].prompt_len == cdone[r0].prompt_len
        s = eng.engine_stats()
        assert s["restarts"] == 1
        assert s["replayed_requests"] >= 1
        assert s["recovered_completions"] >= 1

    def test_unsupervised_engine_crash_escapes(self):
        cfg, params = _setup(supervise=False)
        eng = ContinuousEngine(cfg, params, max_len=64)
        _submit_n(eng, n=1)
        with faults.inject("serve.engine_step@1"):
            with pytest.raises(faults.FaultError):
                eng.step()

    def test_watchdog_trips_on_hung_tick(self):
        cfg, params = _setup(step_timeout_s=0.5)
        clockbox, stride = [0.0], [0.0]

        def clock():
            clockbox[0] += stride[0]
            return clockbox[0]

        clean = SupervisedEngine(cfg, params, max_len=64)
        crids = _submit_n(clean)
        cdone = _drain(clean)

        eng = SupervisedEngine(cfg, params, max_len=64, clock=clock)
        rids = _submit_n(eng)
        eng.step()
        eng.step()
        stride[0] = 1.0                 # one tick spans > step_timeout_s
        rep = eng.step()
        stride[0] = 0.0
        assert eng.stats["watchdog_trips"] == 1
        assert eng.stats["restarts"] == 1
        done = {f.rid: f for f in rep.finished}
        while not eng.idle:
            for f in eng.step().finished:
                done[f.rid] = f
        assert all(done[r].status == "ok" for r in rids)
        # the slow tick's report was absorbed before recovery, so replay
        # continues from it — still token-identical
        for r0, r in zip(crids, rids):
            np.testing.assert_array_equal(cdone[r0].tokens, done[r].tokens)

    def test_restart_budget_exhaustion_is_terminal(self):
        cfg, params = _setup(max_restarts=2)
        eng = SupervisedEngine(cfg, params, max_len=64)
        _submit_n(eng, n=2)
        with faults.inject("serve.engine_step@1+"):
            with pytest.raises(EngineRestartExhausted,
                               match="serve.max_restarts=2"):
                for _ in range(10):
                    eng.step()
        assert eng.stats["restarts"] == 2

    def test_deadline_expired_during_outage_times_out(self):
        cfg, params = _setup()
        clockbox = [0.0]
        eng = SupervisedEngine(cfg, params, max_len=64,
                               clock=lambda: clockbox[0])
        rids = _submit_n(eng, n=2, mnt=8, timeout_s=5.0)
        eng.step()
        eng.step()
        eng.step()
        clockbox[0] = 100.0             # outage outlives every deadline
        with faults.inject("serve.engine_step@1"):
            rep = eng.step()            # crash fires before the tick sweep
        done = {f.rid: f for f in rep.finished}
        assert sorted(done) == sorted(rids)
        assert all(done[r].status == "timeout" for r in rids)
        s = eng.engine_stats()
        assert s["timeout_evictions"] >= 2
        assert s["replayed_requests"] == 0
        assert eng.idle                 # nothing resubmitted

    def test_stats_survive_restart(self):
        # a quarantine in generation 0 must still be visible after the
        # rebuild: dead engines' counters fold into the accumulator
        cfg, params = _setup()
        eng = SupervisedEngine(cfg, params, max_len=64)
        rids = _submit_n(eng)
        with faults.inject("serve.decode_step@2", "serve.engine_step@5"):
            done = _drain(eng)
        s = eng.engine_stats()
        assert s["quarantined"] == 1
        assert s["restarts"] == 1
        statuses = [done[r].status for r in rids]
        assert statuses.count("quarantined") == 1
        assert statuses.count("ok") == len(rids) - 1

    def test_replay_bypasses_queue_bound(self):
        cfg, params = _setup(max_queue=1, max_batch=2)
        eng = SupervisedEngine(cfg, params, max_len=64)
        rids = []
        for _ in range(3):              # interleave so the bound never hits
            rids += _submit_n(eng, n=1)
            eng.step()
        with faults.inject("serve.engine_step@1"):
            done = _drain(eng)
        # all three in-flight requests were resubmitted force=True — more
        # than max_queue can hold — with zero rejections
        s = eng.engine_stats()
        assert s["replayed_requests"] + s["recovered_completions"] >= 3
        assert s["rejections"] == 0
        assert all(done[r].status == "ok" for r in rids)


class TestParamsReload:
    def test_params_reload_through_integrity_check(self, tmp_path):
        cfg, params = _setup()
        path = str(tmp_path / "p.params.pkl")
        save_artifact(path, jax.device_get(params))
        eng = SupervisedEngine(cfg, max_len=64, params_path=path)
        rids = _submit_n(eng, n=2)
        with faults.inject("serve.engine_step@3"):
            done = _drain(eng)
        assert eng.stats["params_reloads"] == 1
        assert all(done[r].status == "ok" for r in rids)

    def test_corrupt_artifact_fails_recovery_loudly(self, tmp_path):
        cfg, params = _setup()
        path = str(tmp_path / "p.params.pkl")
        save_artifact(path, jax.device_get(params))
        eng = SupervisedEngine(cfg, max_len=64, params_path=path)
        _submit_n(eng, n=2)
        raw = bytearray(open(path, "rb").read())
        raw[len(raw) // 2] ^= 0xFF
        open(path, "wb").write(bytes(raw))
        with faults.inject("serve.engine_step@1"):
            with pytest.raises(ArtifactIntegrityError):
                eng.step()


class TestEngineStatsIsolation:
    def test_two_engines_do_not_share_fallback_counters(self, monkeypatch):
        # fake a zero-VMEM TPU: engine A (impl=auto, int4 weights) must
        # take the budget fallback at trace time and count it in ITS
        # engine_stats(); engine B (impl=xla) traced in the same process
        # while A exists must stay clean — the regression this pins is the
        # old process-global counter leaking across engines
        monkeypatch.setattr(kops, "_on_tpu", lambda: True)
        monkeypatch.setattr(kops, "_VMEM_BUDGET_BYTES", 0)
        kops.reset_fallback_stats()
        cfg_a, params = _setup(supervise=False, quantized=True,
                               w4a16_impl="auto")
        packed = pack_for_serving(cfg_a, params)
        cfg_b = dataclasses.replace(cfg_a, serve=dataclasses.replace(
            cfg_a.serve, w4a16_impl="xla"))
        eng_a = ContinuousEngine(cfg_a, packed, max_len=64)
        eng_b = ContinuousEngine(cfg_b, packed, max_len=64)
        ra = _submit_n(eng_a, n=2)
        rb = _submit_n(eng_b, n=2)
        with pytest.warns(RuntimeWarning, match="vmem-budget"):
            done_a = _drain(eng_a)
        done_b = _drain(eng_b)
        fa = eng_a.engine_stats()["kernel_fallbacks"]
        fb = eng_b.engine_stats()["kernel_fallbacks"]
        assert sum(fa.values()) >= 1            # A saw its own downgrades
        assert fb == {}                          # B saw none of A's
        # both engines decode correctly regardless of scope bookkeeping
        assert all(done_a[r].status == "ok" for r in ra)
        assert all(done_b[r].status == "ok" for r in rb)
        for a, b in zip(ra, rb):
            np.testing.assert_array_equal(done_a[a].tokens,
                                          done_b[b].tokens)
        kops.reset_fallback_stats()
