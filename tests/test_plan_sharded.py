"""Sharded group execution parity on a forced host mesh (DESIGN.md §2.6).

Every test here needs ≥4 host devices, so the plain tier-1 run — which must
keep the single real CPU device (dry-run contract, tests/conftest.py) —
skips the whole file; scripts/check.sh runs it as a dedicated leg under
``XLA_FLAGS=--xla_force_host_platform_device_count=4``.  End-to-end
``quantize_model`` parity additionally runs as a subprocess check from
tests/test_distributed.py (``plan_sharded``), so plain ``pytest`` covers
the mesh path too.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.config import QuantConfig
from repro.core import hessian as hess
from repro.core import plan as qplan
from repro.distributed.sharding import quant_group_sharding
from repro.kernels import ops as kops

needs_mesh = pytest.mark.skipif(
    jax.device_count() < 4,
    reason="needs ≥4 host devices (scripts/check.sh multi-device leg)")


def _mesh22():
    from jax.sharding import Mesh
    return Mesh(np.array(jax.devices()[:4]).reshape(2, 2),
                ("data", "model"))


def _member(i: int, out_dim: int, in_dim: int, n_last: int = 64,
            n_calib: int = 128) -> qplan.PlanMember:
    w = jax.random.normal(jax.random.PRNGKey(i), (out_dim, in_dim)) * 0.1
    x = jax.random.normal(jax.random.PRNGKey(100 + i), (n_calib, in_dim))
    st = hess.accumulate(hess.init_hessian(in_dim), x)
    return qplan.PlanMember(f"m{i}", w, st, x[-n_last:], x_count=None)


def _run_plan(qc, members, mesh=None, rpiq=True):
    qplan.clear_executor_cache()
    plan = qplan.build_plan(qc, members)
    report = qplan.QuantReport()
    res = qplan.execute_plan(qc, plan, report, rpiq_enabled=rpiq, mesh=mesh)
    return plan, report, res


def _assert_member_parity(r1, r2):
    assert r1.keys() == r2.keys()
    for name in r1:
        a, b = r1[name], r2[name]
        np.testing.assert_allclose(np.asarray(a.w_q),
                                   np.asarray(jax.device_get(b.w_q)),
                                   rtol=1e-5, atol=1e-6, err_msg=name)
        for ga, gb in zip(a.grid, b.grid):
            np.testing.assert_allclose(np.asarray(ga),
                                       np.asarray(jax.device_get(gb)),
                                       rtol=1e-5, atol=1e-6, err_msg=name)


# ---------------------------------------------------------------------------
# Placement rules (pure logic, but Mesh construction needs the devices)
# ---------------------------------------------------------------------------

@needs_mesh
def test_quant_group_sharding_guards():
    mesh = _mesh22()
    gs = quant_group_sharding(mesh, lanes=4, out_dim=64)
    assert (gs.lane_axis, gs.row_axis) == ("data", "model")
    # lanes don't divide data → lane axis dropped, rows keep model
    gs = quant_group_sharding(mesh, lanes=3, out_dim=64)
    assert (gs.lane_axis, gs.row_axis) == (None, "model")
    # Cout doesn't divide model → row axis dropped, lanes keep data
    gs = quant_group_sharding(mesh, lanes=4, out_dim=33)
    assert (gs.lane_axis, gs.row_axis) == ("data", None)
    # neither divides → the group stays unsharded entirely
    assert quant_group_sharding(mesh, lanes=3, out_dim=33) is None
    assert quant_group_sharding(None, lanes=4, out_dim=64) is None


@needs_mesh
def test_quant_group_specs_and_hessian_placement():
    from jax.sharding import PartitionSpec as P
    mesh = _mesh22()
    gs = quant_group_sharding(mesh, lanes=4, out_dim=64)
    assert gs.spec("w") == P("data", "model", None)
    assert gs.spec("hessian") == P("data", None, None)
    assert gs.spec("lane") == P("data")
    st = hess.HessianState(jnp.zeros((4, 32, 32)),
                           jnp.zeros((4,), jnp.int32))
    st_sh = hess.shard_stacked(st, gs)
    assert st_sh.H.sharding.spec == P("data", None, None)
    assert st_sh.count.sharding.spec == P("data")
    # rows-only groups replicate the state across the mesh — still
    # committed, so it can't clash with the mesh-committed weights
    gs_rows = quant_group_sharding(mesh, lanes=3, out_dim=64)
    st_rep = hess.shard_stacked(st, gs_rows)
    assert st_rep.H.sharding.spec == P(None, None, None)
    assert hess.shard_stacked(st, None) is st


# ---------------------------------------------------------------------------
# Kernel-dispatch level: gptq_block_sharded == gptq_block
# ---------------------------------------------------------------------------

def _sweep_inputs(b=4, out_dim=32, in_dim=64):
    w = jax.random.normal(jax.random.PRNGKey(0), (b, out_dim, in_dim)) * 0.1
    x = jax.random.normal(jax.random.PRNGKey(1), (b, 256, in_dim))
    h = jnp.einsum("bni,bnj->bij", x, x,
                   precision=jax.lax.Precision.HIGHEST)
    hd = hess.damped(hess.HessianState(h, None), 0.01)
    return w, hess.cholesky_inverse_upper(hd)


@needs_mesh
@pytest.mark.parametrize("axes", [("data", "model"), ("data", None),
                                  (None, "model")])
def test_gptq_block_sharded_matches_single(axes):
    w, u = _sweep_inputs()
    kw = dict(bits=4, group_size=32, blocksize=32, symmetric=False)
    ref = kops.gptq_block(w, u, impl="xla", **kw)
    out = kops.gptq_block_sharded(w, u, mesh=_mesh22(), lane_axis=axes[0],
                                  row_axis=axes[1], impl="xla", **kw)
    for name, a, b in zip(("w_q", "scales", "zeros", "err"), ref, out):
        np.testing.assert_allclose(np.asarray(a),
                                   np.asarray(jax.device_get(b)),
                                   rtol=1e-5, atol=1e-5, err_msg=name)


@needs_mesh
@pytest.mark.pallas
def test_gptq_block_sharded_pallas_interpret():
    """Per-shard pallas (interpret off-TPU) under shard_map == XLA path."""
    w, u = _sweep_inputs(b=2, out_dim=16, in_dim=32)
    kw = dict(bits=4, group_size=16, blocksize=16, symmetric=False)
    ref = kops.gptq_block(w, u, impl="xla", **kw)
    out = kops.gptq_block_sharded(w, u, mesh=_mesh22(), lane_axis="data",
                                  row_axis="model", impl="pallas", **kw)
    np.testing.assert_allclose(np.asarray(ref[0]),
                               np.asarray(jax.device_get(out[0])),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# Kernel-dispatch level: rpiq_block_sharded == rpiq_block (stage-2 twin)
# ---------------------------------------------------------------------------

def _rpiq_inputs(b=4, out_dim=32, in_dim=64, n=128):
    w, u = _sweep_inputs(b, out_dim, in_dim)
    from repro.core.gptq import gptq_quantize_batched
    res1 = gptq_quantize_batched(w, u, bits=4, group_size=32, blocksize=32)
    x = jax.random.normal(jax.random.PRNGKey(5), (b, n, in_dim))
    h = jnp.einsum("bni,bnj->bij", x, x,
                   precision=jax.lax.Precision.HIGHEST)
    hd = hess.damped(hess.HessianState(h, None), 0.01)
    return w, x, hd, res1


_RPIQ_KW = dict(bits=4, group_size=32, block_size=32, alpha=1.0, t_max=4,
                exact_gram=True)


@needs_mesh
def test_rpiq_block_sharded_lane_axis_bitwise():
    """Lane-only sharding: members are fully independent, so the sharded
    twin must match the single-device dispatch BITWISE."""
    w, x, hd, res1 = _rpiq_inputs()
    ref = kops.rpiq_block_sharded(res1.w_q, w, x, hd, res1.scales,
                                  res1.zeros, mesh=None, lane_axis=None,
                                  row_axis=None, impl="xla", **_RPIQ_KW)
    out = kops.rpiq_block_sharded(res1.w_q, w, x, hd, res1.scales,
                                  res1.zeros, mesh=_mesh22(),
                                  lane_axis="data", row_axis=None,
                                  impl="xla", **_RPIQ_KW)
    for name, a, b in zip(("w_q", "w_cont", "hist", "proj_loss", "iters"),
                          ref, out):
        np.testing.assert_array_equal(np.asarray(a),
                                      np.asarray(jax.device_get(b)),
                                      err_msg=name)


@needs_mesh
@pytest.mark.parametrize("axes", [("data", "model"), (None, "model")])
def test_rpiq_block_sharded_xla_gathers_rows(axes):
    """With an XLA-resolved backend the closed loop cannot row-shard (the
    while-loop trip count is per-lane data-dependent), so the twin gathers
    rows and shards lanes only — results match single-device."""
    w, x, hd, res1 = _rpiq_inputs()
    ref = kops.rpiq_block_sharded(res1.w_q, w, x, hd, res1.scales,
                                  res1.zeros, mesh=None, lane_axis=None,
                                  row_axis=None, impl="xla", **_RPIQ_KW)
    out = kops.rpiq_block_sharded(res1.w_q, w, x, hd, res1.scales,
                                  res1.zeros, mesh=_mesh22(),
                                  lane_axis=axes[0], row_axis=axes[1],
                                  impl="xla", **_RPIQ_KW)
    np.testing.assert_array_equal(np.asarray(ref[4]),
                                  np.asarray(jax.device_get(out[4])))
    for name, a, b in zip(("w_q", "w_cont", "hist", "proj_loss"), ref, out):
        np.testing.assert_allclose(np.asarray(a),
                                   np.asarray(jax.device_get(b)),
                                   rtol=1e-6, atol=1e-7, err_msg=name)


@needs_mesh
@pytest.mark.pallas
def test_rpiq_block_sharded_pallas_row_psum():
    """Per-shard fused kernel (interpret off-TPU) with the row axis kept:
    the Γ/projected-loss partials psum-fold across row shards before the
    deferred bookkeeping, so early stops and the best projection match the
    single-device kernel."""
    w, x, hd, res1 = _rpiq_inputs(b=2, out_dim=16, in_dim=32, n=64)
    ref = kops.rpiq_block_sharded(res1.w_q, w, x, hd, res1.scales,
                                  res1.zeros, mesh=None, lane_axis=None,
                                  row_axis=None, impl="pallas", **_RPIQ_KW)
    out = kops.rpiq_block_sharded(res1.w_q, w, x, hd, res1.scales,
                                  res1.zeros, mesh=_mesh22(),
                                  lane_axis="data", row_axis="model",
                                  impl="pallas", **_RPIQ_KW)
    np.testing.assert_array_equal(np.asarray(ref[4]),
                                  np.asarray(jax.device_get(out[4])))
    np.testing.assert_allclose(np.asarray(ref[0]),
                               np.asarray(jax.device_get(out[0])),
                               rtol=1e-6, atol=1e-6)
    ha = np.asarray(ref[2])
    hb = np.asarray(jax.device_get(out[2]))
    fin = np.isfinite(ha)
    assert (fin == np.isfinite(hb)).all()
    np.testing.assert_allclose(ha[fin], hb[fin], rtol=1e-5)


# ---------------------------------------------------------------------------
# Executor level: sharded plan == single-device batched plan
# ---------------------------------------------------------------------------

@needs_mesh
@pytest.mark.parametrize("rpiq", [False, True])
def test_group_parity_sharded_vs_single(rpiq):
    """4-lane group over the full (2, 2) mesh: lanes × row tiles."""
    qc = QuantConfig(group_size=16, blocksize=16)
    _, rep1, r1 = _run_plan(qc, [_member(i, 64, 64) for i in range(4)],
                            rpiq=rpiq)
    _, rep2, r2 = _run_plan(qc, [_member(i, 64, 64) for i in range(4)],
                            mesh=_mesh22(), rpiq=rpiq)
    _assert_member_parity(r1, r2)
    for l1, l2 in zip(rep1.linears, rep2.linears):
        assert (l1.name, l1.mode, l1.iters) == (l2.name, l2.mode, l2.iters)
        np.testing.assert_allclose(l1.gamma_final, l2.gamma_final,
                                   rtol=1e-4, atol=1e-6)


@needs_mesh
def test_non_divisible_lanes_shard_rows_only():
    """3 lanes on a 2-wide data axis: lane axis dropped, rows still shard."""
    qc = QuantConfig(group_size=16, blocksize=16)
    members = lambda: [_member(i, 64, 64) for i in range(3)]
    _, _, r1 = _run_plan(qc, members())
    _, _, r2 = _run_plan(qc, members(), mesh=_mesh22())
    _assert_member_parity(r1, r2)


@needs_mesh
def test_non_divisible_group_takes_unsharded_fallback():
    """Neither lanes (3) nor Cout (33) divide → whole group unsharded."""
    mesh = _mesh22()
    assert quant_group_sharding(mesh, 3, 33) is None
    qc = QuantConfig(group_size=16, blocksize=16)
    members = lambda: [_member(i, 33, 64) for i in range(3)]
    _, _, r1 = _run_plan(qc, members())
    _, _, r2 = _run_plan(qc, members(), mesh=mesh)
    _assert_member_parity(r1, r2)


@needs_mesh
def test_starved_mask_parity_sharded():
    """Stacked member with starved lanes: the RTN mask survives sharding."""
    qc = QuantConfig(group_size=16, blocksize=16)

    def stacked():
        w = jnp.stack([_member(i, 32, 64).w_oi for i in range(4)])
        x = jax.random.normal(jax.random.PRNGKey(7), (4, 64, 64))
        h = jnp.einsum("bni,bnj->bij", x, x,
                       precision=jax.lax.Precision.HIGHEST)
        st = hess.HessianState(h, jnp.full((4,), 64, jnp.int32))
        return [qplan.PlanMember(
            "experts", w, st, x, x_count=jnp.full((4,), 64, jnp.int32),
            starved=np.array([False, True, False, True]),
            names=[f"experts[{i}]" for i in range(4)])]

    _, rep1, r1 = _run_plan(qc, stacked())
    _, rep2, r2 = _run_plan(qc, stacked(), mesh=_mesh22())
    _assert_member_parity(r1, r2)
    modes1 = [l.mode for l in rep1.linears]
    assert modes1 == [l.mode for l in rep2.linears]
    assert modes1.count("rtn-fallback") == 2


def _mesh_e(data=1, model=1, expert=4):
    from jax.sharding import Mesh
    n = data * model * expert
    return Mesh(np.array(jax.devices()[:n]).reshape(data, model, expert),
                ("data", "model", "expert"))


@needs_mesh
def test_quant_group_sharding_expert_axis():
    """Expert-stacked groups offer lanes to the expert axis; dense groups
    ignore it (DESIGN.md §2.6 expert parallelism)."""
    # pure expert axis: lanes over "expert", no row tiling
    gs = quant_group_sharding(_mesh_e(1, 1, 4), lanes=8, out_dim=64,
                              expert_stacked=True)
    assert (gs.lane_axis, gs.row_axis) == ("expert", None)
    # expert × data product: lanes over the combined tuple
    gs = quant_group_sharding(_mesh_e(2, 1, 2), lanes=8, out_dim=64,
                              expert_stacked=True)
    assert (gs.lane_axis, gs.row_axis) == (("expert", "data"), None)
    # expert + model: lanes over expert, rows over model
    gs = quant_group_sharding(_mesh_e(1, 2, 2), lanes=8, out_dim=64,
                              expert_stacked=True)
    assert (gs.lane_axis, gs.row_axis) == ("expert", "model")
    # non-expert groups never touch the expert axis (data has size 1
    # here, so lanes stay unsharded entirely)
    gs = quant_group_sharding(_mesh_e(1, 2, 2), lanes=8, out_dim=64,
                              expert_stacked=False)
    assert (gs.lane_axis, gs.row_axis) == (None, "model")
    # divisibility guard: lanes that fit no candidate fall through to
    # rows-only
    gs = quant_group_sharding(_mesh_e(1, 2, 2), lanes=3, out_dim=64,
                              expert_stacked=True)
    assert (gs.lane_axis, gs.row_axis) == (None, "model")


@needs_mesh
@pytest.mark.parametrize("shape", [(1, 1, 4), (2, 1, 2), (1, 2, 2)])
def test_expert_sharded_group_parity(shape):
    """Stacked 8-expert slab over an expert mesh == single-device."""
    qc = QuantConfig(group_size=16, blocksize=16)

    def stacked():
        w = jnp.stack([_member(i, 32, 64).w_oi for i in range(8)])
        x = jax.random.normal(jax.random.PRNGKey(7), (8, 64, 64))
        h = jnp.einsum("bni,bnj->bij", x, x,
                       precision=jax.lax.Precision.HIGHEST)
        st = hess.HessianState(h, jnp.full((8,), 64, jnp.int32))
        return [qplan.PlanMember(
            "experts", w, st, x, x_count=jnp.full((8,), 64, jnp.int32),
            names=[f"experts[{i}]" for i in range(8)])]

    mesh = _mesh_e(*shape)
    gs = quant_group_sharding(mesh, 8, 32, expert_stacked=True)
    assert gs is not None and gs.lane_axis is not None
    _, rep1, r1 = _run_plan(qc, stacked())
    _, rep2, r2 = _run_plan(qc, stacked(), mesh=mesh)
    _assert_member_parity(r1, r2)
    assert [l.mode for l in rep1.linears] == [l.mode for l in rep2.linears]


@needs_mesh
def test_executor_cache_keyed_by_mesh():
    """Same group signature, with vs without mesh → distinct stage entries;
    a second sharded run over an equal mesh hits the cached entries."""
    qc = QuantConfig(group_size=16, blocksize=16)
    members = lambda: [_member(i, 64, 64) for i in range(4)]
    _run_plan(qc, members())
    base = qplan.executor_cache_stats()["misses"]
    plan = qplan.build_plan(qc, members())
    qplan.execute_plan(qc, plan, qplan.QuantReport(), mesh=_mesh22())
    after_sharded = qplan.executor_cache_stats()
    assert after_sharded["misses"] == base + 2      # stage1 + stage2 anew
    qplan.execute_plan(qc, qplan.build_plan(qc, members()),
                       qplan.QuantReport(), mesh=_mesh22())
    again = qplan.executor_cache_stats()
    assert again["misses"] == after_sharded["misses"]
    assert again["hits"] >= after_sharded["hits"] + 2


# ---------------------------------------------------------------------------
# quant.mesh knob
# ---------------------------------------------------------------------------

def test_make_quant_mesh_off_variants():
    from repro.launch.mesh import make_quant_mesh
    for spec in ("off", "", "none", "1x1", "1", "1x1x1"):
        assert make_quant_mesh(spec) is None
    # malformed specs degrade gracefully instead of raising
    for spec in ("x4", "axb", "-2x-2", "0x4", "2x2x2x2"):
        assert make_quant_mesh(spec) is None
    # "DxMxE" is valid grammar; without enough devices it degrades to
    # single-device like any oversized spec
    assert make_quant_mesh("2x2x2") is None or \
        jax.device_count() >= 8
    # uppercase separator is accepted
    assert make_quant_mesh("1X1") is None


@needs_mesh
def test_make_quant_mesh_shapes_and_fallback():
    from repro.launch.mesh import make_quant_mesh
    mesh = make_quant_mesh("2x2")
    assert mesh.axis_names == ("data", "model")
    assert tuple(mesh.devices.shape) == (2, 2)
    auto = make_quant_mesh("auto")
    assert dict(zip(auto.axis_names, auto.devices.shape))["model"] == 1
    # more devices than the host has → graceful single-device fallback
    assert make_quant_mesh("64x64") is None
