"""Multi-device behaviour, run in subprocesses so the main pytest process
keeps the single real CPU device (dry-run contract)."""
import os
import subprocess
import sys

import pytest

_SCRIPT = os.path.join(os.path.dirname(__file__), "_distributed_checks.py")


def _run(check: str, timeout=600):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env.pop("PYTHONPATH", None)
    r = subprocess.run([sys.executable, _SCRIPT, check],
                       capture_output=True, text=True, timeout=timeout,
                       env=env)
    assert r.returncode == 0, \
        f"{check} failed:\nSTDOUT:{r.stdout[-3000:]}\nSTDERR:{r.stderr[-3000:]}"
    assert "OK" in r.stdout


@pytest.mark.slow
def test_sharded_train_matches_single():
    _run("sharded_train")


@pytest.mark.slow
def test_elastic_restore_across_meshes():
    _run("elastic_restore")


@pytest.mark.slow
def test_grad_compression_error_feedback():
    _run("grad_compression")


@pytest.mark.slow
def test_gpipe_matches_stacked_forward():
    _run("gpipe")


@pytest.mark.slow
def test_row_sharded_gptq_exact():
    _run("gptq_rows")


@pytest.mark.slow
def test_sharded_plan_matches_batched():
    """Sharded group execution (quant.mesh knob) == single-device batched.

    Group-level/non-divisible parity lives in tests/test_plan_sharded.py,
    which runs under the scripts/check.sh forced-device-count leg."""
    _run("plan_sharded")


@pytest.mark.slow
def test_moe_expert_sharded_matches_single():
    """Expert-parallel quantization (quant.mesh="1x2x4") == single-device
    on the routed-MoE config, under the overlap scheduler."""
    _run("moe_expert_sharded")
