"""Fault-injection robustness suite (``-m faults``; ISSUE: robustness PR).

Covers the deterministic fault plane itself (core/faults.py), the
quantize-time numerical-guardrail ladder (non-PSD/NaN Hessians → damping
escalation → per-group RTN fallback), kill-and-resume bitwise parity via
step checkpoints (quant.resume=auto), the hardened continuous-serving loop
(deadlines, bounded admission, cancellation, NaN quarantine, pallas→xla
degradation), and the instrumented VMEM-budget kernel fallbacks.

The load-bearing invariants: every injected fault resolves through its
documented ladder rung with a counter increment, and everything the fault
did *not* touch stays bitwise-identical to the fault-free run.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import faults, hessian as hess
from repro.core.pipeline import pack_for_serving, quantize_model
from repro.core.plan import PlanMember, QuantReport, build_plan, execute_plan
from repro.data import MarkovLM, calibration_batches
from repro.kernels import ops as kops
from repro.models import transformer as T
from repro.serving import engine as E
from repro.serving.scheduler import ContinuousEngine, QueueFullError

pytestmark = pytest.mark.faults


# ---------------------------------------------------------------------------
# fault plane semantics
# ---------------------------------------------------------------------------

class TestFaultPlane:
    def test_parse_grammar(self):
        s = faults.parse_spec("plan.stage1_executor@3")
        assert (s.first, s.last, s.prob, s.mode) == (3, 3, 1.0, "kill")
        s = faults.parse_spec("hessian.cholesky@2..4:nonpsd")
        assert (s.first, s.last, s.mode) == (2, 4, "nonpsd")
        s = faults.parse_spec("serve.decode_step@5+")
        assert (s.first, s.last) == (5, -1)
        s = faults.parse_spec("kernels.pallas_dispatch@p0.25")
        assert s.prob == 0.25 and s.last == -1

    def test_unknown_site_rejected(self):
        with pytest.raises(ValueError, match="unknown fault site"):
            faults.parse_spec("nope.nope@1")
        with pytest.raises(ValueError, match="site@trigger"):
            faults.parse_spec("plan.stage1_executor")

    def test_nth_hit_fires_exactly_once(self):
        with faults.inject("plan.stage1_executor@3") as plane:
            for hit in range(1, 6):
                if hit == 3:
                    with pytest.raises(faults.FaultError) as ei:
                        faults.fire("plan.stage1_executor")
                    assert ei.value.hit == 3
                    assert ei.value.site == "plan.stage1_executor"
                else:
                    faults.fire("plan.stage1_executor")
            assert plane.fired["plan.stage1_executor"] == 1

    def test_range_and_open_schedules(self):
        with faults.inject("serve.decode_step@2..3") as plane:
            fired = [faults.poll("serve.decode_step") is not None
                     for _ in range(5)]
            assert fired == [False, True, True, False, False]
        with faults.inject("serve.decode_step@4+"):
            fired = [faults.poll("serve.decode_step") is not None
                     for _ in range(6)]
            assert fired == [False, False, False, True, True, True]

    def test_probabilistic_schedule_is_seed_deterministic(self):
        def draw(seed):
            with faults.inject("serve.decode_step@p0.4", seed=seed):
                return [faults.poll("serve.decode_step") is not None
                        for _ in range(40)]
        a, b, c = draw(7), draw(7), draw(8)
        assert a == b                 # same seed → identical schedule
        assert a != c                 # different seed → different draws
        assert any(a) and not all(a)  # actually probabilistic

    def test_inject_restores_prior_arming(self):
        faults.PLANE.disarm()
        with faults.inject("plan.stage2_executor@1+"):
            assert faults.armed("plan.stage2_executor")
            with faults.inject("plan.stage2_executor@99"):
                assert faults.PLANE._specs["plan.stage2_executor"].first == 99
            assert faults.PLANE._specs["plan.stage2_executor"].first == 1
        assert not faults.armed("plan.stage2_executor")

    def test_restore_survives_propagating_fault(self):
        with pytest.raises(faults.FaultError):
            with faults.inject("plan.stage1_executor@1"):
                faults.fire("plan.stage1_executor")
        assert not faults.armed("plan.stage1_executor")

    def test_unarmed_site_is_noop(self):
        faults.fire("stream.capture_forward")   # must not raise
        assert faults.poll("stream.capture_forward") is None


# ---------------------------------------------------------------------------
# quantize-time guardrail ladder
# ---------------------------------------------------------------------------

def _toy_group(lanes=3, out=16, din=32, n=64):
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    w = jax.random.normal(k1, (lanes, out, din), jnp.float32)
    x = jax.random.normal(k2, (lanes, n, din), jnp.float32)
    H = jnp.einsum("bni,bnj->bij", x, x)
    member = PlanMember(
        "grp", w, hess.HessianState(H, jnp.full((lanes,), n, jnp.int32)), x,
        jnp.full((lanes,), n, jnp.int32), starved=False,
        names=[f"l{i}" for i in range(lanes)])
    qc = dataclasses.replace(get_config("opt-proxy", smoke=True).quant,
                             group_size=16, blocksize=16, rpiq_iters=2)
    return qc, member


class TestGuardrailLadder:
    def _run(self, qc, member, spec=None):
        plan = build_plan(qc, [member])
        report = QuantReport()
        if spec is None:
            res = execute_plan(qc, plan, report)
        else:
            with faults.inject(spec):
                res = execute_plan(qc, plan, report)
        return np.asarray(jax.device_get(res["grp"].w_q)), report

    def test_clean_run_has_no_guardrail_activity(self):
        qc, member = _toy_group()
        _, report = self._run(qc, member)
        assert report.guardrail_stats == {}
        assert all(r.mode == "rpiq" for r in report.linears)

    def test_nan_hessian_forces_rtn_rung(self):
        qc, member = _toy_group()
        clean, _ = self._run(qc, member)
        wq, report = self._run(qc, member, "hessian.cholesky@1:nan")
        gs = report.guardrail_stats
        assert gs["lanes_flagged"] == 1
        assert gs["lanes_rtn_forced"] == 1
        assert gs["damp_retries"] == qc.guardrail_retries
        assert report.linears[0].mode == "rtn-guardrail"
        assert all(r.mode == "rpiq" for r in report.linears[1:])
        # the rescued lane is finite, every untouched lane bitwise-unchanged
        assert np.isfinite(wq[0]).all()
        np.testing.assert_array_equal(clean[1:], wq[1:])

    def test_nonpsd_hessian_recovered_by_damp_escalation(self):
        qc, member = _toy_group()
        clean, _ = self._run(qc, member)
        wq, report = self._run(qc, member, "hessian.cholesky@1:nonpsd")
        gs = report.guardrail_stats
        assert gs["damp_retries"] >= 1
        assert gs["lanes_damp_recovered"] == 1
        assert gs["lanes_rtn_forced"] == 0
        assert all(r.mode == "rpiq" for r in report.linears)
        assert np.isfinite(wq[0]).all()
        np.testing.assert_array_equal(clean[1:], wq[1:])

    def test_guardrail_off_lets_nan_through(self):
        qc, member = _toy_group()
        qc = dataclasses.replace(qc, guardrail=False)
        wq, report = self._run(qc, member, "hessian.cholesky@1:nan")
        assert not np.isfinite(wq[0]).all()
        assert report.guardrail_stats == {}


# ---------------------------------------------------------------------------
# kill-and-resume: bitwise-identical artifacts after a mid-run crash
# ---------------------------------------------------------------------------

def _quant_setup(arch):
    cfg = get_config(arch, smoke=True)
    cfg = dataclasses.replace(cfg, quant=dataclasses.replace(
        cfg.quant, calib_batches=2, calib_batch_size=2, calib_seq_len=16))
    mc, qc = cfg.model, cfg.quant
    params = (T.init_encdec_params(mc, jax.random.PRNGKey(0))
              if mc.is_encoder_decoder
              else T.init_params(mc, jax.random.PRNGKey(0)))
    data = MarkovLM(mc.vocab_size, seed=7)
    calib = calibration_batches(data, qc.calib_batches, qc.calib_batch_size,
                                min(qc.calib_seq_len, mc.max_seq_len - 8))
    if mc.is_encoder_decoder:
        for i, b in enumerate(calib):
            b["frames"] = jax.random.normal(
                jax.random.PRNGKey(i),
                (qc.calib_batch_size, mc.encoder_seq_len, mc.d_model),
                jnp.float32)
    return cfg, params, calib


def _leaves(tree):
    return [np.asarray(jax.device_get(l))
            for l in jax.tree_util.tree_leaves(tree)]


_BASELINES = {}


def _baseline(arch):
    if arch not in _BASELINES:
        cfg, params, calib = _quant_setup(arch)
        pq, rep = quantize_model(cfg, params, calib)
        _BASELINES[arch] = (cfg, params, calib, _leaves(pq),
                            [r.mode for r in rep.linears])
    return _BASELINES[arch]


class TestKillAndResume:
    # hit numbers land the kill inside a later layer so at least one step
    # checkpoint exists (a kill before the first step completes resumes
    # from scratch — correct, but not what this parity test pins)
    @pytest.mark.parametrize("arch,hit", [
        ("opt-proxy", 5),             # dense: 3 groups/layer, kill in layer 2
        ("whisper-large-v3", 8),      # enc-dec: kill past the encoder fence
        ("olmoe-1b-7b", 4),           # MoE expert stacks
    ])
    @pytest.mark.parametrize("pipeline", ["serial", "overlap"])
    def test_stage1_kill_resume_bitwise(self, arch, hit, pipeline, tmp_path):
        cfg, params, calib, ref, ref_modes = _baseline(arch)
        cfg_k = dataclasses.replace(cfg, quant=dataclasses.replace(
            cfg.quant, ckpt_dir=str(tmp_path), resume="auto",
            pipeline=pipeline))
        with pytest.raises(faults.FaultError):
            with faults.inject(f"plan.stage1_executor@{hit}"):
                quantize_model(cfg_k, params, calib)
        pq, rep = quantize_model(cfg_k, params, calib)
        assert rep.pipeline_stats.get("resumed_at", 0) > 0
        for a, b in zip(ref, _leaves(pq)):
            np.testing.assert_array_equal(a, b)
        assert [r.mode for r in rep.linears] == ref_modes

    def test_capture_kill_resume_across_encoder_fence(self, tmp_path):
        """Kill the *capture* forward of the first decoder-side layer: the
        resume must replay the encoder fence (stream switch) host-side and
        still produce bitwise-identical artifacts."""
        cfg, params, calib, ref, ref_modes = _baseline("whisper-large-v3")
        n_enc = cfg.model.encoder_layers
        cfg_k = dataclasses.replace(cfg, quant=dataclasses.replace(
            cfg.quant, ckpt_dir=str(tmp_path), resume="auto"))
        with pytest.raises(faults.FaultError):
            with faults.inject(f"stream.capture_forward@{n_enc + 1}"):
                quantize_model(cfg_k, params, calib)
        pq, rep = quantize_model(cfg_k, params, calib)
        assert rep.pipeline_stats.get("resumed_at", 0) > 0
        for a, b in zip(ref, _leaves(pq)):
            np.testing.assert_array_equal(a, b)

    def test_config_fingerprint_mismatch_restarts_fresh(self, tmp_path):
        cfg, params, calib, ref, _ = _baseline("opt-proxy")
        cfg_k = dataclasses.replace(cfg, quant=dataclasses.replace(
            cfg.quant, ckpt_dir=str(tmp_path), resume="auto"))
        with pytest.raises(faults.FaultError):
            with faults.inject("plan.stage1_executor@5"):
                quantize_model(cfg_k, params, calib)
        # change a quantization knob: the stale checkpoint must be ignored
        cfg_m = dataclasses.replace(cfg_k, quant=dataclasses.replace(
            cfg_k.quant, rpiq_iters=cfg_k.quant.rpiq_iters + 1))
        with pytest.warns(RuntimeWarning, match="fingerprint"):
            pq, rep = quantize_model(cfg_m, params, calib)
        assert rep.pipeline_stats.get("resumed_at") is None

    def test_stage2_kill_without_ckpt_dir_just_crashes(self):
        """No ckpt_dir: the fault propagates and nothing is left behind."""
        cfg, params, calib, _, _ = _baseline("opt-proxy")
        with pytest.raises(faults.FaultError):
            with faults.inject("plan.stage2_executor@2"):
                quantize_model(cfg, params, calib)


# ---------------------------------------------------------------------------
# hardened serving loop
# ---------------------------------------------------------------------------

def _serve_setup(packed=False, **serve_kw):
    cfg = get_config("opt-proxy", smoke=True)
    if serve_kw:
        cfg = dataclasses.replace(cfg, serve=dataclasses.replace(
            cfg.serve, **serve_kw))
    params = T.init_params(cfg.model, jax.random.PRNGKey(0))
    if packed:
        params = pack_for_serving(cfg, params)
    return cfg, params


def _submit_n(eng, n=3, mnt=6, **kw):
    data = MarkovLM(eng.cfg.model.vocab_size, seed=0)
    return [eng.submit({"tokens": data.batch(1, 8)["tokens"]},
                       max_new_tokens=mnt, **kw) for _ in range(n)]


class TestServingHardening:
    def test_timeout_eviction_on_virtual_clock(self):
        cfg, params = _serve_setup()
        clockbox = [0.0]
        eng = ContinuousEngine(cfg, params, max_len=64,
                               clock=lambda: clockbox[0])
        rids = _submit_n(eng, timeout_s=5.0)
        done = {}
        while not eng.idle:
            clockbox[0] += 2.0
            for f in eng.step().finished:
                done[f.rid] = f
        assert eng.stats["timeout_evictions"] >= 1
        assert any(done[r].status == "timeout" for r in rids)
        assert all(r in done for r in rids)       # every request terminates
        # evicted lanes are refilled / freed: engine fully drained
        assert eng.active == 0 and eng.idle

    def test_queue_bound_rejects_explicitly(self):
        cfg, params = _serve_setup(max_queue=2)
        eng = ContinuousEngine(cfg, params, max_len=64)
        _submit_n(eng, n=2, mnt=4)
        with pytest.raises(QueueFullError):
            _submit_n(eng, n=1, mnt=4)
        assert eng.stats["rejections"] == 1
        done = eng.run()                          # admitted ones still finish
        assert len(done) == 2

    def test_cancel_everywhere(self):
        cfg, params = _serve_setup()
        eng = ContinuousEngine(cfg, params, max_len=64)
        rids = _submit_n(eng, n=3)
        # queued cancel (before any tick)
        c = eng.cancel(rids[2])
        assert c is not None and c.status == "cancelled"
        eng.step()
        eng.step()
        # in-flight cancel (prefilled or decoding by now)
        c = eng.cancel(rids[0])
        assert c is not None and c.status == "cancelled"
        assert eng.cancel(rids[0]) is None        # already gone
        assert eng.stats["cancelled"] == 2
        done = eng.run()
        assert done[rids[1]].status == "ok"

    def test_quarantine_evicts_only_poisoned_lane(self):
        cfg, params = _serve_setup()
        eng0 = ContinuousEngine(cfg, params, max_len=64)
        rids0 = _submit_n(eng0)
        clean = eng0.run()
        eng = ContinuousEngine(cfg, params, max_len=64)
        rids = _submit_n(eng)
        with faults.inject("serve.decode_step@2"):
            done = eng.run()
        assert eng.stats["quarantined"] == 1
        statuses = [done[r].status for r in rids]
        assert statuses.count("quarantined") == 1
        # unaffected lanes: token-identical to the fault-free run
        for r0, r in zip(rids0, rids):
            if done[r].status == "ok":
                np.testing.assert_array_equal(clean[r0].tokens,
                                              done[r].tokens)

    def test_nan_guard_off_disables_quarantine(self):
        cfg, params = _serve_setup(decode_nan_guard=False)
        eng = ContinuousEngine(cfg, params, max_len=64)
        rids = _submit_n(eng, mnt=3)
        with faults.inject("serve.decode_step@2"):
            done = eng.run()
        assert eng.stats["quarantined"] == 0
        assert all(done[r].status == "ok" for r in rids)

    def test_prefill_fault_drops_only_its_request(self):
        cfg, params = _serve_setup()
        eng = ContinuousEngine(cfg, params, max_len=64)
        rids = _submit_n(eng)
        with faults.inject("serve.prefill_chunk@1"):
            done = eng.run()
        assert eng.stats["prefill_failures"] == 1
        statuses = [done[r].status for r in rids]
        assert statuses.count("error") == 1 and statuses.count("ok") == 2

    def test_pallas_kernel_fault_degrades_to_xla(self):
        cfg_x, packed = _serve_setup(packed=True, w4a16_impl="xla")
        eng_x = ContinuousEngine(cfg_x, packed, max_len=64)
        rids_x = _submit_n(eng_x, mnt=5)
        ref = eng_x.run()
        cfg_p = dataclasses.replace(cfg_x, serve=dataclasses.replace(
            cfg_x.serve, w4a16_impl="pallas"))
        eng_p = ContinuousEngine(cfg_p, packed, max_len=64)
        rids_p = _submit_n(eng_p, mnt=5)
        with pytest.warns(RuntimeWarning, match="degrading"):
            with faults.inject("kernels.pallas_dispatch@1"):
                done = eng_p.run()
        stats = eng_p.engine_stats()
        assert stats["kernel_degradations"] == 1
        assert stats["w4a16_impl"] == "xla"
        for a, b in zip(rids_x, rids_p):
            assert done[b].status == "ok"
            np.testing.assert_array_equal(ref[a].tokens, done[b].tokens)

    def test_static_generate_degrades_and_matches_xla(self):
        cfg_x, packed = _serve_setup(packed=True, w4a16_impl="xla")
        data = MarkovLM(cfg_x.model.vocab_size, seed=0)
        batch = data.batch(2, 8)
        ref = E.generate(cfg_x, packed, batch, max_new_tokens=4,
                         temperature=0.0)
        cfg_p = dataclasses.replace(cfg_x, serve=dataclasses.replace(
            cfg_x.serve, w4a16_impl="pallas"))
        before = E.engine_stats()["kernel_degradations"]
        with pytest.warns(RuntimeWarning, match="degrading"):
            with faults.inject("kernels.pallas_dispatch@1"):
                res = E.generate(cfg_p, packed, batch, max_new_tokens=4,
                                 temperature=0.0)
        assert E.engine_stats()["kernel_degradations"] == before + 1
        np.testing.assert_array_equal(np.asarray(ref.tokens),
                                      np.asarray(res.tokens))

    def test_non_kernel_fault_is_not_swallowed(self):
        """A request-level fault inside a guarded call must propagate to its
        own handler, not trigger a kernel degradation."""
        assert not E._kernel_fault(faults.FaultError("serve.prefill_chunk",
                                                     "kill", 1))
        assert E._kernel_fault(faults.FaultError("kernels.pallas_dispatch",
                                                 "kill", 1))
        assert E._kernel_fault(RuntimeError("mosaic lowering failed"))


# ---------------------------------------------------------------------------
# instrumented kernel fallbacks (satellite: silent → counted)
# ---------------------------------------------------------------------------

class TestKernelFallbackAccounting:
    def test_vmem_budget_fallback_counts_and_warns(self, monkeypatch):
        # pretend we're on TPU with a zero VMEM budget: the auto path must
        # take the xla fallback (fine on CPU) and account for it
        monkeypatch.setattr(kops, "_on_tpu", lambda: True)
        monkeypatch.setattr(kops, "_VMEM_BUDGET_BYTES", 0)
        kops.reset_fallback_stats()
        k, n, m, gs = 64, 32, 8, 32
        x = jax.random.normal(jax.random.PRNGKey(0), (m, k), jnp.float32)
        packed = jax.random.randint(jax.random.PRNGKey(1), (n, k // 2),
                                    0, 255).astype(jnp.uint8)
        scales = jnp.ones((n, k // gs), jnp.float32)
        zeros = jnp.zeros((n, k // gs), jnp.float32)
        with pytest.warns(RuntimeWarning, match="vmem-budget"):
            y = kops.w4a16_matmul(x, packed, scales, zeros, group_size=gs,
                                  impl="auto")
        assert y.shape == (m, n)
        stats = kops.fallback_stats()
        assert stats.get("w4a16_matmul:vmem-budget", 0) == 1
        kops.reset_fallback_stats()
        assert kops.fallback_stats() == {}

    def test_quantize_report_picks_up_fallback_delta(self, monkeypatch):
        from repro.core import plan as qplan
        from repro.kernels import ref as kref
        # fake a zero-VMEM TPU so the budget-gated executors downgrade; the
        # un-gated pallas entry points (hessian accum, pack) are pinned to
        # their reference paths — they have no budget ladder to exercise
        # and would otherwise try a real Mosaic compile on this host
        monkeypatch.setattr(kops, "_on_tpu", lambda: True)
        monkeypatch.setattr(kops, "_VMEM_BUDGET_BYTES", 0)
        monkeypatch.setattr(kops, "hessian_accum",
                            lambda x, **k: kref.hessian_accum_ref(x))
        monkeypatch.setattr(
            kops, "quant_pack",
            lambda w, s, z, **k: kref.quant_pack_ref(
                w, s, z, k.get("group_size", 128)))
        # trace-time decisions only fire on fresh compiles: drop executors
        # cached by earlier tests in this process
        qplan.clear_executor_cache()
        kops.reset_fallback_stats()
        cfg, params, calib = _quant_setup("opt-proxy")
        with pytest.warns(RuntimeWarning, match="fell back"):
            _, rep = quantize_model(cfg, params, calib)
        qplan.clear_executor_cache()     # poisoned-budget entries: drop them
        assert rep.kernel_fallbacks          # nonzero deltas recorded
        assert all(v > 0 for v in rep.kernel_fallbacks.values())
