"""End-to-end quantization pipeline: taps → Hessians → GPTQ → RPIQ →
propagation → packing → quantized serving."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.pipeline import pack_for_serving, quantize_model
from repro.core.quant import QuantizedTensor
from repro.data import MarkovLM, calibration_batches
from repro.models import transformer as T


def _quantize(arch, n_batches=3, bs=4, seq=24, **qkw):
    cfg = get_config(arch, smoke=True)
    for k, v in qkw.items():
        setattr(cfg.quant, k, v)
    mc = cfg.model
    key = jax.random.PRNGKey(0)
    params = (T.init_encdec_params(mc, key) if mc.is_encoder_decoder
              else T.init_params(mc, key))
    calib = calibration_batches(MarkovLM(mc.vocab_size, seed=1),
                                n_batches, bs, seq)
    if mc.is_encoder_decoder:
        for i, b in enumerate(calib):
            b["frames"] = jax.random.normal(
                jax.random.PRNGKey(i), (bs, mc.encoder_seq_len, mc.d_model))
    return cfg, params, calib, *quantize_model(cfg, params, calib)


class TestPipeline:
    def test_dense_arch(self):
        cfg, params, calib, params_q, report = _quantize("opt-proxy")
        # opt-proxy (ungated): q,k,v,o + up,down per layer = 6
        assert len(report.linears) == cfg.model.num_layers * 6
        lg_fp, _ = T.forward(cfg.model, params, calib[0]["tokens"])
        lg_q, _ = T.forward(cfg.model, params_q, calib[0]["tokens"])
        rel = float(jnp.linalg.norm(lg_fp - lg_q)
                    / jnp.linalg.norm(lg_fp))
        assert rel < 0.5 and not bool(jnp.any(jnp.isnan(lg_q)))

    def test_quantized_beats_rtn_proxy(self):
        """GPTQ+RPIQ output error should beat naive RTN of same layers."""
        from repro.core.quant import fake_quantize
        cfg, params, calib, params_q, _ = _quantize("opt-proxy")
        mc = cfg.model

        def rtn_w(v):
            """RTN on (..., in, out) weights along the input dim."""
            w_oi = jnp.swapaxes(v, -1, -2)
            lead, o, i = w_oi.shape[:-2], w_oi.shape[-2], w_oi.shape[-1]
            q = fake_quantize(w_oi.reshape(-1, i), cfg.quant.bits,
                              cfg.quant.group_size)
            return jnp.swapaxes(q.reshape(*lead, o, i), -1, -2)

        def rtn_tree(tree, path=""):
            if isinstance(tree, dict):
                out = {}
                for k, v in tree.items():
                    if k == "w" and getattr(v, "ndim", 0) >= 2 \
                            and ("mixer" in path or "mlp" in path):
                        out[k] = rtn_w(v)
                    else:
                        out[k] = rtn_tree(v, f"{path}.{k}")
                return out
            if isinstance(tree, list):
                return [rtn_tree(v, path) for v in tree]
            return tree

        params_rtn = rtn_tree(params)
        toks = calib[-1]["tokens"]
        lg_fp, _ = T.forward(mc, params, toks)
        lg_q, _ = T.forward(mc, params_q, toks)
        lg_r, _ = T.forward(mc, params_rtn, toks)
        e_q = float(jnp.linalg.norm(lg_fp - lg_q))
        e_r = float(jnp.linalg.norm(lg_fp - lg_r))
        assert e_q < e_r, (e_q, e_r)

    def test_moe_per_expert_quantization(self):
        cfg, params, calib, params_q, report = _quantize("olmoe-1b-7b")
        names = [l.name for l in report.linears]
        assert any("w_gate[" in n for n in names)
        assert any("w_down[" in n for n in names)
        # router untouched
        seg0 = params_q["blocks"][0]
        np.testing.assert_array_equal(
            np.asarray(seg0["sub0"]["mlp"]["router"]["w"]),
            np.asarray(params["blocks"][0]["sub0"]["mlp"]["router"]["w"]))

    def test_ssm_arch(self):
        cfg, params, calib, params_q, report = _quantize("falcon-mamba-7b")
        modes = {l.name: l.mode for l in report.linears}
        assert any(m == "rpiq" for m in modes.values())
        lg, _ = T.forward(cfg.model, params_q, calib[0]["tokens"])
        assert not bool(jnp.any(jnp.isnan(lg)))

    def test_rpiq_exact_gram_improves(self):
        """Beyond-paper mode: exact-gram α=0.25 actually lowers Γ on a
        meaningful fraction of linears."""
        cfg, params, calib, params_q, report = _quantize(
            "opt-proxy", rpiq_use_global_hessian=False, rpiq_alpha=0.25,
            rpiq_iters=6)
        improved = [l for l in report.linears
                    if l.gamma and l.gamma_final < l.gamma[0] * 0.995]
        assert len(improved) >= len(report.linears) // 3

    def test_pack_roundtrip_exact(self):
        cfg, params, calib, params_q, _ = _quantize("opt-proxy")
        packed = pack_for_serving(cfg, params_q)
        # packed leaves exist
        qts = [l for l in jax.tree_util.tree_leaves(
            packed, is_leaf=lambda x: isinstance(x, QuantizedTensor))
            if isinstance(x := l, QuantizedTensor)]
        assert len(qts) > 0
        lg_q, _ = T.forward(cfg.model, params_q, calib[0]["tokens"])
        lg_p, _ = T.forward(cfg.model, packed, calib[0]["tokens"])
        rel = float(jnp.linalg.norm(lg_p - lg_q)
                    / (jnp.linalg.norm(lg_q) + 1e-9))
        assert rel < 2e-2, rel

    def test_quantized_decode_runs(self):
        cfg, params, calib, params_q, _ = _quantize("internlm2-1.8b")
        packed = pack_for_serving(cfg, params_q)
        toks = calib[0]["tokens"][:, :8]
        lg, caches = T.prefill(cfg.model, packed, toks, max_len=16)
        tok = jnp.argmax(lg, -1).astype(jnp.int32)
        lg2, _ = T.decode_step(cfg.model, packed, tok,
                               jnp.full((toks.shape[0],), 8), caches)
        assert not bool(jnp.any(jnp.isnan(lg2)))

    def test_single_instance_memory_model(self):
        """Stage 2 resident set = last batch + Hessian, not all batches
        (paper eq. 15-17): verified structurally via the report."""
        cfg, params, calib, params_q, report = _quantize("opt-proxy",
                                                         n_batches=4)
        assert report.seconds_stage2 > 0
        # Γ histories recorded per linear (Table 5 artifact)
        assert all(len(l.gamma) >= 1 for l in report.linears
                   if l.mode == "rpiq")


class TestJitCapture:
    """The jitted calibration forward (quant.jit_capture) must match the
    legacy eager capture and reuse compiled entries across repeated
    layers."""

    def test_jit_capture_matches_eager(self):
        """Jit-vs-eager fusion rounds the captured activations differently
        in the last bits, and greedy rounding + layerwise propagation
        amplify that chaotically — the two runs are equally faithful
        quantizations, not bitwise twins (measured: ~13.5% output error vs
        fp for BOTH, ~5% between them).  Parity therefore asserts the
        functional contract: same modes/report structure, near-identical
        quantization error against the fp model, and close logits."""
        outs = []
        for jit_capture in (True, False):
            cfg, params, calib, params_q, rep = _quantize(
                "opt-proxy", jit_capture=jit_capture)
            outs.append((cfg, params, calib, params_q, rep))
        assert len(jax.tree_util.tree_leaves(outs[0][3])) \
            == len(jax.tree_util.tree_leaves(outs[1][3]))
        assert [l.mode for l in outs[0][4].linears] \
            == [l.mode for l in outs[1][4].linears]
        cfg, params, calib = outs[0][0], outs[0][1], outs[0][2]
        toks = calib[0]["tokens"]
        lg_fp, _ = T.forward(cfg.model, params, toks)
        lg_a, _ = T.forward(cfg.model, outs[0][3], toks)
        lg_b, _ = T.forward(cfg.model, outs[1][3], toks)
        nrm = float(jnp.linalg.norm(lg_fp))
        err_a = float(jnp.linalg.norm(lg_a - lg_fp)) / nrm
        err_b = float(jnp.linalg.norm(lg_b - lg_fp)) / nrm
        assert abs(err_a - err_b) < 0.02, (err_a, err_b)
        rel = float(jnp.linalg.norm(lg_a - lg_b)) / nrm
        assert rel < 0.1, rel

    def test_repeated_layers_reuse_compiled_forward(self):
        """Two same-shape layers: layer 2 adds no new forward entries."""
        from repro.core import pipeline as qpipe
        from repro.core.plan import QuantReport
        from repro.models.linear import dense, init_dense

        cfg = get_config("opt-proxy", smoke=True)
        qc = cfg.quant

        def apply_fn(p, h, bi):
            return dense(p["mlp"]["fc"], h, name="mlp.fc")

        hs = [jax.random.normal(jax.random.PRNGKey(i), (2, 8, 32))
              for i in range(3)]
        fwd_cache = {}
        sizes = []
        for li in range(2):
            lp = {"mlp": {"fc": init_dense(jax.random.PRNGKey(10 + li),
                                           32, 32)}}
            _, hs = qpipe.quantize_layer(cfg, lp, hs, apply_fn,
                                         QuantReport(),
                                         fwd_cache=fwd_cache,
                                         fwd_key=("test",))
            sizes.append(len(fwd_cache))
        assert sizes[0] > 0
        # capture entry + propagate entry (quantized params add grid
        # leaves), shared by both layers
        assert sizes[1] == sizes[0] == 2
