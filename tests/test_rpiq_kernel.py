"""Fused Pallas rpiq_block kernel vs the XLA closed loop and NumPy oracle.

The kernel runs EVERY Gauss–Seidel round of the stage-2 refinement in one
``pallas_call`` and defers the early-stop/best-projection bookkeeping to a
handful of vectorized ops (kernels/rpiq_block.py); both backends consume
the same pre-factored blockwise curvature inverses, so interpret-mode
output is pinned bitwise-close (≤1e-6) on ``w_q``, ``proj_loss`` and
``loss_history`` — with per-lane ``iters_run`` exactly equal — across
symmetric/asymmetric grids, group sizes, both curvature modes, non-square
shapes, a padded-Cout row tile, and the stacked member axis the quant plan
feeds it.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from test_batched_parity import stack_problem  # noqa: F401  (fixture reuse)

from repro.core import hessian as hess
from repro.core.gptq import gptq_quantize, gptq_quantize_batched
from repro.core.rpiq import (_block_curvature_inv, _rpiq_core, rpiq_refine,
                             rpiq_refine_batched)
from repro.kernels import ops as kops
from repro.kernels import ref

pytestmark = pytest.mark.pallas


def _problem(cout, cin, n=256, seed=0, symmetric=False, group_size=64,
             blocksize=64):
    """n = 256 instance rows keeps the exact-gram blockwise curvature well
    conditioned at blocksize 128 (a square X_i Gram is barely invertible
    and would amplify backend rounding differences past the 1e-6 pin)."""
    kw, kx = jax.random.split(jax.random.PRNGKey(seed))
    w = jax.random.normal(kw, (cout, cin)) * 0.1
    x = jax.random.normal(kx, (2 * cin, cin))
    st = hess.accumulate(hess.init_hessian(cin), x)
    hd = hess.damped(st, 0.01)
    u = hess.cholesky_inverse_upper(hd)
    res1 = gptq_quantize(w, u, bits=4, group_size=group_size,
                         blocksize=blocksize, symmetric=symmetric)
    return dict(w=w, x=x[-n:], st=st, hd=hd, res1=res1)


def _assert_result_parity(a, b, *, iters_equal=True, rtol=1e-6):
    """(w_q, w_cont, hist, proj_loss, iters) tuples: pin the closed-loop
    outputs the pipeline consumes.  (w_cont intentionally excluded: the
    fused kernel runs rounds past an early stop — kernel docstring.)"""
    np.testing.assert_allclose(np.asarray(a[0]), np.asarray(b[0]),
                               atol=1e-6)
    ha, hb = np.asarray(a[2]), np.asarray(b[2])
    fin = np.isfinite(ha)
    assert (fin == np.isfinite(hb)).all()
    np.testing.assert_allclose(ha[fin], hb[fin], rtol=rtol)
    np.testing.assert_allclose(np.asarray(a[3]), np.asarray(b[3]),
                               rtol=rtol)
    if iters_equal:
        np.testing.assert_array_equal(np.asarray(a[4]), np.asarray(b[4]))


class TestRPIQBlockKernel:
    @pytest.mark.parametrize("symmetric", [False, True])
    @pytest.mark.parametrize("group_size,blocksize", [(64, 64), (128, 128),
                                                      (64, 128)])
    @pytest.mark.parametrize("exact_gram,alpha", [(False, 0.1),
                                                  (True, 1.0)])
    def test_matches_core_and_ref(self, symmetric, group_size, blocksize,
                                  exact_gram, alpha):
        """Non-square (48, 256): pallas == _rpiq_core == NumPy oracle on
        w_q / proj_loss / loss_history, iters_run equal."""
        p = _problem(48, 256, seed=group_size + blocksize + symmetric,
                     symmetric=symmetric, group_size=group_size,
                     blocksize=blocksize)
        kw = dict(bits=4, group_size=group_size, block_size=blocksize,
                  alpha=alpha, t_max=4, early_stop=True,
                  symmetric=symmetric)
        res1 = p["res1"]
        hinv = _block_curvature_inv(p["x"], p["hd"], p["st"].count, None,
                                    block_size=blocksize,
                                    exact_gram=exact_gram)
        out_p = kops.rpiq_block(res1.w_q, p["w"], p["x"], hinv, res1.scales,
                                res1.zeros, impl="pallas", **kw)
        core = _rpiq_core(res1.w_q, p["w"], p["x"], hinv, res1.scales,
                          res1.zeros, **kw)
        _assert_result_parity(out_p, tuple(core))
        refo = ref.rpiq_block_ref(
            np.asarray(res1.w_q), np.asarray(p["w"]), np.asarray(p["x"]),
            np.asarray(hinv), np.asarray(res1.scales),
            np.asarray(res1.zeros), **kw)
        _assert_result_parity(out_p, refo, rtol=1e-6)
        # the refinement never leaves the stage-1 grid
        s = jnp.repeat(res1.scales, group_size, axis=1)
        z = jnp.repeat(res1.zeros, group_size, axis=1)
        codes = jnp.round(out_p[0] / s) + (0.0 if symmetric else z)
        np.testing.assert_allclose(np.asarray((codes - (0.0 if symmetric
                                                        else z)) * s),
                                   np.asarray(out_p[0]), atol=1e-4)

    def test_no_early_stop_runs_all_rounds(self):
        """early_stop=False: every lane reports t_max rounds and the full
        (finite) history, identically across backends."""
        p = _problem(32, 128, seed=11)
        kw = dict(bits=4, group_size=64, block_size=64, alpha=0.1, t_max=3,
                  early_stop=False, symmetric=False)
        hinv = _block_curvature_inv(p["x"], p["hd"], p["st"].count, None,
                                    block_size=64, exact_gram=False)
        res1 = p["res1"]
        out_p = kops.rpiq_block(res1.w_q, p["w"], p["x"], hinv, res1.scales,
                                res1.zeros, impl="pallas", **kw)
        out_x = kops.rpiq_block(res1.w_q, p["w"], p["x"], hinv, res1.scales,
                                res1.zeros, impl="xla", **kw)
        assert int(out_p[4]) == 3 and int(out_x[4]) == 3
        assert np.isfinite(np.asarray(out_p[2])).all()
        _assert_result_parity(out_p, out_x)

    def test_padded_cout_tile(self):
        """Cout = 20 with an explicit block_out = 8 → zero-padded row tile
        (24 rows, 3 row tiles); padded rows must not perturb real ones or
        the Γ partial sums that drive the early stop."""
        p = _problem(20, 128, seed=3)
        kw = dict(bits=4, group_size=64, block_size=64, alpha=1.0, t_max=4,
                  early_stop=True, symmetric=False)
        hinv = _block_curvature_inv(p["x"], p["hd"], p["st"].count, None,
                                    block_size=64, exact_gram=True)
        res1 = p["res1"]
        out_p = kops.rpiq_block(res1.w_q, p["w"], p["x"], hinv, res1.scales,
                                res1.zeros, impl="pallas", block_out=8,
                                **kw)
        core = _rpiq_core(res1.w_q, p["w"], p["x"], hinv, res1.scales,
                          res1.zeros, **kw)
        assert out_p[0].shape == (20, 128)
        _assert_result_parity(out_p, tuple(core))

    def test_batched_member_axis(self, stack_problem):
        """The stacked group slab maps onto the kernel's member grid axis:
        every lane matches the XLA batched path and the per-member core,
        with per-lane early stops (iters_run) intact."""
        p = stack_problem
        Hd = hess.damped(p["st"], 0.01)
        U = hess.cholesky_inverse_upper(Hd)
        res1 = gptq_quantize_batched(p["W"], U, bits=4, group_size=32,
                                     blocksize=64)
        xc = jnp.full((p["B"],), p["N"], jnp.int32)
        kw = dict(bits=4, group_size=32, block_size=64, alpha=0.25,
                  t_max=4, exact_gram=True)
        res_p = rpiq_refine_batched(res1.w_q, p["W"], p["X"], Hd,
                                    res1.scales, res1.zeros,
                                    h_count=p["st"].count, x_count=xc,
                                    impl="pallas", **kw)
        res_x = rpiq_refine_batched(res1.w_q, p["W"], p["X"], Hd,
                                    res1.scales, res1.zeros,
                                    h_count=p["st"].count, x_count=xc,
                                    impl="xla", **kw)
        _assert_result_parity(tuple(res_p), tuple(res_x))
        for i in range(p["B"]):
            r = rpiq_refine(res1.w_q[i], p["W"][i], p["X"][i], Hd[i],
                            res1.scales[i], res1.zeros[i],
                            h_count=p["st"].count[i], x_count=xc[i], **kw)
            np.testing.assert_allclose(np.asarray(res_p.w_q[i]),
                                       np.asarray(r.w_q), atol=1e-6)
            assert int(res_p.iters_run[i]) == int(r.iters_run)

    def test_auto_impl_off_tpu_is_xla(self, stack_problem):
        p = stack_problem
        Hd = hess.damped(p["st"], 0.01)
        U = hess.cholesky_inverse_upper(Hd)
        res1 = gptq_quantize_batched(p["W"], U, bits=4, group_size=32,
                                     blocksize=64)
        kw = dict(bits=4, group_size=32, block_size=64, alpha=0.1, t_max=2)
        res_a = rpiq_refine_batched(res1.w_q, p["W"], p["X"], Hd,
                                    res1.scales, res1.zeros, impl="auto",
                                    **kw)
        res_x = rpiq_refine_batched(res1.w_q, p["W"], p["X"], Hd,
                                    res1.scales, res1.zeros, impl="xla",
                                    **kw)
        np.testing.assert_array_equal(np.asarray(res_a.w_q),
                                      np.asarray(res_x.w_q))
        np.testing.assert_array_equal(np.asarray(res_a.w_cont),
                                      np.asarray(res_x.w_cont))


class TestPipelineArtifactParity:
    def test_quantized_params_match_across_impls(self):
        """End to end: quantize a tiny model under each stage-2 backend —
        the scattered weights and grids must agree ≤2e-5."""
        from repro.configs import get_config
        from repro.core.pipeline import quantize_model
        from repro.data import MarkovLM, calibration_batches
        from repro.models import transformer as T

        outs, reports = [], []
        for impl in ("xla", "pallas"):
            cfg = get_config("opt-proxy", smoke=True)
            cfg.model.num_layers = 2
            cfg.quant.rpiq_impl = impl
            cfg.quant.rpiq_iters = 2
            cfg.quant.rpiq_alpha = 0.25
            params = T.init_params(cfg.model, jax.random.PRNGKey(0))
            calib = calibration_batches(MarkovLM(cfg.model.vocab_size,
                                                 seed=2), 2, 2, 16)
            pq, rep = quantize_model(cfg, params, calib)
            outs.append(pq)
            reports.append(rep)
        flat0 = jax.tree_util.tree_leaves(outs[0])
        flat1 = jax.tree_util.tree_leaves(outs[1])
        assert len(flat0) == len(flat1)
        for a, b in zip(flat0, flat1):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32),
                                       atol=2e-5)
        # per-linear early-stop round counts agree backend to backend
        it0 = [(l.name, l.iters) for l in reports[0].linears]
        it1 = [(l.name, l.iters) for l in reports[1].linears]
        assert sorted(it0) == sorted(it1)
