"""Shared fixtures. NOTE: no XLA_FLAGS here — unit/smoke tests must see the
real single CPU device; multi-device tests run in subprocesses
(test_distributed.py) with their own device-count flag."""
import os
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


@pytest.fixture(scope="session")
def rng_key():
    import jax
    return jax.random.PRNGKey(0)
