"""End-to-end system behaviour: the paper's full loop on CPU scale.

train (opt-proxy) → quantize (GPTQ stage 1 + RPIQ stage 2, single-instance
calibration) → serve (int4-packed decode) → verify quality ordering:
fp ≥ RPIQ ≥ GPTQ-only on held-out perplexity (the paper's Table 1 claim at
smoke scale).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.pipeline import pack_for_serving, quantize_model
from repro.data import MarkovLM, calibration_batches
from repro.models import transformer as T
from repro.serving.engine import generate
from repro.training.train_step import init_train_state, make_train_step


def _ppl(cfg, params, data, n=4, b=8, s=32):
    tot, cnt = 0.0, 0
    for i in range(n):
        toks = data.batch(b, s)["tokens"]
        logits, _ = T.forward(cfg.model, params, toks)
        logz = jax.nn.logsumexp(logits[:, :-1], axis=-1)
        gold = jnp.take_along_axis(logits[:, :-1],
                                   toks[:, 1:, None], axis=-1)[..., 0]
        tot += float(jnp.sum(logz - gold))
        cnt += int(toks[:, 1:].size)
    return float(np.exp(tot / cnt))


@pytest.mark.slow
def test_train_quantize_serve_loop():
    cfg = get_config("opt-proxy", smoke=True)
    cfg.train.lr = 3e-3
    cfg.train.warmup_steps = 5
    cfg.quant.rpiq_use_global_hessian = False   # eq.6 mode (stronger)
    cfg.quant.rpiq_alpha = 0.3
    cfg.quant.rpiq_iters = 6

    # 1. train until the model clearly beats random
    st = init_train_state(cfg, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(cfg))
    data = MarkovLM(cfg.model.vocab_size, seed=0, branching=3)
    for i in range(80):
        st, m = step(st, data.batch(8, 32))
    params = st.params
    eval_data = MarkovLM(cfg.model.vocab_size, seed=99, branching=3)
    # same chain structure: MarkovLM transition depends only on seed...
    # use a held-out stream of the SAME process for eval:
    eval_data = MarkovLM(cfg.model.vocab_size, seed=0, branching=3)
    eval_data.step = 10_000
    ppl_fp = _ppl(cfg, params, eval_data)
    assert ppl_fp < cfg.model.vocab_size / 4    # actually learned

    # 2. quantize: GPTQ-only vs full RPIQ
    calib = calibration_batches(MarkovLM(cfg.model.vocab_size, seed=0,
                                         branching=3), 4, 8, 32)
    cfg_gptq = get_config("opt-proxy", smoke=True)
    cfg_gptq.quant.rpiq_iters = 0
    pq_gptq, _ = quantize_model(cfg_gptq, params, calib)
    pq_rpiq, report = quantize_model(cfg, params, calib)

    eval_data.step = 10_000
    ppl_gptq = _ppl(cfg, pq_gptq, eval_data)
    eval_data.step = 10_000
    ppl_rpiq = _ppl(cfg, pq_rpiq, eval_data)

    # quality ordering with tolerance: quantized within 25% of fp; RPIQ not
    # worse than GPTQ by more than 2% (usually better).
    assert ppl_gptq < ppl_fp * 1.25, (ppl_fp, ppl_gptq)
    assert ppl_rpiq <= ppl_gptq * 1.02, (ppl_gptq, ppl_rpiq)

    # 3. serve the packed model
    packed = pack_for_serving(cfg, pq_rpiq)
    batch = data.batch(2, 8)
    res = generate(cfg, packed, batch, max_new_tokens=4, temperature=0.0)
    assert res.tokens.shape == (2, 4)
    assert not np.any(np.isnan(np.asarray(res.logprobs)))
