"""Streaming LayerWalker pipeline: quant.pipeline=overlap == serial.

The stream scheduler (core/stream.py) must be a pure re-scheduling of the
serial walk — same dispatches, same accumulation order — so the two modes
are pinned BITWISE on fixed-seed pipeline fixtures across all three
architectures the walker covers (decoder-only, encoder-decoder, MoE):
on-grid params, report Γ histories/modes, and packed serving artifacts.
A forced-fallback lane marks every layer's Hessian repair unsound and
checks the scheduler degrades to serial re-capture without changing
results; the capture-forward cache counters (satellite of the same PR)
are asserted on both walkers.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import pipeline as qpipe
from repro.core.pipeline import (capture_cache_stats, pack_for_serving,
                                 quantize_model)
from repro.data import MarkovLM, calibration_batches
from repro.models import transformer as T

ARCHS = ("opt-proxy", "whisper-large-v3", "olmoe-1b-7b")


def _fixture(arch, n_batches=3, bs=4, seq=24):
    cfg = get_config(arch, smoke=True)
    mc = cfg.model
    key = jax.random.PRNGKey(0)
    params = (T.init_encdec_params(mc, key) if mc.is_encoder_decoder
              else T.init_params(mc, key))
    calib = calibration_batches(MarkovLM(mc.vocab_size, seed=1),
                                n_batches, bs, seq)
    if mc.is_encoder_decoder:
        for i, b in enumerate(calib):
            b["frames"] = jax.random.normal(
                jax.random.PRNGKey(i), (bs, mc.encoder_seq_len, mc.d_model))
    return cfg, params, calib


def _run(arch, pipeline, **qkw):
    cfg, params, calib = _fixture(arch)
    cfg.quant.pipeline = pipeline
    for k, v in qkw.items():
        setattr(cfg.quant, k, v)
    params_q, report = quantize_model(cfg, params, calib)
    packed = pack_for_serving(cfg, params_q)
    return params_q, report, packed


def _assert_trees_bitwise(a, b, what):
    la, ta = jax.tree_util.tree_flatten(a)
    lb, tb = jax.tree_util.tree_flatten(b)
    assert ta == tb, f"{what}: tree structure differs"
    for i, (x, y) in enumerate(zip(la, lb)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                      err_msg=f"{what}: leaf {i}")


def _assert_reports_equal(rep_s, rep_o):
    recs_s = [(l.name, l.shape, l.mode, l.gptq_err, l.gamma, l.gamma_final,
               l.iters) for l in rep_s.linears]
    recs_o = [(l.name, l.shape, l.mode, l.gptq_err, l.gamma, l.gamma_final,
               l.iters) for l in rep_o.linears]
    assert recs_s == recs_o


class TestOverlapParity:
    """pipeline=overlap is bitwise pipeline=serial on every walker."""

    @pytest.mark.parametrize("arch", ARCHS)
    def test_overlap_matches_serial(self, arch):
        pq_s, rep_s, packed_s = _run(arch, "serial")
        pq_o, rep_o, packed_o = _run(arch, "overlap")
        _assert_trees_bitwise(pq_s, pq_o, f"{arch} on-grid params")
        _assert_trees_bitwise(packed_s, packed_o, f"{arch} packed artifacts")
        _assert_reports_equal(rep_s, rep_o)
        assert rep_o.pipeline_stats["mode"] == "overlap"
        assert rep_s.pipeline_stats["mode"] == "serial"
        assert rep_o.pipeline_stats["steps"] == len(rep_o.layer_step_seconds)

    def test_overlap_speculates_on_dense(self):
        """Dense stacks capture-ahead every adjacent same-slot pair and
        repair each speculation exactly once."""
        _, rep, _ = _run("opt-proxy", "overlap")
        st = rep.pipeline_stats
        assert st["spec_captures"] == st["steps"] - 1 > 0
        assert st["repairs"] == st["spec_captures"]
        assert st["serial_fallbacks"] == 0

    def test_moe_speculates_with_flip_repair(self):
        """Routed MoE now speculates like dense stacks: the plan-level
        flip repair (core/pipeline._moe_members) verifies the speculative
        routing on the true stream instead of degrading to serial."""
        _, rep, _ = _run("olmoe-1b-7b", "overlap")
        st = rep.pipeline_stats
        assert st["spec_captures"] == st["steps"] - 1 > 0
        assert st["repairs"] == st["spec_captures"]
        assert st["serial_fallbacks"] == 0
        # every speculated MoE layer went through the flip-repair ledger
        assert st["moe_spec_layers"] == st["spec_captures"]
        assert st["moe_assignments"] > 0
        assert st["moe_plan_reuses"] + st["moe_flip_repairs"] > 0
        assert st["fallback_flip_budget"] == 0

    def test_moe_flip_budget_zero_forces_serial_replan(self):
        """quant.moe_flip_budget=-1 rejects every speculative plan (any
        flip count exceeds a negative budget) — the layer re-plans
        serially, counted per reason, and results stay bitwise serial."""
        pq_s, rep_s, packed_s = _run("olmoe-1b-7b", "serial")
        pq_o, rep_o, packed_o = _run("olmoe-1b-7b", "overlap",
                                     moe_flip_budget=-1.0)
        st = rep_o.pipeline_stats
        assert st["fallback_flip_budget"] == st["moe_spec_layers"] > 0
        assert st["serial_fallbacks"] == st["fallback_flip_budget"]
        assert st["moe_plan_reuses"] == st["moe_flip_repairs"] == 0
        _assert_trees_bitwise(pq_s, pq_o, "flip-budget params")
        _assert_trees_bitwise(packed_s, packed_o, "flip-budget packed")
        _assert_reports_equal(rep_s, rep_o)

    def test_encdec_fence_blocks_speculation(self):
        """Speculation never crosses the enc→dec StreamSwitch: with 2+2
        layers, exactly the two within-stream pairs speculate."""
        _, rep, _ = _run("whisper-large-v3", "overlap")
        st = rep.pipeline_stats
        assert st["steps"] == 4
        assert st["spec_captures"] == st["repairs"] == 2

    def test_forced_fallback_lane(self, monkeypatch):
        """Repair marked unsound everywhere → scheduler degrades every
        step to serial re-capture, results still bitwise serial."""
        pq_s, rep_s, packed_s = _run("opt-proxy", "serial")
        monkeypatch.setattr(qpipe, "_layer_repair_sound", lambda lp: False)
        pq_o, rep_o, packed_o = _run("opt-proxy", "overlap")
        st = rep_o.pipeline_stats
        assert st["spec_captures"] == 0
        assert st["serial_fallbacks"] == st["steps"] - 1 > 0
        _assert_trees_bitwise(pq_s, pq_o, "forced-fallback params")
        _assert_trees_bitwise(packed_s, packed_o, "forced-fallback packed")
        _assert_reports_equal(rep_s, rep_o)

    def test_overlap_with_eager_capture(self):
        """quant.jit_capture=false disables speculation (eager forwards
        can't ride the async queue) but overlap still matches serial."""
        pq_s, rep_s, _ = _run("opt-proxy", "serial", jit_capture=False)
        pq_o, rep_o, _ = _run("opt-proxy", "overlap", jit_capture=False)
        assert rep_o.pipeline_stats["spec_captures"] == 0
        _assert_trees_bitwise(pq_s, pq_o, "eager-capture params")
        _assert_reports_equal(rep_s, rep_o)

    def test_unknown_pipeline_mode_raises(self):
        cfg, params, calib = _fixture("opt-proxy")
        cfg.quant.pipeline = "threaded"
        with pytest.raises(ValueError, match="quant.pipeline"):
            quantize_model(cfg, params, calib)


class TestCaptureCacheStats:
    """Per-run fwd_cache hygiene: repeated identical layers must HIT the
    compiled-forward cache on both walkers, and the counters are exposed
    next to plan.executor_cache_stats()."""

    def test_dense_walker_hits(self):
        _run("opt-proxy", "serial")
        st = capture_cache_stats()
        # 2 identical layers × 3 batches × (capture + propagate) lookups;
        # only the first layer's two entries miss
        assert st["misses"] == 2
        assert st["hits"] > st["misses"]

    def test_encdec_walker_hits(self):
        _run("whisper-large-v3", "serial")
        st = capture_cache_stats()
        assert st["hits"] > 0
        # repeated enc layers share entries; dec entries key per batch
        # (enc_out baked into the trace) yet still hit on the second layer
        assert st["hits"] >= st["misses"]

    def test_overlap_speculation_shares_entries(self):
        """The speculative capture must reuse the same compiled entries as
        its exact repair — overlap adds lookups, never compiles."""
        _run("opt-proxy", "serial")
        serial_misses = capture_cache_stats()["misses"]
        _run("opt-proxy", "overlap")
        st = capture_cache_stats()
        assert st["misses"] == serial_misses
        assert st["hits"] > 0
