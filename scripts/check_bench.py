#!/usr/bin/env python
"""Perf-regression gate over the committed BENCH_table4.json trajectory.

Two claims the bench artifact exists to evidence, checked on every CI run
(scripts/check.sh) so a regression cannot land silently behind a
regenerated JSON:

1. **Fused-kernel dispatch story** — the executed-XLA-op ratio of the
   ``xla`` serial row over the ``pallas`` serial row must stay >= 10x
   for BOTH stages on the headline ``moe-*`` row (the paper's
   dispatch-reduction claim, docs/BENCHMARKS.md), and must never invert
   (<= 1x) on any config.  The dense-grid stage-2 ratios sit below 10x
   BY CONSTRUCTION — the xla ``while`` body is counted once (a
   deliberate lower bound, table4_time.py) and small configs have few
   blocks — so the 10x floor applies only where the claim is made.  Op
   counts are deterministic per shape: any drift means code changed.

2. **Routed-MoE overlap stays speculative** — every ``moe-*`` config must
   have overlap rows whose recorded ``pipeline_stats`` show the streaming
   scheduler actually speculating (spec_captures > 0) with the MoE layers
   flip-repaired at plan level rather than degraded to serial re-planning
   (serial_fallbacks == 0, moe_spec_layers > 0, and no flip-budget
   trips).  One of those rows must be the expert-sharded cell
   (``quant_mesh`` set): expert-parallel quantization must stay on the
   speculative path too.

Exit 0 when every gate holds; exit 1 with one line per violation.
"""
from __future__ import annotations

import json
import sys

MIN_OP_RATIO = 10.0


def check(cells: list) -> list:
    errs = []
    by_cfg: dict = {}
    for c in cells:
        by_cfg.setdefault(c.get("config"), []).append(c)

    ratio_checked = 0
    for cfg_name, cs in sorted(by_cfg.items()):
        serial = {c["impl"]: c for c in cs if c.get("pipeline") != "overlap"}
        xla, pallas = serial.get("xla"), serial.get("pallas")
        if not (xla and pallas):
            continue
        floor = MIN_OP_RATIO if cfg_name.startswith("moe-") else 1.0
        for key, stage in (("xla_ops", "stage1"), ("xla_ops_s2", "stage2")):
            nx, np_ = xla.get(key), pallas.get(key)
            if not (nx and np_):
                continue
            ratio_checked += 1
            ratio = nx / np_
            if ratio < floor or ratio <= 1.0:
                errs.append(
                    f"{cfg_name}/{stage}: op-count ratio {ratio:.1f}x "
                    f"(xla {nx} / pallas {np_}) < {floor:.0f}x")
    if not ratio_checked:
        errs.append("no config carries xla/pallas op counts — "
                    "regenerate with `python -m benchmarks.run table4`")

    moe_cfgs = [k for k in by_cfg if k and k.startswith("moe-")]
    if not moe_cfgs:
        errs.append("no moe-* config in the bench artifact")
    for cfg_name in sorted(moe_cfgs):
        overlap = [c for c in by_cfg[cfg_name]
                   if c.get("pipeline") == "overlap"]
        if not overlap:
            errs.append(f"{cfg_name}: no overlap row")
            continue
        for c in overlap:
            tag = cfg_name + ("/expert-sharded" if c.get("quant_mesh")
                              else "/overlap")
            st = c.get("pipeline_stats") or {}
            if not st:
                errs.append(f"{tag}: overlap row carries no pipeline_stats")
                continue
            if not st.get("spec_captures"):
                errs.append(f"{tag}: scheduler never speculated "
                            f"(spec_captures={st.get('spec_captures')})")
            if not st.get("moe_spec_layers"):
                errs.append(f"{tag}: no MoE layer captured speculatively")
            if st.get("serial_fallbacks"):
                errs.append(f"{tag}: regressed to serial re-planning "
                            f"(serial_fallbacks={st['serial_fallbacks']}, "
                            f"flip_budget trips="
                            f"{st.get('fallback_flip_budget')})")
        if not any(c.get("quant_mesh") for c in overlap):
            errs.append(f"{cfg_name}: no expert-sharded overlap cell "
                        f"(quant_mesh)")
    return errs


def main(argv: list) -> int:
    path = argv[1] if len(argv) > 1 else "BENCH_table4.json"
    with open(path) as f:
        cells = json.load(f)
    errs = check(cells)
    if errs:
        for e in errs:
            print(f"[check_bench] FAIL {e}", file=sys.stderr)
        return 1
    print(f"[check_bench] OK {path}: op-count ratios >= "
          f"{MIN_OP_RATIO:.0f}x, MoE overlap rows speculative "
          f"({len(cells)} cells)")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
