#!/usr/bin/env python
"""Chaos soak: seeded randomized fault schedules + end-to-end invariants.

The fault plane (core/faults.py) has deterministic *hand-written*
schedules all over the test suite; this harness is the complement the
robustness story needs — **randomized** schedules across every registered
site, driven through (a) a serving trace under the crash-recovering
supervisor and (b) a tiny quantize run with layer-checkpointed resume,
with an invariant checker that must hold for *any* seed:

Serving invariants (per seed):
  S1  every submitted request reaches exactly one terminal status
      (no loss, no double-finish) and the engine drains
  S2  terminal statuses partition the trace:
      ok + timeout + quarantined + cancelled + error + rejected == n
  S3  deterministic replay: every request that finished ``ok`` under
      faults is token-identical to the same request in a fault-free
      replay of the same trace (recovered completions included)
  S4  counters are self-consistent with statuses: quarantined ==
      #quarantined, prefill_failures == #error, timeout_evictions ==
      #timeout, rejections == #rejected, recovered_completions <= #ok,
      and restarts == replay rounds observed

Quantize invariants (per seed):
  Q1  a walk killed by randomized executor/capture faults (and resume
      loads randomly corrupted via ``checkpoint.load:corrupt``) still
      runs to completion through ``quant.resume=auto`` retries
  Q2  the final packed artifacts are bitwise-identical to an
      uninterrupted fault-free run
  Q3  under randomized ``hessian.cholesky`` corruption the guardrail
      ladder accounts for every flagged lane
      (lanes_flagged == lanes_damp_recovered + lanes_rtn_forced)
      and every packed artifact stays finite

Schedules are pure functions of the seed (per-site rng streams seeded by
(seed, site) — core/faults.py), and the serving trace advances a virtual
clock one unit per tick, so a seed replays identically on any host.

    PYTHONPATH=src python scripts/chaos_soak.py --seeds 0,1,2 --smoke

Exit 0 when every invariant holds for every seed; exit 1 listing every
violation otherwise. The scripts/check.sh chaos leg runs seeds 0,1,2 at
smoke scale; heavier randomized sweeps live under the ``chaos`` pytest
marker (tests/test_chaos.py).
"""
from __future__ import annotations

import argparse
import contextlib
import dataclasses
import os
import shutil
import sys
import tempfile
import warnings
from typing import Dict, List, Optional

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs.registry import get_config  # noqa: E402
from repro.core import faults  # noqa: E402
from repro.core.pipeline import pack_for_serving, quantize_model  # noqa: E402
from repro.data import MarkovLM, calibration_batches  # noqa: E402
from repro.models import transformer as T  # noqa: E402
from repro.serving.supervisor import SupervisedEngine  # noqa: E402

ARCH = "opt-proxy"

# serving sites get small per-hit probabilities drawn from these ranges;
# kernels.pallas_dispatch is armed too (site coverage) but cannot hit on
# a CPU host where impl=auto resolves to the XLA path before the pallas
# branch traces — the degradation path itself is pinned in test_faults.py
_SERVE_SITES = {
    "serve.engine_step": (0.02, 0.08),
    "serve.decode_step": (0.02, 0.06),
    "serve.prefill_chunk": (0.02, 0.06),
    "kernels.pallas_dispatch": (0.01, 0.05),
}
_QUANT_KILL_SITES = {
    "plan.stage1_executor": (0.02, 0.08),
    "plan.stage2_executor": (0.02, 0.08),
    "stream.capture_forward": (0.02, 0.08),
}


def _arm_string(sites: Dict[str, tuple], rng: np.random.Generator,
                mode: Optional[str] = None) -> str:
    parts = []
    for site, (lo, hi) in sites.items():
        p = float(rng.uniform(lo, hi))
        spec = f"{site}@p{p:.4f}"
        if mode:
            spec += f":{mode}"
        parts.append(spec)
    return ",".join(parts)


# ---------------------------------------------------------------------------
# Serving soak
# ---------------------------------------------------------------------------

def _serving_setup(smoke: bool, seed: int):
    cfg = get_config(ARCH, smoke=True)
    cfg.serve = dataclasses.replace(
        cfg.serve, scheduler="continuous", max_batch=2, prefill_chunk=3,
        quantized=False, supervise=True,
        # the soak probes invariants under arbitrarily many crashes, not
        # the restart budget (budget exhaustion is pinned in
        # tests/test_supervisor.py) — keep recovery unbounded here
        max_restarts=10_000)
    key = jax.random.PRNGKey(0)
    params = T.init_params(cfg.model, key)
    rng = np.random.default_rng(1000 + seed)
    n = 6 if smoke else 12
    reqs = []
    for _ in range(n):
        s0 = int(rng.choice([4, 6, 8]))
        toks = rng.integers(1, cfg.model.vocab_size,
                            size=(1, s0)).astype(np.int32)
        reqs.append(({"tokens": jnp.asarray(toks)},
                     int(rng.choice([3, 5, 8]))))
    max_len = 8 + 8 + 2
    return cfg, params, reqs, max_len


def run_serving_soak(seed: int, smoke: bool) -> List[str]:
    """Drive one seeded randomized fault schedule through a serving trace
    (virtual clock, one unit per tick, request i submitted at tick 2*i —
    deterministic on any host) and check invariants S1–S4."""
    violations: List[str] = []
    cfg, params, reqs, max_len = _serving_setup(smoke, seed)
    rng = np.random.default_rng(seed)
    arm = _arm_string(_SERVE_SITES, rng)

    def drive(arm_spec: str):
        clock = [0.0]
        eng = SupervisedEngine(cfg, params, max_len=max_len,
                               clock=lambda: clock[0])
        statuses: Dict[int, str] = {}
        tokens: Dict[int, np.ndarray] = {}
        finish_count: Dict[int, int] = {}
        rid_of: Dict[int, int] = {}       # request index -> supervisor rid
        tick = 0
        max_ticks = 5000
        ctx = faults.inject(*[s for s in arm_spec.split(",") if s],
                            seed=seed) if arm_spec else \
            contextlib.nullcontext()
        with ctx, warnings.catch_warnings():
            warnings.simplefilter("ignore")
            while len(statuses) < len(reqs):
                for i, (b, mnt) in enumerate(reqs):
                    if i not in rid_of and tick >= 2 * i:
                        rid_of[i] = eng.submit(b, max_new_tokens=mnt)
                if eng.idle and len(rid_of) < len(reqs):
                    tick += 1             # nothing in flight yet: wait
                    clock[0] = float(tick)
                    continue
                rep = eng.step()
                tick += 1
                clock[0] = float(tick)
                for f in rep.finished:
                    finish_count[f.rid] = finish_count.get(f.rid, 0) + 1
                    idx = next(i for i, r in rid_of.items() if r == f.rid)
                    statuses[idx] = f.status
                    tokens[idx] = np.asarray(f.tokens)
                if tick > max_ticks:
                    break
        return {"statuses": statuses, "tokens": tokens,
                "finish_count": finish_count, "rid_of": rid_of,
                "engine_stats": eng.engine_stats(), "idle": eng.idle,
                "ticks": tick}

    ref = drive("")
    got = drive(arm)

    n = len(reqs)
    # S1: drained, every request finished exactly once
    if not got["idle"] or len(got["statuses"]) != n:
        violations.append(
            f"[seed {seed}] S1: engine did not drain "
            f"({len(got['statuses'])}/{n} terminal after "
            f"{got['ticks']} ticks)")
    for rid, c in got["finish_count"].items():
        if c != 1:
            violations.append(
                f"[seed {seed}] S1: rid {rid} finished {c} times")
    # S2: statuses partition the trace (no rejections possible here:
    # unbounded queue; cancel not exercised in the soak)
    counts: Dict[str, int] = {}
    for s in got["statuses"].values():
        counts[s] = counts.get(s, 0) + 1
    if sum(counts.values()) != n:
        violations.append(
            f"[seed {seed}] S2: statuses {counts} do not partition n={n}")
    known = {"ok", "timeout", "quarantined", "cancelled", "error"}
    for s in counts:
        if s not in known:
            violations.append(f"[seed {seed}] S2: unknown status {s!r}")
    # S3: deterministic replay — ok outputs token-identical to fault-free
    for i, s in got["statuses"].items():
        if s != "ok":
            continue
        if not np.array_equal(got["tokens"][i], ref["tokens"][i]):
            violations.append(
                f"[seed {seed}] S3: request {i} finished ok but its "
                f"tokens differ from the fault-free replay "
                f"({got['tokens'][i].tolist()} vs "
                f"{ref['tokens'][i].tolist()})")
    # S4: counters self-consistent with statuses
    es = got["engine_stats"]
    for counter, status in (("quarantined", "quarantined"),
                            ("prefill_failures", "error"),
                            ("timeout_evictions", "timeout")):
        if es.get(counter, 0) != counts.get(status, 0):
            violations.append(
                f"[seed {seed}] S4: {counter}={es.get(counter, 0)} but "
                f"#{status} statuses={counts.get(status, 0)}")
    if es.get("rejections", 0) != 0:
        violations.append(
            f"[seed {seed}] S4: rejections={es['rejections']} on an "
            "unbounded queue")
    if es.get("recovered_completions", 0) > counts.get("ok", 0):
        violations.append(
            f"[seed {seed}] S4: recovered_completions="
            f"{es['recovered_completions']} > ok={counts.get('ok', 0)}")
    return violations


# ---------------------------------------------------------------------------
# Quantize soak
# ---------------------------------------------------------------------------

def _quant_cfg(ckpt_dir: str = ""):
    cfg = get_config(ARCH, smoke=True)
    cfg.quant.calib_batches = 2
    cfg.quant.calib_batch_size = 4
    cfg.quant.calib_seq_len = 32
    if ckpt_dir:
        cfg.quant.ckpt_dir = ckpt_dir
        cfg.quant.resume = "auto"
    return cfg


def _calib(cfg):
    data = MarkovLM(cfg.model.vocab_size, seed=7)
    return calibration_batches(data, cfg.quant.calib_batches,
                               cfg.quant.calib_batch_size,
                               cfg.quant.calib_seq_len)


def _packed_leaves(cfg, params, calib):
    params_q, report = quantize_model(cfg, params, calib)
    packed = pack_for_serving(cfg, params_q)
    return [np.asarray(x) for x in jax.tree_util.tree_leaves(
        jax.device_get(packed))], report


def run_quantize_soak(seed: int, smoke: bool) -> List[str]:
    violations: List[str] = []
    rng = np.random.default_rng(seed)
    key = jax.random.PRNGKey(0)
    base = get_config(ARCH, smoke=True)
    params = T.init_params(base.model, key)

    clean_leaves, _ = _packed_leaves(_quant_cfg(), params, _calib(_quant_cfg()))

    work = tempfile.mkdtemp(prefix=f"chaos_soak_{seed}_")
    try:
        cfg = _quant_cfg(os.path.join(work, "ckpt"))
        calib = _calib(cfg)
        arm = _arm_string(_QUANT_KILL_SITES, rng)
        # resume loads are occasionally corrupted too: quant.resume=auto
        # must warn + start fresh, never load garbage (Q1 still completes,
        # Q2 still bitwise-identical)
        arm += f",checkpoint.load@p{float(rng.uniform(0.1, 0.3)):.4f}:corrupt"
        attempts = 0
        leaves = None
        with faults.inject(*arm.split(","), seed=seed), \
                warnings.catch_warnings():
            warnings.simplefilter("ignore")
            while attempts < 12 and leaves is None:
                attempts += 1
                try:
                    leaves, _ = _packed_leaves(cfg, params, calib)
                except faults.FaultError:
                    continue        # killed; next attempt resumes
        if leaves is None:
            # schedule too hot for the attempt budget: disarm and finish
            # through one last resume (still exercises Q1's resume path)
            with warnings.catch_warnings():
                warnings.simplefilter("ignore")
                leaves, _ = _packed_leaves(cfg, params, calib)
        if len(leaves) != len(clean_leaves):
            violations.append(
                f"[seed {seed}] Q2: leaf count {len(leaves)} != "
                f"{len(clean_leaves)}")
        else:
            for i, (a, b) in enumerate(zip(clean_leaves, leaves)):
                if a.dtype != b.dtype or not np.array_equal(
                        a.view(np.uint8), b.view(np.uint8)):
                    violations.append(
                        f"[seed {seed}] Q2: leaf {i} differs from the "
                        f"fault-free run (after {attempts} attempts)")
                    break
    finally:
        shutil.rmtree(work, ignore_errors=True)

    # Q3: randomized Hessian corruption, guardrail accounting
    gcfg = _quant_cfg()
    p = float(rng.uniform(0.15, 0.4))
    mode = "nan" if rng.random() < 0.5 else "nonpsd"
    with faults.inject(f"hessian.cholesky@p{p:.4f}:{mode}", seed=seed), \
            warnings.catch_warnings():
        warnings.simplefilter("ignore")
        leaves, report = _packed_leaves(gcfg, params, _calib(gcfg))
    gs = report.guardrail_stats
    if gs.get("lanes_flagged", 0) != (gs.get("lanes_damp_recovered", 0)
                                      + gs.get("lanes_rtn_forced", 0)):
        violations.append(f"[seed {seed}] Q3: guardrail ledger does not "
                          f"balance: {gs}")
    for i, a in enumerate(leaves):
        if np.issubdtype(a.dtype, np.floating) and not np.isfinite(a).all():
            violations.append(
                f"[seed {seed}] Q3: non-finite values in packed leaf {i} "
                f"under hessian.cholesky@p{p:.4f}:{mode}")
            break
    return violations


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--seeds", default="0,1,2",
                    help="comma-separated seed list")
    ap.add_argument("--smoke", action="store_true",
                    help="smoke scale (check.sh leg)")
    ap.add_argument("--serving-only", action="store_true")
    ap.add_argument("--quantize-only", action="store_true")
    args = ap.parse_args(argv)
    seeds = [int(s) for s in args.seeds.split(",") if s.strip()]
    violations: List[str] = []
    for seed in seeds:
        if not args.quantize_only:
            v = run_serving_soak(seed, args.smoke)
            print(f"[chaos_soak] seed {seed} serving: "
                  f"{'OK' if not v else f'{len(v)} violations'}")
            violations += v
        if not args.serving_only:
            v = run_quantize_soak(seed, args.smoke)
            print(f"[chaos_soak] seed {seed} quantize: "
                  f"{'OK' if not v else f'{len(v)} violations'}")
            violations += v
    if violations:
        print(f"[chaos_soak] {len(violations)} invariant violations:")
        for v in violations:
            print(f"  {v}")
        return 1
    print(f"[chaos_soak] all invariants hold over {len(seeds)} seeds")
    return 0


if __name__ == "__main__":
    sys.exit(main())
