"""Docs cross-reference check (scripts/check.sh).

Every ``SOMENAME.md`` mentioned anywhere under ``src/`` or ``scripts/``
(docstrings, comments) or cited by another doc under ``docs/`` must
exist — at the referenced path, at the repo root, or in ``docs/``.
Guards against dangling design-doc citations: the codebase cited
"DESIGN.md §2" for three PRs before the file existed, and doc-to-doc
links (docs/FAULTS.md ↔ docs/SERVING.md) rot just as easily.

Exit 0 and a summary line when clean; exit 1 listing every missing
reference and its citing files otherwise.
"""
from __future__ import annotations

import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
_MD_REF = re.compile(r"[A-Za-z0-9_][A-Za-z0-9_/.-]*\.md\b")

# placeholder/generated names, not citations: the docstring example
# above, and the bench's generated perf summary (untracked output)
_IGNORE = {"SOMENAME.md", "artifacts/perf_summary.md"}


def _scan_files():
    yield from sorted((ROOT / "src").rglob("*.py"))
    yield from sorted((ROOT / "scripts").rglob("*.py"))
    yield from sorted((ROOT / "docs").rglob("*.md"))


def check() -> int:
    missing: dict[str, set] = {}
    n_refs = 0
    for f in _scan_files():
        for ref in set(_MD_REF.findall(f.read_text(encoding="utf-8"))):
            if ref in _IGNORE:
                continue
            n_refs += 1
            candidates = (ROOT / ref,
                          ROOT / pathlib.Path(ref).name,
                          ROOT / "docs" / pathlib.Path(ref).name)
            if not any(c.is_file() for c in candidates):
                missing.setdefault(ref, set()).add(
                    str(f.relative_to(ROOT)))
    if missing:
        for ref, files in sorted(missing.items()):
            print(f"MISSING {ref}  (referenced by "
                  f"{', '.join(sorted(files))})")
        return 1
    print(f"docs-xref OK ({n_refs} doc references under src/, scripts/ "
          "and docs/ all resolve)")
    return 0


if __name__ == "__main__":
    sys.exit(check())
